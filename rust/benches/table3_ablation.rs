//! Table 3: ablation of the two-stage training strategy (§3.3 / §4.4),
//! on the MMLU-like benchmark.
//!
//! * RevFFN (full)        — stage 1 warm-up then stage 2 joint tuning.
//! * w/o Stage 1          — joint training from the start.
//! * w/o Stage 2          — projections only (PEFT-like configuration).
//!
//! Expected shape: full > w/o-stage1 > w/o-stage2, with a large gap to
//! the projections-only row (paper: 66.7 / 57.1 / 54.5).
//!
//!     cargo bench --bench table3_ablation -- [steps] [pretrain]

use revffn::config::RunConfig;
use revffn::coordinator::Trainer;
use revffn::engine::Method;
use revffn::runtime::Device;
use revffn::util::bench;

fn main() -> anyhow::Result<()> {
    let args: Vec<u64> = std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let steps = args.first().copied().unwrap_or(60);
    let pretrain = args.get(1).copied().unwrap_or(40);
    let device = Device::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;

    bench::section(&format!("Table 3 — two-stage ablation ({steps} total steps/config)"));
    println!("{:<34} {:>10} {:>9}", "Configuration", "mmlu-like", "paper");

    let configs: [(&str, u64, u64, f64); 3] = [
        ("RevFFN (Full Method)", steps / 5, steps - steps / 5, 66.7),
        ("w/o Stage 1 (Joint Training)", 0, steps, 57.1),
        ("w/o Stage 2 (Projections Only)", steps, 0, 54.5),
    ];

    let mut scores = Vec::new();
    for (label, s1, s2, paper) in configs {
        let mut cfg = RunConfig::default_tiny("artifacts/tiny");
        cfg.method = Method::Revffn;
        cfg.schedule.stage1_steps = s1;
        cfg.schedule.stage2_steps = s2;
        cfg.data.pretrain_steps = pretrain;
        cfg.eval_every = 0;
        cfg.out_dir = format!("runs/table3/{}", label.replace([' ', '/', '('], "_")).into();
        let mut trainer = Trainer::new(&device, cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
        let report = trainer.run().map_err(|e| anyhow::anyhow!("{label}: {e}"))?;
        let s = trainer.bench_scores(24, 7).map_err(|e| anyhow::anyhow!("{e}"))?;
        bench::row(label, format!("{:>9.1}% {:>8.1}", s.mmlu_like, paper));
        eprintln!(
            "   [{label}] eval_loss {:.3}, train {:.3}->{:.3}",
            report.eval_loss.unwrap_or(f32::NAN),
            report.first_loss,
            report.final_loss
        );
        scores.push((label, s.mmlu_like));
    }

    println!("\nshape check (paper: Full > w/o-S1 > w/o-S2):");
    let full = scores[0].1;
    let no_s1 = scores[1].1;
    let no_s2 = scores[2].1;
    println!("  Full {:.1} vs w/o-S1 {:.1} vs w/o-S2 {:.1}", full, no_s1, no_s2);
    println!(
        "  [{}] full >= w/o-stage1   [{}] w/o-stage1 >= w/o-stage2",
        if full >= no_s1 { "ok" } else { "MISS" },
        if no_s1 >= no_s2 { "ok" } else { "MISS" },
    );
    Ok(())
}
