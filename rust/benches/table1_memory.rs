//! Table 1 (memory column): peak VRAM per fine-tuning method at real
//! Qwen1.5-MoE-A2.7B geometry, under the paper's protocol (80 GB budget,
//! per-method maximized batch) and at a fixed batch; plus the XLA
//! live-buffer calibration on the actually-lowered tiny graphs.
//!
//!     cargo bench --bench table1_memory

use revffn::memory::{
    calib, format_table, ordering_checks, paper_table1, table1_memory, Assumptions, Geometry,
    Method,
};
use revffn::memory::report::{activation_reduction, rev_reduction};
use revffn::util::bench;

fn main() {
    bench::section("Table 1 — Peak VRAM, Qwen1.5-MoE-A2.7B, seq 2048, 80 GB budget");

    for (name, assume) in [
        ("paper-calibrated assumptions (bf16, 8-bit moments)", Assumptions::paper_calibrated()),
        ("bf16 mixed-precision assumptions (fp32 moments+master)", Assumptions::bf16_mixed()),
    ] {
        for (proto, fixed) in [("maximized batch", None), ("fixed batch B=64", Some(64))] {
            let rows = table1_memory(Geometry::qwen15_moe_a27b(), assume, 2048, 80.0, fixed);
            print!("{}", format_table(&rows, &format!("-- {name}, {proto} --")));
            if let Some(r) = rev_reduction(&rows) {
                print!("   RevFFN vs SFT+ckpt: peak {:.0}%", r * 100.0);
            }
            if let Some(r) = activation_reduction(&rows) {
                println!(", activations {:.0}% (paper text: 49%)", r * 100.0);
            }
            for (check, ok) in ordering_checks(&rows) {
                println!("   [{}] {check}", if ok { "ok" } else { "MISS" });
            }
            println!();
        }
    }

    bench::section("Paper Table 1 reference rows");
    for m in Method::ALL {
        let (gb, tput) = paper_table1(m);
        bench::row(m.label(), format!("{gb:>6.1} GB   {tput:>6.1} samples/s"));
    }

    bench::section("Calibration vs XLA live-buffer analysis (tiny, f32)");
    match calib::calibrate("artifacts/tiny") {
        Ok(rows) if !rows.is_empty() => {
            println!(
                "{:<16} {:>16} {:>16} {:>8}",
                "variant", "XLA temp (B)", "analytic (B)", "ratio"
            );
            for r in &rows {
                println!(
                    "{:<16} {:>16} {:>16.0} {:>8.2}",
                    r.variant, r.measured_temp_bytes, r.analytic_act_bytes, r.ratio
                );
            }
        }
        _ => println!("(artifacts/tiny not analyzed — run `make artifacts`)"),
    }
    match calib::reversible_vs_naive("artifacts/tiny") {
        Ok(Some((rev, naive))) => {
            println!(
                "\nreversible vs naive backward, XLA temp bytes: {rev} vs {naive} => {:.2}x reduction",
                naive as f64 / rev as f64
            );
        }
        _ => println!("(revffn_naive calibration artifact unavailable)"),
    }
}
