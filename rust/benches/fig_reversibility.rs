//! Fig. 1 / §3.1 claim: "reconstruction error below machine epsilon" —
//! the quantitative content of the architecture figure.
//!
//! Measures max-abs reconstruction error of the inverse pass (one
//! fixed-point iteration, as the paper prescribes) through the full
//! reversible stack, at init and after training steps, plus the
//! round-trip wall time vs a forward pass (the recompute overhead that
//! drives the Table-1 throughput trade-off).
//!
//!     cargo bench --bench fig_reversibility

use revffn::data::synthetic::CorpusConfig;
use revffn::data::{encode_corpus, Batcher};
use revffn::engine::{Method, Session};
use revffn::runtime::{literal, Artifact, Program, Stepper};
use revffn::util::bench;

fn reconstruct_err(
    artifact: &Artifact,
    prog: &Program,
    stepper: &mut Stepper,
    token_seed: usize,
) -> anyhow::Result<f32> {
    let io = &artifact.manifest.io;
    let params = stepper.materialize_params().map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut inputs = params.to_literals().map_err(|e| anyhow::anyhow!("{e}"))?;
    let tokens: Vec<i32> = (0..io.batch_size * io.seq_len)
        .map(|i| ((i * 31 + token_seed * 97) % 500) as i32 + 5)
        .collect();
    inputs.push(
        literal::i32_literal(&tokens, &[io.batch_size, io.seq_len])
            .map_err(|e| anyhow::anyhow!("{e}"))?,
    );
    let out = prog.run(&inputs).map_err(|e| anyhow::anyhow!("{e}"))?;
    literal::scalar_to_f32(&out[0]).map_err(|e| anyhow::anyhow!("{e}"))
}

fn main() -> anyhow::Result<()> {
    // one session: the RevFFN inference model + corpus/tokenizer, plus
    // cached access to the auxiliary reconstruct programs
    let mut session = Session::builder("artifacts/tiny")
        .method(Method::Revffn)
        .corpus(CorpusConfig { n_train: 128, ..Default::default() })
        .build()
        .map_err(|e| anyhow::anyhow!("{e} — run `make artifacts`"))?;
    let (artifact, prog_arc) = session
        .program("reconstruct", "reconstruct")
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    bench::section("Fig 1 / §3.1 — reversible reconstruction error (f32 eps = 1.19e-7)");

    // at init, over several token batches
    let mut worst: f32 = 0.0;
    for seed in 0..5 {
        let e = reconstruct_err(&artifact, &prog_arc, &mut session.stepper, seed)?;
        worst = worst.max(e);
    }
    bench::row("max error @ init (5 batches)", format!("{worst:.3e}"));

    // fixed-point iteration sweep + the exactly-invertible symmetric
    // ablation: the paper claims 'below machine epsilon' with ONE
    // iteration — quantify what one iteration actually buys, and what
    // exactness costs (the Reformer-style F(X2) variant).
    for (variant, label) in [
        ("reconstruct_iters2", "2 fixed-point iterations"),
        ("reconstruct_iters4", "4 fixed-point iterations"),
        ("reconstruct_symmetric", "symmetric variant (exact inverse)"),
    ] {
        let Ok((art, prog)) = session.program(variant, "reconstruct") else {
            bench::row(label, "(artifact missing)");
            continue;
        };
        let mut worst: f32 = 0.0;
        for seed in 0..3 {
            let e = reconstruct_err(&art, &prog, &mut session.stepper, seed)?;
            worst = worst.max(e);
        }
        bench::row(label, format!("{worst:.3e}"));
    }

    // after training steps the weights grow — error must stay at fp noise
    let (b, s) = session.stepper.batch_shape();
    let samples = encode_corpus(&session.tokenizer, &session.corpus.train, s);
    let mut batcher = Batcher::new(samples, b, s, 0);
    for checkpoint in [5u64, 20] {
        while session.stepper.step < checkpoint {
            let batch = batcher.next_batch();
            session
                .stepper
                .train_step(&batch, 3e-4)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        let mut worst: f32 = 0.0;
        for seed in 0..3 {
            let e = reconstruct_err(&artifact, &prog_arc, &mut session.stepper, seed)?;
            worst = worst.max(e);
        }
        bench::row(
            &format!("max error after {checkpoint} train steps"),
            format!("{worst:.3e}"),
        );
    }

    // recompute overhead: inverse+forward round-trip vs forward alone
    bench::section("Recompute overhead (round-trip vs forward)");
    let io_bs = session.stepper.batch_shape();
    let tokens: Vec<i32> = (0..io_bs.0 * io_bs.1).map(|i| (i % 300) as i32 + 5).collect();
    let fwd_t = bench::time(1, 5, || {
        let _ = session.stepper.forward(&tokens).unwrap();
    });
    bench::row("forward", fwd_t.fmt_ms());
    let params_lits = session
        .stepper
        .materialize_params()
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .to_literals()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let io = &artifact.manifest.io;
    let rt_tokens: Vec<i32> =
        (0..io.batch_size * io.seq_len).map(|i| (i % 300) as i32 + 5).collect();
    let rt_t = bench::time(1, 5, || {
        let mut inputs = params_lits.clone();
        inputs.push(
            literal::i32_literal(&rt_tokens, &[io.batch_size, io.seq_len]).unwrap(),
        );
        let _ = prog_arc.run(&inputs).unwrap();
    });
    bench::row("forward + full inverse round-trip", rt_t.fmt_ms());
    println!(
        "\nround-trip / forward = {:.2}x (the §3.1 'modest increase in computation')",
        rt_t.median_s / fwd_t.median_s
    );
    Ok(())
}
