//! Table 1 (throughput column): measured optimizer-step wall time per
//! fine-tuning method on the AOT-lowered tiny artifacts, all at the SAME
//! batch shape, reported as samples/s plus the normalized ratio vs
//! SFT+Checkpointing (the shape the paper's column implies).
//!
//! Absolute numbers are CPU-PJRT, not H800; what must reproduce is the
//! *relative* structure: PEFT fastest, full-FT+recompute slowest,
//! RevFFN between (recompute cost, but reversible recompute only).
//!
//!     cargo bench --bench table1_throughput

use revffn::data::synthetic::{Corpus, CorpusConfig};
use revffn::data::{encode_corpus, Batcher, Tokenizer};
use revffn::engine::Method;
use revffn::memory::paper_table1;
use revffn::runtime::{Artifact, Device, ProgramCache, Stepper};
use revffn::util::bench;

fn main() -> anyhow::Result<()> {
    let device = Device::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    let cache = ProgramCache::new();

    bench::section("Table 1 — Throughput (tiny artifacts, CPU PJRT, equal batch)");

    let corpus = Corpus::generate(CorpusConfig { n_train: 256, ..Default::default() });

    let mut results: Vec<(Method, f64)> = Vec::new(); // (method, samples/s)
    for method in Method::ALL {
        let variant = method.eval_variant();
        let dir = format!("artifacts/tiny/{variant}");
        let artifact = match Artifact::load(&dir) {
            Ok(a) => a,
            Err(e) => {
                println!("{variant:<16} SKIPPED ({e})");
                continue;
            }
        };
        let mut stepper = Stepper::new(&device, &cache, artifact)
            .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
        let (b, s) = stepper.batch_shape();
        let tokenizer = Tokenizer::train(&corpus.train_text(), stepper.vocab_size())
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let samples = encode_corpus(&tokenizer, &corpus.train, s);
        let mut batcher = Batcher::new(samples, b, s, 0);

        // warmup (compile-amortized) + timed steps
        let mut times = Vec::new();
        for i in 0..7 {
            let batch = batcher.next_batch();
            let stats = stepper
                .train_step(&batch, 1e-4)
                .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
            if i >= 2 {
                times.push(stats.step_time_s);
            }
        }
        let t = bench::summarize(&times);
        let sps = b as f64 / t.median_s;
        results.push((method, sps));
        bench::row(method.label(), format!("{:>8.2} samples/s   ({})", sps, t.fmt_ms()));
    }

    bench::section("Normalized vs SFT+Checkpointing (ours | paper)");
    let ours_sft = results
        .iter()
        .find(|(m, _)| *m == Method::Sft)
        .map(|(_, s)| *s)
        .unwrap_or(1.0);
    let paper_sft = paper_table1(Method::Sft.memory_method()).1;
    for (method, sps) in &results {
        let paper_ratio = paper_table1(method.memory_method()).1 / paper_sft;
        bench::row(
            method.label(),
            format!("{:>6.2}x | {:>6.2}x", sps / ours_sft, paper_ratio),
        );
    }
    println!(
        "\nshape checks: PEFT > full-FT methods; RevFFN vs SFT ratio paper={:.2}x",
        paper_table1(Method::Revffn.memory_method()).1 / paper_sft
    );
    Ok(())
}
