//! Table 1 (throughput column): measured optimizer-step wall time per
//! fine-tuning method on the AOT-lowered tiny artifacts, all at the SAME
//! batch shape, reported as samples/s plus the normalized ratio vs
//! SFT+Checkpointing (the shape the paper's column implies).
//!
//! Paths timed per method:
//!
//! * `fused` — one literal-path `train_step` per optimizer step.
//! * `fused_buffers` — same step on the device-resident buffer path
//!   (params + moments pinned as `PjRtBuffer`s; only batch up, scalars
//!   down). The row records measured host transfers per step.
//! * `accum_device` / `accum_host` (methods that support accumulation,
//!   `grad_accum=2`) — the literal-resident accumulate loop vs the
//!   legacy host-summing baseline (kept as
//!   `grad_step`/`apply_accumulated_host`) so the step-time delta is
//!   tracked on the same config from here on.
//! * `accum_buffers` — the fully buffer-resident accumulate loop
//!   (`grad_step_buffers` → `add_buffers`/`finish_buffers` →
//!   `apply_accumulated_buffers`).
//!
//! Results go to stdout AND to `BENCH_throughput.json` (machine-readable:
//! samples/s, tokens/s, step-time p50/p95, host transfers per method and
//! path).
//!
//!     cargo bench --bench table1_throughput

use revffn::data::synthetic::{Corpus, CorpusConfig};
use revffn::data::{encode_corpus, Batcher, Tokenizer};
use revffn::engine::Method;
use revffn::memory::paper_table1;
use revffn::runtime::{Artifact, Device, GradAccumulator, ProgramCache, Stepper};
use revffn::util::bench::{self, Timing};
use revffn::util::json::{Json, ObjBuilder};

/// Microbatches per accumulate-path optimizer step.
const GRAD_ACCUM: usize = 2;
/// Timed + discarded iterations per (method, path).
const ITERS: usize = 5;
const WARMUP: usize = 2;

const OUT_PATH: &str = "BENCH_throughput.json";

fn row_json(
    method: Method,
    path: &str,
    b: usize,
    s: usize,
    samples_per_step: usize,
    t: &Timing,
    device_resident: Option<bool>,
    transfers_per_step: Option<(f64, f64)>,
) -> Json {
    let sps = samples_per_step as f64 / t.median_s.max(1e-12);
    let mut o = ObjBuilder::new()
        .str("method", method.name())
        .str("path", path)
        .num("batch_size", b as f64)
        .num("seq_len", s as f64)
        .num("samples_per_s", sps)
        .num("tokens_per_s", sps * s as f64)
        .num("step_p50_ms", t.median_s * 1e3)
        .num("step_p95_ms", t.p95_s * 1e3)
        .num("iters", t.iters as f64);
    if let Some(d) = device_resident {
        o = o.bool("device_resident", d);
    }
    if let Some((up, down)) = transfers_per_step {
        o = o.num("uploads_per_step", up).num("downloads_per_step", down);
    }
    o.build()
}

fn main() -> anyhow::Result<()> {
    // telemetry on: the PJRT transfer counters and stage histograms
    // accumulate across every timed path and land in the output doc
    revffn::obs::registry::arm();
    let device = Device::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    let cache = ProgramCache::new();

    bench::section("Table 1 — Throughput (tiny artifacts, CPU PJRT, equal batch)");

    let corpus = Corpus::generate(CorpusConfig { n_train: 256, ..Default::default() });

    let mut rows: Vec<Json> = Vec::new();
    let mut results: Vec<(Method, f64)> = Vec::new(); // (method, fused samples/s)
    for method in Method::ALL {
        let variant = method.eval_variant();
        let dir = format!("artifacts/tiny/{variant}");
        let artifact = match Artifact::load(&dir) {
            Ok(a) => a,
            Err(e) => {
                println!("{variant:<16} SKIPPED ({e})");
                continue;
            }
        };
        let mut stepper = Stepper::new(&device, &cache, artifact)
            .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
        let (b, s) = stepper.batch_shape();
        let tokenizer = Tokenizer::train(&corpus.train_text(), stepper.vocab_size())
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let samples = encode_corpus(&tokenizer, &corpus.train, s);
        let mut batcher = Batcher::new(samples, b, s, 0);

        // -- fused path: one train_step per optimizer step ----------------
        let mut times = Vec::new();
        let t_start = device.transfer_stats();
        for i in 0..WARMUP + ITERS {
            let batch = batcher.next_batch();
            let stats = stepper
                .train_step(&batch, 1e-4)
                .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
            if i >= WARMUP {
                times.push(stats.step_time_s);
            }
        }
        let n_steps = (WARMUP + ITERS) as f64;
        let moved = device.transfer_stats().since(&t_start);
        let t = bench::summarize(&times);
        let sps = b as f64 / t.median_s;
        results.push((method, sps));
        rows.push(row_json(
            method,
            "fused",
            b,
            s,
            b,
            &t,
            None,
            Some((moved.uploads as f64 / n_steps, moved.downloads as f64 / n_steps)),
        ));
        bench::row(method.label(), format!("{sps:>8.2} samples/s   ({})", t.fmt_ms()));

        // -- fused path, buffer-resident state (this PR) -------------------
        if stepper.enable_device_state().is_ok() {
            let mut times = Vec::new();
            let t_start = device.transfer_stats();
            for i in 0..WARMUP + ITERS {
                let batch = batcher.next_batch();
                let stats = stepper
                    .train_step(&batch, 1e-4)
                    .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
                if i >= WARMUP {
                    times.push(stats.step_time_s);
                }
            }
            let moved = device.transfer_stats().since(&t_start);
            // false here means the runtime could not untuple buffer
            // outputs and the stepper fell back mid-bench
            let resident = stepper.is_device_resident();
            let tb = bench::summarize(&times);
            let up = moved.uploads as f64 / n_steps;
            let down = moved.downloads as f64 / n_steps;
            rows.push(row_json(
                method,
                "fused_buffers",
                b,
                s,
                b,
                &tb,
                Some(resident),
                Some((up, down)),
            ));
            bench::row(
                &format!("{} [fused buffers]", method.label()),
                format!(
                    "{:>8.2} samples/s   ({})  {up:.1} up / {down:.1} down per step",
                    b as f64 / tb.median_s,
                    tb.fmt_ms()
                ),
            );
            stepper
                .disable_device_state()
                .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
        }

        if !(method.supports_grad_accum() && stepper.supports_accumulation()) {
            continue;
        }

        // -- accumulate path, literal-resident (this PR) ------------------
        let mut accum = GradAccumulator::for_stepper(&stepper);
        let run_accum = |stepper: &mut Stepper,
                         batcher: &mut Batcher,
                         accum: &mut GradAccumulator|
         -> anyhow::Result<()> {
            for _ in 0..GRAD_ACCUM {
                let batch = batcher.next_batch();
                let out = stepper
                    .grad_step_literals(&batch)
                    .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
                accum.add(out.grads).map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
            }
            let mean = accum.finish().map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
            stepper
                .apply_accumulated(&mean, 1e-4)
                .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
            Ok(())
        };
        let mut times = Vec::new();
        for i in 0..WARMUP + ITERS {
            let t0 = std::time::Instant::now();
            run_accum(&mut stepper, &mut batcher, &mut accum)?;
            if i >= WARMUP {
                times.push(t0.elapsed().as_secs_f64());
            }
        }
        let td = bench::summarize(&times);
        let n_samples = b * GRAD_ACCUM;
        rows.push(row_json(
            method,
            "accum_device",
            b,
            s,
            n_samples,
            &td,
            Some(accum.is_device_resident()),
            None,
        ));
        bench::row(
            &format!("{} [accum x{GRAD_ACCUM} device]", method.label()),
            format!("{:>8.2} samples/s   ({})", n_samples as f64 / td.median_s, td.fmt_ms()),
        );

        // -- accumulate path, fully buffer-resident (this PR) --------------
        if stepper.supports_device_accum() && stepper.enable_device_state().is_ok() {
            let run_buffers = |stepper: &mut Stepper,
                               batcher: &mut Batcher|
             -> anyhow::Result<f64> {
                let mut accum = GradAccumulator::for_stepper(stepper);
                let t0 = std::time::Instant::now();
                for _ in 0..GRAD_ACCUM {
                    let batch = batcher.next_batch();
                    let out = stepper
                        .grad_step_buffers(&batch)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                    accum.add_buffers(out.grads).map_err(|e| anyhow::anyhow!("{e}"))?;
                }
                let mean = accum.finish_buffers().map_err(|e| anyhow::anyhow!("{e}"))?;
                stepper
                    .apply_accumulated_buffers(&mean, 1e-4)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                Ok(t0.elapsed().as_secs_f64())
            };
            let mut times = Vec::new();
            let t_start = device.transfer_stats();
            let mut failed = None;
            for i in 0..WARMUP + ITERS {
                match run_buffers(&mut stepper, &mut batcher) {
                    Ok(dt) if i >= WARMUP => times.push(dt),
                    Ok(_) => {}
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
            match failed {
                Some(e) => {
                    // buffer path unsupported on this runtime — recover the
                    // literal state if it is still current, else surface
                    println!("{variant:<16} accum_buffers SKIPPED ({e})");
                    if stepper.can_abandon_buffers() {
                        stepper.abandon_buffers().map_err(|e| anyhow::anyhow!("{e}"))?;
                    } else {
                        stepper
                            .disable_device_state()
                            .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
                    }
                }
                None => {
                    let moved = device.transfer_stats().since(&t_start);
                    let up = moved.uploads as f64 / n_steps;
                    let down = moved.downloads as f64 / n_steps;
                    let tbuf = bench::summarize(&times);
                    rows.push(row_json(
                        method,
                        "accum_buffers",
                        b,
                        s,
                        n_samples,
                        &tbuf,
                        Some(true),
                        Some((up, down)),
                    ));
                    bench::row(
                        &format!("{} [accum x{GRAD_ACCUM} buffers]", method.label()),
                        format!(
                            "{:>8.2} samples/s   ({})  {up:.1} up / {down:.1} down per step",
                            n_samples as f64 / tbuf.median_s,
                            tbuf.fmt_ms()
                        ),
                    );
                    stepper
                        .disable_device_state()
                        .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
                }
            }
        }

        // -- accumulate path, pre-PR host-summing baseline ----------------
        let mut times = Vec::new();
        for i in 0..WARMUP + ITERS {
            let t0 = std::time::Instant::now();
            let mut grads: Option<Vec<Vec<f32>>> = None;
            for _ in 0..GRAD_ACCUM {
                let batch = batcher.next_batch();
                let (g, _loss, _aux) = stepper
                    .grad_step(&batch)
                    .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
                match grads.as_mut() {
                    None => grads = Some(g),
                    Some(acc) => {
                        for (a, gi) in acc.iter_mut().zip(&g) {
                            for (x, y) in a.iter_mut().zip(gi) {
                                *x += *y;
                            }
                        }
                    }
                }
            }
            let mut grads = grads.expect("grad_accum >= 1");
            let scale = 1.0 / GRAD_ACCUM as f32;
            for g in grads.iter_mut() {
                for x in g.iter_mut() {
                    *x *= scale;
                }
            }
            stepper
                .apply_accumulated_host(&grads, 1e-4)
                .map_err(|e| anyhow::anyhow!("{variant}: {e}"))?;
            if i >= WARMUP {
                times.push(t0.elapsed().as_secs_f64());
            }
        }
        let th = bench::summarize(&times);
        rows.push(row_json(method, "accum_host", b, s, n_samples, &th, None, None));
        bench::row(
            &format!("{} [accum x{GRAD_ACCUM} host]", method.label()),
            format!(
                "{:>8.2} samples/s   ({})  device/host p50 {:.2}x",
                n_samples as f64 / th.median_s,
                th.fmt_ms(),
                th.median_s / td.median_s.max(1e-12)
            ),
        );
    }

    bench::section("Normalized vs SFT+Checkpointing (ours | paper)");
    let ours_sft = results
        .iter()
        .find(|(m, _)| *m == Method::Sft)
        .map(|(_, s)| *s)
        .unwrap_or(1.0);
    let paper_sft = paper_table1(Method::Sft.memory_method()).1;
    for (method, sps) in &results {
        let paper_ratio = paper_table1(method.memory_method()).1 / paper_sft;
        bench::row(
            method.label(),
            format!("{:>6.2}x | {:>6.2}x", sps / ours_sft, paper_ratio),
        );
    }
    println!(
        "\nshape checks: PEFT > full-FT methods; RevFFN vs SFT ratio paper={:.2}x",
        paper_table1(Method::Revffn.memory_method()).1 / paper_sft
    );

    // registry snapshot: process-wide transfer totals and per-site
    // stage latency quantiles accumulated across every path above
    let snap = revffn::obs::registry::snapshot();
    let steps_timed = rows.len().max(1) as f64 * (WARMUP + ITERS) as f64;
    let stages: Vec<Json> = snap
        .hists
        .iter()
        .map(|h| {
            ObjBuilder::new()
                .str("site", h.site.name())
                .num("count", h.count as f64)
                .num("p50_s", h.p50_s)
                .num("p95_s", h.p95_s)
                .num("p99_s", h.p99_s)
                .num("sum_s", h.sum_s)
                .build()
        })
        .collect();
    let uploads = snap.counter(revffn::obs::registry::Counter::Uploads);
    let downloads = snap.counter(revffn::obs::registry::Counter::Downloads);
    // static-vs-predicted peak drift per variant/program, exported as
    // `revffn_hlo_mem_drift` gauge rows (docs/OBSERVABILITY.md) so a
    // bench archive records how honestly the analytic model priced the
    // exact artifacts it ran
    let (_, drift) = revffn::analysis::liveness::check_hlo_mem(
        std::path::Path::new("artifacts/tiny"),
        &revffn::analysis::liveness::HloMemOpts::default(),
    );
    let drift_rows: Vec<Json> = drift
        .iter()
        .map(|r| {
            ObjBuilder::new()
                .str("name", revffn::obs::prom::HLO_MEM_DRIFT)
                .str("variant", &r.variant)
                .str("program", &r.program)
                .num("value", r.ratio)
                .num("static_bytes", r.static_bytes as f64)
                .num("predicted_bytes", r.predicted_bytes as f64)
                .build()
        })
        .collect();
    let telemetry = ObjBuilder::new()
        .num("uploads_total", uploads as f64)
        .num("downloads_total", downloads as f64)
        .num("uploads_per_step", uploads as f64 / steps_timed)
        .num("downloads_per_step", downloads as f64 / steps_timed)
        .val("stages", Json::Arr(stages))
        .val("hlo_mem_drift", Json::Arr(drift_rows))
        .build();

    let doc = ObjBuilder::new()
        .str("bench", "table1_throughput")
        .str("artifacts", "artifacts/tiny")
        .num("grad_accum", GRAD_ACCUM as f64)
        .num("warmup", WARMUP as f64)
        .num("iters", ITERS as f64)
        .val("telemetry", telemetry)
        .val("methods", Json::Arr(rows))
        .build();
    std::fs::write(OUT_PATH, doc.to_string())?;
    println!("\nwrote {OUT_PATH}");
    Ok(())
}
