//! Table 2: downstream benchmark performance per fine-tuning method.
//!
//! Protocol (the paper's, scaled to this testbed): start every method
//! from the SAME "pre-trained" state (LM pre-pass on the bilingual
//! synthetic mix), fine-tune on the English-only instruction corpus for
//! an equal optimizer-step budget, then score on the synthetic suite
//! (MMLU/GSM8K/Multilingual/MT-Bench counterparts).
//!
//! Expected shape (paper): full-parameter rows >= PEFT rows on
//! knowledge/reasoning; RevFFN >= SFT; base model worst; multilingual
//! slightly *regresses* for all tuned rows (English-only corpus).
//!
//!     cargo bench --bench table2_downstream -- [steps] [pretrain]

use revffn::config::RunConfig;
use revffn::coordinator::Trainer;
use revffn::engine::Method;
use revffn::eval::paper_table2;
use revffn::runtime::Device;
use revffn::util::bench;

fn main() -> anyhow::Result<()> {
    let args: Vec<u64> = std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let steps = args.first().copied().unwrap_or(60);
    let pretrain = args.get(1).copied().unwrap_or(40);
    let questions = 24;

    let device = Device::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    bench::section(&format!(
        "Table 2 — downstream suite ({steps} steps/method, {pretrain} pre-pass steps)"
    ));
    println!(
        "{:<10} {:>10} {:>10} {:>13} {:>10}   (paper: mmlu/gsm8k/multi/mtbench)",
        "method", "mmlu-like", "gsm8k-like", "multi-like", "mtb-like"
    );

    // Base row = the 'pre-trained checkpoint' substitute: the LM pre-pass
    // alone, no instruction fine-tuning (one near-zero-LR step satisfies
    // the scheduler's minimum).
    {
        let mut cfg = RunConfig::default_tiny("artifacts/tiny");
        cfg.method = Method::Sft;
        cfg.data.pretrain_steps = pretrain;
        cfg.schedule.stage1_steps = 0;
        cfg.schedule.stage2_steps = 1;
        cfg.schedule.lr = 1e-12;
        cfg.eval_every = 0;
        cfg.out_dir = "runs/table2/base".into();
        let mut trainer = Trainer::new(&device, cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
        trainer.run().map_err(|e| anyhow::anyhow!("base: {e}"))?;
        let s = trainer
            .bench_scores(questions, 7)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        print_row("base", [s.mmlu_like, s.gsm8k_like, s.multilingual_like, s.mtbench_like]);
    }

    for method in Method::ALL {
        let mut cfg = RunConfig::default_tiny("artifacts/tiny");
        cfg.method = method;
        cfg.data.pretrain_steps = pretrain;
        cfg.eval_every = 0;
        cfg.out_dir = format!("runs/table2/{method}").into();
        if method.is_two_stage() {
            // keep total step budget equal: stage1 takes 20% of it (§3.3)
            cfg.schedule.stage1_steps = steps / 5;
            cfg.schedule.stage2_steps = steps - steps / 5;
        } else {
            cfg.schedule.stage1_steps = 0;
            cfg.schedule.stage2_steps = steps;
        }
        let mut trainer = Trainer::new(&device, cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
        let report = trainer.run().map_err(|e| anyhow::anyhow!("{method}: {e}"))?;
        let s = trainer
            .bench_scores(questions, 7)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        print_row(
            method.name(),
            [s.mmlu_like, s.gsm8k_like, s.multilingual_like, s.mtbench_like],
        );
        eprintln!(
            "   [{method}] loss {:.3}->{:.3}, {:.1} samples/s",
            report.first_loss, report.final_loss, report.median_samples_per_s
        );
    }
    println!("\n(absolute scores are testbed-scale; the paper shape to check: full-FT >= PEFT,");
    println!(" RevFFN >= SFT on mmlu/gsm8k/mtbench; multilingual dips slightly for tuned rows)");
    Ok(())
}

fn print_row(method: &str, ours: [f64; 4]) {
    let paper = paper_table2(method)
        .map(|p| format!("({:.1}/{:.1}/{:.1}/{:.2})", p[0], p[1], p[2], p[3]))
        .unwrap_or_default();
    println!(
        "{method:<10} {:>9.1}% {:>9.1}% {:>12.1}% {:>10.2}   {paper}",
        ours[0], ours[1], ours[2], ours[3]
    );
}
