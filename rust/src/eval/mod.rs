//! Downstream evaluation: log-likelihood scoring primitives and the
//! synthetic Table-2 benchmark suite (MMLU/GSM8K/Multilingual/MT-Bench
//! counterparts).

pub mod generate;
pub mod scoring;
pub mod suite;

pub use generate::{generate, generate_text, GenerateConfig};
pub use scoring::{log_softmax_at, score_samples, SampleScore};
pub use suite::{paper_table2, BenchScores, EvalSuite};
