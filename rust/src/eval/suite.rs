//! The Table-2 benchmark suite (synthetic counterparts — see DESIGN.md
//! §Substitutions):
//!
//! * **MMLU-like**        — k-way MCQ over the knowledge world: which item
//!                          is the product of place P? (knowledge retention)
//! * **GSM8K-like**       — multi-step arithmetic, scored as MCQ over the
//!                          correct sum vs. plausible distractors
//!                          (multi-step reasoning)
//! * **Multilingual-like**— MMLU-like rendered in the token-permuted
//!                          "language B" (cross-lingual transfer)
//! * **MT-Bench-like**    — mean per-token log-likelihood of held-out
//!                          instruction responses, mapped to a 0–10 score
//!                          (instruction/chat quality)

use crate::data::dataset::encode_example;
use crate::data::synthetic::{to_lang_b, Example, Family, World};
use crate::data::tokenizer::Tokenizer;
use crate::error::Result;
use crate::util::rng::Rng;
use crate::eval::scoring::{argmax_candidate, score_samples};
use crate::runtime::stepper::Stepper;

/// Table-2 row for one model.
#[derive(Debug, Clone)]
pub struct BenchScores {
    pub mmlu_like: f64,
    pub gsm8k_like: f64,
    pub multilingual_like: f64,
    pub mtbench_like: f64,
}

pub struct EvalSuite {
    pub world: World,
    pub n_questions: usize,
    pub seed: u64,
}

impl EvalSuite {
    pub fn new(world: World, n_questions: usize, seed: u64) -> Self {
        EvalSuite { world, n_questions, seed }
    }

    /// MCQ accuracy: the true completion must out-score the distractors.
    fn mcq_accuracy(
        &self,
        stepper: &Stepper,
        tok: &Tokenizer,
        questions: &[(String, Vec<String>, usize)], // (prompt, candidates, true idx)
        seq: usize,
    ) -> Result<f64> {
        if questions.is_empty() {
            return Ok(0.0);
        }
        let mut correct = 0usize;
        for (prompt, candidates, truth) in questions {
            let samples: Vec<_> = candidates
                .iter()
                .filter_map(|c| {
                    encode_example(
                        tok,
                        &Example {
                            instruction: prompt.clone(),
                            response: c.clone(),
                            family: Family::Knowledge,
                        },
                        seq,
                    )
                    .ok()
                })
                .collect();
            if samples.len() != candidates.len() {
                continue;
            }
            let scores = score_samples(stepper, &samples)?;
            if argmax_candidate(&scores) == *truth {
                correct += 1;
            }
        }
        Ok(100.0 * correct as f64 / questions.len() as f64)
    }

    fn knowledge_questions(&self, lang_b: bool) -> Vec<(String, Vec<String>, usize)> {
        let mut rng = Rng::seed_from_u64(self.seed ^ if lang_b { 0xb } else { 0xa });
        let w = &self.world;
        (0..self.n_questions)
            .map(|_| {
                let p = rng.gen_range(0..w.places.len());
                let (q, _) = w.fact_sentence(p);
                let truth_item = w.facts[p];
                // candidates: all items, answer rendered as the full sentence
                let candidates: Vec<String> = w
                    .items
                    .iter()
                    .map(|it| {
                        let s = format!("The product of {} is {}.", w.places[p], it);
                        if lang_b { to_lang_b(&s) } else { s }
                    })
                    .collect();
                let q = if lang_b { to_lang_b(&q) } else { q };
                (q, candidates, truth_item)
            })
            .collect()
    }

    fn arithmetic_questions(&self) -> Vec<(String, Vec<String>, usize)> {
        let mut rng = Rng::seed_from_u64(self.seed ^ 0xc);
        (0..self.n_questions)
            .map(|_| {
                let n = rng.gen_range_inclusive(2, 4);
                let nums: Vec<u32> = (0..n).map(|_| rng.gen_u32_range(1..20)).collect();
                let sum: u32 = nums.iter().sum();
                let list = nums
                    .iter()
                    .map(|v| v.to_string())
                    .collect::<Vec<_>>()
                    .join(" plus ");
                let mut cands: Vec<u32> = vec![sum];
                while cands.len() < 4 {
                    let delta = rng.gen_u32_range(1..6);
                    let c = if rng.gen_bool(0.5) { sum + delta } else { sum.saturating_sub(delta) };
                    if !cands.contains(&c) {
                        cands.push(c);
                    }
                }
                // shuffle candidate order deterministically
                let truth_val = cands[0];
                for i in (1..cands.len()).rev() {
                    let j = rng.gen_range(0..i + 1);
                    cands.swap(i, j);
                }
                let truth = cands.iter().position(|&c| c == truth_val).unwrap();
                (
                    format!("Compute {list}."),
                    cands.iter().map(|c| format!("The answer is {c}.")).collect(),
                    truth,
                )
            })
            .collect()
    }

    /// MT-Bench-like: mean per-token log-likelihood of held-out responses,
    /// squashed to 0–10. The logistic calibration (center −2.0 nats,
    /// scale 0.75) maps "random-vocab" models near 0 and near-perfect
    /// completion models near 10.
    fn chat_score(
        &self,
        stepper: &Stepper,
        tok: &Tokenizer,
        held_out: &[Example],
        seq: usize,
    ) -> Result<f64> {
        let samples: Vec<_> = held_out
            .iter()
            .filter(|e| e.family == Family::Rewrite || e.family == Family::Arithmetic)
            .take(self.n_questions)
            .filter_map(|e| encode_example(tok, e, seq).ok())
            .collect();
        if samples.is_empty() {
            return Ok(0.0);
        }
        let scores = score_samples(stepper, &samples)?;
        let mean_lp: f64 =
            scores.iter().map(|s| s.per_token()).sum::<f64>() / scores.len() as f64;
        Ok(10.0 / (1.0 + (-(mean_lp + 2.0) / 0.75).exp()))
    }

    /// Run the full suite against a trained model.
    pub fn run(
        &self,
        stepper: &Stepper,
        tok: &Tokenizer,
        held_out: &[Example],
    ) -> Result<BenchScores> {
        let (_b, s) = stepper.batch_shape();
        let mmlu = self.mcq_accuracy(stepper, tok, &self.knowledge_questions(false), s)?;
        let gsm = self.mcq_accuracy(stepper, tok, &self.arithmetic_questions(), s)?;
        let multi = self.mcq_accuracy(stepper, tok, &self.knowledge_questions(true), s)?;
        let chat = self.chat_score(stepper, tok, held_out, s)?;
        Ok(BenchScores {
            mmlu_like: mmlu,
            gsm8k_like: gsm,
            multilingual_like: multi,
            mtbench_like: chat,
        })
    }
}

/// Paper Table 2 reference rows (for side-by-side reporting).
pub fn paper_table2(method: &str) -> Option<[f64; 4]> {
    match method {
        "base" => Some([62.4, 61.2, 40.4, 6.25]),
        "lora" => Some([65.2, 71.5, 38.5, 7.18]),
        "dora" => Some([65.7, 70.8, 38.9, 7.25]),
        "ia3" => Some([65.0, 70.2, 38.2, 7.15]),
        "sft" => Some([66.1, 74.8, 39.5, 7.52]),
        "lomo" => Some([66.2, 74.6, 39.3, 7.50]),
        "galore" => Some([66.3, 74.2, 39.2, 7.46]),
        "revffn" => Some([66.7, 75.1, 38.8, 7.65]),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Corpus, CorpusConfig};

    #[test]
    fn questions_are_deterministic() {
        let c = Corpus::generate(CorpusConfig::default());
        let s1 = EvalSuite::new(c.world.clone(), 10, 3);
        let s2 = EvalSuite::new(c.world.clone(), 10, 3);
        let q1 = s1.knowledge_questions(false);
        let q2 = s2.knowledge_questions(false);
        assert_eq!(q1.len(), q2.len());
        assert_eq!(q1[0].0, q2[0].0);
        assert_eq!(q1[0].2, q2[0].2);
    }

    #[test]
    fn arithmetic_truth_index_valid() {
        let c = Corpus::generate(CorpusConfig::default());
        let suite = EvalSuite::new(c.world, 20, 5);
        for (_q, cands, truth) in suite.arithmetic_questions() {
            assert_eq!(cands.len(), 4);
            assert!(truth < 4);
            // correct answer is derivable from the prompt and must be
            // among candidates exactly once
            let uniq: std::collections::HashSet<_> = cands.iter().collect();
            assert_eq!(uniq.len(), 4);
        }
    }

    #[test]
    fn lang_b_questions_differ_from_lang_a() {
        let c = Corpus::generate(CorpusConfig::default());
        let suite = EvalSuite::new(c.world, 5, 9);
        let a = suite.knowledge_questions(false);
        let b = suite.knowledge_questions(true);
        assert_ne!(a[0].0, b[0].0);
    }

    #[test]
    fn paper_rows_complete() {
        for m in ["base", "lora", "dora", "ia3", "sft", "lomo", "galore", "revffn"] {
            assert!(paper_table2(m).is_some());
        }
        assert!(paper_table2("qlora").is_none());
    }
}
