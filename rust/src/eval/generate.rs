//! Autoregressive generation on top of the AOT `forward` artifact.
//!
//! The forward program has a static (B, S) shape, so decoding re-runs the
//! full forward each token over a right-padded window — simple and exact
//! (no KV cache is exported by the AOT bundle; at tiny scale this costs
//! milliseconds per token). Supports greedy, temperature and top-k
//! sampling, batched up to the artifact's batch dimension.

use crate::data::tokenizer::{Tokenizer, BOS, EOS, PAD};
use crate::error::{Error, Result};
use crate::runtime::stepper::Stepper;
use crate::util::rng::Rng;

/// Decoding configuration.
#[derive(Debug, Clone)]
pub struct GenerateConfig {
    pub max_new_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f32,
    /// 0 = no top-k truncation.
    pub top_k: usize,
    pub seed: u64,
}

impl Default for GenerateConfig {
    fn default() -> Self {
        GenerateConfig { max_new_tokens: 32, temperature: 0.0, top_k: 0, seed: 0 }
    }
}

/// Sample one token id from a logit row.
fn sample_token(row: &[f32], cfg: &GenerateConfig, rng: &mut Rng) -> i32 {
    if cfg.temperature <= 0.0 {
        return row
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(UNKNOWN);
    }
    // top-k mask then temperature softmax
    let mut idx: Vec<usize> = (0..row.len()).collect();
    if cfg.top_k > 0 && cfg.top_k < row.len() {
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
        idx.truncate(cfg.top_k);
    }
    let m = idx.iter().map(|&i| row[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((row[i] - m) / cfg.temperature) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut r = rng.gen_f64() * total;
    for (i, w) in idx.iter().zip(&weights) {
        r -= w;
        if r <= 0.0 {
            return *i as i32;
        }
    }
    *idx.last().unwrap() as i32
}

const UNKNOWN: i32 = 3;

/// Generate a completion for one prompt. Returns the generated token ids
/// (without the prompt; stops at EOS or `max_new_tokens`).
pub fn generate(stepper: &Stepper, prompt_ids: &[i32], cfg: &GenerateConfig)
    -> Result<Vec<i32>> {
    let (b, s) = stepper.batch_shape();
    let v = stepper.vocab_size();
    let mut ids = Vec::with_capacity(prompt_ids.len() + 1);
    ids.push(BOS);
    ids.extend_from_slice(prompt_ids);
    if ids.len() >= s {
        return Err(Error::Config(format!(
            "prompt ({} tokens) must fit the artifact window {s}",
            ids.len()
        )));
    }
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut out = Vec::new();
    for _ in 0..cfg.max_new_tokens {
        if ids.len() >= s {
            break;
        }
        // pack the sequence into row 0 of a padded batch
        let mut tokens = vec![PAD; b * s];
        tokens[..ids.len()].copy_from_slice(&ids);
        let logits = stepper.forward(&tokens)?;
        let pos = ids.len() - 1; // next-token logits at the last real slot
        let row = &logits[pos * v..(pos + 1) * v];
        let next = sample_token(row, cfg, &mut rng);
        if next == EOS {
            break;
        }
        ids.push(next);
        out.push(next);
    }
    Ok(out)
}

/// Convenience: prompt → rendered instruction → generated text.
pub fn generate_text(stepper: &Stepper, tok: &Tokenizer, instruction: &str,
                     cfg: &GenerateConfig) -> Result<String> {
    let prompt = crate::data::dataset::render_prompt(instruction);
    let ids = generate(stepper, &tok.encode(&prompt), cfg)?;
    Ok(tok.decode(&ids))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::seed_from_u64(0);
        let row = vec![0.1, 2.0, -1.0, 0.5];
        let cfg = GenerateConfig::default();
        assert_eq!(sample_token(&row, &cfg, &mut rng), 1);
    }

    #[test]
    fn temperature_sampling_stays_in_topk() {
        let mut rng = Rng::seed_from_u64(1);
        let row = vec![5.0, 4.9, -10.0, -10.0];
        let cfg = GenerateConfig { temperature: 1.0, top_k: 2, ..Default::default() };
        for _ in 0..50 {
            let t = sample_token(&row, &cfg, &mut rng);
            assert!(t == 0 || t == 1, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let row = vec![1.0, 1.1, 0.9, 1.05];
        let cfg = GenerateConfig { temperature: 0.8, top_k: 0, seed: 9, ..Default::default() };
        let mut r1 = Rng::seed_from_u64(9);
        let mut r2 = Rng::seed_from_u64(9);
        for _ in 0..20 {
            assert_eq!(sample_token(&row, &cfg, &mut r1), sample_token(&row, &cfg, &mut r2));
        }
    }
}
