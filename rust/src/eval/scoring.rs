//! Log-likelihood scoring primitives shared by all benchmarks.
//!
//! Every synthetic benchmark reduces to: render candidates as
//! prompt+response samples, run the AOT `forward` artifact, and compare
//! summed response log-probabilities. The log-softmax runs host-side
//! over the returned logits.

use crate::data::dataset::Sample;
use crate::error::{Error, Result};
use crate::runtime::stepper::Stepper;

/// Summed response log-prob + token count for each sample.
#[derive(Debug, Clone, Copy)]
pub struct SampleScore {
    pub logprob: f64,
    pub n_tokens: usize,
}

impl SampleScore {
    pub fn per_token(&self) -> f64 {
        self.logprob / self.n_tokens.max(1) as f64
    }
}

/// Score a batch-worth of samples (pads the final partial batch by
/// repeating the last sample; the padding scores are discarded).
pub fn score_samples(stepper: &Stepper, samples: &[Sample]) -> Result<Vec<SampleScore>> {
    let (b, s) = stepper.batch_shape();
    let v = stepper.vocab_size();
    if samples.is_empty() {
        return Ok(Vec::new());
    }
    let mut out = Vec::with_capacity(samples.len());
    for chunk in samples.chunks(b) {
        let mut tokens = Vec::with_capacity(b * s);
        for i in 0..b {
            let sample = chunk.get(i).unwrap_or_else(|| chunk.last().unwrap());
            if sample.tokens.len() != s {
                return Err(Error::Layout(format!(
                    "sample seq {} != artifact seq {s}",
                    sample.tokens.len()
                )));
            }
            tokens.extend_from_slice(&sample.tokens);
        }
        let logits = stepper.forward(&tokens)?;
        if logits.len() != b * s * v {
            return Err(Error::Layout(format!(
                "forward returned {} logits, want {}",
                logits.len(),
                b * s * v
            )));
        }
        for (i, sample) in chunk.iter().enumerate() {
            let mut lp = 0.0f64;
            let mut n = 0usize;
            for t in 0..s {
                if sample.loss_mask[t] == 0.0 {
                    continue;
                }
                let row = &logits[(i * s + t) * v..(i * s + t + 1) * v];
                lp += log_softmax_at(row, sample.targets[t] as usize);
                n += 1;
            }
            out.push(SampleScore { logprob: lp, n_tokens: n });
        }
    }
    Ok(out)
}

/// Numerically-stable log softmax evaluated at one index.
pub fn log_softmax_at(row: &[f32], idx: usize) -> f64 {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
    (row[idx] as f64) - m - z.ln()
}

/// Index of the best-scoring candidate (per-token normalized to avoid
/// length bias).
pub fn argmax_candidate(scores: &[SampleScore]) -> usize {
    scores
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.per_token().partial_cmp(&b.per_token()).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let row = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&row, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn log_softmax_prefers_larger_logit() {
        let row = vec![0.0f32, 5.0, -1.0];
        assert!(log_softmax_at(&row, 1) > log_softmax_at(&row, 0));
        assert!(log_softmax_at(&row, 0) > log_softmax_at(&row, 2));
    }

    #[test]
    fn argmax_uses_per_token_normalization() {
        let scores = vec![
            SampleScore { logprob: -10.0, n_tokens: 2 },  // -5/token
            SampleScore { logprob: -12.0, n_tokens: 10 }, // -1.2/token
        ];
        assert_eq!(argmax_candidate(&scores), 1);
    }
}
