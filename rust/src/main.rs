//! `revffn` — the launcher CLI (hand-rolled arg parsing; the offline
//! build carries no clap).
//!
//! Subcommands:
//! * `train`        — run a fine-tuning method end-to-end (two-stage for
//!                    RevFFN), logging metrics and optionally evaluating.
//! * `eval`         — run the synthetic benchmark suite on a checkpoint
//!                    or freshly-initialized model.
//! * `plan-memory`  — print the Table-1 analytic VRAM breakdown at real
//!                    Qwen1.5-MoE-A2.7B geometry.
//! * `calibrate`    — compare the analytic model against XLA's live-buffer
//!                    analysis of the lowered tiny graphs.
//! * `gen-data`     — dump the synthetic instruction corpus as JSONL.
//! * `reconstruct`  — measure reversible reconstruction error (§3.1).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use revffn::config::RunConfig;
use revffn::coordinator::Trainer;
use revffn::data::synthetic::{Corpus, CorpusConfig};
use revffn::eval::EvalSuite;
use revffn::memory::{self, Assumptions, Geometry};
use revffn::runtime::{Artifact, Device, ProgramCache, Stepper};

const USAGE: &str = "\
revffn — RevFFN training coordinator

USAGE: revffn <command> [--flag value]...

COMMANDS:
  train         --artifacts DIR --method M [--stage1-steps N] [--stage2-steps N]
                [--pretrain-steps N] [--out-dir DIR] [--config FILE.json]
                [--eval-suite] [--save-checkpoint]
  eval          --artifacts DIR --method M [--checkpoint FILE.rvt] [--questions N]
  plan-memory   [--seq N] [--budget-gb G] [--batch B] [--assumptions bf16_mixed|paper|f32]
  calibrate     [--artifacts DIR]
  gen-data      [--seed N] [--n N] [--out FILE.jsonl]
  reconstruct   [--artifacts DIR]
  generate      --prompt TEXT [--artifacts DIR] [--method M] [--checkpoint F]
                [--max-new-tokens N] [--temperature T] [--top-k K]
";

/// flag parser: `--key value` and boolean `--key` pairs.
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Result<Self> {
        let mut m = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected argument {a:?}\n{USAGE}");
            };
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.replace('-', "_"), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.replace('-', "_"), "true".into());
                i += 1;
            }
        }
        Ok(Flags(m))
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.0.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn opt(&self, key: &str) -> Option<String> {
        self.0.get(key).cloned()
    }

    fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.0.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.0.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    fn bool(&self, key: &str) -> bool {
        self.0.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&argv[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&flags),
        "eval" => cmd_eval(&flags),
        "plan-memory" => cmd_plan_memory(&flags),
        "calibrate" => cmd_calibrate(&flags),
        "gen-data" => cmd_gen_data(&flags),
        "reconstruct" => cmd_reconstruct(&flags),
        "generate" => cmd_generate(&flags),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_train(f: &Flags) -> Result<()> {
    let mut cfg = match f.opt("config") {
        Some(p) => RunConfig::from_json_file(&p).map_err(|e| anyhow!("loading {p}: {e}"))?,
        None => {
            let mut c = RunConfig::default_tiny(f.str("artifacts", "artifacts/tiny"));
            c.method = f.str("method", "revffn");
            c.schedule.stage1_steps = f.u64("stage1_steps", 30)?;
            c.schedule.stage2_steps = f.u64("stage2_steps", 170)?;
            c.data.pretrain_steps = f.u64("pretrain_steps", 0)?;
            c.out_dir = PathBuf::from(f.str("out_dir", "runs/latest"));
            c.save_checkpoint = f.bool("save_checkpoint");
            c
        }
    };
    if cfg.method != "revffn" {
        cfg.schedule.stage1_steps = 0;
    }
    let device = Device::cpu().map_err(|e| anyhow!("{e}"))?;
    eprintln!("[device] {} x{}", device.platform_name(), device.device_count());
    let mut trainer = Trainer::new(&device, cfg).map_err(|e| anyhow!("{e}"))?;
    let report = trainer.run().map_err(|e| anyhow!("{e}"))?;
    println!(
        "method={} steps={} loss {:.4} -> {:.4} (eval {:.4}) {:.1} samples/s, {:.0}s",
        report.method,
        report.steps_run,
        report.first_loss,
        report.final_loss,
        report.eval_loss.unwrap_or(f32::NAN),
        report.median_samples_per_s,
        report.wall_time_s
    );
    if f.bool("eval_suite") {
        let stepper = trainer.stepper.as_ref().expect("model available after run");
        let suite = EvalSuite::new(trainer.corpus.world.clone(), 32, 7);
        let scores = suite
            .run(stepper, &trainer.tokenizer, &trainer.corpus.eval)
            .map_err(|e| anyhow!("{e}"))?;
        println!(
            "bench: mmlu-like {:.1}%  gsm8k-like {:.1}%  multilingual-like {:.1}%  mtbench-like {:.2}",
            scores.mmlu_like, scores.gsm8k_like, scores.multilingual_like, scores.mtbench_like
        );
    }
    Ok(())
}

fn cmd_eval(f: &Flags) -> Result<()> {
    let artifacts = PathBuf::from(f.str("artifacts", "artifacts/tiny"));
    let method = f.str("method", "revffn");
    let device = Device::cpu().map_err(|e| anyhow!("{e}"))?;
    let cache = ProgramCache::new();
    let variant = if method == "revffn" { "revffn_stage2".to_string() } else { method.clone() };
    let artifact = Artifact::load(artifacts.join(&variant)).map_err(|e| anyhow!("{e}"))?;
    let mut stepper = Stepper::new(&device, &cache, artifact).map_err(|e| anyhow!("{e}"))?;
    if let Some(ck) = f.opt("checkpoint") {
        let ck = revffn::checkpoint::load(&ck).map_err(|e| anyhow!("{e}"))?;
        let n = stepper
            .replace_params(|p| revffn::checkpoint::restore_into(&ck, p))
            .map_err(|e| anyhow!("{e}"))?;
        eprintln!("[checkpoint] restored {n} tensors from step {}", ck.step);
    }
    let corpus = Corpus::generate(CorpusConfig::default());
    let tokenizer =
        revffn::data::Tokenizer::train(&corpus.pretrain_text(), stepper.vocab_size())
            .map_err(|e| anyhow!("{e}"))?;
    let suite = EvalSuite::new(corpus.world.clone(), f.u64("questions", 32)? as usize, 7);
    let scores =
        suite.run(&stepper, &tokenizer, &corpus.eval).map_err(|e| anyhow!("{e}"))?;
    println!(
        "mmlu-like {:.1}%  gsm8k-like {:.1}%  multilingual-like {:.1}%  mtbench-like {:.2}",
        scores.mmlu_like, scores.gsm8k_like, scores.multilingual_like, scores.mtbench_like
    );
    Ok(())
}

fn cmd_plan_memory(f: &Flags) -> Result<()> {
    let assumptions = f.str("assumptions", "bf16_mixed");
    let assume = match assumptions.as_str() {
        "paper" => Assumptions::paper_calibrated(),
        "f32" => Assumptions::f32_exact(),
        _ => Assumptions::bf16_mixed(),
    };
    let seq = f.u64("seq", 2048)?;
    let budget = f.f64("budget_gb", 80.0)?;
    let batch = f.opt("batch").map(|b| b.parse()).transpose()?;
    let rows = memory::table1_memory(Geometry::qwen15_moe_a27b(), assume, seq, budget, batch);
    print!(
        "{}",
        memory::format_table(
            &rows,
            &format!(
                "Table 1 (memory) — Qwen1.5-MoE-A2.7B, seq={seq}, budget={budget} GB, assumptions={assumptions}"
            )
        )
    );
    for (check, ok) in memory::ordering_checks(&rows) {
        println!("  [{}] {}", if ok { "ok" } else { "MISS" }, check);
    }
    Ok(())
}

fn cmd_calibrate(f: &Flags) -> Result<()> {
    let artifacts = PathBuf::from(f.str("artifacts", "artifacts/tiny"));
    let rows = memory::calib::calibrate(&artifacts).map_err(|e| anyhow!("{e}"))?;
    println!(
        "{:<16} {:>16} {:>16} {:>8}",
        "variant", "XLA temp (B)", "analytic (B)", "ratio"
    );
    for r in &rows {
        println!(
            "{:<16} {:>16} {:>16.0} {:>8.2}",
            r.variant, r.measured_temp_bytes, r.analytic_act_bytes, r.ratio
        );
    }
    if let Some((rev, naive)) =
        memory::calib::reversible_vs_naive(&artifacts).map_err(|e| anyhow!("{e}"))?
    {
        println!(
            "reversible vs naive temp bytes: {rev} vs {naive} ({:.1}x reduction)",
            naive as f64 / rev as f64
        );
    }
    Ok(())
}

fn cmd_gen_data(f: &Flags) -> Result<()> {
    let corpus = Corpus::generate(CorpusConfig {
        seed: f.u64("seed", 17)?,
        n_train: f.u64("n", 256)? as usize,
        ..Default::default()
    });
    let out = PathBuf::from(f.str("out", "runs/corpus.jsonl"));
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut text = String::new();
    for ex in &corpus.train {
        text.push_str(&ex.to_json().to_string());
        text.push('\n');
    }
    std::fs::write(&out, text)?;
    println!("wrote {} examples to {}", corpus.train.len(), out.display());
    Ok(())
}

fn cmd_generate(f: &Flags) -> Result<()> {
    let artifacts = PathBuf::from(f.str("artifacts", "artifacts/tiny"));
    let method = f.str("method", "revffn");
    let prompt = f
        .opt("prompt")
        .ok_or_else(|| anyhow!("--prompt is required"))?;
    let device = Device::cpu().map_err(|e| anyhow!("{e}"))?;
    let cache = ProgramCache::new();
    let variant = if method == "revffn" { "revffn_stage2".to_string() } else { method.clone() };
    let artifact = Artifact::load(artifacts.join(&variant)).map_err(|e| anyhow!("{e}"))?;
    let mut stepper = Stepper::new(&device, &cache, artifact).map_err(|e| anyhow!("{e}"))?;
    if let Some(ck) = f.opt("checkpoint") {
        let ck = revffn::checkpoint::load(&ck).map_err(|e| anyhow!("{e}"))?;
        let n = stepper
            .replace_params(|p| revffn::checkpoint::restore_into(&ck, p))
            .map_err(|e| anyhow!("{e}"))?;
        eprintln!("[checkpoint] restored {n} tensors from step {}", ck.step);
    }
    let corpus = Corpus::generate(CorpusConfig::default());
    let tokenizer =
        revffn::data::Tokenizer::train(&corpus.pretrain_text(), stepper.vocab_size())
            .map_err(|e| anyhow!("{e}"))?;
    let cfg = revffn::eval::GenerateConfig {
        max_new_tokens: f.u64("max_new_tokens", 32)? as usize,
        temperature: f.f64("temperature", 0.0)? as f32,
        top_k: f.u64("top_k", 0)? as usize,
        seed: f.u64("seed", 0)?,
    };
    let text = revffn::eval::generate_text(&stepper, &tokenizer, &prompt, &cfg)
        .map_err(|e| anyhow!("{e}"))?;
    println!("{text}");
    Ok(())
}

fn cmd_reconstruct(f: &Flags) -> Result<()> {
    let artifacts = PathBuf::from(f.str("artifacts", "artifacts/tiny"));
    let device = Device::cpu().map_err(|e| anyhow!("{e}"))?;
    let artifact = Artifact::load(artifacts.join("reconstruct")).map_err(|e| anyhow!("{e}"))?;
    let hlo = artifact.hlo_path("reconstruct").map_err(|e| anyhow!("{e}"))?;
    let prog = device.load_hlo_text(&hlo).map_err(|e| anyhow!("{e}"))?;
    let params =
        revffn::runtime::ParamStore::from_blobs(&artifact).map_err(|e| anyhow!("{e}"))?;
    let mut inputs = params.to_literals().map_err(|e| anyhow!("{e}"))?;
    let io = &artifact.manifest.io;
    let tokens: Vec<i32> =
        (0..io.batch_size * io.seq_len).map(|i| (i % 200) as i32 + 5).collect();
    inputs.push(
        revffn::runtime::literal::i32_literal(&tokens, &[io.batch_size, io.seq_len])
            .map_err(|e| anyhow!("{e}"))?,
    );
    let out = prog.run(&inputs).map_err(|e| anyhow!("{e}"))?;
    let err = revffn::runtime::literal::scalar_to_f32(&out[0]).map_err(|e| anyhow!("{e}"))?;
    println!(
        "max-abs reconstruction error over {} layers: {err:.3e} (f32 eps = 1.19e-7)",
        artifact.manifest.model.n_layers
    );
    Ok(())
}
