//! `revffn` — the launcher CLI (hand-rolled arg parsing via
//! `revffn::util::Flags`; the offline build carries no clap).
//!
//! Every subcommand is a thin shell over the `engine` API — see
//! `docs/API.md` for the full CLI ↔ API mapping:
//!
//! * `train`        — `Trainer::start()` / `Run::step()` (two-stage for
//!                    RevFFN), logging metrics and optionally evaluating.
//! * `eval`         — `Session` + `BenchScores` on a checkpoint or
//!                    freshly-initialized model.
//! * `plan-memory`  — print the Table-1 analytic VRAM breakdown at real
//!                    Qwen1.5-MoE-A2.7B geometry.
//! * `calibrate`    — compare the analytic model against XLA's live-buffer
//!                    analysis of the lowered tiny graphs.
//! * `gen-data`     — dump the synthetic instruction corpus as JSONL.
//! * `reconstruct`  — measure reversible reconstruction error (§3.1) via
//!                    `SessionBuilder::build_program`.
//! * `generate`     — `Session::generate` autoregressive decoding.
//! * `serve`        — the multi-run scheduling/serving control plane
//!                    (`serve::serve`): N concurrent jobs over one
//!                    device, admission-controlled by the analytic
//!                    memory model, streaming NDJSON events over TCP.
//! * `check`        — device-free static analysis (`analysis` module):
//!                    artifact/manifest contracts, checkpoint-vs-manifest
//!                    compatibility, config-vs-budget pricing, and the
//!                    repo invariant lint. See docs/ANALYSIS.md.

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use revffn::config::{PriceGeometry, RunConfig, ServeConfig};
use revffn::coordinator::Trainer;
use revffn::data::synthetic::{Corpus, CorpusConfig};
use revffn::engine::{Method, Session};
use revffn::memory::{self, Assumptions, Geometry};
use revffn::runtime::Device;
use revffn::util::Flags;

const USAGE: &str = "\
revffn — RevFFN training coordinator

USAGE: revffn <command> [--flag value]...

COMMANDS:
  train         --artifacts DIR --method M [--stage1-steps N] [--stage2-steps N]
                [--pretrain-steps N] [--eval-batches N] [--out-dir DIR]
                [--config FILE.json] [--eval-suite] [--save-checkpoint]
                [--checkpoint-every N] [--keep-last N] [--resume [FILE.rvt]]
                [--no-device-resident] [--trace-out FILE.json]
                [--metrics-out FILE.prom] [--metrics-every-secs N]
                (telemetry sinks: docs/OBSERVABILITY.md — --trace-out
                dumps hot-path spans as Chrome trace-event JSON,
                --metrics-out writes the Prometheus exposition on a
                cadence)
  eval          --artifacts DIR --method M [--checkpoint FILE.rvt] [--questions N]
  plan-memory   [--seq N] [--budget-gb G] [--batch B] [--assumptions bf16_mixed|paper|f32]
  calibrate     [--artifacts DIR]
  gen-data      [--seed N] [--n N] [--out FILE.jsonl]
  reconstruct   [--artifacts DIR]
  generate      --prompt TEXT [--artifacts DIR] [--method M] [--checkpoint F]
                [--max-new-tokens N] [--temperature T] [--top-k K]
  serve         [--artifacts DIR] [--addr HOST:PORT] [--budget-gb G]
                [--host-budget-gb G] [--quantum N] [--event-log-cap N]
                [--checkpoint-every N] [--no-recover]
                [--assumptions bf16_mixed|paper|f32]
                [--price-geometry manifest|qwen] [--run-root DIR]
                [--retry-max-attempts N] [--retry-base-ms MS]
                [--retry-max-ms MS] [--quantum-deadline-ms MS]
                [--conn-limit N] [--io-timeout-ms MS] [--faults SPEC]
                [--tenant-max-jobs N] [--tenant-share-gb G]
                [--events-page-size N] [--price-from-hlo]
                [--config FILE.json]
                (supervised retries, watchdog, fault injection:
                docs/ROBUSTNESS.md; REVFFN_FAULTS overrides --faults;
                priority/tenant scheduling and per-tenant `tenants`
                overrides: docs/SERVE.md)
  check         [--artifacts DIR] [--checkpoint FILE.rvt] [--method M]
                [--variant V] [--config FILE.json] [--budget-gb G]
                [--assumptions A] [--lint] [--src DIR] [--docs]
                [--docs-root DIR] [--hlo-mem DIR] [--mm-tolerance T]
                [--json]
                (static analysis, no device needed — `check --help`,
                docs/ANALYSIS.md)

`train --resume` without a file resumes from the newest periodic
snapshot (ckpt-*.rvt) in --out-dir; periodic snapshots are written
every --checkpoint-every steps (RVT2: params + Adam moments + data
cursor — the continuation is bit-identical to an uninterrupted run).

METHODS: sft | lora | dora | ia3 | lomo | galore | revffn
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&argv[1..]).map_err(|e| anyhow!("{e}\n{USAGE}"))?;
    match cmd.as_str() {
        "train" => cmd_train(&flags),
        "eval" => cmd_eval(&flags),
        "plan-memory" => cmd_plan_memory(&flags),
        "calibrate" => cmd_calibrate(&flags),
        "gen-data" => cmd_gen_data(&flags),
        "reconstruct" => cmd_reconstruct(&flags),
        "generate" => cmd_generate(&flags),
        "serve" => cmd_serve(&flags),
        "check" => cmd_check(&flags),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn method_flag(f: &Flags) -> Result<Method> {
    f.str("method", "revffn").parse().map_err(|e| anyhow!("{e}"))
}

fn cmd_train(f: &Flags) -> Result<()> {
    let mut cfg = match f.opt("config") {
        Some(p) => RunConfig::from_json_file(&p).map_err(|e| anyhow!("loading {p}: {e}"))?,
        None => {
            let mut c = RunConfig::default_tiny(f.str("artifacts", "artifacts/tiny"));
            c.method = method_flag(f)?;
            c.schedule.stage1_steps = f.u64("stage1_steps", 30).map_err(|e| anyhow!("{e}"))?;
            c.schedule.stage2_steps = f.u64("stage2_steps", 170).map_err(|e| anyhow!("{e}"))?;
            c.data.pretrain_steps = f.u64("pretrain_steps", 0).map_err(|e| anyhow!("{e}"))?;
            c.eval_batches =
                f.u64("eval_batches", c.eval_batches as u64).map_err(|e| anyhow!("{e}"))? as usize;
            c.out_dir = PathBuf::from(f.str("out_dir", "runs/latest"));
            c.save_checkpoint = f.bool("save_checkpoint");
            c
        }
    };
    cfg.checkpoint_every =
        f.u64("checkpoint_every", cfg.checkpoint_every).map_err(|e| anyhow!("{e}"))?;
    cfg.keep_last = f.u64("keep_last", cfg.keep_last as u64).map_err(|e| anyhow!("{e}"))? as usize;
    if f.bool("no_device_resident") {
        cfg.device_resident = false;
    }
    if !cfg.method.is_two_stage() {
        cfg.schedule.stage1_steps = 0;
    }
    // --resume FILE.rvt, or bare --resume to auto-discover the newest
    // periodic snapshot in out_dir
    let resume_path = match f.opt("resume").as_deref() {
        None => None,
        Some("true") => Some(revffn::checkpoint::latest_valid_checkpoint(&cfg.out_dir).ok_or_else(
            || {
                anyhow!(
                    "--resume: no periodic snapshot (ckpt-*.rvt) in {} — was the run \
                     started with --checkpoint-every?",
                    cfg.out_dir.display()
                )
            },
        )?),
        Some(path) => Some(PathBuf::from(path)),
    };
    // telemetry sinks (docs/OBSERVABILITY.md): either flag arms the
    // metrics registry; --trace-out additionally records hot-path spans
    // for a Chrome trace-event dump at exit
    let trace_out = f.opt("trace_out").map(PathBuf::from);
    let metrics_out = f.opt("metrics_out").map(PathBuf::from);
    let metrics_every =
        f.u64("metrics_every_secs", 10).map_err(|e| anyhow!("{e}"))?.max(1);
    if trace_out.is_some() || metrics_out.is_some() {
        revffn::obs::registry::arm();
    }
    if trace_out.is_some() {
        revffn::obs::trace::enable();
    }
    let metrics_stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let metrics_thread = metrics_out.map(|path| {
        let stop = metrics_stop.clone();
        let every = std::time::Duration::from_secs(metrics_every);
        std::thread::spawn(move || {
            use std::sync::atomic::Ordering;
            let slice = std::time::Duration::from_millis(200);
            while !stop.load(Ordering::SeqCst) {
                let _ = std::fs::write(&path, revffn::obs::prom::render_default());
                let mut waited = std::time::Duration::ZERO;
                while waited < every && !stop.load(Ordering::SeqCst) {
                    revffn::util::retry::pause(slice);
                    waited += slice;
                }
            }
            // final snapshot: even a short run leaves the exposition
            let _ = std::fs::write(&path, revffn::obs::prom::render_default());
        })
    });
    let device = Device::cpu().map_err(|e| anyhow!("{e}"))?;
    eprintln!("[device] {} x{}", device.platform_name(), device.device_count());
    let mut trainer = Trainer::new(&device, cfg).map_err(|e| anyhow!("{e}"))?;
    let report = match resume_path {
        Some(path) => {
            eprintln!("[resume] loading {}", path.display());
            let ckpt = revffn::checkpoint::load(&path)
                .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
            trainer.run_resumed(ckpt).map_err(|e| anyhow!("{e}"))?
        }
        None => trainer.run().map_err(|e| anyhow!("{e}"))?,
    };
    println!(
        "method={} steps={} loss {:.4} -> {:.4} (eval {:.4}) {:.1} samples/s, {:.0}s",
        report.method,
        report.steps_run,
        report.first_loss,
        report.final_loss,
        report.eval_loss.unwrap_or(f32::NAN),
        report.median_samples_per_s,
        report.wall_time_s
    );
    if f.bool("eval_suite") {
        let scores = trainer.bench_scores(32, 7).map_err(|e| anyhow!("{e}"))?;
        println!(
            "bench: mmlu-like {:.1}%  gsm8k-like {:.1}%  multilingual-like {:.1}%  mtbench-like {:.2}",
            scores.mmlu_like, scores.gsm8k_like, scores.multilingual_like, scores.mtbench_like
        );
    }
    metrics_stop.store(true, std::sync::atomic::Ordering::SeqCst);
    if let Some(t) = metrics_thread {
        let _ = t.join();
    }
    if let Some(path) = &trace_out {
        revffn::obs::trace::write_chrome(path)?;
        eprintln!("[obs] wrote Chrome trace to {} (load in chrome://tracing)", path.display());
    }
    Ok(())
}

fn session_from_flags(f: &Flags) -> Result<Session> {
    let mut builder = Session::builder(f.str("artifacts", "artifacts/tiny"))
        .method(method_flag(f)?);
    if let Some(ck) = f.opt("checkpoint") {
        builder = builder.checkpoint(ck);
    }
    builder.build().map_err(|e| anyhow!("{e}"))
}

fn cmd_eval(f: &Flags) -> Result<()> {
    let session = session_from_flags(f)?;
    let questions = f.u64("questions", 32).map_err(|e| anyhow!("{e}"))? as usize;
    let scores = session.bench_scores(questions, 7).map_err(|e| anyhow!("{e}"))?;
    println!(
        "mmlu-like {:.1}%  gsm8k-like {:.1}%  multilingual-like {:.1}%  mtbench-like {:.2}",
        scores.mmlu_like, scores.gsm8k_like, scores.multilingual_like, scores.mtbench_like
    );
    Ok(())
}

fn cmd_generate(f: &Flags) -> Result<()> {
    let prompt = f
        .opt("prompt")
        .ok_or_else(|| anyhow!("--prompt is required"))?;
    let session = session_from_flags(f)?;
    let cfg = revffn::eval::GenerateConfig {
        max_new_tokens: f.u64("max_new_tokens", 32).map_err(|e| anyhow!("{e}"))? as usize,
        temperature: f.f64("temperature", 0.0).map_err(|e| anyhow!("{e}"))? as f32,
        top_k: f.u64("top_k", 0).map_err(|e| anyhow!("{e}"))? as usize,
        seed: f.u64("seed", 0).map_err(|e| anyhow!("{e}"))?,
    };
    let text = session.generate(&prompt, &cfg).map_err(|e| anyhow!("{e}"))?;
    println!("{text}");
    Ok(())
}

fn cmd_reconstruct(f: &Flags) -> Result<()> {
    let raw = Session::builder(f.str("artifacts", "artifacts/tiny"))
        .variant("reconstruct")
        .build_program("reconstruct")
        .map_err(|e| anyhow!("{e}"))?;
    let mut inputs = raw.params.to_literals().map_err(|e| anyhow!("{e}"))?;
    let io = &raw.artifact.manifest.io;
    let tokens: Vec<i32> =
        (0..io.batch_size * io.seq_len).map(|i| (i % 200) as i32 + 5).collect();
    inputs.push(
        revffn::runtime::literal::i32_literal(&tokens, &[io.batch_size, io.seq_len])
            .map_err(|e| anyhow!("{e}"))?,
    );
    let out = raw.program.run(&inputs).map_err(|e| anyhow!("{e}"))?;
    let err = revffn::runtime::literal::scalar_to_f32(&out[0]).map_err(|e| anyhow!("{e}"))?;
    println!(
        "max-abs reconstruction error over {} layers: {err:.3e} (f32 eps = 1.19e-7)",
        raw.artifact.manifest.model.n_layers
    );
    Ok(())
}

fn cmd_serve(f: &Flags) -> Result<()> {
    // track whether the config file SET host_budget_gb: only an
    // explicit value survives flag overrides — otherwise the host
    // budget keeps tracking the (possibly flag-overridden) device
    // budget, as documented
    let (mut opts, host_explicit) = match f.opt("config") {
        Some(p) => {
            let text = std::fs::read_to_string(&p)?;
            let opts =
                ServeConfig::from_json_str(&text).map_err(|e| anyhow!("loading {p}: {e}"))?;
            let explicit = revffn::util::json::parse(&text)
                .map(|j| j.get("host_budget_gb").is_some())
                .unwrap_or(false);
            (opts, explicit)
        }
        None => (ServeConfig::default(), false),
    };
    if let Some(v) = f.opt("artifacts") {
        opts.artifacts = v.into();
    }
    if let Some(v) = f.opt("addr") {
        opts.addr = v;
    }
    opts.budget_gb = f.f64("budget_gb", opts.budget_gb).map_err(|e| anyhow!("{e}"))?;
    opts.host_budget_gb = if f.opt("host_budget_gb").is_some() {
        f.f64("host_budget_gb", opts.host_budget_gb).map_err(|e| anyhow!("{e}"))?
    } else if host_explicit {
        opts.host_budget_gb
    } else {
        opts.budget_gb
    };
    opts.quantum = f.u64("quantum", opts.quantum).map_err(|e| anyhow!("{e}"))?;
    opts.event_log_cap =
        f.u64("event_log_cap", opts.event_log_cap as u64).map_err(|e| anyhow!("{e}"))? as usize;
    opts.checkpoint_every =
        f.u64("checkpoint_every", opts.checkpoint_every).map_err(|e| anyhow!("{e}"))?;
    if f.bool("no_recover") {
        opts.recover = false;
    }
    if let Some(v) = f.opt("assumptions") {
        opts.assumptions = v;
    }
    if let Some(v) = f.opt("price_geometry") {
        opts.price_geometry = PriceGeometry::parse(&v).map_err(|e| anyhow!("{e}"))?;
    }
    if let Some(v) = f.opt("run_root") {
        opts.run_root = v.into();
    }
    opts.retry_max_attempts = f
        .u64("retry_max_attempts", u64::from(opts.retry_max_attempts))
        .map_err(|e| anyhow!("{e}"))? as u32;
    opts.retry_base_ms = f.u64("retry_base_ms", opts.retry_base_ms).map_err(|e| anyhow!("{e}"))?;
    opts.retry_max_ms = f.u64("retry_max_ms", opts.retry_max_ms).map_err(|e| anyhow!("{e}"))?;
    opts.quantum_deadline_ms =
        f.u64("quantum_deadline_ms", opts.quantum_deadline_ms).map_err(|e| anyhow!("{e}"))?;
    opts.conn_limit =
        f.u64("conn_limit", opts.conn_limit as u64).map_err(|e| anyhow!("{e}"))? as usize;
    opts.io_timeout_ms = f.u64("io_timeout_ms", opts.io_timeout_ms).map_err(|e| anyhow!("{e}"))?;
    if let Some(v) = f.opt("faults") {
        opts.faults = Some(v);
    }
    opts.tenant_max_jobs =
        f.u64("tenant_max_jobs", opts.tenant_max_jobs as u64).map_err(|e| anyhow!("{e}"))? as usize;
    opts.tenant_share_gb =
        f.f64("tenant_share_gb", opts.tenant_share_gb).map_err(|e| anyhow!("{e}"))?;
    opts.events_page_size = f
        .u64("events_page_size", opts.events_page_size as u64)
        .map_err(|e| anyhow!("{e}"))? as usize;
    if f.bool("price_from_hlo") {
        opts.price_from_hlo = true;
    }
    opts.validate().map_err(|e| anyhow!("{e}"))?;
    let handle = revffn::serve::serve(opts.clone()).map_err(|e| anyhow!("{e}"))?;
    eprintln!(
        "[serve] listening on {} — budget {:.3} GB, quantum {}, pricing {} @ {}",
        handle.addr(),
        opts.budget_gb,
        opts.quantum,
        opts.assumptions,
        opts.price_geometry.name()
    );
    eprintln!(
        "[serve] NDJSON verbs: submit | status | events | cancel | resume | metrics | shutdown (docs/SERVE.md)"
    );
    handle.join().map_err(|e| anyhow!("{e}"))
}

const CHECK_USAGE: &str = "\
revffn check — device-free static contract analysis (docs/ANALYSIS.md)

USAGE: revffn check [passes...] [--json]

PASSES (at least one):
  --artifacts DIR       contract-check every variant in an artifact dir
                        (AR rules: presence, arity, shapes/dtypes,
                        donation indices, internal manifest consistency)
  --checkpoint F.rvt    check a .rvt against a variant's manifest — would
                        restore_into accept it? (CK rules; needs
                        --artifacts, picks --method M's eval variant or
                        an explicit --variant V)
  --config FILE.json    validate a run/serve config and price it against
                        the analytic memory model (CF rules;
                        [--budget-gb G] [--assumptions bf16_mixed|paper|f32]
                        override/extend what the config declares)
  --lint                repo invariant lint over Rust sources (LN rules,
                        incl. LN004: no raw thread::sleep outside
                        util/retry.rs, and LN005: no raw Instant::now()
                        in serve/ or engine/ outside obs/; [--src DIR]
                        defaults to rust/src or src)
  --docs                docs-consistency pass over README.md + docs/*.md
                        (DC rules: dangling relative links, CLI flags the
                        binary does not accept, rule IDs cited but missing
                        from the catalog, exported metric names missing
                        from docs/OBSERVABILITY.md; [--docs-root DIR]
                        defaults to the repo root)
  --hlo-mem DIR         schedule-order HLO liveness over every program of
                        every registry method in an artifact dir: static
                        peak live bytes, donation-aware, cross-checked
                        against the analytic memory model (MM rules;
                        [--mm-tolerance T] widens/narrows the accepted
                        static-vs-predicted ratio, default 8.0). Prints
                        the predicted-vs-static drift table after the
                        findings (JSON: extra top-level \"hlo_mem\" key)

OUTPUT: human text, or --json for
  {\"ok\", \"errors\", \"warnings\", \"findings\": [{rule, severity, subject, message}]}
Exit status is nonzero iff any error-severity finding exists.
";

fn cmd_check(f: &Flags) -> Result<()> {
    if f.bool("help") {
        print!("{CHECK_USAGE}");
        return Ok(());
    }
    let mut findings = Vec::new();
    let mut ran_any = false;

    let artifacts = f.opt("artifacts").map(PathBuf::from);
    if let Some(dir) = &artifacts {
        findings.extend(revffn::analysis::check_artifacts(dir));
        ran_any = true;
    }
    if let Some(ck) = f.opt("checkpoint") {
        let Some(dir) = &artifacts else {
            bail!("--checkpoint needs --artifacts to know which manifest to check against\n{CHECK_USAGE}");
        };
        let variant = match f.opt("variant") {
            Some(v) => v,
            None => method_flag(f)?.eval_variant().to_string(),
        };
        findings.extend(revffn::analysis::check_checkpoint(
            &PathBuf::from(ck),
            &dir.join(variant),
        ));
        ran_any = true;
    }
    if let Some(cfg) = f.opt("config") {
        let opts = revffn::analysis::configcheck::ConfigCheckOpts {
            artifacts: artifacts.clone(),
            budget_gb: f.opt("budget_gb").map(|s| s.parse::<f64>()).transpose()?,
            assumptions: f.opt("assumptions"),
        };
        findings.extend(revffn::analysis::check_config(&PathBuf::from(cfg), &opts));
        ran_any = true;
    }
    if f.bool("lint") {
        let src = match f.opt("src") {
            Some(s) => PathBuf::from(s),
            // works from the repo root and from rust/
            None if PathBuf::from("rust/src").is_dir() => PathBuf::from("rust/src"),
            None => PathBuf::from("src"),
        };
        findings.extend(revffn::analysis::lint_sources(&src));
        ran_any = true;
    }
    if f.bool("docs") {
        let root = match f.opt("docs_root") {
            Some(s) => PathBuf::from(s),
            // works from the repo root and from rust/
            None if PathBuf::from("docs").is_dir() => PathBuf::from("."),
            None => PathBuf::from(".."),
        };
        findings.extend(revffn::analysis::check_docs(&root));
        ran_any = true;
    }
    let mut drift = Vec::new();
    let mut hlo_tol = revffn::analysis::liveness::HloMemOpts::default().tolerance;
    let mut hlo_mem_ran = false;
    if let Some(dir) = f.opt("hlo_mem") {
        hlo_tol = f.f64("mm_tolerance", hlo_tol).map_err(|e| anyhow!("{e}"))?;
        let (fs, rows) = revffn::analysis::liveness::check_hlo_mem(
            &PathBuf::from(dir),
            &revffn::analysis::liveness::HloMemOpts { tolerance: hlo_tol },
        );
        findings.extend(fs);
        drift = rows;
        ran_any = true;
        hlo_mem_ran = true;
    }
    if !ran_any {
        bail!("nothing to check — pass at least one of --artifacts / --checkpoint / --config / --lint / --docs / --hlo-mem\n{CHECK_USAGE}");
    }

    let report = revffn::analysis::Report::new(findings);
    if f.bool("json") {
        let mut j = report.to_json();
        if hlo_mem_ran {
            if let revffn::util::json::Json::Obj(map) = &mut j {
                map.insert(
                    "hlo_mem".into(),
                    revffn::analysis::liveness::drift_json(&drift),
                );
            }
        }
        println!("{j}");
    } else {
        print!("{}", report.render_text());
        if hlo_mem_ran {
            print!("{}", revffn::analysis::liveness::render_drift_table(&drift, hlo_tol));
        }
    }
    if !report.ok() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_plan_memory(f: &Flags) -> Result<()> {
    let assumptions = f.str("assumptions", "bf16_mixed");
    let assume = Assumptions::parse(&assumptions).map_err(|e| anyhow!("{e}"))?;
    let seq = f.u64("seq", 2048).map_err(|e| anyhow!("{e}"))?;
    let budget = f.f64("budget_gb", 80.0).map_err(|e| anyhow!("{e}"))?;
    let batch = f.opt("batch").map(|b| b.parse()).transpose()?;
    let rows = memory::table1_memory(Geometry::qwen15_moe_a27b(), assume, seq, budget, batch);
    print!(
        "{}",
        memory::format_table(
            &rows,
            &format!(
                "Table 1 (memory) — Qwen1.5-MoE-A2.7B, seq={seq}, budget={budget} GB, assumptions={assumptions}"
            )
        )
    );
    for (check, ok) in memory::ordering_checks(&rows) {
        println!("  [{}] {}", if ok { "ok" } else { "MISS" }, check);
    }
    Ok(())
}

fn cmd_calibrate(f: &Flags) -> Result<()> {
    let artifacts = PathBuf::from(f.str("artifacts", "artifacts/tiny"));
    let rows = memory::calib::calibrate(&artifacts).map_err(|e| anyhow!("{e}"))?;
    println!(
        "{:<16} {:>16} {:>16} {:>8}",
        "variant", "XLA temp (B)", "analytic (B)", "ratio"
    );
    for r in &rows {
        println!(
            "{:<16} {:>16} {:>16.0} {:>8.2}",
            r.variant, r.measured_temp_bytes, r.analytic_act_bytes, r.ratio
        );
    }
    if let Some((rev, naive)) =
        memory::calib::reversible_vs_naive(&artifacts).map_err(|e| anyhow!("{e}"))?
    {
        println!(
            "reversible vs naive temp bytes: {rev} vs {naive} ({:.1}x reduction)",
            naive as f64 / rev as f64
        );
    }
    Ok(())
}

fn cmd_gen_data(f: &Flags) -> Result<()> {
    let corpus = Corpus::generate(CorpusConfig {
        seed: f.u64("seed", 17).map_err(|e| anyhow!("{e}"))?,
        n_train: f.u64("n", 256).map_err(|e| anyhow!("{e}"))? as usize,
        ..Default::default()
    });
    let out = PathBuf::from(f.str("out", "runs/corpus.jsonl"));
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut text = String::new();
    for ex in &corpus.train {
        text.push_str(&ex.to_json().to_string());
        text.push('\n');
    }
    std::fs::write(&out, text)?;
    println!("wrote {} examples to {}", corpus.train.len(), out.display());
    Ok(())
}
