//! Run configuration — the launcher's single source of truth.
//!
//! A run file (JSON — parsed with the in-crate codec) picks the artifact
//! config dir, the fine-tuning method (typed — see
//! [`crate::engine::Method`]), the two-stage schedule lengths, LR
//! schedule, data generation parameters and evaluation cadence.
//! Everything has working defaults so
//! `revffn train --artifacts artifacts/tiny --method revffn` works with
//! no file at all.

use std::path::{Path, PathBuf};

use crate::data::synthetic::CorpusConfig;
use crate::engine::Method;
use crate::error::{Error, Result};
use crate::util::json::{self, Json, ObjBuilder};

/// Learning-rate schedule shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LrSchedule {
    Constant,
    /// Linear warmup then cosine decay to `min_factor * lr`.
    WarmupCosine,
    /// Linear warmup then linear decay.
    WarmupLinear,
}

impl LrSchedule {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "constant" => Ok(LrSchedule::Constant),
            "warmup_cosine" => Ok(LrSchedule::WarmupCosine),
            "warmup_linear" => Ok(LrSchedule::WarmupLinear),
            other => Err(Error::Config(format!("unknown lr schedule {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LrSchedule::Constant => "constant",
            LrSchedule::WarmupCosine => "warmup_cosine",
            LrSchedule::WarmupLinear => "warmup_linear",
        }
    }
}

#[derive(Debug, Clone)]
pub struct ScheduleConfig {
    /// Stage-1 (adapter warm-up) optimizer steps. 0 disables stage 1
    /// (the paper's "w/o Stage 1" ablation).
    pub stage1_steps: u64,
    /// Stage-2 (joint fine-tuning) steps. 0 disables stage 2
    /// ("w/o Stage 2" ablation: projections only).
    pub stage2_steps: u64,
    pub lr_schedule: LrSchedule,
    /// Peak LR for stage 2 (and for non-RevFFN methods).
    pub lr: f32,
    /// Stage-1 LR ("small learning rate", §3.3).
    pub stage1_lr: f32,
    pub warmup_steps: u64,
    pub min_lr_factor: f32,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        ScheduleConfig {
            stage1_steps: 30,
            stage2_steps: 170,
            lr_schedule: LrSchedule::WarmupCosine,
            lr: 3e-4,
            stage1_lr: 1e-4,
            warmup_steps: 10,
            min_lr_factor: 0.1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct DataConfig {
    pub seed: u64,
    pub n_train: usize,
    pub n_eval: usize,
    pub n_places: usize,
    /// LM pre-pass steps that stand in for "pre-trained checkpoint"
    /// (0 = fine-tune from random init).
    pub pretrain_steps: u64,
    pub pretrain_lr: f32,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            seed: 17,
            n_train: 1024,
            n_eval: 128,
            n_places: 24,
            pretrain_steps: 60,
            pretrain_lr: 1e-3,
        }
    }
}

impl DataConfig {
    /// Synthetic-corpus parameters of this data config.
    pub fn corpus_config(&self) -> CorpusConfig {
        CorpusConfig {
            seed: self.seed,
            n_train: self.n_train,
            n_eval: self.n_eval,
            n_places: self.n_places,
            ..Default::default()
        }
    }
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Artifact config directory (e.g. `artifacts/tiny`).
    pub artifacts: PathBuf,
    /// Fine-tuning method (Table-1 row).
    pub method: Method,
    pub schedule: ScheduleConfig,
    pub data: DataConfig,
    /// Gradient-accumulation microbatches per logged step.
    pub grad_accum: usize,
    /// Keep params + optimizer moments pinned as device buffers across
    /// steps (`PjRtBuffer` path). Default on; the engine falls back to
    /// the literal path automatically when the artifact set or runtime
    /// cannot support it (see `docs/PERF.md`).
    pub device_resident: bool,
    /// Validation cadence in optimizer steps (0 = only at stage ends).
    pub eval_every: u64,
    /// Max eval batches per validation pass (0 = score every batch).
    pub eval_batches: usize,
    /// Where to write metrics / checkpoints (created if missing).
    pub out_dir: PathBuf,
    pub save_checkpoint: bool,
    /// Periodic full-state snapshot cadence in optimizer steps (0 =
    /// off). Snapshots are RVT2 files (`ckpt-p<phase>-s<step>.rvt`
    /// under `out_dir`), written atomically, resumable via
    /// `revffn train --resume` / the serve `resume` verb.
    pub checkpoint_every: u64,
    /// How many periodic snapshots to retain (0 = keep all).
    pub keep_last: usize,
    pub seed: u64,
}

impl RunConfig {
    pub fn default_tiny(artifacts: impl Into<PathBuf>) -> Self {
        RunConfig {
            artifacts: artifacts.into(),
            method: Method::Revffn,
            schedule: ScheduleConfig::default(),
            data: DataConfig::default(),
            grad_accum: 1,
            device_resident: true,
            eval_every: 50,
            eval_batches: 8,
            out_dir: PathBuf::from("runs/latest"),
            save_checkpoint: false,
            checkpoint_every: 0,
            keep_last: 3,
            seed: 0,
        }
    }

    pub fn from_json_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text)
    }

    /// Parse from JSON text; missing keys keep their defaults.
    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_json(&json::parse(text)?)
    }

    /// Parse from a parsed JSON value; missing keys keep their defaults
    /// (the serve control plane submits job configs this way).
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = RunConfig::default_tiny("artifacts/tiny");
        if let Some(v) = j.get("artifacts").and_then(Json::as_str) {
            cfg.artifacts = v.into();
        }
        if let Some(v) = j.get("method").and_then(Json::as_str) {
            cfg.method = v.parse()?;
        }
        if let Some(v) = j.get("grad_accum").and_then(Json::as_usize) {
            cfg.grad_accum = v;
        }
        if let Some(v) = j.get("device_resident").and_then(Json::as_bool) {
            cfg.device_resident = v;
        }
        if let Some(v) = j.get("eval_every").and_then(Json::as_u64) {
            cfg.eval_every = v;
        }
        if let Some(v) = j.get("eval_batches").and_then(Json::as_usize) {
            cfg.eval_batches = v;
        }
        if let Some(v) = j.get("out_dir").and_then(Json::as_str) {
            cfg.out_dir = v.into();
        }
        if let Some(v) = j.get("save_checkpoint").and_then(Json::as_bool) {
            cfg.save_checkpoint = v;
        }
        if let Some(v) = j.get("checkpoint_every").and_then(Json::as_u64) {
            cfg.checkpoint_every = v;
        }
        if let Some(v) = j.get("keep_last").and_then(Json::as_usize) {
            cfg.keep_last = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_u64) {
            cfg.seed = v;
        }
        if let Some(s) = j.get("schedule") {
            let d = &mut cfg.schedule;
            if let Some(v) = s.get("stage1_steps").and_then(Json::as_u64) {
                d.stage1_steps = v;
            }
            if let Some(v) = s.get("stage2_steps").and_then(Json::as_u64) {
                d.stage2_steps = v;
            }
            if let Some(v) = s.get("lr_schedule").and_then(Json::as_str) {
                d.lr_schedule = LrSchedule::parse(v)?;
            }
            if let Some(v) = s.get("lr").and_then(Json::as_f64) {
                d.lr = v as f32;
            }
            if let Some(v) = s.get("stage1_lr").and_then(Json::as_f64) {
                d.stage1_lr = v as f32;
            }
            if let Some(v) = s.get("warmup_steps").and_then(Json::as_u64) {
                d.warmup_steps = v;
            }
            if let Some(v) = s.get("min_lr_factor").and_then(Json::as_f64) {
                d.min_lr_factor = v as f32;
            }
        }
        if let Some(s) = j.get("data") {
            let d = &mut cfg.data;
            if let Some(v) = s.get("seed").and_then(Json::as_u64) {
                d.seed = v;
            }
            if let Some(v) = s.get("n_train").and_then(Json::as_usize) {
                d.n_train = v;
            }
            if let Some(v) = s.get("n_eval").and_then(Json::as_usize) {
                d.n_eval = v;
            }
            if let Some(v) = s.get("n_places").and_then(Json::as_usize) {
                d.n_places = v;
            }
            if let Some(v) = s.get("pretrain_steps").and_then(Json::as_u64) {
                d.pretrain_steps = v;
            }
            if let Some(v) = s.get("pretrain_lr").and_then(Json::as_f64) {
                d.pretrain_lr = v as f32;
            }
        }
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        ObjBuilder::new()
            .str("artifacts", self.artifacts.display().to_string())
            .str("method", self.method.name())
            .num("grad_accum", self.grad_accum as f64)
            .bool("device_resident", self.device_resident)
            .num("eval_every", self.eval_every as f64)
            .num("eval_batches", self.eval_batches as f64)
            .str("out_dir", self.out_dir.display().to_string())
            .bool("save_checkpoint", self.save_checkpoint)
            .num("checkpoint_every", self.checkpoint_every as f64)
            .num("keep_last", self.keep_last as f64)
            .num("seed", self.seed as f64)
            .val(
                "schedule",
                ObjBuilder::new()
                    .num("stage1_steps", self.schedule.stage1_steps as f64)
                    .num("stage2_steps", self.schedule.stage2_steps as f64)
                    .str("lr_schedule", self.schedule.lr_schedule.name())
                    .num("lr", self.schedule.lr as f64)
                    .num("stage1_lr", self.schedule.stage1_lr as f64)
                    .num("warmup_steps", self.schedule.warmup_steps as f64)
                    .num("min_lr_factor", self.schedule.min_lr_factor as f64)
                    .build(),
            )
            .val(
                "data",
                ObjBuilder::new()
                    .num("seed", self.data.seed as f64)
                    .num("n_train", self.data.n_train as f64)
                    .num("n_eval", self.data.n_eval as f64)
                    .num("n_places", self.data.n_places as f64)
                    .num("pretrain_steps", self.data.pretrain_steps as f64)
                    .num("pretrain_lr", self.data.pretrain_lr as f64)
                    .build(),
            )
            .build()
    }

    pub fn validate(&self) -> Result<()> {
        if self.method.is_two_stage() {
            if self.schedule.stage1_steps == 0 && self.schedule.stage2_steps == 0 {
                return Err(Error::Config("both stages disabled".into()));
            }
        } else if self.schedule.stage2_steps == 0 {
            return Err(Error::Config("stage2_steps=0 for a single-stage method".into()));
        }
        if self.grad_accum == 0 {
            return Err(Error::Config("grad_accum must be >= 1".into()));
        }
        if self.grad_accum > 1 && !self.method.supports_grad_accum() {
            return Err(Error::Config(format!(
                "method {} fuses its update into the backward pass and cannot use grad_accum > 1",
                self.method
            )));
        }
        Ok(())
    }

    /// Variant directory for a method+stage under the artifact config dir.
    pub fn variant_dir(&self, stage: u8) -> PathBuf {
        self.artifacts.join(self.method.variant(stage))
    }
}

/// How `revffn serve` prices a submitted job for admission control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PriceGeometry {
    /// Price at the job's own artifact geometry (manifest model +
    /// io batch/seq) — the honest number for what will actually run.
    Manifest,
    /// Price at the real Qwen1.5-MoE-A2.7B geometry (paper scale) with
    /// the artifact's batch/seq — lets a tiny-artifact deployment
    /// exercise a GB-scale budget and the Table-1 method ordering.
    Qwen,
}

impl PriceGeometry {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "manifest" => Ok(PriceGeometry::Manifest),
            "qwen" => Ok(PriceGeometry::Qwen),
            other => Err(Error::Config(format!(
                "unknown price geometry {other:?}; expected manifest | qwen"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PriceGeometry::Manifest => "manifest",
            PriceGeometry::Qwen => "qwen",
        }
    }
}

/// Configuration of the `revffn serve` subsystem (scheduler + admission
/// + control plane). JSON keys mirror the field names; every field has
/// a working default so `revffn serve` runs with no file at all.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TCP listen address of the NDJSON control plane.
    pub addr: String,
    /// Default artifact config dir for submitted jobs that omit
    /// `artifacts` in their config.
    pub artifacts: PathBuf,
    /// Admission budget in GB: the sum of the priced peak-VRAM of all
    /// concurrently admitted jobs must stay within it.
    pub budget_gb: f64,
    /// Scheduling quantum: how many `StepEvent`s one job yields before
    /// the scheduler rotates to the next admitted job.
    pub quantum: u64,
    /// Pricing assumptions preset (`bf16_mixed` | `paper` | `f32`).
    pub assumptions: String,
    /// Geometry jobs are priced at (see [`PriceGeometry`]).
    pub price_geometry: PriceGeometry,
    /// `out_dir` root for jobs that omit one (`<run_root>/<job-id>`).
    pub run_root: PathBuf,
    /// Host-side admission budget in GB: suspended jobs hold their
    /// params + Adam moments as host literal snapshots, and admission
    /// reserves that worst-case footprint too so a budget-full server
    /// cannot be OOM'd by host mirrors. 0 = unbounded.
    pub host_budget_gb: f64,
    /// Per-job event-log ring-buffer capacity (lines). Long-lived
    /// servers emit one NDJSON line per step per job; beyond the cap
    /// the oldest lines are evicted and the log's base offset advances
    /// (`events` subscribers past the base still stream gap-free).
    /// 0 = unbounded.
    pub event_log_cap: usize,
    /// Default `checkpoint_every` applied to submitted jobs that omit
    /// it (0 = leave off). Periodic snapshots are what make a `Failed`
    /// job — or a restarted server — recoverable.
    pub checkpoint_every: u64,
    /// On startup, rescan `run_root` for interrupted jobs (a persisted
    /// `job.json` plus a periodic snapshot) and resubmit them resuming
    /// from their latest checkpoint.
    pub recover: bool,
    /// Supervised-retry budget: how many times a failed job is retried
    /// from its latest valid snapshot before quarantine (0 = a failure
    /// is terminal; docs/ROBUSTNESS.md).
    pub retry_max_attempts: u32,
    /// Exponential-backoff base delay between retries, ms.
    pub retry_base_ms: u64,
    /// Backoff ceiling, ms.
    pub retry_max_ms: u64,
    /// Step watchdog: a job whose single scheduler quantum exceeds this
    /// wall-clock deadline is marked failed (snapshot preserved, slot
    /// released, supervised retry applies). 0 = watchdog off.
    pub quantum_deadline_ms: u64,
    /// Max concurrent control-plane connections (0 = unbounded);
    /// connections past the cap get one error line and are dropped.
    pub conn_limit: usize,
    /// Socket read/write timeout on accepted connections, ms (0 =
    /// none). Slow `events` consumers are disconnected — never blocked
    /// on — when a write stalls past it.
    pub io_timeout_ms: u64,
    /// Fault-injection plan for chaos drills (`SITE[@AT[xTIMES]]:KIND`
    /// clauses; see `util::faults` / docs/ROBUSTNESS.md). `None` in
    /// production — every hook stays a no-op. The `REVFFN_FAULTS`
    /// environment variable overrides this.
    pub faults: Option<String>,
    /// Default per-tenant cap on concurrently admitted jobs (0 =
    /// unlimited). Applies to every tenant without a `tenants` override.
    pub tenant_max_jobs: usize,
    /// Default per-tenant share of the device budget, GB (0 =
    /// unlimited): the summed priced peak-VRAM of one tenant's admitted
    /// jobs must stay within it.
    pub tenant_share_gb: f64,
    /// Per-tenant quota overrides (`tenants` JSON array). Tenants not
    /// listed here get the `tenant_max_jobs`/`tenant_share_gb` defaults
    /// at fairness weight 1.
    pub tenants: Vec<TenantQuotaCfg>,
    /// Max event lines per `events` response page; larger client
    /// `limit`s are clamped down to it. Bounds the copy made under the
    /// board lock and the burst written to any one connection.
    pub events_page_size: usize,
    /// Price admitted jobs from the static HLO liveness peak of their
    /// variant's programs (`analysis::liveness`) instead of the
    /// analytic memory model. Requires `price_geometry: manifest` —
    /// static peaks are facts about the compiled artifacts, so pricing
    /// them at a different geometry would be incoherent.
    pub price_from_hlo: bool,
}

/// One per-tenant quota override in [`ServeConfig::tenants`].
#[derive(Debug, Clone, PartialEq)]
pub struct TenantQuotaCfg {
    /// Tenant name as sent in the `submit` verb's `tenant` key.
    pub name: String,
    /// Max concurrently admitted jobs (0 = unlimited).
    pub max_jobs: usize,
    /// Device-GB share (0 = unlimited).
    pub share_gb: f64,
    /// Fairness weight for weighted-deficit ordering (> 0; default 1).
    pub weight: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7433".into(),
            artifacts: PathBuf::from("artifacts/tiny"),
            budget_gb: 80.0,
            quantum: 4,
            assumptions: "bf16_mixed".into(),
            price_geometry: PriceGeometry::Manifest,
            run_root: PathBuf::from("runs/serve"),
            host_budget_gb: 80.0,
            event_log_cap: 4096,
            checkpoint_every: 10,
            recover: true,
            retry_max_attempts: 3,
            retry_base_ms: 250,
            retry_max_ms: 10_000,
            quantum_deadline_ms: 0,
            conn_limit: 64,
            io_timeout_ms: 60_000,
            faults: None,
            tenant_max_jobs: 0,
            tenant_share_gb: 0.0,
            tenants: Vec::new(),
            events_page_size: 256,
            price_from_hlo: false,
        }
    }
}

impl ServeConfig {
    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_json(&json::parse(text)?)
    }

    /// Parse from JSON; missing keys keep their defaults.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = ServeConfig::default();
        if let Some(v) = j.get("addr").and_then(Json::as_str) {
            cfg.addr = v.into();
        }
        if let Some(v) = j.get("artifacts").and_then(Json::as_str) {
            cfg.artifacts = v.into();
        }
        if let Some(v) = j.get("budget_gb").and_then(Json::as_f64) {
            cfg.budget_gb = v;
        }
        if let Some(v) = j.get("quantum").and_then(Json::as_u64) {
            cfg.quantum = v;
        }
        if let Some(v) = j.get("assumptions").and_then(Json::as_str) {
            cfg.assumptions = v.into();
        }
        if let Some(v) = j.get("price_geometry").and_then(Json::as_str) {
            cfg.price_geometry = PriceGeometry::parse(v)?;
        }
        if let Some(v) = j.get("run_root").and_then(Json::as_str) {
            cfg.run_root = v.into();
        }
        // absent → track the device budget (a suspended job's host
        // snapshot is always smaller than its device peak, so this
        // default never starves admission — it only bounds the mirrors)
        cfg.host_budget_gb = j
            .get("host_budget_gb")
            .and_then(Json::as_f64)
            .unwrap_or(cfg.budget_gb);
        if let Some(v) = j.get("event_log_cap").and_then(Json::as_usize) {
            cfg.event_log_cap = v;
        }
        if let Some(v) = j.get("checkpoint_every").and_then(Json::as_u64) {
            cfg.checkpoint_every = v;
        }
        if let Some(v) = j.get("recover").and_then(Json::as_bool) {
            cfg.recover = v;
        }
        if let Some(v) = j.get("retry_max_attempts").and_then(Json::as_u64) {
            cfg.retry_max_attempts = v as u32;
        }
        if let Some(v) = j.get("retry_base_ms").and_then(Json::as_u64) {
            cfg.retry_base_ms = v;
        }
        if let Some(v) = j.get("retry_max_ms").and_then(Json::as_u64) {
            cfg.retry_max_ms = v;
        }
        if let Some(v) = j.get("quantum_deadline_ms").and_then(Json::as_u64) {
            cfg.quantum_deadline_ms = v;
        }
        if let Some(v) = j.get("conn_limit").and_then(Json::as_usize) {
            cfg.conn_limit = v;
        }
        if let Some(v) = j.get("io_timeout_ms").and_then(Json::as_u64) {
            cfg.io_timeout_ms = v;
        }
        if let Some(v) = j.get("faults").and_then(Json::as_str) {
            cfg.faults = Some(v.to_string());
        }
        if let Some(v) = j.get("tenant_max_jobs").and_then(Json::as_usize) {
            cfg.tenant_max_jobs = v;
        }
        if let Some(v) = j.get("tenant_share_gb").and_then(Json::as_f64) {
            cfg.tenant_share_gb = v;
        }
        if let Some(Json::Arr(items)) = j.get("tenants") {
            for item in items {
                let name = item
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Config("tenants[] entry needs a \"name\"".into()))?
                    .to_string();
                cfg.tenants.push(TenantQuotaCfg {
                    name,
                    max_jobs: item.get("max_jobs").and_then(Json::as_usize).unwrap_or(0),
                    share_gb: item.get("share_gb").and_then(Json::as_f64).unwrap_or(0.0),
                    weight: item.get("weight").and_then(Json::as_f64).unwrap_or(1.0),
                });
            }
        }
        if let Some(v) = j.get("events_page_size").and_then(Json::as_usize) {
            cfg.events_page_size = v;
        }
        if let Some(v) = j.get("price_from_hlo").and_then(Json::as_bool) {
            cfg.price_from_hlo = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        let mut b = ObjBuilder::new()
            .str("addr", self.addr.clone())
            .str("artifacts", self.artifacts.display().to_string())
            .num("budget_gb", self.budget_gb)
            .num("quantum", self.quantum as f64)
            .str("assumptions", self.assumptions.clone())
            .str("price_geometry", self.price_geometry.name())
            .str("run_root", self.run_root.display().to_string())
            .num("host_budget_gb", self.host_budget_gb)
            .num("event_log_cap", self.event_log_cap as f64)
            .num("checkpoint_every", self.checkpoint_every as f64)
            .bool("recover", self.recover)
            .num("retry_max_attempts", self.retry_max_attempts as f64)
            .num("retry_base_ms", self.retry_base_ms as f64)
            .num("retry_max_ms", self.retry_max_ms as f64)
            .num("quantum_deadline_ms", self.quantum_deadline_ms as f64)
            .num("conn_limit", self.conn_limit as f64)
            .num("io_timeout_ms", self.io_timeout_ms as f64)
            .num("tenant_max_jobs", self.tenant_max_jobs as f64)
            .num("tenant_share_gb", self.tenant_share_gb)
            .num("events_page_size", self.events_page_size as f64)
            .bool("price_from_hlo", self.price_from_hlo);
        if let Some(f) = &self.faults {
            b = b.str("faults", f.clone());
        }
        if !self.tenants.is_empty() {
            let items = self
                .tenants
                .iter()
                .map(|t| {
                    ObjBuilder::new()
                        .str("name", t.name.clone())
                        .num("max_jobs", t.max_jobs as f64)
                        .num("share_gb", t.share_gb)
                        .num("weight", t.weight)
                        .build()
                })
                .collect();
            b = b.val("tenants", Json::Arr(items));
        }
        b.build()
    }

    pub fn validate(&self) -> Result<()> {
        if self.budget_gb.is_nan() || self.budget_gb <= 0.0 {
            return Err(Error::Config("budget_gb must be > 0".into()));
        }
        if self.host_budget_gb.is_nan() || self.host_budget_gb < 0.0 {
            return Err(Error::Config("host_budget_gb must be >= 0 (0 = unbounded)".into()));
        }
        if self.quantum == 0 {
            return Err(Error::Config("quantum must be >= 1".into()));
        }
        if self.retry_max_ms < self.retry_base_ms {
            return Err(Error::Config("retry_max_ms must be >= retry_base_ms".into()));
        }
        if let Some(spec) = &self.faults {
            // surface a bad chaos plan at config time, not mid-drill
            crate::util::faults::FaultPlan::parse(spec)?;
        }
        if self.tenant_share_gb.is_nan() || self.tenant_share_gb < 0.0 {
            return Err(Error::Config("tenant_share_gb must be >= 0 (0 = unlimited)".into()));
        }
        if self.events_page_size == 0 {
            return Err(Error::Config("events_page_size must be >= 1".into()));
        }
        if self.price_from_hlo && self.price_geometry != PriceGeometry::Manifest {
            return Err(Error::Config(
                "price_from_hlo requires price_geometry: manifest — static HLO peaks \
                 are facts about the compiled artifacts, not a substitute geometry"
                    .into(),
            ));
        }
        for t in &self.tenants {
            if t.name.is_empty() {
                return Err(Error::Config("tenants[] entry needs a non-empty name".into()));
            }
            if t.share_gb.is_nan() || t.share_gb < 0.0 {
                return Err(Error::Config(format!(
                    "tenant {:?}: share_gb must be >= 0 (0 = unlimited)",
                    t.name
                )));
            }
            if !(t.weight > 0.0) {
                return Err(Error::Config(format!("tenant {:?}: weight must be > 0", t.name)));
            }
        }
        self.assumptions()?;
        Ok(())
    }

    /// Resolve the pricing-assumptions preset.
    pub fn assumptions(&self) -> Result<crate::memory::Assumptions> {
        crate::memory::Assumptions::parse(&self.assumptions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default_tiny("artifacts/tiny").validate().unwrap();
    }

    #[test]
    fn unknown_method_rejected_at_parse() {
        assert!(RunConfig::from_json_str(r#"{"method": "qlora"}"#).is_err());
    }

    #[test]
    fn lomo_with_grad_accum_rejected() {
        let mut c = RunConfig::default_tiny("artifacts/tiny");
        c.method = Method::Lomo;
        c.grad_accum = 4;
        assert!(c.validate().is_err());
        c.grad_accum = 1;
        c.validate().unwrap();
    }

    #[test]
    fn both_stages_zero_rejected() {
        let mut c = RunConfig::default_tiny("artifacts/tiny");
        c.schedule.stage1_steps = 0;
        c.schedule.stage2_steps = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let mut c = RunConfig::default_tiny("artifacts/tiny");
        c.method = Method::Galore;
        c.schedule.stage2_steps = 99;
        c.data.pretrain_steps = 7;
        c.eval_batches = 3;
        c.device_resident = false;
        c.checkpoint_every = 25;
        c.keep_last = 5;
        let text = c.to_json().to_string();
        let c2 = RunConfig::from_json_str(&text).unwrap();
        assert_eq!(c2.method, Method::Galore);
        assert_eq!(c2.schedule.stage2_steps, 99);
        assert_eq!(c2.data.pretrain_steps, 7);
        assert_eq!(c2.eval_batches, 3);
        assert!(!c2.device_resident);
        assert_eq!(c2.checkpoint_every, 25);
        assert_eq!(c2.keep_last, 5);
    }

    #[test]
    fn checkpointing_defaults_off_with_retention() {
        let c = RunConfig::from_json_str("{}").unwrap();
        assert_eq!(c.checkpoint_every, 0, "periodic snapshots are opt-in");
        assert_eq!(c.keep_last, 3);
    }

    #[test]
    fn device_resident_defaults_on() {
        let c = RunConfig::from_json_str("{}").unwrap();
        assert!(c.device_resident);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let c = RunConfig::from_json_str(r#"{"method": "lora"}"#).unwrap();
        assert_eq!(c.method, Method::Lora);
        assert_eq!(c.schedule.stage2_steps, ScheduleConfig::default().stage2_steps);
        assert_eq!(c.eval_batches, 8);
    }

    #[test]
    fn bad_lr_schedule_rejected() {
        let r = RunConfig::from_json_str(r#"{"schedule": {"lr_schedule": "step"}}"#);
        assert!(r.is_err());
    }

    #[test]
    fn serve_config_roundtrip_and_defaults() {
        let c = ServeConfig::from_json_str("{}").unwrap();
        assert_eq!(c.addr, "127.0.0.1:7433");
        assert_eq!(c.quantum, 4);
        assert_eq!(c.price_geometry, PriceGeometry::Manifest);
        let c2 = ServeConfig {
            budget_gb: 12.5,
            quantum: 1,
            price_geometry: PriceGeometry::Qwen,
            assumptions: "paper".into(),
            ..ServeConfig::default()
        };
        let back = ServeConfig::from_json_str(&c2.to_json().to_string()).unwrap();
        assert_eq!(back.budget_gb, 12.5);
        assert_eq!(back.quantum, 1);
        assert_eq!(back.price_geometry, PriceGeometry::Qwen);
        assert!(!back.assumptions().unwrap().master_weights);
    }

    #[test]
    fn serve_host_budget_defaults_to_device_budget() {
        let c = ServeConfig::from_json_str(r#"{"budget_gb": 12.0}"#).unwrap();
        assert_eq!(c.host_budget_gb, 12.0, "absent host budget tracks the device budget");
        let c = ServeConfig::from_json_str(r#"{"budget_gb": 12.0, "host_budget_gb": 0}"#).unwrap();
        assert_eq!(c.host_budget_gb, 0.0, "explicit 0 = unbounded");
        let c =
            ServeConfig::from_json_str(r#"{"budget_gb": 12.0, "host_budget_gb": 3.5}"#).unwrap();
        assert_eq!(c.host_budget_gb, 3.5);
    }

    #[test]
    fn serve_recovery_and_log_cap_roundtrip() {
        let c = ServeConfig::from_json_str("{}").unwrap();
        assert!(c.recover, "crash recovery is on by default");
        assert_eq!(c.event_log_cap, 4096);
        assert_eq!(c.checkpoint_every, 10, "serve jobs snapshot by default");
        let c = ServeConfig::from_json_str(
            r#"{"recover": false, "event_log_cap": 16, "checkpoint_every": 0}"#,
        )
        .unwrap();
        assert!(!c.recover);
        assert_eq!(c.event_log_cap, 16);
        assert_eq!(c.checkpoint_every, 0);
        let back = ServeConfig::from_json_str(&c.to_json().to_string()).unwrap();
        assert!(!back.recover);
        assert_eq!(back.event_log_cap, 16);
    }

    #[test]
    fn serve_config_rejects_bad_values() {
        assert!(ServeConfig::from_json_str(r#"{"budget_gb": 0}"#).is_err());
        assert!(ServeConfig::from_json_str(r#"{"quantum": 0}"#).is_err());
        assert!(ServeConfig::from_json_str(r#"{"assumptions": "fp8"}"#).is_err());
        assert!(ServeConfig::from_json_str(r#"{"price_geometry": "llama"}"#).is_err());
        assert!(ServeConfig::from_json_str(r#"{"host_budget_gb": -1}"#).is_err());
        assert!(
            ServeConfig::from_json_str(r#"{"retry_base_ms": 500, "retry_max_ms": 100}"#).is_err(),
            "backoff ceiling below base"
        );
        assert!(
            ServeConfig::from_json_str(r#"{"faults": "warp_core@1:error"}"#).is_err(),
            "bad fault plans surface at config time"
        );
    }

    #[test]
    fn serve_supervision_knobs_roundtrip_with_defaults() {
        let c = ServeConfig::from_json_str("{}").unwrap();
        assert_eq!(c.retry_max_attempts, 3, "supervised retries are on by default");
        assert_eq!(c.retry_base_ms, 250);
        assert_eq!(c.retry_max_ms, 10_000);
        assert_eq!(c.quantum_deadline_ms, 0, "watchdog is opt-in");
        assert_eq!(c.conn_limit, 64);
        assert_eq!(c.io_timeout_ms, 60_000);
        assert!(c.faults.is_none(), "no chaos in production defaults");

        let c = ServeConfig::from_json_str(
            r#"{"retry_max_attempts": 0, "retry_base_ms": 10, "retry_max_ms": 40,
                "quantum_deadline_ms": 2000, "conn_limit": 0, "io_timeout_ms": 0,
                "faults": "pjrt_execute@3:error; ckpt_write@1:torn"}"#,
        )
        .unwrap();
        let back = ServeConfig::from_json_str(&c.to_json().to_string()).unwrap();
        assert_eq!(back.retry_max_attempts, 0);
        assert_eq!(back.retry_base_ms, 10);
        assert_eq!(back.retry_max_ms, 40);
        assert_eq!(back.quantum_deadline_ms, 2000);
        assert_eq!(back.conn_limit, 0);
        assert_eq!(back.io_timeout_ms, 0);
        assert_eq!(back.faults.as_deref(), Some("pjrt_execute@3:error; ckpt_write@1:torn"));
    }

    #[test]
    fn serve_tenant_quota_keys_roundtrip_with_defaults() {
        let c = ServeConfig::from_json_str("{}").unwrap();
        assert_eq!(c.tenant_max_jobs, 0, "quotas default to unlimited");
        assert_eq!(c.tenant_share_gb, 0.0);
        assert!(c.tenants.is_empty());
        assert_eq!(c.events_page_size, 256);

        let c = ServeConfig::from_json_str(
            r#"{"tenant_max_jobs": 2, "tenant_share_gb": 40.0, "events_page_size": 16,
                "tenants": [
                    {"name": "team-a", "max_jobs": 4, "share_gb": 60.0, "weight": 2.0},
                    {"name": "team-b"}
                ]}"#,
        )
        .unwrap();
        let back = ServeConfig::from_json_str(&c.to_json().to_string()).unwrap();
        assert_eq!(back.tenant_max_jobs, 2);
        assert_eq!(back.tenant_share_gb, 40.0);
        assert_eq!(back.events_page_size, 16);
        assert_eq!(back.tenants.len(), 2);
        assert_eq!(
            back.tenants[0],
            TenantQuotaCfg { name: "team-a".into(), max_jobs: 4, share_gb: 60.0, weight: 2.0 }
        );
        assert_eq!(
            back.tenants[1],
            TenantQuotaCfg { name: "team-b".into(), max_jobs: 0, share_gb: 0.0, weight: 1.0 },
            "omitted override keys mean unlimited at weight 1"
        );
    }

    #[test]
    fn serve_tenant_quota_keys_reject_bad_values() {
        assert!(ServeConfig::from_json_str(r#"{"tenant_share_gb": -1}"#).is_err());
        assert!(ServeConfig::from_json_str(r#"{"events_page_size": 0}"#).is_err());
        assert!(ServeConfig::from_json_str(r#"{"tenants": [{"max_jobs": 1}]}"#).is_err());
        assert!(ServeConfig::from_json_str(r#"{"tenants": [{"name": ""}]}"#).is_err());
        assert!(
            ServeConfig::from_json_str(r#"{"tenants": [{"name": "t", "weight": 0}]}"#).is_err()
        );
        assert!(
            ServeConfig::from_json_str(r#"{"tenants": [{"name": "t", "share_gb": -2}]}"#).is_err()
        );
    }

    #[test]
    fn variant_dirs() {
        let c = RunConfig::default_tiny("a");
        assert!(c.variant_dir(1).ends_with("revffn_stage1"));
        let mut c2 = c.clone();
        c2.method = Method::Lora;
        assert!(c2.variant_dir(2).ends_with("lora"));
    }
}
