//! PJRT execution: load HLO text, compile once, execute many.
//!
//! `Device` wraps the PJRT CPU client; `Program` is one compiled HLO
//! module. Two execution surfaces exist:
//!
//! * [`Program::run`] — literal-in/literal-out. Every call stages its
//!   inputs through host memory and downloads every output. Simple, and
//!   the right tool for cold paths (checkpoint restore, reconstruction
//!   probes, parameter surgery).
//! * [`Program::run_buffers`] — buffer-in/buffer-out on `PjRtBuffer`s.
//!   Nothing crosses the host boundary; callers keep state device-side
//!   across calls and download only what they need (scalars, lazy
//!   snapshots). This is the training hot path — see
//!   [`crate::runtime::stepper::Stepper`] and `docs/PERF.md`.
//!
//! Executables are cached by file path in `ProgramCache` so repeated
//! constructions (benches, eval passes) never recompile. Every `Device`
//! carries [`TransferCounters`] — shared with the programs it loads and
//! the buffers it uploads — so host↔device traffic is observable
//! (`tests/hotpath.rs` pins the "no host staging on the buffer path"
//! invariant with it).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use xla::{
    HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, PrimitiveType,
    XlaComputation,
};

use crate::error::{Error, Result};
use crate::obs::{self, registry};
use crate::util::faults::{self, FaultSite};

/// Host↔device transfer tally (atomic; shared across the device, its
/// programs, and its device-resident state). Counts *transfers*, not
/// bytes: one literal staged up or one buffer/output downloaded each
/// tick the matching counter by one.
#[derive(Default)]
pub struct TransferCounters {
    uploads: AtomicU64,
    downloads: AtomicU64,
}

impl TransferCounters {
    pub(crate) fn count_uploads(&self, n: u64) {
        self.uploads.fetch_add(n, Ordering::Relaxed);
        registry::add(registry::Counter::Uploads, n);
    }

    pub(crate) fn count_downloads(&self, n: u64) {
        self.downloads.fetch_add(n, Ordering::Relaxed);
        registry::add(registry::Counter::Downloads, n);
    }

    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            uploads: self.uploads.load(Ordering::Relaxed),
            downloads: self.downloads.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.uploads.store(0, Ordering::Relaxed);
        self.downloads.store(0, Ordering::Relaxed);
    }
}

/// Point-in-time view of a device's transfer counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferSnapshot {
    pub uploads: u64,
    pub downloads: u64,
}

impl TransferSnapshot {
    /// Transfers since an earlier snapshot of the same counters.
    pub fn since(&self, earlier: &TransferSnapshot) -> TransferSnapshot {
        TransferSnapshot {
            uploads: self.uploads.saturating_sub(earlier.uploads),
            downloads: self.downloads.saturating_sub(earlier.downloads),
        }
    }
}

/// PJRT device handle (CPU plugin; the xla crate also exposes gpu/tpu).
///
/// Cheap to clone: the client and transfer counters are shared. The
/// `Stepper` keeps a clone so it can stage batches and scalars without
/// threading a device reference through every call.
#[derive(Clone)]
pub struct Device {
    client: Arc<PjRtClient>,
    counters: Arc<TransferCounters>,
}

impl Device {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Device {
            client: Arc::new(PjRtClient::cpu()?),
            counters: Arc::new(TransferCounters::default()),
        })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Stage one literal as a device buffer (counted as one upload).
    pub fn to_device(&self, lit: &Literal) -> Result<PjRtBuffer> {
        let _sp = obs::span(obs::Site::PjrtUpload);
        faults::failpoint(FaultSite::PjrtTransfer)?;
        self.counters.count_uploads(1);
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Stage a batch of literals as device buffers.
    pub fn to_device_many(&self, lits: &[Literal]) -> Result<Vec<PjRtBuffer>> {
        lits.iter().map(|l| self.to_device(l)).collect()
    }

    /// Download one buffer back to a host literal (counted as one
    /// download). Scalars and lazy snapshots go through here so the
    /// transfer tally stays honest.
    pub fn from_device(&self, buf: &PjRtBuffer) -> Result<Literal> {
        let _sp = obs::span(obs::Site::PjrtDownload);
        faults::failpoint(FaultSite::PjrtTransfer)?;
        self.counters.count_downloads(1);
        Ok(buf.to_literal_sync()?)
    }

    /// Host↔device transfer totals since creation (or the last reset).
    pub fn transfer_stats(&self) -> TransferSnapshot {
        self.counters.snapshot()
    }

    pub fn reset_transfer_stats(&self) {
        self.counters.reset()
    }

    /// Compile HLO text (the AOT interchange format) into a `Program`.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Program> {
        let path = path.as_ref();
        let proto = HloModuleProto::from_text_file(path)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Program {
            exe,
            source: path.to_path_buf(),
            counters: self.counters.clone(),
        })
    }
}

/// One compiled executable.
pub struct Program {
    exe: PjRtLoadedExecutable,
    source: PathBuf,
    counters: Arc<TransferCounters>,
}

impl Program {
    pub fn source(&self) -> &Path {
        &self.source
    }

    /// Execute with literal inputs; flatten the output list.
    ///
    /// AOT lowering uses `return_tuple=True`, so the module root is one
    /// tuple. Depending on the PJRT execute options the runtime hands
    /// back either that single tuple buffer or the already-untupled
    /// element buffers; [`flatten_output_literals`] normalizes both to
    /// the flat output list the manifest describes. Accepts owned or
    /// borrowed literals — cold paths pass `&Literal` state to avoid
    /// copies.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, inputs: &[L]) -> Result<Vec<Literal>> {
        let _sp = obs::span(obs::Site::PjrtExecute);
        faults::failpoint(FaultSite::PjrtExecute)?;
        self.counters.count_uploads(inputs.len() as u64);
        let result = self.exe.execute::<L>(inputs)?;
        let bufs = result
            .into_iter()
            .next()
            .ok_or_else(|| Error::Layout("program produced no output".into()))?;
        flatten_output_literals(bufs, &self.counters)
    }

    /// Execute with device-buffer inputs; outputs stay device-side.
    ///
    /// No host staging happens in this call: inputs are already device
    /// buffers and outputs are returned as buffers (the runtime untuples
    /// the root tuple into per-output buffers). Callers validate the
    /// output arity against the manifest — a single buffer where many
    /// outputs were expected means the runtime did not untuple, which
    /// the stepper treats as "buffer path unsupported" and falls back
    /// from (see `Stepper::train_step`).
    ///
    /// Donation caveat: AOT state arguments are donated
    /// (`donate_argnums` in `python/compile/aot.py`), so the input
    /// buffers backing params/moments/accumulators are CONSUMED by a
    /// successful execute. Never reuse them — adopt the outputs instead.
    pub fn run_buffers<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        inputs: &[B],
    ) -> Result<Vec<PjRtBuffer>> {
        let _sp = obs::span(obs::Site::PjrtExecute);
        faults::failpoint(FaultSite::PjrtExecute)?;
        let result = self.exe.execute_b::<B>(inputs)?;
        let bufs = result
            .into_iter()
            .next()
            .ok_or_else(|| Error::Layout("program produced no output".into()))?;
        if bufs.is_empty() {
            return Err(Error::Layout("program produced no output".into()));
        }
        Ok(bufs)
    }
}

/// Normalize an execute result to the flat literal list: either the
/// runtime already untupled the root (one buffer per output) or it
/// handed back a single tuple buffer to decompose.
fn flatten_output_literals(
    bufs: Vec<PjRtBuffer>,
    counters: &TransferCounters,
) -> Result<Vec<Literal>> {
    if bufs.len() == 1 {
        counters.count_downloads(1);
        let lit = bufs[0].to_literal_sync()?;
        if lit.primitive_type()? == PrimitiveType::Tuple {
            return Ok(lit.to_tuple()?);
        }
        return Ok(vec![lit]);
    }
    counters.count_downloads(bufs.len() as u64);
    bufs.iter().map(|b| Ok(b.to_literal_sync()?)).collect()
}

/// Path-keyed executable cache (compile once per process).
#[derive(Clone, Default)]
pub struct ProgramCache {
    inner: Arc<Mutex<HashMap<PathBuf, Arc<Program>>>>,
}

impl ProgramCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get_or_load(&self, device: &Device, path: impl AsRef<Path>) -> Result<Arc<Program>> {
        let path = path.as_ref().to_path_buf();
        let mut map = self
            .inner
            .lock()
            .map_err(|_| Error::Training("program cache poisoned".into()))?;
        if let Some(p) = map.get(&path) {
            return Ok(p.clone());
        }
        let prog = Arc::new(device.load_hlo_text(&path)?);
        map.insert(path, prog.clone());
        Ok(prog)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().map(|m| m.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_counters_tally_and_reset() {
        // counts also fold into the global registry when it is armed;
        // hold its test gate so armed registry tests see exact values
        let _g = registry::test_lock();
        let c = TransferCounters::default();
        c.count_uploads(3);
        c.count_downloads(2);
        c.count_uploads(1);
        assert_eq!(c.snapshot(), TransferSnapshot { uploads: 4, downloads: 2 });
        c.reset();
        assert_eq!(c.snapshot(), TransferSnapshot { uploads: 0, downloads: 0 });
    }

    #[test]
    fn snapshot_since_subtracts_saturating() {
        let a = TransferSnapshot { uploads: 10, downloads: 4 };
        let b = TransferSnapshot { uploads: 12, downloads: 9 };
        assert_eq!(b.since(&a), TransferSnapshot { uploads: 2, downloads: 5 });
        // a reset between snapshots must not underflow
        assert_eq!(a.since(&b), TransferSnapshot { uploads: 0, downloads: 0 });
    }
}
