//! PJRT execution: load HLO text, compile once, execute many.
//!
//! `Device` wraps the PJRT CPU client; `Program` is one compiled HLO
//! module. The train loop holds its state as `Literal`s and calls
//! `Program::run`, which returns the flattened output tuple. Executables
//! are cached by file path in `ProgramCache` so repeated constructions
//! (benches, eval passes) never recompile.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::error::{Error, Result};

/// PJRT device handle (CPU plugin; the xla crate also exposes gpu/tpu).
pub struct Device {
    client: PjRtClient,
}

impl Device {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Device { client: PjRtClient::cpu()? })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Compile HLO text (the AOT interchange format) into a `Program`.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Program> {
        let path = path.as_ref();
        let proto = HloModuleProto::from_text_file(path)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Program {
            exe,
            source: path.to_path_buf(),
        })
    }
}

/// One compiled executable.
pub struct Program {
    exe: PjRtLoadedExecutable,
    source: PathBuf,
}

impl Program {
    pub fn source(&self) -> &Path {
        &self.source
    }

    /// Execute with literal inputs; flatten the (single-tuple) output.
    ///
    /// AOT lowering uses `return_tuple=True`, so PJRT hands back one tuple
    /// buffer; we decompose it into the flat output list the manifest
    /// describes. Accepts owned or borrowed literals — the hot path passes
    /// `&Literal` state to avoid copies.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, inputs: &[L]) -> Result<Vec<Literal>> {
        let result = self.exe.execute::<L>(inputs)?;
        let buf = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Layout("program produced no output".into()))?;
        let lit = buf.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// Path-keyed executable cache (compile once per process).
#[derive(Clone, Default)]
pub struct ProgramCache {
    inner: Arc<Mutex<HashMap<PathBuf, Arc<Program>>>>,
}

impl ProgramCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get_or_load(&self, device: &Device, path: impl AsRef<Path>) -> Result<Arc<Program>> {
        let path = path.as_ref().to_path_buf();
        let mut map = self
            .inner
            .lock()
            .map_err(|_| Error::Training("program cache poisoned".into()))?;
        if let Some(p) = map.get(&path) {
            return Ok(p.clone());
        }
        let prog = Arc::new(device.load_hlo_text(&path)?);
        map.insert(path, prog.clone());
        Ok(prog)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().map(|m| m.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
