//! Host tensor <-> XLA `Literal` conversion utilities.
//!
//! The coordinator's host-side tensors are plain `Vec<f32>` / `Vec<i32>`
//! with explicit shapes; this module owns the (cheap, but easy to get
//! wrong) conversions into the `xla` crate's `Literal`s and back.

use xla::{ArrayElement, Literal, PrimitiveType};

use crate::error::{Error, Result};

/// Build an f32 literal of the given shape from a host slice.
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let expect: usize = shape.iter().product::<usize>().max(1);
    if data.len() != expect {
        return Err(Error::Layout(format!(
            "f32_literal: data len {} != shape {:?} ({expect})",
            data.len(),
            shape
        )));
    }
    let lit = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let expect: usize = shape.iter().product::<usize>().max(1);
    if data.len() != expect {
        return Err(Error::Layout(format!(
            "i32_literal: data len {} != shape {:?} ({expect})",
            data.len(),
            shape
        )));
    }
    let lit = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Scalar f32 literal.
pub fn scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

/// All-zero f32 literal of the given shape (optimizer-state init).
pub fn zeros_f32(shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    f32_literal(&vec![0.0; n], shape)
}

/// Read back an f32 literal into a host vector.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read a scalar f32 out of a literal (converting if needed).
pub fn scalar_to_f32(lit: &Literal) -> Result<f32> {
    let lit = match lit.primitive_type()? {
        PrimitiveType::F32 => lit.to_vec::<f32>()?,
        _ => lit.convert(PrimitiveType::F32)?.to_vec::<f32>()?,
    };
    lit.first().copied().ok_or_else(|| Error::Layout("empty literal".into()))
}

/// Element count helper.
pub fn elem_count(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(1)
}

/// Bytes per element for the manifest's dtype strings.
pub fn dtype_bytes(dtype: &str) -> Result<usize> {
    match dtype {
        "f32" | "i32" | "u32" => Ok(4),
        "bf16" | "f16" => Ok(2),
        "f64" | "i64" => Ok(8),
        other => Err(Error::Parse(format!("unknown dtype {other:?}"))),
    }
}

/// Generic typed literal from raw bytes (dtype from manifest).
pub fn literal_from_bytes(bytes: &[u8], shape: &[usize], dtype: &str) -> Result<Literal> {
    match dtype {
        "f32" => {
            let mut v = vec![0f32; bytes.len() / 4];
            cast_f32_le(bytes, &mut v)?;
            f32_literal(&v, shape)
        }
        other => Err(Error::Parse(format!("unsupported blob dtype {other:?}"))),
    }
}

/// Little-endian bytes → f32 (blob decode and checkpoint load both
/// stream through here). The zipped iterators replace the old
/// per-element indexed loop: `iter_mut().zip(chunks_exact(4))` carries
/// no bounds checks, which is what lets the loop vectorize.
pub fn cast_f32_le(bytes: &[u8], out: &mut [f32]) -> Result<()> {
    if bytes.len() != out.len() * 4 {
        return Err(Error::Layout(format!(
            "cast_f32_le: {} bytes for {} floats",
            bytes.len(),
            out.len()
        )));
    }
    for (dst, src) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
    }
    Ok(())
}

/// f32 slice → little-endian bytes, appended to a reusable buffer
/// (checkpoint writes clear + refill one buffer per tensor instead of
/// issuing one 4-byte write per element).
pub fn extend_f32_le(vals: &[f32], out: &mut Vec<u8>) {
    out.reserve(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Ensure a literal has the expected element type `T`.
pub fn check_type<T: ArrayElement>(lit: &Literal) -> Result<()> {
    let ty = lit.ty()?;
    if ty != T::TY {
        return Err(Error::Layout(format!("literal type {ty:?} != expected {:?}", T::TY)));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_2d() {
        let data = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = f32_literal(&data, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
        assert!(i32_literal(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = scalar_f32(7.5);
        assert_eq!(scalar_to_f32(&lit).unwrap(), 7.5);
    }

    #[test]
    fn zeros_have_right_count() {
        let lit = zeros_f32(&[4, 8]).unwrap();
        assert_eq!(lit.element_count(), 32);
        assert!(to_f32_vec(&lit).unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn bytes_to_literal() {
        let vals = [1.5f32, -2.0, 0.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = literal_from_bytes(&bytes, &[3], "f32").unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vals);
    }

    #[test]
    fn cast_f32_le_roundtrips_large_series() {
        let vals: Vec<f32> = (0..4133).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mut bytes = Vec::new();
        extend_f32_le(&vals, &mut bytes);
        assert_eq!(bytes.len(), vals.len() * 4);
        let mut back = vec![0f32; vals.len()];
        cast_f32_le(&bytes, &mut back).unwrap();
        assert_eq!(back, vals);
    }

    #[test]
    fn cast_f32_le_rejects_length_mismatch() {
        let mut out = vec![0f32; 2];
        assert!(cast_f32_le(&[0u8; 7], &mut out).is_err());
    }

    #[test]
    fn extend_f32_le_appends() {
        let mut buf = vec![0xAAu8];
        extend_f32_le(&[1.0], &mut buf);
        assert_eq!(buf.len(), 5);
        assert_eq!(buf[0], 0xAA);
    }

    #[test]
    fn dtype_bytes_table() {
        assert_eq!(dtype_bytes("f32").unwrap(), 4);
        assert_eq!(dtype_bytes("bf16").unwrap(), 2);
        assert!(dtype_bytes("q4").is_err());
    }
}
