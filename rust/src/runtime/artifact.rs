//! Artifact manifests — the L2→L3 contract.
//!
//! `make artifacts` writes, per (config, variant), a `manifest.json`
//! describing every tensor (name/shape/blob/offset), the flat I/O layout
//! of the step functions, and the embedded XLA memory analysis used to
//! calibrate the Table-1 VRAM model. This module parses those manifests
//! (via the in-crate JSON codec) and locates the HLO text files; it never
//! touches Python.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// One tensor of the flat parameter list.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    /// Blob file (under `<cfg>/blobs/`) holding the initial value.
    pub blob: String,
    /// Byte offset of this tensor inside the blob.
    pub offset: usize,
    pub nbytes: usize,
}

impl TensorSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j.str_of("name")?,
            shape: j.usize_vec_of("shape")?,
            dtype: j.str_of("dtype")?,
            blob: j.str_of("blob")?,
            offset: j.usize_of("offset")?,
            nbytes: j.usize_of("nbytes")?,
        })
    }
}

/// Flat I/O layout of the step functions (mirrors StepBuilder.layout()).
#[derive(Debug, Clone)]
pub struct IoLayout {
    pub n_params: usize,
    pub n_opt: usize,
    pub optimizer: String,
    pub trainable: Vec<bool>,
    pub trainable_paths: Vec<String>,
    pub opt_shapes: Vec<Vec<usize>>,
    pub batch_size: usize,
    pub seq_len: usize,
}

impl IoLayout {
    fn from_json(j: &Json) -> Result<Self> {
        let trainable = j
            .arr_of("trainable")?
            .iter()
            .map(|v| v.as_bool().ok_or_else(|| Error::Parse("trainable: non-bool".into())))
            .collect::<Result<Vec<_>>>()?;
        let trainable_paths = j
            .arr_of("trainable_paths")?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| Error::Parse("trainable_paths: non-string".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        let opt_shapes = j
            .arr_of("opt_shapes")?
            .iter()
            .map(|row| {
                row.as_arr()
                    .ok_or_else(|| Error::Parse("opt_shapes: non-array".into()))?
                    .iter()
                    .map(|v| {
                        v.as_usize().ok_or_else(|| Error::Parse("opt_shapes: non-num".into()))
                    })
                    .collect::<Result<Vec<usize>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(IoLayout {
            n_params: j.usize_of("n_params")?,
            n_opt: j.usize_of("n_opt")?,
            optimizer: j.str_of("optimizer")?,
            trainable,
            trainable_paths,
            opt_shapes,
            batch_size: j.usize_of("batch_size")?,
            seq_len: j.usize_of("seq_len")?,
        })
    }
}

/// XLA live-buffer analysis embedded at AOT time (`--analyze`).
#[derive(Debug, Clone)]
pub struct MemoryAnalysis {
    pub temp_size_bytes: u64,
    pub argument_size_bytes: u64,
    pub output_size_bytes: u64,
    pub generated_code_size_bytes: u64,
}

/// Geometry of the model baked into an artifact (mirrors ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelGeometry {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_ff_expert: usize,
    pub d_ff_shared: usize,
    pub max_seq_len: usize,
    pub rev_fixedpoint_iters: usize,
    pub rev_symmetric: bool,
}

impl ModelGeometry {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelGeometry {
            name: j.str_of("name")?,
            vocab_size: j.usize_of("vocab_size")?,
            d_model: j.usize_of("d_model")?,
            n_layers: j.usize_of("n_layers")?,
            n_heads: j.usize_of("n_heads")?,
            n_kv_heads: j.usize_of("n_kv_heads")?,
            n_experts: j.usize_of("n_experts")?,
            top_k: j.usize_of("top_k")?,
            d_ff_expert: j.usize_of("d_ff_expert")?,
            d_ff_shared: j.usize_of("d_ff_shared")?,
            max_seq_len: j.usize_of("max_seq_len")?,
            rev_fixedpoint_iters: j.usize_of("rev_fixedpoint_iters").unwrap_or(1),
            rev_symmetric: j.bool_of("rev_symmetric").unwrap_or(false),
        })
    }
}

/// Per-variant manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variant: String,
    pub method: String,
    pub model: ModelGeometry,
    pub io: IoLayout,
    pub tensors: Vec<TensorSpec>,
    pub artifacts: HashMap<String, String>,
    /// Analysis of the shipped (donated) train step.
    pub memory_analysis: Option<MemoryAnalysis>,
    /// Analysis without input donation — the clean activation-memory
    /// signal used by the Table-1 calibration.
    pub memory_analysis_nodonate: Option<MemoryAnalysis>,
    pub n_params_total: u64,
    pub n_params_trainable: u64,
    pub use_pallas: bool,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let j = json::parse(text)?;
        let tensors = j
            .arr_of("tensors")?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let artifacts = j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| Error::Parse("artifacts: not an object".into()))?
            .iter()
            .map(|(k, v)| {
                v.as_str()
                    .map(|s| (k.clone(), s.to_string()))
                    .ok_or_else(|| Error::Parse("artifacts: non-string".into()))
            })
            .collect::<Result<HashMap<_, _>>>()?;
        let parse_ma = |key: &str| -> Result<Option<MemoryAnalysis>> {
            Ok(match j.get(key) {
                Some(m) if !matches!(m, Json::Null) => Some(MemoryAnalysis {
                    temp_size_bytes: m.u64_of("temp_size_bytes")?,
                    argument_size_bytes: m.u64_of("argument_size_bytes")?,
                    output_size_bytes: m.u64_of("output_size_bytes")?,
                    generated_code_size_bytes: m
                        .u64_of("generated_code_size_bytes")
                        .unwrap_or(0),
                }),
                _ => None,
            })
        };
        let memory_analysis = parse_ma("memory_analysis")?;
        let memory_analysis_nodonate = parse_ma("memory_analysis_nodonate")?;
        Ok(Manifest {
            variant: j.str_of("variant")?,
            method: j.str_of("method").unwrap_or_default(),
            model: ModelGeometry::from_json(j.req("model")?)?,
            io: IoLayout::from_json(j.req("io")?)?,
            tensors,
            artifacts,
            memory_analysis,
            memory_analysis_nodonate,
            n_params_total: j.u64_of("n_params_total").unwrap_or(0),
            n_params_trainable: j.u64_of("n_params_trainable").unwrap_or(0),
            use_pallas: j.bool_of("use_pallas").unwrap_or(false),
        })
    }
}

/// A variant directory on disk: manifest + resolved HLO paths.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Artifact {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("reading {}/manifest.json: {e}", dir.display()),
            ))
        })?;
        let manifest = Manifest::parse(&text)?;
        Ok(Artifact { dir, manifest })
    }

    /// Path of one HLO program (`train_step`, `forward`, `eval_step`, …).
    pub fn hlo_path(&self, kind: &str) -> Result<PathBuf> {
        let rel = self.manifest.artifacts.get(kind).ok_or_else(|| {
            Error::Config(format!(
                "variant {} has no artifact kind {kind:?}",
                self.manifest.variant
            ))
        })?;
        Ok(self.dir.join(rel))
    }

    /// Directory holding the parameter blobs (`../blobs`).
    pub fn blob_dir(&self) -> PathBuf {
        self.dir
            .parent()
            .map(|p| p.join("blobs"))
            .unwrap_or_else(|| PathBuf::from("blobs"))
    }

    /// Indices (into the flat tensor list) of trainable tensors.
    pub fn trainable_indices(&self) -> Vec<usize> {
        self.manifest
            .io
            .trainable
            .iter()
            .enumerate()
            .filter_map(|(i, &t)| t.then_some(i))
            .collect()
    }
}

/// Top-level `index.json` for one lowered config.
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    pub config: String,
    pub variants: Vec<String>,
    pub blobs: HashMap<String, String>,
    pub pallas: bool,
}

impl ArtifactIndex {
    pub fn load(cfg_dir: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(cfg_dir.as_ref().join("index.json"))?;
        let j = json::parse(&text)?;
        let variants = j
            .arr_of("variants")?
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();
        let blobs = j
            .req("blobs")?
            .as_obj()
            .ok_or_else(|| Error::Parse("blobs: not an object".into()))?
            .iter()
            .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
            .collect();
        Ok(ArtifactIndex {
            config: j.str_of("config")?,
            variants,
            blobs,
            pallas: j.bool_of("pallas").unwrap_or(false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        p.exists().then_some(p)
    }

    #[test]
    fn manifest_parses_and_is_consistent() {
        let Some(root) = artifacts_root() else { return };
        let art = Artifact::load(root.join("revffn_stage2")).unwrap();
        let m = &art.manifest;
        assert_eq!(m.io.n_params, m.tensors.len());
        assert_eq!(m.io.trainable.len(), m.tensors.len());
        assert!(m.io.n_opt <= m.io.trainable.iter().filter(|&&t| t).count());
        assert!(art.hlo_path("train_step").unwrap().exists());
        assert!(art.hlo_path("forward").unwrap().exists());
        // router tensors must be frozen in both RevFFN stages (§3.3)
        for (spec, &tr) in m.tensors.iter().zip(&m.io.trainable) {
            if spec.name.contains(".moe.router") {
                assert!(!tr, "router tensor {} must be frozen", spec.name);
            }
        }
    }

    #[test]
    fn stage1_trains_only_adapters_and_stream_norms() {
        let Some(root) = artifacts_root() else { return };
        let art = Artifact::load(root.join("revffn_stage1")).unwrap();
        for (spec, &tr) in art.manifest.tensors.iter().zip(&art.manifest.io.trainable) {
            let is_adapter = spec.name.contains(".adapters.")
                || spec.name.contains(".norm_x1")
                || spec.name.contains(".norm_x2")
                || spec.name.contains(".norm_y1");
            assert_eq!(tr, is_adapter, "stage-1 trainability wrong for {}", spec.name);
        }
    }

    #[test]
    fn index_lists_all_variants() {
        let Some(root) = artifacts_root() else { return };
        let idx = ArtifactIndex::load(&root).unwrap();
        assert!(idx.variants.len() >= 8);
        for v in &idx.variants {
            assert!(root.join(v).join("manifest.json").exists(), "missing {v}");
        }
    }

    #[test]
    fn unknown_artifact_kind_is_config_error() {
        let Some(root) = artifacts_root() else { return };
        let art = Artifact::load(root.join("revffn_stage2")).unwrap();
        assert!(art.hlo_path("nonexistent").is_err());
    }

    #[test]
    fn lomo_manifest_has_no_opt_state() {
        let Some(root) = artifacts_root() else { return };
        let art = Artifact::load(root.join("lomo")).unwrap();
        assert_eq!(art.manifest.io.n_opt, 0);
        assert_eq!(art.manifest.io.optimizer, "sgd");
    }

    #[test]
    fn galore_opt_shapes_are_rank_reduced() {
        let Some(root) = artifacts_root() else { return };
        let art = Artifact::load(root.join("galore")).unwrap();
        assert_eq!(art.manifest.io.optimizer, "galore");
        // the embedding moment must be [r, vocab] not [vocab, d]
        let vocab = art.manifest.model.vocab_size;
        assert!(art
            .manifest
            .io
            .opt_shapes
            .iter()
            .any(|s| s.len() == 2 && s[1] == vocab && s[0] < 64));
    }
}
