//! Parameter store: the flat tensor state the train loop threads through
//! the AOT step functions.
//!
//! Initial values come from the AOT parameter blobs (one contiguous
//! little-endian f32 file per source tree — `standard`, `revffn`,
//! `peft_<method>`); each manifest tensor names its blob + byte offset.
//! The store owns host copies (`Vec<f32>`) *and* the staged `Literal`s,
//! so checkpointing and evaluation never re-read the blob files.

use std::collections::HashMap;

use xla::{Literal, PjRtBuffer};

use crate::error::{Error, Result};
use crate::runtime::artifact::{Artifact, TensorSpec};
use crate::runtime::literal;
use crate::runtime::pjrt::Device;

/// Flat, manifest-ordered parameter state.
pub struct ParamStore {
    specs: Vec<TensorSpec>,
    host: Vec<Vec<f32>>,
    name_index: HashMap<String, usize>,
}

impl ParamStore {
    /// Load every tensor of `artifact` from its parameter blobs.
    pub fn from_blobs(artifact: &Artifact) -> Result<Self> {
        let blob_dir = artifact.blob_dir();
        let mut blobs: HashMap<String, Vec<u8>> = HashMap::new();
        let mut host = Vec::with_capacity(artifact.manifest.tensors.len());
        for spec in &artifact.manifest.tensors {
            let bytes = match blobs.get(&spec.blob) {
                Some(b) => b,
                None => {
                    let path = blob_dir.join(format!("{}.bin", spec.blob));
                    let data = std::fs::read(&path).map_err(|e| {
                        Error::Io(std::io::Error::new(
                            e.kind(),
                            format!("reading blob {}: {e}", path.display()),
                        ))
                    })?;
                    blobs.entry(spec.blob.clone()).or_insert(data)
                }
            };
            let end = spec.offset + spec.nbytes;
            if end > bytes.len() {
                return Err(Error::Layout(format!(
                    "tensor {} overruns blob {} ({} > {})",
                    spec.name,
                    spec.blob,
                    end,
                    bytes.len()
                )));
            }
            let raw = &bytes[spec.offset..end];
            if raw.len() / 4 != spec.elem_count() {
                return Err(Error::Layout(format!(
                    "tensor {}: blob has {} elems, shape {:?} wants {}",
                    spec.name,
                    raw.len() / 4,
                    spec.shape,
                    spec.elem_count()
                )));
            }
            let mut vals = vec![0f32; spec.elem_count()];
            literal::cast_f32_le(raw, &mut vals)?;
            host.push(vals);
        }
        Self::from_host(artifact.manifest.tensors.clone(), host)
    }

    /// Build from in-memory tensors (checkpoint restore, tests).
    pub fn from_host(specs: Vec<TensorSpec>, host: Vec<Vec<f32>>) -> Result<Self> {
        if specs.len() != host.len() {
            return Err(Error::Layout(format!(
                "spec count {} != tensor count {}",
                specs.len(),
                host.len()
            )));
        }
        for (s, h) in specs.iter().zip(&host) {
            if s.elem_count() != h.len() {
                return Err(Error::Layout(format!(
                    "tensor {}: {} elems for shape {:?}",
                    s.name,
                    h.len(),
                    s.shape
                )));
            }
        }
        let name_index = specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.clone(), i))
            .collect();
        Ok(ParamStore { specs, host, name_index })
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn specs(&self) -> &[TensorSpec] {
        &self.specs
    }

    pub fn tensor(&self, name: &str) -> Option<&[f32]> {
        self.name_index.get(name).map(|&i| self.host[i].as_slice())
    }

    /// Spec of a named tensor (checkpoint restore validates stored
    /// shapes against this).
    pub fn spec(&self, name: &str) -> Option<&TensorSpec> {
        self.name_index.get(name).map(|&i| &self.specs[i])
    }

    pub fn tensor_by_index(&self, i: usize) -> &[f32] {
        &self.host[i]
    }

    /// Total parameter count (elements).
    pub fn param_count(&self) -> u64 {
        self.specs.iter().map(|s| s.elem_count() as u64).sum()
    }

    /// Stage every tensor as an XLA literal (manifest order).
    pub fn to_literals(&self) -> Result<Vec<Literal>> {
        self.specs
            .iter()
            .zip(&self.host)
            .map(|(s, h)| literal::f32_literal(h, &s.shape))
            .collect()
    }

    /// Replace host state from step-function outputs (manifest order).
    ///
    /// Cold path by design: the stepper only calls this from
    /// `materialize_params` (checkpointing, handoff, inspection), never
    /// per step. Element counts are validated cheaply against the literal
    /// metadata *before* any download, and each downloaded vector is moved
    /// into place — no second element-wise copy.
    pub fn update_from_literals(&mut self, lits: &[Literal]) -> Result<()> {
        if lits.len() != self.specs.len() {
            return Err(Error::Layout(format!(
                "update: {} literals for {} tensors",
                lits.len(),
                self.specs.len()
            )));
        }
        for (i, lit) in lits.iter().enumerate() {
            if lit.element_count() != self.host[i].len() {
                return Err(Error::Layout(format!(
                    "update: tensor {} got {} elems, want {}",
                    self.specs[i].name,
                    lit.element_count(),
                    self.host[i].len()
                )));
            }
        }
        for (dst, lit) in self.host.iter_mut().zip(lits) {
            *dst = literal::to_f32_vec(lit)?;
        }
        Ok(())
    }

    /// Overwrite a single tensor (tests / surgery).
    pub fn set_tensor(&mut self, name: &str, vals: Vec<f32>) -> Result<()> {
        let &i = self
            .name_index
            .get(name)
            .ok_or_else(|| Error::Layout(format!("unknown tensor {name:?}")))?;
        if vals.len() != self.host[i].len() {
            return Err(Error::Layout(format!(
                "set_tensor {name}: {} elems, want {}",
                vals.len(),
                self.host[i].len()
            )));
        }
        self.host[i] = vals;
        Ok(())
    }

    /// L2 norm over all parameters (divergence tripwire). One pass:
    /// per-tensor partial sums-of-squares, combined once — no flattened
    /// re-iteration over the full element stream.
    pub fn global_norm(&self) -> f64 {
        self.host
            .iter()
            .map(|t| t.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
            .sum::<f64>()
            .sqrt()
    }

    /// Borrowed view of every tensor for the `.rvt` checkpoint writer
    /// (name-tagged). No tensor data is cloned — the writer streams
    /// straight out of the store.
    pub fn snapshot(&self) -> impl Iterator<Item = (&str, &[usize], &[f32])> {
        self.specs
            .iter()
            .zip(&self.host)
            .map(|(s, h)| (s.name.as_str(), s.shape.as_slice(), h.as_slice()))
    }
}

/// Device-resident training state: parameters plus Adam moments pinned
/// as persistent `PjRtBuffer`s.
///
/// This is the buffer-path twin of the `Stepper`'s literal state. Once
/// uploaded, the buffers are threaded through `run_buffers` calls for
/// the rest of a phase; nothing here touches host memory until
/// [`DeviceState::to_literals`] is asked for a snapshot (checkpointing,
/// stage handoff, inspection).
///
/// Lifetime rule (donation): the AOT step functions donate their state
/// arguments, so a successful state-mutating execute CONSUMES the
/// buffers currently held here. Callers must immediately
/// [`DeviceState::replace`] them with the execute's outputs and must
/// never download a state buffer after it was fed to a donating
/// program. `Stepper` is the only intended caller and upholds this.
pub struct DeviceState {
    params: Vec<PjRtBuffer>,
    m: Vec<PjRtBuffer>,
    v: Vec<PjRtBuffer>,
    device: Device,
}

impl DeviceState {
    /// Pin the given literal state on `device` (one upload per tensor).
    pub fn upload(
        device: &Device,
        params: &[Literal],
        m: &[Literal],
        v: &[Literal],
    ) -> Result<Self> {
        Ok(DeviceState {
            params: device.to_device_many(params)?,
            m: device.to_device_many(m)?,
            v: device.to_device_many(v)?,
            device: device.clone(),
        })
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn n_opt(&self) -> usize {
        self.m.len()
    }

    /// Parameter buffers (manifest order). Borrow for `run_buffers`
    /// input lists only.
    pub fn params(&self) -> &[PjRtBuffer] {
        &self.params
    }

    pub fn m(&self) -> &[PjRtBuffer] {
        &self.m
    }

    pub fn v(&self) -> &[PjRtBuffer] {
        &self.v
    }

    /// Adopt a state-mutating execute's outputs as the new pinned state
    /// (the previous buffers were donated to that execute and are gone).
    pub fn replace(
        &mut self,
        params: Vec<PjRtBuffer>,
        m: Vec<PjRtBuffer>,
        v: Vec<PjRtBuffer>,
    ) -> Result<()> {
        if params.len() != self.params.len() || m.len() != self.m.len() || v.len() != self.v.len() {
            return Err(Error::Layout(format!(
                "device state replace: got {}/{}/{} buffers, want {}/{}/{}",
                params.len(),
                m.len(),
                v.len(),
                self.params.len(),
                self.m.len(),
                self.v.len()
            )));
        }
        self.params = params;
        self.m = m;
        self.v = v;
        Ok(())
    }

    /// Re-pin fresh optimizer moments (stage switches reset Adam).
    pub fn reset_opt(&mut self, m: &[Literal], v: &[Literal]) -> Result<()> {
        if m.len() != self.m.len() || v.len() != self.v.len() {
            return Err(Error::Layout("device state reset_opt: arity mismatch".into()));
        }
        self.m = self.device.to_device_many(m)?;
        self.v = self.device.to_device_many(v)?;
        Ok(())
    }

    /// Materialize the pinned state as host literals (params, m, v).
    /// This is the ONLY download point of the buffer path besides the
    /// per-step scalars — snapshots and checkpoints go through here,
    /// lazily, never the inner loop.
    pub fn to_literals(&self) -> Result<(Vec<Literal>, Vec<Literal>, Vec<Literal>)> {
        let dl = |bufs: &[PjRtBuffer]| -> Result<Vec<Literal>> {
            bufs.iter().map(|b| self.device.from_device(b)).collect()
        };
        Ok((dl(&self.params)?, dl(&self.m)?, dl(&self.v)?))
    }
}

/// Optimizer-moment state (m, v) for the trainable subset.
pub struct OptState {
    pub shapes: Vec<Vec<usize>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl OptState {
    /// Fresh zeros, shaped per the manifest's `opt_shapes`.
    pub fn zeros(shapes: &[Vec<usize>]) -> Self {
        let m = shapes
            .iter()
            .map(|s| vec![0f32; literal::elem_count(s)])
            .collect::<Vec<_>>();
        let v = m.clone();
        OptState { shapes: shapes.to_vec(), m, v }
    }

    pub fn to_literals(&self) -> Result<(Vec<Literal>, Vec<Literal>)> {
        let mk = |xs: &Vec<Vec<f32>>| -> Result<Vec<Literal>> {
            xs.iter()
                .zip(&self.shapes)
                .map(|(h, s)| literal::f32_literal(h, s))
                .collect()
        };
        Ok((mk(&self.m)?, mk(&self.v)?))
    }

    pub fn update_from_literals(&mut self, m: &[Literal], v: &[Literal]) -> Result<()> {
        if m.len() != self.m.len() || v.len() != self.v.len() {
            return Err(Error::Layout("opt state arity mismatch".into()));
        }
        for (i, lit) in m.iter().enumerate() {
            self.m[i] = literal::to_f32_vec(lit)?;
        }
        for (i, lit) in v.iter().enumerate() {
            self.v[i] = literal::to_f32_vec(lit)?;
        }
        Ok(())
    }

    /// Bytes held by the moments — the optimizer-state term of Table 1.
    pub fn nbytes(&self) -> u64 {
        (self.m.iter().map(|t| t.len()).sum::<usize>()
            + self.v.iter().map(|t| t.len()).sum::<usize>()) as u64
            * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: Vec<usize>) -> TensorSpec {
        let n: usize = shape.iter().product::<usize>().max(1);
        TensorSpec {
            name: name.into(),
            shape,
            dtype: "f32".into(),
            blob: "x".into(),
            offset: 0,
            nbytes: n * 4,
        }
    }

    #[test]
    fn global_norm_matches_hand_computed() {
        // sum of squares = 4*1 + 9 + 16 = 29  (tensors [1,1,1,1], [3], [4])
        let specs = vec![spec("a", vec![2, 2]), spec("b", vec![1]), spec("c", vec![1])];
        let host = vec![vec![1.0; 4], vec![3.0], vec![4.0]];
        let store = ParamStore::from_host(specs, host).unwrap();
        let want = 29f64.sqrt();
        assert!((store.global_norm() - want).abs() < 1e-12);
    }

    #[test]
    fn global_norm_empty_store_is_zero() {
        let store = ParamStore::from_host(vec![], vec![]).unwrap();
        assert_eq!(store.global_norm(), 0.0);
    }

    #[test]
    fn snapshot_borrows_every_tensor_in_order() {
        let specs = vec![spec("a", vec![2]), spec("b", vec![3])];
        let host = vec![vec![1.0, 2.0], vec![3.0, 4.0, 5.0]];
        let store = ParamStore::from_host(specs, host).unwrap();
        let snap: Vec<_> = store.snapshot().collect();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "a");
        assert_eq!(snap[0].1, &[2]);
        assert_eq!(snap[1].2, &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn update_from_literals_validates_before_download() {
        let specs = vec![spec("a", vec![2])];
        let host = vec![vec![0.0, 0.0]];
        let mut store = ParamStore::from_host(specs, host).unwrap();
        let wrong = literal::f32_literal(&[1.0, 2.0, 3.0], &[3]).unwrap();
        assert!(store.update_from_literals(&[wrong]).is_err());
        // original state untouched by the failed update
        assert_eq!(store.tensor("a").unwrap(), &[0.0, 0.0]);
        let right = literal::f32_literal(&[7.0, 8.0], &[2]).unwrap();
        store.update_from_literals(&[right]).unwrap();
        assert_eq!(store.tensor("a").unwrap(), &[7.0, 8.0]);
    }
}
