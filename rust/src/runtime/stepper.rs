//! `Stepper` — one variant's executable step functions bound to live state.
//!
//! Owns the compiled `train_step` / `grad_step` / `apply_step` /
//! `accum_step` / `scale` / `eval_step` / `forward` programs plus the
//! parameter and optimizer state, and exposes typed entry points the
//! trainer calls every iteration. All buffer ordering logic (the flat
//! manifest layout) is concentrated here.
//!
//! ## State representation (hot-path design)
//!
//! Step outputs are XLA `Literal`s; the stepper keeps them AS literals
//! and feeds them back by reference on the next call (`execute` takes
//! `Borrow<Literal>`), so the steady-state loop performs **zero**
//! host-side parameter copies. The `ParamStore` host mirror is
//! materialized lazily — only for checkpointing, cross-stage adoption,
//! or inspection (see EXPERIMENTS.md §Perf for the before/after).

use std::sync::Arc;
use std::time::Instant;

use xla::Literal;

use crate::error::{Error, Result};
use crate::runtime::artifact::Artifact;
use crate::runtime::literal::{f32_literal, i32_literal, scalar_f32, scalar_to_f32, to_f32_vec};
use crate::runtime::pjrt::{Device, Program, ProgramCache};
use crate::runtime::store::{OptState, ParamStore};

/// One training/eval batch, already tokenized and masked.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub loss_mask: Vec<f32>,
    pub batch_size: usize,
    pub seq_len: usize,
}

impl Batch {
    pub fn validate(&self) -> Result<()> {
        let n = self.batch_size * self.seq_len;
        if self.tokens.len() != n || self.targets.len() != n || self.loss_mask.len() != n {
            return Err(Error::Layout(format!(
                "batch arrays must be {}x{}={}",
                self.batch_size, self.seq_len, n
            )));
        }
        Ok(())
    }
}

/// Scalar results of one optimizer step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    pub grad_norm: f32,
    pub router_aux: f32,
    /// Wall-clock of the PJRT execute call(s).
    pub step_time_s: f64,
}

/// One gradient-only microbatch pass. Gradients stay device-resident
/// (`Literal`s in manifest `trainable_paths` order) — feed them to
/// [`crate::runtime::accum::GradAccumulator`] and
/// [`Stepper::apply_accumulated`] without ever touching host memory.
pub struct GradOut {
    pub grads: Vec<Literal>,
    pub loss: f32,
    pub aux: f32,
    /// Wall-clock of the PJRT execute call.
    pub exec_time_s: f64,
}

pub struct Stepper {
    pub artifact: Artifact,
    /// Host mirror (lazily synchronized; see `materialize_params`).
    pub params: ParamStore,
    host_dirty: bool,
    /// Device-facing state: literals fed by reference every step.
    param_lits: Vec<Literal>,
    m_lits: Vec<Literal>,
    v_lits: Vec<Literal>,
    train: Arc<Program>,
    grad: Option<Arc<Program>>,
    apply: Option<Arc<Program>>,
    /// Accumulation pair: running-sum and mean-scale programs over the
    /// trainable gradients (optional — older artifact sets lack them and
    /// fall back to host summation in `GradAccumulator`).
    accum: Option<Arc<Program>>,
    scale: Option<Arc<Program>>,
    eval: Arc<Program>,
    forward: Arc<Program>,
    /// 1-based optimizer step (Adam bias correction).
    pub step: u64,
}

impl Stepper {
    /// Compile (or fetch cached) programs and stage initial state.
    pub fn new(device: &Device, cache: &ProgramCache, artifact: Artifact) -> Result<Self> {
        let train = cache.get_or_load(device, artifact.hlo_path("train_step")?)?;
        let eval = cache.get_or_load(device, artifact.hlo_path("eval_step")?)?;
        let forward = cache.get_or_load(device, artifact.hlo_path("forward")?)?;
        // grad/apply pair and the accumulation pair are optional
        // (older artifact sets)
        let optional = |kind: &str| -> Result<Option<Arc<Program>>> {
            artifact
                .hlo_path(kind)
                .ok()
                .filter(|p| p.exists())
                .map(|p| cache.get_or_load(device, p))
                .transpose()
        };
        let grad = optional("grad_step")?;
        let apply = optional("apply_step")?;
        let accum = optional("accum_step")?;
        let scale = optional("scale")?;
        let params = ParamStore::from_blobs(&artifact)?;
        let opt = OptState::zeros(&artifact.manifest.io.opt_shapes);
        let param_lits = params.to_literals()?;
        let (m_lits, v_lits) = opt.to_literals()?;
        Ok(Stepper {
            artifact,
            params,
            host_dirty: false,
            param_lits,
            m_lits,
            v_lits,
            train,
            grad,
            apply,
            accum,
            scale,
            eval,
            forward,
            step: 0,
        })
    }

    /// Re-initialize the optimizer moments (stage switches reset Adam).
    pub fn reset_opt(&mut self) -> Result<()> {
        let opt = OptState::zeros(&self.artifact.manifest.io.opt_shapes);
        let (m, v) = opt.to_literals()?;
        self.m_lits = m;
        self.v_lits = v;
        Ok(())
    }

    /// Sync the host mirror from the literal state (no-op when clean).
    pub fn materialize_params(&mut self) -> Result<&ParamStore> {
        if self.host_dirty {
            self.params.update_from_literals(&self.param_lits)?;
            self.host_dirty = false;
        }
        Ok(&self.params)
    }

    /// Rebuild the literal state after mutating the host mirror.
    fn refresh_literals(&mut self) -> Result<()> {
        self.param_lits = self.params.to_literals()?;
        self.host_dirty = false;
        Ok(())
    }

    /// Adopt parameters from another stepper's store (stage handoff or
    /// pre-pass transfer). Tensors are matched by name, with the PEFT
    /// `base.` prefix bridged in both directions (a LoRA tree stores the
    /// backbone under `base.*`, the standard model at the root); missing
    /// tensors keep their current value.
    pub fn adopt_params(&mut self, other: &ParamStore) -> Result<usize> {
        self.materialize_params()?;
        let mut copied = 0;
        let names: Vec<String> =
            self.params.specs().iter().map(|s| s.name.clone()).collect();
        for name in names {
            let candidates = [
                name.clone(),
                name.strip_prefix("base.").map(str::to_string).unwrap_or_default(),
                format!("base.{name}"),
            ];
            for cand in candidates.iter().filter(|c| !c.is_empty()) {
                if let Some(vals) = other.tensor(cand) {
                    self.params.set_tensor(&name, vals.to_vec())?;
                    copied += 1;
                    break;
                }
            }
        }
        self.refresh_literals()?;
        Ok(copied)
    }

    /// Overwrite host params (checkpoint restore) and refresh device state.
    pub fn replace_params(&mut self, mutate: impl FnOnce(&mut ParamStore) -> Result<usize>)
        -> Result<usize> {
        self.materialize_params()?;
        let n = mutate(&mut self.params)?;
        self.refresh_literals()?;
        Ok(n)
    }

    fn batch_literals(&self, batch: &Batch) -> Result<[Literal; 3]> {
        batch.validate()?;
        let shape = [batch.batch_size, batch.seq_len];
        Ok([
            i32_literal(&batch.tokens, &shape)?,
            i32_literal(&batch.targets, &shape)?,
            f32_literal(&batch.loss_mask, &shape)?,
        ])
    }

    /// Execute one fused optimizer step, updating state in place.
    pub fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<StepStats> {
        let io = &self.artifact.manifest.io;
        self.step += 1;
        let [tok, tgt, msk] = self.batch_literals(batch)?;
        let lr_lit = scalar_f32(lr);
        let step_lit = scalar_f32(self.step as f32);
        let mut inputs: Vec<&Literal> = Vec::with_capacity(io.n_params + 2 * io.n_opt + 5);
        inputs.extend(self.param_lits.iter());
        inputs.extend(self.m_lits.iter());
        inputs.extend(self.v_lits.iter());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        inputs.push(&lr_lit);
        inputs.push(&step_lit);

        let t0 = Instant::now();
        let outputs = self.train.run(&inputs)?;
        let step_time_s = t0.elapsed().as_secs_f64();

        let np = io.n_params;
        let no = io.n_opt;
        let expect = np + 2 * no + 3;
        if outputs.len() != expect {
            return Err(Error::Layout(format!(
                "train_step returned {} outputs, manifest wants {expect}",
                outputs.len()
            )));
        }
        let mut outputs = outputs;
        let tail = outputs.split_off(np + 2 * no);
        let v_new = outputs.split_off(np + no);
        let m_new = outputs.split_off(np);
        self.param_lits = outputs;
        self.m_lits = m_new;
        self.v_lits = v_new;
        self.host_dirty = true;

        let loss = scalar_to_f32(&tail[0])?;
        let grad_norm = scalar_to_f32(&tail[1])?;
        let router_aux = scalar_to_f32(&tail[2])?;
        if !loss.is_finite() {
            return Err(Error::Training(format!(
                "non-finite loss {loss} at step {}",
                self.step
            )));
        }
        Ok(StepStats { loss, grad_norm, router_aux, step_time_s })
    }

    /// Gradient-only microbatch pass, gradients left device-resident:
    /// the trainable-tensor `Literal`s (manifest `trainable_paths` order)
    /// come back untouched, only the loss/aux scalars are read to host.
    /// This is the steady-state accumulate hot path.
    pub fn grad_step_literals(&self, batch: &Batch) -> Result<GradOut> {
        let prog = self.grad.as_ref().ok_or_else(|| {
            Error::Config("artifact set lacks grad_step (re-run make artifacts)".into())
        })?;
        let [tok, tgt, msk] = self.batch_literals(batch)?;
        let mut inputs: Vec<&Literal> = Vec::with_capacity(self.param_lits.len() + 3);
        inputs.extend(self.param_lits.iter());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        let t0 = Instant::now();
        let outputs = prog.run(&inputs)?;
        let exec_time_s = t0.elapsed().as_secs_f64();
        let n_t = self.artifact.trainable_indices().len();
        if outputs.len() != n_t + 2 {
            return Err(Error::Layout(format!(
                "grad_step returned {} outputs, want {}",
                outputs.len(),
                n_t + 2
            )));
        }
        let mut grads = outputs;
        let tail = grads.split_off(n_t);
        let loss = scalar_to_f32(&tail[0])?;
        let aux = scalar_to_f32(&tail[1])?;
        Ok(GradOut { grads, loss, aux, exec_time_s })
    }

    /// Host-materialized variant of [`Stepper::grad_step_literals`]
    /// (inspection, tests, the legacy host-summing bench baseline).
    pub fn grad_step(&self, batch: &Batch) -> Result<(Vec<Vec<f32>>, f32, f32)> {
        let out = self.grad_step_literals(batch)?;
        let grads = out.grads.iter().map(to_f32_vec).collect::<Result<Vec<_>>>()?;
        Ok((grads, out.loss, out.aux))
    }

    /// Apply an accumulated (already averaged) gradient held as device
    /// literals — e.g. straight out of
    /// [`crate::runtime::accum::GradAccumulator::finish`]. Returns the
    /// post-clip gradient norm and the execute wall-clock. Increments the
    /// optimizer step.
    pub fn apply_accumulated(&mut self, grads: &[Literal], lr: f32) -> Result<(f32, f64)> {
        let prog = self.apply.as_ref().ok_or_else(|| {
            Error::Config("artifact set lacks apply_step (re-run make artifacts)".into())
        })?;
        let io = &self.artifact.manifest.io;
        let n_t = self.artifact.trainable_indices().len();
        if grads.len() != n_t {
            return Err(Error::Layout(format!(
                "apply: {} grads for {n_t} trainable tensors",
                grads.len()
            )));
        }
        self.step += 1;
        let lr_lit = scalar_f32(lr);
        let step_lit = scalar_f32(self.step as f32);
        let mut inputs: Vec<&Literal> =
            Vec::with_capacity(io.n_params + 2 * io.n_opt + grads.len() + 2);
        inputs.extend(self.param_lits.iter());
        inputs.extend(self.m_lits.iter());
        inputs.extend(self.v_lits.iter());
        inputs.extend(grads.iter());
        inputs.push(&lr_lit);
        inputs.push(&step_lit);
        let t0 = Instant::now();
        let outputs = prog.run(&inputs)?;
        let exec_time_s = t0.elapsed().as_secs_f64();
        let np = io.n_params;
        let no = io.n_opt;
        if outputs.len() != np + 2 * no + 1 {
            return Err(Error::Layout(format!(
                "apply_step returned {} outputs, want {}",
                outputs.len(),
                np + 2 * no + 1
            )));
        }
        let mut outputs = outputs;
        let tail = outputs.split_off(np + 2 * no);
        let v_new = outputs.split_off(np + no);
        let m_new = outputs.split_off(np);
        self.param_lits = outputs;
        self.m_lits = m_new;
        self.v_lits = v_new;
        self.host_dirty = true;
        Ok((scalar_to_f32(&tail[0])?, exec_time_s))
    }

    /// Host-slice variant of [`Stepper::apply_accumulated`] (checkpoint
    /// surgery, the legacy bench baseline): stages the gradients as fresh
    /// literals, then delegates.
    pub fn apply_accumulated_host(&mut self, grads: &[Vec<f32>], lr: f32) -> Result<f32> {
        let t_idx = self.artifact.trainable_indices();
        if grads.len() != t_idx.len() {
            return Err(Error::Layout(format!(
                "apply: {} grads for {} trainable tensors",
                grads.len(),
                t_idx.len()
            )));
        }
        let grad_lits = t_idx
            .iter()
            .zip(grads)
            .map(|(&i, g)| f32_literal(g, &self.artifact.manifest.tensors[i].shape))
            .collect::<Result<Vec<_>>>()?;
        let (norm, _t) = self.apply_accumulated(&grad_lits, lr)?;
        Ok(norm)
    }

    /// Loss-only validation pass (no state mutation).
    pub fn eval_step(&self, batch: &Batch) -> Result<(f32, f32)> {
        let [tok, tgt, msk] = self.batch_literals(batch)?;
        let mut inputs: Vec<&Literal> = Vec::with_capacity(self.param_lits.len() + 3);
        inputs.extend(self.param_lits.iter());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        let outputs = self.eval.run(&inputs)?;
        Ok((scalar_to_f32(&outputs[0])?, scalar_to_f32(&outputs[1])?))
    }

    /// Logits pass: returns [B*S*V] f32 (row-major `[B, S, V]`).
    pub fn forward(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let io = &self.artifact.manifest.io;
        let n = io.batch_size * io.seq_len;
        if tokens.len() != n {
            return Err(Error::Layout(format!(
                "forward wants {} tokens, got {}",
                n,
                tokens.len()
            )));
        }
        let tok = i32_literal(tokens, &[io.batch_size, io.seq_len])?;
        let mut inputs: Vec<&Literal> = Vec::with_capacity(self.param_lits.len() + 1);
        inputs.extend(self.param_lits.iter());
        inputs.push(&tok);
        let outputs = self.forward.run(&inputs)?;
        to_f32_vec(&outputs[0])
    }

    pub fn vocab_size(&self) -> usize {
        self.artifact.manifest.model.vocab_size
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        let io = &self.artifact.manifest.io;
        (io.batch_size, io.seq_len)
    }

    /// Has microbatch accumulation support (grad/apply artifacts)?
    pub fn supports_accumulation(&self) -> bool {
        self.grad.is_some() && self.apply.is_some()
    }

    /// Has the compiled accumulation pair (accum_step/scale artifacts),
    /// i.e. can gradients stay device-resident across microbatches?
    pub fn supports_device_accum(&self) -> bool {
        self.accum.is_some() && self.scale.is_some()
    }

    /// Compiled running-sum program over the trainable gradients, if the
    /// artifact set ships one.
    pub fn accum_program(&self) -> Option<Arc<Program>> {
        self.accum.clone()
    }

    /// Compiled mean-scale program over the trainable gradients, if the
    /// artifact set ships one.
    pub fn scale_program(&self) -> Option<Arc<Program>> {
        self.scale.clone()
    }

    /// Shapes of the trainable tensors (manifest `trainable_paths`
    /// order) — sizes the accumulator's host-fallback buffers.
    pub fn trainable_shapes(&self) -> Vec<Vec<usize>> {
        self.artifact
            .trainable_indices()
            .iter()
            .map(|&i| self.artifact.manifest.tensors[i].shape.clone())
            .collect()
    }
}
