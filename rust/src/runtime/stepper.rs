//! `Stepper` — one variant's executable step functions bound to live state.
//!
//! Owns the compiled `train_step` / `grad_step` / `apply_step` /
//! `accum_step` / `scale` / `eval_step` / `forward` programs plus the
//! parameter and optimizer state, and exposes typed entry points the
//! trainer calls every iteration. All buffer ordering logic (the flat
//! manifest layout) is concentrated here.
//!
//! ## State representation (hot-path design)
//!
//! The stepper holds its state at up to three freshness levels, synced
//! lazily downward:
//!
//! 1. **Device buffers** (`DeviceState`, optional) — params + Adam
//!    moments pinned as `PjRtBuffer`s, threaded through
//!    `Program::run_buffers`. Enabled via
//!    [`Stepper::enable_device_state`]; while active, a training step
//!    moves NOTHING across the host boundary except the batch upload
//!    and the loss/grad-norm/aux scalar downloads.
//! 2. **Literals** (`param_lits`/`m_lits`/`v_lits`) — the literal-path
//!    state, fed by reference to `Program::run`. Stale while
//!    `lits_dirty` (i.e. the device buffers are ahead); synchronized by
//!    one bulk download when a literal-path consumer needs them.
//! 3. **Host mirror** (`ParamStore`) — `Vec<f32>` tensors for
//!    checkpointing, handoff, and inspection. Stale while `host_dirty`;
//!    synchronized by [`Stepper::materialize_params`].
//!
//! Invariant: `lits_dirty` implies a device state exists and has been
//! verified (`buffers_verified`), because only successful buffer-path
//! state mutations set it. Literal-path reads are therefore always
//! current when the buffer path is off or unverified.
//!
//! If the runtime cannot run the buffer path (output arity mismatch —
//! see `Program::run_buffers`), the first buffer-path step fails while
//! the literal state is still current, and the stepper falls back to
//! the literal path automatically and permanently for its lifetime.

use std::sync::Arc;
use std::time::Instant;

use xla::{Literal, PjRtBuffer};

use crate::error::{Error, Result};
use crate::runtime::artifact::Artifact;
use crate::runtime::literal::{f32_literal, i32_literal, scalar_f32, scalar_to_f32, to_f32_vec};
use crate::runtime::pjrt::{Device, Program, ProgramCache};
use crate::runtime::store::{DeviceState, OptState, ParamStore};

/// One training/eval batch, already tokenized and masked.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub loss_mask: Vec<f32>,
    pub batch_size: usize,
    pub seq_len: usize,
}

impl Batch {
    pub fn validate(&self) -> Result<()> {
        let n = self.batch_size * self.seq_len;
        if self.tokens.len() != n || self.targets.len() != n || self.loss_mask.len() != n {
            return Err(Error::Layout(format!(
                "batch arrays must be {}x{}={}",
                self.batch_size, self.seq_len, n
            )));
        }
        Ok(())
    }
}

/// Scalar results of one optimizer step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    pub grad_norm: f32,
    pub router_aux: f32,
    /// Wall-clock of the PJRT execute call(s).
    pub step_time_s: f64,
}

/// One gradient-only microbatch pass. Gradients stay device-resident
/// (`Literal`s in manifest `trainable_paths` order) — feed them to
/// [`crate::runtime::accum::GradAccumulator`] and
/// [`Stepper::apply_accumulated`] without ever touching host memory.
pub struct GradOut {
    pub grads: Vec<Literal>,
    pub loss: f32,
    pub aux: f32,
    /// Wall-clock of the PJRT execute call.
    pub exec_time_s: f64,
}

/// Buffer-path twin of [`GradOut`]: the gradients never left the device
/// — feed them to [`crate::runtime::accum::GradAccumulator::add_buffers`]
/// and [`Stepper::apply_accumulated_buffers`].
pub struct GradOutBuffers {
    pub grads: Vec<PjRtBuffer>,
    pub loss: f32,
    pub aux: f32,
    /// Wall-clock of the PJRT execute call.
    pub exec_time_s: f64,
}

pub struct Stepper {
    pub artifact: Artifact,
    /// Host mirror (lazily synchronized; see `materialize_params`).
    pub params: ParamStore,
    host_dirty: bool,
    /// Device handle (cheap clone of the creator's) for staging batches
    /// and scalars on the buffer path.
    device: Device,
    /// Buffer-resident state, when enabled (authoritative while
    /// `lits_dirty`).
    device_state: Option<DeviceState>,
    /// Literals are stale relative to the device buffers.
    lits_dirty: bool,
    /// The buffer path completed a state-mutating step at least once,
    /// so its output convention is known-good on this runtime.
    buffers_verified: bool,
    /// The buffer path failed its first probe on this runtime
    /// (execute/arity) — permanently literal for this stepper's life.
    /// [`Stepper::enable_device_state`] becomes a no-op, so
    /// suspend/resume cycles (the serve scheduler preempts between
    /// quanta) never re-upload state just to re-fail the probe.
    buffer_path_unsupported: bool,
    /// Literal-facing state: fed by reference on the literal path.
    param_lits: Vec<Literal>,
    m_lits: Vec<Literal>,
    v_lits: Vec<Literal>,
    train: Arc<Program>,
    grad: Option<Arc<Program>>,
    apply: Option<Arc<Program>>,
    /// Accumulation pair: running-sum and mean-scale programs over the
    /// trainable gradients (optional — older artifact sets lack them and
    /// fall back to host summation in `GradAccumulator`).
    accum: Option<Arc<Program>>,
    scale: Option<Arc<Program>>,
    eval: Arc<Program>,
    forward: Arc<Program>,
    /// 1-based optimizer step (Adam bias correction).
    pub step: u64,
}

impl Stepper {
    /// Compile (or fetch cached) programs and stage initial state.
    pub fn new(device: &Device, cache: &ProgramCache, artifact: Artifact) -> Result<Self> {
        let train = cache.get_or_load(device, artifact.hlo_path("train_step")?)?;
        let eval = cache.get_or_load(device, artifact.hlo_path("eval_step")?)?;
        let forward = cache.get_or_load(device, artifact.hlo_path("forward")?)?;
        // grad/apply pair and the accumulation pair are optional
        // (older artifact sets)
        let optional = |kind: &str| -> Result<Option<Arc<Program>>> {
            artifact
                .hlo_path(kind)
                .ok()
                .filter(|p| p.exists())
                .map(|p| cache.get_or_load(device, p))
                .transpose()
        };
        let grad = optional("grad_step")?;
        let apply = optional("apply_step")?;
        let accum = optional("accum_step")?;
        let scale = optional("scale")?;
        let params = ParamStore::from_blobs(&artifact)?;
        let opt = OptState::zeros(&artifact.manifest.io.opt_shapes);
        let param_lits = params.to_literals()?;
        let (m_lits, v_lits) = opt.to_literals()?;
        Ok(Stepper {
            artifact,
            params,
            host_dirty: false,
            device: device.clone(),
            device_state: None,
            lits_dirty: false,
            buffers_verified: false,
            buffer_path_unsupported: false,
            param_lits,
            m_lits,
            v_lits,
            train,
            grad,
            apply,
            accum,
            scale,
            eval,
            forward,
            step: 0,
        })
    }

    /// Pin params + moments as persistent device buffers and route
    /// subsequent steps through `Program::run_buffers`. Idempotent —
    /// and a silent no-op once the buffer path has failed its probe on
    /// this stepper (the fallback to literals is permanent).
    pub fn enable_device_state(&mut self) -> Result<()> {
        if self.device_state.is_some() || self.buffer_path_unsupported {
            return Ok(());
        }
        // literal state is current here: lits_dirty is only ever set
        // while a device state exists
        let ds =
            DeviceState::upload(&self.device, &self.param_lits, &self.m_lits, &self.v_lits)?;
        self.device_state = Some(ds);
        self.buffers_verified = false;
        Ok(())
    }

    /// Leave the buffer path: sync the literal state from the buffers,
    /// then drop them. Idempotent.
    pub fn disable_device_state(&mut self) -> Result<()> {
        self.sync_literals()?;
        self.device_state = None;
        Ok(())
    }

    /// Is the buffer-resident path active?
    pub fn is_device_resident(&self) -> bool {
        self.device_state.is_some()
    }

    /// True when the device buffers can be dropped without losing state
    /// (the literal state is still current — e.g. no buffer-path step
    /// has succeeded yet). The engine uses this to fall back mid-phase.
    pub fn can_abandon_buffers(&self) -> bool {
        self.device_state.is_some() && !self.lits_dirty
    }

    /// Has a buffer-path state mutation succeeded on this stepper (so
    /// the runtime's buffer output convention is known-good and no
    /// fallback redo can happen anymore)?
    pub fn buffers_verified(&self) -> bool {
        self.buffers_verified
    }

    /// Drop the device buffers WITHOUT downloading them. Only legal
    /// while [`Stepper::can_abandon_buffers`]; errors otherwise.
    pub fn abandon_buffers(&mut self) -> Result<()> {
        if self.device_state.is_none() {
            return Ok(());
        }
        if self.lits_dirty {
            return Err(Error::Training(
                "cannot abandon device buffers: they hold the only current state".into(),
            ));
        }
        self.device_state = None;
        self.buffer_path_unsupported = true;
        Ok(())
    }

    /// Re-initialize the optimizer moments (stage switches reset Adam).
    pub fn reset_opt(&mut self) -> Result<()> {
        let opt = OptState::zeros(&self.artifact.manifest.io.opt_shapes);
        let (m, v) = opt.to_literals()?;
        if let Some(ds) = self.device_state.as_mut() {
            ds.reset_opt(&m, &v)?;
        }
        self.m_lits = m;
        self.v_lits = v;
        Ok(())
    }

    /// Sync the literal state from the device buffers (no-op when the
    /// buffer path is off or not ahead). One bulk download.
    fn sync_literals(&mut self) -> Result<()> {
        if !self.lits_dirty {
            return Ok(());
        }
        let ds = self
            .device_state
            .as_ref()
            .ok_or_else(|| Error::Training("literal state lost its device source".into()))?;
        let (p, m, v) = ds.to_literals()?;
        self.param_lits = p;
        self.m_lits = m;
        self.v_lits = v;
        self.lits_dirty = false;
        self.host_dirty = true;
        Ok(())
    }

    /// Sync the host mirror from the live state (no-op when clean).
    /// On the buffer path this is where the lazy snapshot download
    /// happens: device buffers → literals → host vectors.
    pub fn materialize_params(&mut self) -> Result<&ParamStore> {
        self.sync_literals()?;
        if self.host_dirty {
            self.params.update_from_literals(&self.param_lits)?;
            self.host_dirty = false;
        }
        Ok(&self.params)
    }

    /// Rebuild the literal (and, if enabled, buffer) state after
    /// mutating the host mirror.
    fn refresh_literals(&mut self) -> Result<()> {
        self.param_lits = self.params.to_literals()?;
        self.host_dirty = false;
        self.lits_dirty = false;
        if self.device_state.is_some() {
            let ds =
                DeviceState::upload(&self.device, &self.param_lits, &self.m_lits, &self.v_lits)?;
            self.device_state = Some(ds);
        }
        Ok(())
    }

    /// Adopt parameters from another stepper's store (stage handoff or
    /// pre-pass transfer). Tensors are matched by name, with the PEFT
    /// `base.` prefix bridged in both directions (a LoRA tree stores the
    /// backbone under `base.*`, the standard model at the root); missing
    /// tensors keep their current value.
    pub fn adopt_params(&mut self, other: &ParamStore) -> Result<usize> {
        self.materialize_params()?;
        let mut copied = 0;
        let names: Vec<String> = self.params.specs().iter().map(|s| s.name.clone()).collect();
        for name in names {
            let candidates = [
                name.clone(),
                name.strip_prefix("base.").map(str::to_string).unwrap_or_default(),
                format!("base.{name}"),
            ];
            for cand in candidates.iter().filter(|c| !c.is_empty()) {
                if let Some(vals) = other.tensor(cand) {
                    self.params.set_tensor(&name, vals.to_vec())?;
                    copied += 1;
                    break;
                }
            }
        }
        self.refresh_literals()?;
        Ok(copied)
    }

    /// Overwrite host params (checkpoint restore) and refresh device
    /// state.
    pub fn replace_params(
        &mut self,
        mutate: impl FnOnce(&mut ParamStore) -> Result<usize>,
    ) -> Result<usize> {
        self.materialize_params()?;
        let n = mutate(&mut self.params)?;
        self.refresh_literals()?;
        Ok(n)
    }

    /// Manifest shapes of the Adam moments (positional — the checkpoint
    /// format stores moments in this order).
    pub fn opt_shapes(&self) -> &[Vec<usize>] {
        &self.artifact.manifest.io.opt_shapes
    }

    /// Materialize the Adam moments as host vectors (manifest
    /// `opt_shapes` order). On the buffer path this triggers the lazy
    /// device → literal sync first, so the snapshot always reflects the
    /// live state. Cold path: checkpoints only.
    pub fn opt_snapshot(&mut self) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        self.sync_literals()?;
        let m = self.m_lits.iter().map(to_f32_vec).collect::<Result<Vec<_>>>()?;
        let v = self.v_lits.iter().map(to_f32_vec).collect::<Result<Vec<_>>>()?;
        Ok((m, v))
    }

    /// Overwrite the Adam moments from a checkpoint (positional,
    /// shape-checked against the manifest `opt_shapes`) and re-pin the
    /// device copies if the buffer path is active. The counterpart of
    /// [`Stepper::reset_opt`] for resume — restoring params without the
    /// moments silently resets the optimizer and changes training
    /// dynamics, which is exactly the bug full-state checkpoints fix.
    pub fn restore_opt(
        &mut self,
        m: &[(Vec<usize>, Vec<f32>)],
        v: &[(Vec<usize>, Vec<f32>)],
    ) -> Result<()> {
        let shapes = &self.artifact.manifest.io.opt_shapes;
        if m.len() != shapes.len() || v.len() != shapes.len() {
            return Err(Error::Layout(format!(
                "checkpoint has {}/{} moment tensors, manifest wants {}",
                m.len(),
                v.len(),
                shapes.len()
            )));
        }
        for (i, ((ms, _), (vs, _))) in m.iter().zip(v).enumerate() {
            if ms != &shapes[i] || vs != &shapes[i] {
                return Err(Error::Layout(format!(
                    "checkpoint moment {i}: stored shapes {ms:?}/{vs:?} != manifest {:?}",
                    shapes[i]
                )));
            }
        }
        // by invariant the literal state is current unless a device
        // state exists; sync first so a later disable cannot clobber
        // the restored moments with stale buffers
        self.sync_literals()?;
        let mk = |xs: &[(Vec<usize>, Vec<f32>)]| -> Result<Vec<Literal>> {
            xs.iter().map(|(s, d)| f32_literal(d, s)).collect()
        };
        let m_lits = mk(m)?;
        let v_lits = mk(v)?;
        if let Some(ds) = self.device_state.as_mut() {
            ds.reset_opt(&m_lits, &v_lits)?;
        }
        self.m_lits = m_lits;
        self.v_lits = v_lits;
        Ok(())
    }

    /// Set the optimizer step counter (checkpoint resume — Adam bias
    /// correction depends on it, so a resumed run must continue from
    /// the saved count, not from zero).
    pub fn set_step(&mut self, step: u64) {
        self.step = step;
    }

    fn batch_literals(&self, batch: &Batch) -> Result<[Literal; 3]> {
        batch.validate()?;
        let shape = [batch.batch_size, batch.seq_len];
        Ok([
            i32_literal(&batch.tokens, &shape)?,
            i32_literal(&batch.targets, &shape)?,
            f32_literal(&batch.loss_mask, &shape)?,
        ])
    }

    /// Stage a batch as device buffers (tokens, targets, mask).
    fn batch_buffers(&self, batch: &Batch) -> Result<Vec<PjRtBuffer>> {
        let lits = self.batch_literals(batch)?;
        self.device.to_device_many(&lits)
    }

    /// Download a scalar output buffer (loss, grad-norm, aux).
    fn scalar_from_buffer(&self, buf: &PjRtBuffer) -> Result<f32> {
        scalar_to_f32(&self.device.from_device(buf)?)
    }

    /// Execute one fused optimizer step, updating state in place.
    ///
    /// Dispatches to the buffer path when
    /// [`Stepper::enable_device_state`] was called; if that path proves
    /// unsupported on its very first step (while the literal state is
    /// still current), falls back to the literal path for the rest of
    /// this stepper's life.
    pub fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<StepStats> {
        // validate up front so a caller's bad batch surfaces as its own
        // error instead of masquerading as a buffer-path failure below
        batch.validate()?;
        self.step += 1;
        if self.device_state.is_some() {
            match self.train_step_buffers(batch, lr) {
                Ok(stats) => return Ok(stats),
                // only execute/arity failures mean "this runtime cannot
                // run the buffer path" — and only before any buffer
                // step has succeeded (the literal state is still
                // current). Everything else propagates.
                Err(e @ (Error::Layout(_) | Error::Xla(_)))
                    if !self.buffers_verified && self.can_abandon_buffers() =>
                {
                    eprintln!(
                        "[device] buffer path unavailable ({e}); falling back to literal path"
                    );
                    self.device_state = None;
                    self.buffer_path_unsupported = true;
                }
                Err(e) => return Err(e),
            }
        }
        self.train_step_literals(batch, lr)
    }

    /// Buffer-path fused step: state buffers in, state buffers out;
    /// only the three result scalars cross the host boundary (plus the
    /// batch/lr/step upload every step needs).
    fn train_step_buffers(&mut self, batch: &Batch, lr: f32) -> Result<StepStats> {
        let np = self.artifact.manifest.io.n_params;
        let no = self.artifact.manifest.io.n_opt;
        // the timed window spans staging → execute → scalar download,
        // matching what the literal path's `Program::run` wraps, so
        // step times stay comparable across paths (benches rely on it)
        let t0 = Instant::now();
        let staged = self.batch_buffers(batch)?;
        let lr_b = self.device.to_device(&scalar_f32(lr))?;
        let step_b = self.device.to_device(&scalar_f32(self.step as f32))?;
        let outputs = {
            let ds = self.device_state.as_ref().expect("buffer path enabled");
            let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(np + 2 * no + 5);
            inputs.extend(ds.params());
            inputs.extend(ds.m());
            inputs.extend(ds.v());
            inputs.extend(staged.iter());
            inputs.push(&lr_b);
            inputs.push(&step_b);
            self.train.run_buffers(&inputs)?
        };
        let expect = np + 2 * no + 3;
        if outputs.len() != expect {
            return Err(Error::Layout(format!(
                "train_step (buffers) returned {} outputs, manifest wants {expect}",
                outputs.len()
            )));
        }
        let mut outputs = outputs;
        let tail = outputs.split_off(np + 2 * no);
        let v_new = outputs.split_off(np + no);
        let m_new = outputs.split_off(np);
        self.device_state
            .as_mut()
            .expect("buffer path enabled")
            .replace(outputs, m_new, v_new)?;
        self.lits_dirty = true;
        self.host_dirty = true;
        self.buffers_verified = true;
        let loss = self.scalar_from_buffer(&tail[0])?;
        let grad_norm = self.scalar_from_buffer(&tail[1])?;
        let router_aux = self.scalar_from_buffer(&tail[2])?;
        let step_time_s = t0.elapsed().as_secs_f64();
        if !loss.is_finite() {
            return Err(Error::Training(format!(
                "non-finite loss {loss} at step {}",
                self.step
            )));
        }
        Ok(StepStats { loss, grad_norm, router_aux, step_time_s })
    }

    /// Literal-path fused step (staged through PJRT host buffers each
    /// call). The pre-buffer hot path; still the fallback and the cold
    /// paths' workhorse.
    fn train_step_literals(&mut self, batch: &Batch, lr: f32) -> Result<StepStats> {
        let io = &self.artifact.manifest.io;
        let [tok, tgt, msk] = self.batch_literals(batch)?;
        let lr_lit = scalar_f32(lr);
        let step_lit = scalar_f32(self.step as f32);
        let mut inputs: Vec<&Literal> = Vec::with_capacity(io.n_params + 2 * io.n_opt + 5);
        inputs.extend(self.param_lits.iter());
        inputs.extend(self.m_lits.iter());
        inputs.extend(self.v_lits.iter());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        inputs.push(&lr_lit);
        inputs.push(&step_lit);

        let t0 = Instant::now();
        let outputs = self.train.run(&inputs)?;
        let step_time_s = t0.elapsed().as_secs_f64();

        let np = io.n_params;
        let no = io.n_opt;
        let expect = np + 2 * no + 3;
        if outputs.len() != expect {
            return Err(Error::Layout(format!(
                "train_step returned {} outputs, manifest wants {expect}",
                outputs.len()
            )));
        }
        let mut outputs = outputs;
        let tail = outputs.split_off(np + 2 * no);
        let v_new = outputs.split_off(np + no);
        let m_new = outputs.split_off(np);
        self.param_lits = outputs;
        self.m_lits = m_new;
        self.v_lits = v_new;
        self.host_dirty = true;

        let loss = scalar_to_f32(&tail[0])?;
        let grad_norm = scalar_to_f32(&tail[1])?;
        let router_aux = scalar_to_f32(&tail[2])?;
        if !loss.is_finite() {
            return Err(Error::Training(format!(
                "non-finite loss {loss} at step {}",
                self.step
            )));
        }
        Ok(StepStats { loss, grad_norm, router_aux, step_time_s })
    }

    /// Gradient-only microbatch pass, gradients left device-resident:
    /// the trainable-tensor `Literal`s (manifest `trainable_paths` order)
    /// come back untouched, only the loss/aux scalars are read to host.
    /// This is the literal accumulate hot path; the buffer path uses
    /// [`Stepper::grad_step_buffers`].
    pub fn grad_step_literals(&self, batch: &Batch) -> Result<GradOut> {
        if self.lits_dirty {
            return Err(Error::Training(
                "literal grad path called while device buffers are ahead; \
                 use grad_step_buffers or disable_device_state first"
                    .into(),
            ));
        }
        let prog = self.grad.as_ref().ok_or_else(|| {
            Error::Config("artifact set lacks grad_step (re-run make artifacts)".into())
        })?;
        let [tok, tgt, msk] = self.batch_literals(batch)?;
        let mut inputs: Vec<&Literal> = Vec::with_capacity(self.param_lits.len() + 3);
        inputs.extend(self.param_lits.iter());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        let t0 = Instant::now();
        let outputs = prog.run(&inputs)?;
        let exec_time_s = t0.elapsed().as_secs_f64();
        let n_t = self.artifact.trainable_indices().len();
        if outputs.len() != n_t + 2 {
            return Err(Error::Layout(format!(
                "grad_step returned {} outputs, want {}",
                outputs.len(),
                n_t + 2
            )));
        }
        let mut grads = outputs;
        let tail = grads.split_off(n_t);
        let loss = scalar_to_f32(&tail[0])?;
        let aux = scalar_to_f32(&tail[1])?;
        Ok(GradOut { grads, loss, aux, exec_time_s })
    }

    /// Buffer-path gradient pass: params come from the pinned device
    /// state, gradients come back as device buffers. `grad_step` does
    /// not donate its inputs, so the parameter buffers stay live.
    pub fn grad_step_buffers(&self, batch: &Batch) -> Result<GradOutBuffers> {
        let prog = self.grad.as_ref().ok_or_else(|| {
            Error::Config("artifact set lacks grad_step (re-run make artifacts)".into())
        })?;
        let ds = self.device_state.as_ref().ok_or_else(|| {
            Error::Config("grad_step_buffers requires enable_device_state".into())
        })?;
        // timed window covers staging → execute → scalar download, like
        // the literal path's `Program::run` (keeps exec times comparable)
        let t0 = Instant::now();
        let staged = self.batch_buffers(batch)?;
        let outputs = {
            let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(ds.n_params() + 3);
            inputs.extend(ds.params());
            inputs.extend(staged.iter());
            prog.run_buffers(&inputs)?
        };
        let n_t = self.artifact.trainable_indices().len();
        if outputs.len() != n_t + 2 {
            return Err(Error::Layout(format!(
                "grad_step (buffers) returned {} outputs, want {}",
                outputs.len(),
                n_t + 2
            )));
        }
        let mut grads = outputs;
        let tail = grads.split_off(n_t);
        let loss = self.scalar_from_buffer(&tail[0])?;
        let aux = self.scalar_from_buffer(&tail[1])?;
        let exec_time_s = t0.elapsed().as_secs_f64();
        Ok(GradOutBuffers { grads, loss, aux, exec_time_s })
    }

    /// Host-materialized variant of [`Stepper::grad_step_literals`]
    /// (inspection, tests, the legacy host-summing bench baseline).
    pub fn grad_step(&self, batch: &Batch) -> Result<(Vec<Vec<f32>>, f32, f32)> {
        let out = self.grad_step_literals(batch)?;
        let grads = out.grads.iter().map(to_f32_vec).collect::<Result<Vec<_>>>()?;
        Ok((grads, out.loss, out.aux))
    }

    /// Apply an accumulated (already averaged) gradient held as device
    /// literals — e.g. straight out of
    /// [`crate::runtime::accum::GradAccumulator::finish`]. Returns the
    /// post-clip gradient norm and the execute wall-clock. Increments the
    /// optimizer step. If the buffer path is active, syncs and leaves it
    /// first (the two paths must not diverge).
    pub fn apply_accumulated(&mut self, grads: &[Literal], lr: f32) -> Result<(f32, f64)> {
        self.disable_device_state()?;
        let prog = self.apply.as_ref().ok_or_else(|| {
            Error::Config("artifact set lacks apply_step (re-run make artifacts)".into())
        })?;
        let io = &self.artifact.manifest.io;
        let n_t = self.artifact.trainable_indices().len();
        if grads.len() != n_t {
            return Err(Error::Layout(format!(
                "apply: {} grads for {n_t} trainable tensors",
                grads.len()
            )));
        }
        self.step += 1;
        let lr_lit = scalar_f32(lr);
        let step_lit = scalar_f32(self.step as f32);
        let mut inputs: Vec<&Literal> =
            Vec::with_capacity(io.n_params + 2 * io.n_opt + grads.len() + 2);
        inputs.extend(self.param_lits.iter());
        inputs.extend(self.m_lits.iter());
        inputs.extend(self.v_lits.iter());
        inputs.extend(grads.iter());
        inputs.push(&lr_lit);
        inputs.push(&step_lit);
        let t0 = Instant::now();
        let outputs = prog.run(&inputs)?;
        let exec_time_s = t0.elapsed().as_secs_f64();
        let np = io.n_params;
        let no = io.n_opt;
        if outputs.len() != np + 2 * no + 1 {
            return Err(Error::Layout(format!(
                "apply_step returned {} outputs, want {}",
                outputs.len(),
                np + 2 * no + 1
            )));
        }
        let mut outputs = outputs;
        let tail = outputs.split_off(np + 2 * no);
        let v_new = outputs.split_off(np + no);
        let m_new = outputs.split_off(np);
        self.param_lits = outputs;
        self.m_lits = m_new;
        self.v_lits = v_new;
        self.host_dirty = true;
        Ok((scalar_to_f32(&tail[0])?, exec_time_s))
    }

    /// Buffer-path update on the mean gradient (straight out of
    /// [`crate::runtime::accum::GradAccumulator::finish_buffers`]): the
    /// pinned state buffers are donated to `apply_step` and replaced by
    /// its outputs; only the grad-norm scalar is downloaded. Increments
    /// the optimizer step.
    pub fn apply_accumulated_buffers(
        &mut self,
        grads: &[PjRtBuffer],
        lr: f32,
    ) -> Result<(f32, f64)> {
        let prog = self.apply.as_ref().ok_or_else(|| {
            Error::Config("artifact set lacks apply_step (re-run make artifacts)".into())
        })?;
        if self.device_state.is_none() {
            return Err(Error::Config(
                "apply_accumulated_buffers requires enable_device_state".into(),
            ));
        }
        let np = self.artifact.manifest.io.n_params;
        let no = self.artifact.manifest.io.n_opt;
        let n_t = self.artifact.trainable_indices().len();
        if grads.len() != n_t {
            return Err(Error::Layout(format!(
                "apply: {} grads for {n_t} trainable tensors",
                grads.len()
            )));
        }
        // the step counter advances only on success, so the engine's
        // fallback redo of a failed buffer apply cannot double-count
        let next_step = self.step + 1;
        // timed window covers staging → execute → scalar download, like
        // the literal path's `Program::run` (keeps exec times comparable)
        let t0 = Instant::now();
        let lr_b = self.device.to_device(&scalar_f32(lr))?;
        let step_b = self.device.to_device(&scalar_f32(next_step as f32))?;
        let outputs = {
            let ds = self.device_state.as_ref().expect("buffer path enabled");
            let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(np + 2 * no + grads.len() + 2);
            inputs.extend(ds.params());
            inputs.extend(ds.m());
            inputs.extend(ds.v());
            inputs.extend(grads.iter());
            inputs.push(&lr_b);
            inputs.push(&step_b);
            prog.run_buffers(&inputs)?
        };
        if outputs.len() != np + 2 * no + 1 {
            return Err(Error::Layout(format!(
                "apply_step (buffers) returned {} outputs, want {}",
                outputs.len(),
                np + 2 * no + 1
            )));
        }
        let mut outputs = outputs;
        let tail = outputs.split_off(np + 2 * no);
        let v_new = outputs.split_off(np + no);
        let m_new = outputs.split_off(np);
        self.device_state
            .as_mut()
            .expect("buffer path enabled")
            .replace(outputs, m_new, v_new)?;
        self.step = next_step;
        self.lits_dirty = true;
        self.host_dirty = true;
        self.buffers_verified = true;
        let norm = self.scalar_from_buffer(&tail[0])?;
        Ok((norm, t0.elapsed().as_secs_f64()))
    }

    /// Host-slice variant of [`Stepper::apply_accumulated`] (checkpoint
    /// surgery, the legacy bench baseline): stages the gradients as fresh
    /// literals, then delegates.
    pub fn apply_accumulated_host(&mut self, grads: &[Vec<f32>], lr: f32) -> Result<f32> {
        let t_idx = self.artifact.trainable_indices();
        if grads.len() != t_idx.len() {
            return Err(Error::Layout(format!(
                "apply: {} grads for {} trainable tensors",
                grads.len(),
                t_idx.len()
            )));
        }
        let grad_lits = t_idx
            .iter()
            .zip(grads)
            .map(|(&i, g)| f32_literal(g, &self.artifact.manifest.tensors[i].shape))
            .collect::<Result<Vec<_>>>()?;
        let (norm, _t) = self.apply_accumulated(&grad_lits, lr)?;
        Ok(norm)
    }

    /// Loss-only validation pass (no state mutation). Runs on the
    /// buffer path when it is active and verified — `eval_step` does
    /// not donate, so the pinned state stays live — otherwise on the
    /// (current, by invariant) literal state.
    pub fn eval_step(&self, batch: &Batch) -> Result<(f32, f32)> {
        if let Some(ds) = self.device_state.as_ref() {
            if self.buffers_verified {
                let staged = self.batch_buffers(batch)?;
                let outputs = {
                    let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(ds.n_params() + 3);
                    inputs.extend(ds.params());
                    inputs.extend(staged.iter());
                    self.eval.run_buffers(&inputs)?
                };
                if outputs.len() != 2 {
                    return Err(Error::Layout(format!(
                        "eval_step (buffers) returned {} outputs, want 2",
                        outputs.len()
                    )));
                }
                return Ok((
                    self.scalar_from_buffer(&outputs[0])?,
                    self.scalar_from_buffer(&outputs[1])?,
                ));
            }
        }
        let [tok, tgt, msk] = self.batch_literals(batch)?;
        let mut inputs: Vec<&Literal> = Vec::with_capacity(self.param_lits.len() + 3);
        inputs.extend(self.param_lits.iter());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        let outputs = self.eval.run(&inputs)?;
        Ok((scalar_to_f32(&outputs[0])?, scalar_to_f32(&outputs[1])?))
    }

    /// Logits pass: returns [B*S*V] f32 (row-major `[B, S, V]`). Uses
    /// the pinned device params when the buffer path is active and
    /// verified (the logits download is the only host transfer).
    pub fn forward(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let io = &self.artifact.manifest.io;
        let n = io.batch_size * io.seq_len;
        if tokens.len() != n {
            return Err(Error::Layout(format!(
                "forward wants {} tokens, got {}",
                n,
                tokens.len()
            )));
        }
        let tok = i32_literal(tokens, &[io.batch_size, io.seq_len])?;
        if let Some(ds) = self.device_state.as_ref() {
            if self.buffers_verified {
                let tok_b = self.device.to_device(&tok)?;
                let outputs = {
                    let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(ds.n_params() + 1);
                    inputs.extend(ds.params());
                    inputs.push(&tok_b);
                    self.forward.run_buffers(&inputs)?
                };
                if outputs.len() != 1 {
                    return Err(Error::Layout(format!(
                        "forward (buffers) returned {} outputs, want 1",
                        outputs.len()
                    )));
                }
                return to_f32_vec(&self.device.from_device(&outputs[0])?);
            }
        }
        let mut inputs: Vec<&Literal> = Vec::with_capacity(self.param_lits.len() + 1);
        inputs.extend(self.param_lits.iter());
        inputs.push(&tok);
        let outputs = self.forward.run(&inputs)?;
        to_f32_vec(&outputs[0])
    }

    pub fn vocab_size(&self) -> usize {
        self.artifact.manifest.model.vocab_size
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        let io = &self.artifact.manifest.io;
        (io.batch_size, io.seq_len)
    }

    /// Has microbatch accumulation support (grad/apply artifacts)?
    pub fn supports_accumulation(&self) -> bool {
        self.grad.is_some() && self.apply.is_some()
    }

    /// Has the compiled accumulation pair (accum_step/scale artifacts),
    /// i.e. can gradients stay device-resident across microbatches?
    pub fn supports_device_accum(&self) -> bool {
        self.accum.is_some() && self.scale.is_some()
    }

    /// Compiled running-sum program over the trainable gradients, if the
    /// artifact set ships one.
    pub fn accum_program(&self) -> Option<Arc<Program>> {
        self.accum.clone()
    }

    /// Compiled mean-scale program over the trainable gradients, if the
    /// artifact set ships one.
    pub fn scale_program(&self) -> Option<Arc<Program>> {
        self.scale.clone()
    }

    /// Shapes of the trainable tensors (manifest `trainable_paths`
    /// order) — sizes the accumulator's host-fallback buffers.
    pub fn trainable_shapes(&self) -> Vec<Vec<usize>> {
        self.artifact
            .trainable_indices()
            .iter()
            .map(|&i| self.artifact.manifest.tensors[i].shape.clone())
            .collect()
    }

    /// Device handle shared by this stepper's programs and state (the
    /// transfer-stats instrument lives here).
    pub fn device(&self) -> &Device {
        &self.device
    }
}
