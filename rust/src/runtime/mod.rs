//! PJRT runtime (L3 ↔ L2 boundary): artifact manifests, literal
//! conversions, compiled-program cache, parameter store, and the
//! `Stepper` that executes the AOT step functions.
//!
//! Adapted from the `/opt/xla-example/load_hlo` pattern: HLO *text* ->
//! `HloModuleProto::from_text_file` -> `XlaComputation` -> PJRT compile ->
//! execute. Python never runs at training time.
//!
//! Most callers should not construct these types directly:
//! [`crate::engine::Session`] owns the device + cache + artifact + stepper
//! assembly (and checkpoint restore), and [`crate::engine::Run`] drives
//! `Stepper` step functions during training. Reach for this module when
//! building new execution paths (servers, custom probes).

pub mod accum;
pub mod artifact;
pub mod literal;
pub mod pjrt;
pub mod stepper;
pub mod store;

pub use accum::GradAccumulator;
pub use artifact::{Artifact, ArtifactIndex, Manifest, TensorSpec};
pub use pjrt::{Device, Program, ProgramCache, TransferSnapshot};
pub use stepper::{Batch, GradOut, GradOutBuffers, StepStats, Stepper};
pub use store::{DeviceState, OptState, ParamStore};
