//! `GradAccumulator` — device-resident microbatch gradient accumulation.
//!
//! The pre-overhaul accumulate loop downloaded every trainable gradient
//! to host `Vec<f32>`s each microbatch, summed them with scalar loops,
//! and re-uploaded fresh literals for the update — exactly the
//! full-gradient materialization the paper (and LOMO) identify as the
//! dominant cost of full fine-tuning. This accumulator keeps the running
//! sum as XLA `Literal`s end-to-end:
//!
//! * **Buffer path** (`accum_step` + `scale` present AND the stepper
//!   runs device-resident): [`GradAccumulator::add_buffers`] /
//!   [`GradAccumulator::finish_buffers`] thread `PjRtBuffer`s straight
//!   from [`Stepper::grad_step_buffers`] through the compiled pair to
//!   [`Stepper::apply_accumulated_buffers`]. Nothing crosses the host
//!   boundary — not even as staged literals.
//! * **Compiled literal path** (artifact set ships `accum_step` +
//!   `scale`): the first microbatch's gradients are adopted as the
//!   running sum with zero work; each later microbatch runs the compiled
//!   `accum_step(acc…, g…) -> acc+g`; [`GradAccumulator::finish`] runs
//!   `scale(acc…, 1/n) -> mean` (skipped when `n == 1`). The coordinator
//!   never materializes a gradient as `Vec<f32>` and never touches an
//!   element, but each execute still stages its inputs and outputs
//!   through PJRT host buffers (`Program::run`).
//! * **Host fallback** (older artifact sets): each microbatch's
//!   gradients are downloaded once and summed in place into scratch
//!   buffers that are allocated on the first step of a phase and reused
//!   for the rest of it; the mean is uploaded once per optimizer step.
//!
//! Donation note for the buffer path: `accum_step` and `scale` donate
//! the running-sum arguments, so each fold consumes the previous sum
//! buffers and adopts the outputs — exactly the replace-never-reuse
//! rule the stepper follows for its own state.
//!
//! The accumulator is created once per phase (see
//! [`crate::engine::Run`]) and recycled across optimizer steps, so the
//! steady-state loop performs zero per-step heap churn on either path.

use std::sync::Arc;

use xla::{Literal, PjRtBuffer};

use crate::error::{Error, Result};
use crate::obs;
use crate::runtime::literal::{elem_count, f32_literal, scalar_f32, to_f32_vec};
use crate::runtime::pjrt::{Device, Program};
use crate::runtime::stepper::Stepper;

/// Running mean over microbatch gradients (trainable tensors, manifest
/// `trainable_paths` order).
pub struct GradAccumulator {
    accum_prog: Option<Arc<Program>>,
    scale_prog: Option<Arc<Program>>,
    /// Trainable tensor shapes — sizes the fallback buffers and the
    /// final upload.
    shapes: Vec<Vec<usize>>,
    /// Device path: the literal-resident running sum.
    device: Option<Vec<Literal>>,
    /// Buffer path: the buffer-resident running sum (never leaves the
    /// device).
    buffers: Option<Vec<PjRtBuffer>>,
    /// Device handle for the buffer path's scale-scalar upload (set by
    /// [`GradAccumulator::for_stepper`]; absent in fallback-forcing
    /// tests).
    device_handle: Option<Device>,
    /// Fallback path: reusable host sum buffers (allocated lazily once).
    host: Vec<Vec<f32>>,
    host_live: bool,
    /// Microbatches folded into the current sum.
    count: u32,
    /// PJRT execute seconds spent in accum_step/scale since the last
    /// [`GradAccumulator::take_exec_time_s`] (0 on the host fallback).
    exec_s: f64,
}

impl GradAccumulator {
    /// Accumulator for `stepper`'s trainable set, using its compiled
    /// accumulation pair when present.
    pub fn for_stepper(stepper: &Stepper) -> Self {
        let mut acc = Self::new(
            stepper.accum_program(),
            stepper.scale_program(),
            stepper.trainable_shapes(),
        );
        acc.device_handle = Some(stepper.device().clone());
        acc
    }

    /// Explicit constructor (tests force the fallback by passing `None`).
    pub fn new(
        accum_prog: Option<Arc<Program>>,
        scale_prog: Option<Arc<Program>>,
        shapes: Vec<Vec<usize>>,
    ) -> Self {
        GradAccumulator {
            accum_prog,
            scale_prog,
            shapes,
            device: None,
            buffers: None,
            device_handle: None,
            host: Vec::new(),
            host_live: false,
            count: 0,
            exec_s: 0.0,
        }
    }

    /// Drain the PJRT execute seconds spent inside `add`/`finish` since
    /// the last call — the trainer folds this into the step's
    /// `device_time_s` so accumulate and fused paths stay comparable.
    pub fn take_exec_time_s(&mut self) -> f64 {
        std::mem::take(&mut self.exec_s)
    }

    /// Whether gradients stay `Literal`s end-to-end (both programs
    /// present); false means the host fallback is in use.
    pub fn is_device_resident(&self) -> bool {
        self.accum_prog.is_some() && self.scale_prog.is_some()
    }

    /// Microbatches folded into the current sum.
    pub fn count(&self) -> u32 {
        self.count
    }

    /// Can this accumulator run the buffer path (compiled pair +
    /// device handle present)?
    pub fn supports_buffers(&self) -> bool {
        self.accum_prog.is_some() && self.scale_prog.is_some() && self.device_handle.is_some()
    }

    /// Fold one microbatch's gradients (from
    /// [`Stepper::grad_step_literals`]) into the running sum.
    pub fn add(&mut self, grads: Vec<Literal>) -> Result<()> {
        if self.buffers.is_some() {
            return Err(Error::Training(
                "accumulator holds a buffer-path sum; do not mix add() and add_buffers()".into(),
            ));
        }
        if grads.len() != self.shapes.len() {
            return Err(Error::Layout(format!(
                "accumulate: {} grads for {} trainable tensors",
                grads.len(),
                self.shapes.len()
            )));
        }
        self.count += 1;
        if self.is_device_resident() {
            self.add_device(grads)
        } else {
            self.add_host(&grads)
        }
    }

    /// Fold one microbatch's buffer-resident gradients (from
    /// [`Stepper::grad_step_buffers`]) into a buffer-resident running
    /// sum. Requires the compiled accumulation pair.
    pub fn add_buffers(&mut self, grads: Vec<PjRtBuffer>) -> Result<()> {
        if !self.supports_buffers() {
            return Err(Error::Config(
                "artifact set lacks accum_step/scale; buffer-path accumulation unavailable".into(),
            ));
        }
        if self.device.is_some() || self.host_live {
            return Err(Error::Training(
                "accumulator holds a literal-path sum; do not mix add_buffers() and add()".into(),
            ));
        }
        if grads.len() != self.shapes.len() {
            return Err(Error::Layout(format!(
                "accumulate: {} grads for {} trainable tensors",
                grads.len(),
                self.shapes.len()
            )));
        }
        self.count += 1;
        match self.buffers.take() {
            // first microbatch: adopt the gradient buffers as the sum
            None => {
                self.buffers = Some(grads);
                Ok(())
            }
            Some(acc) => {
                let prog = self.accum_prog.as_ref().expect("buffer path");
                let out = {
                    let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(2 * acc.len());
                    inputs.extend(acc.iter());
                    inputs.extend(grads.iter());
                    let sp = obs::span(obs::Site::AccumExecute);
                    let out = prog.run_buffers(&inputs)?;
                    self.exec_s += sp.finish().as_secs_f64();
                    out
                };
                if out.len() != self.shapes.len() {
                    return Err(Error::Layout(format!(
                        "accum_step (buffers) returned {} outputs, want {}",
                        out.len(),
                        self.shapes.len()
                    )));
                }
                self.buffers = Some(out);
                Ok(())
            }
        }
    }

    /// Average the buffer-resident sum and reset for the next optimizer
    /// step. Returns the mean-gradient buffers ready for
    /// [`Stepper::apply_accumulated_buffers`]. The scale scalar upload
    /// is the only host transfer (and only when `n > 1`).
    pub fn finish_buffers(&mut self) -> Result<Vec<PjRtBuffer>> {
        if self.count == 0 {
            return Err(Error::Training("finish_buffers() before any add_buffers()".into()));
        }
        let n = std::mem::take(&mut self.count);
        let acc = self.buffers.take().ok_or_else(|| {
            Error::Training("accumulator lost its buffer state".into())
        })?;
        if n == 1 {
            return Ok(acc); // mean of one = the sum itself
        }
        let prog = self.scale_prog.as_ref().expect("buffer path");
        let device = self.device_handle.as_ref().expect("buffer path");
        let s = device.to_device(&scalar_f32(1.0 / n as f32))?;
        let out = {
            let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(acc.len() + 1);
            inputs.extend(acc.iter());
            inputs.push(&s);
            let sp = obs::span(obs::Site::AccumExecute);
            let out = prog.run_buffers(&inputs)?;
            self.exec_s += sp.finish().as_secs_f64();
            out
        };
        if out.len() != self.shapes.len() {
            return Err(Error::Layout(format!(
                "scale (buffers) returned {} outputs, want {}",
                out.len(),
                self.shapes.len()
            )));
        }
        Ok(out)
    }

    fn add_device(&mut self, grads: Vec<Literal>) -> Result<()> {
        match self.device.take() {
            // first microbatch: adopt the gradients as the sum — no copy
            None => {
                self.device = Some(grads);
                Ok(())
            }
            Some(acc) => {
                let prog = self.accum_prog.as_ref().expect("device path");
                let mut inputs: Vec<&Literal> = Vec::with_capacity(2 * acc.len());
                inputs.extend(acc.iter());
                inputs.extend(grads.iter());
                let sp = obs::span(obs::Site::AccumExecute);
                let out = prog.run(&inputs)?;
                self.exec_s += sp.finish().as_secs_f64();
                if out.len() != self.shapes.len() {
                    return Err(Error::Layout(format!(
                        "accum_step returned {} outputs, want {}",
                        out.len(),
                        self.shapes.len()
                    )));
                }
                self.device = Some(out);
                Ok(())
            }
        }
    }

    fn add_host(&mut self, grads: &[Literal]) -> Result<()> {
        if self.host.is_empty() {
            // one-time allocation, reused for the rest of the phase
            self.host = self.shapes.iter().map(|s| vec![0f32; elem_count(s)]).collect();
        }
        for (acc, lit) in self.host.iter_mut().zip(grads) {
            let g = to_f32_vec(lit)?;
            if g.len() != acc.len() {
                return Err(Error::Layout(format!(
                    "accumulate: gradient has {} elems, want {}",
                    g.len(),
                    acc.len()
                )));
            }
            if self.host_live {
                for (a, x) in acc.iter_mut().zip(&g) {
                    *a += *x;
                }
            } else {
                acc.copy_from_slice(&g);
            }
        }
        self.host_live = true;
        Ok(())
    }

    /// Average the accumulated sum and reset for the next optimizer
    /// step. Returns the mean-gradient literals ready for
    /// [`Stepper::apply_accumulated`].
    pub fn finish(&mut self) -> Result<Vec<Literal>> {
        if self.count == 0 {
            return Err(Error::Training("finish() before any add()".into()));
        }
        let n = std::mem::take(&mut self.count);
        if self.is_device_resident() {
            let acc = self.device.take().ok_or_else(|| {
                Error::Training("accumulator lost its device state".into())
            })?;
            if n == 1 {
                return Ok(acc); // mean of one = the sum itself
            }
            let prog = self.scale_prog.as_ref().expect("device path");
            let s = scalar_f32(1.0 / n as f32);
            let mut inputs: Vec<&Literal> = Vec::with_capacity(acc.len() + 1);
            inputs.extend(acc.iter());
            inputs.push(&s);
            let sp = obs::span(obs::Site::AccumExecute);
            let out = prog.run(&inputs)?;
            self.exec_s += sp.finish().as_secs_f64();
            if out.len() != self.shapes.len() {
                return Err(Error::Layout(format!(
                    "scale returned {} outputs, want {}",
                    out.len(),
                    self.shapes.len()
                )));
            }
            Ok(out)
        } else {
            let scale = 1.0 / n as f32;
            let mut out = Vec::with_capacity(self.host.len());
            for (acc, shape) in self.host.iter_mut().zip(&self.shapes) {
                if n > 1 {
                    for a in acc.iter_mut() {
                        *a *= scale;
                    }
                }
                out.push(f32_literal(acc, shape)?);
            }
            self.host_live = false; // buffers stay allocated for reuse
            Ok(out)
        }
    }
}
