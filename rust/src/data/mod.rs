//! Data pipeline: tokenizer training, synthetic Dolly-like corpus
//! generation, instruction formatting + loss masking, batching, and
//! double-buffered background batch prefetch ([`Pipeline`]).

pub mod batcher;
pub mod dataset;
pub mod pipeline;
pub mod synthetic;
pub mod tokenizer;

pub use batcher::Batcher;
pub use pipeline::Pipeline;
pub use dataset::{encode_corpus, encode_example, encode_lm_text, Sample};
pub use synthetic::{Corpus, CorpusConfig, Example, Family, World};
pub use tokenizer::Tokenizer;
