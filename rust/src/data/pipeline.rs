//! Prefetching batch pipeline: a background thread assembles batches
//! from a [`Batcher`] while the device executes the current one, so
//! tokenized-sample gather/copy overlaps PJRT execution instead of
//! sitting on the critical path of every optimizer step. The queue
//! depth defaults to double buffering and scales with `grad_accum`
//! ([`Pipeline::depth_for`]) so an accumulation burst never drains the
//! queue dry mid-step.
//!
//! Determinism is preserved by construction — the producer thread owns
//! the `Batcher` and calls [`Batcher::fill_next`] in program order, so
//! the delivered sequence is bit-identical to calling the batcher
//! synchronously with the same seed (pinned by the pipeline test in
//! `tests/hotpath.rs`).
//!
//! Buffers are recycled: the consumer hands finished batches back via
//! [`Pipeline::recycle`], and the producer refills them in place
//! ([`Batcher::fill_next`] clears and extends the same allocations), so
//! the steady-state loop allocates nothing per batch.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::thread::JoinHandle;

use crate::data::batcher::Batcher;
use crate::error::{Error, Result};
use crate::runtime::stepper::Batch;

/// Default prefetch depth. 2 = classic double buffering: one being
/// refilled while one waits and one executes.
const DEPTH: usize = 2;

/// Deepest queue [`Pipeline::depth_for`] will pick — a full
/// accumulation burst is bounded so recycled-buffer memory stays flat
/// even for large `grad_accum`.
const MAX_DEPTH: usize = 8;

/// A prefetching wrapper around an epoch-shuffling [`Batcher`].
pub struct Pipeline {
    rx: Option<Receiver<Batch>>,
    recycle_tx: Option<Sender<Batch>>,
    producer: Option<JoinHandle<()>>,
}

impl Pipeline {
    /// Prefetch depth for a `grad_accum` configuration: an optimizer
    /// step drains `grad_accum` batches back to back, so keep one
    /// burst plus a spare ready (floor: double buffering; cap:
    /// [`MAX_DEPTH`]).
    pub fn depth_for(grad_accum: usize) -> usize {
        (grad_accum + 1).clamp(DEPTH, MAX_DEPTH)
    }

    /// Move `batcher` to a background producer thread and start
    /// prefetching immediately (double-buffered).
    pub fn spawn(batcher: Batcher) -> Self {
        Self::spawn_with_depth(batcher, DEPTH)
    }

    /// [`Pipeline::spawn`] with an explicit prefetch depth (how many
    /// assembled batches may sit ahead of the consumer; min 1).
    pub fn spawn_with_depth(mut batcher: Batcher, depth: usize) -> Self {
        let (tx, rx): (SyncSender<Batch>, Receiver<Batch>) = sync_channel(depth.max(1));
        let (recycle_tx, recycle_rx): (Sender<Batch>, Receiver<Batch>) =
            std::sync::mpsc::channel();
        let producer = std::thread::Builder::new()
            .name("batch-prefetch".into())
            .spawn(move || loop {
                // prefer a recycled buffer; fall back to a fresh one
                let mut batch = match recycle_rx.try_recv() {
                    Ok(b) => b,
                    Err(TryRecvError::Empty) => Batch {
                        tokens: Vec::new(),
                        targets: Vec::new(),
                        loss_mask: Vec::new(),
                        batch_size: 0,
                        seq_len: 0,
                    },
                    Err(TryRecvError::Disconnected) => return,
                };
                batcher.fill_next(&mut batch);
                // consumer gone (Pipeline dropped) -> shut down
                if tx.send(batch).is_err() {
                    return;
                }
            })
            .expect("spawn batch-prefetch thread");
        Pipeline { rx: Some(rx), recycle_tx: Some(recycle_tx), producer: Some(producer) }
    }

    /// Take the next prefetched batch (blocks only if the producer is
    /// behind — i.e. batch assembly is slower than device execution).
    pub fn next_batch(&mut self) -> Result<Batch> {
        self.rx
            .as_ref()
            .expect("pipeline alive")
            .recv()
            .map_err(|_| Error::Training("batch prefetch thread died".into()))
    }

    /// Hand a finished batch back for in-place refill.
    pub fn recycle(&mut self, batch: Batch) {
        if let Some(tx) = &self.recycle_tx {
            let _ = tx.send(batch); // producer gone -> just drop the buffer
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // closing both channels unblocks the producer wherever it is
        // (recv on recycle, send on delivery), letting it exit cleanly
        drop(self.rx.take());
        drop(self.recycle_tx.take());
        if let Some(h) = self.producer.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Sample;

    fn samples(n: usize, seq: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| Sample {
                tokens: vec![i as i32; seq],
                targets: vec![(i as i32) + 1; seq],
                loss_mask: vec![1.0; seq],
            })
            .collect()
    }

    #[test]
    fn pipeline_matches_synchronous_batcher() {
        let mut sync = Batcher::new(samples(32, 8), 4, 8, 42);
        let mut pipe = Pipeline::spawn(Batcher::new(samples(32, 8), 4, 8, 42));
        for _ in 0..24 {
            // cross several epoch reshuffles
            let got = pipe.next_batch().unwrap();
            let want = sync.next_batch();
            assert_eq!(got.tokens, want.tokens);
            assert_eq!(got.targets, want.targets);
            assert_eq!(got.loss_mask, want.loss_mask);
            pipe.recycle(got);
        }
    }

    #[test]
    fn drop_shuts_producer_down() {
        let pipe = Pipeline::spawn(Batcher::new(samples(8, 4), 2, 4, 0));
        drop(pipe); // must not hang even with batches in flight
    }

    #[test]
    fn depth_for_scales_with_grad_accum_within_bounds() {
        assert_eq!(Pipeline::depth_for(1), 2); // never below double buffering
        assert_eq!(Pipeline::depth_for(2), 3);
        assert_eq!(Pipeline::depth_for(4), 5);
        assert_eq!(Pipeline::depth_for(64), 8); // capped
    }

    #[test]
    fn deeper_pipeline_preserves_batcher_sequence() {
        let mut sync = Batcher::new(samples(32, 8), 4, 8, 42);
        let mut pipe = Pipeline::spawn_with_depth(Batcher::new(samples(32, 8), 4, 8, 42), 6);
        for _ in 0..24 {
            let got = pipe.next_batch().unwrap();
            let want = sync.next_batch();
            assert_eq!(got.tokens, want.tokens);
            assert_eq!(got.targets, want.targets);
            pipe.recycle(got);
        }
    }
}
