//! Batch assembly: deterministic shuffling, epoch iteration, and
//! microbatch grouping for gradient accumulation.

use crate::data::dataset::Sample;
use crate::util::rng::Rng;
use crate::runtime::stepper::Batch;

/// Epoch-shuffling batcher over encoded samples.
pub struct Batcher {
    samples: Vec<Sample>,
    order: Vec<usize>,
    cursor: usize,
    batch_size: usize,
    seq_len: usize,
    rng: Rng,
    pub epoch: u64,
}

impl Batcher {
    pub fn new(samples: Vec<Sample>, batch_size: usize, seq_len: usize, seed: u64) -> Self {
        let order: Vec<usize> = (0..samples.len()).collect();
        let mut b = Batcher {
            samples,
            order,
            cursor: 0,
            batch_size,
            seq_len,
            rng: Rng::seed_from_u64(seed),
            epoch: 0,
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Number of batches per epoch (full batches only).
    pub fn batches_per_epoch(&self) -> usize {
        self.samples.len() / self.batch_size
    }

    /// Assemble the next batch, wrapping to a new shuffled epoch as needed.
    pub fn next_batch(&mut self) -> Batch {
        let mut batch = Batch {
            tokens: Vec::new(),
            targets: Vec::new(),
            loss_mask: Vec::new(),
            batch_size: self.batch_size,
            seq_len: self.seq_len,
        };
        self.fill_next(&mut batch);
        batch
    }

    /// Assemble the next batch *into* an existing `Batch`, reusing its
    /// buffers (the prefetch pipeline recycles batches through here so
    /// the steady-state loop allocates nothing).
    pub fn fill_next(&mut self, batch: &mut Batch) {
        let b = self.batch_size;
        let s = self.seq_len;
        batch.batch_size = b;
        batch.seq_len = s;
        batch.tokens.clear();
        batch.targets.clear();
        batch.loss_mask.clear();
        batch.tokens.reserve(b * s);
        batch.targets.reserve(b * s);
        batch.loss_mask.reserve(b * s);
        for _ in 0..b {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            let sample = &self.samples[self.order[self.cursor]];
            self.cursor += 1;
            batch.tokens.extend_from_slice(&sample.tokens);
            batch.targets.extend_from_slice(&sample.targets);
            batch.loss_mask.extend_from_slice(&sample.loss_mask);
        }
    }

    /// Advance past `n` batches without assembling them — the resume
    /// path replays the data cursor this way. The epoch/shuffle/cursor
    /// trajectory is identical to calling [`Batcher::fill_next`] `n`
    /// times (the RNG is consumed at exactly the same points), so a
    /// batcher skipped to position `n` delivers bit-identical batches
    /// to one that actually consumed them.
    pub fn skip_batches(&mut self, n: usize) {
        if self.samples.is_empty() {
            return;
        }
        for _ in 0..n {
            for _ in 0..self.batch_size {
                if self.cursor >= self.order.len() {
                    self.epoch += 1;
                    self.reshuffle();
                }
                self.cursor += 1;
            }
        }
    }

    /// Number of full batches `sequential_batches` yields.
    pub fn n_sequential_batches(&self) -> usize {
        self.samples.len() / self.batch_size
    }

    /// Deterministic, in-order batches over the whole set (validation).
    /// Streams lazily — callers that cap evaluation (`cfg.eval_batches`)
    /// only pay for the batches they actually score.
    pub fn sequential_batches(&self) -> impl Iterator<Item = Batch> + '_ {
        let b = self.batch_size;
        let s = self.seq_len;
        self.samples.chunks(b).filter(move |c| c.len() == b).map(move |chunk| {
            let mut tokens = Vec::with_capacity(b * s);
            let mut targets = Vec::with_capacity(b * s);
            let mut mask = Vec::with_capacity(b * s);
            for sample in chunk {
                tokens.extend_from_slice(&sample.tokens);
                targets.extend_from_slice(&sample.targets);
                mask.extend_from_slice(&sample.loss_mask);
            }
            Batch { tokens, targets, loss_mask: mask, batch_size: b, seq_len: s }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples(n: usize, seq: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| Sample {
                tokens: vec![i as i32; seq],
                targets: vec![i as i32; seq],
                loss_mask: vec![1.0; seq],
            })
            .collect()
    }

    #[test]
    fn batches_have_static_shape() {
        let mut b = Batcher::new(samples(10, 8), 4, 8, 0);
        for _ in 0..5 {
            let batch = b.next_batch();
            batch.validate().unwrap();
            assert_eq!(batch.tokens.len(), 32);
        }
    }

    #[test]
    fn epoch_wraps_and_reshuffles() {
        let mut b = Batcher::new(samples(8, 4), 4, 4, 1);
        assert_eq!(b.epoch, 0);
        b.next_batch();
        b.next_batch();
        b.next_batch(); // wraps
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Batcher::new(samples(16, 4), 4, 4, 7);
        let mut b = Batcher::new(samples(16, 4), 4, 4, 7);
        for _ in 0..6 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
    }

    #[test]
    fn sequential_covers_in_order() {
        let b = Batcher::new(samples(9, 4), 2, 4, 0);
        assert_eq!(b.n_sequential_batches(), 4); // 9/2 full batches
        let batches: Vec<Batch> = b.sequential_batches().collect();
        assert_eq!(batches.len(), 4);
        assert_eq!(batches[0].tokens[0], 0);
        assert_eq!(batches[1].tokens[0], 2 * 4 / 4); // sample index 2
    }

    #[test]
    fn sequential_streams_lazily() {
        let b = Batcher::new(samples(100, 4), 2, 4, 0);
        // taking 3 of 50 must not require materializing the rest
        assert_eq!(b.sequential_batches().take(3).count(), 3);
    }

    #[test]
    fn skip_batches_matches_consuming_them() {
        // cross several epoch boundaries so the skipped path exercises
        // the same reshuffle points as real consumption
        for skip in [0usize, 1, 3, 7, 11] {
            let mut consumed = Batcher::new(samples(10, 4), 4, 4, 99);
            for _ in 0..skip {
                consumed.next_batch();
            }
            let mut skipped = Batcher::new(samples(10, 4), 4, 4, 99);
            skipped.skip_batches(skip);
            assert_eq!(skipped.epoch, consumed.epoch, "epoch after skipping {skip}");
            for _ in 0..5 {
                assert_eq!(
                    skipped.next_batch().tokens,
                    consumed.next_batch().tokens,
                    "divergence after skipping {skip}"
                );
            }
        }
    }

    #[test]
    fn skip_batches_on_empty_batcher_is_a_noop() {
        let mut b = Batcher::new(Vec::new(), 4, 4, 0);
        b.skip_batches(100); // must not hang or panic
        assert_eq!(b.epoch, 0);
    }

    #[test]
    fn fill_next_reuses_buffers_and_matches_next_batch() {
        let mut a = Batcher::new(samples(16, 4), 4, 4, 7);
        let mut b = Batcher::new(samples(16, 4), 4, 4, 7);
        let mut reused = a.next_batch();
        let ptr_before = reused.tokens.as_ptr();
        let cap_before = reused.tokens.capacity();
        assert_eq!(reused.tokens, b.next_batch().tokens);
        for _ in 0..5 {
            a.fill_next(&mut reused);
            reused.validate().unwrap();
            assert_eq!(reused.tokens, b.next_batch().tokens);
        }
        // same allocation throughout (capacity never needed to grow)
        assert_eq!(reused.tokens.capacity(), cap_before);
        assert_eq!(reused.tokens.as_ptr(), ptr_before);
    }
}
