//! Byte-level BPE tokenizer (trained from scratch — no external deps).
//!
//! Substrate for the Dolly-style instruction pipeline: the paper
//! fine-tunes a pre-trained tokenizer'd model; here the tokenizer is
//! trained on the synthetic corpus at data-generation time and shipped
//! with the run directory. IDs 0..=3 are reserved: PAD, BOS, EOS, UNK;
//! ids 4..260 are the raw bytes; merges fill the rest of the vocab.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const UNK: i32 = 3;
const BYTE_BASE: i32 = 4;

/// A trained byte-BPE model.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// Merge rules in training order: (left, right) -> new id.
    pub merges: Vec<(i32, i32)>,
    pub vocab_size: usize,
}

impl Tokenizer {
    /// Byte-only tokenizer (no merges) — always valid for vocab >= 260.
    pub fn byte_level(vocab_size: usize) -> Self {
        Tokenizer { merges: Vec::new(), vocab_size }
    }

    /// Train merges greedily on `corpus` until `vocab_size` ids are used.
    ///
    /// Classic BPE: repeatedly merge the most frequent adjacent pair.
    /// Deterministic: frequency ties break on the smaller pair ids.
    pub fn train(corpus: &str, vocab_size: usize) -> Result<Self> {
        if vocab_size < (BYTE_BASE as usize) + 256 {
            return Err(Error::Config(format!(
                "vocab_size {vocab_size} < {} (reserved + bytes)",
                BYTE_BASE + 256
            )));
        }
        let mut ids: Vec<i32> = corpus.bytes().map(|b| b as i32 + BYTE_BASE).collect();
        let mut merges = Vec::new();
        let mut next_id = BYTE_BASE + 256;
        while (next_id as usize) < vocab_size {
            let mut counts: HashMap<(i32, i32), u32> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            let Some((&pair, &count)) = counts
                .iter()
                .max_by_key(|(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
            else {
                break;
            };
            if count < 2 {
                break; // nothing left worth merging
            }
            merges.push(pair);
            ids = Self::apply_merge(&ids, pair, next_id);
            next_id += 1;
        }
        Ok(Tokenizer { merges, vocab_size })
    }

    fn apply_merge(ids: &[i32], pair: (i32, i32), new_id: i32) -> Vec<i32> {
        let mut out = Vec::with_capacity(ids.len());
        let mut i = 0;
        while i < ids.len() {
            if i + 1 < ids.len() && ids[i] == pair.0 && ids[i + 1] == pair.1 {
                out.push(new_id);
                i += 2;
            } else {
                out.push(ids[i]);
                i += 1;
            }
        }
        out
    }

    /// Encode UTF-8 text to token ids (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids: Vec<i32> = text.bytes().map(|b| b as i32 + BYTE_BASE).collect();
        let mut next_id = BYTE_BASE + 256;
        for &pair in &self.merges {
            ids = Self::apply_merge(&ids, pair, next_id);
            next_id += 1;
        }
        ids
    }

    /// Decode ids back to text (merge expansion, then bytes).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut expand: HashMap<i32, (i32, i32)> = HashMap::new();
        let mut next_id = BYTE_BASE + 256;
        for &pair in &self.merges {
            expand.insert(next_id, pair);
            next_id += 1;
        }
        let mut bytes = Vec::new();
        for &id in ids {
            let mut stack = vec![id];
            while let Some(top) = stack.pop() {
                if let Some(&(a, b)) = expand.get(&top) {
                    stack.push(b);
                    stack.push(a);
                } else if (BYTE_BASE..BYTE_BASE + 256).contains(&top) {
                    bytes.push((top - BYTE_BASE) as u8);
                }
                // reserved ids (PAD/BOS/EOS/UNK) decode to nothing
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        use crate::util::json::Json;
        let merges = Json::Arr(
            self.merges
                .iter()
                .map(|&(a, b)| Json::Arr(vec![Json::Num(a as f64), Json::Num(b as f64)]))
                .collect(),
        );
        let j = crate::util::json::ObjBuilder::new()
            .num("vocab_size", self.vocab_size as f64)
            .val("merges", merges)
            .build();
        std::fs::write(path, j.to_string())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        use crate::error::Error;
        let text = std::fs::read_to_string(path)?;
        let j = crate::util::json::parse(&text)?;
        let merges = j
            .arr_of("merges")?
            .iter()
            .map(|pair| {
                let p = pair
                    .as_arr()
                    .ok_or_else(|| Error::Parse("merges: non-array".into()))?;
                let a = p[0].as_f64().ok_or_else(|| Error::Parse("merge: non-num".into()))?;
                let b = p[1].as_f64().ok_or_else(|| Error::Parse("merge: non-num".into()))?;
                Ok((a as i32, b as i32))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Tokenizer { merges, vocab_size: j.usize_of("vocab_size")? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip_without_merges() {
        let tok = Tokenizer::byte_level(512);
        let s = "hello, RevFFN! 123";
        assert_eq!(tok.decode(&tok.encode(s)), s);
    }

    #[test]
    fn train_learns_frequent_pairs() {
        let corpus = "the cat sat on the mat. the cat sat. ".repeat(50);
        let tok = Tokenizer::train(&corpus, 300).unwrap();
        assert!(!tok.merges.is_empty());
        let enc = tok.encode("the cat");
        let plain = Tokenizer::byte_level(512).encode("the cat");
        assert!(enc.len() < plain.len(), "merges should compress");
    }

    #[test]
    fn trained_roundtrip_exact() {
        let corpus = "instruction: add 12 and 34. response: 46. ".repeat(40);
        let tok = Tokenizer::train(&corpus, 320).unwrap();
        for s in ["add 12 and 34", "response: 99", "unseen text!?"] {
            assert_eq!(tok.decode(&tok.encode(s)), s);
        }
    }

    #[test]
    fn ids_stay_in_vocab() {
        let corpus = "aaaa bbbb cccc dddd ".repeat(100);
        let vocab = 280;
        let tok = Tokenizer::train(&corpus, vocab).unwrap();
        let ids = tok.encode(&corpus);
        assert!(ids.iter().all(|&i| (i as usize) < vocab));
    }

    #[test]
    fn vocab_too_small_rejected() {
        assert!(Tokenizer::train("abc", 100).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::util::ScratchDir::new("tok").unwrap();
        let tok = Tokenizer::train(&"ab ab ab ab ".repeat(30), 300).unwrap();
        let p = dir.join("tok.json");
        tok.save(&p).unwrap();
        let tok2 = Tokenizer::load(&p).unwrap();
        assert_eq!(tok.merges, tok2.merges);
        assert_eq!(tok.encode("ab ab"), tok2.encode("ab ab"));
    }
}
