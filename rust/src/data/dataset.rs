//! Instruction formatting, tokenization, packing and loss masking.
//!
//! Mirrors the Dolly SFT recipe: each example is rendered with an
//! instruction template, tokenized, and the loss mask covers ONLY the
//! response tokens (+ EOS). Sequences are truncated/padded to a fixed
//! `seq_len` matching the AOT artifact's static shape.

use crate::data::synthetic::Example;
use crate::data::tokenizer::{Tokenizer, BOS, EOS, PAD};
use crate::error::{Error, Result};

/// One packed training sequence.
#[derive(Debug, Clone)]
pub struct Sample {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub loss_mask: Vec<f32>,
}

/// Render the instruction template (prompt part only).
pub fn render_prompt(instruction: &str) -> String {
    format!("### Instruction:\n{instruction}\n### Response:\n")
}

/// Tokenize + mask one example into a fixed-length `Sample`.
///
/// Layout: `[BOS, prompt…, response…, EOS, PAD…]`; `targets[t]` is
/// `tokens[t+1]` (next-token prediction), `loss_mask` is 1.0 exactly on
/// positions whose *target* is a response token or the EOS.
pub fn encode_example(tok: &Tokenizer, ex: &Example, seq_len: usize) -> Result<Sample> {
    let prompt_ids = tok.encode(&render_prompt(&ex.instruction));
    let resp_ids = tok.encode(&ex.response);

    let mut tokens = Vec::with_capacity(seq_len + 1);
    tokens.push(BOS);
    tokens.extend_from_slice(&prompt_ids);
    let resp_start = tokens.len();
    tokens.extend_from_slice(&resp_ids);
    tokens.push(EOS);
    if resp_start >= seq_len {
        return Err(Error::Config(format!(
            "prompt alone ({resp_start} tokens) exceeds seq_len {seq_len}"
        )));
    }
    tokens.truncate(seq_len + 1);
    let valid = tokens.len();

    let mut toks = vec![PAD; seq_len];
    let mut targets = vec![PAD; seq_len];
    let mut mask = vec![0f32; seq_len];
    for t in 0..seq_len {
        if t < valid {
            toks[t] = tokens[t];
        }
        if t + 1 < valid {
            targets[t] = tokens[t + 1];
            // target position t predicts tokens[t+1]; that token is a
            // response/EOS token iff t+1 >= resp_start
            if t + 1 >= resp_start {
                mask[t] = 1.0;
            }
        }
    }
    Ok(Sample { tokens: toks, targets, loss_mask: mask })
}

/// Plain language-modeling sample from running text (the pre-pass):
/// every non-pad position carries loss.
pub fn encode_lm_chunk(ids: &[i32], seq_len: usize) -> Sample {
    let mut toks = vec![PAD; seq_len];
    let mut targets = vec![PAD; seq_len];
    let mut mask = vec![0f32; seq_len];
    let n = ids.len().min(seq_len + 1);
    for t in 0..seq_len {
        if t < n {
            toks[t] = ids[t];
        }
        if t + 1 < n {
            targets[t] = ids[t + 1];
            mask[t] = 1.0;
        }
    }
    Sample { tokens: toks, targets, loss_mask: mask }
}

/// Tokenize a whole corpus into fixed-length instruction samples,
/// dropping examples whose prompt doesn't fit.
pub fn encode_corpus(tok: &Tokenizer, examples: &[Example], seq_len: usize) -> Vec<Sample> {
    examples
        .iter()
        .filter_map(|ex| encode_example(tok, ex, seq_len).ok())
        .collect()
}

/// Chunk running text into LM samples (stride = seq_len).
pub fn encode_lm_text(tok: &Tokenizer, text: &str, seq_len: usize) -> Vec<Sample> {
    let ids = tok.encode(text);
    ids.chunks(seq_len + 1)
        .filter(|c| c.len() > 1)
        .map(|c| encode_lm_chunk(c, seq_len))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::Family;

    fn tok() -> Tokenizer {
        Tokenizer::byte_level(512)
    }

    fn ex() -> Example {
        Example {
            instruction: "Compute 2 plus 3.".into(),
            response: "The answer is 5.".into(),
            family: Family::Arithmetic,
        }
    }

    #[test]
    fn shapes_are_fixed() {
        let s = encode_example(&tok(), &ex(), 96).unwrap();
        assert_eq!(s.tokens.len(), 96);
        assert_eq!(s.targets.len(), 96);
        assert_eq!(s.loss_mask.len(), 96);
    }

    #[test]
    fn mask_covers_only_response() {
        let t = tok();
        let e = ex();
        let s = encode_example(&t, &e, 128).unwrap();
        let prompt_len = t.encode(&render_prompt(&e.instruction)).len() + 1; // +BOS
        // no loss on prompt-predicting positions
        for i in 0..prompt_len - 1 {
            assert_eq!(s.loss_mask[i], 0.0, "pos {i}");
        }
        let resp_len = t.encode(&e.response).len();
        let masked: f32 = s.loss_mask.iter().sum();
        assert_eq!(masked as usize, resp_len + 1); // response + EOS
    }

    #[test]
    fn targets_shift_by_one() {
        let s = encode_example(&tok(), &ex(), 128).unwrap();
        for i in 0..127 {
            if s.targets[i] != PAD {
                assert_eq!(s.targets[i], s.tokens[i + 1]);
            }
        }
    }

    #[test]
    fn too_long_prompt_rejected() {
        let e = Example {
            instruction: "x".repeat(400),
            response: "y".into(),
            family: Family::Rewrite,
        };
        assert!(encode_example(&tok(), &e, 64).is_err());
    }

    #[test]
    fn lm_chunks_cover_text() {
        let t = tok();
        let samples = encode_lm_text(&t, &"hello world. ".repeat(40), 32);
        assert!(samples.len() > 2);
        for s in &samples {
            assert_eq!(s.tokens.len(), 32);
            assert!(s.loss_mask.iter().sum::<f32>() > 0.0);
        }
    }
}
