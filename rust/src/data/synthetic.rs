//! Synthetic Dolly-like instruction corpus.
//!
//! Substitute for databricks-dolly-15k (see DESIGN.md §Substitutions):
//! deterministic, templated instruction/response pairs over four task
//! families chosen so that each downstream benchmark of Table 2 has a
//! synthetic counterpart with the same *discrimination*:
//!
//! * `Knowledge`  — facts from a closed random world ("The fruit grown in
//!   Valdor is the plum.") → MMLU-like MCQ evaluation.
//! * `Arithmetic` — multi-step modular-sum word problems → GSM8K-like.
//! * `Rewrite`    — instruction-following transformations (reverse,
//!   uppercase, extract) → MT-Bench-like response quality.
//! * A token-permuted "language B" rendering of Knowledge tasks →
//!   Multilingual-like transfer (fine-tuning only on language A should
//!   slightly regress language B, the paper's multilingual dip).
//!
//! Everything is seeded; train/eval splits are disjoint by construction.

use crate::util::json::ObjBuilder;
use crate::util::rng::Rng;

/// One instruction/response pair.
#[derive(Debug, Clone)]
pub struct Example {
    pub instruction: String,
    pub response: String,
    pub family: Family,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Knowledge,
    Arithmetic,
    Rewrite,
    KnowledgeLangB,
}

/// The closed world the knowledge tasks draw from.
#[derive(Debug, Clone)]
pub struct World {
    pub places: Vec<String>,
    pub items: Vec<String>,
    /// facts[p] = index into `items` for place p.
    pub facts: Vec<usize>,
}

const PLACE_STEMS: [&str; 12] = [
    "vald", "quri", "zem", "tolar", "brix", "nuvo", "kesh", "mirra", "olth",
    "pryn", "sorv", "ulek",
];
const ITEM_WORDS: [&str; 8] = [
    "plum", "iron", "silk", "rice", "opal", "wool", "salt", "jade",
];

impl World {
    pub fn generate(seed: u64, n_places: usize) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut places = Vec::with_capacity(n_places);
        for i in 0..n_places {
            let stem = PLACE_STEMS[i % PLACE_STEMS.len()];
            let suffix = ["or", "ia", "um", "eth"][(i / PLACE_STEMS.len()) % 4];
            places.push(format!("{stem}{suffix}"));
        }
        let items: Vec<String> = ITEM_WORDS.iter().map(|s| s.to_string()).collect();
        let facts = (0..n_places).map(|_| rng.gen_range(0..items.len())).collect();
        World { places, items, facts }
    }

    pub fn fact_sentence(&self, p: usize) -> (String, String) {
        (
            format!("What is the product of {}?", self.places[p]),
            format!("The product of {} is {}.", self.places[p], self.items[self.facts[p]]),
        )
    }
}

/// Corpus generator configuration.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub seed: u64,
    pub n_train: usize,
    pub n_eval: usize,
    pub n_places: usize,
    /// Max operands in an arithmetic chain (>=2).
    pub max_chain: usize,
    /// Include the token-permuted language-B knowledge split in training?
    /// (The fine-tuning corpus is English-only, like Dolly; language B
    /// appears only in the *pre-training* mix and the eval suite.)
    pub train_lang_b: bool,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            seed: 17,
            n_train: 2048,
            n_eval: 256,
            n_places: 24,
            max_chain: 4,
            train_lang_b: false,
        }
    }
}

/// Caesar-style letter permutation for "language B".
pub fn to_lang_b(text: &str) -> String {
    text.chars()
        .map(|c| match c {
            'a'..='z' => (b'a' + (c as u8 - b'a' + 7) % 26) as char,
            'A'..='Z' => (b'A' + (c as u8 - b'A' + 7) % 26) as char,
            _ => c,
        })
        .collect()
}

fn arithmetic_example(rng: &mut Rng, max_chain: usize) -> Example {
    let n = rng.gen_range_inclusive(2, max_chain.max(2));
    let nums: Vec<u32> = (0..n).map(|_| rng.gen_u32_range(1..20)).collect();
    let sum: u32 = nums.iter().sum();
    let list = nums
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join(" plus ");
    let mut steps = String::new();
    let mut acc = nums[0];
    for &v in &nums[1..] {
        steps.push_str(&format!("{acc} plus {v} is {}. ", acc + v));
        acc += v;
    }
    Example {
        instruction: format!("Compute {list}."),
        response: format!("{steps}The answer is {sum}."),
        family: Family::Arithmetic,
    }
}

fn rewrite_example(rng: &mut Rng) -> Example {
    let words = ["river", "stone", "amber", "falcon", "meadow", "copper", "harbor"];
    let w = words[rng.gen_range(0..words.len())];
    match rng.gen_range(0..3) {
        0 => Example {
            instruction: format!("Spell the word {w} backwards."),
            response: format!("{}.", w.chars().rev().collect::<String>()),
            family: Family::Rewrite,
        },
        1 => Example {
            instruction: format!("Write the word {w} in capital letters."),
            response: format!("{}.", w.to_uppercase()),
            family: Family::Rewrite,
        },
        _ => Example {
            instruction: format!("What is the first letter of {w}?"),
            response: format!("{}.", w.chars().next().unwrap()),
            family: Family::Rewrite,
        },
    }
}

/// Generated corpus: disjoint train / eval splits + the world.
pub struct Corpus {
    pub train: Vec<Example>,
    pub eval: Vec<Example>,
    pub world: World,
    pub config: CorpusConfig,
}

impl Corpus {
    pub fn generate(config: CorpusConfig) -> Self {
        let world = World::generate(config.seed ^ 0x9e37_79b9, config.n_places);
        let mut rng = Rng::seed_from_u64(config.seed);
        let make = |n: usize, rng: &mut Rng| -> Vec<Example> {
            (0..n)
                .map(|_| match rng.gen_range(0..10) {
                    0..=3 => {
                        let p = rng.gen_range(0..world.places.len());
                        let (q, a) = world.fact_sentence(p);
                        Example { instruction: q, response: a, family: Family::Knowledge }
                    }
                    4..=6 => arithmetic_example(rng, config.max_chain),
                    7..=8 => rewrite_example(rng),
                    _ => {
                        let p = rng.gen_range(0..world.places.len());
                        let (q, a) = world.fact_sentence(p);
                        if config.train_lang_b {
                            Example {
                                instruction: to_lang_b(&q),
                                response: to_lang_b(&a),
                                family: Family::KnowledgeLangB,
                            }
                        } else {
                            Example { instruction: q, response: a, family: Family::Knowledge }
                        }
                    }
                })
                .collect()
        };
        let train = make(config.n_train, &mut rng);
        let eval = make(config.n_eval, &mut rng);
        Corpus { train, eval, world, config }
    }

    /// Raw text of the training split (tokenizer training / LM pre-pass).
    pub fn train_text(&self) -> String {
        let mut s = String::new();
        for ex in &self.train {
            s.push_str(&ex.instruction);
            s.push(' ');
            s.push_str(&ex.response);
            s.push('\n');
        }
        s
    }

    /// Pre-training mix: both languages, all families (the 'pre-trained
    /// checkpoint' substitute — see DESIGN.md §Substitutions).
    pub fn pretrain_text(&self) -> String {
        let mut s = self.train_text();
        for ex in &self.train {
            s.push_str(&to_lang_b(&ex.instruction));
            s.push(' ');
            s.push_str(&to_lang_b(&ex.response));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = Corpus::generate(CorpusConfig::default());
        let b = Corpus::generate(CorpusConfig::default());
        assert_eq!(a.train.len(), b.train.len());
        assert_eq!(a.train[0].instruction, b.train[0].instruction);
        assert_eq!(a.world.facts, b.world.facts);
    }

    #[test]
    fn different_seeds_differ() {
        let a = Corpus::generate(CorpusConfig { seed: 1, ..Default::default() });
        let b = Corpus::generate(CorpusConfig { seed: 2, ..Default::default() });
        assert_ne!(a.world.facts, b.world.facts);
    }

    #[test]
    fn arithmetic_answers_are_correct() {
        let c = Corpus::generate(CorpusConfig::default());
        for ex in c.train.iter().filter(|e| e.family == Family::Arithmetic) {
            let nums: Vec<u32> = ex
                .instruction
                .trim_start_matches("Compute ")
                .trim_end_matches('.')
                .split(" plus ")
                .map(|t| t.parse().unwrap())
                .collect();
            let sum: u32 = nums.iter().sum();
            assert!(ex.response.contains(&format!("The answer is {sum}.")));
        }
    }

    #[test]
    fn lang_b_is_a_bijection() {
        let s = "The product of valdor is plum.";
        let b = to_lang_b(s);
        assert_ne!(s, b);
        // applying the +7 shift 26/ gcd(7,26)=26 times cycles back; check
        // instead that distinct letters stay distinct:
        let b2 = to_lang_b(&b);
        assert_ne!(b, b2);
        assert_eq!(s.len(), b.len());
    }

    #[test]
    fn world_facts_stable_across_splits() {
        let c = Corpus::generate(CorpusConfig::default());
        // every knowledge response in eval must agree with the world
        for ex in c.eval.iter().filter(|e| e.family == Family::Knowledge) {
            let place = ex
                .instruction
                .trim_start_matches("What is the product of ")
                .trim_end_matches('?');
            let p = c.world.places.iter().position(|x| x == place).unwrap();
            assert!(ex.response.contains(&c.world.items[c.world.facts[p]]));
        }
    }
}

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::Knowledge => "knowledge",
            Family::Arithmetic => "arithmetic",
            Family::Rewrite => "rewrite",
            Family::KnowledgeLangB => "knowledge_lang_b",
        }
    }
}

impl Example {
    /// JSONL row (the `gen-data` CLI output).
    pub fn to_json(&self) -> crate::util::json::Json {
        ObjBuilder::new()
            .str("instruction", &self.instruction)
            .str("response", &self.response)
            .str("family", self.family.name())
            .build()
    }
}
