//! Pass 1 (cross-artifact) — `.rvt` checkpoint vs. manifest (CK rules).
//!
//! Answers "would `restore_into` / `restore_opt` accept this file
//! against this variant?" without materializing a single payload: the
//! checkpoint is walked with [`crate::checkpoint::summarize`] (shapes
//! only, bounded reader) and compared to the manifest's tensor specs
//! and `io.opt_shapes` — the exact comparisons the runtime restore path
//! makes, minus the data.

use std::collections::HashMap;
use std::path::Path;

use crate::analysis::Finding;
use crate::checkpoint;
use crate::runtime::artifact::Artifact;

/// Check one checkpoint against one variant directory's manifest.
pub fn check_checkpoint(ckpt: &Path, variant_dir: &Path) -> Vec<Finding> {
    let mut out = Vec::new();
    let subject = ckpt.display().to_string();

    let art = match Artifact::load(variant_dir) {
        Ok(a) => a,
        Err(e) => {
            out.push(Finding::error(
                "AR001",
                variant_dir.display().to_string(),
                format!("cannot load manifest to check against: {e}"),
            ));
            return out;
        }
    };
    let m = &art.manifest;

    // ---- CK001: the file itself must be structurally sound -----------
    let sum = match checkpoint::summarize(ckpt) {
        Ok(s) => s,
        Err(e) => {
            out.push(Finding::error("CK001", subject, format!("unreadable checkpoint: {e}")));
            return out;
        }
    };

    // ---- CK002 / CK003: named tensors vs. manifest specs -------------
    // `restore_into` skips unknown names silently and rejects same-name
    // shape mismatches with Error::Layout; statically the former is a
    // warning (probably the wrong variant) and the latter an error.
    let specs: HashMap<&str, &Vec<usize>> =
        m.tensors.iter().map(|t| (t.name.as_str(), &t.shape)).collect();
    let mut matched = 0usize;
    for (name, shape) in &sum.tensors {
        match specs.get(name.as_str()) {
            Some(want) => {
                if *want != shape {
                    out.push(Finding::error(
                        "CK002",
                        format!("{subject}#{name}"),
                        format!(
                            "stored shape {shape:?} != manifest shape {want:?} — restore_into would reject"
                        ),
                    ));
                } else {
                    matched += 1;
                }
            }
            None => out.push(Finding::warning(
                "CK003",
                format!("{subject}#{name}"),
                format!("tensor {name:?} matches nothing in variant {:?} — restore_into would silently skip it", m.variant),
            )),
        }
    }
    if matched == 0 && !sum.tensors.is_empty() {
        out.push(Finding::warning(
            "CK003",
            subject.clone(),
            format!(
                "none of the {} stored tensors match variant {:?} — restoring would be a no-op",
                sum.tensors.len(),
                m.variant
            ),
        ));
    }

    // ---- CK004: Adam moments vs. io.opt_shapes (positional) ----------
    if let Some((ms, vs)) = &sum.opt_shapes {
        let want = &m.io.opt_shapes;
        if ms.len() != want.len() || vs.len() != want.len() {
            out.push(Finding::error(
                "CK004",
                subject.clone(),
                format!(
                    "moment count m={} v={} != manifest n_opt {} — restore_opt would reject",
                    ms.len(),
                    vs.len(),
                    want.len()
                ),
            ));
        } else {
            for (i, (got, expect)) in ms.iter().chain(vs.iter()).zip(want.iter().chain(want.iter())).enumerate()
            {
                if got != expect {
                    let (tag, idx) = if i < ms.len() { ("m", i) } else { ("v", i - ms.len()) };
                    out.push(Finding::error(
                        "CK004",
                        format!("{subject}#{tag}[{idx}]"),
                        format!("moment shape {got:?} != manifest opt_shape {expect:?}"),
                    ));
                }
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{save_state, OptMoments};
    use crate::runtime::artifact::TensorSpec;
    use crate::runtime::store::ParamStore;
    use crate::util::ScratchDir;

    fn write_variant(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "variant": "sft", "method": "sft",
              "model": {"name": "tiny", "vocab_size": 64, "d_model": 8, "n_layers": 2,
                        "n_heads": 2, "n_kv_heads": 2, "n_experts": 4, "top_k": 2,
                        "d_ff_expert": 16, "d_ff_shared": 16, "max_seq_len": 16},
              "io": {"n_params": 2, "n_opt": 1, "optimizer": "adam",
                     "trainable": [true, false], "trainable_paths": ["embed"],
                     "opt_shapes": [[4, 2]], "batch_size": 2, "seq_len": 4},
              "tensors": [
                {"name": "embed", "shape": [4, 2], "dtype": "f32", "blob": "standard", "offset": 0, "nbytes": 32},
                {"name": "norm_f", "shape": [2], "dtype": "f32", "blob": "standard", "offset": 32, "nbytes": 8}
              ],
              "artifacts": {}
            }"#,
        )
        .unwrap();
    }

    fn store(embed_shape: Vec<usize>) -> ParamStore {
        let nbytes = embed_shape.iter().product::<usize>() * 4;
        let specs = vec![
            TensorSpec {
                name: "embed".into(),
                shape: embed_shape.clone(),
                dtype: "f32".into(),
                blob: "x".into(),
                offset: 0,
                nbytes,
            },
            TensorSpec {
                name: "norm_f".into(),
                shape: vec![2],
                dtype: "f32".into(),
                blob: "x".into(),
                offset: nbytes,
                nbytes: 8,
            },
        ];
        let n = embed_shape.iter().product::<usize>();
        ParamStore::from_host(specs, vec![vec![0.5; n], vec![1.0; 2]]).unwrap()
    }

    #[test]
    fn clean_checkpoint_passes() {
        let dir = ScratchDir::new("ckchk").unwrap();
        write_variant(&dir.join("sft"));
        let ck = dir.join("ok.rvt");
        let opt = OptMoments { m: vec![(vec![4, 2], vec![0.1; 8])], v: vec![(vec![4, 2], vec![0.2; 8])] };
        save_state(&ck, &store(vec![4, 2]), 5, Some(&opt), None).unwrap();
        let f = check_checkpoint(&ck, &dir.join("sft"));
        assert!(f.is_empty(), "unexpected findings: {f:?}");
    }

    #[test]
    fn shape_mismatch_is_ck002_and_moment_mismatch_ck004() {
        let dir = ScratchDir::new("ckchk2").unwrap();
        write_variant(&dir.join("sft"));
        let ck = dir.join("bad.rvt");
        let opt = OptMoments { m: vec![(vec![5, 2], vec![0.1; 10])], v: vec![(vec![5, 2], vec![0.2; 10])] };
        save_state(&ck, &store(vec![5, 2]), 5, Some(&opt), None).unwrap();
        let f = check_checkpoint(&ck, &dir.join("sft"));
        assert!(f.iter().any(|x| x.rule == "CK002"), "{f:?}");
        assert!(f.iter().any(|x| x.rule == "CK004"), "{f:?}");
    }

    #[test]
    fn truncated_checkpoint_is_ck001() {
        let dir = ScratchDir::new("ckchk3").unwrap();
        write_variant(&dir.join("sft"));
        let ck = dir.join("torn.rvt");
        save_state(&ck, &store(vec![4, 2]), 5, None, None).unwrap();
        let full = std::fs::read(&ck).unwrap();
        std::fs::write(&ck, &full[..full.len() / 3]).unwrap();
        let f = check_checkpoint(&ck, &dir.join("sft"));
        assert!(f.iter().any(|x| x.rule == "CK001"), "{f:?}");
    }
}
