//! Pass 5 — docs consistency (DC rules).
//!
//! The docs tree (`README.md` + `docs/*.md`) is part of the product
//! surface, and it drifts: a renamed file leaves a dead link, a CLI
//! table keeps advertising a flag the binary dropped, a doc cites a
//! rule ID the catalog never defined. This pass pins the docs to the
//! code the same way the other passes pin artifacts to programs:
//!
//! * **DC001** — dangling relative link: a markdown link whose target
//!   (resolved against the containing file, fragment stripped) does not
//!   exist on disk. Absolute `http(s)://` / `mailto:` targets and pure
//!   `#fragment` anchors are out of scope.
//! * **DC002** — undocumented-by-code flag: a `--flag` token in the
//!   docs that `main.rs` never reads via the `Flags` accessors. A small
//!   allowlist covers cargo's own flags, which the quickstart examples
//!   legitimately mention.
//! * **DC003** — uncataloged rule ID: an `AR`/`CK`/`CF`/`LN`/`DC` rule
//!   ID cited anywhere in the docs that has no row in the
//!   `docs/ANALYSIS.md` catalog tables.
//! * **DC004** — exported-but-uncataloged metric name: every
//!   `"revffn_…"` string literal in the telemetry layer
//!   (`rust/src/obs/**`, non-test lines) must have a row in the
//!   `docs/OBSERVABILITY.md` catalog tables. Skipped silently when the
//!   tree has no obs module.
//!
//! All scans are line-based so findings carry `file:line` subjects;
//! fenced code blocks are skipped for link extraction (sample payloads
//! may contain bracket syntax) but scanned for flags (usage blocks are
//! exactly where flag tables live).

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use crate::analysis::Finding;

/// Rule-ID families the analysis module defines; DC003 only fires on
/// these prefixes so prose like `RFC2119` can never false-positive.
const ID_FAMILIES: &[&str] = &["AR", "CK", "CF", "LN", "DC", "MM"];

/// Flags the docs may mention that are not `revffn` flags: cargo's own
/// (quickstart build/run and CI command lines), the AOT lowering tool's
/// (`python -m compile.aot --analyze`), plus the `--flag` usage
/// placeholder.
const EXTERNAL_FLAGS: &[&str] = &[
    "--flag",
    "--release",
    "--quiet",
    "--example",
    "--test",
    "--tests",
    "--lib",
    "--bin",
    "--workspace",
    "--features",
    "--no-default-features",
    "--offline",
    "--all-targets",
    "--bench",
    "--no-run",
    "--check",
    "--analyze",
];

/// Markdown links `[text](target)` outside fenced code blocks, as
/// (1-based line, target) pairs.
pub fn extract_links(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut fenced = false;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim_start().starts_with("```") {
            fenced = !fenced;
            continue;
        }
        if fenced {
            continue;
        }
        let b = line.as_bytes();
        let mut i = 0;
        while i + 1 < b.len() {
            if b[i] == b']' && b[i + 1] == b'(' {
                let start = i + 2;
                if let Some(off) = line[start..].find(')') {
                    out.push((lineno + 1, line[start..start + off].trim().to_string()));
                    i = start + off;
                }
            }
            i += 1;
        }
    }
    out
}

/// `--flag` tokens, as (1-based line, flag) pairs. A token starts at a
/// line start / whitespace / `` ` `` / `[` / `|` / `(` / `"` boundary,
/// reads `--` plus a letter plus `[a-z0-9-]*`, and never ends with `-`
/// (so a markdown `---` rule is not a flag).
pub fn extract_flags(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let b = line.as_bytes();
        let mut i = 0;
        while i + 2 < b.len() {
            let boundary = i == 0
                || matches!(b[i - 1], b' ' | b'\t' | b'`' | b'[' | b'|' | b'(' | b'"' | b'=');
            if boundary && b[i] == b'-' && b[i + 1] == b'-' && b[i + 2].is_ascii_lowercase() {
                let mut j = i + 2;
                while j < b.len() && (b[j].is_ascii_lowercase() || b[j].is_ascii_digit() || b[j] == b'-')
                {
                    j += 1;
                }
                let mut end = j;
                while end > i + 2 && b[end - 1] == b'-' {
                    end -= 1;
                }
                out.push((lineno + 1, line[i..end].to_string()));
                i = j;
            } else {
                i += 1;
            }
        }
    }
    out
}

/// The flag set `main.rs` accepts, derived from its `Flags` accessor
/// calls (`f.opt("x")`, `f.str("x", …)`, `f.u64`/`f.f64`/`f.bool`):
/// accessor key `tenant_max_jobs` ↔ CLI flag `--tenant-max-jobs`.
pub fn accepted_flags(main_src: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    out.insert("--help".to_string());
    for pat in ["opt(\"", "str(\"", "u64(\"", "f64(\"", "bool(\""] {
        let mut rest = main_src;
        while let Some(at) = rest.find(pat) {
            let tail = &rest[at + pat.len()..];
            if let Some(end) = tail.find('"') {
                let key = &tail[..end];
                if !key.is_empty() && key.bytes().all(|c| c.is_ascii_alphanumeric() || c == b'_') {
                    out.insert(format!("--{}", key.replace('_', "-")));
                }
                rest = &tail[end..];
            } else {
                break;
            }
        }
    }
    out
}

/// Rule IDs with a catalog row in `docs/ANALYSIS.md`: the first cell of
/// any table row (`| AR001 | … |`), backticks tolerated.
pub fn catalog_ids(analysis_md: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in analysis_md.lines() {
        let t = line.trim();
        let Some(rest) = t.strip_prefix('|') else { continue };
        let Some(cell) = rest.split('|').next() else { continue };
        let id = cell.trim().trim_matches('`');
        if is_rule_id(id) {
            out.insert(id.to_string());
        }
    }
    out
}

/// Rule IDs cited anywhere in a doc, as (1-based line, id) pairs.
/// Byte-wise (doc prose is full of multi-byte punctuation; an ID match
/// is pure ASCII, so a continuation byte can never start one).
pub fn cited_ids(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let b = line.as_bytes();
        let mut i = 0;
        while i + 5 <= b.len() {
            let before_ok = i == 0 || !(b[i - 1].is_ascii_alphanumeric());
            let after_ok = i + 5 == b.len() || !(b[i + 5].is_ascii_alphanumeric());
            if before_ok && after_ok && is_rule_id_bytes(&b[i..i + 5]) {
                out.push((lineno + 1, String::from_utf8_lossy(&b[i..i + 5]).into_owned()));
                i += 5;
            } else {
                i += 1;
            }
        }
    }
    out
}

/// `"revffn_…"` string literals in telemetry source text — the
/// exported metric-name surface DC004 pins to the catalog. Only whole
/// literals that look like metric names count (lowercase/digit/underscore
/// after the prefix), so prefix checks like `starts_with("revffn_")` and
/// rendered sample lines in tests never register; scanning stops at the
/// trailing `#[cfg(test)]` block (repo convention: tests last).
pub fn exported_metric_names(obs_src: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in obs_src.lines() {
        if line.trim() == "#[cfg(test)]" {
            break;
        }
        let b = line.as_bytes();
        let mut i = 0;
        while i < b.len() {
            if b[i] == b'"' {
                if let Some(off) = b[i + 1..].iter().position(|&c| c == b'"') {
                    let lit = &b[i + 1..i + 1 + off];
                    if lit.starts_with(b"revffn_")
                        && lit.len() > "revffn_".len()
                        && lit.iter().all(|&c| {
                            c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'_'
                        })
                    {
                        out.insert(String::from_utf8_lossy(lit).into_owned());
                    }
                    i += off + 2;
                    continue;
                }
            }
            i += 1;
        }
    }
    out
}

/// Metric names with a catalog row in `docs/OBSERVABILITY.md`: the
/// first cell of any table row (`| revffn_steps_total | … |`),
/// backticks tolerated.
pub fn cataloged_metrics(observability_md: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in observability_md.lines() {
        let t = line.trim();
        let Some(rest) = t.strip_prefix('|') else { continue };
        let Some(cell) = rest.split('|').next() else { continue };
        let name = cell.trim().trim_matches('`');
        if name.starts_with("revffn_") {
            out.insert(name.to_string());
        }
    }
    out
}

fn is_rule_id_bytes(b: &[u8]) -> bool {
    b.len() == 5
        && ID_FAMILIES.iter().any(|f| f.as_bytes() == &b[..2])
        && b[2..].iter().all(u8::is_ascii_digit)
}

fn is_rule_id(s: &str) -> bool {
    is_rule_id_bytes(s.as_bytes())
}

/// Run the whole docs pass rooted at the repo top (the directory
/// holding `README.md` and `docs/`).
pub fn check_docs(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut files: Vec<PathBuf> = Vec::new();
    let readme = root.join("README.md");
    if readme.is_file() {
        files.push(readme);
    } else {
        findings.push(Finding::error(
            "DC001",
            readme.display().to_string(),
            "README.md missing — the repo has no front door",
        ));
    }
    let docs_dir = root.join("docs");
    let mut doc_pages: Vec<PathBuf> = std::fs::read_dir(&docs_dir)
        .map(|rd| {
            rd.flatten()
                .map(|e| e.path())
                .filter(|p| p.extension().map(|e| e == "md").unwrap_or(false))
                .collect()
        })
        .unwrap_or_default();
    doc_pages.sort();
    files.extend(doc_pages);

    let accepted = ["rust/src/main.rs", "src/main.rs"]
        .iter()
        .map(|p| root.join(p))
        .find(|p| p.is_file())
        .and_then(|p| std::fs::read_to_string(&p).ok())
        .map(|src| accepted_flags(&src));
    if accepted.is_none() {
        findings.push(Finding::warning(
            "DC002",
            root.display().to_string(),
            "main.rs not found under rust/src or src — flag check skipped",
        ));
    }
    let catalog = std::fs::read_to_string(docs_dir.join("ANALYSIS.md")).ok().map(|t| catalog_ids(&t));
    if catalog.is_none() {
        findings.push(Finding::error(
            "DC003",
            docs_dir.join("ANALYSIS.md").display().to_string(),
            "docs/ANALYSIS.md missing — rule IDs have no catalog to resolve against",
        ));
    }

    // DC004 — exported-but-uncataloged metric names. Skipped silently
    // when the tree has no telemetry module (scratch fixtures, packaged
    // crates); a missing catalog then means every exported name fires.
    if let Some(obs_dir) =
        ["rust/src/obs", "src/obs"].iter().map(|p| root.join(p)).find(|p| p.is_dir())
    {
        let cataloged = std::fs::read_to_string(docs_dir.join("OBSERVABILITY.md"))
            .ok()
            .map(|t| cataloged_metrics(&t))
            .unwrap_or_default();
        let mut obs_files: Vec<PathBuf> = std::fs::read_dir(&obs_dir)
            .map(|rd| {
                rd.flatten()
                    .map(|e| e.path())
                    .filter(|p| p.extension().map(|e| e == "rs").unwrap_or(false))
                    .collect()
            })
            .unwrap_or_default();
        obs_files.sort();
        for file in &obs_files {
            let rel = file.strip_prefix(root).unwrap_or(file).to_string_lossy().replace('\\', "/");
            let Ok(text) = std::fs::read_to_string(file) else { continue };
            for name in exported_metric_names(&text) {
                if !cataloged.contains(&name) {
                    findings.push(Finding::error(
                        "DC004",
                        rel.clone(),
                        format!(
                            "metric {name} is exported here but has no docs/OBSERVABILITY.md catalog row"
                        ),
                    ));
                }
            }
        }
    }

    for file in &files {
        let rel = file.strip_prefix(root).unwrap_or(file).to_string_lossy().replace('\\', "/");
        let text = match std::fs::read_to_string(file) {
            Ok(t) => t,
            Err(e) => {
                findings.push(Finding::error("DC001", rel, format!("unreadable: {e}")));
                continue;
            }
        };
        let parent = file.parent().unwrap_or(root);
        for (line, target) in extract_links(&text) {
            let bare = target.split('#').next().unwrap_or("");
            if bare.is_empty() || bare.contains("://") || bare.starts_with("mailto:") {
                continue;
            }
            if !parent.join(bare).exists() {
                findings.push(Finding::error(
                    "DC001",
                    format!("{rel}:{line}"),
                    format!("dangling link: {target} does not exist"),
                ));
            }
        }
        if let Some(accepted) = &accepted {
            for (line, flag) in extract_flags(&text) {
                if !accepted.contains(&flag) && !EXTERNAL_FLAGS.contains(&flag.as_str()) {
                    findings.push(Finding::error(
                        "DC002",
                        format!("{rel}:{line}"),
                        format!("docs mention {flag}, which main.rs does not accept"),
                    ));
                }
            }
        }
        if let Some(catalog) = &catalog {
            for (line, id) in cited_ids(&text) {
                if !catalog.contains(&id) {
                    findings.push(Finding::error(
                        "DC003",
                        format!("{rel}:{line}"),
                        format!("rule {id} is cited here but has no docs/ANALYSIS.md catalog row"),
                    ));
                }
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn links_extracted_outside_fences_only() {
        let md = "\
see [the api](API.md) and [site](https://example.com#x)\n\
```\n\
not a [link](inside_fence.md)\n\
```\n\
anchor [here](#section) and [rel](../README.md#top)\n";
        let links = extract_links(md);
        let targets: Vec<&str> = links.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(
            targets,
            vec!["API.md", "https://example.com#x", "#section", "../README.md#top"]
        );
        assert_eq!(links[0].0, 1);
        assert_eq!(links[3].0, 5);
    }

    #[test]
    fn flags_extracted_with_boundaries() {
        let md = "use `--budget-gb G` or [--no-recover]; a table |--quantum N|\n\
---\n\
prose--not-a-flag and --x\n";
        let flags: Vec<&str> = extract_flags(md).iter().map(|(_, f)| f.as_str()).collect();
        assert_eq!(flags, vec!["--budget-gb", "--no-recover", "--quantum", "--x"]);
    }

    #[test]
    fn accepted_flags_derived_from_accessor_calls() {
        let src = r#"
            let a = f.opt("artifacts");
            let b = f.u64("stage1_steps", 30)?;
            let c = f.f64("budget_gb", 80.0)?;
            if f.bool("no_recover") {}
            let d = f.str("method", "revffn");
        "#;
        let acc = accepted_flags(src);
        for flag in ["--artifacts", "--stage1-steps", "--budget-gb", "--no-recover", "--method", "--help"]
        {
            assert!(acc.contains(flag), "missing {flag}: {acc:?}");
        }
        assert!(!acc.contains("--revffn"), "string values are not flags");
    }

    #[test]
    fn catalog_and_citations_roundtrip() {
        let catalog_md = "| rule | meaning |\n|---|---|\n| `AR001` | x |\n| LN004 | y |\n";
        let ids = catalog_ids(catalog_md);
        assert!(ids.contains("AR001") && ids.contains("LN004"));
        assert_eq!(ids.len(), 2);
        let cited = cited_ids("AR001 fires before LN004; RFC2119 and PR007 do not count; XAR001y neither\n");
        let names: Vec<&str> = cited.iter().map(|(_, i)| i.as_str()).collect();
        assert_eq!(names, vec!["AR001", "LN004"]);
    }

    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("revffn-docs-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("docs")).unwrap();
        std::fs::create_dir_all(dir.join("rust/src")).unwrap();
        dir
    }

    #[test]
    fn clean_tree_passes() {
        let dir = scratch("clean");
        std::fs::write(
            dir.join("README.md"),
            "see [serve](docs/SERVE.md); run `revffn serve --budget-gb 40`. AR001.\n",
        )
        .unwrap();
        std::fs::write(dir.join("docs/SERVE.md"), "back to [readme](../README.md)\n").unwrap();
        std::fs::write(dir.join("docs/ANALYSIS.md"), "| `AR001` | a rule |\n").unwrap();
        std::fs::write(dir.join("rust/src/main.rs"), "f.f64(\"budget_gb\", 80.0)").unwrap();
        let f = check_docs(&dir);
        assert!(f.is_empty(), "{f:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn each_rule_fires_on_its_defect() {
        let dir = scratch("dirty");
        std::fs::write(
            dir.join("README.md"),
            "dead [link](docs/GONE.md); flag `--no-such-flag`; rule DC999.\n",
        )
        .unwrap();
        std::fs::write(dir.join("docs/ANALYSIS.md"), "| `AR001` | a rule |\n").unwrap();
        std::fs::write(dir.join("rust/src/main.rs"), "f.opt(\"config\")").unwrap();
        let f = check_docs(&dir);
        let rules: Vec<&str> = f.iter().map(|x| x.rule).collect();
        assert!(rules.contains(&"DC001"), "{f:?}");
        assert!(rules.contains(&"DC002"), "{f:?}");
        assert!(rules.contains(&"DC003"), "{f:?}");
        assert_eq!(f.len(), 3, "{f:?}");
        assert!(f.iter().all(|x| x.subject.starts_with("README.md:1")), "{f:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn metric_names_extracted_from_whole_literals_only() {
        let src = "\
pub const A: &str = \"revffn_steps_total\";\n\
let p = n.starts_with(\"revffn_\");\n\
let l = \"revffn_steps_total 1\";\n\
#[cfg(test)]\n\
mod tests { const T: &str = \"revffn_test_metric\"; }\n";
        let names = exported_metric_names(src);
        assert_eq!(names.into_iter().collect::<Vec<_>>(), vec!["revffn_steps_total"]);
        let ids = cataloged_metrics("| `revffn_steps_total` | counter | — |\n| rule | x |\n");
        assert_eq!(ids.into_iter().collect::<Vec<_>>(), vec!["revffn_steps_total"]);
    }

    #[test]
    fn uncataloged_metric_fires_dc004() {
        let dir = scratch("metric");
        std::fs::create_dir_all(dir.join("rust/src/obs")).unwrap();
        std::fs::write(dir.join("README.md"), "front door\n").unwrap();
        std::fs::write(dir.join("docs/ANALYSIS.md"), "| `AR001` | a rule |\n").unwrap();
        std::fs::write(dir.join("rust/src/main.rs"), "f.opt(\"config\")").unwrap();
        std::fs::write(
            dir.join("rust/src/obs/registry.rs"),
            "pub const A: &str = \"revffn_lost_total\";\npub const B: &str = \"revffn_kept_total\";\n",
        )
        .unwrap();
        std::fs::write(dir.join("docs/OBSERVABILITY.md"), "| `revffn_kept_total` | counter |\n")
            .unwrap();
        let f = check_docs(&dir);
        let dc4: Vec<_> = f.iter().filter(|x| x.rule == "DC004").collect();
        assert_eq!(dc4.len(), 1, "{f:?}");
        assert!(dc4[0].message.contains("revffn_lost_total"), "{f:?}");
        assert_eq!(dc4[0].subject, "rust/src/obs/registry.rs");
        // cataloging the name clears the finding
        std::fs::write(
            dir.join("docs/OBSERVABILITY.md"),
            "| `revffn_kept_total` | counter |\n| `revffn_lost_total` | counter |\n",
        )
        .unwrap();
        assert!(check_docs(&dir).iter().all(|x| x.rule != "DC004"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_readme_and_catalog_reported() {
        let dir = scratch("missing");
        std::fs::write(dir.join("rust/src/main.rs"), "f.opt(\"config\")").unwrap();
        let f = check_docs(&dir);
        assert!(f.iter().any(|x| x.rule == "DC001" && x.message.contains("front door")), "{f:?}");
        assert!(f.iter().any(|x| x.rule == "DC003" && x.message.contains("catalog")), "{f:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn own_docs_tree_is_clean() {
        // the acceptance gate: `revffn check --docs` passes on the
        // shipped docs — enforced here and in the static CI job
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
        if !root.join("README.md").is_file() {
            return; // packaged crate without the repo docs tree
        }
        let f = check_docs(&root);
        assert!(f.is_empty(), "docs findings: {f:#?}");
    }
}
