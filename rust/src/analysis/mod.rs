//! `revffn check` — device-free static contract analysis.
//!
//! Every correctness claim the repo makes (bit-identical resume,
//! buffer-vs-literal parity, solo-vs-interleaved serve parity) rests on
//! program/manifest contracts that are otherwise only validated by
//! executing on a PJRT device. This module checks them statically — no
//! XLA, no device, no Python — so a stale artifact set, a
//! shape-mismatched `.rvt`, or a truncated program inventory is caught
//! in the always-on CI job instead of as a runtime crash mid-run.
//!
//! Six passes, each a pure function from inputs to [`Finding`]s:
//!
//! * [`contract::check_artifacts`] — artifact dir vs. what `Stepper` /
//!   `GradAccumulator` / `DeviceState` will feed the programs (AR rules)
//! * [`ckpt::check_checkpoint`] — `.rvt` structure vs. a manifest:
//!   would `restore_into` / `restore_opt` accept it? (CK rules)
//! * [`configcheck::check_config`] — run/serve config vs. the analytic
//!   memory model: does the priced peak fit the budget? (CF rules)
//! * [`lint::lint_sources`] — comment/string-aware source scan of
//!   `rust/src/**` enforcing repo invariants (LN rules)
//! * [`docs::check_docs`] — docs-tree consistency: dangling links,
//!   flags the binary does not accept, uncataloged rule IDs (DC rules)
//! * [`liveness::check_hlo_mem`] — schedule-order HLO liveness: static
//!   per-program peak live bytes vs. the analytic model (MM rules)
//!
//! Rule IDs are stable and documented in `docs/ANALYSIS.md`; adding a
//! rule means adding a `Finding` emission and a catalog row, nothing
//! else. Output is human text or machine JSON (`--json`), and the CLI
//! exits nonzero iff any error-severity finding exists.

pub mod ckpt;
pub mod configcheck;
pub mod contract;
pub mod docs;
pub mod hlo;
pub mod lint;
pub mod liveness;

pub use ckpt::check_checkpoint;
pub use configcheck::check_config;
pub use contract::check_artifacts;
pub use docs::check_docs;
pub use lint::lint_sources;
pub use liveness::check_hlo_mem;

use crate::util::json::{Json, ObjBuilder};

/// How bad a finding is. `Error` findings fail the CLI (nonzero exit);
/// `Warning`s are advisory (degraded checks, soft budgets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One rule violation: a stable rule ID, a subject (variant, file:line,
/// config path — whatever locates the defect), and a human message.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    pub subject: String,
    pub message: String,
}

impl Finding {
    pub fn error(rule: &'static str, subject: impl Into<String>, message: impl Into<String>) -> Self {
        Finding { rule, severity: Severity::Error, subject: subject.into(), message: message.into() }
    }

    pub fn warning(
        rule: &'static str,
        subject: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        Finding {
            rule,
            severity: Severity::Warning,
            subject: subject.into(),
            message: message.into(),
        }
    }

    fn to_json(&self) -> Json {
        ObjBuilder::new()
            .str("rule", self.rule)
            .str("severity", self.severity.name())
            .str("subject", &self.subject)
            .str("message", &self.message)
            .build()
    }
}

/// All findings of one `revffn check` invocation.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
}

impl Report {
    /// Findings are sorted by `(rule, subject, message)` so text and
    /// `--json` output are deterministic regardless of pass order or
    /// filesystem iteration — CI diffs and fixture assertions stay
    /// order-stable.
    pub fn new(mut findings: Vec<Finding>) -> Self {
        findings.sort_by(|a, b| {
            a.rule
                .cmp(b.rule)
                .then_with(|| a.subject.cmp(&b.subject))
                .then_with(|| a.message.cmp(&b.message))
        });
        Report { findings }
    }

    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }

    /// True when nothing error-severity was found.
    pub fn ok(&self) -> bool {
        self.errors() == 0
    }

    /// Does any finding carry this rule ID? (test/assertion helper)
    pub fn has(&self, rule: &str) -> bool {
        self.findings.iter().any(|f| f.rule == rule)
    }

    /// Machine output: `{"ok", "errors", "warnings", "findings": [...]}`
    /// — schema documented in `docs/ANALYSIS.md`.
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self.findings.iter().map(Finding::to_json).collect();
        ObjBuilder::new()
            .bool("ok", self.ok())
            .num("errors", self.errors() as f64)
            .num("warnings", self.warnings() as f64)
            .val("findings", Json::Arr(findings))
            .build()
    }

    /// Human output: one `severity[RULE] subject: message` line per
    /// finding plus a summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}[{}] {}: {}\n",
                f.severity.name(),
                f.rule,
                f.subject,
                f.message
            ));
        }
        out.push_str(&format!(
            "check: {} error(s), {} warning(s)\n",
            self.errors(),
            self.warnings()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_counts_and_json_shape() {
        let r = Report::new(vec![
            Finding::error("AR005", "sft/train_step", "arity 8 != 9"),
            Finding::warning("AR009", "sft/scale", "unparseable"),
        ]);
        assert_eq!(r.errors(), 1);
        assert_eq!(r.warnings(), 1);
        assert!(!r.ok());
        assert!(r.has("AR005"));
        assert!(!r.has("CK001"));
        let j = r.to_json();
        assert!(!j.bool_of("ok").unwrap());
        assert_eq!(j.u64_of("errors").unwrap(), 1);
        assert_eq!(j.arr_of("findings").unwrap().len(), 2);
        assert_eq!(j.arr_of("findings").unwrap()[0].str_of("rule").unwrap(), "AR005");
        let text = r.render_text();
        assert!(text.contains("error[AR005] sft/train_step"));
        assert!(text.contains("1 error(s), 1 warning(s)"));
    }

    #[test]
    fn findings_sort_deterministically() {
        let shuffled = vec![
            Finding::warning("MM005", "sft/scale", "drift"),
            Finding::error("AR005", "sft/train_step", "arity"),
            Finding::error("MM001", "lora/forward", "peak"),
            Finding::error("AR005", "lora/train_step", "arity"),
        ];
        let r = Report::new(shuffled);
        let order: Vec<(&str, &str)> =
            r.findings.iter().map(|f| (f.rule, f.subject.as_str())).collect();
        assert_eq!(
            order,
            vec![
                ("AR005", "lora/train_step"),
                ("AR005", "sft/train_step"),
                ("MM001", "lora/forward"),
                ("MM005", "sft/scale"),
            ]
        );
    }

    #[test]
    fn empty_report_is_ok() {
        let r = Report::default();
        assert!(r.ok());
        assert!(r.to_json().bool_of("ok").unwrap());
    }
}
