//! Pass 1 (cross-artifact) — config vs. memory model (CF rules).
//!
//! A `ServeConfig` whose declared budget cannot fit the priced peak of
//! a method it serves, or a train `RunConfig` whose job prices over the
//! budget it will be admitted against, fails at submit time on a live
//! server — this pass prices the same jobs statically with
//! [`crate::serve::admission::price_job`] (manifest-only, no XLA) and
//! reports the collision up front.

use std::path::{Path, PathBuf};

use crate::analysis::Finding;
use crate::config::{PriceGeometry, RunConfig, ServeConfig};
use crate::engine::Method;
use crate::memory::{Assumptions, Geometry};
use crate::serve::admission;
use crate::util::json::{self, Json};

/// CLI overrides for [`check_config`].
#[derive(Debug, Default)]
pub struct ConfigCheckOpts {
    /// Price against this artifact dir instead of the config's own.
    pub artifacts: Option<PathBuf>,
    /// Budget to check a `RunConfig` against (a run config declares no
    /// budget of its own; without this, pricing is skipped).
    pub budget_gb: Option<f64>,
    /// Assumptions preset override (`bf16_mixed` | `paper` | `f32`).
    pub assumptions: Option<String>,
}

/// Keys that mark a JSON document as a `ServeConfig` rather than a
/// train `RunConfig`.
const SERVE_KEYS: &[&str] =
    &["addr", "budget_gb", "quantum", "price_geometry", "run_root", "host_budget_gb", "event_log_cap"];

/// Check one config file (serve or run — detected by its keys).
pub fn check_config(path: &Path, opts: &ConfigCheckOpts) -> Vec<Finding> {
    let subject = path.display().to_string();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => return vec![Finding::error("CF001", subject, format!("unreadable: {e}"))],
    };
    let j = match json::parse(&text) {
        Ok(j) => j,
        Err(e) => return vec![Finding::error("CF001", subject, format!("{e}"))],
    };
    let is_serve = SERVE_KEYS.iter().any(|k| j.get(k).is_some());
    if is_serve {
        check_serve(&j, &subject, opts)
    } else {
        check_run(&j, &subject, opts)
    }
}

fn resolve_assumptions(
    cfg_preset: &str,
    opts: &ConfigCheckOpts,
    subject: &str,
    out: &mut Vec<Finding>,
) -> Option<Assumptions> {
    let preset = opts.assumptions.as_deref().unwrap_or(cfg_preset);
    match Assumptions::parse(preset) {
        Ok(a) => Some(a),
        Err(e) => {
            out.push(Finding::error("CF001", subject.to_string(), format!("{e}")));
            None
        }
    }
}

fn check_serve(j: &Json, subject: &str, opts: &ConfigCheckOpts) -> Vec<Finding> {
    let mut out = Vec::new();
    let cfg = match ServeConfig::from_json(j) {
        Ok(c) => c,
        Err(e) => {
            out.push(Finding::error("CF001", subject.to_string(), format!("{e}")));
            return out;
        }
    };
    let Some(assume) = resolve_assumptions(&cfg.assumptions, opts, subject, &mut out) else {
        return out;
    };
    let artifacts = opts.artifacts.clone().unwrap_or_else(|| cfg.artifacts.clone());
    if !artifacts.is_dir() {
        out.push(Finding::warning(
            "CF004",
            subject.to_string(),
            format!("artifact dir {} not present — pricing skipped", artifacts.display()),
        ));
        return out;
    }
    let geometry = match cfg.price_geometry {
        PriceGeometry::Manifest => None,
        PriceGeometry::Qwen => Some(Geometry::qwen15_moe_a27b()),
    };
    for method in Method::ALL {
        if !artifacts.join(method.eval_variant()).join("manifest.json").is_file() {
            continue;
        }
        match admission::price_job(&artifacts, method, assume, geometry.clone()) {
            Ok(priced) => {
                if priced.peak_gb > cfg.budget_gb {
                    out.push(Finding::error(
                        "CF002",
                        format!("{subject}#{method}"),
                        format!(
                            "priced peak {:.3} GB ({} @ {}) exceeds budget_gb {:.3} — \
                             this job could never be admitted",
                            priced.peak_gb, method, priced.geometry, cfg.budget_gb
                        ),
                    ));
                }
                if cfg.host_budget_gb > 0.0 && priced.host_gb > cfg.host_budget_gb {
                    out.push(Finding::warning(
                        "CF003",
                        format!("{subject}#{method}"),
                        format!(
                            "host snapshot price {:.3} GB exceeds host_budget_gb {:.3}",
                            priced.host_gb, cfg.host_budget_gb
                        ),
                    ));
                }
            }
            Err(e) => out.push(Finding::warning(
                "CF004",
                format!("{subject}#{method}"),
                format!("pricing failed: {e}"),
            )),
        }
    }
    out
}

fn check_run(j: &Json, subject: &str, opts: &ConfigCheckOpts) -> Vec<Finding> {
    let mut out = Vec::new();
    let cfg = match RunConfig::from_json(j).and_then(|c| c.validate().map(|_| c)) {
        Ok(c) => c,
        Err(e) => {
            out.push(Finding::error("CF001", subject.to_string(), format!("{e}")));
            return out;
        }
    };
    let Some(budget) = opts.budget_gb else { return out };
    let Some(assume) = resolve_assumptions("bf16_mixed", opts, subject, &mut out) else {
        return out;
    };
    let artifacts = opts.artifacts.clone().unwrap_or_else(|| cfg.artifacts.clone());
    match admission::price_job(&artifacts, cfg.method, assume, None) {
        Ok(priced) => {
            if priced.peak_gb > budget {
                out.push(Finding::error(
                    "CF002",
                    format!("{subject}#{}", cfg.method),
                    format!(
                        "priced peak {:.3} GB exceeds budget {budget:.3} GB",
                        priced.peak_gb
                    ),
                ));
            }
        }
        Err(e) => out.push(Finding::warning(
            "CF004",
            subject.to_string(),
            format!("pricing failed: {e}"),
        )),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ScratchDir;

    #[test]
    fn invalid_serve_config_is_cf001() {
        let dir = ScratchDir::new("cfchk").unwrap();
        let p = dir.join("serve.json");
        std::fs::write(&p, r#"{"budget_gb": -1}"#).unwrap();
        let f = check_config(&p, &ConfigCheckOpts::default());
        assert!(f.iter().any(|x| x.rule == "CF001"), "{f:?}");
    }

    #[test]
    fn run_config_detected_and_validated() {
        let dir = ScratchDir::new("cfchk2").unwrap();
        let p = dir.join("run.json");
        std::fs::write(&p, r#"{"method": "lomo", "grad_accum": 4}"#).unwrap();
        let f = check_config(&p, &ConfigCheckOpts::default());
        assert!(f.iter().any(|x| x.rule == "CF001"), "lomo+accum must fail: {f:?}");
    }

    #[test]
    fn unparseable_json_is_cf001() {
        let dir = ScratchDir::new("cfchk3").unwrap();
        let p = dir.join("x.json");
        std::fs::write(&p, "{nope").unwrap();
        let f = check_config(&p, &ConfigCheckOpts::default());
        assert_eq!(f[0].rule, "CF001");
    }

    #[test]
    fn serve_config_without_artifacts_warns_cf004() {
        let dir = ScratchDir::new("cfchk4").unwrap();
        let p = dir.join("serve.json");
        std::fs::write(&p, r#"{"budget_gb": 8, "artifacts": "/nonexistent/art"}"#).unwrap();
        let f = check_config(&p, &ConfigCheckOpts::default());
        assert!(f.iter().any(|x| x.rule == "CF004"), "{f:?}");
        assert!(f.iter().all(|x| x.rule != "CF002"));
    }
}
