//! Pass 2 — repo invariant lint (LN rules).
//!
//! A small comment/string-aware scanner over `rust/src/**` enforcing
//! invariants that rustc cannot:
//!
//! * **LN001** — no panicking `unwrap()` / `expect()` / `panic!` /
//!   `unreachable!` / `todo!` in `serve/` non-test code. A panic in a
//!   handler tears down that connection; in the scheduler thread it
//!   kills every job on the device.
//! * **LN002** — no raw `Mutex::lock()` on the shared `Board` outside
//!   the single poisoned-lock policy helper (`serve/lock.rs`).
//! * **LN003** — no allocation sized from wire-derived lengths
//!   (`with_capacity`, `vec![0; n]`) in `serve/` — the bounded `Reader`
//!   in `checkpoint/` (claim-before-allocate) is the sanctioned
//!   pattern for untrusted sizes.
//! * **LN004** — no raw `thread::sleep` anywhere in `rust/src/**`
//!   outside `util/retry.rs`: ad-hoc sleeps become unbounded retry
//!   loops with no jitter and no cap. Waits go through
//!   `util::retry::Backoff` (retry delays) or `util::retry::pause`
//!   (the one sanctioned sleep wrapper).
//! * **LN005** — no raw `Instant::now()` in `serve/` or `engine/`
//!   outside `obs/`: ad-hoc stopwatches are timing sites the telemetry
//!   layer cannot see. Timing goes through `obs::span` (records into
//!   the stage histograms and the trace ring) or `obs::now` (the
//!   sanctioned clock for deadline arithmetic).
//! * **LN006** — no silent truncating `as` integer casts in the wire
//!   layer (`serve/protocol.rs`, `serve/server.rs`): a length or cursor
//!   narrowed with `as` wraps silently on a hostile or corrupt frame.
//!   Wire-derived integers convert through `try_from` (explicit
//!   saturation/rejection) or the saturating `Json::path_u64` /
//!   `Json::as_u64` accessors.
//!
//! The scanner strips line/block comments (nested), string literals
//! (incl. raw and byte strings), and char literals before matching, and
//! stops at the file's trailing `#[cfg(test)]` block (repo convention:
//! tests last), so test code may panic freely.

use std::path::Path;

use crate::analysis::Finding;

/// Replace comments, string literals, and char literals with spaces,
/// preserving newlines (line numbers survive stripping).
fn strip(text: &str) -> String {
    #[derive(Clone, Copy, PartialEq)]
    enum S {
        Code,
        Line,
        Block(u32),
        Str,
        Raw(usize),
    }
    let cs: Vec<char> = text.chars().collect();
    let mut out = String::with_capacity(text.len());
    let mut s = S::Code;
    let mut i = 0;
    // `r##"` (any number of hashes) starting at i? → (advance, hashes)
    let raw_start = |i: usize| -> Option<(usize, usize)> {
        if cs.get(i) != Some(&'r') {
            return None;
        }
        if i > 0 && (cs[i - 1].is_alphanumeric() || cs[i - 1] == '_') {
            return None;
        }
        let mut j = i + 1;
        while cs.get(j) == Some(&'#') {
            j += 1;
        }
        (cs.get(j) == Some(&'"')).then(|| (j + 1 - i, j - (i + 1)))
    };
    while i < cs.len() {
        let c = cs[i];
        match s {
            S::Code => {
                if c == '/' && cs.get(i + 1) == Some(&'/') {
                    s = S::Line;
                    out.push(' ');
                    i += 2;
                } else if c == '/' && cs.get(i + 1) == Some(&'*') {
                    s = S::Block(1);
                    out.push(' ');
                    i += 2;
                } else if c == '"' {
                    s = S::Str;
                    out.push(' ');
                    i += 1;
                } else if let Some((adv, hashes)) = raw_start(i) {
                    s = S::Raw(hashes);
                    out.push(' ');
                    i += adv;
                } else if c == 'b' && cs.get(i + 1) == Some(&'"') {
                    s = S::Str;
                    out.push(' ');
                    i += 2;
                } else if c == 'b' && raw_start(i + 1).is_some() {
                    let (adv, hashes) = raw_start(i + 1).unwrap_or((1, 0));
                    s = S::Raw(hashes);
                    out.push(' ');
                    i += 1 + adv;
                } else if c == '\'' {
                    // char literal vs. lifetime
                    if cs.get(i + 1) == Some(&'\\') {
                        let mut j = i + 2;
                        while j < cs.len() && cs[j] != '\'' && j - i < 12 {
                            j += 1;
                        }
                        if cs.get(j) == Some(&'\'') {
                            out.push(' ');
                            i = j + 1;
                            continue;
                        }
                        out.push(c);
                        i += 1;
                    } else if cs.get(i + 2) == Some(&'\'') && cs.get(i + 1) != Some(&'\'') {
                        out.push(' ');
                        i += 3;
                    } else {
                        // lifetime — keep the tick, harmless
                        out.push(c);
                        i += 1;
                    }
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            S::Line => {
                if c == '\n' {
                    out.push('\n');
                    s = S::Code;
                }
                i += 1;
            }
            S::Block(d) => {
                if c == '*' && cs.get(i + 1) == Some(&'/') {
                    s = if d == 1 { S::Code } else { S::Block(d - 1) };
                    i += 2;
                } else if c == '/' && cs.get(i + 1) == Some(&'*') {
                    s = S::Block(d + 1);
                    i += 2;
                } else {
                    if c == '\n' {
                        out.push('\n');
                    }
                    i += 1;
                }
            }
            S::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    s = S::Code;
                    i += 1;
                } else {
                    if c == '\n' {
                        out.push('\n');
                    }
                    i += 1;
                }
            }
            S::Raw(h) => {
                if c == '"' && (0..h).all(|k| cs.get(i + 1 + k) == Some(&'#')) {
                    s = S::Code;
                    i += h + 1;
                } else {
                    if c == '\n' {
                        out.push('\n');
                    }
                    i += 1;
                }
            }
        }
    }
    out
}

const LN001_PATTERNS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];
const LN003_PATTERNS: &[&str] = &["with_capacity(", "vec![0"];
const LN006_INT_TARGETS: &[&str] =
    &["u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize"];

/// Does the (stripped) line contain ` as <int-type>` with a word
/// boundary after the type token? Returns the matched type name.
fn truncating_cast(line: &str) -> Option<&'static str> {
    let mut rest = line;
    while let Some(p) = rest.find(" as ") {
        let after = &rest[p + 4..];
        let tok = after.trim_start();
        for t in LN006_INT_TARGETS {
            if let Some(tail) = tok.strip_prefix(t) {
                let boundary = tail
                    .chars()
                    .next()
                    .map(|c| !c.is_ascii_alphanumeric() && c != '_')
                    .unwrap_or(true);
                if boundary {
                    return Some(t);
                }
            }
        }
        rest = after;
    }
    None
}

/// Lint one file's text. `rel` is the path relative to the source root
/// (`serve/server.rs` style) — it decides which rules apply.
pub fn lint_text(rel: &str, text: &str) -> Vec<Finding> {
    let norm = rel.replace('\\', "/");
    let in_serve = norm.starts_with("serve/") || norm.contains("/serve/");
    let in_engine = norm.starts_with("engine/") || norm.contains("/engine/");
    let is_obs = norm.starts_with("obs/") || norm.contains("/obs/");
    let is_lock_helper = norm.ends_with("serve/lock.rs") || norm == "serve/lock.rs";
    let is_backoff_helper = norm.ends_with("util/retry.rs") || norm == "util/retry.rs";
    let is_wire = norm.ends_with("serve/protocol.rs") || norm.ends_with("serve/server.rs");
    let stripped = strip(text);
    let mut out = Vec::new();
    for (lineno, line) in stripped.lines().enumerate() {
        if line.trim() == "#[cfg(test)]" {
            break;
        }
        let subject = format!("{norm}:{}", lineno + 1);
        if in_serve {
            for pat in LN001_PATTERNS {
                if line.contains(pat) {
                    out.push(Finding::error(
                        "LN001",
                        subject.clone(),
                        format!(
                            "panicking {} in serve code — return an error response / job-failure event instead",
                            pat.trim_start_matches('.')
                        ),
                    ));
                }
            }
            if !is_lock_helper && line.contains(".lock()") {
                out.push(Finding::error(
                    "LN002",
                    subject.clone(),
                    "raw Mutex::lock() on the shared Board — go through serve::lock::board (the single poisoned-lock policy)".to_string(),
                ));
            }
            for pat in LN003_PATTERNS {
                if line.contains(pat) {
                    out.push(Finding::error(
                        "LN003",
                        subject.clone(),
                        format!(
                            "allocation via {pat}…) in serve code — sizes here can be wire-derived; use the bounded claim-before-allocate Reader pattern (checkpoint/)"
                        ),
                    ));
                }
            }
        }
        if !is_backoff_helper && line.contains("thread::sleep(") {
            out.push(Finding::error(
                "LN004",
                subject.clone(),
                "raw thread::sleep — waits go through util::retry (Backoff::delay for retry delays, retry::pause for sanctioned sleeps)".to_string(),
            ));
        }
        if (in_serve || in_engine) && !is_obs && line.contains("Instant::now(") {
            out.push(Finding::error(
                "LN005",
                subject.clone(),
                "raw Instant::now() in timed code — time through obs::span (stage histograms + trace) or obs::now (deadline arithmetic) so telemetry sees the site".to_string(),
            ));
        }
        if is_wire {
            if let Some(t) = truncating_cast(line) {
                out.push(Finding::error(
                    "LN006",
                    subject.clone(),
                    format!(
                        "silent truncating `as {t}` cast in the wire layer — lengths and cursors from the wire must convert via try_from (or the saturating Json::path_u64 / Json::as_u64)"
                    ),
                ));
            }
        }
    }
    out
}

/// Recursively lint every `.rs` file under `root` (normally `rust/src`).
pub fn lint_sources(root: &Path) -> Vec<Finding> {
    if !root.is_dir() {
        return vec![Finding::error(
            "LN000",
            root.display().to_string(),
            "source root does not exist",
        )];
    }
    let mut files = Vec::new();
    collect_rs(root, root, &mut files);
    files.sort();
    let mut out = Vec::new();
    for rel in files {
        match std::fs::read_to_string(root.join(&rel)) {
            Ok(text) => out.extend(lint_text(&rel, &text)),
            Err(e) => out.push(Finding::error("LN000", rel, format!("unreadable: {e}"))),
        }
    }
    out
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(root, &p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            if let Ok(rel) = p.strip_prefix(root) {
                out.push(rel.to_string_lossy().replace('\\', "/"));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_panics_in_serve_code() {
        let f = lint_text("serve/server.rs", "fn f(x: Option<u8>) { x.unwrap(); }\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "LN001");
        assert_eq!(f[0].subject, "serve/server.rs:1");
        let f = lint_text("serve/scheduler.rs", "let y = m.lock().expect(\"board\");\n");
        assert!(f.iter().any(|x| x.rule == "LN001"));
        assert!(f.iter().any(|x| x.rule == "LN002"));
    }

    #[test]
    fn comments_strings_and_tests_are_exempt() {
        let src = "\
// this .unwrap() is a comment\n\
/* and panic!( in /* nested */ blocks too */\n\
let s = \".expect( in a string\";\n\
let r = r#\"vec![0; raw .unwrap()\"#;\n\
let c = '\"';\n\
let q = \"quote\";\n\
#[cfg(test)]\n\
mod tests { fn t() { x.unwrap(); } }\n";
        assert!(lint_text("serve/protocol.rs", src).is_empty());
    }

    #[test]
    fn unwrap_or_else_is_not_unwrap() {
        let src = "let g = m.lock().unwrap_or_else(PoisonError::into_inner);\n";
        let f = lint_text("serve/lock.rs", src);
        assert!(f.is_empty(), "lock helper is exempt from LN002, unwrap_or_else from LN001: {f:?}");
        let f = lint_text("serve/server.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "LN002");
    }

    #[test]
    fn non_serve_files_have_no_serve_rules() {
        assert!(lint_text("util/json.rs", "x.unwrap(); m.lock(); vec![0; n];\n").is_empty());
    }

    #[test]
    fn raw_sleep_flagged_everywhere_but_the_backoff_helper() {
        // serve code
        let f = lint_text("serve/server.rs", "std::thread::sleep(POLL);\n");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "LN004");
        // LN004 is repo-wide, not serve-only
        let f = lint_text("coordinator/trainer.rs", "thread::sleep(Duration::from_millis(5));\n");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "LN004");
        // the one sanctioned home of the real sleep
        assert!(lint_text("util/retry.rs", "std::thread::sleep(d);\n").is_empty());
        // comments and test blocks stay exempt
        let src = "// thread::sleep( in prose\n#[cfg(test)]\nmod tests { fn t() { std::thread::sleep(d); } }\n";
        assert!(lint_text("engine/run.rs", src).is_empty());
    }

    #[test]
    fn wire_sized_allocations_flagged() {
        let f = lint_text("serve/server.rs", "let b = Vec::with_capacity(n); let z = vec![0u8; n];\n");
        assert_eq!(f.iter().filter(|x| x.rule == "LN003").count(), 2);
    }

    #[test]
    fn char_literal_quote_does_not_derail_stripper() {
        let src = "if c == '\"' { x.unwrap() }\n";
        let f = lint_text("serve/server.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "LN001");
    }

    #[test]
    fn raw_instant_flagged_in_serve_and_engine_only() {
        let src = "let t0 = std::time::Instant::now();\n";
        let f = lint_text("serve/scheduler.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "LN005");
        let f = lint_text("engine/run.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "LN005");
        // the telemetry layer itself owns the real clock
        assert!(lint_text("obs/trace.rs", src).is_empty());
        // the rule is scoped: other subsystems may time ad hoc
        assert!(lint_text("util/retry.rs", src).is_empty());
        // comments, strings, and trailing test blocks stay exempt
        let exempt = "// Instant::now( in prose\nlet s = \"Instant::now(\";\n#[cfg(test)]\nmod tests { fn t() { Instant::now(); } }\n";
        assert!(lint_text("serve/server.rs", exempt).is_empty());
    }

    #[test]
    fn truncating_casts_flagged_only_in_wire_files() {
        let src = "let n = len as u32;\n";
        for wire in ["serve/protocol.rs", "serve/server.rs"] {
            let f = lint_text(wire, src);
            assert_eq!(f.len(), 1, "{wire}: {f:?}");
            assert_eq!(f[0].rule, "LN006");
            assert_eq!(f[0].subject, format!("{wire}:1"));
        }
        // the rest of serve/ (and the repo) may cast freely
        assert!(lint_text("serve/scheduler.rs", src).is_empty());
        assert!(lint_text("util/json.rs", src).is_empty());
        // float casts and non-integer targets are not LN006's business
        assert!(lint_text("serve/server.rs", "let x = n as f64;\n").is_empty());
        // word boundary: `as usize_like` is an identifier, not a cast
        assert!(lint_text("serve/server.rs", "let x = n as usize_like;\n").is_empty());
        // comments, strings, and test blocks stay exempt
        let exempt = "// cast as u64 in prose\nlet s = \"x as u32\";\n#[cfg(test)]\nmod t { fn f() { let y = n as u16; } }\n";
        assert!(lint_text("serve/protocol.rs", exempt).is_empty());
    }

    #[test]
    fn own_source_tree_is_clean() {
        // the acceptance gate: zero findings on rust/src/** — enforced
        // here and in the static CI job
        let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
        let f = lint_sources(&root);
        assert!(f.is_empty(), "lint findings on rust/src: {f:#?}");
    }
}
