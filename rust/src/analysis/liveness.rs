//! Pass 6 — schedule-order HLO liveness & peak-memory (MM rules).
//!
//! Serve admission and the CF rules price jobs off the analytic model
//! in `memory/model.rs`; nothing verified that model against the
//! programs we actually execute. This pass closes the loop, device-free:
//! it walks every instruction of every lowered program in schedule
//! (textual) order, tracks which buffers are live — donation-aware via
//! `input_output_alias`, so an in-place update costs nothing — and
//! reports the peak in bytes, attributed to the instruction and the
//! live set that produced it. The static peak is then cross-checked
//! against a manifest-grounded per-program prediction built from the
//! same terms the analytic model uses.
//!
//! Rules (catalog: `docs/ANALYSIS.md`):
//!
//! * MM001 (error) — static peak exceeds the prediction beyond the
//!   tolerance: the analytic model under-prices; admission could OOM.
//! * MM002 (error) — donated buffer double-counted: one parameter is
//!   claimed by two or more alias outputs.
//! * MM003 (error) — alias declared but not exploitable: the calling
//!   convention donates state but the module carries no alias map, or
//!   an aliased output's buffer cannot reuse its parameter's in place.
//! * MM004 (error) — fused-vs-accum peak ordering violated: a
//!   split-path program peaks above the fused `train_step`.
//! * MM005 (warning) — predicted-vs-static drift: the model
//!   over-predicts beyond tolerance, or a program's HLO could not be
//!   analyzed so its drift row is missing. Advisory.
//!
//! Artifact-layer load failures (missing dir, bad index/manifest) reuse
//! AR001 — same meaning as in the contract pass. The full
//! predicted-vs-static table is always returned as [`DriftRow`]s for
//! the CLI drift report and the `revffn_hlo_mem_drift` gauge rows.

use std::collections::HashMap;
use std::path::Path;

use crate::analysis::hlo::{self, Instr, Module};
use crate::analysis::Finding;
use crate::engine::Method;
use crate::error::{Error, Result};
use crate::memory::{Assumptions, Geometry, MemoryModel};
use crate::runtime::artifact::{Artifact, ArtifactIndex, Manifest};
use crate::util::json::{Json, ObjBuilder};

/// Knobs for the cross-check.
#[derive(Debug, Clone, Copy)]
pub struct HloMemOpts {
    /// Accepted static/predicted ratio in either direction. The
    /// prediction is analytic and the HLO is unoptimized text, so the
    /// band is deliberately wide; the default catches order-of-magnitude
    /// lies, not rounding.
    pub tolerance: f64,
}

impl Default for HloMemOpts {
    fn default() -> Self {
        HloMemOpts { tolerance: 8.0 }
    }
}

/// Split-path programs may exceed the fused train_step peak by at most
/// this factor before MM004 fires (slack for bookkeeping buffers).
const ORDERING_SLACK: f64 = 1.25;

/// Where a program's static peak landed.
#[derive(Debug, Clone)]
pub struct PeakReport {
    pub peak_bytes: u64,
    /// Instruction name at the (first) peak point, `(parameters)` when
    /// the arguments alone dominate.
    pub peak_at: String,
    /// Live buffers at the peak, largest first, capped at 8 entries;
    /// the parameter block is lumped as one `(parameters)` entry.
    pub live: Vec<(String, u64)>,
    pub args_bytes: u64,
}

/// Donation analysis of one module's alias map.
#[derive(Debug, Clone, Default)]
pub struct Donation {
    /// `(output index, parameter number)` pairs XLA can honor in place.
    pub applied: Vec<(usize, usize)>,
    /// `(output index, parameter number, reason)` — declared but not
    /// exploitable.
    pub unexploitable: Vec<(usize, usize, String)>,
    /// Parameter numbers claimed by two or more outputs.
    pub double_params: Vec<usize>,
}

/// One predicted-vs-static comparison row.
#[derive(Debug, Clone)]
pub struct DriftRow {
    pub variant: String,
    pub program: String,
    pub static_bytes: u64,
    pub predicted_bytes: u64,
    /// static / predicted.
    pub ratio: f64,
    pub peak_at: String,
}

/// Bytes a (non-parameter) instruction's result buffer occupies.
/// `tuple` / `get-tuple-element` / `bitcast` alias existing buffers and
/// cost nothing; parameters are accounted in the argument block.
fn buf_bytes(i: &Instr) -> u64 {
    match i.opcode.as_str() {
        "parameter" | "tuple" | "get-tuple-element" | "bitcast" => 0,
        _ => i.shape.flat_bytes(),
    }
}

/// Map output index → entry-instruction index of its producer. A tuple
/// root forwards to its k-th operand; a non-tuple root produces output
/// 0 itself.
fn output_producers(module: &Module) -> HashMap<usize, usize> {
    let mut out = HashMap::new();
    let Some(entry) = module.entry() else { return out };
    let idx: HashMap<&str, usize> =
        entry.instrs.iter().enumerate().map(|(i, ins)| (ins.name.as_str(), i)).collect();
    let Some(root_i) = entry.instrs.iter().position(|i| i.is_root) else { return out };
    let root = &entry.instrs[root_i];
    if root.opcode == "tuple" {
        for (k, op) in root.operands.iter().enumerate() {
            if let Some(&i) = idx.get(op.as_str()) {
                out.insert(k, i);
            }
        }
    } else {
        out.insert(0, root_i);
    }
    out
}

/// Analyze the alias map: which donations XLA can honor in place, which
/// are declared but unexploitable, and which parameters are claimed
/// more than once.
pub fn analyze_donation(module: &Module) -> Donation {
    let mut don = Donation::default();
    let Some(entry) = module.entry() else { return don };
    let producers = output_producers(module);
    let param_of: HashMap<usize, usize> = entry
        .instrs
        .iter()
        .enumerate()
        .filter_map(|(i, ins)| ins.param_number.map(|p| (p, i)))
        .collect();
    let mut claims: HashMap<usize, usize> = HashMap::new();
    for &(out, param) in &module.alias {
        *claims.entry(param).or_insert(0) += 1;
        let Some(&pi) = param_of.get(&param) else {
            don.unexploitable.push((out, param, format!("no parameter {param} in ENTRY")));
            continue;
        };
        let Some(&prod_i) = producers.get(&out) else {
            don.unexploitable.push((out, param, format!("no output {out} at the ROOT")));
            continue;
        };
        let prod = &entry.instrs[prod_i];
        let pbytes = entry.instrs[pi].shape.flat_bytes();
        let obytes = prod.shape.flat_bytes();
        if prod.opcode == "parameter" || obytes == pbytes {
            don.applied.push((out, param));
        } else {
            don.unexploitable.push((
                out,
                param,
                format!(
                    "output {out} is {obytes} bytes ({}) but parameter {param} is {pbytes} bytes — XLA cannot reuse the buffer in place",
                    prod.shape.render()
                ),
            ));
        }
    }
    don.double_params = {
        let mut d: Vec<usize> = claims.iter().filter(|(_, &c)| c >= 2).map(|(&p, _)| p).collect();
        d.sort_unstable();
        d
    };
    don
}

/// Schedule-order liveness over the ENTRY computation: peak live bytes
/// with arguments resident for the whole program, temporaries live from
/// definition to last use, root-reachable buffers live to the end, and
/// exploitable donations costing nothing (they write into their
/// parameter's buffer).
pub fn entry_peak(module: &Module) -> Result<PeakReport> {
    let entry = module
        .entry()
        .ok_or_else(|| Error::Parse("hlo: no ENTRY computation".into()))?;
    let n = entry.instrs.len();
    let idx: HashMap<&str, usize> =
        entry.instrs.iter().enumerate().map(|(i, ins)| (ins.name.as_str(), i)).collect();
    let root_i = entry
        .instrs
        .iter()
        .position(|i| i.is_root)
        .ok_or_else(|| Error::Parse("hlo: ENTRY has no ROOT".into()))?;

    let args_bytes: u64 = entry
        .instrs
        .iter()
        .filter(|i| i.opcode == "parameter")
        .map(|i| i.shape.flat_bytes())
        .sum();

    // last textual use of each definition
    let mut last_use: Vec<usize> = (0..n).collect();
    for (i, ins) in entry.instrs.iter().enumerate() {
        for op in &ins.operands {
            if let Some(&d) = idx.get(op.as_str()) {
                last_use[d] = last_use[d].max(i);
            }
        }
    }
    // buffers reaching the root (through value-forwarding ops) live to
    // the end of the program — they are the outputs
    let mut escapes = vec![false; n];
    let mut stack = vec![root_i];
    while let Some(i) = stack.pop() {
        if escapes[i] {
            continue;
        }
        escapes[i] = true;
        let ins = &entry.instrs[i];
        if matches!(ins.opcode.as_str(), "tuple" | "get-tuple-element" | "bitcast") {
            for op in &ins.operands {
                if let Some(&d) = idx.get(op.as_str()) {
                    stack.push(d);
                }
            }
        }
    }
    for i in 0..n {
        if escapes[i] {
            last_use[i] = n.saturating_sub(1);
        }
    }
    // exploitable donations write into their parameter's buffer
    let donated: Vec<usize> = {
        let producers = output_producers(module);
        analyze_donation(module)
            .applied
            .iter()
            .filter_map(|(out, _)| producers.get(out).copied())
            .collect()
    };

    let mut peak = args_bytes;
    let mut peak_i: Option<usize> = None;
    for i in 0..n {
        let mut live = args_bytes;
        for d in 0..=i {
            if last_use[d] >= i && !donated.contains(&d) {
                live += buf_bytes(&entry.instrs[d]);
            }
        }
        if live > peak {
            peak = live;
            peak_i = Some(i);
        }
    }
    let (peak_at, mut live_set) = match peak_i {
        None => ("(parameters)".to_string(), Vec::new()),
        Some(pi) => {
            let mut set: Vec<(String, u64)> = (0..=pi)
                .filter(|&d| last_use[d] >= pi && !donated.contains(&d))
                .map(|d| (format!("%{}", entry.instrs[d].name), buf_bytes(&entry.instrs[d])))
                .filter(|(_, b)| *b > 0)
                .collect();
            set.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
            (format!("%{}", entry.instrs[pi].name), set)
        }
    };
    if args_bytes > 0 {
        live_set.push(("(parameters)".to_string(), args_bytes));
        live_set.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    }
    live_set.truncate(8);
    Ok(PeakReport { peak_bytes: peak, peak_at, live: live_set, args_bytes })
}

/// Does this program kind's calling convention donate anything for this
/// manifest? (Mirrors the contract pass's donate bounds: train/apply
/// donate the `params + 2·moments` state prefix, accum/scale donate the
/// trainable accumulators, everything else donates nothing.)
fn expects_donation(kind: &str, m: &Manifest) -> bool {
    match kind {
        "train_step" | "apply_step" => m.tensors.len() + 2 * m.io.opt_shapes.len() > 0,
        "accum_step" | "scale" => m.io.trainable.iter().filter(|&&t| t).count() > 0,
        _ => false,
    }
}

/// Manifest-grounded per-program peak prediction, in bytes — the same
/// terms the analytic breakdown uses, composed per calling convention:
/// weights from the manifest tensor inventory, grads/moments from the
/// trainable set and `opt_shapes`, activations and logits from
/// [`MemoryModel`] under the f32 preset (the tiny artifacts are pure
/// f32, matching the AOT → XLA calibration path).
fn predicted_bytes(m: &Manifest, model: &MemoryModel, mm: crate::memory::Method, kind: &str) -> u64 {
    let weights: f64 = m.tensors.iter().map(|t| t.nbytes as f64).sum();
    let grads: f64 = m
        .tensors
        .iter()
        .zip(&m.io.trainable)
        .filter(|(_, &t)| t)
        .map(|(t, _)| t.elem_count() as f64 * 4.0)
        .sum();
    let moments: f64 = 2.0
        * m.io
            .opt_shapes
            .iter()
            .map(|s| s.iter().product::<usize>() as f64 * 4.0)
            .sum::<f64>();
    let (b, s) = (m.io.batch_size as u64, m.io.seq_len as u64);
    // tokens + targets (s32) + mask (f32), all [B,S]
    let data = (b * s) as f64 * 12.0;
    let scalars = 8.0; // lr + step
    let logits = model.logits_term_bytes(b, s);
    let act_bwd = model.backward_activation_bytes(mm, b, s);
    let act_fwd = model.forward_activation_bytes(mm, b, s);
    let bytes = match kind {
        "train_step" => weights + moments + grads + data + scalars + act_bwd + logits,
        "grad_step" => weights + grads + data + act_bwd + logits,
        "apply_step" => weights + moments + 2.0 * grads + scalars,
        "accum_step" => 2.0 * grads,
        "scale" => 2.0 * grads + 4.0,
        "forward" => weights + (b * s) as f64 * 4.0 + act_fwd + logits,
        "eval_step" => weights + data + act_fwd + logits,
        _ => weights + data + act_fwd + logits,
    };
    bytes.max(1.0) as u64
}

/// MM004: split-path peaks must not exceed the fused train_step peak
/// (the whole point of shipping a fused program) beyond slack.
fn peak_ordering_findings(variant: &str, peaks: &[(String, u64)]) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(&(_, train)) = peaks.iter().find(|(k, _)| k == "train_step") else {
        return out;
    };
    let bound = train as f64 * ORDERING_SLACK;
    for (kind, bytes) in peaks {
        if matches!(kind.as_str(), "grad_step" | "apply_step" | "accum_step" | "scale")
            && *bytes as f64 > bound
        {
            out.push(Finding::error(
                "MM004",
                format!("{variant}/{kind}"),
                format!(
                    "split-path program statically peaks at {bytes} B, above the fused train_step peak of {train} B (+{:.0}% slack): the accumulation path would not fit where the fused path does",
                    (ORDERING_SLACK - 1.0) * 100.0
                ),
            ));
        }
    }
    out
}

/// Variant discovery, mirroring the contract pass: `index.json` when
/// present, else sorted `*/manifest.json` subdirectories.
fn discover_variants(dir: &Path) -> std::result::Result<Vec<String>, Finding> {
    let subject = dir.display().to_string();
    if !dir.is_dir() {
        return Err(Finding::error("AR001", subject, "artifact directory does not exist"));
    }
    let variants = if dir.join("index.json").exists() {
        match ArtifactIndex::load(dir) {
            Ok(idx) => idx.variants,
            Err(e) => return Err(Finding::error("AR001", subject, format!("index.json: {e}"))),
        }
    } else {
        let mut found = Vec::new();
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                if entry.path().join("manifest.json").is_file() {
                    found.push(entry.file_name().to_string_lossy().into_owned());
                }
            }
        }
        found.sort();
        found
    };
    if variants.is_empty() {
        return Err(Finding::error(
            "AR001",
            subject,
            "no variants found (no index.json, no */manifest.json)",
        ));
    }
    Ok(variants)
}

/// The `--hlo-mem` pass: statically compute each program's peak live
/// bytes and cross-check against the analytic prediction. Returns the
/// findings plus the full drift table (one row per analyzed program),
/// both in deterministic order.
pub fn check_hlo_mem(dir: &Path, opts: &HloMemOpts) -> (Vec<Finding>, Vec<DriftRow>) {
    let tol = opts.tolerance.max(1.0);
    let mut findings = Vec::new();
    let mut rows: Vec<DriftRow> = Vec::new();
    let variants = match discover_variants(dir) {
        Ok(v) => v,
        Err(f) => return (vec![f], rows),
    };
    for v in &variants {
        let art = match Artifact::load(dir.join(v)) {
            Ok(a) => a,
            Err(e) => {
                findings.push(Finding::error("AR001", v.clone(), format!("{e}")));
                continue;
            }
        };
        // ablation-only variants (revffn_naive, reconstruct*) have no
        // registry method and no analytic row to compare against
        let Some(method) = Method::from_variant(v) else { continue };
        let mm = method.memory_method();
        let model = MemoryModel::new(
            Geometry::from_manifest(&art.manifest.model),
            Assumptions::f32_exact(),
        );
        let mut peaks: Vec<(String, u64)> = Vec::new();
        for kind in method.hlo_mem_programs() {
            if !art.manifest.artifacts.contains_key(kind) {
                continue; // inventory completeness is AR003's job
            }
            let subject = format!("{v}/{kind}");
            let text = match art.hlo_path(kind).and_then(|p| {
                std::fs::read_to_string(&p).map_err(crate::error::Error::from)
            }) {
                Ok(t) => t,
                Err(e) => {
                    findings.push(Finding::warning(
                        "MM005",
                        subject,
                        format!("HLO unreadable ({e}); drift row missing"),
                    ));
                    continue;
                }
            };
            let module = match hlo::parse_module(&text) {
                Ok(m) => m,
                Err(e) => {
                    findings.push(Finding::warning(
                        "MM005",
                        subject,
                        format!("{e}; liveness skipped, drift row missing"),
                    ));
                    continue;
                }
            };
            let don = analyze_donation(&module);
            for p in &don.double_params {
                findings.push(Finding::error(
                    "MM002",
                    subject.clone(),
                    format!(
                        "parameter {p} is donated to {} outputs — the donation accounting would count its buffer twice",
                        module.alias.iter().filter(|(_, q)| q == p).count()
                    ),
                ));
            }
            for (out, param, why) in &don.unexploitable {
                findings.push(Finding::error(
                    "MM003",
                    subject.clone(),
                    format!("alias {{{out}}} -> parameter {param} declared but not exploitable: {why}"),
                ));
            }
            if expects_donation(kind, &art.manifest) && module.alias.is_empty() {
                findings.push(Finding::error(
                    "MM003",
                    subject.clone(),
                    "calling convention donates the mutable state prefix but the module carries no input_output_alias map — every updated buffer would be allocated twice".to_string(),
                ));
            }
            let peak = match entry_peak(&module) {
                Ok(p) => p,
                Err(e) => {
                    findings.push(Finding::warning(
                        "MM005",
                        subject,
                        format!("{e}; drift row missing"),
                    ));
                    continue;
                }
            };
            let predicted = predicted_bytes(&art.manifest, &model, mm, kind);
            let ratio = peak.peak_bytes as f64 / predicted.max(1) as f64;
            if ratio > tol {
                let top: Vec<String> =
                    peak.live.iter().take(3).map(|(n, b)| format!("{n}={b}B")).collect();
                findings.push(Finding::error(
                    "MM001",
                    subject.clone(),
                    format!(
                        "static peak {} B at {} exceeds the model prediction {predicted} B by {ratio:.1}x (tolerance {tol}x); live set: {}",
                        peak.peak_bytes,
                        peak.peak_at,
                        top.join(", ")
                    ),
                ));
            } else if 1.0 / ratio.max(f64::MIN_POSITIVE) > tol {
                findings.push(Finding::warning(
                    "MM005",
                    subject.clone(),
                    format!(
                        "model over-predicts: {predicted} B predicted vs {} B static ({:.1}x over, tolerance {tol}x)",
                        peak.peak_bytes,
                        1.0 / ratio.max(f64::MIN_POSITIVE)
                    ),
                ));
            }
            peaks.push((kind.to_string(), peak.peak_bytes));
            rows.push(DriftRow {
                variant: v.clone(),
                program: kind.to_string(),
                static_bytes: peak.peak_bytes,
                predicted_bytes: predicted,
                ratio,
                peak_at: peak.peak_at,
            });
        }
        findings.extend(peak_ordering_findings(v, &peaks));
    }
    (findings, rows)
}

/// The drift table as JSON rows (the `hlo_mem` key of `check --json`
/// and the bench gauge snapshot share this shape).
pub fn drift_json(rows: &[DriftRow]) -> Json {
    let arr: Vec<Json> = rows
        .iter()
        .map(|r| {
            ObjBuilder::new()
                .str("variant", &r.variant)
                .str("program", &r.program)
                .num("static_bytes", r.static_bytes as f64)
                .num("predicted_bytes", r.predicted_bytes as f64)
                .num("ratio", r.ratio)
                .str("peak_at", &r.peak_at)
                .build()
        })
        .collect();
    Json::Arr(arr)
}

/// Human rendering of the drift table.
pub fn render_drift_table(rows: &[DriftRow], tolerance: f64) -> String {
    let mut out = format!(
        "hlo-mem drift (static liveness peak vs analytic prediction, tolerance {tolerance}x):\n"
    );
    out.push_str(&format!(
        "  {:<16} {:<12} {:>12} {:>14} {:>7}  peak at\n",
        "variant", "program", "static(B)", "predicted(B)", "ratio"
    ));
    for r in rows {
        out.push_str(&format!(
            "  {:<16} {:<12} {:>12} {:>14} {:>7.2}  {}\n",
            r.variant, r.program, r.static_bytes, r.predicted_bytes, r.ratio, r.peak_at
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"HloModule t, input_output_alias={ {0}: (0, {}, may-alias) }
ENTRY %main.1 (Arg_0.1: f32[4,2], Arg_1.2: f32[4,2]) -> (f32[4,2]) {
  %Arg_0.1 = f32[4,2]{1,0} parameter(0)
  %Arg_1.2 = f32[4,2]{1,0} parameter(1)
  %big.3 = f32[8,8]{1,0} broadcast(%Arg_1.2), dimensions={0}
  %sum.4 = f32[4,2]{1,0} reduce(%big.3, %Arg_0.1), dimensions={0}
  %new.5 = f32[4,2]{1,0} add(%Arg_0.1, %sum.4)
  ROOT %tuple.6 = (f32[4,2]{1,0}) tuple(%new.5)
}
"#;

    #[test]
    fn peak_is_attributed_to_the_widest_point() {
        let m = hlo::parse_module(TINY).unwrap();
        let p = entry_peak(&m).unwrap();
        assert_eq!(p.args_bytes, 64);
        // peak at %sum.4: args(64) + big(256) + sum(32); %new.5 is
        // donated into parameter 0 and costs nothing
        assert_eq!(p.peak_bytes, 64 + 256 + 32);
        assert_eq!(p.peak_at, "%sum.4");
        assert_eq!(p.live[0], ("%big.3".to_string(), 256));
        assert!(p.live.iter().any(|(n, _)| n == "(parameters)"));
    }

    #[test]
    fn donation_zeroes_the_updated_buffer() {
        let m = hlo::parse_module(TINY).unwrap();
        let don = analyze_donation(&m);
        assert_eq!(don.applied, vec![(0, 0)]);
        assert!(don.unexploitable.is_empty());
        assert!(don.double_params.is_empty());
        // without the alias map the output buffer costs extra at the end
        let no_alias = TINY.replace(", input_output_alias={ {0}: (0, {}, may-alias) }", "");
        let m2 = hlo::parse_module(&no_alias).unwrap();
        let p2 = entry_peak(&m2).unwrap();
        assert_eq!(p2.peak_bytes, 64 + 256 + 32, "peak point unchanged");
        // but at the last instruction the undonated %new.5 is live
        assert!(analyze_donation(&m2).applied.is_empty());
    }

    #[test]
    fn double_donation_and_mismatch_are_detected() {
        let double = TINY.replace(
            "{ {0}: (0, {}, may-alias) }",
            "{ {0}: (0, {}, may-alias), {0}: (0, {}, may-alias) }",
        );
        let m = hlo::parse_module(&double).unwrap();
        assert_eq!(analyze_donation(&m).double_params, vec![0]);
        // alias an output whose buffer cannot fit the parameter
        let text = r#"HloModule t, input_output_alias={ {0}: (0, {}, may-alias) }
ENTRY %m (a: f32[4,2]) -> (f32[8]) {
  %a = f32[4,2]{1,0} parameter(0)
  %b = f32[8]{0} broadcast(%a)
  ROOT %t = (f32[8]) tuple(%b)
}
"#;
        let m2 = hlo::parse_module(text).unwrap();
        let don = analyze_donation(&m2);
        assert!(don.applied.is_empty());
        assert_eq!(don.unexploitable.len(), 1);
        assert!(don.unexploitable[0].2.contains("cannot reuse"));
    }

    #[test]
    fn ordering_findings_fire_only_above_slack() {
        let peaks = vec![
            ("train_step".to_string(), 1000u64),
            ("grad_step".to_string(), 1200),
            ("accum_step".to_string(), 1300),
            ("eval_step".to_string(), 9999),
        ];
        let fs = peak_ordering_findings("sft", &peaks);
        assert_eq!(fs.len(), 1, "only accum_step exceeds 1.25x: {fs:?}");
        assert_eq!(fs[0].rule, "MM004");
        assert!(fs[0].subject.contains("accum_step"));
    }

    #[test]
    fn drift_table_renders_and_serializes() {
        let rows = vec![DriftRow {
            variant: "sft".into(),
            program: "train_step".into(),
            static_bytes: 9428,
            predicted_bytes: 9960,
            ratio: 0.95,
            peak_at: "%lse.14".into(),
        }];
        let text = render_drift_table(&rows, 8.0);
        assert!(text.contains("train_step"));
        assert!(text.contains("9428"));
        let j = drift_json(&rows);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr[0].str_of("program").unwrap(), "train_step");
        assert_eq!(arr[0].u64_of("static_bytes").unwrap(), 9428);
    }
}
