//! Pass 1 — artifact contract checking (AR rules).
//!
//! Statically cross-checks each variant's compiled-program inventory and
//! I/O signatures against what the runtime will actually feed them. The
//! expected flat calling convention is the one `StepBuilder` lowers and
//! `Stepper`/`GradAccumulator` drive:
//!
//! ```text
//! train_step  (params, m, v, tokens, targets, mask, lr, step) -> (params', m', v', loss, gnorm, aux)
//! grad_step   (params, tokens, targets, mask)                 -> (grads…, loss, aux)
//! apply_step  (params, m, v, grads, lr, step)                 -> (params', m', v', gnorm)
//! accum_step  (acc…, grads…)                                  -> (acc'…)
//! scale       (acc…, scale)                                   -> (grads…)
//! forward     (params, tokens)                                -> (logits)
//! eval_step   (params, tokens, targets, mask)                 -> (loss, aux)
//! reconstruct (params, tokens)                                -> (err)
//! ```
//!
//! where `params` are the manifest tensors in order, `m`/`v` the Adam
//! moments at `io.opt_shapes`, grads the trainable tensors, tokens and
//! targets `s32[B,S]`, mask `f32[B,S]`, and lr/step/scale `f32[]`
//! scalars. Donation (`input_output_alias`) may only name the mutable
//! state prefix — donating a data input would corrupt the caller.

use std::path::Path;

use crate::analysis::hlo::{self, TensorTy};
use crate::analysis::Finding;
use crate::engine::Method;
use crate::runtime::artifact::{Artifact, ArtifactIndex, Manifest};
use crate::runtime::literal::dtype_bytes;

/// Manifest dtype string → HLO element-type spelling.
fn hlo_dtype(manifest_dtype: &str) -> String {
    match manifest_dtype {
        "i32" => "s32".into(),
        "i64" => "s64".into(),
        other => other.into(),
    }
}

fn ty(dtype: &str, dims: &[usize]) -> TensorTy {
    TensorTy { dtype: dtype.into(), dims: dims.to_vec() }
}

/// Expected interface of one program kind, derived from the manifest.
struct Spec {
    /// `(label, type)` per input, in parameter order.
    inputs: Vec<(String, TensorTy)>,
    out_arity: usize,
    /// Output slots with a manifest-determined type (`(index, label,
    /// type)`); slots not listed (losses, aux) are arity-checked only.
    out_checked: Vec<(usize, String, TensorTy)>,
    /// Donation may only name parameters `< donate_bound` (the mutable
    /// state prefix; 0 = the program must not donate at all).
    donate_bound: usize,
}

/// Build the expected interface for `kind`, or `None` for kinds the
/// checker does not know (they get an existence check only).
fn expected_io(kind: &str, m: &Manifest) -> Option<Spec> {
    let io = &m.io;
    let params: Vec<(String, TensorTy)> = m
        .tensors
        .iter()
        .map(|t| (t.name.clone(), ty(&hlo_dtype(&t.dtype), &t.shape)))
        .collect();
    let moments = |tag: &str| -> Vec<(String, TensorTy)> {
        io.opt_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| (format!("{tag}[{i}]"), ty("f32", s)))
            .collect()
    };
    let grads: Vec<(String, TensorTy)> = m
        .tensors
        .iter()
        .zip(&io.trainable)
        .filter(|(_, &t)| t)
        .map(|(t, _)| (format!("grad[{}]", t.name), ty("f32", &t.shape)))
        .collect();
    let np = params.len();
    let no = io.opt_shapes.len();
    let nt = grads.len();
    let bs = [io.batch_size, io.seq_len];
    let tokens = ("tokens".to_string(), ty("s32", &bs));
    let targets = ("targets".to_string(), ty("s32", &bs));
    let mask = ("mask".to_string(), ty("f32", &bs));
    let scalar = |label: &str| (label.to_string(), ty("f32", &[]));
    // the state prefix (params, m, v) comes back unchanged in shape
    let state_out = |inputs: &[(String, TensorTy)]| -> Vec<(usize, String, TensorTy)> {
        inputs.iter().take(np + 2 * no).cloned().enumerate().map(|(i, (l, t))| (i, l, t)).collect()
    };
    let grads_out = || -> Vec<(usize, String, TensorTy)> {
        grads.iter().cloned().enumerate().map(|(i, (l, t))| (i, l, t)).collect()
    };

    let spec = match kind {
        "train_step" => {
            let mut inputs = params;
            inputs.extend(moments("m"));
            inputs.extend(moments("v"));
            inputs.extend([tokens, targets, mask, scalar("lr"), scalar("step")]);
            let out_checked = state_out(&inputs);
            Spec { inputs, out_arity: np + 2 * no + 3, out_checked, donate_bound: np + 2 * no }
        }
        "grad_step" => {
            let mut inputs = params;
            inputs.extend([tokens, targets, mask]);
            Spec { inputs, out_arity: nt + 2, out_checked: grads_out(), donate_bound: 0 }
        }
        "apply_step" => {
            let mut inputs = params;
            inputs.extend(moments("m"));
            inputs.extend(moments("v"));
            inputs.extend(grads.clone());
            inputs.extend([scalar("lr"), scalar("step")]);
            let out_checked = state_out(&inputs);
            Spec { inputs, out_arity: np + 2 * no + 1, out_checked, donate_bound: np + 2 * no }
        }
        "accum_step" => {
            let mut inputs: Vec<(String, TensorTy)> =
                grads.iter().cloned().map(|(l, t)| (l.replace("grad[", "acc["), t)).collect();
            inputs.extend(grads.clone());
            Spec { inputs, out_arity: nt, out_checked: grads_out(), donate_bound: nt }
        }
        "scale" => {
            let mut inputs: Vec<(String, TensorTy)> =
                grads.iter().cloned().map(|(l, t)| (l.replace("grad[", "acc["), t)).collect();
            inputs.push(scalar("scale"));
            Spec { inputs, out_arity: nt, out_checked: grads_out(), donate_bound: nt }
        }
        "forward" | "reconstruct" => {
            let mut inputs = params;
            inputs.push(tokens);
            Spec { inputs, out_arity: 1, out_checked: Vec::new(), donate_bound: 0 }
        }
        "eval_step" => {
            let mut inputs = params;
            inputs.extend([tokens, targets, mask]);
            Spec { inputs, out_arity: 2, out_checked: Vec::new(), donate_bound: 0 }
        }
        _ => return None,
    };
    Some(spec)
}

/// All AR checks for one loaded variant.
pub fn check_variant(art: &Artifact) -> Vec<Finding> {
    let m = &art.manifest;
    let v = m.variant.clone();
    let mut out = Vec::new();

    // ---- AR002: manifest internal consistency ------------------------
    let nt = m.io.trainable.iter().filter(|&&t| t).count();
    let mut ar002 = |msg: String| out.push(Finding::error("AR002", v.clone(), msg));
    if m.io.n_params != m.tensors.len() {
        ar002(format!("io.n_params {} != tensors.len() {}", m.io.n_params, m.tensors.len()));
    }
    if m.io.trainable.len() != m.tensors.len() {
        ar002(format!(
            "io.trainable.len() {} != tensors.len() {}",
            m.io.trainable.len(),
            m.tensors.len()
        ));
    }
    if m.io.trainable_paths.len() != nt {
        ar002(format!(
            "io.trainable_paths.len() {} != trainable count {nt}",
            m.io.trainable_paths.len()
        ));
    }
    if m.io.opt_shapes.len() != m.io.n_opt {
        ar002(format!("io.opt_shapes.len() {} != io.n_opt {}", m.io.opt_shapes.len(), m.io.n_opt));
    }
    if m.io.n_opt > nt {
        ar002(format!("io.n_opt {} > trainable count {nt}", m.io.n_opt));
    }
    if m.io.batch_size == 0 || m.io.seq_len == 0 {
        ar002(format!("degenerate geometry batch={} seq={}", m.io.batch_size, m.io.seq_len));
    }
    for t in &m.tensors {
        match dtype_bytes(&t.dtype) {
            Ok(b) => {
                if t.nbytes != t.elem_count() * b {
                    out.push(Finding::error(
                        "AR002",
                        format!("{v}/{}", t.name),
                        format!(
                            "nbytes {} != {} elements x {b} bytes ({})",
                            t.nbytes,
                            t.elem_count(),
                            t.dtype
                        ),
                    ));
                }
            }
            Err(_) => out.push(Finding::error(
                "AR002",
                format!("{v}/{}", t.name),
                format!("unknown dtype {:?}", t.dtype),
            )),
        }
    }

    // ---- AR010: router tensors frozen in RevFFN stages (§3.3) --------
    if v.starts_with("revffn_stage") {
        for (spec, &tr) in m.tensors.iter().zip(&m.io.trainable) {
            if tr && spec.name.contains(".moe.router") {
                out.push(Finding::error(
                    "AR010",
                    format!("{v}/{}", spec.name),
                    "router tensor marked trainable in a RevFFN stage".to_string(),
                ));
            }
        }
    }

    // ---- AR003: program presence per Method capability ---------------
    if let Some(method) = Method::from_variant(&v) {
        for k in method.required_programs() {
            if !m.artifacts.contains_key(*k) {
                out.push(Finding::error(
                    "AR003",
                    format!("{v}/{k}"),
                    format!("required program {k:?} missing from artifact inventory"),
                ));
            }
        }
        for pair in method.paired_programs() {
            let [a, b] = *pair;
            let (ha, hb) = (m.artifacts.contains_key(a), m.artifacts.contains_key(b));
            if ha != hb {
                let (present, absent) = if ha { (a, b) } else { (b, a) };
                out.push(Finding::error(
                    "AR003",
                    format!("{v}/{absent}"),
                    format!(
                        "{present:?} present without its pair {absent:?} — the capability would fail at first use"
                    ),
                ));
            }
        }
    }

    // ---- per program: AR004..AR009 -----------------------------------
    let mut kinds: Vec<&String> = m.artifacts.keys().collect();
    kinds.sort();
    for kind in kinds {
        let subject = format!("{v}/{kind}");
        let path = match art.hlo_path(kind) {
            Ok(p) => p,
            Err(_) => continue,
        };
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) => {
                out.push(Finding::error(
                    "AR004",
                    subject,
                    format!("listed program file {} unreadable: {e}", path.display()),
                ));
                continue;
            }
        };
        let Some(spec) = expected_io(kind, m) else { continue };
        let Some(sig) = hlo::parse_signature(&text) else {
            out.push(Finding::warning(
                "AR009",
                subject,
                "HLO signature unparseable — interface checks skipped".to_string(),
            ));
            continue;
        };
        if sig.params.len() != spec.inputs.len() {
            out.push(Finding::error(
                "AR005",
                subject.clone(),
                format!("input arity {} != expected {}", sig.params.len(), spec.inputs.len()),
            ));
        } else {
            for (i, ((label, want), got)) in spec.inputs.iter().zip(&sig.params).enumerate() {
                if want != got {
                    out.push(Finding::error(
                        "AR007",
                        format!("{subject}#{i}"),
                        format!(
                            "input {label}: manifest expects {} but program takes {}",
                            want.render(),
                            got.render()
                        ),
                    ));
                }
            }
        }
        if sig.outputs.len() != spec.out_arity {
            out.push(Finding::error(
                "AR006",
                subject.clone(),
                format!("output arity {} != expected {}", sig.outputs.len(), spec.out_arity),
            ));
        } else {
            for (idx, label, want) in &spec.out_checked {
                if &sig.outputs[*idx] != want {
                    out.push(Finding::error(
                        "AR007",
                        format!("{subject}#out{idx}"),
                        format!(
                            "output {label}: manifest expects {} but program returns {}",
                            want.render(),
                            sig.outputs[*idx].render()
                        ),
                    ));
                }
            }
        }
        if let Some(aliased) = &sig.aliased {
            for &i in aliased {
                if i >= spec.donate_bound {
                    out.push(Finding::error(
                        "AR008",
                        subject.clone(),
                        format!(
                            "donates parameter {i} outside the mutable state prefix (< {}) — \
                             the runtime still needs that buffer",
                            spec.donate_bound
                        ),
                    ));
                }
            }
        }
    }

    out
}

/// Check a whole artifact config directory (`artifacts/<cfg>`): every
/// variant listed in `index.json`, or every subdirectory carrying a
/// `manifest.json` when there is no index.
pub fn check_artifacts(dir: &Path) -> Vec<Finding> {
    let subject = dir.display().to_string();
    if !dir.is_dir() {
        return vec![Finding::error("AR001", subject, "artifact directory does not exist")];
    }
    let variants: Vec<String> = if dir.join("index.json").exists() {
        match ArtifactIndex::load(dir) {
            Ok(idx) => idx.variants,
            Err(e) => {
                return vec![Finding::error("AR001", subject, format!("index.json: {e}"))];
            }
        }
    } else {
        let mut found = Vec::new();
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                if entry.path().join("manifest.json").is_file() {
                    found.push(entry.file_name().to_string_lossy().into_owned());
                }
            }
        }
        found.sort();
        found
    };
    if variants.is_empty() {
        return vec![Finding::error("AR001", subject, "no variants found (no index.json, no */manifest.json)")];
    }
    let mut out = Vec::new();
    for v in &variants {
        let vdir = dir.join(v);
        match Artifact::load(&vdir) {
            Ok(art) => {
                if art.manifest.variant != *v {
                    out.push(Finding::error(
                        "AR002",
                        v.clone(),
                        format!(
                            "manifest says variant {:?} but lives in directory {v:?}",
                            art.manifest.variant
                        ),
                    ));
                }
                out.extend(check_variant(&art));
            }
            Err(e) => out.push(Finding::error("AR001", v.clone(), format!("{e}"))),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "variant": "sft", "method": "sft",
          "model": {"name": "tiny", "vocab_size": 64, "d_model": 8, "n_layers": 2,
                    "n_heads": 2, "n_kv_heads": 2, "n_experts": 4, "top_k": 2,
                    "d_ff_expert": 16, "d_ff_shared": 16, "max_seq_len": 16},
          "io": {"n_params": 2, "n_opt": 1, "optimizer": "adam",
                 "trainable": [true, false], "trainable_paths": ["embed"],
                 "opt_shapes": [[4, 2]], "batch_size": 2, "seq_len": 4},
          "tensors": [
            {"name": "embed", "shape": [4, 2], "dtype": "f32", "blob": "standard", "offset": 0, "nbytes": 32},
            {"name": "norm_f", "shape": [2], "dtype": "f32", "blob": "standard", "offset": 32, "nbytes": 8}
          ],
          "artifacts": {"train_step": "train_step.hlo.txt"}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn expected_train_step_interface() {
        let m = manifest();
        let s = expected_io("train_step", &m).unwrap();
        // 2 params + 1 m + 1 v + tokens/targets/mask/lr/step
        assert_eq!(s.inputs.len(), 9);
        assert_eq!(s.inputs[0].1.render(), "f32[4,2]");
        assert_eq!(s.inputs[4].0, "tokens");
        assert_eq!(s.inputs[4].1.render(), "s32[2,4]");
        assert_eq!(s.inputs[8].1.render(), "f32[]");
        assert_eq!(s.out_arity, 2 + 2 + 3);
        assert_eq!(s.out_checked.len(), 4);
        assert_eq!(s.donate_bound, 4);
    }

    #[test]
    fn expected_pair_and_eval_interfaces() {
        let m = manifest();
        let g = expected_io("grad_step", &m).unwrap();
        assert_eq!(g.inputs.len(), 5);
        assert_eq!(g.out_arity, 3, "1 trainable grad + loss + aux");
        assert_eq!(g.donate_bound, 0);
        let a = expected_io("apply_step", &m).unwrap();
        assert_eq!(a.inputs.len(), 2 + 2 + 1 + 2);
        assert_eq!(a.out_arity, 5);
        let acc = expected_io("accum_step", &m).unwrap();
        assert_eq!(acc.inputs.len(), 2);
        assert_eq!(acc.out_arity, 1);
        assert_eq!(acc.donate_bound, 1);
        let sc = expected_io("scale", &m).unwrap();
        assert_eq!(sc.inputs.len(), 2);
        assert_eq!(sc.inputs[1].1.render(), "f32[]");
        assert!(expected_io("mystery_kind", &m).is_none());
    }

    #[test]
    fn internal_consistency_catches_bad_nbytes() {
        let mut m = manifest();
        m.tensors[0].nbytes = 31;
        let art = Artifact { dir: std::path::PathBuf::from("/nonexistent"), manifest: m };
        let f = check_variant(&art);
        assert!(f.iter().any(|f| f.rule == "AR002" && f.subject.contains("embed")));
    }

    #[test]
    fn missing_artifact_dir_is_ar001() {
        let f = check_artifacts(Path::new("/nonexistent/artifacts"));
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "AR001");
    }
}
