//! Minimal HLO-text signature reader.
//!
//! The AOT layer (`python/compile/aot.py`) serializes every program as
//! `as_hlo_text()` output. For contract checking we only need the ENTRY
//! computation's interface — parameter types, the ROOT tuple's element
//! types, and the `input_output_alias` donation map — not a real HLO
//! parser. The reader is deliberately tolerant: anything it cannot
//! understand yields `None`, which the contract pass reports as an
//! AR009 *warning* (checks skipped), never a spurious error against
//! real compiler output.

/// One flat tensor type, e.g. `f32[4,8]` or `s32[]` (scalar).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorTy {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorTy {
    pub fn render(&self) -> String {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        format!("{}[{}]", self.dtype, dims.join(","))
    }
}

/// The ENTRY computation's interface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Signature {
    /// Parameter types in parameter-number order.
    pub params: Vec<TensorTy>,
    /// ROOT tuple element types (`return_tuple=True` at AOT time, so
    /// the root is always a tuple; a non-tuple root parses as one
    /// element).
    pub outputs: Vec<TensorTy>,
    /// Parameter numbers named in `input_output_alias` — the donated
    /// inputs. `None` when the module header carries no alias map (the
    /// program donates nothing, or the text predates aliasing).
    pub aliased: Option<Vec<usize>>,
}

/// Parse `f32[4,8]{1,0}` / `s32[]` → [`TensorTy`]. Trailing layout or
/// metadata after `]` is ignored.
fn parse_tensor_ty(tok: &str) -> Option<TensorTy> {
    let open = tok.find('[')?;
    let close = tok[open..].find(']')? + open;
    let dtype = tok[..open].trim().to_string();
    if dtype.is_empty() || !dtype.chars().all(|c| c.is_ascii_alphanumeric()) {
        return None;
    }
    let inner = &tok[open + 1..close];
    let mut dims = Vec::new();
    if !inner.trim().is_empty() {
        for part in inner.split(',') {
            dims.push(part.trim().parse::<usize>().ok()?);
        }
    }
    Some(TensorTy { dtype, dims })
}

/// Split a tuple type body (the text between the outer parens) at
/// top-level commas — bracket/brace/paren aware, so `f32[4,8]{1,0}`
/// stays one token.
fn split_top_level(body: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut start = 0;
    for (i, c) in body.char_indices() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            ',' if depth == 0 => {
                out.push(body[start..i].trim());
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(body[start..].trim());
    out
}

/// Extract a balanced `{...}` / `(...)` span starting at `open_idx`
/// (which must point at the opening delimiter). Returns the inner text.
fn balanced_span(text: &str, open_idx: usize, open: char, close: char) -> Option<&str> {
    let bytes = text.as_bytes();
    if bytes.get(open_idx) != Some(&(open as u8)) {
        return None;
    }
    let mut depth = 0usize;
    for (i, c) in text[open_idx..].char_indices() {
        if c == open {
            depth += 1;
        } else if c == close {
            depth -= 1;
            if depth == 0 {
                return Some(&text[open_idx + 1..open_idx + i]);
            }
        }
    }
    None
}

/// Parse the ENTRY computation's signature out of full HLO text.
pub fn parse_signature(text: &str) -> Option<Signature> {
    // --- ENTRY block: from the `ENTRY` header line to the closing `}`
    let mut in_entry = false;
    let mut params: Vec<(usize, TensorTy)> = Vec::new();
    let mut outputs: Option<Vec<TensorTy>> = None;
    for line in text.lines() {
        let trimmed = line.trim();
        if !in_entry {
            if trimmed.starts_with("ENTRY ") || trimmed.starts_with("ENTRY%") {
                in_entry = true;
            }
            continue;
        }
        if trimmed == "}" {
            break;
        }
        // instruction lines: `%name = <type> <op>(...)`
        let Some(eq) = trimmed.find(" = ") else { continue };
        let rest = &trimmed[eq + 3..];
        if let Some(ppos) = rest.find("parameter(") {
            let ty_tok = rest[..ppos].trim();
            let after = &rest[ppos + "parameter(".len()..];
            let digits: String = after.chars().take_while(|c| c.is_ascii_digit()).collect();
            let idx = digits.parse::<usize>().ok()?;
            params.push((idx, parse_tensor_ty(ty_tok)?));
        }
        if trimmed.starts_with("ROOT ") || trimmed.starts_with("ROOT%") {
            let tys = if rest.starts_with('(') {
                let body = balanced_span(rest, 0, '(', ')')?;
                split_top_level(body)
                    .into_iter()
                    .map(parse_tensor_ty)
                    .collect::<Option<Vec<_>>>()?
            } else {
                let tok = rest.split_whitespace().next()?;
                vec![parse_tensor_ty(tok)?]
            };
            outputs = Some(tys);
        }
    }
    if !in_entry {
        return None;
    }
    // parameter numbers must be dense 0..n
    params.sort_by_key(|(i, _)| *i);
    for (expect, (got, _)) in params.iter().enumerate() {
        if *got != expect {
            return None;
        }
    }
    let params: Vec<TensorTy> = params.into_iter().map(|(_, t)| t).collect();
    let outputs = outputs?;

    // --- donation map on the HloModule header (anywhere in the text)
    let aliased = text.find("input_output_alias=").and_then(|pos| {
        let brace = pos + "input_output_alias=".len();
        let body = balanced_span(text, brace, '{', '}')?;
        // entries look like `{0}: (3, {}, may-alias)` — the first
        // integer after each `: (` is the donated parameter number
        let mut out = Vec::new();
        let mut rest = body;
        while let Some(p) = rest.find(": (") {
            let after = &rest[p + 3..];
            let digits: String = after.chars().take_while(|c| c.is_ascii_digit()).collect();
            if let Ok(n) = digits.parse::<usize>() {
                out.push(n);
            }
            rest = after;
        }
        out.sort_unstable();
        out.dedup();
        Some(out)
    });

    Some(Signature { params, outputs, aliased })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"HloModule train_step.42, input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}, may-alias) }, entry_computation_layout={(f32[4,2]{1,0},f32[])->(f32[4,2]{1,0},f32[])}

%fused_add (a.0: f32[4,2], b.0: f32[4,2]) -> f32[4,2] {
  %a.0 = f32[4,2]{1,0} parameter(0)
  %b.0 = f32[4,2]{1,0} parameter(1)
  ROOT %r.0 = f32[4,2]{1,0} add(%a.0, %b.0)
}

ENTRY %main.42 (Arg_0.1: f32[4,2], Arg_1.2: f32[]) -> (f32[4,2], f32[]) {
  %Arg_0.1 = f32[4,2]{1,0} parameter(0)
  %Arg_1.2 = f32[] parameter(1), metadata={op_name="lr"}
  %t.3 = s32[2,4]{1,0} constant({...})
  ROOT %tuple.9 = (f32[4,2]{1,0}, f32[]) tuple(%Arg_0.1, %Arg_1.2)
}
"#;

    #[test]
    fn parses_entry_signature_not_fusions() {
        let sig = parse_signature(SAMPLE).unwrap();
        assert_eq!(sig.params.len(), 2, "fusion params must not leak in");
        assert_eq!(sig.params[0], TensorTy { dtype: "f32".into(), dims: vec![4, 2] });
        assert_eq!(sig.params[1], TensorTy { dtype: "f32".into(), dims: vec![] });
        assert_eq!(sig.outputs.len(), 2);
        assert_eq!(sig.outputs[1].render(), "f32[]");
        assert_eq!(sig.aliased, Some(vec![0, 1]));
    }

    #[test]
    fn no_alias_header_means_none() {
        let text = "HloModule fwd\n\nENTRY %m (a: s32[2,4]) -> (f32[]) {\n  %a = s32[2,4]{1,0} parameter(0)\n  ROOT %t = (f32[]) tuple()\n}\n";
        let sig = parse_signature(text).unwrap();
        assert_eq!(sig.params[0].dtype, "s32");
        assert_eq!(sig.outputs.len(), 1);
        assert!(sig.aliased.is_none());
    }

    #[test]
    fn garbage_degrades_to_none() {
        assert!(parse_signature("not hlo at all").is_none());
        assert!(parse_signature("ENTRY %m () -> f32[] {\n}\n").is_none(), "no ROOT");
        // gap in parameter numbering
        let gap = "ENTRY %m (a: f32[]) -> (f32[]) {\n  %a = f32[] parameter(1)\n  ROOT %t = (f32[]) tuple(%a)\n}\n";
        assert!(parse_signature(gap).is_none());
    }

    #[test]
    fn tensor_ty_parsing() {
        assert_eq!(parse_tensor_ty("f32[4,8]{1,0}").unwrap().dims, vec![4, 8]);
        assert_eq!(parse_tensor_ty("s32[]").unwrap().dims, Vec::<usize>::new());
        assert!(parse_tensor_ty("f32").is_none());
        assert!(parse_tensor_ty("[4]").is_none());
    }
}
