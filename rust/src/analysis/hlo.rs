//! Tolerant HLO-text parser.
//!
//! The AOT layer (`python/compile/aot.py`) serializes every program as
//! `as_hlo_text()` output. Two readers live here:
//!
//! * [`parse_signature`] — the original ENTRY-interface reader the
//!   contract pass (AR rules) uses: parameter types, ROOT tuple element
//!   types, donated parameter numbers. Anything it cannot understand
//!   yields `None`, reported as an AR009 *warning* (checks skipped),
//!   never a spurious error against real compiler output.
//! * [`parse_module`] — a full-module reader for the liveness pass (MM
//!   rules): every computation body, every instruction with its shape
//!   (tensors and tuples), operands, and the output-index →
//!   parameter-number alias pairs. Still tolerant — unknown opcodes and
//!   attributes pass through untouched — but a line that claims to be
//!   an instruction and cannot be read degrades to a structured
//!   [`crate::error::Error::Parse`], never a panic.

/// One flat tensor type, e.g. `f32[4,8]` or `s32[]` (scalar).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorTy {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorTy {
    pub fn render(&self) -> String {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        format!("{}[{}]", self.dtype, dims.join(","))
    }

    /// Flat buffer size. Unknown element types fall back to 4 bytes
    /// (the dominant f32/s32 case) — tolerance over precision, so one
    /// exotic dtype cannot kill a whole-program liveness sweep.
    pub fn flat_bytes(&self) -> u64 {
        let elems: u64 = self.dims.iter().map(|&d| d as u64).product();
        elems * hlo_dtype_bytes(&self.dtype).unwrap_or(4)
    }
}

/// HLO element-type spelling → bytes per element.
pub fn hlo_dtype_bytes(dtype: &str) -> Option<u64> {
    Some(match dtype {
        "pred" | "s8" | "u8" | "f8e4m3" | "f8e5m2" => 1,
        "f16" | "bf16" | "s16" | "u16" => 2,
        "f32" | "s32" | "u32" => 4,
        "f64" | "s64" | "u64" | "c64" => 8,
        "c128" => 16,
        _ => return None,
    })
}

/// An instruction's result shape: a flat tensor or a (possibly nested)
/// tuple of shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Shape {
    Tensor(TensorTy),
    Tuple(Vec<Shape>),
}

impl Shape {
    /// Total bytes across all tensor leaves.
    pub fn flat_bytes(&self) -> u64 {
        match self {
            Shape::Tensor(t) => t.flat_bytes(),
            Shape::Tuple(elems) => elems.iter().map(Shape::flat_bytes).sum(),
        }
    }

    pub fn render(&self) -> String {
        match self {
            Shape::Tensor(t) => t.render(),
            Shape::Tuple(elems) => {
                let parts: Vec<String> = elems.iter().map(Shape::render).collect();
                format!("({})", parts.join(", "))
            }
        }
    }
}

/// One parsed instruction line.
#[derive(Debug, Clone)]
pub struct Instr {
    /// Name without the leading `%`.
    pub name: String,
    pub shape: Shape,
    pub opcode: String,
    /// Operand instruction names (leading `%` stripped); non-reference
    /// operand tokens (constant literals, parameter numbers) are not
    /// listed here.
    pub operands: Vec<String>,
    pub is_root: bool,
    /// `Some(n)` when the opcode is `parameter(n)`.
    pub param_number: Option<usize>,
    /// Raw attribute text after the operand list (`dimensions={...},
    /// to_apply=%add` …), kept verbatim.
    pub attrs: String,
}

/// One computation body (`%name (...) -> ty { ... }` or the ENTRY).
#[derive(Debug, Clone)]
pub struct Computation {
    pub name: String,
    pub instrs: Vec<Instr>,
    pub is_entry: bool,
}

impl Computation {
    /// The `ROOT` instruction, if the body declared one.
    pub fn root(&self) -> Option<&Instr> {
        self.instrs.iter().find(|i| i.is_root)
    }
}

/// A whole parsed HLO module.
#[derive(Debug, Clone)]
pub struct Module {
    pub name: String,
    pub computations: Vec<Computation>,
    /// `input_output_alias` pairs as `(output index, parameter number)`,
    /// sorted by output index. Empty when the header carries no map.
    pub alias: Vec<(usize, usize)>,
}

impl Module {
    pub fn entry(&self) -> Option<&Computation> {
        self.computations.iter().find(|c| c.is_entry)
    }
}

/// The ENTRY computation's interface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Signature {
    /// Parameter types in parameter-number order.
    pub params: Vec<TensorTy>,
    /// ROOT tuple element types (`return_tuple=True` at AOT time, so
    /// the root is always a tuple; a non-tuple root parses as one
    /// element).
    pub outputs: Vec<TensorTy>,
    /// Parameter numbers named in `input_output_alias` — the donated
    /// inputs. `None` when the module header carries no alias map (the
    /// program donates nothing, or the text predates aliasing).
    pub aliased: Option<Vec<usize>>,
}

/// Parse `f32[4,8]{1,0}` / `s32[]` → [`TensorTy`]. Trailing layout or
/// metadata after `]` is ignored.
fn parse_tensor_ty(tok: &str) -> Option<TensorTy> {
    let open = tok.find('[')?;
    let close = tok[open..].find(']')? + open;
    let dtype = tok[..open].trim().to_string();
    if dtype.is_empty() || !dtype.chars().all(|c| c.is_ascii_alphanumeric()) {
        return None;
    }
    let inner = &tok[open + 1..close];
    let mut dims = Vec::new();
    if !inner.trim().is_empty() {
        for part in inner.split(',') {
            dims.push(part.trim().parse::<usize>().ok()?);
        }
    }
    Some(TensorTy { dtype, dims })
}

/// Split a tuple type body (the text between the outer parens) at
/// top-level commas — bracket/brace/paren aware, so `f32[4,8]{1,0}`
/// stays one token.
fn split_top_level(body: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut start = 0;
    for (i, c) in body.char_indices() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            ',' if depth == 0 => {
                out.push(body[start..i].trim());
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(body[start..].trim());
    out
}

/// Extract a balanced `{...}` / `(...)` span starting at `open_idx`
/// (which must point at the opening delimiter). Returns the inner text.
fn balanced_span(text: &str, open_idx: usize, open: char, close: char) -> Option<&str> {
    let bytes = text.as_bytes();
    if bytes.get(open_idx) != Some(&(open as u8)) {
        return None;
    }
    let mut depth = 0usize;
    for (i, c) in text[open_idx..].char_indices() {
        if c == open {
            depth += 1;
        } else if c == close {
            depth -= 1;
            if depth == 0 {
                return Some(&text[open_idx + 1..open_idx + i]);
            }
        }
    }
    None
}

/// Parse the ENTRY computation's signature out of full HLO text.
pub fn parse_signature(text: &str) -> Option<Signature> {
    // --- ENTRY block: from the `ENTRY` header line to the closing `}`
    let mut in_entry = false;
    let mut params: Vec<(usize, TensorTy)> = Vec::new();
    let mut outputs: Option<Vec<TensorTy>> = None;
    for line in text.lines() {
        let trimmed = line.trim();
        if !in_entry {
            if trimmed.starts_with("ENTRY ") || trimmed.starts_with("ENTRY%") {
                in_entry = true;
            }
            continue;
        }
        if trimmed == "}" {
            break;
        }
        // instruction lines: `%name = <type> <op>(...)`
        let Some(eq) = trimmed.find(" = ") else { continue };
        let rest = &trimmed[eq + 3..];
        if let Some(ppos) = rest.find("parameter(") {
            let ty_tok = rest[..ppos].trim();
            let after = &rest[ppos + "parameter(".len()..];
            let digits: String = after.chars().take_while(|c| c.is_ascii_digit()).collect();
            let idx = digits.parse::<usize>().ok()?;
            params.push((idx, parse_tensor_ty(ty_tok)?));
        }
        if trimmed.starts_with("ROOT ") || trimmed.starts_with("ROOT%") {
            let tys = if rest.starts_with('(') {
                let body = balanced_span(rest, 0, '(', ')')?;
                split_top_level(body)
                    .into_iter()
                    .map(parse_tensor_ty)
                    .collect::<Option<Vec<_>>>()?
            } else {
                let tok = rest.split_whitespace().next()?;
                vec![parse_tensor_ty(tok)?]
            };
            outputs = Some(tys);
        }
    }
    if !in_entry {
        return None;
    }
    // parameter numbers must be dense 0..n
    params.sort_by_key(|(i, _)| *i);
    for (expect, (got, _)) in params.iter().enumerate() {
        if *got != expect {
            return None;
        }
    }
    let params: Vec<TensorTy> = params.into_iter().map(|(_, t)| t).collect();
    let outputs = outputs?;

    // --- donation map on the HloModule header (anywhere in the text)
    let aliased = text.find("input_output_alias=").and_then(|pos| {
        let brace = pos + "input_output_alias=".len();
        let body = balanced_span(text, brace, '{', '}')?;
        // entries look like `{0}: (3, {}, may-alias)` — the first
        // integer after each `: (` is the donated parameter number
        let mut out = Vec::new();
        let mut rest = body;
        while let Some(p) = rest.find(": (") {
            let after = &rest[p + 3..];
            let digits: String = after.chars().take_while(|c| c.is_ascii_digit()).collect();
            if let Ok(n) = digits.parse::<usize>() {
                out.push(n);
            }
            rest = after;
        }
        out.sort_unstable();
        out.dedup();
        Some(out)
    });

    Some(Signature { params, outputs, aliased })
}

/// Parse a shape token: `f32[4,2]{1,0}`, `s32[]`, or a tuple
/// `(f32[4,2], (f32[], s32[2]))`. Trailing layout after `]` is ignored.
pub fn parse_shape(tok: &str) -> Option<Shape> {
    let tok = tok.trim();
    if tok.starts_with('(') {
        let body = balanced_span(tok, 0, '(', ')')?;
        let mut elems = Vec::new();
        if !body.trim().is_empty() {
            for part in split_top_level(body) {
                elems.push(parse_shape(part)?);
            }
        }
        Some(Shape::Tuple(elems))
    } else {
        parse_tensor_ty(tok).map(Shape::Tensor)
    }
}

/// Byte length of the leading shape token in `s` (which starts at a
/// shape): for tuples the balanced `(...)` span, for tensors everything
/// up to the first whitespace outside brackets (so `f32[4,2]{1,0}`
/// stays whole).
fn shape_token_len(s: &str) -> Option<usize> {
    if s.starts_with('(') {
        return balanced_span(s, 0, '(', ')').map(|body| body.len() + 2);
    }
    let mut depth = 0i32;
    for (i, c) in s.char_indices() {
        match c {
            '[' | '{' | '(' => depth += 1,
            ']' | '}' | ')' => depth -= 1,
            c if c.is_whitespace() && depth <= 0 => return Some(i),
            _ => {}
        }
    }
    Some(s.len())
}

/// Parse one instruction line (`%name = <shape> opcode(operands), attrs`,
/// optionally `ROOT`-prefixed). `None` means the line is malformed.
fn parse_instr(trimmed: &str) -> Option<Instr> {
    let (is_root, rest) = match trimmed.strip_prefix("ROOT") {
        Some(r) => (true, r.trim_start()),
        None => (false, trimmed),
    };
    let name_tok = rest.strip_prefix('%')?;
    let eq = name_tok.find('=')?;
    let name = name_tok[..eq].trim().to_string();
    if name.is_empty() {
        return None;
    }
    let rhs = name_tok[eq + 1..].trim_start();
    let shape_len = shape_token_len(rhs)?;
    let shape = parse_shape(&rhs[..shape_len])?;
    let after_shape = rhs[shape_len..].trim_start();
    let op_end = after_shape
        .find(|c: char| c == '(' || c == ',' || c.is_whitespace())
        .unwrap_or(after_shape.len());
    let opcode = after_shape[..op_end].to_string();
    if opcode.is_empty() || !opcode.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.') {
        return None;
    }
    let rest2 = after_shape[op_end..].trim_start();
    let mut operands = Vec::new();
    let mut param_number = None;
    let attrs;
    if rest2.starts_with('(') {
        let body = balanced_span(rest2, 0, '(', ')')?;
        for tok in split_top_level(body) {
            // operand tokens are `%name` (possibly `ty %name` in older
            // dialects); literal bodies (`constant({...})`) have no `%`
            if let Some(p) = tok.find('%') {
                let op = tok[p + 1..].trim();
                if !op.is_empty() {
                    operands.push(op.to_string());
                }
            }
        }
        if opcode == "parameter" {
            param_number = body.trim().parse::<usize>().ok();
        }
        attrs = rest2[body.len() + 2..].trim_start_matches(',').trim().to_string();
    } else {
        attrs = rest2.trim_start_matches(',').trim().to_string();
    }
    Some(Instr { name, shape, opcode, operands, is_root, param_number, attrs })
}

/// Parse the `input_output_alias={...}` header map into `(output index,
/// parameter number)` pairs. Missing/garbled map → empty vec (the
/// liveness pass decides whether an absent map is a finding).
fn parse_alias_pairs(text: &str) -> Vec<(usize, usize)> {
    let Some(pos) = text.find("input_output_alias=") else { return Vec::new() };
    let Some(body) = balanced_span(text, pos + "input_output_alias=".len(), '{', '}') else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let mut rest = body;
    loop {
        // one entry: `{K}: (P, {}, may-alias)`
        let Some(ob) = rest.find('{') else { break };
        let Some(cb) = rest[ob..].find('}').map(|i| i + ob) else { break };
        let out_idx = rest[ob + 1..cb].split(',').next().and_then(|s| s.trim().parse::<usize>().ok());
        let after = &rest[cb + 1..];
        let Some(op) = after.find('(') else { break };
        let Some(inner) = balanced_span(after, op, '(', ')') else { break };
        let param = inner
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect::<String>()
            .parse::<usize>()
            .ok();
        if let (Some(o), Some(p)) = (out_idx, param) {
            out.push((o, p));
        }
        rest = &after[op + inner.len() + 2..];
    }
    out.sort_unstable();
    out
}

/// Parse a whole HLO module: every computation body with its
/// instructions, plus the header alias map. Tolerant of unknown opcodes
/// and attributes; structural problems (no ENTRY, no ROOT, a malformed
/// instruction line, gapped parameter numbering) degrade to
/// [`crate::error::Error::Parse`] — never a panic.
pub fn parse_module(text: &str) -> crate::error::Result<Module> {
    let perr = |m: String| crate::error::Error::Parse(format!("hlo: {m}"));
    let mut name = String::from("unknown");
    let mut computations: Vec<Computation> = Vec::new();
    let mut current: Option<Computation> = None;
    for line in text.lines() {
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with("//") {
            continue;
        }
        if let Some(rest) = trimmed.strip_prefix("HloModule") {
            if let Some(tok) = rest.split([',', ' ']).find(|t| !t.trim().is_empty()) {
                name = tok.trim().to_string();
            }
            continue;
        }
        let is_entry_hdr = trimmed.starts_with("ENTRY ") || trimmed.starts_with("ENTRY%");
        let is_comp_hdr = trimmed.ends_with('{')
            && (is_entry_hdr || (trimmed.starts_with('%') && trimmed.contains("->")));
        if is_comp_hdr {
            if let Some(c) = current.take() {
                computations.push(c);
            }
            let hdr = if is_entry_hdr { trimmed["ENTRY".len()..].trim_start() } else { trimmed };
            let cname = hdr
                .strip_prefix('%')
                .unwrap_or(hdr)
                .split(|c: char| c.is_whitespace() || c == '(')
                .next()
                .unwrap_or("")
                .to_string();
            current = Some(Computation { name: cname, instrs: Vec::new(), is_entry: is_entry_hdr });
            continue;
        }
        if trimmed == "}" {
            if let Some(c) = current.take() {
                computations.push(c);
            }
            continue;
        }
        if let Some(cur) = current.as_mut() {
            if trimmed.starts_with('%') || trimmed.starts_with("ROOT") {
                match parse_instr(trimmed) {
                    Some(i) => cur.instrs.push(i),
                    None => return Err(perr(format!("unreadable instruction line: {trimmed}"))),
                }
            }
            // anything else inside a body (metadata continuations …) is
            // tolerated and skipped
        }
    }
    if let Some(c) = current.take() {
        computations.push(c);
    }
    let alias = parse_alias_pairs(text);
    let module = Module { name, computations, alias };
    let Some(entry) = module.entry() else {
        return Err(perr("no ENTRY computation".into()));
    };
    if entry.root().is_none() {
        return Err(perr("ENTRY computation has no ROOT instruction".into()));
    }
    // parameter numbers must be dense 0..n, mirroring parse_signature
    let mut params: Vec<usize> = entry.instrs.iter().filter_map(|i| i.param_number).collect();
    params.sort_unstable();
    for (expect, got) in params.iter().enumerate() {
        if *got != expect {
            return Err(perr(format!("parameter numbering has a gap at {expect}")));
        }
    }
    Ok(module)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"HloModule train_step.42, input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}, may-alias) }, entry_computation_layout={(f32[4,2]{1,0},f32[])->(f32[4,2]{1,0},f32[])}

%fused_add (a.0: f32[4,2], b.0: f32[4,2]) -> f32[4,2] {
  %a.0 = f32[4,2]{1,0} parameter(0)
  %b.0 = f32[4,2]{1,0} parameter(1)
  ROOT %r.0 = f32[4,2]{1,0} add(%a.0, %b.0)
}

ENTRY %main.42 (Arg_0.1: f32[4,2], Arg_1.2: f32[]) -> (f32[4,2], f32[]) {
  %Arg_0.1 = f32[4,2]{1,0} parameter(0)
  %Arg_1.2 = f32[] parameter(1), metadata={op_name="lr"}
  %t.3 = s32[2,4]{1,0} constant({...})
  ROOT %tuple.9 = (f32[4,2]{1,0}, f32[]) tuple(%Arg_0.1, %Arg_1.2)
}
"#;

    #[test]
    fn parses_entry_signature_not_fusions() {
        let sig = parse_signature(SAMPLE).unwrap();
        assert_eq!(sig.params.len(), 2, "fusion params must not leak in");
        assert_eq!(sig.params[0], TensorTy { dtype: "f32".into(), dims: vec![4, 2] });
        assert_eq!(sig.params[1], TensorTy { dtype: "f32".into(), dims: vec![] });
        assert_eq!(sig.outputs.len(), 2);
        assert_eq!(sig.outputs[1].render(), "f32[]");
        assert_eq!(sig.aliased, Some(vec![0, 1]));
    }

    #[test]
    fn no_alias_header_means_none() {
        let text = "HloModule fwd\n\nENTRY %m (a: s32[2,4]) -> (f32[]) {\n  %a = s32[2,4]{1,0} parameter(0)\n  ROOT %t = (f32[]) tuple()\n}\n";
        let sig = parse_signature(text).unwrap();
        assert_eq!(sig.params[0].dtype, "s32");
        assert_eq!(sig.outputs.len(), 1);
        assert!(sig.aliased.is_none());
    }

    #[test]
    fn garbage_degrades_to_none() {
        assert!(parse_signature("not hlo at all").is_none());
        assert!(parse_signature("ENTRY %m () -> f32[] {\n}\n").is_none(), "no ROOT");
        // gap in parameter numbering
        let gap = "ENTRY %m (a: f32[]) -> (f32[]) {\n  %a = f32[] parameter(1)\n  ROOT %t = (f32[]) tuple(%a)\n}\n";
        assert!(parse_signature(gap).is_none());
    }

    #[test]
    fn tensor_ty_parsing() {
        assert_eq!(parse_tensor_ty("f32[4,8]{1,0}").unwrap().dims, vec![4, 8]);
        assert_eq!(parse_tensor_ty("s32[]").unwrap().dims, Vec::<usize>::new());
        assert!(parse_tensor_ty("f32").is_none());
        assert!(parse_tensor_ty("[4]").is_none());
    }

    #[test]
    fn module_parser_reads_bodies_and_alias() {
        let m = parse_module(SAMPLE).unwrap();
        assert_eq!(m.name, "train_step.42");
        assert_eq!(m.computations.len(), 2, "fusion + entry");
        assert_eq!(m.alias, vec![(0, 0), (1, 1)]);
        let entry = m.entry().unwrap();
        assert_eq!(entry.name, "main.42");
        assert_eq!(entry.instrs.len(), 4);
        assert_eq!(entry.instrs[0].param_number, Some(0));
        assert_eq!(entry.instrs[1].attrs, "metadata={op_name=\"lr\"}");
        // constant literal body must not leak into operands
        assert_eq!(entry.instrs[2].opcode, "constant");
        assert!(entry.instrs[2].operands.is_empty());
        let root = entry.root().unwrap();
        assert_eq!(root.operands, vec!["Arg_0.1", "Arg_1.2"]);
        assert_eq!(root.shape.render(), "(f32[4,2], f32[])");
        assert_eq!(root.shape.flat_bytes(), 8 * 4 + 4);
        // the fusion body parses too
        let fused = &m.computations[0];
        assert!(!fused.is_entry);
        assert_eq!(fused.root().unwrap().opcode, "add");
    }

    #[test]
    fn module_parser_degrades_to_parse_error() {
        assert!(matches!(parse_module("not hlo"), Err(crate::error::Error::Parse(_))));
        assert!(matches!(
            parse_module("ENTRY %m () -> f32[] {\n}\n"),
            Err(crate::error::Error::Parse(_))
        ));
        // a line claiming to be an instruction but unreadable
        let bad = "ENTRY %m (a: f32[]) -> (f32[]) {\n  %a = garbage\n  ROOT %t = (f32[]) tuple(%a)\n}\n";
        assert!(matches!(parse_module(bad), Err(crate::error::Error::Parse(_))));
        // gapped parameter numbering
        let gap = "ENTRY %m (a: f32[]) -> (f32[]) {\n  %a = f32[] parameter(1)\n  ROOT %t = (f32[]) tuple(%a)\n}\n";
        assert!(matches!(parse_module(gap), Err(crate::error::Error::Parse(_))));
    }

    #[test]
    fn shape_parsing_handles_nested_tuples_and_bytes() {
        let s = parse_shape("(f32[4,2]{1,0}, (s32[2], pred[]))").unwrap();
        assert_eq!(s.flat_bytes(), 32 + 8 + 1);
        assert_eq!(s.render(), "(f32[4,2], (s32[2], pred[]))");
        assert_eq!(parse_shape("bf16[8]").unwrap().flat_bytes(), 16);
        assert!(parse_shape("???").is_none());
    }

    #[test]
    fn attrs_operands_stay_separate() {
        let text = "HloModule r\nENTRY %m (a: f32[4]) -> (f32[]) {\n  %a = f32[4] parameter(0)\n  %z = f32[] constant(0)\n  %r = f32[] reduce(%a, %z), dimensions={0}, to_apply=%add_f32\n  ROOT %t = (f32[]) tuple(%r)\n}\n";
        let m = parse_module(text).unwrap();
        let red = &m.entry().unwrap().instrs[2];
        assert_eq!(red.operands, vec!["a", "z"], "to_apply target is an attr, not an operand");
        assert!(red.attrs.contains("to_apply=%add_f32"));
    }
}
