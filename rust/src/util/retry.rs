//! Sanctioned waiting: exponential backoff with seeded jitter, plus the
//! single raw-sleep chokepoint [`pause`].
//!
//! Lint rule LN004 (`revffn check --lint`, docs/ANALYSIS.md) forbids
//! `thread::sleep` anywhere else under `rust/src` — every wait in the
//! tree (scheduler poll parks, supervised-retry delays, injected fault
//! stalls) routes through this module so waits stay auditable, bounded,
//! and jittered in one place.

use std::time::Duration;

use crate::util::Rng;

/// Exponential backoff with deterministic "equal jitter".
///
/// Delay before retry `attempt` (1-based) is `base * 2^(attempt-1)`
/// capped at `max`, then jittered to `[d/2, d)` — half fixed so a delay
/// never collapses to zero, half uniform so concurrent retries
/// decorrelate. The jitter stream is seeded, so a given `Backoff` value
/// produces a reproducible delay sequence.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    max: Duration,
    rng: Rng,
}

impl Backoff {
    pub fn new(base_ms: u64, max_ms: u64, seed: u64) -> Backoff {
        Backoff {
            base: Duration::from_millis(base_ms),
            max: Duration::from_millis(max_ms.max(base_ms)),
            rng: Rng::seed_from_u64(seed),
        }
    }

    /// Jittered delay before retry `attempt` (1-based). A zero base
    /// yields zero delays (used by tests to retry immediately).
    pub fn delay(&mut self, attempt: u32) -> Duration {
        if self.base.is_zero() {
            return Duration::ZERO;
        }
        let shift = attempt.saturating_sub(1).min(20);
        let exp = self.base.saturating_mul(1u32 << shift).min(self.max);
        let half = exp / 2;
        half + Duration::from_secs_f64(half.as_secs_f64() * self.rng.gen_f64())
    }
}

/// The one sanctioned raw sleep (LN004): poll parks, backoff waits, and
/// injected delay faults all come through here.
pub fn pause(d: Duration) {
    if !d.is_zero() {
        std::thread::sleep(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_delays_are_reproducible() {
        let mut a = Backoff::new(100, 10_000, 7);
        let mut b = Backoff::new(100, 10_000, 7);
        for attempt in 1..=8 {
            assert_eq!(a.delay(attempt), b.delay(attempt));
        }
    }

    #[test]
    fn delays_grow_exponentially_within_jitter_bounds() {
        let mut b = Backoff::new(100, 100_000, 3);
        for attempt in 1..=6u32 {
            let exp = Duration::from_millis(100 * (1u64 << (attempt - 1)));
            let d = b.delay(attempt);
            assert!(d >= exp / 2, "attempt {attempt}: {d:?} < {:?}", exp / 2);
            assert!(d < exp, "attempt {attempt}: {d:?} >= {exp:?}");
        }
    }

    #[test]
    fn delays_cap_at_max() {
        let mut b = Backoff::new(100, 400, 1);
        for attempt in 1..=12 {
            assert!(b.delay(attempt) < Duration::from_millis(400));
        }
    }

    #[test]
    fn zero_base_means_no_wait() {
        let mut b = Backoff::new(0, 10_000, 1);
        for attempt in 1..=4 {
            assert_eq!(b.delay(attempt), Duration::ZERO);
        }
    }

    #[test]
    fn max_below_base_is_clamped_up() {
        let mut b = Backoff::new(200, 50, 1);
        // max is raised to base, so every delay lands in [100, 200)
        let d = b.delay(5);
        assert!(d >= Duration::from_millis(100) && d < Duration::from_millis(200));
    }

    #[test]
    fn huge_attempt_does_not_overflow() {
        let mut b = Backoff::new(1_000, 30_000, 1);
        let d = b.delay(u32::MAX);
        assert!(d < Duration::from_millis(30_000));
    }
}
