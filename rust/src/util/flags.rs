//! Hand-rolled CLI flag parser (the offline build carries no clap).
//!
//! Grammar: a flat list of `--key value` pairs and boolean `--key`
//! flags. A token following a flag is its value unless it starts with
//! `--`; values beginning with a single `-` (negative numbers) are
//! accepted. Dashes in keys normalize to underscores, so `--stage1-steps`
//! and `--stage1_steps` are the same flag. Positional arguments are
//! rejected.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed flags: normalized key → raw string value (`"true"` for bare
/// boolean flags).
#[derive(Debug, Default)]
pub struct Flags(HashMap<String, String>);

impl Flags {
    pub fn parse(args: &[String]) -> Result<Self> {
        let mut m = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(key) = a.strip_prefix("--") else {
                return Err(Error::Config(format!("unexpected positional argument {a:?}")));
            };
            if key.is_empty() {
                return Err(Error::Config("empty flag name \"--\"".into()));
            }
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.replace('-', "_"), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.replace('-', "_"), "true".into());
                i += 1;
            }
        }
        Ok(Flags(m))
    }

    /// String value with a default.
    pub fn str(&self, key: &str, default: &str) -> String {
        self.0.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// String value, `None` when absent.
    pub fn opt(&self, key: &str) -> Option<String> {
        self.0.get(key).cloned()
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.0.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} must be an integer, got {v:?}"))),
            None => Ok(default),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.0.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} must be a number, got {v:?}"))),
            None => Ok(default),
        }
    }

    /// Boolean flag: present without a value (or with `true`) → true.
    pub fn bool(&self, key: &str) -> bool {
        self.0.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn key_value_pairs() {
        let f = Flags::parse(&args(&["--artifacts", "a/b", "--method", "lora"])).unwrap();
        assert_eq!(f.str("artifacts", "x"), "a/b");
        assert_eq!(f.str("method", "revffn"), "lora");
        assert_eq!(f.str("missing", "dflt"), "dflt");
        assert_eq!(f.opt("method").as_deref(), Some("lora"));
        assert_eq!(f.opt("missing"), None);
    }

    #[test]
    fn bare_boolean_flags() {
        let f = Flags::parse(&args(&["--eval-suite", "--save-checkpoint"])).unwrap();
        assert!(f.bool("eval_suite"));
        assert!(f.bool("save_checkpoint"));
        assert!(!f.bool("absent"));
    }

    #[test]
    fn boolean_before_another_flag() {
        let f = Flags::parse(&args(&["--eval-suite", "--questions", "16"])).unwrap();
        assert!(f.bool("eval_suite"));
        assert_eq!(f.u64("questions", 0).unwrap(), 16);
    }

    #[test]
    fn explicit_false_turns_flag_off() {
        let f = Flags::parse(&args(&["--save-checkpoint", "false"])).unwrap();
        assert!(!f.bool("save_checkpoint"));
    }

    #[test]
    fn positional_argument_rejected() {
        assert!(Flags::parse(&args(&["train", "--method", "sft"])).is_err());
        assert!(Flags::parse(&args(&["--method", "sft", "stray"])).is_err());
        assert!(Flags::parse(&args(&["--"])).is_err());
    }

    #[test]
    fn value_beginning_with_single_dash_accepted() {
        let f = Flags::parse(&args(&["--temperature", "-0.5", "--seed", "-3"])).unwrap();
        assert_eq!(f.f64("temperature", 0.0).unwrap(), -0.5);
        // not parseable as u64 — must error, not silently default
        assert!(f.u64("seed", 0).is_err());
    }

    #[test]
    fn double_dash_value_is_swallowed_as_flag() {
        // a value starting with `--` reads as the next flag: the first
        // key becomes boolean — documented grammar, locked in here
        let f = Flags::parse(&args(&["--out-dir", "--weird"])).unwrap();
        assert!(f.bool("out_dir"));
        assert!(f.bool("weird"));
    }

    #[test]
    fn dashes_normalize_to_underscores() {
        let f = Flags::parse(&args(&["--stage1-steps", "30"])).unwrap();
        assert_eq!(f.u64("stage1_steps", 0).unwrap(), 30);
    }

    #[test]
    fn malformed_numbers_error() {
        let f = Flags::parse(&args(&["--steps", "many", "--lr", "fast"])).unwrap();
        assert!(f.u64("steps", 1).is_err());
        assert!(f.f64("lr", 1.0).is_err());
        // absent keys fall back to defaults without error
        assert_eq!(f.u64("other", 7).unwrap(), 7);
        assert_eq!(f.f64("other_f", 0.5).unwrap(), 0.5);
    }
}
