//! Deterministic fault injection (chaos harness) for the serve and
//! training hot paths.
//!
//! A [`FaultPlan`] arms a set of [`FaultSpec`]s against named
//! [`FaultSite`]s — the real failure surfaces threaded through the
//! tree: `runtime/pjrt.rs` (program execute + host transfer),
//! `checkpoint` (snapshot write / fsync / rename, including torn
//! writes), and the serve wire layer (socket read / write). Each site
//! calls [`hit`] (usually via [`failpoint`] / [`io_failpoint`]) on its
//! hot path; with no plan installed that is a single relaxed atomic
//! load, so production pays nothing.
//!
//! Plans are compact strings, taken from the serve config `faults` key
//! or the `REVFFN_FAULTS` environment variable (the env var wins):
//!
//! ```text
//! pjrt_execute@3:error             # the 3rd execute call fails
//! ckpt_write@1:torn                # the first snapshot write is torn
//! wire_read@2x0:delay=50           # every read from the 2nd on stalls 50ms
//! seed=7;pjrt_execute@5:error      # seed the tear/jitter RNG
//! ```
//!
//! Clauses are `;`- or `,`-separated: `SITE[@AT[xTIMES]]:KIND`, where
//! `AT` is the 1-based hit index at which the fault starts firing
//! (default 1) and `TIMES` is how many consecutive hits fire (default
//! 1; `0` = every hit from `AT` on). `KIND` is `error`, `torn`, or
//! `delay=MILLIS`. See docs/ROBUSTNESS.md for the full catalog.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::retry;
use crate::util::Rng;

/// An injection point threaded through a real failure surface.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// PJRT program execution (`Program::run` / `Program::run_buffers`).
    PjrtExecute,
    /// Host<->device literal transfer (`Device::to_device` / `from_device`).
    PjrtTransfer,
    /// Checkpoint payload write (supports `torn`).
    CkptWrite,
    /// Checkpoint fsync before the atomic rename.
    CkptFsync,
    /// Checkpoint tmp -> final rename.
    CkptRename,
    /// Serve control-plane socket read (one NDJSON request line).
    WireRead,
    /// Serve control-plane socket write (one NDJSON reply/event line).
    WireWrite,
}

impl FaultSite {
    pub const ALL: [FaultSite; 7] = [
        FaultSite::PjrtExecute,
        FaultSite::PjrtTransfer,
        FaultSite::CkptWrite,
        FaultSite::CkptFsync,
        FaultSite::CkptRename,
        FaultSite::WireRead,
        FaultSite::WireWrite,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::PjrtExecute => "pjrt_execute",
            FaultSite::PjrtTransfer => "pjrt_transfer",
            FaultSite::CkptWrite => "ckpt_write",
            FaultSite::CkptFsync => "ckpt_fsync",
            FaultSite::CkptRename => "ckpt_rename",
            FaultSite::WireRead => "wire_read",
            FaultSite::WireWrite => "wire_write",
        }
    }

    pub fn parse(s: &str) -> Option<FaultSite> {
        FaultSite::ALL.iter().copied().find(|site| site.name() == s)
    }

    fn index(self) -> usize {
        FaultSite::ALL
            .iter()
            .position(|s| *s == self)
            .unwrap_or_default()
    }
}

/// What happens when an armed spec fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The operation fails with an injected error.
    Error,
    /// The operation stalls this many milliseconds, then succeeds
    /// (exercises watchdogs and socket timeouts).
    Delay(u64),
    /// Checkpoint-write only: the snapshot is truncated mid-stream and
    /// renamed into place without an fsync — a simulated torn write
    /// that `latest_valid_checkpoint` must skip. At sites that cannot
    /// tear it degrades to `Error`.
    Torn,
}

/// One armed fault: fire `kind` at `site`, starting at the `at`-th hit
/// (1-based), for `times` consecutive hits (0 = forever).
#[derive(Clone, Debug)]
pub struct FaultSpec {
    pub site: FaultSite,
    pub kind: FaultKind,
    pub at: u64,
    pub times: u64,
}

/// A parsed fault plan: seed for the tear/jitter RNG plus armed specs.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse the compact spec grammar (module docs). Empty clauses are
    /// skipped, so trailing separators are harmless.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for clause in text.split([';', ',']) {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(seed) = clause.strip_prefix("seed=") {
                plan.seed = seed
                    .trim()
                    .parse::<u64>()
                    .map_err(|_| bad_spec(clause, "seed must be a u64"))?;
                continue;
            }
            let (head, kind_str) = clause
                .split_once(':')
                .ok_or_else(|| bad_spec(clause, "expected SITE[@AT[xTIMES]]:KIND"))?;
            let (site_str, trigger) = match head.split_once('@') {
                Some((s, t)) => (s.trim(), Some(t.trim())),
                None => (head.trim(), None),
            };
            let site = FaultSite::parse(site_str)
                .ok_or_else(|| bad_spec(clause, "unknown fault site"))?;
            let (at, times) = match trigger {
                None => (1, 1),
                Some(t) => match t.split_once('x') {
                    None => (parse_u64(t, clause, "AT")?, 1),
                    Some((a, n)) => (
                        parse_u64(a.trim(), clause, "AT")?,
                        parse_u64(n.trim(), clause, "TIMES")?,
                    ),
                },
            };
            if at == 0 {
                return Err(bad_spec(clause, "AT is 1-based; 0 never fires"));
            }
            let kind = match kind_str.trim() {
                "error" => FaultKind::Error,
                "torn" => FaultKind::Torn,
                other => match other.strip_prefix("delay=") {
                    Some(ms) => FaultKind::Delay(parse_u64(ms.trim(), clause, "delay millis")?),
                    None => return Err(bad_spec(clause, "kind must be error|torn|delay=MS")),
                },
            };
            plan.specs.push(FaultSpec {
                site,
                kind,
                at,
                times,
            });
        }
        Ok(plan)
    }

    /// Read `REVFFN_FAULTS`; `Ok(None)` when unset or blank.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("REVFFN_FAULTS") {
            Ok(s) if !s.trim().is_empty() => Ok(Some(FaultPlan::parse(&s)?)),
            _ => Ok(None),
        }
    }
}

fn bad_spec(clause: &str, why: &str) -> Error {
    Error::Config(format!("fault spec `{clause}`: {why}"))
}

fn parse_u64(s: &str, clause: &str, what: &str) -> Result<u64> {
    s.parse::<u64>()
        .map_err(|_| bad_spec(clause, &format!("{what} must be a u64")))
}

struct Armed {
    spec: FaultSpec,
    hits: u64,
}

struct Installed {
    rng: Rng,
    armed: Vec<Armed>,
    fired: [u64; FaultSite::ALL.len()],
}

// Disabled fast path: one relaxed load. The Mutex is touched only while
// a plan is installed (tests, chaos drills).
static ENABLED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Installed>> = Mutex::new(None);
static TEST_GATE: Mutex<()> = Mutex::new(());

fn lock_plan() -> MutexGuard<'static, Option<Installed>> {
    PLAN.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Install a plan process-wide, replacing any previous one. Hit
/// counters start from zero.
pub fn install(plan: FaultPlan) {
    let armed = plan
        .specs
        .into_iter()
        .map(|spec| Armed { spec, hits: 0 })
        .collect();
    *lock_plan() = Some(Installed {
        rng: Rng::seed_from_u64(plan.seed),
        armed,
        fired: [0; FaultSite::ALL.len()],
    });
    ENABLED.store(true, Ordering::SeqCst);
}

/// Remove any installed plan; every site reverts to the no-op path.
pub fn clear() {
    *lock_plan() = None;
    ENABLED.store(false, Ordering::SeqCst);
}

/// Resolve and install a plan: `REVFFN_FAULTS` wins over the config
/// spec. Returns whether a plan was installed.
pub fn install_from(config_spec: Option<&str>) -> Result<bool> {
    if let Some(plan) = FaultPlan::from_env()? {
        install(plan);
        return Ok(true);
    }
    if let Some(spec) = config_spec {
        install(FaultPlan::parse(spec)?);
        return Ok(true);
    }
    Ok(false)
}

/// Record one hit at `site` and return the fault kind to apply, if any
/// armed spec fires. The production fast path (no plan) is a single
/// relaxed atomic load.
#[inline]
pub fn hit(site: FaultSite) -> Option<FaultKind> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    hit_slow(site)
}

fn hit_slow(site: FaultSite) -> Option<FaultKind> {
    let mut guard = lock_plan();
    let inst = guard.as_mut()?;
    let mut out = None;
    for a in inst.armed.iter_mut() {
        if a.spec.site != site {
            continue;
        }
        a.hits += 1;
        let n = a.hits;
        let firing = n >= a.spec.at && (a.spec.times == 0 || n < a.spec.at + a.spec.times);
        if firing && out.is_none() {
            out = Some(a.spec.kind);
        }
    }
    if out.is_some() {
        inst.fired[site.index()] += 1;
    }
    out
}

/// How many faults have fired at `site` under the current plan.
pub fn fired(site: FaultSite) -> u64 {
    lock_plan()
        .as_ref()
        .map(|inst| inst.fired[site.index()])
        .unwrap_or(0)
}

/// Fraction of a torn checkpoint to keep, in `[0.25, 0.75)`, drawn
/// from the plan's seeded RNG so tears are reproducible per plan.
pub fn torn_fraction() -> f64 {
    match lock_plan().as_mut() {
        Some(inst) => 0.25 + 0.5 * inst.rng.gen_f64(),
        None => 0.5,
    }
}

/// Error/delay failpoint for sites where a torn write has no meaning
/// (`Torn` degrades to `Error`). Delay faults stall via [`retry::pause`].
pub fn failpoint(site: FaultSite) -> Result<()> {
    match hit(site) {
        None => Ok(()),
        Some(FaultKind::Delay(ms)) => {
            retry::pause(Duration::from_millis(ms));
            Ok(())
        }
        Some(FaultKind::Error) | Some(FaultKind::Torn) => Err(Error::Training(format!(
            "injected fault: {}",
            site.name()
        ))),
    }
}

/// `std::io`-flavored failpoint for the serve wire layer.
pub fn io_failpoint(site: FaultSite) -> std::io::Result<()> {
    match hit(site) {
        None => Ok(()),
        Some(FaultKind::Delay(ms)) => {
            retry::pause(Duration::from_millis(ms));
            Ok(())
        }
        Some(FaultKind::Error) | Some(FaultKind::Torn) => Err(std::io::Error::other(format!(
            "injected fault: {}",
            site.name()
        ))),
    }
}

/// Fault plans are process-global; a test that installs one must hold
/// this lock for its whole body (and `clear()` right after locking) so
/// parallel tests never observe each other's plans.
pub fn test_lock() -> MutexGuard<'static, ()> {
    TEST_GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_defaults_and_trigger_grammar() {
        let p = FaultPlan::parse("pjrt_execute:error").unwrap();
        assert_eq!(p.specs.len(), 1);
        assert_eq!(p.specs[0].site, FaultSite::PjrtExecute);
        assert_eq!(p.specs[0].kind, FaultKind::Error);
        assert_eq!((p.specs[0].at, p.specs[0].times), (1, 1));

        let p = FaultPlan::parse("seed=9; ckpt_write@3:torn, wire_read@2x0:delay=50;").unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.specs.len(), 2);
        assert_eq!(p.specs[0].site, FaultSite::CkptWrite);
        assert_eq!(p.specs[0].kind, FaultKind::Torn);
        assert_eq!((p.specs[0].at, p.specs[0].times), (3, 1));
        assert_eq!(p.specs[1].site, FaultSite::WireRead);
        assert_eq!(p.specs[1].kind, FaultKind::Delay(50));
        assert_eq!((p.specs[1].at, p.specs[1].times), (2, 0));
    }

    #[test]
    fn parse_rejects_junk() {
        for bad in [
            "nope:error",
            "pjrt_execute",
            "pjrt_execute:boom",
            "pjrt_execute@0:error",
            "pjrt_execute@x:error",
            "pjrt_execute:delay=abc",
            "seed=minus",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn window_semantics_fire_in_range_only() {
        let _g = test_lock();
        clear();
        install(FaultPlan::parse("pjrt_execute@3x2:error").unwrap());
        let fired_at: Vec<bool> = (1..=6).map(|_| hit(FaultSite::PjrtExecute).is_some()).collect();
        assert_eq!(fired_at, [false, false, true, true, false, false]);
        assert_eq!(fired(FaultSite::PjrtExecute), 2);
        clear();
        assert!(hit(FaultSite::PjrtExecute).is_none());
    }

    #[test]
    fn forever_window_and_site_isolation() {
        let _g = test_lock();
        clear();
        install(FaultPlan::parse("wire_write@2x0:error").unwrap());
        assert!(hit(FaultSite::WireWrite).is_none());
        for _ in 0..5 {
            assert_eq!(hit(FaultSite::WireWrite), Some(FaultKind::Error));
        }
        // other sites untouched
        assert!(hit(FaultSite::WireRead).is_none());
        assert!(failpoint(FaultSite::CkptRename).is_ok());
        clear();
    }

    #[test]
    fn failpoints_translate_kinds() {
        let _g = test_lock();
        clear();
        install(FaultPlan::parse("ckpt_rename@1:torn; wire_write@1:error").unwrap());
        // torn degrades to an error at a site that cannot tear
        assert!(failpoint(FaultSite::CkptRename).is_err());
        assert!(io_failpoint(FaultSite::WireWrite).is_err());
        clear();
    }

    #[test]
    fn torn_fraction_is_seeded_and_bounded() {
        let _g = test_lock();
        clear();
        install(FaultPlan {
            seed: 11,
            specs: Vec::new(),
        });
        let a = torn_fraction();
        assert!((0.25..0.75).contains(&a));
        install(FaultPlan {
            seed: 11,
            specs: Vec::new(),
        });
        assert_eq!(a, torn_fraction());
        clear();
        // no plan: deterministic midpoint
        assert_eq!(torn_fraction(), 0.5);
    }

    #[test]
    fn env_install_wins_over_config_spec() {
        let _g = test_lock();
        clear();
        std::env::set_var("REVFFN_FAULTS", "pjrt_transfer@1:error");
        let installed = install_from(Some("wire_read@1:error")).unwrap();
        std::env::remove_var("REVFFN_FAULTS");
        assert!(installed);
        assert!(hit(FaultSite::WireRead).is_none());
        assert_eq!(hit(FaultSite::PjrtTransfer), Some(FaultKind::Error));
        clear();

        // env unset: the config spec installs
        assert!(install_from(Some("wire_read@1:error")).unwrap());
        assert_eq!(hit(FaultSite::WireRead), Some(FaultKind::Error));
        clear();
        assert!(!install_from(None).unwrap());
    }
}
