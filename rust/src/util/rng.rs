//! Deterministic PRNG substrate (PCG32) — the offline build carries no
//! external `rand`; data generation, shuffling and the property-test
//! harness all run on this generator.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014): small state, excellent statistical
//! quality for simulation workloads, and trivially reproducible across
//! platforms (pure integer arithmetic).

/// PCG32 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seed deterministically; distinct seeds give independent streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut r = Rng { state: 0, inc: (seed << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        r.next_u32();
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, n)` (Lemire's method, unbiased enough for data gen).
    pub fn gen_range(&mut self, range: std::ops::Range<usize>) -> usize {
        let n = (range.end - range.start) as u64;
        debug_assert!(n > 0);
        range.start + (self.next_u64() % n) as usize
    }

    /// Inclusive variant `[lo, hi]`.
    pub fn gen_range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range(lo..hi + 1)
    }

    pub fn gen_u32_range(&mut self, range: std::ops::Range<u32>) -> u32 {
        range.start + self.next_u32() % (range.end - range.start)
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0..i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(0..xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..1000 {
            let v = r.gen_range(3..17);
            assert!((3..17).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::seed_from_u64(9);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
