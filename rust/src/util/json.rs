//! Minimal JSON parser + writer (substrate — the build is fully offline,
//! so serde is implemented in-crate; see DESIGN.md §Inventory S17).
//!
//! Supports the full JSON grammar needed by the artifact manifests and
//! run configs: objects, arrays, strings (with escapes), numbers, bools,
//! null. Numbers are kept as f64 (manifest values are well within 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------------
    // Typed accessors
    // ------------------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest parsing).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Parse(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => m.get_or(),
            _ => None,
        }
    }

    // typed + named error helpers -------------------------------------

    pub fn str_of(&self, key: &str) -> Result<String> {
        self.req(key)?
            .as_str()
            .map(|s| s.to_string())
            .ok_or_else(|| Error::Parse(format!("key {key:?} is not a string")))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Parse(format!("key {key:?} is not a number")))
    }

    pub fn u64_of(&self, key: &str) -> Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| Error::Parse(format!("key {key:?} is not a number")))
    }

    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Parse(format!("key {key:?} is not a number")))
    }

    pub fn bool_of(&self, key: &str) -> Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| Error::Parse(format!("key {key:?} is not a bool")))
    }

    pub fn arr_of(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| Error::Parse(format!("key {key:?} is not an array")))
    }

    /// Array of usize (shapes).
    pub fn usize_vec_of(&self, key: &str) -> Result<Vec<usize>> {
        self.arr_of(key)?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Parse(format!("{key:?}: non-numeric element")))
            })
            .collect()
    }
}

// a tiny helper so as_obj above compiles cleanly
trait GetOr {
    fn get_or(&self) -> Option<&Self>;
}
impl GetOr for BTreeMap<String, Json> {
    fn get_or(&self) -> Option<&Self> {
        Some(self)
    }
}

// ----------------------------------------------------------------------
// Parsing
// ----------------------------------------------------------------------

/// Maximum object/array nesting. The parser recurses once per level, so
/// unbounded depth lets a hostile document (e.g. `[[[[…`) overflow the
/// stack of whatever thread is parsing — on the serve plane that is a
/// connection-handler thread fed straight from the wire. 128 is far past
/// any manifest or config this repo writes and well inside the default
/// thread stack.
pub const MAX_DEPTH: usize = 128;

pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0, depth: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(Error::Parse(format!("trailing data at byte {}", p.i)));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(Error::Parse(format!(
                "nesting deeper than {MAX_DEPTH} levels at byte {}",
                self.i
            )));
        }
        Ok(())
    }

    fn exit(&mut self) {
        self.depth -= 1;
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Parse("unexpected end of input".into()))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            return Err(Error::Parse(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char, self.i, self.b[self.i] as char
            )));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Parse(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.enter()?;
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            self.exit();
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    self.exit();
                    return Ok(Json::Obj(m));
                }
                c => return Err(Error::Parse(format!("expected , or }} found {:?}", c as char))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.enter()?;
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            self.exit();
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    self.exit();
                    return Ok(Json::Arr(v));
                }
                c => return Err(Error::Parse(format!("expected , or ] found {:?}", c as char))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Parse("truncated \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|e| Error::Parse(e.to_string()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| Error::Parse(e.to_string()))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(Error::Parse("bad escape".into())),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] >= 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| Error::Parse(e.to_string()))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| Error::Parse(e.to_string()))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Parse(format!("bad number {text:?}: {e}")))
    }
}

// ----------------------------------------------------------------------
// Lazy path extraction (no tree allocation)
// ----------------------------------------------------------------------
//
// The serve request hot path needs two or three scalar fields out of each
// wire line (`cmd`, `job`, a cursor); building the full `Json` tree per
// request allocates a `BTreeMap` + `String` per key just to throw them
// away. `Json::get_path` scans the raw bytes instead: it decodes only the
// object keys it walks past and materializes only the one value at the
// requested path (for the hot path that is a short string or a number —
// effectively allocation-free).
//
// Agreement contract (property-tested in `tests/wire.rs`): for every
// input that `parse` accepts, `get_path(text, path)` returns exactly what
// walking the parsed tree with `Json::get` would — including duplicate-
// key last-wins semantics and the `MAX_DEPTH` cap along the traversed
// spine. On inputs `parse` rejects, `get_path` never panics and may
// return anything (it does not validate the parts of the document it
// skips — that is the point).

impl Json {
    /// Lazily extract the value at `path` from raw JSON text.
    ///
    /// `Ok(None)` means a path step was missing or the value there was
    /// not an object; `Err` means the scanned spine was malformed. An
    /// empty path parses and returns the whole document.
    pub fn get_path(text: &str, path: &[&str]) -> Result<Option<Json>> {
        if path.is_empty() {
            return parse(text).map(Some);
        }
        let mut s = Scan { b: text.as_bytes(), i: 0, depth: 0 };
        for (step, key) in path.iter().enumerate() {
            s.ws();
            if s.peek()? != b'{' {
                return Ok(None);
            }
            s.i += 1;
            s.depth += 1;
            if s.depth > MAX_DEPTH {
                return Err(Error::Parse(format!(
                    "nesting deeper than {MAX_DEPTH} levels at byte {}",
                    s.i
                )));
            }
            // Scan every member: duplicate keys must resolve last-wins,
            // exactly like `BTreeMap::insert` does in the full parser.
            let mut found: Option<usize> = None;
            s.ws();
            if s.peek()? == b'}' {
                return Ok(None);
            }
            loop {
                s.ws();
                let k = s.key()?;
                s.ws();
                if s.peek()? != b':' {
                    return Err(Error::Parse(format!("expected ':' at byte {}", s.i)));
                }
                s.i += 1;
                s.ws();
                if k == *key {
                    found = Some(s.i);
                }
                s.skip_value()?;
                s.ws();
                match s.peek()? {
                    b',' => s.i += 1,
                    b'}' => {
                        s.i += 1;
                        break;
                    }
                    c => {
                        return Err(Error::Parse(format!(
                            "expected , or }} found {:?}",
                            c as char
                        )))
                    }
                }
            }
            match found {
                None => return Ok(None),
                Some(at) if step + 1 == path.len() => {
                    // Materialize just this value, with the spine's depth
                    // so the cap matches what the full parser enforces.
                    let mut p = Parser { b: s.b, i: at, depth: s.depth };
                    return p.value().map(Some);
                }
                Some(at) => s.i = at,
            }
        }
        Ok(None)
    }

    /// `get_path` narrowed to a string; `None` on error/missing/mismatch.
    pub fn path_str(text: &str, path: &[&str]) -> Option<String> {
        match Self::get_path(text, path) {
            Ok(Some(Json::Str(s))) => Some(s),
            _ => None,
        }
    }

    /// `get_path` narrowed to a number; `None` on error/missing/mismatch.
    pub fn path_f64(text: &str, path: &[&str]) -> Option<f64> {
        match Self::get_path(text, path) {
            Ok(Some(Json::Num(n))) => Some(n),
            _ => None,
        }
    }

    /// `get_path` narrowed to a number, saturated to `u64` exactly like
    /// [`Json::as_u64`] (negative/NaN → 0, overflow → `u64::MAX`);
    /// `None` on error/missing/mismatch. The wire layer uses this so
    /// hostile numbers resolve identically on the lazy and full paths
    /// without a truncating cast at the call site (LN006).
    pub fn path_u64(text: &str, path: &[&str]) -> Option<u64> {
        match Self::get_path(text, path) {
            Ok(Some(n @ Json::Num(_))) => n.as_u64(),
            _ => None,
        }
    }

    /// `get_path` narrowed to a bool; `None` on error/missing/mismatch.
    pub fn path_bool(text: &str, path: &[&str]) -> Option<bool> {
        match Self::get_path(text, path) {
            Ok(Some(Json::Bool(b))) => Some(b),
            _ => None,
        }
    }
}

/// Byte scanner behind `get_path`: skips values without building them.
struct Scan<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Scan<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Parse("unexpected end of input".into()))
    }

    /// Decode an object key with the full parser's string routine, so
    /// escaped keys (`"cmd"`) compare equal to their decoded form.
    fn key(&mut self) -> Result<String> {
        let mut p = Parser { b: self.b, i: self.i, depth: self.depth };
        let s = p.string()?;
        self.i = p.i;
        Ok(s)
    }

    /// Skip one value without materializing it. Containers are skipped
    /// iteratively (bracket counting — no recursion, so hostile nesting
    /// cannot overflow the stack), but the depth cap is still enforced to
    /// mirror the full parser's refusal.
    fn skip_value(&mut self) -> Result<()> {
        self.ws();
        match self.peek()? {
            b'"' => self.skip_string(),
            b'{' | b'[' => {
                let mut d = 0usize;
                loop {
                    match self.peek()? {
                        b'{' | b'[' => {
                            d += 1;
                            if self.depth + d > MAX_DEPTH {
                                return Err(Error::Parse(format!(
                                    "nesting deeper than {MAX_DEPTH} levels at byte {}",
                                    self.i
                                )));
                            }
                            self.i += 1;
                        }
                        b'}' | b']' => {
                            d -= 1;
                            self.i += 1;
                            if d == 0 {
                                return Ok(());
                            }
                        }
                        b'"' => self.skip_string()?,
                        _ => self.i += 1,
                    }
                }
            }
            b',' | b':' | b'}' | b']' => {
                Err(Error::Parse(format!("expected a value at byte {}", self.i)))
            }
            _ => {
                // number or literal: consume to the next delimiter
                while self.i < self.b.len()
                    && !matches!(
                        self.b[self.i],
                        b',' | b'}' | b']' | b' ' | b'\t' | b'\n' | b'\r'
                    )
                {
                    self.i += 1;
                }
                Ok(())
            }
        }
    }

    fn skip_string(&mut self) -> Result<()> {
        if self.peek()? != b'"' {
            return Err(Error::Parse(format!("expected '\"' at byte {}", self.i)));
        }
        self.i += 1;
        loop {
            match self.peek()? {
                b'"' => {
                    self.i += 1;
                    return Ok(());
                }
                b'\\' => {
                    self.i += 1;
                    self.peek()?; // escaped byte must exist
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
    }
}

// ----------------------------------------------------------------------
// Writing
// ----------------------------------------------------------------------

/// Escape a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Compact serialization (`json.to_string()` via the blanket
/// `ToString`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str("\":");
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for writing objects field by field.
#[derive(Default)]
pub struct ObjBuilder {
    m: BTreeMap<String, Json>,
}

impl ObjBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn str(mut self, k: &str, v: impl Into<String>) -> Self {
        self.m.insert(k.into(), Json::Str(v.into()));
        self
    }

    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.m.insert(k.into(), Json::Num(v));
        self
    }

    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.m.insert(k.into(), Json::Bool(v));
        self
    }

    pub fn val(mut self, k: &str, v: Json) -> Self {
        self.m.insert(k.into(), v);
        self
    }

    pub fn build(self) -> Json {
        Json::Obj(self.m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
            "variant": "revffn_stage2",
            "io": {"n_params": 22, "trainable": [true, false], "opt_shapes": [[4, 8]]},
            "use_pallas": false,
            "n_params_total": 3200384,
            "nested": {"a": [1, 2.5, -3e2], "b": null}
        }"#;
        let j = parse(text).unwrap();
        assert_eq!(j.str_of("variant").unwrap(), "revffn_stage2");
        assert_eq!(j.req("io").unwrap().usize_of("n_params").unwrap(), 22);
        assert!(!j.bool_of("use_pallas").unwrap());
        assert_eq!(j.u64_of("n_params_total").unwrap(), 3_200_384);
        let shapes = j.req("io").unwrap().arr_of("opt_shapes").unwrap();
        assert_eq!(shapes[0].as_arr().unwrap()[1].as_usize().unwrap(), 8);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te".into());
        let text = j.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn unicode_passthrough() {
        let j = parse(r#""P↑ adapters — ↑""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "P↑ adapters — ↑");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} extra").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("{\"a\": }").is_err());
    }

    #[test]
    fn numbers_int_and_float() {
        let j = parse("[0, -5, 3.25, 1e3, 2E-2]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[1].as_f64().unwrap(), -5.0);
        assert_eq!(a[3].as_f64().unwrap(), 1000.0);
        assert!((a[4].as_f64().unwrap() - 0.02).abs() < 1e-12);
    }

    #[test]
    fn builder_produces_parseable_output() {
        let j = ObjBuilder::new()
            .str("name", "x")
            .num("v", 1.5)
            .bool("ok", true)
            .val("arr", Json::Arr(vec![Json::Num(1.0)]))
            .build();
        let round = parse(&j.to_string()).unwrap();
        assert_eq!(round.f64_of("v").unwrap(), 1.5);
    }

    #[test]
    fn deep_nesting() {
        let mut text = String::new();
        for _ in 0..50 {
            text.push('[');
        }
        text.push('1');
        for _ in 0..50 {
            text.push(']');
        }
        assert!(parse(&text).is_ok());
    }

    fn nested_arrays(depth: usize) -> String {
        let mut text = String::new();
        for _ in 0..depth {
            text.push('[');
        }
        text.push('1');
        for _ in 0..depth {
            text.push(']');
        }
        text
    }

    #[test]
    fn nesting_at_depth_limit_parses() {
        assert!(parse(&nested_arrays(MAX_DEPTH)).is_ok());
        // mixed object/array nesting also counts levels
        let mixed = format!("{{\"k\":{}}}", nested_arrays(MAX_DEPTH - 1));
        assert!(parse(&mixed).is_ok());
    }

    // ---- lazy path extraction ----------------------------------------

    /// Reference semantics: full parse, then walk with `get`.
    fn eager_path(text: &str, path: &[&str]) -> Option<Json> {
        let mut v = parse(text).ok()?;
        for key in path {
            v = v.get(key)?.clone();
        }
        Some(v)
    }

    #[test]
    fn get_path_extracts_scalars_without_full_parse() {
        let text = r#"{"cmd":"events","job":"job-3","after_seq":17,"follow":true}"#;
        assert_eq!(Json::path_str(text, &["cmd"]).unwrap(), "events");
        assert_eq!(Json::path_str(text, &["job"]).unwrap(), "job-3");
        assert_eq!(Json::path_f64(text, &["after_seq"]).unwrap(), 17.0);
        assert!(Json::path_bool(text, &["follow"]).unwrap());
        assert!(Json::path_str(text, &["missing"]).is_none());
    }

    #[test]
    fn get_path_walks_nested_objects() {
        let text = r#"{"a":{"b":{"c":[1,2,3]}},"z":0}"#;
        let got = Json::get_path(text, &["a", "b", "c"]).unwrap().unwrap();
        assert_eq!(got, parse("[1,2,3]").unwrap());
        assert_eq!(Json::get_path(text, &["a", "x"]).unwrap(), None);
        // walking through a non-object yields None, same as `get`
        assert_eq!(Json::get_path(text, &["z", "q"]).unwrap(), None);
    }

    #[test]
    fn get_path_duplicate_keys_last_wins_like_btreemap() {
        let text = r#"{"k":1,"k":2,"k":{"x":"last"}}"#;
        assert_eq!(
            Json::get_path(text, &["k"]).unwrap(),
            eager_path(text, &["k"])
        );
        assert_eq!(Json::path_str(text, &["k", "x"]).unwrap(), "last");
    }

    #[test]
    fn get_path_decodes_escaped_keys_and_skips_tricky_values() {
        // escaped key bytes must compare decoded; skipped values contain
        // braces and escaped quotes inside strings
        let text = r#"{"a":"{\"not\":1}","cmd":"yes","b":[{"]":"}"}]}"#;
        assert_eq!(Json::path_str(text, &["cmd"]).unwrap(), "yes");
        assert_eq!(Json::path_str(text, &["a"]).unwrap(), "{\"not\":1}");
    }

    #[test]
    fn get_path_respects_depth_cap_on_spine_and_skip() {
        let deep = format!("{{\"k\":{}}}", nested_arrays(MAX_DEPTH));
        assert!(Json::get_path(&deep, &["k"]).is_err());
        let skip_deep = format!("{{\"a\":{},\"k\":1}}", nested_arrays(MAX_DEPTH + 4));
        assert!(Json::get_path(&skip_deep, &["k"]).is_err());
        let ok = format!("{{\"k\":{}}}", nested_arrays(MAX_DEPTH - 1));
        assert!(Json::get_path(&ok, &["k"]).unwrap().is_some());
        // hostile depth far past the cap errors instead of overflowing
        assert!(Json::get_path(&nested_arrays(100_000), &["k"]).is_err());
    }

    #[test]
    fn get_path_agrees_with_parser_on_corpus_like_lines() {
        let cases = [
            r#"{}"#,
            r#"{"cmd":""}"#,
            r#"{"cmd":42}"#,
            r#"{"cmd":"status","job":" "}"#,
            r#"{"cmd":"submit","config":{"method":"revffn","eval_every":0}}"#,
            r#"{"cmd":"events","job":"job-0","from":-3}"#,
            r#"  { "cmd" : "status" }  "#,
            r#"[1,2,3]"#,
            r#""just a string""#,
            r#"null"#,
        ];
        for text in cases {
            for path in [&["cmd"][..], &["job"][..], &["config", "method"][..]] {
                assert_eq!(
                    Json::get_path(text, path).ok().flatten(),
                    eager_path(text, path),
                    "disagreement on {text:?} at {path:?}"
                );
            }
        }
    }

    #[test]
    fn nesting_beyond_depth_limit_errors() {
        let err = parse(&nested_arrays(MAX_DEPTH + 1)).unwrap_err();
        assert!(err.to_string().contains("nesting"), "got: {err}");
        // far beyond the limit must error, not overflow the stack
        assert!(parse(&nested_arrays(100_000)).is_err());
        // siblings at legal depth do not accumulate
        let wide = format!("[{}, {}]", nested_arrays(MAX_DEPTH - 1), nested_arrays(MAX_DEPTH - 1));
        assert!(parse(&wide).is_ok());
    }
}
