//! Property-test harness (proptest substitute for the offline build).
//!
//! `prop_check` drives a predicate with `n` randomized cases from the
//! in-crate PCG32; on failure it re-runs a simple halving shrink over the
//! case index's seed to report the smallest failing seed it can find.
//! Generators are plain closures over `Rng` — composable and explicit.

use crate::util::rng::Rng;

/// Run `n` random cases; panic with the failing seed on first failure.
pub fn prop_check<T: std::fmt::Debug>(
    name: &str,
    n: usize,
    base_seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    for case in 0..n {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Rng::seed_from_u64(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            // shrink: try lower-entropy seeds derived from this one
            let mut worst = (seed, format!("{input:?}"));
            for shrink in [seed / 2, seed / 4, base_seed, 0] {
                let mut r = Rng::seed_from_u64(shrink);
                let cand = gen(&mut r);
                if !prop(&cand) {
                    worst = (shrink, format!("{cand:?}"));
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {}): input = {}",
                worst.0, worst.1
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Rng;

    pub fn string(rng: &mut Rng, max_len: usize) -> String {
        let len = rng.gen_range(0..max_len + 1);
        (0..len)
            .map(|_| {
                // mixed ASCII + some multi-byte chars
                match rng.gen_range(0..10) {
                    0 => '✓',
                    1 => 'é',
                    _ => (rng.gen_range(0x20..0x7f) as u8) as char,
                }
            })
            .collect()
    }

    pub fn f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| (rng.gen_normal() as f32) * scale).collect()
    }

    pub fn i32_vec(rng: &mut Rng, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..len)
            .map(|_| lo + (rng.gen_range(0..(hi - lo) as usize)) as i32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        prop_check("reverse-involution", 50, 7,
            |rng| {
                let n = rng.gen_range(0..20);
                gen::i32_vec(rng, n, -5, 5)
            },
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                w == *v
            });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        prop_check("always-false", 3, 1, |rng| rng.next_u32(), |_| false);
    }
}
