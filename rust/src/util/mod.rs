//! In-crate substrates for the fully-offline build: JSON codec, PRNG,
//! CLI flag parser, bench-timing helpers, fault injection, the
//! sanctioned backoff/sleep helper, and a scratch-dir guard for tests.

pub mod bench;
pub mod faults;
pub mod flags;
pub mod json;
pub mod prop;
pub mod retry;
pub mod rng;

pub use flags::Flags;
pub use json::Json;
pub use rng::Rng;

/// RAII scratch directory for tests (tempfile substitute).
pub struct ScratchDir {
    pub path: std::path::PathBuf,
}

impl ScratchDir {
    pub fn new(tag: &str) -> std::io::Result<Self> {
        let pid = std::process::id();
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let path = std::env::temp_dir().join(format!("revffn-{tag}-{pid}-{t}"));
        std::fs::create_dir_all(&path)?;
        Ok(ScratchDir { path })
    }

    pub fn join(&self, name: &str) -> std::path::PathBuf {
        self.path.join(name)
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_dir_created_and_removed() {
        let p;
        {
            let d = ScratchDir::new("t").unwrap();
            p = d.path.clone();
            std::fs::write(d.join("x"), "y").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }
}
