//! Bench-harness substrate (criterion substitute for the offline build).
//!
//! Each `rust/benches/*.rs` is a `harness = false` binary using this
//! module: warmup + timed iterations, median/mean/stddev reporting, and
//! a uniform output format so `cargo bench` output reads like a table.

use std::time::Instant;

/// Timing summary over iterations.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: usize,
    pub mean_s: f64,
    /// p50.
    pub median_s: f64,
    pub p95_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn fmt_ms(&self) -> String {
        format!(
            "median {:.2} ms  p95 {:.2} ms  mean {:.2} ms ± {:.2}  (n={}, min {:.2}, max {:.2})",
            self.median_s * 1e3,
            self.p95_s * 1e3,
            self.mean_s * 1e3,
            self.stddev_s * 1e3,
            self.iters,
            self.min_s * 1e3,
            self.max_s * 1e3
        )
    }
}

/// Time `f` with `warmup` discarded runs and `iters` measured runs.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    summarize(&times)
}

/// Summarize raw per-iteration seconds.
pub fn summarize(times: &[f64]) -> Timing {
    let n = times.len().max(1);
    let mut sorted = times.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n as f64;
    let pick = |q: usize| sorted.get(n * q / 100).or(sorted.last()).copied().unwrap_or(0.0);
    Timing {
        iters: n,
        mean_s: mean,
        median_s: pick(50),
        p95_s: pick(95),
        stddev_s: var.sqrt(),
        min_s: sorted.first().copied().unwrap_or(0.0),
        max_s: sorted.last().copied().unwrap_or(0.0),
    }
}

/// Standard bench header so all `cargo bench` outputs align.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// One formatted result row.
pub fn row(label: &str, value: impl std::fmt::Display) {
    println!("{label:<34} {value}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_constant_series() {
        let t = summarize(&[0.5; 9]);
        assert_eq!(t.median_s, 0.5);
        assert!(t.stddev_s < 1e-12);
    }

    #[test]
    fn summarize_orders_min_max() {
        let t = summarize(&[0.3, 0.1, 0.2]);
        assert_eq!(t.min_s, 0.1);
        assert_eq!(t.max_s, 0.3);
        assert_eq!(t.median_s, 0.2);
        assert_eq!(t.p95_s, 0.3); // 3*95/100 = index 2
    }

    #[test]
    fn summarize_percentiles_large_series() {
        let times: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let t = summarize(&times);
        assert_eq!(t.median_s, 51.0); // index 50 of sorted 1..=100
        assert_eq!(t.p95_s, 96.0); // index 95
    }

    #[test]
    fn summarize_empty_is_safe() {
        let t = summarize(&[]);
        assert_eq!(t.median_s, 0.0);
        assert_eq!(t.p95_s, 0.0);
    }

    #[test]
    fn time_runs_the_closure() {
        let mut count = 0;
        let t = time(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(t.iters, 5);
    }
}
