//! The cooperative multi-run scheduler.
//!
//! One `Scheduler` owns one shared [`Device`] and drives every admitted
//! job's [`Run`] round-robin: each [`Scheduler::tick`] resumes the next
//! active job (re-pinning its params + moments as device buffers),
//! yields up to `quantum` [`StepEvent`]s from it, then — if another job
//! is waiting for the device — suspends it again (one lazy
//! `to_literals` sync releases the pinned buffers). Buffer↔literal
//! state sync is bit-exact (pinned by `tests/hotpath.rs`), so an
//! interleaved job computes exactly what it would have computed solo;
//! `tests/serve.rs` asserts the losses are bit-identical.
//!
//! Scheduling is deterministic given the submission order: admission is
//! strict FIFO (a queued job is never overtaken, even by a smaller
//! one), the round-robin order is the admission order, and the quantum
//! is fixed. Every yielded event is serialized onto the shared
//! [`Board`] (an `Arc<Mutex<_>>` the TCP handlers read), so the control
//! plane streams live NDJSON without touching the device thread.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::config::{PriceGeometry, RunConfig, ServeConfig};
use crate::coordinator::{TrainReport, Trainer};
use crate::engine::{Run, StepEvent};
use crate::error::{Error, Result};
use crate::memory::{Assumptions, Geometry};
use crate::runtime::pjrt::{Device, ProgramCache};
use crate::serve::admission::{self, Admission};
use crate::serve::protocol::{self, JobSnapshot, JobState};
use crate::util::json::Json;

/// Decision returned by [`Scheduler::submit`].
#[derive(Debug, Clone)]
pub struct SubmitOutcome {
    pub id: String,
    /// Admitted immediately (false = queued behind the budget, or the
    /// activation failed — `state` disambiguates).
    pub admitted: bool,
    pub peak_gb: f64,
    /// The job's state right after submission (`Running`, `Queued`, or
    /// `Failed` when activation errored).
    pub state: JobState,
}

/// Shared, lock-protected view of every job: snapshots, event logs, and
/// the global emission timeline. TCP handlers read this; only the
/// scheduler writes it.
#[derive(Debug)]
pub struct Board {
    pub jobs: Vec<JobView>,
    pub budget_gb: f64,
    pub committed_gb: f64,
    /// Job ids in event-emission order — the observable interleaving.
    pub timeline: Vec<String>,
}

impl Board {
    fn new(budget_gb: f64) -> Self {
        Board { jobs: Vec::new(), budget_gb, committed_gb: 0.0, timeline: Vec::new() }
    }

    /// Look a job up by id.
    pub fn job(&self, id: &str) -> Option<&JobView> {
        self.jobs.iter().find(|j| j.snap.id == id)
    }
}

/// One job's public state: snapshot + its NDJSON event log.
#[derive(Debug)]
pub struct JobView {
    pub snap: JobSnapshot,
    pub events: Vec<String>,
    pub report: Option<TrainReport>,
}

/// Scheduler-private job record.
struct Job {
    id: String,
    /// Present while queued; taken on activation.
    cfg: Option<RunConfig>,
    /// Present while running.
    run: Option<Run<Trainer>>,
    peak_gb: f64,
    seq: u64,
    state: JobState,
}

enum Quantum {
    Progress,
    Done,
    Failed(String),
}

pub struct Scheduler {
    device: Device,
    /// Compiled programs are shared across jobs: N concurrent jobs on
    /// the same variant compile it once.
    cache: ProgramCache,
    opts: ServeConfig,
    assume: Assumptions,
    admission: Admission,
    jobs: Vec<Job>,
    /// Round-robin order of admitted jobs (indices into `jobs`).
    active: VecDeque<usize>,
    /// FIFO admission queue (indices into `jobs`).
    waiting: VecDeque<usize>,
    board: Arc<Mutex<Board>>,
}

impl Scheduler {
    pub fn new(device: Device, opts: ServeConfig) -> Result<Self> {
        opts.validate()?;
        let assume = opts.assumptions()?;
        let board = Arc::new(Mutex::new(Board::new(opts.budget_gb)));
        Ok(Scheduler {
            device,
            cache: ProgramCache::new(),
            admission: Admission::new(opts.budget_gb),
            assume,
            opts,
            jobs: Vec::new(),
            active: VecDeque::new(),
            waiting: VecDeque::new(),
            board,
        })
    }

    /// The shared job board (snapshots + event logs + timeline).
    pub fn board(&self) -> Arc<Mutex<Board>> {
        self.board.clone()
    }

    /// Id the next submitted job will get — the single source of the
    /// id scheme (`submit` and the `out_dir` default both use it).
    fn next_job_id(&self) -> String {
        format!("job-{}", self.jobs.len())
    }

    /// Submit a job from its wire-format JSON config. Keys the config
    /// omits fall back to the serve defaults (`artifacts` → the serve
    /// artifact dir, `out_dir` → `<run_root>/<job-id>`).
    pub fn submit_json(&mut self, config: &Json, name: Option<String>) -> Result<SubmitOutcome> {
        let mut cfg = RunConfig::from_json(config)?;
        if config.get("artifacts").is_none() {
            cfg.artifacts = self.opts.artifacts.clone();
        }
        if config.get("out_dir").is_none() {
            cfg.out_dir = self.opts.run_root.join(self.next_job_id());
        }
        self.submit(cfg, name)
    }

    /// Submit a fully-formed job config: price it, then admit (FIFO) or
    /// queue it. A job pricing over the whole budget is rejected
    /// outright — it could never run.
    pub fn submit(&mut self, cfg: RunConfig, name: Option<String>) -> Result<SubmitOutcome> {
        cfg.validate()?;
        let geo = match self.opts.price_geometry {
            PriceGeometry::Qwen => Some(Geometry::qwen15_moe_a27b()),
            PriceGeometry::Manifest => None,
        };
        let priced = admission::price_job(&cfg.artifacts, cfg.method, self.assume, geo)?;
        if priced.peak_gb > self.opts.budget_gb {
            return Err(Error::Config(format!(
                "job prices {:.3} GB at {} geometry — over the whole {:.3} GB budget",
                priced.peak_gb, priced.geometry, self.opts.budget_gb
            )));
        }
        let idx = self.jobs.len();
        let id = self.next_job_id();
        let name = name.unwrap_or_else(|| id.clone());
        let method = cfg.method.name().to_string();
        self.jobs.push(Job {
            id: id.clone(),
            cfg: Some(cfg),
            run: None,
            peak_gb: priced.peak_gb,
            seq: 0,
            state: JobState::Queued,
        });
        {
            let mut board = self.board.lock().expect("board lock");
            board.jobs.push(JobView {
                snap: JobSnapshot {
                    id: id.clone(),
                    name,
                    method,
                    state: JobState::Queued,
                    peak_gb: priced.peak_gb,
                    steps_done: 0,
                    last_loss: None,
                    eval_loss: None,
                    events: 0,
                    error: None,
                },
                events: Vec::new(),
                report: None,
            });
        }
        // strict FIFO: never overtake an already-waiting job, even if
        // this one would fit the headroom
        let mut admitted = self.waiting.is_empty() && self.admission.try_admit(priced.peak_gb);
        if admitted {
            self.activate(idx);
            // activation can fail (missing variant dir, bad artifacts):
            // the reservation was already rolled back and the error is
            // on the board — the submit reply must not claim admission
            admitted = self.jobs[idx].state == JobState::Running;
        } else {
            self.waiting.push_back(idx);
        }
        self.sync_ledger();
        Ok(SubmitOutcome { id, admitted, peak_gb: priced.peak_gb, state: self.jobs[idx].state })
    }

    /// Cancel a job. `Ok(true)` if it was queued or running, `Ok(false)`
    /// if it had already reached a terminal state.
    pub fn cancel(&mut self, id: &str) -> Result<bool> {
        let idx = self
            .jobs
            .iter()
            .position(|j| j.id == id)
            .ok_or_else(|| Error::Config(format!("unknown job {id:?}")))?;
        match self.jobs[idx].state {
            JobState::Queued => {
                self.waiting.retain(|&i| i != idx);
                self.jobs[idx].cfg = None;
                self.set_state(idx, JobState::Cancelled, None);
                Ok(true)
            }
            JobState::Running => {
                self.active.retain(|&i| i != idx);
                // dropping the run releases its pinned buffers and
                // prefetch thread
                self.jobs[idx].run = None;
                self.admission.release(self.jobs[idx].peak_gb);
                self.set_state(idx, JobState::Cancelled, None);
                self.drain_waiting();
                Ok(true)
            }
            _ => Ok(false),
        }
    }

    /// Cancel every non-terminal job (server shutdown).
    pub fn cancel_all(&mut self) {
        for idx in 0..self.jobs.len() {
            if matches!(self.jobs[idx].state, JobState::Queued | JobState::Running) {
                let id = self.jobs[idx].id.clone();
                let _ = self.cancel(&id);
            }
        }
    }

    /// Jobs not yet in a terminal state.
    pub fn open_jobs(&self) -> usize {
        self.jobs.iter().filter(|j| !j.state.is_terminal()).count()
    }

    /// State of one job, if it exists.
    pub fn job_state(&self, id: &str) -> Option<JobState> {
        self.jobs.iter().find(|j| j.id == id).map(|j| j.state)
    }

    /// Drive one quantum of the next active job. Returns `false` when
    /// there is nothing to run (idle).
    pub fn tick(&mut self) -> Result<bool> {
        if self.active.is_empty() {
            self.drain_waiting();
        }
        let Some(idx) = self.active.pop_front() else {
            return Ok(false);
        };
        let mut run = self.jobs[idx].run.take().expect("running job holds a run");
        let mut outcome = Quantum::Progress;
        // resume: re-pin this job's state as device buffers for the
        // quantum (no-op when the job is not device-resident)
        if let Err(e) = run.resume() {
            outcome = Quantum::Failed(format!("resume: {e}"));
        } else {
            for _ in 0..self.opts.quantum {
                match run.step() {
                    Ok(Some(ev)) => self.emit(idx, &ev),
                    Ok(None) => {
                        outcome = Quantum::Done;
                        break;
                    }
                    Err(e) => {
                        outcome = Quantum::Failed(e.to_string());
                        break;
                    }
                }
            }
        }
        match outcome {
            Quantum::Progress => {
                // preempt: hand the device to the next job. When this
                // is the only active job, skip the suspend/resume churn
                // — state handoff is lossless either way.
                if !self.active.is_empty() {
                    if let Err(e) = run.suspend() {
                        drop(run);
                        self.finalize(idx, JobState::Failed, Some(format!("suspend: {e}")));
                        return Ok(true);
                    }
                }
                self.jobs[idx].run = Some(run);
                self.active.push_back(idx);
            }
            Quantum::Done => match run.finish() {
                Ok(report) => {
                    self.board.lock().expect("board lock").jobs[idx].report = Some(report);
                    self.finalize(idx, JobState::Finished, None);
                }
                Err(e) => self.finalize(idx, JobState::Failed, Some(e.to_string())),
            },
            Quantum::Failed(msg) => {
                drop(run);
                self.finalize(idx, JobState::Failed, Some(msg));
            }
        }
        Ok(true)
    }

    /// Drive until every submitted job reaches a terminal state
    /// (inline/testing entry; the server calls [`Scheduler::tick`]).
    pub fn run_until_idle(&mut self) -> Result<()> {
        while self.tick()? {}
        Ok(())
    }

    // ------------------------------------------------------------------

    fn activate(&mut self, idx: usize) {
        let cfg = self.jobs[idx].cfg.take().expect("queued job holds a config");
        match Trainer::with_cache(&self.device, self.cache.clone(), cfg)
            .and_then(Trainer::into_run)
        {
            Ok(run) => {
                self.jobs[idx].run = Some(run);
                self.set_state(idx, JobState::Running, None);
                self.active.push_back(idx);
            }
            Err(e) => {
                self.admission.release(self.jobs[idx].peak_gb);
                self.set_state(idx, JobState::Failed, Some(e.to_string()));
            }
        }
    }

    /// Terminal transition of an admitted job: record state, return its
    /// reservation, and admit whoever now fits (FIFO).
    fn finalize(&mut self, idx: usize, state: JobState, error: Option<String>) {
        self.admission.release(self.jobs[idx].peak_gb);
        self.set_state(idx, state, error);
        self.drain_waiting();
    }

    fn drain_waiting(&mut self) {
        while let Some(&idx) = self.waiting.front() {
            if !self.admission.try_admit(self.jobs[idx].peak_gb) {
                break;
            }
            self.waiting.pop_front();
            self.activate(idx);
        }
        self.sync_ledger();
    }

    fn set_state(&mut self, idx: usize, state: JobState, error: Option<String>) {
        self.jobs[idx].state = state;
        let mut board = self.board.lock().expect("board lock");
        board.jobs[idx].snap.state = state;
        if error.is_some() {
            board.jobs[idx].snap.error = error;
        }
        board.committed_gb = self.admission.committed_gb();
    }

    fn sync_ledger(&mut self) {
        self.board.lock().expect("board lock").committed_gb = self.admission.committed_gb();
    }

    /// Serialize one event onto the board (log + snapshot + timeline).
    fn emit(&mut self, idx: usize, ev: &StepEvent) {
        let job = &mut self.jobs[idx];
        let seq = job.seq;
        job.seq += 1;
        let id = job.id.clone();
        let line = protocol::event_json(&id, seq, ev).to_string();
        let mut board = self.board.lock().expect("board lock");
        let view = &mut board.jobs[idx];
        view.events.push(line);
        view.snap.events = seq + 1;
        match ev {
            StepEvent::Step(rec) => {
                view.snap.steps_done += 1;
                view.snap.last_loss = Some(rec.loss);
            }
            StepEvent::EvalPoint { eval_loss, .. } => view.snap.eval_loss = Some(*eval_loss),
            _ => {}
        }
        board.timeline.push(id);
    }
}
