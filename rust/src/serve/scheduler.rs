//! The cooperative multi-run scheduler.
//!
//! One `Scheduler` owns one shared [`Device`] and drives every admitted
//! job's [`Run`] round-robin: each [`Scheduler::tick`] resumes the next
//! active job (re-pinning its params + moments as device buffers),
//! yields up to `quantum` [`StepEvent`]s from it, then — if another job
//! is waiting for the device — suspends it again (one lazy
//! `to_literals` sync releases the pinned buffers). Buffer↔literal
//! state sync is bit-exact (pinned by `tests/hotpath.rs`), so an
//! interleaved job computes exactly what it would have computed solo;
//! `tests/serve.rs` asserts the losses are bit-identical.
//!
//! Dispatch is priority-scheduled, not FIFO. Every job carries a
//! scheduling class ([`Priority`]: `interactive` > `normal` > `batch`),
//! an optional deadline, and a tenant identity, and both decision
//! points honor them at quantum boundaries:
//!
//! * **Device time** (which active job runs next): highest class first
//!   — a newly admitted higher-class job overtakes a running
//!   lower-class one at the next quantum boundary, using the same
//!   suspend/resume handoff as ordinary preemption. Within a class,
//!   earliest deadline first (EDF; no deadline sorts last), then
//!   round-robin in admission order.
//! * **Admission** (which waiting job gets freed budget): highest class
//!   first; within a class the tenant with the lowest weighted service
//!   debt is preferred (see `admission::Tenants` — debt carries over,
//!   so a heavy tenant cannot starve others), then EDF, then submit
//!   order. A job whose tenant is at quota (`max_jobs` / `share_gb`)
//!   is skipped — other tenants admit past it — but a job blocked only
//!   by the *global* budget blocks everything behind it in the same
//!   order (no small-job overtake, so big jobs cannot starve).
//!
//! Scheduling stays deterministic given the submission order: all
//! ordering keys (class, deadline, debt, submit order) are fixed at
//! submit/admission time and the quantum is fixed. Every yielded event
//! is serialized onto the shared [`Board`] (an `Arc<Mutex<_>>` the TCP
//! handlers read), so the control plane streams live NDJSON without
//! touching the device thread.

use std::cmp::Ordering;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::checkpoint;
use crate::config::{PriceGeometry, RunConfig, ServeConfig};
use crate::coordinator::{TrainReport, Trainer};
use crate::engine::{Run, StepEvent};
use crate::error::{Error, Result};
use crate::memory::{Assumptions, Geometry};
use crate::obs::{self, registry};
use crate::runtime::pjrt::{Device, ProgramCache};
use crate::serve::admission::{self, Admission, TenantPolicy, Tenants};
use crate::serve::lock;
use crate::serve::protocol::{self, JobSnapshot, JobState, Priority};
use crate::serve::supervise::{HealthProbe, RetryPolicy, Supervision};
use crate::util::json::Json;
use crate::util::retry::{self, Backoff};

/// Nap while a due retry waits on budget or backoff (keeps
/// `run_until_idle` from busy-spinning between deadlines).
const RETRY_POLL: Duration = Duration::from_millis(5);

/// Decision returned by [`Scheduler::submit`].
#[derive(Debug, Clone)]
pub struct SubmitOutcome {
    pub id: String,
    /// Admitted immediately (false = queued behind the budget or a
    /// tenant quota, or the activation failed — `state`
    /// disambiguates).
    pub admitted: bool,
    pub peak_gb: f64,
    /// The job's state right after submission (`Running`, `Queued`, or
    /// `Failed` when activation errored).
    pub state: JobState,
    /// Scheduling class the job was accepted under.
    pub priority: Priority,
    /// Tenant the job is accounted to.
    pub tenant: String,
}

/// Scheduling metadata carried by a submit (wire fields `priority`,
/// `tenant`, `deadline_ms`).
#[derive(Debug, Clone, Default)]
pub struct SubmitMeta {
    pub priority: Priority,
    /// Quota-accounting identity; `None` = `"default"`.
    pub tenant: Option<String>,
    /// Within-class deadline, milliseconds from submit.
    pub deadline_ms: Option<u64>,
}

impl SubmitMeta {
    pub fn tenant_name(&self) -> &str {
        self.tenant.as_deref().unwrap_or("default")
    }
}

/// Shared, lock-protected view of every job: snapshots, event logs, and
/// the global emission timeline. TCP handlers read this; only the
/// scheduler writes it.
#[derive(Debug)]
pub struct Board {
    pub jobs: Vec<JobView>,
    pub budget_gb: f64,
    pub committed_gb: f64,
    /// Configured host-snapshot budget (0 = unbounded; see
    /// `ServeConfig::host_budget_gb`).
    pub host_budget_gb: f64,
    pub host_committed_gb: f64,
    /// Job ids in event-emission order — the observable interleaving.
    pub timeline: Vec<String>,
    /// Per-tenant weighted service debt (mirrors `admission::Tenants`;
    /// refreshed by the scheduler whenever ledgers move).
    pub tenant_debt: BTreeMap<String, f64>,
    /// Per-tenant deadline-miss counts (first detections only — a job
    /// counts once no matter how long it overruns).
    pub tenant_misses: BTreeMap<String, u64>,
}

impl Board {
    fn new(budget_gb: f64, host_budget_gb: f64) -> Self {
        Board {
            jobs: Vec::new(),
            budget_gb,
            committed_gb: 0.0,
            host_budget_gb,
            host_committed_gb: 0.0,
            timeline: Vec::new(),
            tenant_debt: BTreeMap::new(),
            tenant_misses: BTreeMap::new(),
        }
    }

    /// Look a job up by id.
    pub fn job(&self, id: &str) -> Option<&JobView> {
        self.jobs.iter().find(|j| j.snap.id == id)
    }
}

impl Default for Board {
    fn default() -> Self {
        Board::new(0.0, 0.0)
    }
}

/// A job's NDJSON event log as a capped ring buffer. One line per
/// `StepEvent` leaks memory on long-lived servers, so beyond `cap`
/// lines the oldest are evicted and `base` advances: line `i` of the
/// buffer carries event sequence number `base + i`. Subscribers whose
/// cursor is past the base still stream gap-free; a subscriber that
/// lagged behind an eviction is clamped forward to the base (see
/// [`EventLog::lines_from`]).
#[derive(Debug)]
pub struct EventLog {
    lines: VecDeque<String>,
    base: u64,
    cap: usize,
}

impl EventLog {
    /// `cap` lines retained (0 = unbounded).
    pub fn new(cap: usize) -> Self {
        Self::with_base(cap, 0)
    }

    /// Ring starting at sequence `base` — a resumed job continues its
    /// predecessor's numbering, so followers never see seq reset.
    pub fn with_base(cap: usize, base: u64) -> Self {
        EventLog { lines: VecDeque::new(), base, cap }
    }

    pub fn push(&mut self, line: String) {
        self.lines.push_back(line);
        if self.cap > 0 {
            while self.lines.len() > self.cap {
                self.lines.pop_front();
                self.base += 1;
            }
        }
    }

    /// Sequence number of the oldest retained line.
    pub fn base(&self) -> u64 {
        self.base
    }

    /// Total events ever pushed (= the next sequence number).
    pub fn total(&self) -> u64 {
        self.base + self.lines.len() as u64
    }

    /// Lines from sequence `seq` on, plus the sequence number the
    /// returned slice actually starts at (clamped forward to the base
    /// when `seq` points into the evicted region).
    pub fn lines_from(&self, seq: u64) -> (Vec<String>, u64) {
        self.page_from(seq, usize::MAX)
    }

    /// One keyset page: at most `limit` lines from sequence `seq` on,
    /// plus the clamped start sequence. This is what the `events` verb
    /// serves — bounding the copy made under the board lock is the
    /// backpressure: a lagging follower costs one page per request, not
    /// a full ring replay.
    pub fn page_from(&self, seq: u64, limit: usize) -> (Vec<String>, u64) {
        let start = seq.max(self.base);
        let idx = (start - self.base) as usize;
        let lines = if idx >= self.lines.len() {
            Vec::new()
        } else {
            self.lines.iter().skip(idx).take(limit).cloned().collect()
        };
        (lines, start)
    }

    /// All retained lines, oldest first (tests, status dumps).
    pub fn to_vec(&self) -> Vec<String> {
        self.lines.iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }
}

/// One job's public state: snapshot + its NDJSON event log.
#[derive(Debug)]
pub struct JobView {
    pub snap: JobSnapshot,
    pub events: EventLog,
    pub report: Option<TrainReport>,
}

/// Scheduler-private job record.
struct Job {
    id: String,
    /// The job's config — activation clones from it (cold path), and
    /// resume re-prices and re-activates from it after the job fails
    /// or is cancelled.
    cfg: RunConfig,
    name: String,
    /// Present while running.
    run: Option<Run<Trainer>>,
    /// Checkpoint to restore on activation (resume / recovery path).
    resume_from: Option<std::path::PathBuf>,
    peak_gb: f64,
    /// Host-side snapshot reservation (see `PricedJob::host_gb`).
    host_gb: f64,
    seq: u64,
    state: JobState,
    /// Supervised-recovery record: attempts, failure chain, deadline.
    sup: Supervision,
    /// Scheduling class (dispatch + admission ordering).
    priority: Priority,
    /// Quota-accounting identity.
    tenant: String,
    /// Absolute within-class deadline (EDF key); `None` sorts last.
    deadline: Option<Instant>,
    /// The requested relative deadline, kept for snapshots/persistence.
    deadline_ms: Option<u64>,
}

enum Quantum {
    Progress,
    Done,
    Failed(String),
}

pub struct Scheduler {
    device: Device,
    /// Compiled programs are shared across jobs: N concurrent jobs on
    /// the same variant compile it once.
    cache: ProgramCache,
    opts: ServeConfig,
    assume: Assumptions,
    admission: Admission,
    jobs: Vec<Job>,
    /// Admitted jobs (indices into `jobs`); each tick picks the best
    /// dispatch candidate (class, deadline, then this queue's order —
    /// which round-robins because finished quanta push_back).
    active: VecDeque<usize>,
    /// Admission queue (indices into `jobs`), ordered at drain time by
    /// class, tenant debt, deadline, then submit order.
    waiting: VecDeque<usize>,
    /// Per-tenant quota ledgers + weighted-deficit fairness state.
    tenants: Tenants,
    board: Arc<Mutex<Board>>,
    /// Supervised-retry policy (docs/ROBUSTNESS.md).
    policy: RetryPolicy,
    /// Shared backoff jitter stream for retry delays.
    backoff: Backoff,
    /// Device-health probe gating supervised re-admission.
    probe: HealthProbe,
}

impl Scheduler {
    pub fn new(device: Device, opts: ServeConfig) -> Result<Self> {
        opts.validate()?;
        let assume = opts.assumptions()?;
        let board = Arc::new(Mutex::new(Board::new(opts.budget_gb, opts.host_budget_gb)));
        let host_budget =
            if opts.host_budget_gb > 0.0 { opts.host_budget_gb } else { f64::INFINITY };
        let policy = RetryPolicy::from_serve(&opts);
        let backoff = Backoff::new(policy.base_ms, policy.max_ms, 0xb0ff);
        let mut tenants = Tenants::new(TenantPolicy {
            max_jobs: opts.tenant_max_jobs,
            share_gb: opts.tenant_share_gb,
            weight: 1.0,
        });
        for t in &opts.tenants {
            tenants.set_policy(
                &t.name,
                TenantPolicy { max_jobs: t.max_jobs, share_gb: t.share_gb, weight: t.weight },
            );
        }
        Ok(Scheduler {
            device,
            cache: ProgramCache::new(),
            admission: Admission::with_host_budget(opts.budget_gb, host_budget),
            assume,
            opts,
            jobs: Vec::new(),
            active: VecDeque::new(),
            waiting: VecDeque::new(),
            tenants,
            board,
            policy,
            backoff,
            probe: HealthProbe::new(),
        })
    }

    /// The shared job board (snapshots + event logs + timeline).
    pub fn board(&self) -> Arc<Mutex<Board>> {
        self.board.clone()
    }

    /// Id the next submitted job will get — the single source of the
    /// id scheme (`submit` and the `out_dir` default both use it).
    fn next_job_id(&self) -> String {
        format!("job-{}", self.jobs.len())
    }

    /// Submit a job from its wire-format JSON config. Keys the config
    /// omits fall back to the serve defaults (`artifacts` → the serve
    /// artifact dir, `out_dir` → a fresh directory under `run_root`).
    pub fn submit_json(
        &mut self,
        config: &Json,
        name: Option<String>,
        meta: SubmitMeta,
    ) -> Result<SubmitOutcome> {
        let mut cfg = RunConfig::from_json(config)?;
        if config.get("artifacts").is_none() {
            cfg.artifacts = self.opts.artifacts.clone();
        }
        if config.get("out_dir").is_none() {
            cfg.out_dir = self.fresh_out_dir();
        }
        // serve jobs snapshot periodically by default so they stay
        // recoverable — but only on true omission: an explicit
        // `"checkpoint_every": 0` is an opt-out (each snapshot is a
        // full-state device→host download plus a full-model write)
        if config.get("checkpoint_every").is_none() {
            cfg.checkpoint_every = self.opts.checkpoint_every;
        }
        self.submit_with(cfg, name, meta)
    }

    /// A default `out_dir` that no other job — from this server life or
    /// a previous one — is using. Job ids renumber from 0 every server
    /// life, so `<run_root>/<job-id>` alone can collide with a leftover
    /// directory whose snapshots/marker belong to an older job; probing
    /// for an unused directory keeps checkpoint streams from ever
    /// interleaving across jobs.
    fn fresh_out_dir(&self) -> std::path::PathBuf {
        let id = self.next_job_id();
        let base = self.opts.run_root.join(&id);
        if !base.exists() {
            return base;
        }
        let mut k = 1u64;
        loop {
            let cand = self.opts.run_root.join(format!("{id}-{k}"));
            if !cand.exists() {
                return cand;
            }
            k += 1;
        }
    }

    /// Submit a fully-formed job config at default scheduling metadata
    /// (`normal` class, `default` tenant, no deadline): price it, then
    /// admit or queue it. A job pricing over the whole budget is
    /// rejected outright — it could never run.
    pub fn submit(&mut self, cfg: RunConfig, name: Option<String>) -> Result<SubmitOutcome> {
        self.submit_inner(cfg, name, None, SubmitMeta::default())
    }

    /// [`Scheduler::submit`] with explicit scheduling metadata.
    pub fn submit_with(
        &mut self,
        cfg: RunConfig,
        name: Option<String>,
        meta: SubmitMeta,
    ) -> Result<SubmitOutcome> {
        self.submit_inner(cfg, name, None, meta)
    }

    /// Resubmit a `Failed` or `Cancelled` job from its latest periodic
    /// snapshot. The old job record stays terminal; the continuation
    /// runs as a NEW job (fresh id, same name and out_dir) that
    /// restores params + Adam moments + the data cursor before its
    /// first step, and whose event numbering continues where the
    /// original stream stopped.
    pub fn resume_job(&mut self, id: &str) -> Result<SubmitOutcome> {
        let job = self
            .jobs
            .iter()
            .find(|j| j.id == id)
            .ok_or_else(|| Error::Config(format!("unknown job {id:?}")))?;
        match job.state {
            JobState::Failed | JobState::Cancelled | JobState::Quarantined => {}
            other => {
                return Err(Error::Config(format!(
                    "job {id} is {}; only failed, cancelled, or quarantined jobs can resume",
                    other.name()
                )))
            }
        }
        let cfg = job.cfg.clone();
        let name = job.name.clone();
        // the continuation inherits the original's scheduling identity;
        // a relative deadline restarts from the resubmit
        let meta = SubmitMeta {
            priority: job.priority,
            tenant: Some(job.tenant.clone()),
            deadline_ms: job.deadline_ms,
        };
        let ckpt = checkpoint::latest_valid_checkpoint(&cfg.out_dir).ok_or_else(|| {
            Error::Config(format!(
                "job {id} has no periodic snapshot under {} — set checkpoint_every",
                cfg.out_dir.display()
            ))
        })?;
        self.submit_inner(cfg, Some(name), Some(ckpt), meta)
    }

    /// Rescan `run_root` for interrupted jobs (a persisted `job.json`
    /// plus at least one periodic snapshot) and resubmit each resuming
    /// from its latest checkpoint — how a restarted server gets its
    /// jobs back. Returns how many were recovered; unrecoverable
    /// directories are reported and skipped.
    pub fn recover(&mut self) -> usize {
        let Ok(entries) = std::fs::read_dir(&self.opts.run_root) else {
            return 0;
        };
        let mut dirs: Vec<_> = entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
        dirs.sort(); // deterministic recovery order
        let mut recovered = 0;
        for dir in dirs {
            let marker = dir.join("job.json");
            if !marker.exists() {
                continue;
            }
            let parsed = std::fs::read_to_string(&marker)
                .map_err(Error::Io)
                .and_then(|text| {
                    let j = crate::util::json::parse(&text)?;
                    let name = j.get("name").and_then(Json::as_str).map(str::to_string);
                    let cfg = RunConfig::from_json(
                        j.get("config").ok_or_else(|| {
                            Error::Parse("job.json lacks a config object".into())
                        })?,
                    )?;
                    // scheduling identity survives the restart; markers
                    // from before these fields existed recover at the
                    // defaults
                    let meta = SubmitMeta {
                        priority: j
                            .get("priority")
                            .and_then(Json::as_str)
                            .and_then(|p| Priority::parse(p).ok())
                            .unwrap_or_default(),
                        tenant: j.get("tenant").and_then(Json::as_str).map(str::to_string),
                        deadline_ms: j.get("deadline_ms").and_then(Json::as_u64),
                    };
                    Ok((name, cfg, meta))
                });
            let (name, cfg, meta) = match parsed {
                Ok(x) => x,
                Err(e) => {
                    eprintln!("[serve] not recovering {}: {e}", marker.display());
                    continue;
                }
            };
            // no snapshot yet (interrupted before the first cadence
            // hit, or snapshots disabled): restart the job from
            // scratch rather than silently losing it — an in-flight
            // job must come back one way or the other
            let ckpt = checkpoint::latest_valid_checkpoint(&cfg.out_dir);
            if ckpt.is_none() {
                eprintln!(
                    "[serve] {}: no usable snapshot — restarting from scratch",
                    marker.display()
                );
            }
            match self.submit_inner(cfg, name, ckpt, meta) {
                Ok(o) => {
                    let state = o.state.name();
                    eprintln!("[serve] recovered {} as {} ({state})", dir.display(), o.id);
                    recovered += 1;
                }
                Err(e) => eprintln!("[serve] could not recover {}: {e}", dir.display()),
            }
        }
        recovered
    }

    fn submit_inner(
        &mut self,
        cfg: RunConfig,
        name: Option<String>,
        resume_from: Option<std::path::PathBuf>,
        meta: SubmitMeta,
    ) -> Result<SubmitOutcome> {
        cfg.validate()?;
        let geo = match self.opts.price_geometry {
            PriceGeometry::Qwen => Some(Geometry::qwen15_moe_a27b()),
            PriceGeometry::Manifest => None,
        };
        let priced = if self.opts.price_from_hlo {
            admission::price_job_static(&cfg.artifacts, cfg.method, self.assume, geo)?
        } else {
            admission::price_job(&cfg.artifacts, cfg.method, self.assume, geo)?
        };
        if priced.peak_gb > self.opts.budget_gb {
            return Err(Error::Config(format!(
                "job prices {:.3} GB at {} geometry — over the whole {:.3} GB budget",
                priced.peak_gb, priced.geometry, self.opts.budget_gb
            )));
        }
        let tenant = meta.tenant_name().to_string();
        // a job pricing over its tenant's whole share could never be
        // admitted either — reject at submit, same as over-budget
        let share = self.tenants.policy(&tenant).share_gb;
        if share > 0.0 && priced.peak_gb > share * (1.0 + 1e-9) {
            return Err(Error::Config(format!(
                "job prices {:.3} GB — over tenant {tenant:?}'s whole {share:.3} GB share",
                priced.peak_gb
            )));
        }
        let idx = self.jobs.len();
        let id = self.next_job_id();
        let name = name.unwrap_or_else(|| id.clone());
        let method = cfg.method.name().to_string();
        // persist the job config next to its checkpoints so a restarted
        // server can find and resume it (recover()); removed again when
        // the job ends in a state with nothing left to recover
        self.persist_job_file(&cfg, &name, &meta)?;
        // a resumed job continues its predecessor's event numbering
        // (cursor-only read — no tensor payload is materialized here)
        let base_seq = resume_from
            .as_deref()
            .and_then(|p| checkpoint::load_cursor(p).ok().flatten())
            .map(|c| c.seq)
            .unwrap_or(0);
        self.jobs.push(Job {
            id: id.clone(),
            cfg,
            name: name.clone(),
            run: None,
            resume_from,
            peak_gb: priced.peak_gb,
            host_gb: priced.host_gb,
            seq: base_seq,
            state: JobState::Queued,
            sup: Supervision::default(),
            priority: meta.priority,
            tenant: tenant.clone(),
            deadline: meta.deadline_ms.map(|ms| obs::now() + Duration::from_millis(ms)),
            deadline_ms: meta.deadline_ms,
        });
        {
            let mut board = lock::board(&self.board);
            board.jobs.push(JobView {
                snap: JobSnapshot {
                    id: id.clone(),
                    name,
                    method,
                    state: JobState::Queued,
                    peak_gb: priced.peak_gb,
                    steps_done: 0,
                    last_loss: None,
                    eval_loss: None,
                    events: base_seq,
                    error: None,
                    attempts: 0,
                    retry_at: None,
                    priority: meta.priority,
                    tenant: tenant.clone(),
                    deadline_ms: meta.deadline_ms,
                    deadline_missed_by_ms: None,
                },
                events: EventLog::with_base(self.opts.event_log_cap, base_seq),
                report: None,
            });
        }
        // queue, then drain: the drain picks by (class, tenant debt,
        // deadline, submit order), so a higher-class submit overtakes
        // waiting lower-class jobs, while an equal-or-lower one cannot
        // jump the queue even if it would fit the headroom
        self.waiting.push_back(idx);
        self.drain_waiting();
        let state = self.jobs[idx].state;
        Ok(SubmitOutcome {
            id,
            admitted: state == JobState::Running,
            peak_gb: priced.peak_gb,
            state,
            priority: meta.priority,
            tenant,
        })
    }

    /// Write `<out_dir>/job.json` (`{"name": …, "config": {…}}` plus
    /// the scheduling identity) — the recovery marker `recover()` looks
    /// for.
    fn persist_job_file(&self, cfg: &RunConfig, name: &str, meta: &SubmitMeta) -> Result<()> {
        std::fs::create_dir_all(&cfg.out_dir)?;
        let mut b = crate::util::json::ObjBuilder::new()
            .str("name", name)
            .val("config", cfg.to_json())
            .str("priority", meta.priority.name())
            .str("tenant", meta.tenant_name());
        if let Some(d) = meta.deadline_ms {
            b = b.num("deadline_ms", d as f64);
        }
        let j = b.build();
        std::fs::write(cfg.out_dir.join("job.json"), format!("{j}\n"))?;
        Ok(())
    }

    /// Remove the recovery marker once nothing is left to recover.
    fn remove_job_file(&self, idx: usize) {
        let _ = std::fs::remove_file(self.jobs[idx].cfg.out_dir.join("job.json"));
    }

    /// Cancel a job. `Ok(true)` if it was queued, running, or waiting
    /// out a supervised retry; `Ok(false)`
    /// if it had already reached a terminal state. A user cancellation
    /// removes the job's recovery marker — it must not resurrect on the
    /// next server start (it stays resumable in-process via the
    /// `resume` verb while its snapshots exist).
    pub fn cancel(&mut self, id: &str) -> Result<bool> {
        self.cancel_impl(id, false)
    }

    /// Cancel every non-terminal job (server shutdown). Recovery
    /// markers stay on disk: a shutdown is a server-wide stop, not a
    /// per-job decision, so the next server life recovers these jobs
    /// from their latest snapshots.
    pub fn cancel_all(&mut self) {
        for idx in 0..self.jobs.len() {
            if matches!(
                self.jobs[idx].state,
                JobState::Queued | JobState::Running | JobState::Retrying
            ) {
                let id = self.jobs[idx].id.clone();
                let _ = self.cancel_impl(&id, true);
            }
        }
    }

    fn cancel_impl(&mut self, id: &str, keep_marker: bool) -> Result<bool> {
        let idx = self
            .jobs
            .iter()
            .position(|j| j.id == id)
            .ok_or_else(|| Error::Config(format!("unknown job {id:?}")))?;
        match self.jobs[idx].state {
            JobState::Queued => {
                self.waiting.retain(|&i| i != idx);
                self.set_state(idx, JobState::Cancelled, None);
                if !keep_marker {
                    self.remove_job_file(idx);
                }
                Ok(true)
            }
            JobState::Running => {
                self.active.retain(|&i| i != idx);
                // dropping the run releases its pinned buffers and
                // prefetch thread
                self.jobs[idx].run = None;
                self.release_job(idx);
                self.set_state(idx, JobState::Cancelled, None);
                if !keep_marker {
                    self.remove_job_file(idx);
                }
                self.drain_waiting();
                Ok(true)
            }
            JobState::Retrying => {
                // no reservation is held while a retry waits out its
                // backoff, so there is nothing to release or drain
                self.jobs[idx].sup.retry_at = None;
                self.set_state(idx, JobState::Cancelled, None);
                if !keep_marker {
                    self.remove_job_file(idx);
                }
                Ok(true)
            }
            _ => Ok(false),
        }
    }

    /// Jobs not yet in a terminal state.
    pub fn open_jobs(&self) -> usize {
        self.jobs.iter().filter(|j| !j.state.is_terminal()).count()
    }

    /// State of one job, if it exists.
    pub fn job_state(&self, id: &str) -> Option<JobState> {
        self.jobs.iter().find(|j| j.id == id).map(|j| j.state)
    }

    /// Drive one quantum of the next active job. Returns `false` when
    /// there is nothing to run (idle) — including no supervised retry
    /// waiting out its backoff.
    pub fn tick(&mut self) -> Result<bool> {
        let retry_wait = self.poll_retries();
        if self.active.is_empty() {
            self.drain_waiting();
        }
        // quantum-boundary preemption: the dispatch pick is by class
        // (then EDF, then round-robin), so a higher-class job admitted
        // since the last tick overtakes a running lower-class one here
        // — the suspend at the end of the previous quantum already
        // parked the loser's state as host literals
        let Some(pos) = self.pick_active() else {
            if let Some(d) = retry_wait {
                // a retry deadline is pending and the device is
                // otherwise idle: nap toward it so run_until_idle keeps
                // driving without busy-spinning
                retry::pause(d.min(RETRY_POLL));
                return Ok(true);
            }
            return Ok(false);
        };
        let Some(idx) = self.active.remove(pos) else {
            return Ok(false);
        };
        // invariant: an active job holds a run. If it somehow does not,
        // fail that one job instead of killing the scheduler thread (and
        // with it every other job on the device).
        let Some(mut run) = self.jobs[idx].run.take() else {
            self.fail_admitted(idx, "scheduler invariant: active job lost its run".into());
            return Ok(true);
        };
        let quantum_sp = obs::span(obs::Site::SchedQuantum);
        let mut outcome = Quantum::Progress;
        // resume: re-pin this job's state as device buffers for the
        // quantum (no-op when the job is not device-resident)
        let resumed = {
            let _sp = obs::span(obs::Site::SchedResume);
            run.resume()
        };
        if let Err(e) = resumed {
            outcome = Quantum::Failed(format!("resume: {e}"));
        } else {
            for _ in 0..self.opts.quantum {
                match run.step() {
                    Ok(Some(ev)) => self.emit(idx, &ev),
                    Ok(None) => {
                        outcome = Quantum::Done;
                        break;
                    }
                    Err(e) => {
                        outcome = Quantum::Failed(e.to_string());
                        break;
                    }
                }
            }
        }
        self.note_deadline_miss(idx, false);
        match outcome {
            Quantum::Progress => {
                // step watchdog: a quantum that blew through the
                // deadline means the job is wedged or starving its
                // peers — fail it (snapshots stay on disk) and release
                // the slot instead of letting it hold the device
                let deadline = self.opts.quantum_deadline_ms;
                if deadline > 0 {
                    let elapsed = quantum_sp.elapsed();
                    if elapsed > Duration::from_millis(deadline) {
                        registry::inc(registry::Counter::QuantumOverrun);
                        drop(run);
                        self.fail_admitted(
                            idx,
                            format!(
                                "watchdog: quantum ran {}ms against a {}ms deadline",
                                elapsed.as_millis(),
                                deadline
                            ),
                        );
                        return Ok(true);
                    }
                }
                // preempt: hand the device to the next job. When this
                // is the only active job, skip the suspend/resume churn
                // — state handoff is lossless either way.
                if !self.active.is_empty() {
                    let _sp = obs::span(obs::Site::SchedSuspend);
                    if let Err(e) = run.suspend() {
                        drop(run);
                        self.fail_admitted(idx, format!("suspend: {e}"));
                        return Ok(true);
                    }
                }
                self.jobs[idx].run = Some(run);
                self.active.push_back(idx);
            }
            Quantum::Done => match run.finish() {
                Ok(report) => {
                    lock::board(&self.board).jobs[idx].report = Some(report);
                    self.finalize(idx, JobState::Finished, None);
                }
                Err(e) => self.fail_admitted(idx, e.to_string()),
            },
            Quantum::Failed(msg) => {
                drop(run);
                self.fail_admitted(idx, msg);
            }
        }
        Ok(true)
    }

    /// Drive until every submitted job reaches a terminal state
    /// (inline/testing entry; the server calls [`Scheduler::tick`]).
    pub fn run_until_idle(&mut self) -> Result<()> {
        while self.tick()? {}
        Ok(())
    }

    // ------------------------------------------------------------------

    fn activate(&mut self, idx: usize) {
        let cfg = self.jobs[idx].cfg.clone();
        let resume_from = self.jobs[idx].resume_from.take();
        let mut built = self.build_run(cfg.clone(), resume_from.as_deref());
        // graceful degradation: an allocation-shaped failure at
        // admission time gets one more chance after the newest running
        // job parks its device buffers as host literals (it re-pins
        // lazily at its next quantum)
        if matches!(built, Err(Error::Xla(_)) | Err(Error::Layout(_))) {
            if let Some(victim) = self.suspend_newest_active() {
                eprintln!(
                    "[serve] activation of {} retried after suspending {victim} to host",
                    self.jobs[idx].id
                );
                built = self.build_run(cfg, resume_from.as_deref());
            }
        }
        match built {
            Ok(run) => {
                self.jobs[idx].run = Some(run);
                self.set_state(idx, JobState::Running, None);
                self.active.push_back(idx);
            }
            Err(e) => {
                self.release_job(idx);
                self.supervise_failure(idx, e.to_string());
            }
        }
    }

    /// Build (and optionally restore) the `Run` for one job config.
    fn build_run(
        &self,
        cfg: RunConfig,
        resume_from: Option<&std::path::Path>,
    ) -> Result<Run<Trainer>> {
        let mut run =
            Trainer::with_cache(&self.device, self.cache.clone(), cfg).and_then(Trainer::into_run)?;
        if let Some(path) = resume_from {
            let ckpt = checkpoint::load(path)?;
            run.restore(ckpt)?;
        }
        Ok(run)
    }

    /// Suspend the most recently admitted active job to host literals,
    /// releasing its pinned device buffers. Returns its id when one was
    /// actually suspended.
    fn suspend_newest_active(&mut self) -> Option<String> {
        let &victim = self.active.iter().max()?;
        let run = self.jobs[victim].run.as_mut()?;
        match run.suspend() {
            Ok(()) => Some(self.jobs[victim].id.clone()),
            Err(_) => None,
        }
    }

    /// Failure funnel for an admitted job (reservation held): release
    /// the reservation, route through supervision, then admit whoever
    /// the dispatch order now picks.
    fn fail_admitted(&mut self, idx: usize, msg: String) {
        self.release_job(idx);
        self.supervise_failure(idx, msg);
        self.drain_waiting();
    }

    /// Record a failure on a job whose reservation is already released:
    /// schedule a supervised retry with exponential backoff, or — with
    /// supervision off / the attempt budget spent — mark it `Failed` /
    /// `Quarantined`. The recovery marker stays in all three outcomes:
    /// each leaves snapshots worth bringing back (a server restart also
    /// resets the retry budget this way).
    fn supervise_failure(&mut self, idx: usize, msg: String) {
        self.jobs[idx].run = None;
        self.jobs[idx].sup.record(msg.clone());
        if !self.policy.enabled() {
            self.set_state(idx, JobState::Failed, Some(msg));
        } else if self.jobs[idx].sup.attempts <= self.policy.max_attempts {
            let delay = self.backoff.delay(self.jobs[idx].sup.attempts);
            self.jobs[idx].sup.retry_at = Some(obs::now() + delay);
            registry::inc(registry::Counter::Retries);
            self.set_state(idx, JobState::Retrying, Some(msg));
        } else {
            self.jobs[idx].sup.retry_at = None;
            registry::inc(registry::Counter::Quarantines);
            let chain = self.jobs[idx].sup.chain();
            self.set_state(idx, JobState::Quarantined, Some(chain));
        }
    }

    /// Re-activate supervised retries whose backoff deadline has
    /// passed: device-health probe first (a probe failure consumes an
    /// attempt — a dead device quarantines its jobs instead of spinning
    /// forever), then re-admission against the budget, then activation
    /// from the latest valid snapshot (none ⇒ a deterministic restart
    /// from scratch). Returns the shortest wait until a pending retry
    /// is due, if any job is still `Retrying`.
    fn poll_retries(&mut self) -> Option<Duration> {
        let now = obs::now();
        let mut wait: Option<Duration> = None;
        for idx in 0..self.jobs.len() {
            if self.jobs[idx].state != JobState::Retrying {
                continue;
            }
            if let Some(at) = self.jobs[idx].sup.retry_at {
                if at > now {
                    let d = at - now;
                    wait = Some(wait.map_or(d, |w| w.min(d)));
                    continue;
                }
            }
            let _sp = obs::span(obs::Site::SchedRetry);
            if let Err(e) = self.probe.check(&self.device) {
                self.supervise_failure(idx, format!("device health probe: {e}"));
                continue;
            }
            if !self.try_admit_job(idx) {
                // budget or tenant quota busy: hold the retry (no
                // attempt consumed) and check again next tick
                wait = Some(wait.map_or(RETRY_POLL, |w| w.min(RETRY_POLL)));
                continue;
            }
            self.jobs[idx].sup.retry_at = None;
            self.jobs[idx].resume_from =
                checkpoint::latest_valid_checkpoint(&self.jobs[idx].cfg.out_dir);
            self.activate(idx);
        }
        self.sync_ledger();
        wait
    }

    /// Terminal transition of an admitted job: record state, return its
    /// reservation, and admit whoever the dispatch order now picks.
    /// Failures no longer come through here (see
    /// [`Scheduler::fail_admitted`]), but the marker rule stays general:
    /// it survives any exit with something left to bring back.
    fn finalize(&mut self, idx: usize, state: JobState, error: Option<String>) {
        self.release_job(idx);
        self.set_state(idx, state, error);
        if state != JobState::Failed {
            self.remove_job_file(idx);
        }
        self.drain_waiting();
    }

    /// Admit waiting jobs while budget allows, picking each round by
    /// (class desc, tenant debt asc, deadline asc, submit order).
    /// Tenant-quota-blocked jobs are skipped — their tenant being at
    /// its cap must not block other tenants — but when the best
    /// *eligible* candidate fails the global budget the drain stops:
    /// nothing overtakes it, so a large job cannot be starved by
    /// smaller ones slipping past. Debt updates between rounds, so a
    /// burst from one tenant interleaves fairly with everyone else's
    /// queue even within a single drain.
    fn drain_waiting(&mut self) {
        loop {
            let Some(pos) = self.pick_waiting() else { break };
            let Some(&idx) = self.waiting.get(pos) else { break };
            if !self.try_admit_job(idx) {
                break;
            }
            self.waiting.remove(pos);
            self.activate(idx);
        }
        self.sync_ledger();
    }

    /// Position (in `waiting`) of the next admission candidate: the
    /// best-ordered waiting job whose tenant quota has room. `None`
    /// when every waiting job is quota-blocked (or none is waiting).
    fn pick_waiting(&self) -> Option<usize> {
        (0..self.waiting.len())
            .filter(|&p| {
                let j = &self.jobs[self.waiting[p]];
                self.tenants.admits(&j.tenant, j.peak_gb)
            })
            .min_by(|&pa, &pb| self.admission_order(self.waiting[pa], self.waiting[pb]))
    }

    /// Position (in `active`) of the next dispatch candidate: highest
    /// class, then EDF, then queue order (round-robin — a finished
    /// quantum pushes back).
    fn pick_active(&self) -> Option<usize> {
        (0..self.active.len()).min_by(|&pa, &pb| {
            self.dispatch_order(self.active[pa], self.active[pb]).then(pa.cmp(&pb))
        })
    }

    /// Device-time ordering between two jobs: class desc, deadline asc
    /// (None last). Ties are broken by the caller (queue position for
    /// dispatch, submit order for admission).
    fn dispatch_order(&self, a: usize, b: usize) -> Ordering {
        let (ja, jb) = (&self.jobs[a], &self.jobs[b]);
        class_deadline_cmp(
            (ja.priority, ja.deadline),
            (jb.priority, jb.deadline),
        )
    }

    /// Admission ordering: class desc, then tenant debt asc (the
    /// weighted-deficit fairness pick), then deadline asc, then submit
    /// order.
    fn admission_order(&self, a: usize, b: usize) -> Ordering {
        let (ja, jb) = (&self.jobs[a], &self.jobs[b]);
        jb.priority
            .rank()
            .cmp(&ja.priority.rank())
            .then_with(|| self.tenants.debt(&ja.tenant).total_cmp(&self.tenants.debt(&jb.tenant)))
            .then_with(|| deadline_cmp(ja.deadline, jb.deadline))
            .then_with(|| a.cmp(&b))
    }

    /// Reserve budget AND tenant quota for one job; charges the tenant
    /// ledger only when the global ledger admitted.
    fn try_admit_job(&mut self, idx: usize) -> bool {
        let (peak, host) = (self.jobs[idx].peak_gb, self.jobs[idx].host_gb);
        let tenant = self.jobs[idx].tenant.clone();
        if !self.tenants.admits(&tenant, peak) {
            return false;
        }
        if !self.admission.try_admit(peak, host) {
            return false;
        }
        self.tenants.charge(&tenant, peak);
        true
    }

    /// Return one admitted job's budget reservation and tenant share.
    fn release_job(&mut self, idx: usize) {
        let (peak, host) = (self.jobs[idx].peak_gb, self.jobs[idx].host_gb);
        let tenant = self.jobs[idx].tenant.clone();
        self.admission.release(peak, host);
        self.tenants.release(&tenant, peak);
    }

    fn set_state(&mut self, idx: usize, state: JobState, error: Option<String>) {
        self.jobs[idx].state = state;
        if state.is_terminal() {
            // terminal overwrite: the final figure replaces the
            // first-detection one so `status` reports the full overrun
            self.note_deadline_miss(idx, true);
        }
        let mut board = lock::board(&self.board);
        let snap = &mut board.jobs[idx].snap;
        snap.state = state;
        snap.attempts = u64::from(self.jobs[idx].sup.attempts);
        snap.retry_at =
            if state == JobState::Retrying { self.jobs[idx].sup.retry_at } else { None };
        if error.is_some() {
            snap.error = error;
        } else if state == JobState::Running {
            // a (re)activation clears the previous failure message —
            // `status` reports the current state, the failure chain is
            // preserved in the supervision record
            snap.error = None;
        }
        board.committed_gb = self.admission.committed_gb();
        board.host_committed_gb = self.admission.host_committed_gb();
        board.tenant_debt = self.tenants.debts().into_iter().collect();
    }

    fn sync_ledger(&mut self) {
        let mut board = lock::board(&self.board);
        board.committed_gb = self.admission.committed_gb();
        board.host_committed_gb = self.admission.host_committed_gb();
        board.tenant_debt = self.tenants.debts().into_iter().collect();
    }

    /// Deadline-miss accounting: once a job with a deadline is observed
    /// past it, record how far over it ran (`deadline_missed_by_ms` in
    /// its snapshot) and — on the first detection only — bump the global
    /// and per-tenant miss counters. Terminal transitions overwrite the
    /// figure so a finished job reports its final overrun.
    fn note_deadline_miss(&mut self, idx: usize, terminal: bool) {
        let Some(deadline) = self.jobs[idx].deadline else { return };
        let now = obs::now();
        if now <= deadline {
            return;
        }
        let missed_ms = (now - deadline).as_millis() as u64;
        let tenant = self.jobs[idx].tenant.clone();
        let mut board = lock::board(&self.board);
        let snap = &mut board.jobs[idx].snap;
        let first = snap.deadline_missed_by_ms.is_none();
        if first || terminal {
            snap.deadline_missed_by_ms = Some(missed_ms);
        }
        if first {
            registry::inc(registry::Counter::DeadlineMiss);
            *board.tenant_misses.entry(tenant).or_insert(0) += 1;
        }
    }

    /// Serialize one event onto the board (log + snapshot + timeline).
    fn emit(&mut self, idx: usize, ev: &StepEvent) {
        let job = &mut self.jobs[idx];
        let seq = job.seq;
        job.seq += 1;
        let id = job.id.clone();
        let line = protocol::event_json(&id, seq, ev).to_string();
        let mut board = lock::board(&self.board);
        let view = &mut board.jobs[idx];
        view.events.push(line);
        view.snap.events = seq + 1;
        match ev {
            StepEvent::Step(rec) => {
                view.snap.steps_done += 1;
                view.snap.last_loss = Some(rec.loss);
            }
            StepEvent::EvalPoint { eval_loss, .. } => view.snap.eval_loss = Some(*eval_loss),
            _ => {}
        }
        board.timeline.push(id);
    }
}

/// Earliest-deadline-first key: `None` (no deadline) sorts after every
/// real deadline.
fn deadline_cmp(a: Option<Instant>, b: Option<Instant>) -> Ordering {
    match (a, b) {
        (Some(x), Some(y)) => x.cmp(&y),
        (Some(_), None) => Ordering::Less,
        (None, Some(_)) => Ordering::Greater,
        (None, None) => Ordering::Equal,
    }
}

/// The dispatch key: higher class first, then EDF. Exposed as a free
/// function so the ordering is unit-testable without a device.
fn class_deadline_cmp(
    a: (Priority, Option<Instant>),
    b: (Priority, Option<Instant>),
) -> Ordering {
    b.0.rank().cmp(&a.0.rank()).then_with(|| deadline_cmp(a.1, b.1))
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- dispatch/admission ordering (device-free) -------------------

    fn at(ms: u64) -> Option<Instant> {
        // a shared epoch keeps the test's deadlines comparable
        use std::sync::OnceLock;
        static EPOCH: OnceLock<Instant> = OnceLock::new();
        let epoch = *EPOCH.get_or_init(Instant::now);
        Some(epoch + Duration::from_millis(ms))
    }

    #[test]
    fn higher_class_dispatches_first() {
        let hi = (Priority::Interactive, None);
        let lo = (Priority::Batch, at(1));
        assert_eq!(class_deadline_cmp(hi, lo), Ordering::Less, "class beats deadline");
        assert_eq!(class_deadline_cmp(lo, hi), Ordering::Greater);
        assert_eq!(
            class_deadline_cmp((Priority::Normal, None), (Priority::Batch, None)),
            Ordering::Less
        );
    }

    #[test]
    fn deadline_breaks_ties_within_class() {
        let soon = (Priority::Normal, at(10));
        let late = (Priority::Normal, at(10_000));
        let never = (Priority::Normal, None);
        assert_eq!(class_deadline_cmp(soon, late), Ordering::Less);
        assert_eq!(class_deadline_cmp(late, never), Ordering::Less, "any deadline beats none");
        assert_eq!(class_deadline_cmp(never, soon), Ordering::Greater);
        assert_eq!(class_deadline_cmp(never, never), Ordering::Equal, "ties fall to queue order");
    }

    #[test]
    fn admission_prefers_lowest_debt_tenant_within_class() {
        // simulate the drain's pick over a waiting queue: tenant "big"
        // has consumed service, "small" has not — same class, so the
        // deficit ordering must prefer "small" despite later submission
        let mut tenants = Tenants::default();
        tenants.charge("big", 8.0);
        tenants.release("big", 8.0); // idle, but debt carries over
        let debt_big = tenants.debt("big");
        let debt_small = tenants.debt("small");
        assert!(debt_small < debt_big);
        // and a quota-blocked tenant is not a candidate at all
        let mut capped = Tenants::new(TenantPolicy { max_jobs: 1, share_gb: 0.0, weight: 1.0 });
        capped.charge("t", 1.0);
        assert!(!capped.admits("t", 1.0));
        assert!(capped.admits("u", 1.0), "another tenant admits while t waits at quota");
    }

    #[test]
    fn quota_starvation_is_bounded_by_debt() {
        // a heavy tenant hammering the queue accrues debt with every
        // admission, so after K grants its debt exceeds the light
        // tenant's and the pick flips — the starvation bound
        let mut tenants = Tenants::default();
        let mut grants_before_flip = 0;
        tenants.charge("light", 1.0); // light got one unit once
        while tenants.debt("heavy") <= tenants.debt("light") {
            tenants.charge("heavy", 1.0);
            grants_before_flip += 1;
            assert!(grants_before_flip < 100, "debt must eventually order heavy last");
        }
        assert!(grants_before_flip <= 2, "flip must come after ~1 equal-sized grant");
    }

    #[test]
    fn event_log_uncapped_keeps_everything() {
        let mut log = EventLog::new(0);
        for i in 0..100 {
            log.push(format!("e{i}"));
        }
        assert_eq!(log.len(), 100);
        assert_eq!(log.base(), 0);
        assert_eq!(log.total(), 100);
        let (lines, start) = log.lines_from(97);
        assert_eq!(start, 97);
        assert_eq!(lines, vec!["e97", "e98", "e99"]);
    }

    #[test]
    fn event_log_evicts_oldest_and_advances_base() {
        let mut log = EventLog::new(4);
        for i in 0..10 {
            log.push(format!("e{i}"));
        }
        assert_eq!(log.len(), 4, "ring holds cap lines");
        assert_eq!(log.base(), 6, "six oldest evicted");
        assert_eq!(log.total(), 10, "total counts evicted lines too");
        assert_eq!(log.to_vec(), vec!["e6", "e7", "e8", "e9"]);
    }

    #[test]
    fn event_log_from_is_gap_free_after_eviction() {
        let mut log = EventLog::new(3);
        for i in 0..8 {
            log.push(format!("e{i}"));
        }
        // a subscriber that lagged into the evicted region is clamped
        // forward to the base — it never receives lines whose seq
        // numbers would skip around within the returned batch
        let (lines, start) = log.lines_from(0);
        assert_eq!(start, log.base());
        assert_eq!(lines, vec!["e5", "e6", "e7"]);
        // a caught-up subscriber reads exactly the tail
        let (lines, start) = log.lines_from(7);
        assert_eq!(start, 7);
        assert_eq!(lines, vec!["e7"]);
        // a cursor at the end gets nothing
        let (lines, start) = log.lines_from(8);
        assert_eq!(start, 8);
        assert!(lines.is_empty());
    }

    #[test]
    fn event_log_pages_clamp_lagging_cursors_and_chain() {
        let mut log = EventLog::new(5);
        for i in 0..12 {
            log.push(format!("e{i}"));
        }
        // base is now 7; a cursor deep in the evicted region clamps
        // forward and still only gets one bounded page
        let (lines, start) = log.page_from(1, 2);
        assert_eq!(start, 7);
        assert_eq!(lines, vec!["e7", "e8"]);
        // chaining pages via next_cursor = start + count reconstructs
        // exactly the sequence a full replay would deliver
        let mut cursor = 0u64;
        let mut replay = Vec::new();
        loop {
            let (page, start) = log.page_from(cursor, 2);
            if page.is_empty() {
                break;
            }
            cursor = start + page.len() as u64;
            replay.extend(page);
        }
        assert_eq!(replay, log.to_vec());
        assert_eq!(cursor, log.total());
        // limit 0 yields an empty page without moving anything
        let (page, start) = log.page_from(9, 0);
        assert!(page.is_empty());
        assert_eq!(start, 9);
    }

    #[test]
    fn event_log_with_base_continues_numbering() {
        let mut log = EventLog::with_base(0, 42);
        log.push("e42".into());
        assert_eq!(log.base(), 42);
        assert_eq!(log.total(), 43);
        let (lines, start) = log.lines_from(0);
        assert_eq!(start, 42, "pre-resume seqs live in the predecessor's log");
        assert_eq!(lines, vec!["e42"]);
    }
}
