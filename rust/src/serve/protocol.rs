//! The serve wire protocol: JSON-lines (NDJSON) over TCP.
//!
//! Every request and response is one JSON object per line. The verbs:
//!
//! * `{"cmd":"submit","config":{…RunConfig…},"name":"…",
//!   "priority":"interactive|normal|batch","tenant":"…",
//!   "deadline_ms":N}` →
//!   `{"ok":true,"job":"job-0","admitted":true,"peak_gb":…,
//!   "priority":…,"tenant":…,"state":…}`. Priority selects the
//!   scheduling class (default `normal`); within a class jobs order by
//!   earliest deadline (`deadline_ms`, relative to submit; absent =
//!   latest). `tenant` (default `"default"`) is the quota-accounting
//!   identity.
//! * `{"cmd":"status"}` / `{"cmd":"status","job":"job-0"}` → one
//!   status object with the budget ledger and per-job snapshots.
//! * `{"cmd":"events","job":"job-0","after_seq":C,"limit":N,
//!   "follow":false}` → a keyset-paginated page: up to `limit` event
//!   lines with `seq > C`, then a `{"page":true,…,"next_cursor":…}`
//!   footer — pass `next_cursor` back as the next `after_seq`.
//!   `follow:true` streams live in bounded batches and ends with a
//!   `{"job":…,"done":true,…}` terminator. The legacy inclusive `from`
//!   cursor is still accepted (`after_seq` wins when both appear).
//! * `{"cmd":"metrics"}` → `{"ok":true,"kind":"metrics",
//!   "steps_total":N,"body":"…"}` where `body` is the full telemetry
//!   state in Prometheus text exposition format (the registry plus the
//!   per-tenant/per-class scheduler families — docs/OBSERVABILITY.md).
//! * `{"cmd":"cancel","job":"job-0"}` → `{"ok":true,"cancelled":…}`.
//! * `{"cmd":"resume","job":"job-0"}` → resubmits a
//!   failed/cancelled/quarantined job from its latest periodic
//!   snapshot as a new job:
//!   `{"ok":true,"job":"job-3","resumed_from":"job-0","admitted":…}`.
//!
//! Plus `{"cmd":"shutdown"}` to stop the server (tests, smoke scripts).
//!
//! Everything (de)serializes through the in-crate `util::json` codec —
//! the wire format needs no dependency the build doesn't already carry.
//! The server dispatches requests through [`Request::from_line_fast`],
//! which lazily scans the raw bytes (`Json::get_path`) for the
//! scalar-only verbs and only builds a full tree for `submit` (whose
//! `config` subtree needs one anyway) or when the lazy scan comes up
//! short. Non-finite floats (the pre-pass's NaN eval loss) serialize as
//! JSON `null`, never as bare `NaN`.

use crate::engine::StepEvent;
use crate::error::{Error, Result};
use crate::util::json::{self, Json, ObjBuilder};

/// Lifecycle state of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Priced over the current headroom; waiting for budget (FIFO).
    Queued,
    /// Admitted and being driven by the scheduler.
    Running,
    Finished,
    Failed,
    Cancelled,
    /// Failed, but within the supervised-retry budget: waiting out its
    /// backoff delay before re-activation from the latest valid
    /// snapshot (docs/ROBUSTNESS.md).
    Retrying,
    /// Exhausted the retry budget; `error` carries the failure chain.
    /// Terminal for the scheduler, but `resume` accepts it.
    Quarantined,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Finished => "finished",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::Retrying => "retrying",
            JobState::Quarantined => "quarantined",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "queued" => Ok(JobState::Queued),
            "running" => Ok(JobState::Running),
            "finished" => Ok(JobState::Finished),
            "failed" => Ok(JobState::Failed),
            "cancelled" => Ok(JobState::Cancelled),
            "retrying" => Ok(JobState::Retrying),
            "quarantined" => Ok(JobState::Quarantined),
            other => Err(Error::Parse(format!("unknown job state {other:?}"))),
        }
    }

    /// No further events will be produced in this state. `Retrying` is
    /// NOT terminal — event followers keep waiting across a retry.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Finished | JobState::Failed | JobState::Cancelled | JobState::Quarantined
        )
    }
}

/// Scheduling class of a submitted job. Higher classes are dispatched
/// first at every quantum boundary; within a class, earliest deadline
/// wins and submit order breaks ties.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Throughput work: runs when nothing more urgent is runnable.
    Batch,
    /// The default class.
    #[default]
    Normal,
    /// Latency-sensitive work: overtakes running lower-class jobs at
    /// the next quantum boundary (preemption reuses suspend/resume).
    Interactive,
}

impl Priority {
    pub fn name(&self) -> &'static str {
        match self {
            Priority::Batch => "batch",
            Priority::Normal => "normal",
            Priority::Interactive => "interactive",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "batch" => Ok(Priority::Batch),
            "normal" => Ok(Priority::Normal),
            "interactive" => Ok(Priority::Interactive),
            other => Err(Error::Parse(format!("unknown priority {other:?}"))),
        }
    }

    /// Numeric class rank — larger runs first.
    pub fn rank(&self) -> u8 {
        match self {
            Priority::Batch => 0,
            Priority::Normal => 1,
            Priority::Interactive => 2,
        }
    }
}

/// One parsed control-plane request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Submit {
        config: Json,
        name: Option<String>,
        /// Scheduling class (wire default: `normal`).
        priority: Priority,
        /// Quota-accounting identity (wire default: `"default"`).
        tenant: Option<String>,
        /// Within-class deadline, milliseconds from submit. Absent =
        /// no deadline (orders after every job that has one).
        deadline_ms: Option<u64>,
    },
    Status {
        job: Option<String>,
    },
    Events {
        job: String,
        /// First sequence number to deliver (resolved cursor: the wire
        /// carries the exclusive `after_seq`, or the legacy inclusive
        /// `from`).
        from: u64,
        /// Page size cap; `None` = server default. The server clamps
        /// this to its configured maximum either way.
        limit: Option<u64>,
        follow: bool,
    },
    Cancel {
        job: String,
    },
    /// Resubmit a failed/cancelled/quarantined job from its latest
    /// checkpoint.
    Resume {
        job: String,
    },
    /// Telemetry scrape: the registry plus the scheduler's per-tenant
    /// and per-class families, rendered as Prometheus text.
    Metrics,
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Submit { config, name, priority, tenant, deadline_ms } => {
                let mut b = ObjBuilder::new().str("cmd", "submit").val("config", config.clone());
                if let Some(n) = name {
                    b = b.str("name", n.clone());
                }
                if *priority != Priority::default() {
                    b = b.str("priority", priority.name());
                }
                if let Some(t) = tenant {
                    b = b.str("tenant", t.clone());
                }
                if let Some(d) = deadline_ms {
                    b = b.num("deadline_ms", *d as f64);
                }
                b.build()
            }
            Request::Status { job } => {
                let mut b = ObjBuilder::new().str("cmd", "status");
                if let Some(j) = job {
                    b = b.str("job", j.clone());
                }
                b.build()
            }
            Request::Events { job, from, limit, follow } => {
                let mut b = ObjBuilder::new().str("cmd", "events").str("job", job.clone());
                if *from > 0 {
                    // exclusive keyset cursor: resume after seq from-1
                    b = b.num("after_seq", (*from - 1) as f64);
                }
                if let Some(n) = limit {
                    b = b.num("limit", *n as f64);
                }
                b.bool("follow", *follow).build()
            }
            Request::Cancel { job } => {
                ObjBuilder::new().str("cmd", "cancel").str("job", job.clone()).build()
            }
            Request::Resume { job } => {
                ObjBuilder::new().str("cmd", "resume").str("job", job.clone()).build()
            }
            Request::Metrics => ObjBuilder::new().str("cmd", "metrics").build(),
            Request::Shutdown => ObjBuilder::new().str("cmd", "shutdown").build(),
        }
    }

    pub fn from_json(j: &Json) -> Result<Request> {
        let cmd = j.str_of("cmd")?;
        match cmd.as_str() {
            "submit" => Ok(Request::Submit {
                config: j.get("config").cloned().unwrap_or_else(|| Json::Obj(Default::default())),
                name: j.get("name").and_then(Json::as_str).map(str::to_string),
                priority: match j.get("priority").and_then(Json::as_str) {
                    Some(p) => Priority::parse(p)?,
                    None => Priority::default(),
                },
                tenant: j.get("tenant").and_then(Json::as_str).map(str::to_string),
                deadline_ms: j.get("deadline_ms").and_then(Json::as_u64),
            }),
            "status" => Ok(Request::Status {
                job: j.get("job").and_then(Json::as_str).map(str::to_string),
            }),
            "events" => Ok(Request::Events {
                job: j.str_of("job")?,
                from: resolve_cursor(
                    j.get("after_seq").and_then(Json::as_u64),
                    j.get("from").and_then(Json::as_u64),
                ),
                limit: j.get("limit").and_then(Json::as_u64),
                follow: j.get("follow").and_then(Json::as_bool).unwrap_or(true),
            }),
            "cancel" => Ok(Request::Cancel { job: j.str_of("job")? }),
            "resume" => Ok(Request::Resume { job: j.str_of("job")? }),
            "metrics" => Ok(Request::Metrics),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(Error::Parse(format!("unknown cmd {other:?}"))),
        }
    }

    /// One NDJSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    pub fn from_line(line: &str) -> Result<Request> {
        Self::from_json(&json::parse(line.trim())?)
    }

    /// Hot-path parse: lazily scan the raw bytes for the scalar-only
    /// verbs (`status`/`events`/`cancel`/`resume`/`shutdown`) without
    /// building a `Json` tree, falling back to the full parser for
    /// `submit` (its `config` subtree needs a tree anyway), for unknown
    /// or malformed input (so error messages stay identical), and for
    /// any field the scan cannot settle. On every line the full parser
    /// accepts, this returns exactly what [`Request::from_line`] would
    /// (pinned by the wire property tests); on lines it rejects, the
    /// lazy path may still salvage a scalar verb whose scanned spine is
    /// well-formed — the fields the strict parser would have rejected
    /// were unused either way.
    pub fn from_line_fast(line: &str) -> Result<Request> {
        let t = line.trim();
        match Json::path_str(t, &["cmd"]).as_deref() {
            Some("status") => Ok(Request::Status { job: Json::path_str(t, &["job"]) }),
            Some("events") => match Json::path_str(t, &["job"]) {
                // job is required: let the full parser produce its error
                None => Self::from_line(line),
                Some(job) => Ok(Request::Events {
                    job,
                    from: resolve_cursor(
                        Json::path_u64(t, &["after_seq"]),
                        Json::path_u64(t, &["from"]),
                    ),
                    limit: Json::path_u64(t, &["limit"]),
                    follow: Json::path_bool(t, &["follow"]).unwrap_or(true),
                }),
            },
            Some("cancel") => match Json::path_str(t, &["job"]) {
                None => Self::from_line(line),
                Some(job) => Ok(Request::Cancel { job }),
            },
            Some("resume") => match Json::path_str(t, &["job"]) {
                None => Self::from_line(line),
                Some(job) => Ok(Request::Resume { job }),
            },
            Some("metrics") => Ok(Request::Metrics),
            Some("shutdown") => Ok(Request::Shutdown),
            _ => Self::from_line(line),
        }
    }
}

/// Resolve the events cursor: exclusive `after_seq` wins over the
/// legacy inclusive `from`; both absent = 0 (start of log). Both call
/// sites saturate through `Json::as_u64` / `Json::path_u64`, so hostile
/// numbers (negative, 1e308, NaN) resolve identically on the lazy and
/// full-parse paths.
fn resolve_cursor(after_seq: Option<u64>, from: Option<u64>) -> u64 {
    match (after_seq, from) {
        (Some(a), _) => a.saturating_add(1),
        (None, Some(f)) => f,
        (None, None) => 0,
    }
}

/// JSON number, or `null` when non-finite (NaN eval losses) — bare
/// `NaN` is not valid JSON and would corrupt the stream.
pub fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// Serialize one job `StepEvent` as an NDJSON event line. `seq` is the
/// job-local event sequence number (the `from` cursor of the `events`
/// verb indexes it).
pub fn event_json(job: &str, seq: u64, ev: &StepEvent) -> Json {
    let b = ObjBuilder::new().str("job", job).num("seq", seq as f64);
    match ev {
        StepEvent::PhaseStarted { phase, stage, label, steps, peak_lr, batch_size, seq_len } => b
            .str("type", "phase_started")
            .num("phase", *phase as f64)
            .num("stage", *stage as f64)
            .str("label", *label)
            .num("steps", *steps as f64)
            .val("peak_lr", num_or_null(*peak_lr as f64))
            .num("batch_size", *batch_size as f64)
            .num("seq_len", *seq_len as f64)
            .build(),
        StepEvent::Step(rec) => b
            .str("type", "step")
            .num("step", rec.step as f64)
            .num("stage", rec.stage as f64)
            .val("loss", num_or_null(rec.loss as f64))
            .val("lr", num_or_null(rec.lr as f64))
            .val("grad_norm", num_or_null(rec.grad_norm as f64))
            .val("router_aux", num_or_null(rec.router_aux as f64))
            .num("step_time_s", rec.step_time_s)
            .num("device_time_s", rec.device_time_s)
            .num("samples_per_s", rec.samples_per_s)
            .build(),
        StepEvent::EvalPoint { step, eval_loss } => b
            .str("type", "eval")
            .num("step", *step as f64)
            .val("eval_loss", num_or_null(*eval_loss as f64))
            .build(),
        StepEvent::PhaseFinished { phase, stage, eval_loss } => b
            .str("type", "phase_finished")
            .num("phase", *phase as f64)
            .num("stage", *stage as f64)
            .val("eval_loss", num_or_null(*eval_loss as f64))
            .build(),
    }
}

/// End-of-stream marker for the `events` verb (`follow:true` only — a
/// follower sees it once the job is terminal and fully drained).
pub fn done_json(job: &str, state: JobState, events: u64) -> Json {
    ObjBuilder::new()
        .str("job", job)
        .bool("done", true)
        .str("state", state.name())
        .num("events", events as f64)
        .build()
}

/// Page footer for a non-follow `events` request: `count` event lines
/// were delivered and `next_cursor` is the cursor for the next page —
/// pass it back as `from` verbatim, or equivalently pass the last
/// delivered line's `seq` as `after_seq` (`next_cursor` is always that
/// seq + 1; when `count` is 0 it echoes the request's resolved cursor,
/// so retrying with it is exact even at the start of the log).
/// `done:true` means the job is terminal and no event past this page
/// will ever exist — stop paging.
/// `dropped` counts event lines the ring evicted past this follower's
/// cursor before it read them — the page is gap-free from its clamped
/// start, but `gapped:true` tells the client the stream is no longer
/// complete (it also feeds `revffn_events_dropped_total`).
pub fn events_page_json(
    job: &str,
    count: u64,
    next_cursor: u64,
    state: JobState,
    done: bool,
    dropped: u64,
) -> Json {
    ObjBuilder::new()
        .str("job", job)
        .bool("page", true)
        .num("count", count as f64)
        .num("next_cursor", next_cursor as f64)
        .str("state", state.name())
        .bool("done", done)
        .bool("gapped", dropped > 0)
        .num("dropped", dropped as f64)
        .build()
}

/// Public snapshot of one job (the `status` verb's row).
#[derive(Debug, Clone)]
pub struct JobSnapshot {
    pub id: String,
    pub name: String,
    pub method: String,
    pub state: JobState,
    pub peak_gb: f64,
    /// Optimizer steps recorded so far.
    pub steps_done: u64,
    pub last_loss: Option<f32>,
    pub eval_loss: Option<f32>,
    /// Events produced so far (the `events` verb's cursor space).
    pub events: u64,
    /// Last failure — or, once quarantined, the whole failure chain.
    pub error: Option<String>,
    /// Supervised-retry failures so far (0 = never failed).
    pub attempts: u64,
    /// When the next supervised retry is due (`Retrying` only).
    pub retry_at: Option<std::time::Instant>,
    /// Scheduling class.
    pub priority: Priority,
    /// Quota-accounting identity.
    pub tenant: String,
    /// Requested deadline (ms from submit), if any.
    pub deadline_ms: Option<u64>,
    /// How far past its deadline the job has run, if it missed it: the
    /// first-detection figure while running, the final overrun once
    /// terminal. `None` = no deadline, or not (yet) missed.
    pub deadline_missed_by_ms: Option<u64>,
}

pub fn snapshot_json(s: &JobSnapshot) -> Json {
    let mut b = ObjBuilder::new()
        .str("id", s.id.clone())
        .str("name", s.name.clone())
        .str("method", s.method.clone())
        .str("state", s.state.name())
        .num("peak_gb", s.peak_gb)
        .num("steps_done", s.steps_done as f64)
        .val("last_loss", s.last_loss.map_or(Json::Null, |x| num_or_null(x as f64)))
        .val("eval_loss", s.eval_loss.map_or(Json::Null, |x| num_or_null(x as f64)))
        .num("events", s.events as f64)
        .num("attempts", s.attempts as f64)
        .str("priority", s.priority.name())
        .str("tenant", s.tenant.clone())
        .val(
            "deadline_ms",
            s.deadline_ms.map_or(Json::Null, |d| Json::Num(d as f64)),
        )
        .val(
            "deadline_missed_by_ms",
            s.deadline_missed_by_ms.map_or(Json::Null, |d| Json::Num(d as f64)),
        )
        .val(
            "next_retry_ms",
            s.retry_at.map_or(Json::Null, |at| {
                Json::Num(at.saturating_duration_since(crate::obs::now()).as_millis() as f64)
            }),
        );
    if let Some(e) = &s.error {
        b = b.str("error", e.clone());
    }
    b.build()
}

/// The full `status` response: device + host budget ledgers, the job
/// table, and per-tenant deadline-miss counts (tenants that never
/// missed are omitted). `host_budget_gb` is the configured value
/// (0 = unbounded).
pub fn status_json(
    jobs: &[JobSnapshot],
    budget_gb: f64,
    committed_gb: f64,
    host_budget_gb: f64,
    host_committed_gb: f64,
    tenant_misses: &[(String, u64)],
) -> Json {
    let mut misses = ObjBuilder::new();
    for (tenant, n) in tenant_misses {
        misses = misses.num(tenant, *n as f64);
    }
    ObjBuilder::new()
        .bool("ok", true)
        .num("budget_gb", budget_gb)
        .num("committed_gb", committed_gb)
        .num("host_budget_gb", host_budget_gb)
        .num("host_committed_gb", host_committed_gb)
        .val("tenant_deadline_misses", misses.build())
        .val("jobs", Json::Arr(jobs.iter().map(snapshot_json).collect()))
        .build()
}

pub fn ok_json() -> Json {
    ObjBuilder::new().bool("ok", true).build()
}

/// Response to the `metrics` verb: `steps_total` is surfaced as a JSON
/// number so shallow clients (the smoke script) need not parse the
/// Prometheus `body`.
pub fn metrics_json(steps_total: u64, body: &str) -> Json {
    ObjBuilder::new()
        .bool("ok", true)
        .str("kind", "metrics")
        .num("steps_total", steps_total as f64)
        .str("body", body)
        .build()
}

pub fn error_json(message: &str) -> Json {
    ObjBuilder::new().bool("ok", false).str("error", message).build()
}

/// Response to a successful `submit`. `state` disambiguates
/// `admitted:false` — `queued` will run later; `failed` never will
/// (activation errored; the `status` verb carries the error text).
/// Echoes the scheduling class and tenant the job was accounted under.
pub fn submitted_json(
    job: &str,
    admitted: bool,
    peak_gb: f64,
    state: JobState,
    priority: Priority,
    tenant: &str,
) -> Json {
    ObjBuilder::new()
        .bool("ok", true)
        .str("job", job)
        .bool("admitted", admitted)
        .num("peak_gb", peak_gb)
        .str("state", state.name())
        .str("priority", priority.name())
        .str("tenant", tenant)
        .build()
}

/// Response to a successful `resume`: the continuation's submit
/// outcome plus the id of the job it was resumed from.
pub fn resumed_json(
    resumed_from: &str,
    job: &str,
    admitted: bool,
    peak_gb: f64,
    state: JobState,
) -> Json {
    ObjBuilder::new()
        .bool("ok", true)
        .str("job", job)
        .str("resumed_from", resumed_from)
        .bool("admitted", admitted)
        .num("peak_gb", peak_gb)
        .str("state", state.name())
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::StepRecord;

    fn submit(config: &str, name: Option<&str>) -> Request {
        Request::Submit {
            config: json::parse(config).unwrap(),
            name: name.map(str::to_string),
            priority: Priority::default(),
            tenant: None,
            deadline_ms: None,
        }
    }

    #[test]
    fn requests_roundtrip_through_lines() {
        let cases = vec![
            submit(r#"{"method":"revffn","eval_every":0}"#, Some("job-a")),
            submit("{}", None),
            Request::Submit {
                config: json::parse("{}").unwrap(),
                name: Some("hot".into()),
                priority: Priority::Interactive,
                tenant: Some("team-a".into()),
                deadline_ms: Some(30_000),
            },
            Request::Status { job: None },
            Request::Status { job: Some("job-3".into()) },
            Request::Events { job: "job-0".into(), from: 17, limit: None, follow: false },
            Request::Events { job: "job-0".into(), from: 0, limit: Some(64), follow: true },
            Request::Cancel { job: "job-1".into() },
            Request::Resume { job: "job-2".into() },
            Request::Metrics,
            Request::Shutdown,
        ];
        for req in cases {
            let line = req.to_line();
            assert!(!line.contains('\n'), "one line per request");
            let back = Request::from_line(&line).unwrap();
            assert_eq!(back, req, "roundtrip failed for {line}");
            let fast = Request::from_line_fast(&line).unwrap();
            assert_eq!(fast, req, "fast-path disagreed on {line}");
        }
    }

    #[test]
    fn events_defaults_follow_and_from() {
        let r = Request::from_line(r#"{"cmd":"events","job":"job-0"}"#).unwrap();
        assert_eq!(
            r,
            Request::Events { job: "job-0".into(), from: 0, limit: None, follow: true }
        );
    }

    #[test]
    fn events_cursor_grammar() {
        // exclusive after_seq resolves to the next sequence number
        let r = Request::from_line(r#"{"cmd":"events","job":"j","after_seq":9}"#).unwrap();
        assert_eq!(r, Request::Events { job: "j".into(), from: 10, limit: None, follow: true });
        // legacy inclusive `from` still accepted
        let r = Request::from_line(r#"{"cmd":"events","job":"j","from":9}"#).unwrap();
        assert_eq!(r, Request::Events { job: "j".into(), from: 9, limit: None, follow: true });
        // after_seq wins when both appear
        let r =
            Request::from_line(r#"{"cmd":"events","job":"j","after_seq":4,"from":99}"#).unwrap();
        assert_eq!(r, Request::Events { job: "j".into(), from: 5, limit: None, follow: true });
        // hostile cursors saturate instead of wrapping
        let r =
            Request::from_line(r#"{"cmd":"events","job":"j","after_seq":1e308}"#).unwrap();
        assert!(matches!(r, Request::Events { from: u64::MAX, .. }));
    }

    #[test]
    fn submit_priority_grammar() {
        let r = Request::from_line(
            r#"{"cmd":"submit","config":{},"priority":"interactive","tenant":"t0","deadline_ms":500}"#,
        )
        .unwrap();
        match r {
            Request::Submit { priority, tenant, deadline_ms, .. } => {
                assert_eq!(priority, Priority::Interactive);
                assert_eq!(tenant.as_deref(), Some("t0"));
                assert_eq!(deadline_ms, Some(500));
            }
            other => panic!("wrong request {other:?}"),
        }
        // unknown class is a parse error, not a silent default
        assert!(
            Request::from_line(r#"{"cmd":"submit","config":{},"priority":"urgent"}"#).is_err()
        );
        assert!(Priority::Interactive.rank() > Priority::Normal.rank());
        assert!(Priority::Normal.rank() > Priority::Batch.rank());
        for p in [Priority::Batch, Priority::Normal, Priority::Interactive] {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn fast_path_agrees_with_full_parser_on_scalar_verbs() {
        let lines = [
            r#"{"cmd":"status"}"#,
            r#"{"cmd":"status","job":"job-0"}"#,
            r#"{"cmd":"events","job":"job-0","after_seq":17,"limit":32,"follow":false}"#,
            r#"{"cmd":"events","job":"job-0","from":-3}"#,
            r#"{"cmd":"cancel","job":"job-1"}"#,
            r#"{"cmd":"resume","job":"job-2"}"#,
            r#"{"cmd":"metrics"}"#,
            r#"{"cmd":"shutdown"}"#,
            r#"  {"cmd":"status"}  "#,
        ];
        for line in lines {
            assert_eq!(
                Request::from_line_fast(line).unwrap(),
                Request::from_line(line).unwrap(),
                "disagreement on {line}"
            );
        }
        // malformed lines fall back to the full parser's rejection
        assert!(Request::from_line_fast("not json").is_err());
        assert!(Request::from_line_fast(r#"{"cmd":"cancel"}"#).is_err());
        assert!(Request::from_line_fast(r#"{"cmd":42}"#).is_err());
    }

    #[test]
    fn unknown_cmd_rejected() {
        assert!(Request::from_line(r#"{"cmd":"resubmit"}"#).is_err());
        assert!(Request::from_line("not json").is_err());
        assert!(Request::from_line(r#"{"cmd":"cancel"}"#).is_err(), "cancel needs a job");
        assert!(Request::from_line(r#"{"cmd":"resume"}"#).is_err(), "resume needs a job");
    }

    #[test]
    fn step_event_serializes_and_parses() {
        let rec = StepRecord {
            step: 7,
            stage: 2,
            loss: 1.25,
            lr: 3e-4,
            grad_norm: 0.5,
            router_aux: 0.01,
            step_time_s: 0.125,
            device_time_s: 0.1,
            samples_per_s: 64.0,
        };
        let j = event_json("job-0", 3, &StepEvent::Step(rec));
        let line = j.to_string();
        let back = json::parse(&line).unwrap();
        assert_eq!(back.str_of("type").unwrap(), "step");
        assert_eq!(back.str_of("job").unwrap(), "job-0");
        assert_eq!(back.u64_of("seq").unwrap(), 3);
        assert_eq!(back.f64_of("loss").unwrap(), 1.25);
        assert_eq!(back.u64_of("step").unwrap(), 7);
    }

    #[test]
    fn nan_eval_loss_serializes_as_null() {
        // the LM pre-pass finishes with a NaN eval loss — bare NaN
        // would corrupt the NDJSON stream
        let j = event_json(
            "job-0",
            9,
            &StepEvent::PhaseFinished { phase: 0, stage: 0, eval_loss: f32::NAN },
        );
        let line = j.to_string();
        let back = json::parse(&line).unwrap();
        assert_eq!(back.req("eval_loss").unwrap(), &Json::Null);
    }

    #[test]
    fn phase_started_carries_shape() {
        let ev = StepEvent::PhaseStarted {
            phase: 1,
            stage: 2,
            label: "stage2-joint-finetune",
            steps: 170,
            peak_lr: 3e-4,
            batch_size: 8,
            seq_len: 128,
        };
        let back = json::parse(&event_json("j", 0, &ev).to_string()).unwrap();
        assert_eq!(back.u64_of("steps").unwrap(), 170);
        assert_eq!(back.u64_of("seq_len").unwrap(), 128);
        assert_eq!(back.str_of("label").unwrap(), "stage2-joint-finetune");
    }

    #[test]
    fn status_and_done_shapes() {
        let snap = JobSnapshot {
            id: "job-0".into(),
            name: "a".into(),
            method: "revffn".into(),
            state: JobState::Running,
            peak_gb: 1.5,
            steps_done: 4,
            last_loss: Some(2.0),
            eval_loss: None,
            events: 6,
            error: None,
            attempts: 0,
            retry_at: None,
            priority: Priority::Interactive,
            tenant: "team-a".into(),
            deadline_ms: Some(2_000),
            deadline_missed_by_ms: Some(350),
        };
        let misses = vec![("team-a".to_string(), 1u64)];
        let st =
            json::parse(&status_json(&[snap], 8.0, 1.5, 8.0, 0.25, &misses).to_string()).unwrap();
        assert!(st.bool_of("ok").unwrap());
        assert_eq!(st.f64_of("budget_gb").unwrap(), 8.0);
        assert_eq!(st.f64_of("host_budget_gb").unwrap(), 8.0);
        assert_eq!(st.f64_of("host_committed_gb").unwrap(), 0.25);
        let tm = st.req("tenant_deadline_misses").unwrap();
        assert_eq!(tm.get("team-a").and_then(Json::as_u64), Some(1));
        let jobs = st.arr_of("jobs").unwrap();
        assert_eq!(jobs[0].str_of("state").unwrap(), "running");
        assert_eq!(jobs[0].req("eval_loss").unwrap(), &Json::Null);
        assert_eq!(jobs[0].u64_of("attempts").unwrap(), 0);
        assert_eq!(jobs[0].req("next_retry_ms").unwrap(), &Json::Null);
        assert_eq!(jobs[0].str_of("priority").unwrap(), "interactive");
        assert_eq!(jobs[0].str_of("tenant").unwrap(), "team-a");
        assert_eq!(jobs[0].u64_of("deadline_ms").unwrap(), 2_000);
        assert_eq!(jobs[0].u64_of("deadline_missed_by_ms").unwrap(), 350);

        let done = json::parse(&done_json("job-0", JobState::Finished, 6).to_string()).unwrap();
        assert!(done.bool_of("done").unwrap());
        assert_eq!(done.str_of("state").unwrap(), "finished");
    }

    #[test]
    fn events_page_footer_shape() {
        let j = json::parse(
            &events_page_json("job-0", 32, 47, JobState::Running, false, 0).to_string(),
        )
        .unwrap();
        assert!(j.bool_of("page").unwrap());
        assert!(!j.bool_of("done").unwrap());
        assert_eq!(j.u64_of("count").unwrap(), 32);
        assert_eq!(j.u64_of("next_cursor").unwrap(), 47);
        assert_eq!(j.str_of("state").unwrap(), "running");
        assert!(!j.bool_of("gapped").unwrap());
        assert_eq!(j.u64_of("dropped").unwrap(), 0);
        let end = json::parse(
            &events_page_json("job-0", 0, 47, JobState::Finished, true, 5).to_string(),
        )
        .unwrap();
        assert!(end.bool_of("done").unwrap());
        assert!(end.bool_of("gapped").unwrap(), "clamped page must be flagged");
        assert_eq!(end.u64_of("dropped").unwrap(), 5);
    }

    #[test]
    fn metrics_response_shape() {
        let body = "# TYPE revffn_steps_total counter\nrevffn_steps_total 12\n";
        let j = json::parse(&metrics_json(12, body).to_string()).unwrap();
        assert!(j.bool_of("ok").unwrap());
        assert_eq!(j.str_of("kind").unwrap(), "metrics");
        assert_eq!(j.u64_of("steps_total").unwrap(), 12);
        assert_eq!(j.str_of("body").unwrap(), body, "prometheus text survives the wire");
    }

    #[test]
    fn resumed_response_names_both_jobs() {
        let j = resumed_json("job-0", "job-3", true, 1.25, JobState::Running);
        let back = json::parse(&j.to_string()).unwrap();
        assert!(back.bool_of("ok").unwrap());
        assert_eq!(back.str_of("job").unwrap(), "job-3");
        assert_eq!(back.str_of("resumed_from").unwrap(), "job-0");
        assert_eq!(back.str_of("state").unwrap(), "running");
    }

    #[test]
    fn job_states_roundtrip() {
        for s in [
            JobState::Queued,
            JobState::Running,
            JobState::Finished,
            JobState::Failed,
            JobState::Cancelled,
            JobState::Retrying,
            JobState::Quarantined,
        ] {
            assert_eq!(JobState::parse(s.name()).unwrap(), s);
        }
        assert!(!JobState::Queued.is_terminal());
        assert!(JobState::Cancelled.is_terminal());
        assert!(!JobState::Retrying.is_terminal(), "followers wait across a retry");
        assert!(JobState::Quarantined.is_terminal());
    }

    #[test]
    fn retrying_snapshot_reports_attempts_and_deadline() {
        let snap = JobSnapshot {
            id: "job-1".into(),
            name: "b".into(),
            method: "revffn".into(),
            state: JobState::Retrying,
            peak_gb: 1.0,
            steps_done: 9,
            last_loss: None,
            eval_loss: None,
            events: 11,
            error: Some("injected fault: pjrt_execute".into()),
            attempts: 2,
            retry_at: Some(std::time::Instant::now() + std::time::Duration::from_secs(5)),
            priority: Priority::default(),
            tenant: "default".into(),
            deadline_ms: None,
            deadline_missed_by_ms: None,
        };
        let j = json::parse(&snapshot_json(&snap).to_string()).unwrap();
        assert_eq!(j.str_of("state").unwrap(), "retrying");
        assert_eq!(j.u64_of("attempts").unwrap(), 2);
        let ms = j.f64_of("next_retry_ms").unwrap();
        assert!(ms > 0.0 && ms <= 5_000.0, "next_retry_ms {ms}");
        assert!(j.str_of("error").unwrap().contains("injected"));
    }
}
