//! The serve wire protocol: JSON-lines (NDJSON) over TCP.
//!
//! Every request and response is one JSON object per line. Four verbs:
//!
//! * `{"cmd":"submit","config":{…RunConfig…},"name":"…"}` →
//!   `{"ok":true,"job":"job-0","admitted":true,"peak_gb":…}`
//! * `{"cmd":"status"}` / `{"cmd":"status","job":"job-0"}` → one
//!   status object with the budget ledger and per-job snapshots.
//! * `{"cmd":"events","job":"job-0","from":0,"follow":true}` → streams
//!   the job's `StepEvent`s as NDJSON lines, then a
//!   `{"job":…,"done":true,…}` terminator (follow=false returns what
//!   exists and terminates immediately).
//! * `{"cmd":"cancel","job":"job-0"}` → `{"ok":true,"cancelled":…}`.
//! * `{"cmd":"resume","job":"job-0"}` → resubmits a
//!   failed/cancelled/quarantined job from its latest periodic
//!   snapshot as a new job:
//!   `{"ok":true,"job":"job-3","resumed_from":"job-0","admitted":…}`.
//!
//! Plus `{"cmd":"shutdown"}` to stop the server (tests, smoke scripts).
//!
//! Everything (de)serializes through the in-crate `util::json` codec —
//! the wire format needs no dependency the build doesn't already carry.
//! Non-finite floats (the pre-pass's NaN eval loss) serialize as JSON
//! `null`, never as bare `NaN`.

use crate::engine::StepEvent;
use crate::error::{Error, Result};
use crate::util::json::{self, Json, ObjBuilder};

/// Lifecycle state of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Priced over the current headroom; waiting for budget (FIFO).
    Queued,
    /// Admitted and being driven by the scheduler.
    Running,
    Finished,
    Failed,
    Cancelled,
    /// Failed, but within the supervised-retry budget: waiting out its
    /// backoff delay before re-activation from the latest valid
    /// snapshot (docs/ROBUSTNESS.md).
    Retrying,
    /// Exhausted the retry budget; `error` carries the failure chain.
    /// Terminal for the scheduler, but `resume` accepts it.
    Quarantined,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Finished => "finished",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::Retrying => "retrying",
            JobState::Quarantined => "quarantined",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "queued" => Ok(JobState::Queued),
            "running" => Ok(JobState::Running),
            "finished" => Ok(JobState::Finished),
            "failed" => Ok(JobState::Failed),
            "cancelled" => Ok(JobState::Cancelled),
            "retrying" => Ok(JobState::Retrying),
            "quarantined" => Ok(JobState::Quarantined),
            other => Err(Error::Parse(format!("unknown job state {other:?}"))),
        }
    }

    /// No further events will be produced in this state. `Retrying` is
    /// NOT terminal — event followers keep waiting across a retry.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Finished | JobState::Failed | JobState::Cancelled | JobState::Quarantined
        )
    }
}

/// One parsed control-plane request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Submit { config: Json, name: Option<String> },
    Status { job: Option<String> },
    Events { job: String, from: u64, follow: bool },
    Cancel { job: String },
    /// Resubmit a failed/cancelled/quarantined job from its latest
    /// checkpoint.
    Resume { job: String },
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Submit { config, name } => {
                let mut b = ObjBuilder::new().str("cmd", "submit").val("config", config.clone());
                if let Some(n) = name {
                    b = b.str("name", n.clone());
                }
                b.build()
            }
            Request::Status { job } => {
                let mut b = ObjBuilder::new().str("cmd", "status");
                if let Some(j) = job {
                    b = b.str("job", j.clone());
                }
                b.build()
            }
            Request::Events { job, from, follow } => ObjBuilder::new()
                .str("cmd", "events")
                .str("job", job.clone())
                .num("from", *from as f64)
                .bool("follow", *follow)
                .build(),
            Request::Cancel { job } => {
                ObjBuilder::new().str("cmd", "cancel").str("job", job.clone()).build()
            }
            Request::Resume { job } => {
                ObjBuilder::new().str("cmd", "resume").str("job", job.clone()).build()
            }
            Request::Shutdown => ObjBuilder::new().str("cmd", "shutdown").build(),
        }
    }

    pub fn from_json(j: &Json) -> Result<Request> {
        let cmd = j.str_of("cmd")?;
        match cmd.as_str() {
            "submit" => Ok(Request::Submit {
                config: j.get("config").cloned().unwrap_or_else(|| Json::Obj(Default::default())),
                name: j.get("name").and_then(Json::as_str).map(str::to_string),
            }),
            "status" => Ok(Request::Status {
                job: j.get("job").and_then(Json::as_str).map(str::to_string),
            }),
            "events" => Ok(Request::Events {
                job: j.str_of("job")?,
                from: j.get("from").and_then(Json::as_u64).unwrap_or(0),
                follow: j.get("follow").and_then(Json::as_bool).unwrap_or(true),
            }),
            "cancel" => Ok(Request::Cancel { job: j.str_of("job")? }),
            "resume" => Ok(Request::Resume { job: j.str_of("job")? }),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(Error::Parse(format!("unknown cmd {other:?}"))),
        }
    }

    /// One NDJSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    pub fn from_line(line: &str) -> Result<Request> {
        Self::from_json(&json::parse(line.trim())?)
    }
}

/// JSON number, or `null` when non-finite (NaN eval losses) — bare
/// `NaN` is not valid JSON and would corrupt the stream.
pub fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// Serialize one job `StepEvent` as an NDJSON event line. `seq` is the
/// job-local event sequence number (the `from` cursor of the `events`
/// verb indexes it).
pub fn event_json(job: &str, seq: u64, ev: &StepEvent) -> Json {
    let b = ObjBuilder::new().str("job", job).num("seq", seq as f64);
    match ev {
        StepEvent::PhaseStarted { phase, stage, label, steps, peak_lr, batch_size, seq_len } => b
            .str("type", "phase_started")
            .num("phase", *phase as f64)
            .num("stage", *stage as f64)
            .str("label", *label)
            .num("steps", *steps as f64)
            .val("peak_lr", num_or_null(*peak_lr as f64))
            .num("batch_size", *batch_size as f64)
            .num("seq_len", *seq_len as f64)
            .build(),
        StepEvent::Step(rec) => b
            .str("type", "step")
            .num("step", rec.step as f64)
            .num("stage", rec.stage as f64)
            .val("loss", num_or_null(rec.loss as f64))
            .val("lr", num_or_null(rec.lr as f64))
            .val("grad_norm", num_or_null(rec.grad_norm as f64))
            .val("router_aux", num_or_null(rec.router_aux as f64))
            .num("step_time_s", rec.step_time_s)
            .num("device_time_s", rec.device_time_s)
            .num("samples_per_s", rec.samples_per_s)
            .build(),
        StepEvent::EvalPoint { step, eval_loss } => b
            .str("type", "eval")
            .num("step", *step as f64)
            .val("eval_loss", num_or_null(*eval_loss as f64))
            .build(),
        StepEvent::PhaseFinished { phase, stage, eval_loss } => b
            .str("type", "phase_finished")
            .num("phase", *phase as f64)
            .num("stage", *stage as f64)
            .val("eval_loss", num_or_null(*eval_loss as f64))
            .build(),
    }
}

/// End-of-stream marker for the `events` verb.
pub fn done_json(job: &str, state: JobState, events: u64) -> Json {
    ObjBuilder::new()
        .str("job", job)
        .bool("done", true)
        .str("state", state.name())
        .num("events", events as f64)
        .build()
}

/// Public snapshot of one job (the `status` verb's row).
#[derive(Debug, Clone)]
pub struct JobSnapshot {
    pub id: String,
    pub name: String,
    pub method: String,
    pub state: JobState,
    pub peak_gb: f64,
    /// Optimizer steps recorded so far.
    pub steps_done: u64,
    pub last_loss: Option<f32>,
    pub eval_loss: Option<f32>,
    /// Events produced so far (the `events` verb's cursor space).
    pub events: u64,
    /// Last failure — or, once quarantined, the whole failure chain.
    pub error: Option<String>,
    /// Supervised-retry failures so far (0 = never failed).
    pub attempts: u64,
    /// When the next supervised retry is due (`Retrying` only).
    pub retry_at: Option<std::time::Instant>,
}

pub fn snapshot_json(s: &JobSnapshot) -> Json {
    let mut b = ObjBuilder::new()
        .str("id", s.id.clone())
        .str("name", s.name.clone())
        .str("method", s.method.clone())
        .str("state", s.state.name())
        .num("peak_gb", s.peak_gb)
        .num("steps_done", s.steps_done as f64)
        .val("last_loss", s.last_loss.map_or(Json::Null, |x| num_or_null(x as f64)))
        .val("eval_loss", s.eval_loss.map_or(Json::Null, |x| num_or_null(x as f64)))
        .num("events", s.events as f64)
        .num("attempts", s.attempts as f64)
        .val(
            "next_retry_ms",
            s.retry_at.map_or(Json::Null, |at| {
                Json::Num(at.saturating_duration_since(std::time::Instant::now()).as_millis()
                    as f64)
            }),
        );
    if let Some(e) = &s.error {
        b = b.str("error", e.clone());
    }
    b.build()
}

/// The full `status` response: device + host budget ledgers and the
/// job table. `host_budget_gb` is the configured value (0 = unbounded).
pub fn status_json(
    jobs: &[JobSnapshot],
    budget_gb: f64,
    committed_gb: f64,
    host_budget_gb: f64,
    host_committed_gb: f64,
) -> Json {
    ObjBuilder::new()
        .bool("ok", true)
        .num("budget_gb", budget_gb)
        .num("committed_gb", committed_gb)
        .num("host_budget_gb", host_budget_gb)
        .num("host_committed_gb", host_committed_gb)
        .val("jobs", Json::Arr(jobs.iter().map(snapshot_json).collect()))
        .build()
}

pub fn ok_json() -> Json {
    ObjBuilder::new().bool("ok", true).build()
}

pub fn error_json(message: &str) -> Json {
    ObjBuilder::new().bool("ok", false).str("error", message).build()
}

/// Response to a successful `submit`. `state` disambiguates
/// `admitted:false` — `queued` will run later; `failed` never will
/// (activation errored; the `status` verb carries the error text).
pub fn submitted_json(job: &str, admitted: bool, peak_gb: f64, state: JobState) -> Json {
    ObjBuilder::new()
        .bool("ok", true)
        .str("job", job)
        .bool("admitted", admitted)
        .num("peak_gb", peak_gb)
        .str("state", state.name())
        .build()
}

/// Response to a successful `resume`: the continuation's submit
/// outcome plus the id of the job it was resumed from.
pub fn resumed_json(
    resumed_from: &str,
    job: &str,
    admitted: bool,
    peak_gb: f64,
    state: JobState,
) -> Json {
    ObjBuilder::new()
        .bool("ok", true)
        .str("job", job)
        .str("resumed_from", resumed_from)
        .bool("admitted", admitted)
        .num("peak_gb", peak_gb)
        .str("state", state.name())
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::StepRecord;

    #[test]
    fn requests_roundtrip_through_lines() {
        let cases = vec![
            Request::Submit {
                config: json::parse(r#"{"method":"revffn","eval_every":0}"#).unwrap(),
                name: Some("job-a".into()),
            },
            Request::Submit {
                config: json::parse("{}").unwrap(),
                name: None,
            },
            Request::Status { job: None },
            Request::Status { job: Some("job-3".into()) },
            Request::Events { job: "job-0".into(), from: 17, follow: false },
            Request::Cancel { job: "job-1".into() },
            Request::Resume { job: "job-2".into() },
            Request::Shutdown,
        ];
        for req in cases {
            let line = req.to_line();
            assert!(!line.contains('\n'), "one line per request");
            let back = Request::from_line(&line).unwrap();
            assert_eq!(back, req, "roundtrip failed for {line}");
        }
    }

    #[test]
    fn events_defaults_follow_and_from() {
        let r = Request::from_line(r#"{"cmd":"events","job":"job-0"}"#).unwrap();
        assert_eq!(r, Request::Events { job: "job-0".into(), from: 0, follow: true });
    }

    #[test]
    fn unknown_cmd_rejected() {
        assert!(Request::from_line(r#"{"cmd":"resubmit"}"#).is_err());
        assert!(Request::from_line("not json").is_err());
        assert!(Request::from_line(r#"{"cmd":"cancel"}"#).is_err(), "cancel needs a job");
        assert!(Request::from_line(r#"{"cmd":"resume"}"#).is_err(), "resume needs a job");
    }

    #[test]
    fn step_event_serializes_and_parses() {
        let rec = StepRecord {
            step: 7,
            stage: 2,
            loss: 1.25,
            lr: 3e-4,
            grad_norm: 0.5,
            router_aux: 0.01,
            step_time_s: 0.125,
            device_time_s: 0.1,
            samples_per_s: 64.0,
        };
        let j = event_json("job-0", 3, &StepEvent::Step(rec));
        let line = j.to_string();
        let back = json::parse(&line).unwrap();
        assert_eq!(back.str_of("type").unwrap(), "step");
        assert_eq!(back.str_of("job").unwrap(), "job-0");
        assert_eq!(back.u64_of("seq").unwrap(), 3);
        assert_eq!(back.f64_of("loss").unwrap(), 1.25);
        assert_eq!(back.u64_of("step").unwrap(), 7);
    }

    #[test]
    fn nan_eval_loss_serializes_as_null() {
        // the LM pre-pass finishes with a NaN eval loss — bare NaN
        // would corrupt the NDJSON stream
        let j = event_json(
            "job-0",
            9,
            &StepEvent::PhaseFinished { phase: 0, stage: 0, eval_loss: f32::NAN },
        );
        let line = j.to_string();
        let back = json::parse(&line).unwrap();
        assert_eq!(back.req("eval_loss").unwrap(), &Json::Null);
    }

    #[test]
    fn phase_started_carries_shape() {
        let ev = StepEvent::PhaseStarted {
            phase: 1,
            stage: 2,
            label: "stage2-joint-finetune",
            steps: 170,
            peak_lr: 3e-4,
            batch_size: 8,
            seq_len: 128,
        };
        let back = json::parse(&event_json("j", 0, &ev).to_string()).unwrap();
        assert_eq!(back.u64_of("steps").unwrap(), 170);
        assert_eq!(back.u64_of("seq_len").unwrap(), 128);
        assert_eq!(back.str_of("label").unwrap(), "stage2-joint-finetune");
    }

    #[test]
    fn status_and_done_shapes() {
        let snap = JobSnapshot {
            id: "job-0".into(),
            name: "a".into(),
            method: "revffn".into(),
            state: JobState::Running,
            peak_gb: 1.5,
            steps_done: 4,
            last_loss: Some(2.0),
            eval_loss: None,
            events: 6,
            error: None,
            attempts: 0,
            retry_at: None,
        };
        let st = json::parse(&status_json(&[snap], 8.0, 1.5, 8.0, 0.25).to_string()).unwrap();
        assert!(st.bool_of("ok").unwrap());
        assert_eq!(st.f64_of("budget_gb").unwrap(), 8.0);
        assert_eq!(st.f64_of("host_budget_gb").unwrap(), 8.0);
        assert_eq!(st.f64_of("host_committed_gb").unwrap(), 0.25);
        let jobs = st.arr_of("jobs").unwrap();
        assert_eq!(jobs[0].str_of("state").unwrap(), "running");
        assert_eq!(jobs[0].req("eval_loss").unwrap(), &Json::Null);
        assert_eq!(jobs[0].u64_of("attempts").unwrap(), 0);
        assert_eq!(jobs[0].req("next_retry_ms").unwrap(), &Json::Null);

        let done = json::parse(&done_json("job-0", JobState::Finished, 6).to_string()).unwrap();
        assert!(done.bool_of("done").unwrap());
        assert_eq!(done.str_of("state").unwrap(), "finished");
    }

    #[test]
    fn resumed_response_names_both_jobs() {
        let j = resumed_json("job-0", "job-3", true, 1.25, JobState::Running);
        let back = json::parse(&j.to_string()).unwrap();
        assert!(back.bool_of("ok").unwrap());
        assert_eq!(back.str_of("job").unwrap(), "job-3");
        assert_eq!(back.str_of("resumed_from").unwrap(), "job-0");
        assert_eq!(back.str_of("state").unwrap(), "running");
    }

    #[test]
    fn job_states_roundtrip() {
        for s in [
            JobState::Queued,
            JobState::Running,
            JobState::Finished,
            JobState::Failed,
            JobState::Cancelled,
            JobState::Retrying,
            JobState::Quarantined,
        ] {
            assert_eq!(JobState::parse(s.name()).unwrap(), s);
        }
        assert!(!JobState::Queued.is_terminal());
        assert!(JobState::Cancelled.is_terminal());
        assert!(!JobState::Retrying.is_terminal(), "followers wait across a retry");
        assert!(JobState::Quarantined.is_terminal());
    }

    #[test]
    fn retrying_snapshot_reports_attempts_and_deadline() {
        let snap = JobSnapshot {
            id: "job-1".into(),
            name: "b".into(),
            method: "revffn".into(),
            state: JobState::Retrying,
            peak_gb: 1.0,
            steps_done: 9,
            last_loss: None,
            eval_loss: None,
            events: 11,
            error: Some("injected fault: pjrt_execute".into()),
            attempts: 2,
            retry_at: Some(std::time::Instant::now() + std::time::Duration::from_secs(5)),
        };
        let j = json::parse(&snapshot_json(&snap).to_string()).unwrap();
        assert_eq!(j.str_of("state").unwrap(), "retrying");
        assert_eq!(j.u64_of("attempts").unwrap(), 2);
        let ms = j.f64_of("next_retry_ms").unwrap();
        assert!(ms > 0.0 && ms <= 5_000.0, "next_retry_ms {ms}");
        assert!(j.str_of("error").unwrap().contains("injected"));
    }
}
