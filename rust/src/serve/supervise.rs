//! Supervised recovery for serve jobs (docs/ROBUSTNESS.md).
//!
//! Three pieces the scheduler composes:
//!
//! * [`RetryPolicy`] — the config knobs: how many supervised retries a
//!   failed job gets and the exponential-backoff window between them
//!   (delays come from [`crate::util::retry::Backoff`]).
//! * [`Supervision`] — the per-job record: attempt count, the failure
//!   chain (one entry per failure, surfaced verbatim in `status` once
//!   the job quarantines), and the next-retry deadline. A due retry
//!   re-enters through the same admission gate as a fresh submit —
//!   global budget AND the job's tenant quota — so a retrying job can
//!   hold in `Retrying` past its backoff until its tenant has room,
//!   rather than jumping the fairness queue.
//! * [`HealthProbe`] — a cheap compiled-program execute that gates
//!   re-admission after a failure: a device that cannot add two
//!   four-element vectors must not get the job back. When the probe
//!   module cannot compile (exotic PJRT plugin), it degrades to a
//!   host↔device literal roundtrip rather than going blind.

use std::time::Instant;

use crate::config::ServeConfig;
use crate::error::{Error, Result};
use crate::runtime::literal::{f32_literal, to_f32_vec};
use crate::runtime::pjrt::{Device, Program};

/// Supervised-retry knobs, lifted from [`ServeConfig`].
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Retries a failed job gets before quarantine (0 = supervision
    /// off: the first failure is terminal, pre-supervision behavior).
    pub max_attempts: u32,
    /// Backoff base delay, milliseconds (0 = retry immediately).
    pub base_ms: u64,
    /// Backoff ceiling, milliseconds.
    pub max_ms: u64,
}

impl RetryPolicy {
    pub fn from_serve(opts: &ServeConfig) -> RetryPolicy {
        RetryPolicy {
            max_attempts: opts.retry_max_attempts,
            base_ms: opts.retry_base_ms,
            max_ms: opts.retry_max_ms,
        }
    }

    pub fn enabled(&self) -> bool {
        self.max_attempts > 0
    }
}

/// Per-job supervision record.
#[derive(Debug, Clone, Default)]
pub struct Supervision {
    /// Failures so far (== `failures.len()`).
    pub attempts: u32,
    /// Failure chain, oldest first.
    pub failures: Vec<String>,
    /// When the pending retry is due (`Retrying` state only).
    pub retry_at: Option<Instant>,
}

impl Supervision {
    /// Record one failure.
    pub fn record(&mut self, msg: String) {
        self.attempts += 1;
        self.failures.push(msg);
    }

    /// The failure chain as one string for `status` / quarantine.
    pub fn chain(&self) -> String {
        let mut out = String::new();
        for (i, f) in self.failures.iter().enumerate() {
            if i > 0 {
                out.push_str("; ");
            }
            out.push_str("attempt ");
            out.push_str(&(i + 1).to_string());
            out.push_str(": ");
            out.push_str(f);
        }
        out
    }
}

/// Minimal HLO module the probe compiles once per scheduler: doubles a
/// four-element vector. Executing it exercises the same
/// compile→execute→download path training steps take, at trivial cost.
const PROBE_HLO: &str = "HloModule health_probe, \
entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY %main.1 (Arg_0.1: f32[4]) -> f32[4] {
  %Arg_0.1 = f32[4]{0} parameter(0)
  ROOT %add.2 = f32[4]{0} add(%Arg_0.1, %Arg_0.1)
}
";

const PROBE_IN: [f32; 4] = [1.0, 2.0, 3.0, 4.0];

/// Device-health probe gating supervised re-admission.
#[derive(Default)]
pub struct HealthProbe {
    program: Option<Program>,
    compile_failed: bool,
}

impl HealthProbe {
    pub fn new() -> HealthProbe {
        HealthProbe::default()
    }

    /// Execute the probe; `Ok(())` means the device computes correctly.
    /// An injected or real execute fault surfaces as `Err`, which the
    /// scheduler counts against the job's retry budget — a dead device
    /// quarantines its jobs instead of spinning forever.
    pub fn check(&mut self, device: &Device) -> Result<()> {
        if self.program.is_none() && !self.compile_failed {
            match compile_probe(device) {
                Ok(p) => self.program = Some(p),
                Err(_) => self.compile_failed = true,
            }
        }
        match &self.program {
            Some(p) => {
                let input = f32_literal(&PROBE_IN, &[4])?;
                let out = p.run(&[input])?;
                let first = out
                    .first()
                    .ok_or_else(|| Error::Training("health probe produced no output".into()))?;
                let got = to_f32_vec(first)?;
                if got.len() == 4 && got.iter().zip([2.0f32, 4.0, 6.0, 8.0]).all(|(a, b)| *a == b)
                {
                    Ok(())
                } else {
                    Err(Error::Training(format!(
                        "health probe computed {got:?}, expected [2, 4, 6, 8]"
                    )))
                }
            }
            // Probe module unavailable: a literal roundtrip still
            // proves transfers work, which beats passing blind.
            None => {
                let lit = f32_literal(&PROBE_IN, &[4])?;
                let buf = device.to_device(&lit)?;
                let back = to_f32_vec(&device.from_device(&buf)?)?;
                if back == PROBE_IN {
                    Ok(())
                } else {
                    Err(Error::Training(format!(
                        "health probe roundtrip returned {back:?}"
                    )))
                }
            }
        }
    }
}

/// `load_hlo_text` compiles from a file path, so the probe module goes
/// through a scratch file (removed immediately after compile).
fn compile_probe(device: &Device) -> Result<Program> {
    let path = std::env::temp_dir().join(format!("revffn-probe-{}.hlo.txt", std::process::id()));
    std::fs::write(&path, PROBE_HLO)?;
    let prog = device.load_hlo_text(&path);
    let _ = std::fs::remove_file(&path);
    prog
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_formats_attempts_in_order() {
        let mut s = Supervision::default();
        assert_eq!(s.chain(), "");
        s.record("execute exploded".into());
        s.record("probe said no".into());
        assert_eq!(s.attempts, 2);
        assert_eq!(s.chain(), "attempt 1: execute exploded; attempt 2: probe said no");
    }

    #[test]
    fn policy_enabled_iff_attempts_budgeted() {
        let on = RetryPolicy { max_attempts: 3, base_ms: 250, max_ms: 10_000 };
        let off = RetryPolicy { max_attempts: 0, base_ms: 250, max_ms: 10_000 };
        assert!(on.enabled());
        assert!(!off.enabled());
    }
}
