//! Admission control: price each submitted job's peak VRAM with the
//! analytic memory model and admit only while the sum fits the budget.
//!
//! This turns `memory::model` from a reporting tool into an operational
//! gate, and it is where RevFFN's depth-independent activation live-set
//! (§3.1) becomes a serving property instead of a table row: at the
//! same `budget_gb`, more concurrent RevFFN fine-tuning jobs are
//! admitted than SFT jobs, because each prices a smaller peak — the gap
//! grows with batch·seq·layers (LOMO-style work, arXiv 2306.09782,
//! similarly treats the memory budget as the first-class scheduling
//! constraint). A job's price is fixed at submit time; the scheduler
//! releases the reservation when the job finishes, fails, or is
//! cancelled.

use std::path::Path;

use crate::engine::Method;
use crate::error::Result;
use crate::memory::{Assumptions, Geometry, MemoryModel};
use crate::runtime::artifact::Artifact;

/// Peak-VRAM price (GB) of one job at a geometry/method/batch/seq.
pub fn price(geo: &Geometry, method: Method, assume: Assumptions, batch: u64, seq: u64) -> f64 {
    MemoryModel::new(geo.clone(), assume).peak_gb(method.memory_method(), batch, seq)
}

/// A submitted job priced for admission.
#[derive(Debug, Clone)]
pub struct PricedJob {
    pub peak_gb: f64,
    /// Host-RAM price of the job's full-state literal snapshot (params
    /// + both Adam moments, f32) — what a *suspended* job pins in host
    /// memory while another job owns the device, and what a checkpoint
    /// materializes. Reserved up front: any admitted job may be
    /// preempted, so the worst case is the honest admission cost.
    pub host_gb: f64,
    pub batch: u64,
    pub seq: u64,
    /// Name of the geometry the price was computed at.
    pub geometry: String,
}

/// Price a job from its artifact set: batch/seq come from the method's
/// eval-variant manifest; the geometry does too unless `geometry`
/// overrides it (e.g. pricing a tiny-artifact job at Qwen scale). Only
/// the manifest is read — no XLA work.
pub fn price_job(
    artifacts: &Path,
    method: Method,
    assume: Assumptions,
    geometry: Option<Geometry>,
) -> Result<PricedJob> {
    let artifact = Artifact::load(artifacts.join(method.eval_variant()))?;
    let io = &artifact.manifest.io;
    let (batch, seq) = (io.batch_size as u64, io.seq_len as u64);
    let geo = geometry.unwrap_or_else(|| Geometry::from_manifest(&artifact.manifest.model));
    let model = MemoryModel::new(geo.clone(), assume);
    Ok(PricedJob {
        peak_gb: model.peak_gb(method.memory_method(), batch, seq),
        host_gb: model.host_state_gb(method.memory_method()),
        batch,
        seq,
        geometry: geo.name.clone(),
    })
}

/// The budget ledger: tracks the summed peak-GB of admitted jobs on
/// the device side AND the summed host-snapshot GB on the host side. A
/// job is admitted only when both fit — suspended jobs' host-side
/// literal mirrors were previously invisible here, letting a
/// budget-full server be OOM'd in host RAM.
#[derive(Debug, Clone)]
pub struct Admission {
    budget_gb: f64,
    committed_gb: f64,
    host_budget_gb: f64,
    host_committed_gb: f64,
    admitted: usize,
}

impl Admission {
    /// Device budget only (host side unbounded).
    pub fn new(budget_gb: f64) -> Self {
        Self::with_host_budget(budget_gb, f64::INFINITY)
    }

    /// Device + host budgets (`host_budget_gb` caps the summed
    /// suspended-snapshot footprint; pass `f64::INFINITY` to disable).
    pub fn with_host_budget(budget_gb: f64, host_budget_gb: f64) -> Self {
        Admission {
            budget_gb,
            committed_gb: 0.0,
            host_budget_gb,
            host_committed_gb: 0.0,
            admitted: 0,
        }
    }

    /// Reserve `peak_gb` device-side and `host_gb` host-side if BOTH
    /// fit. The comparisons carry a tiny relative epsilon so releasing
    /// and re-admitting identical jobs never flips on accumulated
    /// float rounding.
    pub fn try_admit(&mut self, peak_gb: f64, host_gb: f64) -> bool {
        let device_ok = self.committed_gb + peak_gb <= self.budget_gb * (1.0 + 1e-9);
        let host_ok = self.host_committed_gb + host_gb <= self.host_budget_gb * (1.0 + 1e-9);
        if device_ok && host_ok {
            self.committed_gb += peak_gb;
            self.host_committed_gb += host_gb;
            self.admitted += 1;
            true
        } else {
            false
        }
    }

    /// Return a finished/cancelled job's reservations to the pool. When
    /// the last job leaves, both ledgers snap back to exactly zero so
    /// rounding drift cannot accumulate across job generations.
    pub fn release(&mut self, peak_gb: f64, host_gb: f64) {
        self.admitted = self.admitted.saturating_sub(1);
        if self.admitted == 0 {
            self.committed_gb = 0.0;
            self.host_committed_gb = 0.0;
        } else {
            self.committed_gb = (self.committed_gb - peak_gb).max(0.0);
            self.host_committed_gb = (self.host_committed_gb - host_gb).max(0.0);
        }
    }

    pub fn budget_gb(&self) -> f64 {
        self.budget_gb
    }

    pub fn committed_gb(&self) -> f64 {
        self.committed_gb
    }

    pub fn host_budget_gb(&self) -> f64 {
        self.host_budget_gb
    }

    pub fn host_committed_gb(&self) -> f64 {
        self.host_committed_gb
    }

    pub fn headroom_gb(&self) -> f64 {
        (self.budget_gb - self.committed_gb).max(0.0)
    }

    /// Number of currently admitted jobs.
    pub fn admitted(&self) -> usize {
        self.admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fine-tuning-shaped workload where activations matter: deep
    /// model, large batch, long sequences.
    fn deep_geo() -> Geometry {
        let mut g = Geometry::qwen15_moe_a27b();
        g.n_layers = 48;
        g
    }

    fn fit_count(geo: &Geometry, method: Method, budget_gb: f64) -> usize {
        let p = price(geo, method, Assumptions::paper_calibrated(), 256, 4096);
        let mut adm = Admission::new(budget_gb);
        let mut n = 0;
        while adm.try_admit(p, 0.0) {
            n += 1;
            assert!(n < 1000, "runaway admission");
        }
        n
    }

    #[test]
    fn revffn_prices_below_sft_at_training_shapes() {
        let geo = deep_geo();
        let a = Assumptions::paper_calibrated();
        let rev = price(&geo, Method::Revffn, a, 256, 4096);
        let sft = price(&geo, Method::Sft, a, 256, 4096);
        assert!(rev < sft, "revffn {rev:.1} GB must undercut sft {sft:.1} GB");
    }

    #[test]
    fn more_revffn_jobs_fit_than_sft_under_same_budget() {
        // The acceptance-criterion property: RevFFN jobs price
        // depth-independent activations, so a fixed budget admits more
        // of them concurrently than SFT jobs.
        let geo = deep_geo();
        let sft_price = price(&geo, Method::Sft, Assumptions::paper_calibrated(), 256, 4096);
        let budget = 4.5 * sft_price;
        let n_sft = fit_count(&geo, Method::Sft, budget);
        let n_rev = fit_count(&geo, Method::Revffn, budget);
        assert!(n_sft >= 1);
        assert!(
            n_rev > n_sft,
            "same budget must admit more revffn jobs: {n_rev} vs {n_sft}"
        );
    }

    #[test]
    fn revffn_price_grows_slower_with_depth_than_sft() {
        // Doubling depth adds weights for everyone, but activation
        // growth only for non-reversible methods.
        let a = Assumptions::paper_calibrated();
        let mut g = Geometry::qwen15_moe_a27b();
        g.n_layers = 24;
        let rev24 = price(&g, Method::Revffn, a, 64, 2048);
        let sft24 = price(&g, Method::Sft, a, 64, 2048);
        g.n_layers = 96;
        let rev96 = price(&g, Method::Revffn, a, 64, 2048);
        let sft96 = price(&g, Method::Sft, a, 64, 2048);
        assert!(rev96 - rev24 < sft96 - sft24);
    }

    #[test]
    fn release_frees_budget_for_queued_jobs() {
        let mut adm = Admission::new(10.0);
        assert!(adm.try_admit(6.0, 0.0));
        assert!(!adm.try_admit(6.0, 0.0), "second job must not fit");
        adm.release(6.0, 0.0);
        assert_eq!(adm.admitted(), 0);
        assert_eq!(adm.committed_gb(), 0.0);
        assert!(adm.try_admit(6.0, 0.0), "released budget must re-admit");
    }

    #[test]
    fn admission_ledger_tracks_sums() {
        let mut adm = Admission::new(10.0);
        assert!(adm.try_admit(3.0, 0.0));
        assert!(adm.try_admit(4.0, 0.0));
        assert!((adm.committed_gb() - 7.0).abs() < 1e-12);
        assert!((adm.headroom_gb() - 3.0).abs() < 1e-12);
        assert_eq!(adm.admitted(), 2);
        assert!(!adm.try_admit(3.5, 0.0));
        adm.release(3.0, 0.0);
        assert!(adm.try_admit(3.5, 0.0));
    }

    #[test]
    fn single_job_over_budget_never_admits() {
        let mut adm = Admission::new(1.0);
        assert!(!adm.try_admit(1.5, 0.0));
        assert_eq!(adm.admitted(), 0);
    }

    #[test]
    fn host_budget_blocks_admission_even_with_device_headroom() {
        // the host-mirror OOM fix: device budget fits three jobs, but
        // their suspended snapshots only fit two host-side
        let mut adm = Admission::with_host_budget(30.0, 5.0);
        assert!(adm.try_admit(6.0, 2.0));
        assert!(adm.try_admit(6.0, 2.0));
        assert!(!adm.try_admit(6.0, 2.0), "third job must be blocked by the host ledger");
        assert!((adm.host_committed_gb() - 4.0).abs() < 1e-12);
        assert!((adm.committed_gb() - 12.0).abs() < 1e-12, "device side untouched by refusal");
        adm.release(6.0, 2.0);
        assert!(adm.try_admit(6.0, 2.0), "released host budget must re-admit");
    }

    #[test]
    fn unbounded_host_budget_never_blocks() {
        let mut adm = Admission::new(100.0);
        for _ in 0..10 {
            assert!(adm.try_admit(5.0, 1e12));
        }
        adm.release(5.0, 1e12);
        assert_eq!(adm.admitted(), 9);
    }

    #[test]
    fn both_ledgers_snap_to_zero_when_empty() {
        let mut adm = Admission::with_host_budget(10.0, 10.0);
        assert!(adm.try_admit(0.1 + 0.2, 0.1 + 0.2)); // float-noisy prices
        adm.release(0.3, 0.3);
        assert_eq!(adm.committed_gb(), 0.0);
        assert_eq!(adm.host_committed_gb(), 0.0);
    }

    #[test]
    fn priced_job_host_cost_below_device_peak() {
        let geo = deep_geo();
        let a = Assumptions::paper_calibrated();
        let model = crate::memory::MemoryModel::new(geo.clone(), a);
        let host = model.host_state_gb(Method::Revffn.memory_method());
        let peak = price(&geo, Method::Revffn, a, 256, 4096);
        assert!(host > 0.0);
        assert!(host < peak, "host snapshot {host:.1} GB must undercut device peak {peak:.1} GB");
    }
}
