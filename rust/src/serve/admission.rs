//! Admission control: price each submitted job's peak VRAM with the
//! analytic memory model and admit only while the sum fits the budget.
//!
//! This turns `memory::model` from a reporting tool into an operational
//! gate, and it is where RevFFN's depth-independent activation live-set
//! (§3.1) becomes a serving property instead of a table row: at the
//! same `budget_gb`, more concurrent RevFFN fine-tuning jobs are
//! admitted than SFT jobs, because each prices a smaller peak — the gap
//! grows with batch·seq·layers (LOMO-style work, arXiv 2306.09782,
//! similarly treats the memory budget as the first-class scheduling
//! constraint). A job's price is fixed at submit time; the scheduler
//! releases the reservation when the job finishes, fails, or is
//! cancelled.

use std::collections::BTreeMap;
use std::path::Path;

use crate::engine::Method;
use crate::error::Result;
use crate::memory::{Assumptions, Geometry, MemoryModel};
use crate::runtime::artifact::Artifact;

/// Peak-VRAM price (GB) of one job at a geometry/method/batch/seq.
pub fn price(geo: &Geometry, method: Method, assume: Assumptions, batch: u64, seq: u64) -> f64 {
    MemoryModel::new(geo.clone(), assume).peak_gb(method.memory_method(), batch, seq)
}

/// A submitted job priced for admission.
#[derive(Debug, Clone)]
pub struct PricedJob {
    pub peak_gb: f64,
    /// Host-RAM price of the job's full-state literal snapshot (params
    /// + both Adam moments, f32) — what a *suspended* job pins in host
    /// memory while another job owns the device, and what a checkpoint
    /// materializes. Reserved up front: any admitted job may be
    /// preempted, so the worst case is the honest admission cost.
    pub host_gb: f64,
    pub batch: u64,
    pub seq: u64,
    /// Name of the geometry the price was computed at.
    pub geometry: String,
}

/// Price a job from its artifact set: batch/seq come from the method's
/// eval-variant manifest; the geometry does too unless `geometry`
/// overrides it (e.g. pricing a tiny-artifact job at Qwen scale). Only
/// the manifest is read — no XLA work.
pub fn price_job(
    artifacts: &Path,
    method: Method,
    assume: Assumptions,
    geometry: Option<Geometry>,
) -> Result<PricedJob> {
    let artifact = Artifact::load(artifacts.join(method.eval_variant()))?;
    let io = &artifact.manifest.io;
    let (batch, seq) = (io.batch_size as u64, io.seq_len as u64);
    let geo = geometry.unwrap_or_else(|| Geometry::from_manifest(&artifact.manifest.model));
    let model = MemoryModel::new(geo.clone(), assume);
    Ok(PricedJob {
        peak_gb: model.peak_gb(method.memory_method(), batch, seq),
        host_gb: model.host_state_gb(method.memory_method()),
        batch,
        seq,
        geometry: geo.name.clone(),
    })
}

/// Price a job from the *static* HLO liveness peak of its artifacts
/// (`price_from_hlo`) instead of the analytic model: the maximum
/// schedule-order peak across every program of every stage variant —
/// exactly the quantity `revffn check --hlo-mem` verifies the analytic
/// model against (MM rules, docs/ANALYSIS.md). Host-side cost, batch
/// and seq still come from [`price_job`]: the suspended-snapshot
/// footprint is a runtime-state fact the HLO text does not describe.
/// The geometry label is tagged `hlo:` so `status`/`metrics` output
/// shows which pricer admitted the job.
pub fn price_job_static(
    artifacts: &Path,
    method: Method,
    assume: Assumptions,
    geometry: Option<Geometry>,
) -> Result<PricedJob> {
    let mut priced = price_job(artifacts, method, assume, geometry)?;
    let mut peak: u64 = 0;
    for variant in method.spec().stage_variants {
        let artifact = Artifact::load(artifacts.join(variant))?;
        for kind in method.hlo_mem_programs() {
            if !artifact.manifest.artifacts.contains_key(kind) {
                continue;
            }
            let text = std::fs::read_to_string(artifact.hlo_path(kind)?)?;
            let module = crate::analysis::hlo::parse_module(&text)?;
            peak = peak.max(crate::analysis::liveness::entry_peak(&module)?.peak_bytes);
        }
    }
    priced.peak_gb = peak as f64 / 1e9;
    priced.geometry = format!("hlo:{}", priced.geometry);
    Ok(priced)
}

/// The budget ledger: tracks the summed peak-GB of admitted jobs on
/// the device side AND the summed host-snapshot GB on the host side. A
/// job is admitted only when both fit — suspended jobs' host-side
/// literal mirrors were previously invisible here, letting a
/// budget-full server be OOM'd in host RAM.
#[derive(Debug, Clone)]
pub struct Admission {
    budget_gb: f64,
    committed_gb: f64,
    host_budget_gb: f64,
    host_committed_gb: f64,
    admitted: usize,
}

impl Admission {
    /// Device budget only (host side unbounded).
    pub fn new(budget_gb: f64) -> Self {
        Self::with_host_budget(budget_gb, f64::INFINITY)
    }

    /// Device + host budgets (`host_budget_gb` caps the summed
    /// suspended-snapshot footprint; pass `f64::INFINITY` to disable).
    pub fn with_host_budget(budget_gb: f64, host_budget_gb: f64) -> Self {
        Admission {
            budget_gb,
            committed_gb: 0.0,
            host_budget_gb,
            host_committed_gb: 0.0,
            admitted: 0,
        }
    }

    /// Reserve `peak_gb` device-side and `host_gb` host-side if BOTH
    /// fit. The comparisons carry a tiny relative epsilon so releasing
    /// and re-admitting identical jobs never flips on accumulated
    /// float rounding.
    pub fn try_admit(&mut self, peak_gb: f64, host_gb: f64) -> bool {
        let device_ok = self.committed_gb + peak_gb <= self.budget_gb * (1.0 + 1e-9);
        let host_ok = self.host_committed_gb + host_gb <= self.host_budget_gb * (1.0 + 1e-9);
        if device_ok && host_ok {
            self.committed_gb += peak_gb;
            self.host_committed_gb += host_gb;
            self.admitted += 1;
            true
        } else {
            false
        }
    }

    /// Return a finished/cancelled job's reservations to the pool. When
    /// the last job leaves, both ledgers snap back to exactly zero so
    /// rounding drift cannot accumulate across job generations.
    pub fn release(&mut self, peak_gb: f64, host_gb: f64) {
        self.admitted = self.admitted.saturating_sub(1);
        if self.admitted == 0 {
            self.committed_gb = 0.0;
            self.host_committed_gb = 0.0;
        } else {
            self.committed_gb = (self.committed_gb - peak_gb).max(0.0);
            self.host_committed_gb = (self.host_committed_gb - host_gb).max(0.0);
        }
    }

    pub fn budget_gb(&self) -> f64 {
        self.budget_gb
    }

    pub fn committed_gb(&self) -> f64 {
        self.committed_gb
    }

    pub fn host_budget_gb(&self) -> f64 {
        self.host_budget_gb
    }

    pub fn host_committed_gb(&self) -> f64 {
        self.host_committed_gb
    }

    pub fn headroom_gb(&self) -> f64 {
        (self.budget_gb - self.committed_gb).max(0.0)
    }

    /// Number of currently admitted jobs.
    pub fn admitted(&self) -> usize {
        self.admitted
    }
}

// ----------------------------------------------------------------------
// Per-tenant quotas + weighted-deficit fairness
// ----------------------------------------------------------------------

/// Quota policy for one tenant (or the default applied to any tenant
/// without an override). Zero means "unlimited" for both caps, so the
/// single-tenant deployment keeps PR 4's behavior untouched.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantPolicy {
    /// Max concurrently *admitted* jobs (queued jobs don't count).
    pub max_jobs: usize,
    /// Max summed device peak-GB across the tenant's admitted jobs —
    /// the tenant's share of the device budget, in the same
    /// `memory::model` pricing units the global ledger uses.
    pub share_gb: f64,
    /// Fairness weight for deficit accounting (must be > 0; a tenant
    /// with weight 2 is owed twice the device-GB throughput of a
    /// weight-1 tenant before the scheduler prefers the latter).
    pub weight: f64,
}

impl Default for TenantPolicy {
    fn default() -> Self {
        TenantPolicy { max_jobs: 0, share_gb: 0.0, weight: 1.0 }
    }
}

#[derive(Debug, Clone, Default)]
struct TenantLedger {
    jobs: usize,
    gb: f64,
    /// Normalized service received: Σ admitted peak_gb / weight. The
    /// admission picker always prefers the lowest-debt tenant, and debt
    /// persists across a tenant going idle (the carry-over), so a heavy
    /// tenant cannot starve others by resubmitting faster.
    debt: f64,
}

/// Per-tenant admission ledgers. The global [`Admission`] budget stays
/// the hard capacity gate; this layer enforces *fairness* on top of it:
/// hard per-tenant caps (`max_jobs`, `share_gb`) plus weighted-deficit
/// ordering for the scheduler's pick among waiting tenants.
#[derive(Debug, Clone, Default)]
pub struct Tenants {
    default_policy: TenantPolicy,
    overrides: BTreeMap<String, TenantPolicy>,
    ledgers: BTreeMap<String, TenantLedger>,
}

impl Tenants {
    pub fn new(default_policy: TenantPolicy) -> Self {
        Tenants { default_policy, ..Default::default() }
    }

    /// Install a per-tenant override (config `tenants` table).
    pub fn set_policy(&mut self, tenant: &str, policy: TenantPolicy) {
        self.overrides.insert(tenant.to_string(), policy);
    }

    pub fn policy(&self, tenant: &str) -> &TenantPolicy {
        self.overrides.get(tenant).unwrap_or(&self.default_policy)
    }

    /// Would admitting a `peak_gb` job keep `tenant` within its quota?
    /// (The global budget is checked separately by [`Admission`].) The
    /// share comparison carries the same relative epsilon as the global
    /// ledger so release/re-admit cycles never flip on float rounding.
    pub fn admits(&self, tenant: &str, peak_gb: f64) -> bool {
        let pol = self.policy(tenant);
        let led = self.ledgers.get(tenant);
        let (jobs, gb) = led.map_or((0, 0.0), |l| (l.jobs, l.gb));
        let jobs_ok = pol.max_jobs == 0 || jobs < pol.max_jobs;
        let share_ok = pol.share_gb == 0.0 || gb + peak_gb <= pol.share_gb * (1.0 + 1e-9);
        jobs_ok && share_ok
    }

    /// Record an admission: bumps the tenant's live usage and its
    /// normalized debt (`peak_gb / weight`). A tenant first seen here
    /// joins at the lowest live debt, not at zero — otherwise renaming
    /// yourself would reset your place in line.
    pub fn charge(&mut self, tenant: &str, peak_gb: f64) {
        let floor = self.debt_floor();
        let weight = self.policy(tenant).weight.max(1e-9);
        let led = self.ledgers.entry(tenant.to_string()).or_insert(TenantLedger {
            jobs: 0,
            gb: 0.0,
            debt: floor,
        });
        led.jobs += 1;
        led.gb += peak_gb;
        led.debt += peak_gb / weight;
    }

    /// Return a leaving job's share. Usage snaps to zero when the
    /// tenant's last job leaves; debt is deliberately kept — it IS the
    /// carry-over.
    pub fn release(&mut self, tenant: &str, peak_gb: f64) {
        if let Some(led) = self.ledgers.get_mut(tenant) {
            led.jobs = led.jobs.saturating_sub(1);
            led.gb = if led.jobs == 0 { 0.0 } else { (led.gb - peak_gb).max(0.0) };
        }
    }

    /// Normalized service debt used to order tenants (lower = picked
    /// first). Unseen tenants report the current floor.
    pub fn debt(&self, tenant: &str) -> f64 {
        self.ledgers.get(tenant).map_or_else(|| self.debt_floor(), |l| l.debt)
    }

    /// Currently admitted jobs of one tenant.
    pub fn jobs(&self, tenant: &str) -> usize {
        self.ledgers.get(tenant).map_or(0, |l| l.jobs)
    }

    /// Currently committed device-GB of one tenant.
    pub fn committed_gb(&self, tenant: &str) -> f64 {
        self.ledgers.get(tenant).map_or(0.0, |l| l.gb)
    }

    /// Every tenant ever seen with its current service debt, in name
    /// order — the exposition layer mirrors this onto the board so the
    /// `metrics` verb can label a debt gauge per tenant.
    pub fn debts(&self) -> Vec<(String, f64)> {
        self.ledgers.iter().map(|(t, l)| (t.clone(), l.debt)).collect()
    }

    /// Lowest debt among tenants with live jobs (0 when none): the
    /// join-point for newcomers.
    fn debt_floor(&self) -> f64 {
        let floor = self
            .ledgers
            .values()
            .filter(|l| l.jobs > 0)
            .map(|l| l.debt)
            .fold(f64::INFINITY, f64::min);
        if floor.is_finite() {
            floor
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fine-tuning-shaped workload where activations matter: deep
    /// model, large batch, long sequences.
    fn deep_geo() -> Geometry {
        let mut g = Geometry::qwen15_moe_a27b();
        g.n_layers = 48;
        g
    }

    fn fit_count(geo: &Geometry, method: Method, budget_gb: f64) -> usize {
        let p = price(geo, method, Assumptions::paper_calibrated(), 256, 4096);
        let mut adm = Admission::new(budget_gb);
        let mut n = 0;
        while adm.try_admit(p, 0.0) {
            n += 1;
            assert!(n < 1000, "runaway admission");
        }
        n
    }

    #[test]
    fn revffn_prices_below_sft_at_training_shapes() {
        let geo = deep_geo();
        let a = Assumptions::paper_calibrated();
        let rev = price(&geo, Method::Revffn, a, 256, 4096);
        let sft = price(&geo, Method::Sft, a, 256, 4096);
        assert!(rev < sft, "revffn {rev:.1} GB must undercut sft {sft:.1} GB");
    }

    #[test]
    fn more_revffn_jobs_fit_than_sft_under_same_budget() {
        // The acceptance-criterion property: RevFFN jobs price
        // depth-independent activations, so a fixed budget admits more
        // of them concurrently than SFT jobs.
        let geo = deep_geo();
        let sft_price = price(&geo, Method::Sft, Assumptions::paper_calibrated(), 256, 4096);
        let budget = 4.5 * sft_price;
        let n_sft = fit_count(&geo, Method::Sft, budget);
        let n_rev = fit_count(&geo, Method::Revffn, budget);
        assert!(n_sft >= 1);
        assert!(
            n_rev > n_sft,
            "same budget must admit more revffn jobs: {n_rev} vs {n_sft}"
        );
    }

    #[test]
    fn revffn_price_grows_slower_with_depth_than_sft() {
        // Doubling depth adds weights for everyone, but activation
        // growth only for non-reversible methods.
        let a = Assumptions::paper_calibrated();
        let mut g = Geometry::qwen15_moe_a27b();
        g.n_layers = 24;
        let rev24 = price(&g, Method::Revffn, a, 64, 2048);
        let sft24 = price(&g, Method::Sft, a, 64, 2048);
        g.n_layers = 96;
        let rev96 = price(&g, Method::Revffn, a, 64, 2048);
        let sft96 = price(&g, Method::Sft, a, 64, 2048);
        assert!(rev96 - rev24 < sft96 - sft24);
    }

    #[test]
    fn release_frees_budget_for_queued_jobs() {
        let mut adm = Admission::new(10.0);
        assert!(adm.try_admit(6.0, 0.0));
        assert!(!adm.try_admit(6.0, 0.0), "second job must not fit");
        adm.release(6.0, 0.0);
        assert_eq!(adm.admitted(), 0);
        assert_eq!(adm.committed_gb(), 0.0);
        assert!(adm.try_admit(6.0, 0.0), "released budget must re-admit");
    }

    #[test]
    fn admission_ledger_tracks_sums() {
        let mut adm = Admission::new(10.0);
        assert!(adm.try_admit(3.0, 0.0));
        assert!(adm.try_admit(4.0, 0.0));
        assert!((adm.committed_gb() - 7.0).abs() < 1e-12);
        assert!((adm.headroom_gb() - 3.0).abs() < 1e-12);
        assert_eq!(adm.admitted(), 2);
        assert!(!adm.try_admit(3.5, 0.0));
        adm.release(3.0, 0.0);
        assert!(adm.try_admit(3.5, 0.0));
    }

    #[test]
    fn single_job_over_budget_never_admits() {
        let mut adm = Admission::new(1.0);
        assert!(!adm.try_admit(1.5, 0.0));
        assert_eq!(adm.admitted(), 0);
    }

    #[test]
    fn host_budget_blocks_admission_even_with_device_headroom() {
        // the host-mirror OOM fix: device budget fits three jobs, but
        // their suspended snapshots only fit two host-side
        let mut adm = Admission::with_host_budget(30.0, 5.0);
        assert!(adm.try_admit(6.0, 2.0));
        assert!(adm.try_admit(6.0, 2.0));
        assert!(!adm.try_admit(6.0, 2.0), "third job must be blocked by the host ledger");
        assert!((adm.host_committed_gb() - 4.0).abs() < 1e-12);
        assert!((adm.committed_gb() - 12.0).abs() < 1e-12, "device side untouched by refusal");
        adm.release(6.0, 2.0);
        assert!(adm.try_admit(6.0, 2.0), "released host budget must re-admit");
    }

    #[test]
    fn unbounded_host_budget_never_blocks() {
        let mut adm = Admission::new(100.0);
        for _ in 0..10 {
            assert!(adm.try_admit(5.0, 1e12));
        }
        adm.release(5.0, 1e12);
        assert_eq!(adm.admitted(), 9);
    }

    #[test]
    fn both_ledgers_snap_to_zero_when_empty() {
        let mut adm = Admission::with_host_budget(10.0, 10.0);
        assert!(adm.try_admit(0.1 + 0.2, 0.1 + 0.2)); // float-noisy prices
        adm.release(0.3, 0.3);
        assert_eq!(adm.committed_gb(), 0.0);
        assert_eq!(adm.host_committed_gb(), 0.0);
    }

    #[test]
    fn tenant_max_jobs_caps_concurrency() {
        let mut t = Tenants::new(TenantPolicy { max_jobs: 2, share_gb: 0.0, weight: 1.0 });
        assert!(t.admits("a", 1.0));
        t.charge("a", 1.0);
        assert!(t.admits("a", 1.0));
        t.charge("a", 1.0);
        assert!(!t.admits("a", 1.0), "third concurrent job must be quota-blocked");
        assert!(t.admits("b", 1.0), "another tenant is unaffected");
        t.release("a", 1.0);
        assert!(t.admits("a", 1.0), "released slot re-admits");
    }

    #[test]
    fn tenant_share_gb_caps_device_footprint() {
        let mut t = Tenants::new(TenantPolicy { max_jobs: 0, share_gb: 5.0, weight: 1.0 });
        assert!(t.admits("a", 3.0));
        t.charge("a", 3.0);
        assert!(t.admits("a", 2.0), "exactly at share must fit");
        t.charge("a", 2.0);
        assert!(!t.admits("a", 0.5));
        assert!((t.committed_gb("a") - 5.0).abs() < 1e-12);
        t.release("a", 3.0);
        assert!(t.admits("a", 3.0));
    }

    #[test]
    fn default_policy_is_unlimited() {
        let mut t = Tenants::default();
        for _ in 0..100 {
            assert!(t.admits("solo", 10.0));
            t.charge("solo", 10.0);
        }
        assert_eq!(t.jobs("solo"), 100);
    }

    #[test]
    fn per_tenant_override_beats_default() {
        let mut t = Tenants::new(TenantPolicy::default());
        t.set_policy("capped", TenantPolicy { max_jobs: 1, share_gb: 0.0, weight: 1.0 });
        t.charge("capped", 1.0);
        assert!(!t.admits("capped", 1.0));
        assert!(t.admits("free", 1.0));
    }

    #[test]
    fn debt_orders_heavy_tenant_behind_light_one() {
        let mut t = Tenants::default();
        t.charge("heavy", 8.0);
        t.charge("light", 1.0);
        assert!(t.debt("heavy") > t.debt("light"));
        // release does NOT erase debt — the carry-over
        t.release("heavy", 8.0);
        assert!(t.debt("heavy") > t.debt("light"));
    }

    #[test]
    fn weight_scales_debt_accrual() {
        let mut t = Tenants::new(TenantPolicy::default());
        t.set_policy("vip", TenantPolicy { max_jobs: 0, share_gb: 0.0, weight: 4.0 });
        t.charge("vip", 4.0);
        t.charge("std", 4.0);
        assert!(
            t.debt("vip") < t.debt("std"),
            "same GB must cost a weight-4 tenant a quarter of the debt"
        );
    }

    #[test]
    fn newcomer_joins_at_live_floor_not_zero() {
        let mut t = Tenants::default();
        t.charge("a", 6.0);
        t.charge("b", 9.0);
        // newcomer starts at the lowest live debt (a's 6.0), so it gets
        // preference over b but no infinite backlog of credit
        assert!((t.debt("new") - 6.0).abs() < 1e-12);
        t.charge("new", 1.0);
        assert!(t.debt("new") > t.debt("a"));
        assert!(t.debt("new") < t.debt("b"));
    }

    #[test]
    fn tenant_usage_snaps_to_zero_when_idle() {
        let mut t = Tenants::default();
        t.charge("a", 0.1 + 0.2); // float-noisy price
        t.release("a", 0.3);
        assert_eq!(t.committed_gb("a"), 0.0);
        assert_eq!(t.jobs("a"), 0);
    }

    #[test]
    fn priced_job_host_cost_below_device_peak() {
        let geo = deep_geo();
        let a = Assumptions::paper_calibrated();
        let model = crate::memory::MemoryModel::new(geo.clone(), a);
        let host = model.host_state_gb(Method::Revffn.memory_method());
        let peak = price(&geo, Method::Revffn, a, 256, 4096);
        assert!(host > 0.0);
        assert!(host < peak, "host snapshot {host:.1} GB must undercut device peak {peak:.1} GB");
    }
}
