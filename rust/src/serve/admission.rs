//! Admission control: price each submitted job's peak VRAM with the
//! analytic memory model and admit only while the sum fits the budget.
//!
//! This turns `memory::model` from a reporting tool into an operational
//! gate, and it is where RevFFN's depth-independent activation live-set
//! (§3.1) becomes a serving property instead of a table row: at the
//! same `budget_gb`, more concurrent RevFFN fine-tuning jobs are
//! admitted than SFT jobs, because each prices a smaller peak — the gap
//! grows with batch·seq·layers (LOMO-style work, arXiv 2306.09782,
//! similarly treats the memory budget as the first-class scheduling
//! constraint). A job's price is fixed at submit time; the scheduler
//! releases the reservation when the job finishes, fails, or is
//! cancelled.

use std::path::Path;

use crate::engine::Method;
use crate::error::Result;
use crate::memory::{Assumptions, Geometry, MemoryModel};
use crate::runtime::artifact::Artifact;

/// Peak-VRAM price (GB) of one job at a geometry/method/batch/seq.
pub fn price(geo: &Geometry, method: Method, assume: Assumptions, batch: u64, seq: u64) -> f64 {
    MemoryModel::new(geo.clone(), assume).peak_gb(method.memory_method(), batch, seq)
}

/// A submitted job priced for admission.
#[derive(Debug, Clone)]
pub struct PricedJob {
    pub peak_gb: f64,
    pub batch: u64,
    pub seq: u64,
    /// Name of the geometry the price was computed at.
    pub geometry: String,
}

/// Price a job from its artifact set: batch/seq come from the method's
/// eval-variant manifest; the geometry does too unless `geometry`
/// overrides it (e.g. pricing a tiny-artifact job at Qwen scale). Only
/// the manifest is read — no XLA work.
pub fn price_job(
    artifacts: &Path,
    method: Method,
    assume: Assumptions,
    geometry: Option<Geometry>,
) -> Result<PricedJob> {
    let artifact = Artifact::load(artifacts.join(method.eval_variant()))?;
    let io = &artifact.manifest.io;
    let (batch, seq) = (io.batch_size as u64, io.seq_len as u64);
    let geo = geometry.unwrap_or_else(|| Geometry::from_manifest(&artifact.manifest.model));
    Ok(PricedJob {
        peak_gb: price(&geo, method, assume, batch, seq),
        batch,
        seq,
        geometry: geo.name.clone(),
    })
}

/// The budget ledger: tracks the summed peak-GB of admitted jobs.
#[derive(Debug, Clone)]
pub struct Admission {
    budget_gb: f64,
    committed_gb: f64,
    admitted: usize,
}

impl Admission {
    pub fn new(budget_gb: f64) -> Self {
        Admission { budget_gb, committed_gb: 0.0, admitted: 0 }
    }

    /// Reserve `peak_gb` if it fits. The comparison carries a tiny
    /// relative epsilon so releasing and re-admitting identical jobs
    /// never flips on accumulated float rounding.
    pub fn try_admit(&mut self, peak_gb: f64) -> bool {
        if self.committed_gb + peak_gb <= self.budget_gb * (1.0 + 1e-9) {
            self.committed_gb += peak_gb;
            self.admitted += 1;
            true
        } else {
            false
        }
    }

    /// Return a finished/cancelled job's reservation to the pool. When
    /// the last job leaves, the ledger snaps back to exactly zero so
    /// rounding drift cannot accumulate across job generations.
    pub fn release(&mut self, peak_gb: f64) {
        self.admitted = self.admitted.saturating_sub(1);
        self.committed_gb = if self.admitted == 0 {
            0.0
        } else {
            (self.committed_gb - peak_gb).max(0.0)
        };
    }

    pub fn budget_gb(&self) -> f64 {
        self.budget_gb
    }

    pub fn committed_gb(&self) -> f64 {
        self.committed_gb
    }

    pub fn headroom_gb(&self) -> f64 {
        (self.budget_gb - self.committed_gb).max(0.0)
    }

    /// Number of currently admitted jobs.
    pub fn admitted(&self) -> usize {
        self.admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fine-tuning-shaped workload where activations matter: deep
    /// model, large batch, long sequences.
    fn deep_geo() -> Geometry {
        let mut g = Geometry::qwen15_moe_a27b();
        g.n_layers = 48;
        g
    }

    fn fit_count(geo: &Geometry, method: Method, budget_gb: f64) -> usize {
        let p = price(geo, method, Assumptions::paper_calibrated(), 256, 4096);
        let mut adm = Admission::new(budget_gb);
        let mut n = 0;
        while adm.try_admit(p) {
            n += 1;
            assert!(n < 1000, "runaway admission");
        }
        n
    }

    #[test]
    fn revffn_prices_below_sft_at_training_shapes() {
        let geo = deep_geo();
        let a = Assumptions::paper_calibrated();
        let rev = price(&geo, Method::Revffn, a, 256, 4096);
        let sft = price(&geo, Method::Sft, a, 256, 4096);
        assert!(rev < sft, "revffn {rev:.1} GB must undercut sft {sft:.1} GB");
    }

    #[test]
    fn more_revffn_jobs_fit_than_sft_under_same_budget() {
        // The acceptance-criterion property: RevFFN jobs price
        // depth-independent activations, so a fixed budget admits more
        // of them concurrently than SFT jobs.
        let geo = deep_geo();
        let sft_price = price(&geo, Method::Sft, Assumptions::paper_calibrated(), 256, 4096);
        let budget = 4.5 * sft_price;
        let n_sft = fit_count(&geo, Method::Sft, budget);
        let n_rev = fit_count(&geo, Method::Revffn, budget);
        assert!(n_sft >= 1);
        assert!(
            n_rev > n_sft,
            "same budget must admit more revffn jobs: {n_rev} vs {n_sft}"
        );
    }

    #[test]
    fn revffn_price_grows_slower_with_depth_than_sft() {
        // Doubling depth adds weights for everyone, but activation
        // growth only for non-reversible methods.
        let a = Assumptions::paper_calibrated();
        let mut g = Geometry::qwen15_moe_a27b();
        g.n_layers = 24;
        let rev24 = price(&g, Method::Revffn, a, 64, 2048);
        let sft24 = price(&g, Method::Sft, a, 64, 2048);
        g.n_layers = 96;
        let rev96 = price(&g, Method::Revffn, a, 64, 2048);
        let sft96 = price(&g, Method::Sft, a, 64, 2048);
        assert!(rev96 - rev24 < sft96 - sft24);
    }

    #[test]
    fn release_frees_budget_for_queued_jobs() {
        let mut adm = Admission::new(10.0);
        assert!(adm.try_admit(6.0));
        assert!(!adm.try_admit(6.0), "second job must not fit");
        adm.release(6.0);
        assert_eq!(adm.admitted(), 0);
        assert_eq!(adm.committed_gb(), 0.0);
        assert!(adm.try_admit(6.0), "released budget must re-admit");
    }

    #[test]
    fn admission_ledger_tracks_sums() {
        let mut adm = Admission::new(10.0);
        assert!(adm.try_admit(3.0));
        assert!(adm.try_admit(4.0));
        assert!((adm.committed_gb() - 7.0).abs() < 1e-12);
        assert!((adm.headroom_gb() - 3.0).abs() < 1e-12);
        assert_eq!(adm.admitted(), 2);
        assert!(!adm.try_admit(3.5));
        adm.release(3.0);
        assert!(adm.try_admit(3.5));
    }

    #[test]
    fn single_job_over_budget_never_admits() {
        let mut adm = Admission::new(1.0);
        assert!(!adm.try_admit(1.5));
        assert_eq!(adm.admitted(), 0);
    }
}
