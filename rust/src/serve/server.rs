//! The `revffn serve` TCP control plane.
//!
//! Three thread roles, all std-only:
//!
//! * **Scheduler thread** — creates the PJRT device (the client is not
//!   `Send`, so it must be born here), owns the [`Scheduler`], and
//!   loops: drain control messages (submit/cancel arrive over an mpsc
//!   channel, in arrival order — which is what makes the interleaving
//!   deterministic), then drive one [`Scheduler::tick`]. When idle it
//!   parks on the channel with a timeout instead of spinning.
//! * **Accept thread** — polls a non-blocking `TcpListener`, spawning a
//!   handler thread per connection.
//! * **Handler threads** — speak the NDJSON protocol: requests in,
//!   responses out, and for the `events` verb a follow-loop that copies
//!   new lines out of the shared [`Board`] until the job is terminal.
//!
//! Handlers never touch the device; everything they read comes off the
//! board, everything they change goes through the control channel.
//!
//! Degradation posture (docs/ROBUSTNESS.md): sockets carry read/write
//! timeouts and the accept loop enforces a connection cap, so a slow or
//! hostile client times out or is turned away at the door instead of
//! pinning a handler thread forever; an `events` follower that stops
//! draining is disconnected when its writes time out.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::ServeConfig;
use crate::error::{Error, Result};
use crate::obs::{self, prom, registry};
use crate::runtime::Device;
use crate::serve::lock;
use crate::serve::protocol::{self, JobState, Request};
use crate::serve::scheduler::{Board, Scheduler, SubmitMeta, SubmitOutcome};
use crate::util::faults::{self, FaultSite};
use crate::util::json::Json;
use crate::util::retry;

/// How long the scheduler parks on the control channel when idle, and
/// how often event followers re-poll the board.
const POLL: Duration = Duration::from_millis(25);

/// `true` for the error kinds a timed-out socket read/write produces
/// (`WouldBlock` on unix, `TimedOut` on windows).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// RAII slot in the connection cap: decrements on drop, however the
/// handler thread exits.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Control messages from handler threads to the scheduler thread.
enum Control {
    Submit {
        config: Json,
        name: Option<String>,
        meta: SubmitMeta,
        reply: Sender<std::result::Result<SubmitOutcome, String>>,
    },
    Cancel {
        job: String,
        reply: Sender<std::result::Result<bool, String>>,
    },
    /// Resubmit a failed/cancelled/quarantined job from its latest
    /// snapshot.
    Resume {
        job: String,
        reply: Sender<std::result::Result<SubmitOutcome, String>>,
    },
    /// Wake the scheduler loop so it notices the shutdown flag.
    Shutdown,
}

/// A running serve instance. Dropping the handle does NOT stop the
/// server — call [`ServerHandle::shutdown`] (or send the `shutdown`
/// verb) and then [`ServerHandle::join`].
pub struct ServerHandle {
    addr: SocketAddr,
    board: Arc<Mutex<Board>>,
    ctl: Sender<Control>,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// The address actually bound (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared job board (tests inspect it directly).
    pub fn board(&self) -> Arc<Mutex<Board>> {
        self.board.clone()
    }

    /// Ask every thread to stop (idempotent).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = self.ctl.send(Control::Shutdown);
    }

    /// Wait for the accept + scheduler threads to exit.
    pub fn join(mut self) -> Result<()> {
        for t in self.threads.drain(..) {
            t.join().map_err(|_| Error::Training("server thread panicked".into()))?;
        }
        Ok(())
    }
}

/// Bind the control plane and start serving. Returns once the listener
/// is bound; scheduling runs on background threads until `shutdown`.
pub fn serve(opts: ServeConfig) -> Result<ServerHandle> {
    // telemetry arms here, once: the `metrics` verb scrapes the
    // process-global registry, so counters must be live before the
    // first request can land
    registry::arm();
    // fault injection arms here, once, before any thread can hit a
    // failpoint (REVFFN_FAULTS overrides the config plan)
    if faults::install_from(opts.faults.as_deref())? {
        eprintln!("[serve] fault injection armed");
    }
    let listener = TcpListener::bind(&opts.addr).map_err(|e| {
        Error::Io(std::io::Error::new(e.kind(), format!("bind {}: {e}", opts.addr)))
    })?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let (ctl_tx, ctl_rx) = channel::<Control>();

    // the scheduler thread creates its own Device (PJRT clients are not
    // Send); the board comes back over a bootstrap channel
    let (board_tx, board_rx) = channel::<std::result::Result<Arc<Mutex<Board>>, String>>();
    let sched_opts = opts.clone();
    let sched_shutdown = shutdown.clone();
    let sched_thread = std::thread::Builder::new()
        .name("serve-scheduler".into())
        .spawn(move || scheduler_thread(sched_opts, ctl_rx, board_tx, sched_shutdown))?;
    let board = board_rx
        .recv()
        .map_err(|_| Error::Training("scheduler thread died during startup".into()))?
        .map_err(Error::Training)?;

    let accept_board = board.clone();
    let accept_ctl = ctl_tx.clone();
    let accept_shutdown = shutdown.clone();
    let conn_limit = opts.conn_limit;
    let io_timeout = (opts.io_timeout_ms > 0).then(|| Duration::from_millis(opts.io_timeout_ms));
    let page_size = opts.events_page_size;
    let accept_thread = std::thread::Builder::new().name("serve-accept".into()).spawn(move || {
        accept_loop(
            listener,
            accept_ctl,
            accept_board,
            accept_shutdown,
            conn_limit,
            io_timeout,
            page_size,
        )
    })?;

    Ok(ServerHandle {
        addr,
        board,
        ctl: ctl_tx,
        shutdown,
        threads: vec![sched_thread, accept_thread],
    })
}

fn scheduler_thread(
    opts: ServeConfig,
    ctl: Receiver<Control>,
    board_tx: Sender<std::result::Result<Arc<Mutex<Board>>, String>>,
    shutdown: Arc<AtomicBool>,
) {
    let recover = opts.recover;
    let sched = Device::cpu()
        .map_err(|e| format!("creating PJRT device: {e}"))
        .and_then(|device| {
            Scheduler::new(device, opts).map_err(|e| format!("starting scheduler: {e}"))
        });
    let mut sched = match sched {
        Ok(s) => {
            let _ = board_tx.send(Ok(s.board()));
            s
        }
        Err(msg) => {
            let _ = board_tx.send(Err(msg));
            return;
        }
    };
    // crash recovery: rescan run_root for interrupted jobs (persisted
    // job.json + a periodic snapshot) and resume them from their
    // latest checkpoints before taking new traffic
    if recover {
        let n = sched.recover();
        if n > 0 {
            eprintln!("[serve] recovered {n} interrupted job(s) from disk");
        }
    }
    loop {
        if shutdown.load(Ordering::SeqCst) {
            sched.cancel_all();
            return;
        }
        // drain pending control messages in arrival order
        while let Ok(msg) = ctl.try_recv() {
            handle_control(&mut sched, msg);
        }
        match sched.tick() {
            Ok(true) => {}
            Ok(false) => {
                // idle: park on the channel instead of spinning
                match ctl.recv_timeout(POLL) {
                    Ok(msg) => handle_control(&mut sched, msg),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => return,
                }
            }
            Err(e) => {
                // tick() errors are per-job and recorded on the board;
                // an error escaping here is a scheduler invariant break
                eprintln!("[serve] scheduler error: {e}");
                sched.cancel_all();
                shutdown.store(true, Ordering::SeqCst);
                return;
            }
        }
    }
}

fn handle_control(sched: &mut Scheduler, msg: Control) {
    match msg {
        Control::Submit { config, name, meta, reply } => {
            let r = sched.submit_json(&config, name, meta).map_err(|e| e.to_string());
            let _ = reply.send(r);
        }
        Control::Cancel { job, reply } => {
            let r = sched.cancel(&job).map_err(|e| e.to_string());
            let _ = reply.send(r);
        }
        Control::Resume { job, reply } => {
            let r = sched.resume_job(&job).map_err(|e| e.to_string());
            let _ = reply.send(r);
        }
        Control::Shutdown => {}
    }
}

fn accept_loop(
    listener: TcpListener,
    ctl: Sender<Control>,
    board: Arc<Mutex<Board>>,
    shutdown: Arc<AtomicBool>,
    conn_limit: usize,
    io_timeout: Option<Duration>,
    page_size: usize,
) {
    let conns = Arc::new(AtomicUsize::new(0));
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                // socket deadlines: a peer that stops reading or
                // writing gets a timeout error on the handler thread,
                // not a thread wedged forever
                let _ = stream.set_read_timeout(io_timeout);
                let _ = stream.set_write_timeout(io_timeout);
                // connection cap: refuse with a parseable error line
                // rather than accumulating handler threads without
                // bound (0 = uncapped)
                if conn_limit > 0 && conns.fetch_add(1, Ordering::SeqCst) >= conn_limit {
                    conns.fetch_sub(1, Ordering::SeqCst);
                    let _ =
                        write_line(&mut stream, &error_line("server at connection capacity"));
                    continue;
                }
                let guard = ConnGuard(conns.clone());
                let ctl = ctl.clone();
                let board = board.clone();
                let shutdown = shutdown.clone();
                let _ = std::thread::Builder::new().name("serve-conn".into()).spawn(move || {
                    let _guard = guard;
                    if let Err(e) = handle_connection(stream, ctl, board, shutdown, page_size) {
                        eprintln!("[serve] connection: {e}");
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                retry::pause(POLL);
            }
            Err(e) => {
                eprintln!("[serve] accept: {e}");
                retry::pause(POLL);
            }
        }
    }
}

fn write_line(stream: &mut TcpStream, j: &Json) -> std::io::Result<()> {
    faults::io_failpoint(FaultSite::WireWrite)?;
    let mut line = j.to_string();
    line.push('\n');
    stream.write_all(line.as_bytes())?;
    stream.flush()
}

/// Build an error reply and count it (`revffn_wire_errors_total`).
fn error_line(msg: &str) -> Json {
    registry::inc(registry::Counter::WireErrors);
    protocol::error_json(msg)
}

/// RAII increment of the active-followers gauge: one per live `events`
/// follow stream, decremented however the handler exits.
struct FollowerGauge;

impl FollowerGauge {
    fn new() -> Self {
        registry::gauge_inc(registry::Gauge::FollowersActive);
        FollowerGauge
    }
}

impl Drop for FollowerGauge {
    fn drop(&mut self) {
        registry::gauge_dec(registry::Gauge::FollowersActive);
    }
}

/// Assemble the full Prometheus exposition for the `metrics` verb:
/// process-global registry families plus scheduler gauges derived from
/// the board at scrape time.
fn metrics_response(b: &Board) -> Json {
    let mut fams = prom::registry_families();
    fams.extend(board_families(b));
    let body = prom::render(&fams);
    protocol::metrics_json(registry::value(registry::Counter::Steps), &body)
}

/// Scheduler-state families: per-tenant queue depth / active jobs /
/// reserved GB (aggregated from live job rows), per-tenant debt and
/// deadline misses (off the board maps the scheduler refreshes),
/// per-class queue depth, jobs-by-state, and the memory ledgers.
fn board_families(b: &Board) -> Vec<prom::Family> {
    use crate::obs::prom::{Family, Kind, Sample};
    use crate::serve::protocol::Priority;
    let mut queued: std::collections::BTreeMap<&str, u64> = Default::default();
    let mut active: std::collections::BTreeMap<&str, u64> = Default::default();
    let mut reserved: std::collections::BTreeMap<&str, f64> = Default::default();
    let mut class_queued: std::collections::BTreeMap<&'static str, u64> = Default::default();
    let mut by_state: std::collections::BTreeMap<&'static str, u64> = Default::default();
    for state in [
        JobState::Queued,
        JobState::Running,
        JobState::Finished,
        JobState::Failed,
        JobState::Cancelled,
        JobState::Retrying,
        JobState::Quarantined,
    ] {
        by_state.insert(state.name(), 0);
    }
    for class in [Priority::Batch, Priority::Normal, Priority::Interactive] {
        class_queued.insert(class.name(), 0);
    }
    for v in &b.jobs {
        let s = &v.snap;
        *by_state.entry(s.state.name()).or_insert(0) += 1;
        match s.state {
            JobState::Queued => {
                *queued.entry(&s.tenant).or_insert(0) += 1;
                *class_queued.entry(s.priority.name()).or_insert(0) += 1;
            }
            JobState::Running => {
                *active.entry(&s.tenant).or_insert(0) += 1;
                *reserved.entry(&s.tenant).or_insert(0.0) += s.peak_gb;
            }
            _ => {}
        }
    }
    let tenant = |m: &std::collections::BTreeMap<&str, u64>| -> Vec<Sample> {
        m.iter().map(|(t, v)| Sample::new(vec![("tenant", t.to_string())], *v as f64)).collect()
    };
    let scalar = |v: f64| vec![Sample::new(Vec::new(), v)];
    vec![
        Family {
            name: prom::TENANT_QUEUE_DEPTH,
            help: "Queued jobs per tenant.",
            kind: Kind::Gauge,
            samples: tenant(&queued),
        },
        Family {
            name: prom::TENANT_ACTIVE_JOBS,
            help: "Running jobs per tenant.",
            kind: Kind::Gauge,
            samples: tenant(&active),
        },
        Family {
            name: prom::TENANT_RESERVED_GB,
            help: "Admitted accelerator reservation per tenant, GB.",
            kind: Kind::Gauge,
            samples: reserved
                .iter()
                .map(|(t, v)| Sample::new(vec![("tenant", t.to_string())], *v))
                .collect(),
        },
        Family {
            name: prom::TENANT_DEBT,
            help: "Weighted service debt per tenant (admission fairness).",
            kind: Kind::Gauge,
            samples: b
                .tenant_debt
                .iter()
                .map(|(t, v)| Sample::new(vec![("tenant", t.to_string())], *v))
                .collect(),
        },
        Family {
            name: prom::TENANT_DEADLINE_MISS,
            help: "Jobs that missed their submitted deadline, per tenant.",
            kind: Kind::Counter,
            samples: b
                .tenant_misses
                .iter()
                .map(|(t, v)| Sample::new(vec![("tenant", t.to_string())], *v as f64))
                .collect(),
        },
        Family {
            name: prom::CLASS_QUEUE_DEPTH,
            help: "Queued jobs per scheduling class.",
            kind: Kind::Gauge,
            samples: class_queued
                .iter()
                .map(|(c, v)| Sample::new(vec![("class", c.to_string())], *v as f64))
                .collect(),
        },
        Family {
            name: prom::JOBS_BY_STATE,
            help: "Jobs on the board by lifecycle state.",
            kind: Kind::Gauge,
            samples: by_state
                .iter()
                .map(|(s, v)| Sample::new(vec![("state", s.to_string())], *v as f64))
                .collect(),
        },
        Family {
            name: prom::BUDGET_GB,
            help: "Configured accelerator memory budget, GB.",
            kind: Kind::Gauge,
            samples: scalar(b.budget_gb),
        },
        Family {
            name: prom::COMMITTED_GB,
            help: "Accelerator memory committed to admitted jobs, GB.",
            kind: Kind::Gauge,
            samples: scalar(b.committed_gb),
        },
        Family {
            name: prom::HOST_BUDGET_GB,
            help: "Configured host snapshot budget, GB (0 = unbounded).",
            kind: Kind::Gauge,
            samples: scalar(b.host_budget_gb),
        },
        Family {
            name: prom::HOST_COMMITTED_GB,
            help: "Host memory committed to suspended snapshots, GB.",
            kind: Kind::Gauge,
            samples: scalar(b.host_committed_gb),
        },
    ]
}

fn handle_connection(
    stream: TcpStream,
    ctl: Sender<Control>,
    board: Arc<Mutex<Board>>,
    shutdown: Arc<AtomicBool>,
    page_size: usize,
) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            // an idle or wedged client hit the socket deadline: close
            // this connection quietly, the server is fine
            Err(e) if is_timeout(&e) => return Ok(()),
            Err(e) => return Err(e.into()),
        };
        faults::io_failpoint(FaultSite::WireRead)?;
        if line.trim().is_empty() {
            continue;
        }
        // the hot path: a lazy scan settles scalar verbs without
        // building a Json tree; submit and malformed lines fall back to
        // the full parser (identical behavior, pinned by wire tests)
        let req = {
            let _sp = obs::span(obs::Site::WireRead);
            Request::from_line_fast(&line)
        };
        let req = match req {
            Ok(r) => r,
            Err(e) => {
                write_line(&mut out, &error_line(&e.to_string()))?;
                continue;
            }
        };
        registry::inc(registry::Counter::WireRequests);
        let _handle_sp = obs::span(obs::Site::WireHandle);
        match req {
            Request::Submit { config, name, priority, tenant, deadline_ms } => {
                let meta = SubmitMeta { priority, tenant, deadline_ms };
                let (reply_tx, reply_rx) = channel();
                if ctl.send(Control::Submit { config, name, meta, reply: reply_tx }).is_err() {
                    write_line(&mut out, &error_line("scheduler stopped"))?;
                    continue;
                }
                let resp = match reply_rx.recv() {
                    Ok(Ok(o)) => protocol::submitted_json(
                        &o.id, o.admitted, o.peak_gb, o.state, o.priority, &o.tenant,
                    ),
                    Ok(Err(msg)) => error_line(&msg),
                    Err(_) => error_line("scheduler stopped"),
                };
                write_line(&mut out, &resp)?;
            }
            Request::Status { job } => {
                let resp = {
                    let b = lock::board(&board);
                    let rows: Vec<_> = b
                        .jobs
                        .iter()
                        .filter(|v| match job.as_deref() {
                            Some(id) => v.snap.id == id,
                            None => true,
                        })
                        .map(|v| v.snap.clone())
                        .collect();
                    if job.is_some() && rows.is_empty() {
                        error_line("unknown job")
                    } else {
                        let misses: Vec<(String, u64)> =
                            b.tenant_misses.iter().map(|(t, n)| (t.clone(), *n)).collect();
                        protocol::status_json(
                            &rows,
                            b.budget_gb,
                            b.committed_gb,
                            b.host_budget_gb,
                            b.host_committed_gb,
                            &misses,
                        )
                    }
                };
                write_line(&mut out, &resp)?;
            }
            Request::Events { job, from, limit, follow } => {
                // client limits are honored up to the configured page
                // size; both modes serve bounded pages (the non-follow
                // footer carries `next_cursor` for the next request)
                let page = limit
                    .map(|l| usize::try_from(l).unwrap_or(usize::MAX))
                    .unwrap_or(page_size)
                    .clamp(1, page_size);
                stream_events(&mut out, &board, &shutdown, &job, from, page, follow)?;
            }
            Request::Cancel { job } => {
                let (reply_tx, reply_rx) = channel();
                if ctl.send(Control::Cancel { job, reply: reply_tx }).is_err() {
                    write_line(&mut out, &error_line("scheduler stopped"))?;
                    continue;
                }
                let resp = match reply_rx.recv() {
                    Ok(Ok(cancelled)) => crate::util::json::ObjBuilder::new()
                        .bool("ok", true)
                        .bool("cancelled", cancelled)
                        .build(),
                    Ok(Err(msg)) => error_line(&msg),
                    Err(_) => error_line("scheduler stopped"),
                };
                write_line(&mut out, &resp)?;
            }
            Request::Resume { job } => {
                let (reply_tx, reply_rx) = channel();
                if ctl.send(Control::Resume { job: job.clone(), reply: reply_tx }).is_err() {
                    write_line(&mut out, &error_line("scheduler stopped"))?;
                    continue;
                }
                let resp = match reply_rx.recv() {
                    Ok(Ok(o)) => {
                        protocol::resumed_json(&job, &o.id, o.admitted, o.peak_gb, o.state)
                    }
                    Ok(Err(msg)) => error_line(&msg),
                    Err(_) => error_line("scheduler stopped"),
                };
                write_line(&mut out, &resp)?;
            }
            Request::Metrics => {
                // scrape: registry families plus board-derived
                // scheduler gauges, rendered as Prometheus text and
                // shipped inside one NDJSON reply
                let resp = {
                    let b = lock::board(&board);
                    metrics_response(&b)
                };
                write_line(&mut out, &resp)?;
            }
            Request::Shutdown => {
                shutdown.store(true, Ordering::SeqCst);
                let _ = ctl.send(Control::Shutdown);
                write_line(&mut out, &protocol::ok_json())?;
                return Ok(());
            }
        }
    }
    Ok(())
}

/// Serve a job's event lines from the keyset cursor `from`, at most
/// `page` lines per board read.
///
/// Non-follow mode returns exactly one page plus an
/// [`protocol::events_page_json`] footer whose `next_cursor` resumes
/// the scan — backpressure is the client asking for the next page, and
/// no request ever replays the whole ring. Follow mode keeps polling
/// (still page-bounded per read, so one follower can never hold the
/// board lock for a full-ring copy) until the job reaches a terminal
/// state, then ends with a `done` marker line.
///
/// The per-job log is a capped ring (`ServeConfig::event_log_cap`): a
/// cursor pointing into the evicted region is clamped forward to the
/// log's base offset. The skipped sequence numbers are lines this
/// reader will never see — they are counted
/// (`revffn_events_dropped_total`) and surfaced on the page footer as
/// `gapped`/`dropped` instead of being silently swallowed. The
/// delivered lines themselves are always a contiguous run (each line
/// self-describes its `seq`; a follower that keeps up never observes
/// an eviction).
fn stream_events(
    out: &mut TcpStream,
    board: &Arc<Mutex<Board>>,
    shutdown: &Arc<AtomicBool>,
    job: &str,
    from: u64,
    page: usize,
    follow: bool,
) -> Result<()> {
    let mut cursor = from;
    let mut dropped: u64 = 0;
    let _follower = follow.then(FollowerGauge::new);
    loop {
        let (batch, next_cursor, state, total) = {
            let b = lock::board(board);
            let Some(view) = b.job(job) else {
                write_line(out, &error_line("unknown job"))?;
                return Ok(());
            };
            let (lines, start) = view.events.page_from(cursor, page);
            // ring eviction: the clamp from `cursor` to `start` is a
            // hole in this reader's stream — account for it
            let gap = start.saturating_sub(cursor);
            if gap > 0 {
                registry::add(registry::Counter::EventsDropped, gap);
                dropped += gap;
            }
            let next = start.saturating_add(u64::try_from(lines.len()).unwrap_or(u64::MAX));
            (lines, next, view.snap.state, view.snap.events)
        };
        if let Err(e) = push_lines(out, &batch) {
            // a follower that stopped draining hit the write deadline:
            // disconnect it rather than let it pin the handler (and the
            // board lock cadence) indefinitely
            if is_timeout(&e) {
                eprintln!("[serve] events: disconnected slow consumer of {job}");
                return Ok(());
            }
            return Err(e.into());
        }
        cursor = next_cursor;
        // how far this reader trails the producer, in events
        registry::gauge_set(registry::Gauge::FollowerLag, total.saturating_sub(cursor));
        if !follow {
            // one page per request: the footer's cursor is where the
            // next request resumes, `done` says no further page can
            // ever exist
            let done = state.is_terminal() && cursor >= total;
            let footer = protocol::events_page_json(
                job,
                u64::try_from(batch.len()).unwrap_or(u64::MAX),
                cursor,
                state,
                done,
                dropped,
            );
            if let Err(e) = write_line(out, &footer) {
                if is_timeout(&e) {
                    eprintln!("[serve] events: disconnected slow consumer of {job}");
                    return Ok(());
                }
                return Err(e.into());
            }
            return Ok(());
        }
        if !batch.is_empty() {
            // more lines may already be waiting past this page: drain
            // them before deciding whether the stream is over
            continue;
        }
        if state.is_terminal() || shutdown.load(Ordering::SeqCst) {
            // the page came back empty at a terminal state, so the log
            // is fully drained — close the stream
            if let Err(e) = write_line(out, &protocol::done_json(job, state, total)) {
                if is_timeout(&e) {
                    eprintln!("[serve] events: disconnected slow consumer of {job}");
                    return Ok(());
                }
                return Err(e.into());
            }
            return Ok(());
        }
        retry::pause(POLL);
    }
}

/// Write a batch of NDJSON lines and flush (no-op on an empty batch).
fn push_lines(out: &mut TcpStream, lines: &[String]) -> std::io::Result<()> {
    for line in lines {
        out.write_all(line.as_bytes())?;
        out.write_all(b"\n")?;
    }
    if !lines.is_empty() {
        out.flush()?;
    }
    Ok(())
}
