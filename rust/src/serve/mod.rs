//! `revffn serve` — multi-run scheduling and serving with memory-model
//! admission control.
//!
//! The subsystem that turns the step-granular engine into a multi-tenant
//! service: N fine-tuning jobs share one device, interleaved at
//! `StepEvent` granularity, admitted against an analytic peak-VRAM
//! budget. Four pieces:
//!
//! * [`admission`] — prices each submitted job with `memory::model` at
//!   its geometry/method and admits while the priced peaks fit
//!   `budget_gb`. RevFFN jobs price depth-independent activations, so a
//!   fixed budget admits more of them than SFT jobs (unit-tested).
//!   Per-tenant quotas ([`admission::Tenants`]) bound one tenant's
//!   concurrent jobs and device-GB share, with weighted-deficit debt
//!   deciding who admits first within a class.
//! * [`scheduler`] — a cooperative [`Scheduler`] over owned
//!   [`crate::engine::Run`]s: dispatch by priority class then earliest
//!   deadline (round-robin on ties), per-job `DeviceState` handoff (pin
//!   buffers on resume, release via a lazy literal sync on preemption)
//!   and deterministic interleaving given the submission order.
//! * [`protocol`] — the JSON-lines wire format (`submit` / `status` /
//!   `events` / `cancel` / `metrics` / `shutdown`), built on the
//!   in-crate codec, with keyset-cursor pagination for `events`
//!   (docs/SERVE.md) and a Prometheus scrape surface for `metrics`
//!   (docs/OBSERVABILITY.md).
//! * [`server`] — the `std::net` TCP control plane streaming each job's
//!   `StepEvent`s as NDJSON, with per-socket timeouts and a connection
//!   cap so slow or hostile clients cannot wedge the plane.
//! * [`supervise`] — supervised recovery (docs/ROBUSTNESS.md): failed
//!   jobs retry from their latest valid snapshot with exponential
//!   backoff, a device-health probe gates re-admission, and jobs that
//!   exhaust the budget quarantine with their failure chain.
//!
//! Entry points: `revffn serve` in the CLI, [`server::serve`] in code,
//! or a bare [`Scheduler`] for in-process multiplexing (how
//! `tests/serve.rs` pins solo-vs-interleaved bit-identity).

pub mod admission;
pub mod lock;
pub mod protocol;
pub mod scheduler;
pub mod server;
pub mod supervise;

pub use admission::{Admission, TenantPolicy, Tenants};
pub use protocol::{JobState, Priority, Request};
pub use scheduler::{Board, EventLog, JobView, Scheduler, SubmitMeta, SubmitOutcome};
pub use server::{serve, ServerHandle};
pub use supervise::{HealthProbe, RetryPolicy, Supervision};
