//! Poisoned-lock policy for the shared [`Board`].
//!
//! Every thread in the serve plane — handlers, the event streamer, the
//! scheduler loop — reads or writes the board through this one helper, so
//! the crate has exactly one answer to "what happens when the mutex is
//! poisoned": recover the guard and keep serving. The board holds only
//! monitoring state (job snapshots, event rings, the admission ledger
//! mirror); a writer that panicked mid-update can at worst leave a stale
//! snapshot, which the next `sync_ledger`/`set_state` overwrites. Tearing
//! down every connection over that would turn a transient panic into a
//! full control-plane outage.
//!
//! Lint rule LN002 (`revffn check --lint`) rejects any other `.lock()`
//! call site under `serve/`, which keeps this policy single-homed.

use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::serve::scheduler::Board;

/// Acquire the board, recovering from a poisoned mutex.
pub fn board(m: &Mutex<Board>) -> MutexGuard<'_, Board> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_after_poison() {
        let b = Arc::new(Mutex::new(Board::default()));
        let b2 = b.clone();
        let _ = std::thread::spawn(move || {
            let _g = b2.lock().unwrap();
            panic!("poison the board");
        })
        .join();
        assert!(b.lock().is_err(), "mutex should be poisoned");
        // the policy helper still hands out a usable guard
        let g = board(&b);
        assert!(g.jobs.is_empty());
    }
}
