//! Typed fine-tuning methods and the `MethodSpec` registry.
//!
//! Every launcher-visible property of a method — its CLI/JSON name, the
//! artifact variant directory per training stage, whether host-side
//! gradient accumulation is meaningful, and the analytic memory-model
//! row — lives here. Adding a method variant is a one-entry change: the
//! config parser, schedule planner, trainer, CLI, benches, and the
//! calibration path all consume this registry instead of comparing
//! strings.

use std::fmt;
use std::str::FromStr;

use crate::error::{Error, Result};
use crate::memory;

/// A fine-tuning method (one Table-1/Table-2 row).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Full-parameter SFT with activation checkpointing.
    Sft,
    Lora,
    Dora,
    Ia3,
    /// LOMO-style fused gradient/update ("Full Parameter Fine-tuning for
    /// Large Language Models with Limited Resources").
    Lomo,
    Galore,
    /// RevFFN two-stage reversible fine-tuning (this paper).
    Revffn,
}

/// Static properties of one method.
#[derive(Debug, Clone, Copy)]
pub struct MethodSpec {
    /// CLI / JSON name (`--method NAME`).
    pub name: &'static str,
    /// Human-readable table label.
    pub label: &'static str,
    /// Artifact variant directory per training stage, in execution
    /// order. Single-stage methods have exactly one entry; the last
    /// entry is also the inference/eval variant.
    pub stage_variants: &'static [&'static str],
    /// Whether microbatch gradient accumulation is meaningful. LOMO
    /// fuses the update into the backward pass, so accumulating full
    /// gradients (even device-resident) would defeat the method.
    pub supports_grad_accum: bool,
    /// Row in the analytic peak-VRAM model (`memory::Method`).
    pub memory: memory::Method,
}

const SPEC_SFT: MethodSpec = MethodSpec {
    name: "sft",
    label: "SFT + Checkpointing",
    stage_variants: &["sft"],
    supports_grad_accum: true,
    memory: memory::Method::SftCheckpoint,
};
const SPEC_LORA: MethodSpec = MethodSpec {
    name: "lora",
    label: "LoRA",
    stage_variants: &["lora"],
    supports_grad_accum: true,
    memory: memory::Method::Lora,
};
const SPEC_DORA: MethodSpec = MethodSpec {
    name: "dora",
    label: "DoRA",
    stage_variants: &["dora"],
    supports_grad_accum: true,
    memory: memory::Method::Dora,
};
const SPEC_IA3: MethodSpec = MethodSpec {
    name: "ia3",
    label: "(IA)^3",
    stage_variants: &["ia3"],
    supports_grad_accum: true,
    memory: memory::Method::Ia3,
};
const SPEC_LOMO: MethodSpec = MethodSpec {
    name: "lomo",
    label: "LOMO",
    stage_variants: &["lomo"],
    supports_grad_accum: false,
    memory: memory::Method::Lomo,
};
const SPEC_GALORE: MethodSpec = MethodSpec {
    name: "galore",
    label: "GaLore",
    stage_variants: &["galore"],
    supports_grad_accum: true,
    memory: memory::Method::Galore,
};
const SPEC_REVFFN: MethodSpec = MethodSpec {
    name: "revffn",
    label: "RevFFN",
    stage_variants: &["revffn_stage1", "revffn_stage2"],
    supports_grad_accum: true,
    memory: memory::Method::Revffn,
};

impl Method {
    /// Every registered method, in canonical (Table-1 row) order.
    pub const ALL: [Method; 7] = [
        Method::Sft,
        Method::Lora,
        Method::Dora,
        Method::Ia3,
        Method::Lomo,
        Method::Galore,
        Method::Revffn,
    ];

    /// The registry entry for this method.
    pub fn spec(self) -> &'static MethodSpec {
        match self {
            Method::Sft => &SPEC_SFT,
            Method::Lora => &SPEC_LORA,
            Method::Dora => &SPEC_DORA,
            Method::Ia3 => &SPEC_IA3,
            Method::Lomo => &SPEC_LOMO,
            Method::Galore => &SPEC_GALORE,
            Method::Revffn => &SPEC_REVFFN,
        }
    }

    /// CLI / JSON name.
    pub fn name(self) -> &'static str {
        self.spec().name
    }

    /// Human-readable table label.
    pub fn label(self) -> &'static str {
        self.spec().label
    }

    /// Number of training stages (1 for everything but RevFFN).
    pub fn stages(self) -> u8 {
        self.spec().stage_variants.len() as u8
    }

    pub fn is_two_stage(self) -> bool {
        self.stages() > 1
    }

    /// Whether microbatch gradient accumulation is meaningful.
    pub fn supports_grad_accum(self) -> bool {
        self.spec().supports_grad_accum
    }

    /// Artifact variant directory name for a 1-based stage. Stages past
    /// the method's last stage clamp to the final variant, so schedule
    /// code can always ask for "stage 2".
    pub fn variant(self, stage: u8) -> &'static str {
        let sv = self.spec().stage_variants;
        let idx = (stage.max(1) as usize - 1).min(sv.len() - 1);
        sv[idx]
    }

    /// Variant used for inference and evaluation (the final stage).
    pub fn eval_variant(self) -> &'static str {
        let sv = self.spec().stage_variants;
        sv[sv.len() - 1]
    }

    /// Reverse lookup: which method does an artifact variant directory
    /// belong to? Ablation-only variants (`revffn_naive`, the
    /// `reconstruct*` family) map to `None`.
    pub fn from_variant(variant: &str) -> Option<Method> {
        Method::ALL
            .iter()
            .copied()
            .find(|m| m.spec().stage_variants.contains(&variant))
    }

    /// Row in the analytic peak-VRAM model.
    pub fn memory_method(self) -> memory::Method {
        self.spec().memory
    }

    /// Program kinds `Stepper::load` requires unconditionally for any
    /// variant of this method — a manifest missing one of these can
    /// never train or eval. (`revffn check` AR003 enforces this
    /// statically; every future method inherits the check through the
    /// registry.)
    pub fn required_programs(self) -> &'static [&'static str] {
        &["train_step", "eval_step", "forward"]
    }

    /// Program kinds that are optional but must appear as complete
    /// pairs: `grad_step`/`apply_step` unlock host-side accumulation,
    /// `accum_step`/`scale` unlock the device-resident accumulator. A
    /// half-present pair means the artifact set was truncated or
    /// hand-edited, and the capability would fail at first use.
    pub fn paired_programs(self) -> &'static [[&'static str; 2]] {
        &[["grad_step", "apply_step"], ["accum_step", "scale"]]
    }

    /// Full per-method program inventory the static memory sweep prices:
    /// the unconditionally required kinds plus both optional pairs, in
    /// schedule order (fused path first, then the split-accumulation
    /// path). `revffn check --hlo-mem` walks exactly this list for every
    /// variant, so a method gaining a program kind automatically joins
    /// the liveness cross-check through the registry.
    pub fn hlo_mem_programs(self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = self.required_programs().to_vec();
        for pair in self.paired_programs() {
            out.extend(pair.iter().copied());
        }
        out
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Method {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        Method::ALL
            .iter()
            .copied()
            .find(|m| m.name() == s)
            .ok_or_else(|| {
                let names: Vec<&str> = Method::ALL.iter().map(|m| m.name()).collect();
                Error::Config(format!("unknown method {s:?}; expected one of {names:?}"))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_display_roundtrip() {
        for m in Method::ALL {
            assert_eq!(m.name().parse::<Method>().unwrap(), m);
            assert_eq!(m.to_string(), m.name());
        }
    }

    #[test]
    fn unknown_method_rejected() {
        assert!("qlora".parse::<Method>().is_err());
        assert!("".parse::<Method>().is_err());
    }

    #[test]
    fn registry_invariants() {
        let mut names = std::collections::HashSet::new();
        let mut variants = std::collections::HashSet::new();
        for m in Method::ALL {
            let spec = m.spec();
            assert!(names.insert(spec.name), "duplicate name {}", spec.name);
            assert!(!spec.stage_variants.is_empty(), "{}: no stages", spec.name);
            for v in spec.stage_variants {
                assert!(variants.insert(*v), "duplicate variant {v}");
            }
            assert_eq!(m.eval_variant(), *spec.stage_variants.last().unwrap());
        }
    }

    #[test]
    fn revffn_is_two_stage() {
        assert!(Method::Revffn.is_two_stage());
        assert_eq!(Method::Revffn.variant(1), "revffn_stage1");
        assert_eq!(Method::Revffn.variant(2), "revffn_stage2");
        assert_eq!(Method::Revffn.eval_variant(), "revffn_stage2");
        assert_eq!(Method::Sft.stages(), 1);
        assert_eq!(Method::Sft.variant(2), "sft");
    }

    #[test]
    fn from_variant_reverse_lookup() {
        assert_eq!(Method::from_variant("revffn_stage1"), Some(Method::Revffn));
        assert_eq!(Method::from_variant("revffn_stage2"), Some(Method::Revffn));
        assert_eq!(Method::from_variant("lomo"), Some(Method::Lomo));
        assert_eq!(Method::from_variant("revffn_naive"), None);
        assert_eq!(Method::from_variant("reconstruct"), None);
    }

    #[test]
    fn hlo_mem_inventory_covers_required_and_pairs() {
        for m in Method::ALL {
            let inv = m.hlo_mem_programs();
            for k in m.required_programs() {
                assert!(inv.contains(k), "{m}: {k} missing from hlo-mem inventory");
            }
            for pair in m.paired_programs() {
                for k in pair {
                    assert!(inv.contains(k), "{m}: {k} missing from hlo-mem inventory");
                }
            }
        }
    }

    #[test]
    fn lomo_cannot_accumulate() {
        assert!(!Method::Lomo.supports_grad_accum());
        assert!(Method::Revffn.supports_grad_accum());
    }
}
