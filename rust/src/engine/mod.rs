//! The crate's public driving API.
//!
//! Three pieces, designed so that adding a fine-tuning method variant is
//! a one-file change and training is externally drivable:
//!
//! * [`Method`] / [`MethodSpec`] — the typed method registry. Replaces
//!   every stringly-typed `method` / variant-directory comparison in the
//!   config, trainer, CLI, benches and calibration code.
//! * [`Session`] / [`SessionBuilder`] — the unified model-loading
//!   facade: artifact-load → program-compile → checkpoint-restore →
//!   tokenizer-train, shared by `eval`, `generate`, `reconstruct`, the
//!   examples and the benches.
//! * [`Run`] / [`StepEvent`] — the step-granular training driver.
//!   `Trainer::run()` is a thin compatibility loop over it; external
//!   callers can interleave, pause, or multiplex runs and observe
//!   `PhaseStarted` / `Step` / `EvalPoint` / `PhaseFinished` events.

pub mod method;
pub mod run;
pub mod session;

pub use method::{Method, MethodSpec};
pub use run::{Observer, Run, StepEvent};
pub use session::{RawProgram, Session, SessionBuilder};
