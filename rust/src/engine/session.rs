//! `Session` — the unified model-loading facade.
//!
//! One builder covers the artifact-load → program-compile →
//! checkpoint-restore → tokenizer-train sequence that the CLI
//! subcommands (`eval`, `generate`, `reconstruct`), the examples and the
//! benches previously each re-implemented. Two products:
//!
//! * [`SessionBuilder::build`] — a full [`Session`]: a live [`Stepper`]
//!   for the method's inference variant plus the synthetic corpus and a
//!   tokenizer trained at the artifact's vocab size.
//! * [`SessionBuilder::build_program`] — a [`RawProgram`]: one compiled
//!   auxiliary HLO program (e.g. the `reconstruct` variants) with its
//!   blob-initialized parameters, no tokenizer.
//!
//! Training runs are driven by [`crate::coordinator::Trainer`] /
//! [`crate::engine::Run`]; a `Session` is the read/serve side.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::checkpoint;
use crate::data::synthetic::{Corpus, CorpusConfig};
use crate::data::tokenizer::Tokenizer;
use crate::engine::method::Method;
use crate::error::Result;
use crate::eval::{generate_text, BenchScores, EvalSuite, GenerateConfig};
use crate::runtime::artifact::Artifact;
use crate::runtime::pjrt::{Device, Program, ProgramCache};
use crate::runtime::stepper::Stepper;
use crate::runtime::store::ParamStore;

/// Generate the synthetic corpus and train a tokenizer sized to the
/// artifact vocabulary — the shared data half of every loading path
/// (`SessionBuilder::build` and `Trainer::new`).
pub(crate) fn corpus_and_tokenizer(
    config: CorpusConfig,
    vocab_size: usize,
) -> Result<(Corpus, Tokenizer)> {
    let corpus = Corpus::generate(config);
    let tokenizer = Tokenizer::train(&corpus.pretrain_text(), vocab_size)?;
    Ok((corpus, tokenizer))
}

/// Builder for [`Session`] / [`RawProgram`].
pub struct SessionBuilder {
    artifacts: PathBuf,
    method: Method,
    variant: Option<String>,
    checkpoint: Option<PathBuf>,
    corpus: CorpusConfig,
    device: Option<Device>,
}

impl SessionBuilder {
    pub fn new(artifacts: impl Into<PathBuf>) -> Self {
        SessionBuilder {
            artifacts: artifacts.into(),
            method: Method::Revffn,
            variant: None,
            checkpoint: None,
            corpus: CorpusConfig::default(),
            device: None,
        }
    }

    /// Fine-tuning method whose inference variant to load (default:
    /// [`Method::Revffn`]).
    pub fn method(mut self, method: Method) -> Self {
        self.method = method;
        self
    }

    /// Explicit artifact variant directory, overriding the method's
    /// default (`method.eval_variant()`). Use for auxiliary variants
    /// like `reconstruct`.
    pub fn variant(mut self, variant: impl Into<String>) -> Self {
        self.variant = Some(variant.into());
        self
    }

    /// Restore parameters from an `.rvt` checkpoint after loading.
    pub fn checkpoint(mut self, path: impl Into<PathBuf>) -> Self {
        self.checkpoint = Some(path.into());
        self
    }

    /// Synthetic corpus configuration (default: `CorpusConfig::default()`).
    pub fn corpus(mut self, config: CorpusConfig) -> Self {
        self.corpus = config;
        self
    }

    /// Reuse an existing PJRT device instead of creating a CPU client.
    pub fn device(mut self, device: Device) -> Self {
        self.device = Some(device);
        self
    }

    fn resolve_variant(&self) -> String {
        self.variant
            .clone()
            .unwrap_or_else(|| self.method.eval_variant().to_string())
    }

    /// Build the full facade: compiled stepper + corpus + tokenizer.
    pub fn build(self) -> Result<Session> {
        let variant = self.resolve_variant();
        let SessionBuilder { artifacts, method, checkpoint: ckpt, corpus, device, .. } = self;
        let device = match device {
            Some(d) => d,
            None => Device::cpu()?,
        };
        let cache = ProgramCache::new();
        let artifact = Artifact::load(artifacts.join(&variant))?;
        let mut stepper = Stepper::new(&device, &cache, artifact)?;
        if let Some(path) = &ckpt {
            // params-only read: eval/generate never touch the Adam
            // moments an RVT2 file carries, so don't materialize them
            let ck = checkpoint::load_params(path)?;
            let n = stepper.replace_params(|p| checkpoint::restore_into(&ck, p))?;
            eprintln!("[checkpoint] restored {n} tensors from step {}", ck.step);
        }
        let (corpus, tokenizer) = corpus_and_tokenizer(corpus, stepper.vocab_size())?;
        Ok(Session { device, cache, artifacts, method, corpus, tokenizer, stepper })
    }

    /// Build one auxiliary program (no tokenizer, no eval suite): load
    /// the variant's manifest, compile the named HLO artifact, stage its
    /// blob parameters, and apply the checkpoint if one was given.
    pub fn build_program(self, kind: &str) -> Result<RawProgram> {
        let variant = self.resolve_variant();
        let SessionBuilder { artifacts, checkpoint: ckpt, device, .. } = self;
        let device = match device {
            Some(d) => d,
            None => Device::cpu()?,
        };
        let cache = ProgramCache::new();
        let artifact = Artifact::load(artifacts.join(&variant))?;
        let program = cache.get_or_load(&device, artifact.hlo_path(kind)?)?;
        let mut params = ParamStore::from_blobs(&artifact)?;
        if let Some(path) = &ckpt {
            let ck = checkpoint::load_params(path)?;
            let n = checkpoint::restore_into(&ck, &mut params)?;
            eprintln!("[checkpoint] restored {n} tensors from step {}", ck.step);
        }
        Ok(RawProgram { device, artifact, program, params })
    }
}

/// A loaded model bound to a device: the one-stop facade for eval,
/// generation, and auxiliary-program access.
pub struct Session {
    pub device: Device,
    cache: ProgramCache,
    artifacts: PathBuf,
    pub method: Method,
    pub corpus: Corpus,
    pub tokenizer: Tokenizer,
    pub stepper: Stepper,
}

impl Session {
    pub fn builder(artifacts: impl Into<PathBuf>) -> SessionBuilder {
        SessionBuilder::new(artifacts)
    }

    /// Artifact config directory this session loads from.
    pub fn artifacts(&self) -> &Path {
        &self.artifacts
    }

    pub fn cache(&self) -> &ProgramCache {
        &self.cache
    }

    /// The Table-2 benchmark suite over this session's world.
    pub fn eval_suite(&self, n_questions: usize, seed: u64) -> EvalSuite {
        EvalSuite::new(self.corpus.world.clone(), n_questions, seed)
    }

    /// Score the model on the synthetic benchmark suite.
    pub fn bench_scores(&self, n_questions: usize, seed: u64) -> Result<BenchScores> {
        self.eval_suite(n_questions, seed)
            .run(&self.stepper, &self.tokenizer, &self.corpus.eval)
    }

    /// Autoregressive generation through the AOT `forward` artifact.
    pub fn generate(&self, prompt: &str, cfg: &GenerateConfig) -> Result<String> {
        generate_text(&self.stepper, &self.tokenizer, prompt, cfg)
    }

    /// Load + compile another variant's HLO program through this
    /// session's device and cache (reconstruction probes, ablations…).
    pub fn program(&self, variant: &str, kind: &str) -> Result<(Artifact, Arc<Program>)> {
        let artifact = Artifact::load(self.artifacts.join(variant))?;
        let program = self.cache.get_or_load(&self.device, artifact.hlo_path(kind)?)?;
        Ok((artifact, program))
    }
}

/// One compiled auxiliary program plus its parameters (see
/// [`SessionBuilder::build_program`]).
pub struct RawProgram {
    pub device: Device,
    pub artifact: Artifact,
    pub program: Arc<Program>,
    pub params: ParamStore,
}
