//! `Run` — the step-granular training driver.
//!
//! A `Run` is an iterator-style state machine over the planned phases of
//! a [`Trainer`]'s config: each call to [`Run::step`] performs at most
//! one unit of work (open a phase, execute one optimizer step, close a
//! phase) and yields the resulting [`StepEvent`]. External callers — the
//! CLI, the benches, the eval suite, the serve scheduler — can
//! interleave, pause, or multiplex runs between calls; `Trainer::run()`
//! is a thin loop over this type, and [`crate::serve::Scheduler`] drives
//! many owned runs round-robin over one shared device.
//!
//! A `Run` either borrows its trainer (`Trainer::start()` →
//! `Run<&mut Trainer>`, the inline-driving form) or owns it
//! (`Trainer::into_run()` → `Run<Trainer>`, the form a scheduler keeps
//! N of). Both expose the same `step`/`finish` surface plus the
//! suspend/resume handoff ([`Run::suspend`] releases the job's pinned
//! device buffers via one lazy literal sync; [`Run::resume`] re-pins
//! them), which is what lets a scheduler preempt between steps without
//! perturbing the math — buffer↔literal state sync is bit-exact.
//!
//! Event order for a two-phase RevFFN run with an LM pre-pass:
//!
//! ```text
//! PhaseStarted{stage:0} Step.. PhaseFinished{stage:0}        (lm-prepass)
//! PhaseStarted{stage:1} Step.. [EvalPoint..] EvalPoint PhaseFinished{stage:1}
//! PhaseStarted{stage:2} Step.. [EvalPoint..] EvalPoint PhaseFinished{stage:2}
//! -> step() returns None; finish() yields the TrainReport
//! ```
//!
//! Every `Step` / `EvalPoint` event mirrors exactly one record in
//! `trainer.metrics`, so an observer sees the same stream the metrics
//! sink persists (pre-pass steps record as stage 0).

use std::borrow::{Borrow, BorrowMut};
use std::collections::VecDeque;

use crate::checkpoint;
use crate::coordinator::lr::lr_at;
use crate::coordinator::metrics::StepRecord;
use crate::coordinator::schedule::{plan, Phase, PhaseKind};
use crate::coordinator::trainer::{TrainReport, Trainer};
use crate::data::dataset::{encode_corpus, encode_lm_text};
use crate::data::{Batcher, Pipeline};
use crate::error::{Error, Result};
use crate::obs::{self, registry};
use crate::runtime::accum::GradAccumulator;
use crate::runtime::stepper::{Batch, Stepper};

/// One observable unit of training progress.
#[derive(Debug, Clone)]
pub enum StepEvent {
    /// A phase's stepper is compiled, parameters handed off, and data
    /// batched; `steps` optimizer steps follow.
    PhaseStarted {
        /// 0-based index into the planned phases.
        phase: usize,
        /// Artifact stage this phase executes: 1 or 2, or 0 for the LM
        /// pre-pass (which runs the `sft` variant).
        stage: u8,
        label: &'static str,
        steps: u64,
        peak_lr: f32,
        batch_size: usize,
        seq_len: usize,
    },
    /// One logged optimizer step (possibly `grad_accum` microbatches).
    /// The record is identical to what `trainer.metrics` stores.
    Step(StepRecord),
    /// A validation pass (cadence or end-of-phase), identical to the
    /// metrics eval record.
    EvalPoint { step: u64, eval_loss: f32 },
    /// The phase's final validation ran; its stepper becomes the
    /// parameter source for the next phase. The LM pre-pass runs no
    /// validation, so its `eval_loss` is NaN.
    PhaseFinished { phase: usize, stage: u8, eval_loss: f32 },
}

/// Observer hook: called with every event as it is yielded.
pub type Observer = Box<dyn FnMut(&StepEvent)>;

/// An in-flight training run. Create via [`Trainer::start`] (borrowed)
/// or [`Trainer::into_run`] (owned — for schedulers).
pub struct Run<T: BorrowMut<Trainer>> {
    trainer: T,
    phases: Vec<Phase>,
    phase_idx: usize,
    step_in_phase: u64,
    phase_open: bool,
    /// The live model of the current (or just-finished) phase.
    stepper: Option<Stepper>,
    /// Prefetching training-batch source (background assembly thread).
    pipeline: Option<Pipeline>,
    /// Device-resident gradient accumulator (buffer path when the
    /// stepper runs on pinned `PjRtBuffer`s, literal path otherwise),
    /// created per phase when `grad_accum > 1` and the
    /// method/artifacts support it.
    accum: Option<GradAccumulator>,
    /// Validation source (absent during the LM pre-pass).
    eval_batcher: Option<Batcher>,
    queue: VecDeque<StepEvent>,
    last_eval: Option<f32>,
    observer: Option<Observer>,
    finished: bool,
    /// Seed the open phase's training batcher was created with
    /// (recorded into checkpoints, validated on resume).
    batch_seed: u64,
    /// Training batches consumed from the open phase's pipeline — the
    /// data-cursor half of a full-state checkpoint. Counted on the
    /// consumer side, NOT inside the batcher: the prefetch thread runs
    /// ahead, so only batches the run actually trained on count.
    batches_taken: u64,
    /// Events yielded so far (serve event-stream continuity).
    seq: u64,
    /// Optimizer steps completed across all phases (periodic-snapshot
    /// cadence: `cfg.checkpoint_every`).
    steps_total: u64,
    /// Checkpoint to fast-forward from, staged by [`Run::restore`] and
    /// consumed when its phase opens.
    pending_resume: Option<checkpoint::Checkpoint>,
    /// Whether this run restored from a checkpoint — `finish` then
    /// merges `metrics.jsonl` instead of overwriting the predecessor's
    /// records (the in-memory metrics only cover post-resume steps).
    resumed: bool,
}

impl<T: BorrowMut<Trainer>> Run<T> {
    pub(crate) fn new(trainer: T) -> Result<Self> {
        let phases = plan(&trainer.borrow().cfg);
        if phases.is_empty() {
            return Err(Error::Config("empty schedule".into()));
        }
        Ok(Run {
            trainer,
            phases,
            phase_idx: 0,
            step_in_phase: 0,
            phase_open: false,
            stepper: None,
            pipeline: None,
            accum: None,
            eval_batcher: None,
            queue: VecDeque::new(),
            last_eval: None,
            observer: None,
            finished: false,
            batch_seed: 0,
            batches_taken: 0,
            seq: 0,
            steps_total: 0,
            pending_resume: None,
            resumed: false,
        })
    }

    /// Resume this run from a full-state checkpoint (an RVT2 file with
    /// a run cursor — see [`crate::checkpoint`]). Must be called before
    /// the first [`Run::step`]: the run fast-forwards to the cursor's
    /// phase/step, restores params + Adam moments + the optimizer step
    /// counter into that phase's stepper, and replays the data pipeline
    /// to the next unseen batch — continuation is bit-identical to the
    /// uninterrupted run.
    ///
    /// Params-only checkpoints (RVT1, or an end-of-run `final.rvt`) are
    /// rejected: restoring weights without the moments silently resets
    /// the optimizer and changes training dynamics. Load those through
    /// [`crate::engine::SessionBuilder::checkpoint`] instead.
    pub fn restore(&mut self, ckpt: checkpoint::Checkpoint) -> Result<()> {
        if self.phase_open || self.phase_idx != 0 || self.finished || !self.queue.is_empty() {
            return Err(Error::Config("restore() must precede the first step()".into()));
        }
        let cursor = ckpt.cursor.ok_or_else(|| {
            Error::Config(
                "checkpoint has no run cursor (params-only RVT1, or a final snapshot) — \
                 it can seed a Session but cannot resume a run"
                    .into(),
            )
        })?;
        if ckpt.opt.is_none() {
            return Err(Error::Config(
                "checkpoint has no optimizer moments; resuming from it would silently \
                 reset Adam"
                    .into(),
            ));
        }
        if cursor.phase_idx as usize >= self.phases.len() {
            return Err(Error::Config(format!(
                "checkpoint cursor at phase {} but the schedule plans {} phases — \
                 was the config changed, or the run already complete?",
                cursor.phase_idx,
                self.phases.len()
            )));
        }
        if cursor.step_in_phase > self.phases[cursor.phase_idx as usize].steps {
            return Err(Error::Config(format!(
                "checkpoint cursor at step {} of a {}-step phase — config mismatch",
                cursor.step_in_phase,
                self.phases[cursor.phase_idx as usize].steps
            )));
        }
        self.phase_idx = cursor.phase_idx as usize;
        self.seq = cursor.seq;
        self.steps_total = cursor.steps_total;
        self.pending_resume = Some(ckpt);
        self.resumed = true;
        Ok(())
    }

    /// Install an observer invoked with every yielded event (metrics
    /// mirrors, progress bars, remote reporting…).
    pub fn set_observer<F: FnMut(&StepEvent) + 'static>(&mut self, f: F) {
        self.observer = Some(Box::new(f));
    }

    /// The planned phases of this run.
    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }

    /// Advance by one unit of work and yield its event; `None` once the
    /// schedule is exhausted (then call [`Run::finish`]).
    pub fn step(&mut self) -> Result<Option<StepEvent>> {
        loop {
            if let Some(ev) = self.queue.pop_front() {
                if let Some(obs) = self.observer.as_mut() {
                    obs(&ev);
                }
                self.seq += 1;
                return Ok(Some(ev));
            }
            if self.finished {
                return Ok(None);
            }
            self.advance()?;
        }
    }

    /// Scheduler preemption surface: release this run's pinned device
    /// buffers (one lazy `to_literals` sync — the literal state becomes
    /// authoritative) so another run can own the device's memory. No-op
    /// when nothing is pinned.
    pub fn suspend(&mut self) -> Result<()> {
        if let Some(stepper) = self.stepper.as_mut() {
            stepper.disable_device_state()?;
        }
        Ok(())
    }

    /// Undo [`Run::suspend`]: re-pin params + moments as device buffers
    /// for the next quantum. Mirrors `open_phase`'s gating — skipped
    /// (with automatic literal-path execution) when the run is not
    /// device-resident, no phase is open, or the accumulate path lacks
    /// the compiled accum/scale pair.
    pub fn resume(&mut self) -> Result<()> {
        let device_resident = self.trainer.borrow().cfg.device_resident;
        if !device_resident || !self.phase_open {
            return Ok(());
        }
        let use_accum = self.accum.is_some();
        if let Some(stepper) = self.stepper.as_mut() {
            if !use_accum || stepper.supports_device_accum() {
                if let Err(e) = stepper.enable_device_state() {
                    eprintln!(
                        "[device] buffer path unavailable on resume ({e}); using literal path"
                    );
                }
            }
        }
        Ok(())
    }

    /// Drive any remaining steps, then finalize: sync parameters to
    /// host, write `metrics.jsonl`, save the checkpoint if configured,
    /// hand the trained stepper back to the trainer, and summarize.
    pub fn finish(mut self) -> Result<TrainReport> {
        while self.step()?.is_some() {}
        let mut stepper = self
            .stepper
            .take()
            .ok_or_else(|| Error::Config("run finished without executing a phase".into()))?;
        let trainer = self.trainer.borrow_mut();
        stepper.materialize_params()?;
        // training is over: release the pinned device buffers instead
        // of handing back a stepper that holds a full extra copy of
        // params + moments device-side (post-run eval/generate are
        // cold paths and run fine on the literal state)
        stepper.disable_device_state()?;
        let (first, last) = trainer.metrics.loss_delta().unwrap_or((0.0, 0.0));
        let report = TrainReport {
            method: trainer.cfg.method,
            steps_run: trainer.metrics.steps.len() as u64,
            final_loss: last,
            first_loss: first,
            eval_loss: self.last_eval,
            median_samples_per_s: trainer.metrics.median_throughput().unwrap_or(0.0),
            wall_time_s: trainer.metrics.wall_time_s(),
        };
        std::fs::create_dir_all(&trainer.cfg.out_dir)?;
        let metrics_path = trainer.cfg.out_dir.join("metrics.jsonl");
        if self.resumed {
            trainer.metrics.write_jsonl_merged(metrics_path)?;
        } else {
            trainer.metrics.write_jsonl(metrics_path)?;
        }
        if trainer.cfg.save_checkpoint {
            let sp = obs::span(obs::Site::CheckpointSave);
            checkpoint::save_stepper(trainer.cfg.out_dir.join("final.rvt"), &mut stepper)?;
            sp.finish();
            registry::inc(registry::Counter::CheckpointSaves);
        }
        trainer.stepper = Some(stepper);
        Ok(report)
    }

    /// Perform one unit of work, pushing its event(s) onto the queue.
    fn advance(&mut self) -> Result<()> {
        if self.phase_idx >= self.phases.len() {
            self.finished = true;
            return Ok(());
        }
        let phase = self.phases[self.phase_idx].clone();
        if !self.phase_open {
            if phase.kind == PhaseKind::LmPrepass
                && self.trainer.borrow().prepass_dir().is_none()
            {
                if self.pending_resume.is_some() {
                    return Err(Error::Config(
                        "checkpoint resumes into the LM pre-pass but this artifact set \
                         has no sft variant to run it on"
                            .into(),
                    ));
                }
                // artifact set without an sft variant (pallas-only
                // dirs): skip the pre-pass, as the eager path used to
                self.phase_idx += 1;
                return Ok(());
            }
            self.open_phase(&phase)?;
            return Ok(());
        }
        if self.step_in_phase < phase.steps {
            self.train_one(&phase)?;
            self.step_in_phase += 1;
            self.steps_total += 1;
            self.maybe_checkpoint()?;
            return Ok(());
        }
        self.close_phase(&phase)
    }

    /// Compile the phase's stepper, hand parameters off from the
    /// previous phase (the LM pre-pass is just an earlier phase), and
    /// batch the data.
    fn open_phase(&mut self, phase: &Phase) -> Result<()> {
        let prepass = phase.kind == PhaseKind::LmPrepass;
        let resume = self.pending_resume.take();
        let trainer = self.trainer.borrow_mut();
        let mut stepper = if prepass {
            trainer.load_prepass_stepper()?
        } else {
            trainer.load_stepper(phase.stage)?
        };
        if let Some(prev) = self.stepper.as_mut() {
            let params = prev.materialize_params()?;
            let copied = stepper.adopt_params(params)?;
            // release the finished phase's pinned buffers BEFORE the
            // new phase pins its own — never hold two full device
            // states across a stage boundary
            prev.disable_device_state()?;
            if self.phases[self.phase_idx - 1].kind == PhaseKind::LmPrepass {
                eprintln!("[handoff] adopted {copied} pre-passed tensors");
            }
        }
        let (b, s) = stepper.batch_shape();
        // the pre-pass trains next-token prediction on the raw corpus
        // text; fine-tuning phases train on the instruction pairs
        let (train_samples, batch_seed) = if prepass {
            (
                encode_lm_text(&trainer.tokenizer, &trainer.corpus.pretrain_text(), s),
                trainer.cfg.seed ^ 0xface,
            )
        } else {
            (
                encode_corpus(&trainer.tokenizer, &trainer.corpus.train, s),
                trainer.cfg.seed,
            )
        };
        if train_samples.is_empty() {
            return Err(Error::Config(format!("no training samples fit seq_len {s}")));
        }
        // Resuming into this phase: restore the checkpoint's full state
        // into the freshly-loaded stepper (params are name-matched and
        // shape-checked; Adam moments and the step counter come back
        // too), and note how far the data cursor must be replayed.
        let cursor = match &resume {
            Some(ckpt) => {
                let sp = obs::span(obs::Site::CheckpointRestore);
                let cursor = ckpt.cursor.expect("restore() validated the cursor");
                if cursor.batch_seed != batch_seed {
                    return Err(Error::Config(format!(
                        "checkpoint batch seed {:#x} != this config's {batch_seed:#x} — \
                         resuming would replay different data",
                        cursor.batch_seed
                    )));
                }
                let matched =
                    stepper.replace_params(|p| checkpoint::restore_into(ckpt, p))?;
                if matched != stepper.params.len() {
                    return Err(Error::Config(format!(
                        "checkpoint restored only {matched} of {} tensors — wrong \
                         variant or artifact set?",
                        stepper.params.len()
                    )));
                }
                let opt = ckpt.opt.as_ref().expect("restore() validated the moments");
                stepper.restore_opt(&opt.m, &opt.v)?;
                stepper.set_step(ckpt.step);
                eprintln!(
                    "[resume] {}: step {}/{} (optimizer step {}, {} batches replayed)",
                    phase.label, cursor.step_in_phase, phase.steps, ckpt.step,
                    cursor.batches_taken
                );
                sp.finish();
                registry::inc(registry::Counter::CheckpointRestores);
                Some(cursor)
            }
            None => None,
        };
        let grad_accum = if prepass { 1 } else { trainer.cfg.grad_accum };
        let seed = trainer.cfg.seed;
        let device_resident = trainer.cfg.device_resident;
        let supports_ga = trainer.cfg.method.supports_grad_accum();
        // training batches are assembled on a background thread so the
        // gather/copy overlaps device execution; the prefetch depth
        // scales with grad_accum (an optimizer step drains that many
        // batches back to back). Validation stays a plain synchronous
        // batcher (it streams lazily). On resume the batcher skips the
        // already-consumed batches BEFORE the prefetch thread starts,
        // so the first delivered batch is the first unseen one.
        let mut batcher = Batcher::new(train_samples, b, s, batch_seed);
        if let Some(c) = &cursor {
            batcher.skip_batches(c.batches_taken as usize);
        }
        self.pipeline =
            Some(Pipeline::spawn_with_depth(batcher, Pipeline::depth_for(grad_accum)));
        self.eval_batcher = if prepass {
            None
        } else {
            let eval_samples = encode_corpus(&trainer.tokenizer, &trainer.corpus.eval, s);
            Some(Batcher::new(eval_samples, b, s, seed))
        };
        let use_accum = grad_accum > 1 && supports_ga && stepper.supports_accumulation();
        self.accum = use_accum.then(|| GradAccumulator::for_stepper(&stepper));
        // Device-resident execution (cfg.device_resident, default on):
        // pin params + moments as PjRtBuffers for the phase. Skipped —
        // automatic fallback to the literal path — when the accumulate
        // path lacks the compiled accum_step/scale pair, or if the
        // upload itself fails. On resume this runs after the restore,
        // so the pinned buffers hold the checkpointed state.
        if device_resident && (!use_accum || stepper.supports_device_accum()) {
            if let Err(e) = stepper.enable_device_state() {
                eprintln!("[device] buffer path unavailable ({e}); using literal path");
            }
        }
        self.stepper = Some(stepper);
        self.phase_open = true;
        self.batch_seed = batch_seed;
        self.step_in_phase = cursor.map(|c| c.step_in_phase).unwrap_or(0);
        self.batches_taken = cursor.map(|c| c.batches_taken).unwrap_or(0);
        self.queue.push_back(StepEvent::PhaseStarted {
            phase: self.phase_idx,
            stage: phase.stage,
            label: phase.label,
            steps: phase.steps,
            peak_lr: phase.peak_lr,
            batch_size: b,
            seq_len: s,
        });
        Ok(())
    }

    /// One logged optimizer step: `grad_accum` microbatches, either as
    /// device-resident accumulation (grad-only passes summed through
    /// [`GradAccumulator`] — as pinned buffers or staged literals — one
    /// update on the mean gradient) or as sequential fused steps. The
    /// recorded `grad_norm` is the mean-gradient norm in both paths,
    /// and `device_time_s` counts the same thing in both — PJRT execute
    /// seconds — so the paths report comparable per-sample throughput.
    /// The LM pre-pass always runs single fused steps at a flat LR.
    fn train_one(&mut self, phase: &Phase) -> Result<()> {
        let prepass = phase.kind == PhaseKind::LmPrepass;
        let step = self.step_in_phase;
        let trainer = self.trainer.borrow_mut();
        let ga = if prepass { 1 } else { trainer.cfg.grad_accum };
        let eval_every = if prepass { 0 } else { trainer.cfg.eval_every };
        let lr = if prepass {
            phase.peak_lr
        } else {
            lr_at(&trainer.cfg.schedule, phase.peak_lr, step, phase.steps)
        };

        let stepper = self.stepper.as_mut().expect("phase open");
        let pipeline = self.pipeline.as_mut().expect("phase open");
        let (b, _s) = stepper.batch_shape();

        let mut loss_acc = 0.0f32;
        let mut aux_acc = 0.0f32;
        let mut device_s = 0.0f64;
        let grad_norm;
        let sp = obs::span(obs::Site::EngineStep);
        if let Some(accum) = self.accum.as_mut() {
            let use_buffers = stepper.is_device_resident() && accum.supports_buffers();
            let outcome = if use_buffers && !stepper.buffers_verified() {
                // first buffer-path step of this stepper: fetch the
                // burst up front so a fallback redo trains on the SAME
                // data — the delivered sequence stays identical to a
                // pure literal run
                let mut batches = Vec::with_capacity(ga);
                for _ in 0..ga {
                    batches.push(pipeline.next_batch()?);
                }
                let r = match Self::accum_step_slice(stepper, &batches, accum, lr, true) {
                    // the buffer path proved unsupported before any
                    // state mutation — the literal state is still
                    // current, so drop the buffers and redo the step
                    Err(e @ (Error::Layout(_) | Error::Xla(_)))
                        if stepper.can_abandon_buffers() =>
                    {
                        eprintln!(
                            "[device] buffer accumulate unavailable ({e}); \
                             falling back to literal path"
                        );
                        stepper.abandon_buffers()?;
                        *accum = GradAccumulator::for_stepper(stepper);
                        Self::accum_step_slice(stepper, &batches, accum, lr, false)
                    }
                    other => other,
                };
                for batch in batches {
                    pipeline.recycle(batch);
                }
                r
            } else {
                // steady state (buffer path verified, or literal path):
                // stream batches one at a time so assembly overlaps
                // execution regardless of grad_accum vs queue depth
                Self::accum_step_streaming(stepper, pipeline, accum, ga, lr, use_buffers)
            };
            let (l, a, d, gn) = outcome?;
            loss_acc = l;
            aux_acc = a;
            device_s = d;
            grad_norm = gn;
        } else {
            let mut gn_acc = 0.0f32;
            for _ in 0..ga {
                let batch = pipeline.next_batch()?;
                let stats = stepper.train_step(&batch, lr)?;
                pipeline.recycle(batch);
                loss_acc += stats.loss;
                gn_acc += stats.grad_norm;
                aux_acc += stats.router_aux;
                device_s += stats.step_time_s;
            }
            grad_norm = gn_acc / ga as f32;
        }
        let time_acc = sp.finish().as_secs_f64();
        let gaf = ga as f32;
        let samples = (b * ga) as f64;
        let rec = StepRecord {
            step: stepper.step,
            stage: phase.stage,
            loss: loss_acc / gaf,
            lr,
            grad_norm,
            router_aux: aux_acc / gaf,
            step_time_s: time_acc,
            device_time_s: device_s,
            samples_per_s: samples / time_acc.max(1e-9),
        };
        trainer.metrics.record_step(rec.clone());
        registry::inc(registry::Counter::Steps);
        self.queue.push_back(StepEvent::Step(rec));
        // the step consumed exactly `ga` batches (the buffer-path
        // fallback redo reuses its pre-fetched burst, never extras) —
        // advance the data cursor the next checkpoint will record
        self.batches_taken += ga as u64;

        if eval_every > 0 && (step + 1) % eval_every == 0 {
            self.validate_now()?;
        }
        Ok(())
    }

    /// Periodic full-state snapshot (`cfg.checkpoint_every`), taken at
    /// an optimizer-step boundary — the accumulator is always drained
    /// here, so no partial microbatch state needs serializing. The
    /// write is atomic (tmp + rename) and retention keeps the newest
    /// `cfg.keep_last` files. On the device-resident path this is the
    /// one deliberate full-state download per cadence interval.
    fn maybe_checkpoint(&mut self) -> Result<()> {
        let trainer = self.trainer.borrow();
        let every = trainer.cfg.checkpoint_every;
        if every == 0 || self.steps_total % every != 0 {
            return Ok(());
        }
        let out_dir = trainer.cfg.out_dir.clone();
        let keep_last = trainer.cfg.keep_last;
        let cursor = checkpoint::RunCursor {
            phase_idx: self.phase_idx as u64,
            step_in_phase: self.step_in_phase,
            batches_taken: self.batches_taken,
            batch_seed: self.batch_seed,
            seq: self.seq + self.queue.len() as u64,
            steps_total: self.steps_total,
        };
        let stepper = self.stepper.as_mut().expect("phase open");
        let path = checkpoint::periodic_path(&out_dir, cursor.phase_idx, cursor.step_in_phase);
        let sp = obs::span(obs::Site::CheckpointSave);
        checkpoint::save_stepper_state(&path, stepper, Some(&cursor))?;
        sp.finish();
        registry::inc(registry::Counter::CheckpointSaves);
        checkpoint::prune_checkpoints(&out_dir, keep_last);
        Ok(())
    }

    /// One gradient microbatch folded into the accumulator, on either
    /// path. Returns `(loss, aux, exec_s)`.
    fn accum_microbatch(
        stepper: &Stepper,
        accum: &mut GradAccumulator,
        batch: &Batch,
        use_buffers: bool,
    ) -> Result<(f32, f32, f64)> {
        if use_buffers {
            let out = stepper.grad_step_buffers(batch)?;
            accum.add_buffers(out.grads)?;
            Ok((out.loss, out.aux, out.exec_time_s))
        } else {
            let out = stepper.grad_step_literals(batch)?;
            accum.add(out.grads)?;
            Ok((out.loss, out.aux, out.exec_time_s))
        }
    }

    /// Finish the accumulator and apply the mean gradient, on either
    /// path. The update consumes the already-averaged gradient, so its
    /// post-clip norm IS the mean-gradient norm — no rescaling.
    /// Returns `(grad_norm, exec_s)` with the accum/scale execute
    /// seconds folded in.
    fn accum_apply(
        stepper: &mut Stepper,
        accum: &mut GradAccumulator,
        lr: f32,
        use_buffers: bool,
    ) -> Result<(f32, f64)> {
        if use_buffers {
            let mean = accum.finish_buffers()?;
            let accum_s = accum.take_exec_time_s();
            let (grad_norm, apply_s) = stepper.apply_accumulated_buffers(&mean, lr)?;
            Ok((grad_norm, accum_s + apply_s))
        } else {
            let mean = accum.finish()?;
            let accum_s = accum.take_exec_time_s();
            let (grad_norm, apply_s) = stepper.apply_accumulated(&mean, lr)?;
            Ok((grad_norm, accum_s + apply_s))
        }
    }

    /// One accumulate-path optimizer step over pre-fetched batches —
    /// used for a stepper's first buffer step, where a fallback redo
    /// must see the same data. Returns
    /// `(loss_sum, aux_sum, device_exec_s, grad_norm)`.
    fn accum_step_slice(
        stepper: &mut Stepper,
        batches: &[Batch],
        accum: &mut GradAccumulator,
        lr: f32,
        use_buffers: bool,
    ) -> Result<(f32, f32, f64, f32)> {
        let mut loss_acc = 0.0f32;
        let mut aux_acc = 0.0f32;
        let mut device_s = 0.0f64;
        for batch in batches {
            let (loss, aux, t) = Self::accum_microbatch(stepper, accum, batch, use_buffers)?;
            loss_acc += loss;
            aux_acc += aux;
            device_s += t;
        }
        let (grad_norm, apply_s) = Self::accum_apply(stepper, accum, lr, use_buffers)?;
        device_s += apply_s;
        Ok((loss_acc, aux_acc, device_s, grad_norm))
    }

    /// Steady-state accumulate step: batches are pulled and recycled
    /// one at a time, so assembly overlaps execution even when
    /// `grad_accum` exceeds the prefetch depth. Returns
    /// `(loss_sum, aux_sum, device_exec_s, grad_norm)`.
    fn accum_step_streaming(
        stepper: &mut Stepper,
        pipeline: &mut Pipeline,
        accum: &mut GradAccumulator,
        ga: usize,
        lr: f32,
        use_buffers: bool,
    ) -> Result<(f32, f32, f64, f32)> {
        let mut loss_acc = 0.0f32;
        let mut aux_acc = 0.0f32;
        let mut device_s = 0.0f64;
        for _ in 0..ga {
            let batch = pipeline.next_batch()?;
            let (loss, aux, t) = Self::accum_microbatch(stepper, accum, &batch, use_buffers)?;
            pipeline.recycle(batch);
            loss_acc += loss;
            aux_acc += aux;
            device_s += t;
        }
        let (grad_norm, apply_s) = Self::accum_apply(stepper, accum, lr, use_buffers)?;
        device_s += apply_s;
        Ok((loss_acc, aux_acc, device_s, grad_norm))
    }

    /// End-of-phase validation (skipped for the LM pre-pass, which has
    /// no eval objective), then rotate to the next phase.
    fn close_phase(&mut self, phase: &Phase) -> Result<()> {
        let eval_loss = if phase.kind == PhaseKind::LmPrepass {
            f32::NAN
        } else {
            self.validate_now()?
        };
        self.queue.push_back(StepEvent::PhaseFinished {
            phase: self.phase_idx,
            stage: phase.stage,
            eval_loss,
        });
        self.phase_idx += 1;
        self.phase_open = false;
        Ok(())
    }

    /// Run a validation pass, record it, and queue its event.
    fn validate_now(&mut self) -> Result<f32> {
        let stepper = self.stepper.as_ref().expect("phase open");
        let eval_batcher = self.eval_batcher.as_ref().expect("phase has eval data");
        let trainer = self.trainer.borrow_mut();
        let eval_loss = trainer.validate(stepper, eval_batcher)?;
        let at = stepper.step;
        trainer.metrics.record_eval(at, eval_loss);
        self.last_eval = Some(eval_loss);
        self.queue.push_back(StepEvent::EvalPoint { step: at, eval_loss });
        Ok(eval_loss)
    }
}
