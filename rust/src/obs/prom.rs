//! Prometheus text exposition (format 0.0.4) for the metrics registry.
//!
//! Renders [`Family`] groups — `# HELP` / `# TYPE` headers plus one
//! sample line per label set — with the format's escaping rules
//! (label values escape `\`, `"`, and newline; help text escapes `\`
//! and newline). [`registry_families`] converts the process-global
//! registry snapshot (counters, gauges, per-site latency summaries)
//! plus the fault-injection trip counts into families; serve's
//! `metrics` verb appends its scheduler-derived per-tenant/per-class
//! families on top (`serve/server.rs`), and the CLI trainer writes
//! [`render_default`] to `--metrics-out` on a cadence.
//!
//! Every metric name below is a literal in `rust/src/obs/` and must
//! have a catalog row in `docs/OBSERVABILITY.md` — `revffn check
//! --docs` rule DC004 enforces that.

use crate::obs::registry;
use crate::util::faults::{self, FaultSite};

/// Per-site latency summary family (quantiles from the registry
/// histograms).
pub const STAGE_SECONDS: &str = "revffn_stage_seconds";
/// Fault-injection trips per site (`util::faults::fired`).
pub const FAULT_TRIPS: &str = "revffn_fault_trips_total";

// Scheduler-derived families assembled by `serve/server.rs` at scrape
// time. The name constants live here so DC004 can enumerate every
// exported name from `rust/src/obs/` alone.
pub const TENANT_QUEUE_DEPTH: &str = "revffn_tenant_queue_depth";
pub const TENANT_ACTIVE_JOBS: &str = "revffn_tenant_active_jobs";
pub const TENANT_RESERVED_GB: &str = "revffn_tenant_reserved_gb";
pub const TENANT_DEBT: &str = "revffn_tenant_debt";
pub const TENANT_DEADLINE_MISS: &str = "revffn_tenant_deadline_miss_total";
pub const CLASS_QUEUE_DEPTH: &str = "revffn_class_queue_depth";
pub const JOBS_BY_STATE: &str = "revffn_jobs";
pub const BUDGET_GB: &str = "revffn_budget_gb";
pub const COMMITTED_GB: &str = "revffn_committed_gb";
pub const HOST_BUDGET_GB: &str = "revffn_host_budget_gb";
pub const HOST_COMMITTED_GB: &str = "revffn_host_committed_gb";
/// Static-vs-predicted peak-memory drift per variant/program, ratio
/// units (`analysis::liveness`); rows are embedded in the bench
/// telemetry snapshot (`BENCH_throughput.json`) rather than scraped.
pub const HLO_MEM_DRIFT: &str = "revffn_hlo_mem_drift";

/// Prometheus metric kind (drives the `# TYPE` header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Summary,
}

impl Kind {
    fn token(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Summary => "summary",
        }
    }
}

/// One sample line: optional name suffix (`_sum` / `_count` for
/// summaries), label pairs, value.
#[derive(Debug, Clone)]
pub struct Sample {
    pub suffix: &'static str,
    pub labels: Vec<(&'static str, String)>,
    pub value: f64,
}

impl Sample {
    pub fn new(labels: Vec<(&'static str, String)>, value: f64) -> Sample {
        Sample { suffix: "", labels, value }
    }
}

/// One metric family: a `# HELP`/`# TYPE` header plus its samples.
#[derive(Debug, Clone)]
pub struct Family {
    pub name: &'static str,
    pub help: &'static str,
    pub kind: Kind,
    pub samples: Vec<Sample>,
}

/// Escape a label value: `\` → `\\`, `"` → `\"`, newline → `\n`.
pub fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape help text: `\` → `\\`, newline → `\n`.
pub fn escape_help(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Is `name` a valid Prometheus metric name this repo would export?
/// (Stricter than the spec: lowercase, digits, underscores only.)
pub fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.as_bytes()[0].is_ascii_lowercase()
        && name.bytes().all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_')
}

fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else {
        format!("{v}")
    }
}

/// Render families as Prometheus exposition text.
pub fn render(families: &[Family]) -> String {
    let mut out = String::new();
    for fam in families {
        out.push_str("# HELP ");
        out.push_str(fam.name);
        out.push(' ');
        out.push_str(&escape_help(fam.help));
        out.push('\n');
        out.push_str("# TYPE ");
        out.push_str(fam.name);
        out.push(' ');
        out.push_str(fam.kind.token());
        out.push('\n');
        for s in &fam.samples {
            out.push_str(fam.name);
            out.push_str(s.suffix);
            if !s.labels.is_empty() {
                out.push('{');
                for (i, (k, v)) in s.labels.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(k);
                    out.push_str("=\"");
                    out.push_str(&escape_label(v));
                    out.push('"');
                }
                out.push('}');
            }
            out.push(' ');
            out.push_str(&fmt_value(s.value));
            out.push('\n');
        }
    }
    out
}

/// The process-global registry as families: every counter and gauge,
/// one summary per recorded span site, and the fault-injection trip
/// counts.
pub fn registry_families() -> Vec<Family> {
    let snap = registry::snapshot();
    let mut out = Vec::new();
    for (c, v) in &snap.counters {
        out.push(Family {
            name: c.name(),
            help: c.help(),
            kind: Kind::Counter,
            samples: vec![Sample::new(Vec::new(), *v as f64)],
        });
    }
    for (g, v) in &snap.gauges {
        out.push(Family {
            name: g.name(),
            help: g.help(),
            kind: Kind::Gauge,
            samples: vec![Sample::new(Vec::new(), *v as f64)],
        });
    }
    if !snap.hists.is_empty() {
        let mut samples = Vec::new();
        for h in &snap.hists {
            let site = || vec![("site", h.site.name().to_string())];
            for (q, v) in [("0.5", h.p50_s), ("0.95", h.p95_s), ("0.99", h.p99_s)] {
                let mut labels = site();
                labels.push(("quantile", q.to_string()));
                samples.push(Sample::new(labels, v));
            }
            samples.push(Sample { suffix: "_sum", labels: site(), value: h.sum_s });
            samples.push(Sample { suffix: "_count", labels: site(), value: h.count as f64 });
        }
        out.push(Family {
            name: STAGE_SECONDS,
            help: "Hot-path stage latency by span site (seconds)",
            kind: Kind::Summary,
            samples,
        });
    }
    let trips: Vec<Sample> = FaultSite::ALL
        .iter()
        .filter(|s| faults::fired(**s) > 0)
        .map(|s| Sample::new(vec![("site", s.name().to_string())], faults::fired(*s) as f64))
        .collect();
    if !trips.is_empty() {
        out.push(Family {
            name: FAULT_TRIPS,
            help: "Injected-fault trips by site",
            kind: Kind::Counter,
            samples: trips,
        });
    }
    out
}

/// Registry families rendered to exposition text — what the CLI
/// trainer writes to `--metrics-out`.
pub fn render_default() -> String {
    render(&registry_families())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::{Counter, Gauge};
    use crate::obs::trace::Site;
    use std::time::Duration;

    #[test]
    fn label_and_help_escaping() {
        assert_eq!(escape_label("a\\b\"c\nd"), "a\\\\b\\\"c\\nd");
        assert_eq!(escape_help("a\\b\"c\nd"), "a\\\\b\"c\\nd");
        let fam = Family {
            name: "revffn_test_metric",
            help: "line one\nline two",
            kind: Kind::Gauge,
            samples: vec![Sample::new(vec![("tenant", "a\"b\\c".to_string())], 1.0)],
        };
        let text = render(&[fam]);
        assert!(text.contains("# HELP revffn_test_metric line one\\nline two\n"), "{text}");
        assert!(text.contains("revffn_test_metric{tenant=\"a\\\"b\\\\c\"} 1\n"), "{text}");
    }

    #[test]
    fn exported_names_are_valid() {
        let mut names: Vec<&str> = vec![
            STAGE_SECONDS,
            FAULT_TRIPS,
            TENANT_QUEUE_DEPTH,
            TENANT_ACTIVE_JOBS,
            TENANT_RESERVED_GB,
            TENANT_DEBT,
            TENANT_DEADLINE_MISS,
            CLASS_QUEUE_DEPTH,
            JOBS_BY_STATE,
            BUDGET_GB,
            COMMITTED_GB,
            HOST_BUDGET_GB,
            HOST_COMMITTED_GB,
        ];
        names.extend(Counter::ALL.iter().map(|c| c.name()));
        names.extend(Gauge::ALL.iter().map(|g| g.name()));
        for n in names {
            assert!(valid_name(n), "invalid metric name {n}");
            assert!(n.starts_with("revffn_"), "unprefixed metric name {n}");
        }
    }

    #[test]
    fn non_finite_values_render_as_prometheus_literals() {
        let fam = Family {
            name: "revffn_test_metric",
            help: "h",
            kind: Kind::Gauge,
            samples: vec![
                Sample::new(Vec::new(), f64::NAN),
                Sample::new(Vec::new(), f64::INFINITY),
            ],
        };
        let text = render(&[fam]);
        assert!(text.contains("revffn_test_metric NaN\n"), "{text}");
        assert!(text.contains("revffn_test_metric +Inf\n"), "{text}");
    }

    #[test]
    fn registry_snapshot_renders_parseable_families() {
        let _g = registry::test_lock();
        registry::reset();
        registry::arm();
        registry::inc(Counter::Steps);
        registry::observe(Site::EngineStep, Duration::from_micros(900));
        let text = render_default();
        registry::disarm();
        registry::reset();
        assert!(text.contains("# TYPE revffn_steps_total counter\n"), "{text}");
        assert!(text.contains("revffn_steps_total 1\n"), "{text}");
        assert!(text.contains("# TYPE revffn_stage_seconds summary\n"), "{text}");
        assert!(
            text.contains("revffn_stage_seconds_count{site=\"engine.step\"} 1\n"),
            "{text}"
        );
        assert!(
            text.contains("revffn_stage_seconds{site=\"engine.step\",quantile=\"0.5\"} 0.001\n"),
            "{text}"
        );
        // every line is HELP, TYPE, or `name[{labels}] value`
        for line in text.lines() {
            assert!(
                line.starts_with("# HELP ")
                    || line.starts_with("# TYPE ")
                    || line.starts_with("revffn_"),
                "unparseable line: {line}"
            );
        }
    }
}
