//! Unified telemetry (docs/OBSERVABILITY.md): a process-global metrics
//! registry, hot-path tracing spans, and Prometheus exposition.
//!
//! Three dependency-free layers:
//!
//! * [`registry`] — statically enumerated counters, gauges, and
//!   per-site latency histograms. Disarmed collectors cost one relaxed
//!   atomic load (the `util::faults` fast-path discipline), so the
//!   instrumentation lives permanently in the hot path and is armed by
//!   sinks: `serve` at startup, the CLI trainer under `--metrics-out`
//!   / `--trace-out`, the throughput bench for its JSON snapshot.
//! * [`trace`] — RAII begin/end spans over the real hot paths (PJRT
//!   transfers/execution, the optimizer step, gradient accumulation,
//!   checkpoint save/restore, scheduler quanta and suspend/resume
//!   handoffs, supervised retries, wire read/handle), collected in a
//!   bounded ring and exportable as Chrome trace-event JSON
//!   (`--trace-out FILE`). Spans are the sanctioned clock for `serve/`
//!   and `engine/` — lint rule LN005 bans raw `Instant::now()` there.
//! * [`prom`] — Prometheus text rendering for the registry plus the
//!   scrape-time families serve assembles (per-tenant/per-class
//!   scheduler gauges, deadline-miss counters, fault trips). The serve
//!   `metrics` verb returns this text over the wire.
//!
//! Rule of thumb for instrumenting new code: wrap the operation in
//! [`span`] (you get the histogram and the trace event), count discrete
//! outcomes with [`registry::inc`], and catalog any new metric name in
//! docs/OBSERVABILITY.md — `revffn check --docs` (DC004) will hold you
//! to it.

pub mod prom;
pub mod registry;
pub mod trace;

pub use trace::{now, span, Site, SpanGuard};
