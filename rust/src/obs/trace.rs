//! Structured tracing spans over the hot paths, plus the sanctioned
//! wall clock.
//!
//! [`span`] returns an RAII [`SpanGuard`]: construction records a
//! begin event, [`SpanGuard::finish`] (or drop — early returns and `?`
//! propagation included) records the matching end event, so exported
//! traces are balanced by construction even under fault injection.
//! Every close also feeds the site's latency histogram in
//! `obs::registry`, making spans the single timing primitive: lint rule
//! LN005 bans raw `Instant::now()` in `serve/` and `engine/` so all
//! timing flows through here ([`now`] for deadline arithmetic,
//! [`span`]/[`SpanGuard::elapsed`] for durations).
//!
//! Tracing proper (the event ring) is disarmed by default and costs one
//! relaxed load per span when off; [`enable`] arms it (CLI
//! `--trace-out`). Events live in a bounded ring — overflow drops the
//! oldest and counts the loss — and export as Chrome trace-event JSON
//! (`chrome://tracing`, Perfetto) via [`export_chrome`].

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::{Duration, Instant};

use crate::obs::registry;
use crate::util::json::{Json, ObjBuilder};

/// Span sites — the fixed vocabulary shared by trace events and the
/// per-site latency histograms (`revffn_stage_seconds{site=…}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// Host→device literal staging (`Device::to_device`).
    PjrtUpload,
    /// Compiled-program execution (`Program::run` / `run_buffers`).
    PjrtExecute,
    /// Device→host download (`Device::from_device`).
    PjrtDownload,
    /// One optimizer step end-to-end (`engine::Run::train_one`).
    EngineStep,
    /// Gradient accumulate/scale program execution (`GradAccumulator`).
    AccumExecute,
    /// Full-state checkpoint write.
    CheckpointSave,
    /// Full-state checkpoint restore.
    CheckpointRestore,
    /// One scheduler quantum (pick → steps → handoff).
    SchedQuantum,
    /// Suspending an active job (device→host state sync).
    SchedSuspend,
    /// Resuming a job onto the device (pin buffers, rebuild run).
    SchedResume,
    /// Supervised retry re-admission (health probe + admission gate).
    SchedRetry,
    /// Blocking wait for the next wire line on a control connection.
    WireRead,
    /// Parse + dispatch + reply for one wire request.
    WireHandle,
}

impl Site {
    pub const ALL: [Site; 13] = [
        Site::PjrtUpload,
        Site::PjrtExecute,
        Site::PjrtDownload,
        Site::EngineStep,
        Site::AccumExecute,
        Site::CheckpointSave,
        Site::CheckpointRestore,
        Site::SchedQuantum,
        Site::SchedSuspend,
        Site::SchedResume,
        Site::SchedRetry,
        Site::WireRead,
        Site::WireHandle,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Site::PjrtUpload => "pjrt.upload",
            Site::PjrtExecute => "pjrt.execute",
            Site::PjrtDownload => "pjrt.download",
            Site::EngineStep => "engine.step",
            Site::AccumExecute => "accum.execute",
            Site::CheckpointSave => "checkpoint.save",
            Site::CheckpointRestore => "checkpoint.restore",
            Site::SchedQuantum => "sched.quantum",
            Site::SchedSuspend => "sched.suspend",
            Site::SchedResume => "sched.resume",
            Site::SchedRetry => "sched.retry",
            Site::WireRead => "wire.read",
            Site::WireHandle => "wire.handle",
        }
    }

    pub(crate) fn index(self) -> usize {
        Site::ALL.iter().position(|s| *s == self).unwrap_or(0)
    }
}

/// The sanctioned wall clock for `serve/` and `engine/` (LN005):
/// deadline arithmetic and backoff scheduling read time through here.
#[inline]
pub fn now() -> Instant {
    Instant::now()
}

/// One begin or end record in the trace ring.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Global sequence number (strictly increasing across threads).
    pub seq: u64,
    pub site: Site,
    /// `true` = span begin, `false` = span end.
    pub begin: bool,
    /// Microseconds since the trace epoch ([`enable`] time).
    pub t_us: u64,
    /// Small dense per-thread id (assigned on first event).
    pub tid: u64,
}

/// Ring capacity: ~32k begin/end pairs of headroom; overflow drops the
/// oldest events and is counted, never silent.
const RING_CAP: usize = 65_536;

static TRACING: AtomicBool = AtomicBool::new(false);
static SEQ: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static RING: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn lock_ring() -> MutexGuard<'static, Vec<TraceEvent>> {
    RING.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Is the trace ring collecting? One relaxed load when off.
#[inline]
pub fn enabled() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Arm the trace ring (clears prior events; sets the epoch on first
/// call).
pub fn enable() {
    let _ = EPOCH.get_or_init(Instant::now);
    lock_ring().clear();
    DROPPED.store(0, Ordering::Relaxed);
    TRACING.store(true, Ordering::SeqCst);
}

/// Disarm the trace ring (events already collected are kept for
/// export).
pub fn disable() {
    TRACING.store(false, Ordering::SeqCst);
}

fn push_event(site: Site, begin: bool, at: Instant) {
    let epoch = *EPOCH.get_or_init(Instant::now);
    let ev = TraceEvent {
        seq: SEQ.fetch_add(1, Ordering::Relaxed),
        site,
        begin,
        t_us: at.saturating_duration_since(epoch).as_micros().min(u64::MAX as u128) as u64,
        tid: TID.with(|t| *t),
    };
    let mut ring = lock_ring();
    if ring.len() >= RING_CAP {
        // drop the oldest half in one memmove rather than one event per
        // push — overflow is exceptional, not a steady state
        let half = RING_CAP / 2;
        ring.drain(..half);
        DROPPED.fetch_add(half as u64, Ordering::Relaxed);
    }
    ring.push(ev);
}

/// RAII span: begin on construction, end on [`finish`](SpanGuard::finish)
/// or drop. The guard always carries real elapsed time (callers feed
/// step stats from it), so it is also the sanctioned stopwatch when
/// both sinks are disarmed.
#[derive(Debug)]
pub struct SpanGuard {
    site: Site,
    t0: Instant,
    open: bool,
}

impl SpanGuard {
    /// Time since span begin, without closing it.
    pub fn elapsed(&self) -> Duration {
        self.t0.elapsed()
    }

    /// Close the span and return its duration.
    pub fn finish(mut self) -> Duration {
        self.close()
    }

    fn close(&mut self) -> Duration {
        let d = self.t0.elapsed();
        if self.open {
            self.open = false;
            registry::observe(self.site, d);
            if enabled() {
                push_event(self.site, false, Instant::now());
            }
        }
        d
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.open {
            self.close();
        }
    }
}

/// Open a span at `site`.
#[inline]
pub fn span(site: Site) -> SpanGuard {
    let t0 = Instant::now();
    if enabled() {
        push_event(site, true, t0);
    }
    SpanGuard { site, t0, open: true }
}

/// Copy out the collected events (oldest first) and the count of events
/// lost to ring overflow.
pub fn events() -> (Vec<TraceEvent>, u64) {
    (lock_ring().clone(), DROPPED.load(Ordering::Relaxed))
}

/// Render the ring as Chrome trace-event JSON (the `traceEvents` array
/// format `chrome://tracing` and Perfetto load directly).
pub fn export_chrome() -> String {
    let (evs, dropped) = events();
    let rows: Vec<Json> = evs
        .iter()
        .map(|e| {
            ObjBuilder::new()
                .str("name", e.site.name())
                .str("ph", if e.begin { "B" } else { "E" })
                .num("ts", e.t_us as f64)
                .num("pid", 1.0)
                .num("tid", e.tid as f64)
                .val("args", ObjBuilder::new().num("seq", e.seq as f64).build())
                .build()
        })
        .collect();
    ObjBuilder::new()
        .val("traceEvents", Json::Arr(rows))
        .str("displayTimeUnit", "ms")
        .num("revffnDroppedEvents", dropped as f64)
        .build()
        .to_string()
}

/// Write [`export_chrome`] to a file (CLI `--trace-out`).
pub fn write_chrome(path: &std::path::Path) -> std::io::Result<()> {
    std::fs::write(path, export_chrome())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::faults::{self, FaultSite};

    /// Other unit tests in this process may open spans while a trace
    /// test has the ring armed; every assertion below therefore filters
    /// to the current thread's events first.
    fn mine(evs: &[TraceEvent]) -> Vec<TraceEvent> {
        let me = TID.with(|t| *t);
        evs.iter().copied().filter(|e| e.tid == me).collect()
    }

    /// Balanced means: per site, begins and ends interleave as a
    /// well-formed bracket sequence, and timestamps/seq never decrease.
    fn assert_balanced(evs: &[TraceEvent]) {
        use std::collections::BTreeMap;
        let mut depth: BTreeMap<usize, i64> = BTreeMap::new();
        let mut last_t = 0u64;
        let mut last_seq = None;
        for e in evs {
            assert!(e.t_us >= last_t, "timestamps must be ordered: {evs:?}");
            last_t = e.t_us;
            if let Some(prev) = last_seq {
                assert!(e.seq > prev, "seq must strictly increase: {evs:?}");
            }
            last_seq = Some(e.seq);
            let d = depth.entry(e.site.index()).or_insert(0);
            *d += if e.begin { 1 } else { -1 };
            assert!(*d >= 0, "end before begin at {:?}: {evs:?}", e.site);
        }
        for (site, d) in depth {
            assert_eq!(d, 0, "unbalanced span at site {site}: {evs:?}");
        }
    }

    #[test]
    fn spans_balance_and_order() {
        let _g = registry::test_lock();
        enable();
        {
            let outer = span(Site::SchedQuantum);
            let inner = span(Site::EngineStep);
            drop(inner);
            let _ = outer.finish();
        }
        let evs = mine(&events().0);
        disable();
        assert_eq!(evs.len(), 4, "{evs:?}");
        assert_balanced(&evs);
        assert!(evs[0].begin && evs[0].site == Site::SchedQuantum);
        assert!(evs[1].begin && evs[1].site == Site::EngineStep);
        assert!(!evs[2].begin && evs[2].site == Site::EngineStep);
        assert!(!evs[3].begin && evs[3].site == Site::SchedQuantum);
    }

    #[test]
    fn spans_stay_balanced_under_fault_injection() {
        // the guard design's golden-path guarantee: an injected fault
        // that error-returns out of a spanned scope still produces the
        // end event via Drop, so exports stay balanced
        let _g = registry::test_lock();
        let _f = faults::test_lock();
        faults::install_from(Some("pjrt_execute:error")).expect("install plan");
        enable();
        let step = || -> crate::error::Result<()> {
            let _sp = span(Site::AccumExecute);
            faults::failpoint(FaultSite::PjrtExecute)?;
            Ok(())
        };
        assert!(step().is_err(), "injected fault must surface");
        let evs = mine(&events().0);
        disable();
        faults::clear();
        assert_eq!(evs.len(), 2, "{evs:?}");
        assert_balanced(&evs);
        // and the Chrome export carries them as a B/E pair
        let me = TID.with(|t| *t) as f64;
        let doc = export_chrome();
        let parsed = crate::util::json::parse(&doc).expect("export must be valid JSON");
        let rows = parsed.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents");
        let ours: Vec<&crate::util::json::Json> = rows
            .iter()
            .filter(|r| {
                r.get("tid").and_then(|t| t.as_f64()) == Some(me)
                    && r.get("name").and_then(|n| n.as_str()) == Some("accum.execute")
            })
            .collect();
        assert_eq!(ours.len(), 2, "{doc}");
        assert_eq!(ours[0].get("ph").and_then(|p| p.as_str()), Some("B"));
        assert_eq!(ours[1].get("ph").and_then(|p| p.as_str()), Some("E"));
    }

    #[test]
    fn disabled_ring_collects_nothing_but_guard_still_times() {
        let _g = registry::test_lock();
        disable();
        lock_ring().clear();
        let sp = span(Site::WireHandle);
        std::hint::black_box(&sp);
        let d = sp.finish();
        assert!(d >= Duration::ZERO);
        let evs = mine(&events().0);
        assert!(evs.is_empty(), "disarmed ring must stay empty: {evs:?}");
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let _g = registry::test_lock();
        enable();
        for _ in 0..(RING_CAP / 2 + 10) {
            let _sp = span(Site::WireRead);
        }
        let (evs, dropped) = events();
        disable();
        assert!(evs.len() <= RING_CAP);
        assert!(dropped > 0, "overflow must be counted");
        // our surviving events still balance from the first begin on
        let ours = mine(&evs);
        let start = ours.iter().position(|e| e.begin).expect("some begin survives");
        assert_balanced(&ours[start..]);
    }
}
