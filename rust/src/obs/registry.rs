//! Process-global metrics registry: monotonic counters, integer gauges,
//! and fixed-bucket latency histograms.
//!
//! The registry is **disarmed by default**: every record call bails on a
//! single relaxed atomic load (the same fast-path discipline as
//! `util::faults::hit`), so telemetry compiled into the hot path costs
//! one predictable branch until a sink arms it. `server::serve` arms it
//! at startup, the CLI trainer arms it when `--metrics-out` /
//! `--trace-out` is given, and `table1_throughput` arms it to embed a
//! snapshot in `BENCH_throughput.json`.
//!
//! All collectors are statically enumerated ([`Counter`], [`Gauge`], and
//! one histogram per [`Site`]) — no allocation, no locks, no string
//! interning on the record path. Dynamic label sets (per-tenant, per
//! fault site) are assembled at *scrape* time by the exposition layer
//! (`obs::prom`, `serve/server.rs`) from their owning state, which keeps
//! the registry itself dependency-free.
//!
//! Every metric name exported from this module is cataloged in
//! `docs/OBSERVABILITY.md`; `revffn check --docs` (DC004) fails on an
//! exported-but-uncataloged name.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use crate::obs::trace::Site;

/// Master switch. Relaxed is enough: a record racing an `arm()` may be
/// lost, which telemetry tolerates by design.
static ARMED: AtomicBool = AtomicBool::new(false);

/// Is the registry collecting? One relaxed load — the entire cost of a
/// disarmed collector.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Start collecting (idempotent).
pub fn arm() {
    ARMED.store(true, Ordering::SeqCst);
}

/// Stop collecting (tests; production sinks stay armed for life).
pub fn disarm() {
    ARMED.store(false, Ordering::SeqCst);
}

/// Monotonic counters. Names follow the Prometheus `_total` convention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Optimizer steps completed (`engine::Run::train_one`).
    Steps,
    /// Host→device transfers (`runtime::pjrt::TransferCounters`).
    Uploads,
    /// Device→host transfers (`runtime::pjrt::TransferCounters`).
    Downloads,
    /// Jobs that ran past their submitted deadline (first detection).
    DeadlineMiss,
    /// Scheduler quanta that overran the watchdog budget.
    QuantumOverrun,
    /// Supervised retries scheduled after a job failure.
    Retries,
    /// Jobs quarantined after exhausting their retry budget.
    Quarantines,
    /// Events skipped past a lagging `events` cursor by the ring clamp.
    EventsDropped,
    /// Wire requests parsed and dispatched by the serve control plane.
    WireRequests,
    /// Wire requests answered with an error response.
    WireErrors,
    /// Full-state checkpoint snapshots written.
    CheckpointSaves,
    /// Full-state checkpoint restores performed.
    CheckpointRestores,
}

impl Counter {
    pub const ALL: [Counter; 12] = [
        Counter::Steps,
        Counter::Uploads,
        Counter::Downloads,
        Counter::DeadlineMiss,
        Counter::QuantumOverrun,
        Counter::Retries,
        Counter::Quarantines,
        Counter::EventsDropped,
        Counter::WireRequests,
        Counter::WireErrors,
        Counter::CheckpointSaves,
        Counter::CheckpointRestores,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::Steps => "revffn_steps_total",
            Counter::Uploads => "revffn_transfer_uploads_total",
            Counter::Downloads => "revffn_transfer_downloads_total",
            Counter::DeadlineMiss => "revffn_deadline_miss_total",
            Counter::QuantumOverrun => "revffn_quantum_overrun_total",
            Counter::Retries => "revffn_retries_total",
            Counter::Quarantines => "revffn_quarantine_total",
            Counter::EventsDropped => "revffn_events_dropped_total",
            Counter::WireRequests => "revffn_wire_requests_total",
            Counter::WireErrors => "revffn_wire_errors_total",
            Counter::CheckpointSaves => "revffn_checkpoint_saves_total",
            Counter::CheckpointRestores => "revffn_checkpoint_restores_total",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Counter::Steps => "Optimizer steps completed",
            Counter::Uploads => "Host-to-device transfers",
            Counter::Downloads => "Device-to-host transfers",
            Counter::DeadlineMiss => "Jobs that ran past their submitted deadline",
            Counter::QuantumOverrun => "Scheduler quanta that overran the watchdog budget",
            Counter::Retries => "Supervised retries scheduled after job failures",
            Counter::Quarantines => "Jobs quarantined after exhausting their retry budget",
            Counter::EventsDropped => "Events skipped past lagging cursors by the ring clamp",
            Counter::WireRequests => "Wire requests dispatched by the serve control plane",
            Counter::WireErrors => "Wire requests answered with an error response",
            Counter::CheckpointSaves => "Full-state checkpoint snapshots written",
            Counter::CheckpointRestores => "Full-state checkpoint restores performed",
        }
    }

    fn index(self) -> usize {
        Counter::ALL.iter().position(|c| *c == self).unwrap_or(0)
    }
}

/// Instantaneous integer gauges (set/inc/dec semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    /// Live `events` follower connections.
    FollowersActive,
    /// Last observed follower's event-log lag (total − cursor).
    FollowerLag,
}

impl Gauge {
    pub const ALL: [Gauge; 2] = [Gauge::FollowersActive, Gauge::FollowerLag];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::FollowersActive => "revffn_followers_active",
            Gauge::FollowerLag => "revffn_follower_lag_events",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Gauge::FollowersActive => "Live events-follower connections",
            Gauge::FollowerLag => "Last observed follower's event-log lag in events",
        }
    }

    fn index(self) -> usize {
        Gauge::ALL.iter().position(|g| *g == self).unwrap_or(0)
    }
}

const ZERO: AtomicU64 = AtomicU64::new(0);
static COUNTERS: [AtomicU64; Counter::ALL.len()] = [ZERO; Counter::ALL.len()];
static GAUGES: [AtomicU64; Gauge::ALL.len()] = [ZERO; Gauge::ALL.len()];

/// Histogram bucket upper bounds, microseconds; one implicit overflow
/// bucket follows. Log-spaced to cover a 50 µs PJRT transfer through a
/// multi-second checkpoint write.
pub const BUCKET_BOUNDS_US: [u64; 13] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000, 1_000_000];

const N_BUCKETS: usize = BUCKET_BOUNDS_US.len() + 1;

struct Hist {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

const HIST_ZERO: Hist = Hist { buckets: [ZERO; N_BUCKETS], count: ZERO, sum_us: ZERO };
static HISTS: [Hist; Site::ALL.len()] = [HIST_ZERO; Site::ALL.len()];

/// Bucket index a value (µs) falls into: first bound `>=` the value,
/// else the overflow bucket.
pub fn bucket_index(us: u64) -> usize {
    BUCKET_BOUNDS_US.iter().position(|b| us <= *b).unwrap_or(BUCKET_BOUNDS_US.len())
}

/// Add 1 to a counter (no-op while disarmed).
#[inline]
pub fn inc(c: Counter) {
    add(c, 1);
}

/// Add `n` to a counter (no-op while disarmed).
#[inline]
pub fn add(c: Counter, n: u64) {
    if !armed() {
        return;
    }
    COUNTERS[c.index()].fetch_add(n, Ordering::Relaxed);
}

/// Current counter value (reads even while disarmed).
pub fn value(c: Counter) -> u64 {
    COUNTERS[c.index()].load(Ordering::Relaxed)
}

/// Set a gauge (no-op while disarmed).
#[inline]
pub fn gauge_set(g: Gauge, v: u64) {
    if !armed() {
        return;
    }
    GAUGES[g.index()].store(v, Ordering::Relaxed);
}

/// Increment a gauge (no-op while disarmed).
#[inline]
pub fn gauge_inc(g: Gauge) {
    if !armed() {
        return;
    }
    GAUGES[g.index()].fetch_add(1, Ordering::Relaxed);
}

/// Decrement a gauge, saturating at zero (no-op while disarmed).
#[inline]
pub fn gauge_dec(g: Gauge) {
    if !armed() {
        return;
    }
    let _ = GAUGES[g.index()].fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
        Some(v.saturating_sub(1))
    });
}

/// Current gauge value (reads even while disarmed).
pub fn gauge_value(g: Gauge) -> u64 {
    GAUGES[g.index()].load(Ordering::Relaxed)
}

/// Record one span duration into its site's histogram (no-op while
/// disarmed). Called by `obs::trace::SpanGuard` on every span close.
#[inline]
pub fn observe(site: Site, d: Duration) {
    if !armed() {
        return;
    }
    let us = d.as_micros().min(u64::MAX as u128) as u64;
    let h = &HISTS[site.index()];
    h.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
    h.count.fetch_add(1, Ordering::Relaxed);
    h.sum_us.fetch_add(us, Ordering::Relaxed);
}

/// Point-in-time view of one site's histogram. Quantiles are bucket
/// upper bounds (conservative: the true quantile is ≤ the reported one,
/// except in the overflow bucket where the largest finite bound is
/// reported).
#[derive(Debug, Clone, Copy)]
pub struct HistSnapshot {
    pub site: Site,
    pub count: u64,
    pub sum_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

/// Point-in-time view of the whole registry.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub counters: Vec<(Counter, u64)>,
    pub gauges: Vec<(Gauge, u64)>,
    pub hists: Vec<HistSnapshot>,
}

impl Snapshot {
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters.iter().find(|(k, _)| *k == c).map_or(0, |(_, v)| *v)
    }

    pub fn hist(&self, site: Site) -> Option<&HistSnapshot> {
        self.hists.iter().find(|h| h.site == site)
    }
}

/// Quantile estimate over bucket counts: the upper bound of the first
/// bucket whose cumulative count reaches `q * total`.
fn quantile_us(buckets: &[u64; N_BUCKETS], total: u64, q: f64) -> u64 {
    if total == 0 {
        return 0;
    }
    let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, b) in buckets.iter().enumerate() {
        cum += *b;
        if cum >= rank {
            return BUCKET_BOUNDS_US.get(i).copied().unwrap_or(BUCKET_BOUNDS_US[12]);
        }
    }
    BUCKET_BOUNDS_US[12]
}

/// Snapshot every collector (histograms with zero observations are
/// omitted).
pub fn snapshot() -> Snapshot {
    let counters = Counter::ALL.iter().map(|c| (*c, value(*c))).collect();
    let gauges = Gauge::ALL.iter().map(|g| (*g, gauge_value(*g))).collect();
    let mut hists = Vec::new();
    for site in Site::ALL {
        let h = &HISTS[site.index()];
        let count = h.count.load(Ordering::Relaxed);
        if count == 0 {
            continue;
        }
        let mut buckets = [0u64; N_BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(h.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        hists.push(HistSnapshot {
            site,
            count,
            sum_s: h.sum_us.load(Ordering::Relaxed) as f64 / 1e6,
            p50_s: quantile_us(&buckets, count, 0.50) as f64 / 1e6,
            p95_s: quantile_us(&buckets, count, 0.95) as f64 / 1e6,
            p99_s: quantile_us(&buckets, count, 0.99) as f64 / 1e6,
        });
    }
    Snapshot { counters, gauges, hists }
}

/// Zero every collector (tests and bench sections).
pub fn reset() {
    for c in &COUNTERS {
        c.store(0, Ordering::Relaxed);
    }
    for g in &GAUGES {
        g.store(0, Ordering::Relaxed);
    }
    for h in &HISTS {
        for b in &h.buckets {
            b.store(0, Ordering::Relaxed);
        }
        h.count.store(0, Ordering::Relaxed);
        h.sum_us.store(0, Ordering::Relaxed);
    }
}

static TEST_GATE: Mutex<()> = Mutex::new(());

/// Serialize tests that arm/reset the process-global registry (same
/// pattern as `util::faults::test_lock`).
pub fn test_lock() -> MutexGuard<'static, ()> {
    TEST_GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    #[test]
    fn disarmed_collectors_record_nothing() {
        let _g = test_lock();
        disarm();
        reset();
        inc(Counter::Steps);
        add(Counter::Uploads, 7);
        gauge_set(Gauge::FollowerLag, 9);
        observe(Site::EngineStep, Duration::from_millis(3));
        assert_eq!(value(Counter::Steps), 0);
        assert_eq!(value(Counter::Uploads), 0);
        assert_eq!(gauge_value(Gauge::FollowerLag), 0);
        assert!(snapshot().hists.is_empty());
    }

    #[test]
    fn concurrent_increments_all_land() {
        let _g = test_lock();
        reset();
        arm();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(|| {
                    for _ in 0..1000 {
                        inc(Counter::Steps);
                        add(Counter::Uploads, 2);
                        observe(Site::EngineStep, Duration::from_micros(80));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().expect("worker panicked");
        }
        assert_eq!(value(Counter::Steps), 8000);
        assert_eq!(value(Counter::Uploads), 16000);
        let snap = snapshot();
        let h = snap.hist(Site::EngineStep).expect("histogram recorded");
        assert_eq!(h.count, 8000);
        disarm();
        reset();
    }

    #[test]
    fn gauges_set_inc_dec_saturate() {
        let _g = test_lock();
        reset();
        arm();
        gauge_inc(Gauge::FollowersActive);
        gauge_inc(Gauge::FollowersActive);
        gauge_dec(Gauge::FollowersActive);
        assert_eq!(gauge_value(Gauge::FollowersActive), 1);
        gauge_dec(Gauge::FollowersActive);
        gauge_dec(Gauge::FollowersActive); // below zero saturates
        assert_eq!(gauge_value(Gauge::FollowersActive), 0);
        gauge_set(Gauge::FollowerLag, 41);
        assert_eq!(gauge_value(Gauge::FollowerLag), 41);
        disarm();
        reset();
    }

    #[test]
    fn bucket_boundaries_are_le_inclusive() {
        // a value exactly on a bound lands in that bucket; one past it
        // lands in the next
        for (i, b) in BUCKET_BOUNDS_US.iter().enumerate() {
            assert_eq!(bucket_index(*b), i, "bound {b}µs");
            assert_eq!(bucket_index(*b + 1), i + 1, "bound {b}µs + 1");
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(u64::MAX), BUCKET_BOUNDS_US.len());
    }

    #[test]
    fn quantiles_bound_the_recorded_values() {
        // property: for any batch of durations, each reported quantile
        // is >= the true quantile of the recorded values (bucket upper
        // bounds are conservative) and within one bucket of it
        let _g = test_lock();
        prop_check(
            "hist_quantile_bounds",
            60,
            0xB0B5,
            |rng: &mut Rng| {
                let n = 1 + rng.gen_range(0..40);
                (0..n).map(|_| rng.gen_range(0..2_000_000) as u64).collect::<Vec<u64>>()
            },
            |values: &Vec<u64>| {
                reset();
                arm();
                for us in values {
                    observe(Site::EngineStep, Duration::from_micros(*us));
                }
                let snap = snapshot();
                let h = snap.hist(Site::EngineStep).expect("recorded");
                disarm();
                reset();
                let mut sorted = values.clone();
                sorted.sort_unstable();
                let true_q = |q: f64| {
                    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
                    sorted[rank - 1]
                };
                let ok = |got_s: f64, q: f64| {
                    let truth = true_q(q);
                    let got = (got_s * 1e6).round() as u64;
                    // conservative upper bound…
                    let upper_ok = got >= truth.min(BUCKET_BOUNDS_US[12]);
                    // …but not past the bucket the truth falls in
                    let cap = BUCKET_BOUNDS_US
                        .get(bucket_index(truth))
                        .copied()
                        .unwrap_or(BUCKET_BOUNDS_US[12]);
                    upper_ok && got <= cap.max(truth)
                };
                h.count == values.len() as u64
                    && ok(h.p50_s, 0.50)
                    && ok(h.p95_s, 0.95)
                    && ok(h.p99_s, 0.99)
            },
        );
    }

    #[test]
    fn snapshot_reads_back_counters_and_sums() {
        let _g = test_lock();
        reset();
        arm();
        add(Counter::Downloads, 3);
        observe(Site::PjrtDownload, Duration::from_micros(100));
        observe(Site::PjrtDownload, Duration::from_micros(200));
        let snap = snapshot();
        assert_eq!(snap.counter(Counter::Downloads), 3);
        let h = snap.hist(Site::PjrtDownload).expect("recorded");
        assert_eq!(h.count, 2);
        assert!((h.sum_s - 300e-6).abs() < 1e-9);
        disarm();
        reset();
    }
}
