//! Analytic peak-VRAM model for single-GPU fine-tuning (Table 1, E1).
//!
//! Peak VRAM on a data-parallel single GPU is arithmetic over tensor
//! lifetimes; this module implements that arithmetic per method at any
//! geometry (including the real Qwen1.5-MoE-A2.7B, which is never
//! instantiated). Terms:
//!
//! * weights           — all parameters, `w_bytes` each
//! * master weights    — fp32 copies of *trainable* params (mixed precision)
//! * gradients         — trainable params (LoMo: one layer at a time)
//! * optimizer moments — AdamW m+v on trainable (GaLore: rank-r subspace;
//!                       LoMo: none)
//! * activations       — method-dependent live set (see below)
//! * logits + loss     — B·S·V fp32 (chunked cross-entropy optional)
//!
//! Activation live-sets:
//! * full caching (PEFT)   : L · block_act + L · boundary
//! * checkpointing (SFT…)  : L · boundary + 1 · block_act (recompute)
//! * reversible (RevFFN)   : 2 · boundary(d/2 streams ⇒ 1 · boundary) +
//!                           1 · block_act — **independent of L** (§3.1)
//!
//! The model is validated two ways (memory/calib.rs): against XLA's
//! live-buffer analysis of the lowered tiny graphs, and against the
//! paper's own Table 1 under its assumptions preset.

/// Model geometry (mirrors the python ModelConfig; constructed from a
/// manifest or from the named presets below).
#[derive(Debug, Clone)]
pub struct Geometry {
    pub name: String,
    pub vocab_size: u64,
    pub d_model: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub n_kv_heads: u64,
    pub n_experts: u64,
    pub top_k: u64,
    pub d_ff_expert: u64,
    pub d_ff_shared: u64,
}

impl Geometry {
    /// Real Qwen1.5-MoE-A2.7B geometry (14.3 B total / 2.7 B activated).
    pub fn qwen15_moe_a27b() -> Self {
        Geometry {
            name: "qwen15_moe_a27b".into(),
            vocab_size: 151_936,
            d_model: 2048,
            n_layers: 24,
            n_heads: 16,
            n_kv_heads: 16,
            n_experts: 60,
            top_k: 4,
            d_ff_expert: 1408,
            d_ff_shared: 5632,
        }
    }

    pub fn from_manifest(m: &crate::runtime::artifact::ModelGeometry) -> Self {
        Geometry {
            name: m.name.clone(),
            vocab_size: m.vocab_size as u64,
            d_model: m.d_model as u64,
            n_layers: m.n_layers as u64,
            n_heads: m.n_heads as u64,
            n_kv_heads: m.n_kv_heads as u64,
            n_experts: m.n_experts as u64,
            top_k: m.top_k as u64,
            d_ff_expert: m.d_ff_expert as u64,
            d_ff_shared: m.d_ff_shared as u64,
        }
    }

    pub fn d_kv(&self) -> u64 {
        self.d_model / self.n_heads * self.n_kv_heads
    }

    /// Parameters of one decoder layer's attention block.
    pub fn attn_params(&self) -> u64 {
        let d = self.d_model;
        2 * d * d + 2 * d * self.d_kv()
    }

    /// Parameters of one decoder layer's MoE block (router + experts +
    /// shared expert + shared gate).
    pub fn moe_params(&self) -> u64 {
        let d = self.d_model;
        d * self.n_experts
            + self.n_experts * 3 * d * self.d_ff_expert
            + 3 * d * self.d_ff_shared
            + d
    }

    pub fn router_params(&self) -> u64 {
        self.d_model * self.n_experts * self.n_layers
    }

    /// Per-layer norm gains (standard model: 2·d).
    pub fn norm_params(&self) -> u64 {
        2 * self.d_model
    }

    /// RevFFN adapters per layer: 2 P↑(q,kv) + P↓ for attention,
    /// P↑ + P↓ for the MLP, each d/2·d — plus 3 stream norms (d/2).
    pub fn adapter_params(&self) -> u64 {
        let d = self.d_model;
        let dh = d / 2;
        5 * dh * d + 3 * dh
    }

    pub fn embed_params(&self) -> u64 {
        self.vocab_size * self.d_model
    }

    /// Total parameters of the standard (baseline) model.
    pub fn total_params(&self) -> u64 {
        self.embed_params()
            + self.n_layers * (self.attn_params() + self.moe_params() + self.norm_params())
            + self.d_model
    }

    /// Total parameters of the RevFFN-wrapped model.
    pub fn total_params_revffn(&self) -> u64 {
        // stream norms replace the 2 full-d norms (3·d/2 counted in adapters)
        self.embed_params()
            + self.n_layers * (self.attn_params() + self.moe_params() + self.adapter_params())
            + self.d_model
    }

    /// Largest single-layer trainable tensor group (LoMo's live-grad set).
    pub fn max_layer_params(&self) -> u64 {
        (self.attn_params() + self.moe_params() + self.norm_params()).max(self.embed_params())
    }
}

/// Numeric-format assumptions for the accounting.
#[derive(Debug, Clone, Copy)]
pub struct Assumptions {
    pub w_bytes: f64,
    pub g_bytes: f64,
    /// Per-moment bytes (AdamW has two moments).
    pub m_bytes: f64,
    pub act_bytes: f64,
    /// Keep fp32 master copies of trainable weights?
    pub master_weights: bool,
    /// Chunked cross-entropy (logits materialized in S-chunks)?
    pub chunked_logits: bool,
    /// PEFT baselines also run gradient checkpointing (standard HF
    /// practice at fine-tuning batch sizes; the lowered tiny graphs do
    /// NOT, so the f32 calibration preset turns this off).
    pub peft_checkpointing: bool,
    /// Allocator fragmentation / workspace multiplier on the total.
    pub overhead: f64,
}

impl Assumptions {
    /// Parse a named preset: `"bf16_mixed"`, `"paper"`, or `"f32"`
    /// (the CLI `--assumptions` / serve-config vocabulary).
    pub fn parse(name: &str) -> crate::error::Result<Self> {
        match name {
            "bf16_mixed" => Ok(Assumptions::bf16_mixed()),
            "paper" => Ok(Assumptions::paper_calibrated()),
            "f32" => Ok(Assumptions::f32_exact()),
            other => Err(crate::error::Error::Config(format!(
                "unknown assumptions preset {other:?}; expected bf16_mixed | paper | f32"
            ))),
        }
    }

    /// bf16 compute, fp32 moments + master — the standard mixed-precision
    /// recipe (our principled default).
    pub fn bf16_mixed() -> Self {
        Assumptions {
            w_bytes: 2.0,
            g_bytes: 2.0,
            m_bytes: 4.0,
            act_bytes: 2.0,
            master_weights: true,
            chunked_logits: true,
            peft_checkpointing: true,
            overhead: 1.05,
        }
    }

    /// The weakest-footprint recipe consistent with the paper's Table 1
    /// scale: bf16 everything, 8-bit moments, no master copies, chunked
    /// logits. Used for the "paper-calibrated" rows.
    pub fn paper_calibrated() -> Self {
        Assumptions {
            w_bytes: 2.0,
            g_bytes: 2.0,
            m_bytes: 1.0,
            act_bytes: 2.0,
            master_weights: false,
            chunked_logits: true,
            peft_checkpointing: true,
            overhead: 1.05,
        }
    }

    /// Pure f32 (matches the tiny AOT artifacts → XLA calibration).
    pub fn f32_exact() -> Self {
        Assumptions {
            w_bytes: 4.0,
            g_bytes: 4.0,
            m_bytes: 4.0,
            act_bytes: 4.0,
            master_weights: false,
            chunked_logits: false,
            peft_checkpointing: false,
            overhead: 1.0,
        }
    }
}

/// Method rows of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Lora,
    Dora,
    Ia3,
    SftCheckpoint,
    Lomo,
    Galore,
    Revffn,
}

impl Method {
    pub const ALL: [Method; 7] = [
        Method::Lora,
        Method::Dora,
        Method::Ia3,
        Method::SftCheckpoint,
        Method::Lomo,
        Method::Galore,
        Method::Revffn,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Method::Lora => "LoRA",
            Method::Dora => "DoRA",
            Method::Ia3 => "(IA)^3",
            Method::SftCheckpoint => "SFT + Checkpointing",
            Method::Lomo => "LOMO",
            Method::Galore => "GaLore",
            Method::Revffn => "RevFFN",
        }
    }

    pub fn is_full_parameter(&self) -> bool {
        matches!(self, Method::SftCheckpoint | Method::Lomo | Method::Galore | Method::Revffn)
    }
}

/// Per-component byte breakdown.
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    pub weights: f64,
    pub master: f64,
    pub grads: f64,
    pub moments: f64,
    pub activations: f64,
    pub logits: f64,
    pub total: f64,
}

impl Breakdown {
    pub fn gb(bytes: f64) -> f64 {
        bytes / 1e9
    }
}

/// The analytic model.
pub struct MemoryModel {
    pub geo: Geometry,
    pub assume: Assumptions,
    /// LoRA/GaLore rank.
    pub rank: u64,
}

impl MemoryModel {
    pub fn new(geo: Geometry, assume: Assumptions) -> Self {
        MemoryModel { geo, assume, rank: 8 }
    }

    fn lora_params(&self) -> u64 {
        let g = &self.geo;
        let d = g.d_model;
        // A: d×r + B: r×dout for wq,wk,wv,wo
        g.n_layers * self.rank * (2 * (d + d) + 2 * (d + g.d_kv()))
    }

    fn ia3_params(&self) -> u64 {
        let g = &self.geo;
        g.n_layers * (2 * g.d_kv() + g.d_ff_shared)
    }

    fn trainable_params(&self, m: Method) -> u64 {
        let g = &self.geo;
        match m {
            Method::Lora => self.lora_params(),
            Method::Dora => self.lora_params() + g.n_layers * (2 * g.d_model + 2 * g.d_kv()),
            Method::Ia3 => self.ia3_params(),
            Method::SftCheckpoint | Method::Lomo => g.total_params(),
            Method::Galore => g.total_params(),
            Method::Revffn => g.total_params_revffn() - g.router_params(),
        }
    }

    fn total_weights(&self, m: Method) -> u64 {
        match m {
            Method::Revffn => self.geo.total_params_revffn(),
            Method::Lora | Method::Dora => self.geo.total_params() + self.trainable_params(m),
            Method::Ia3 => self.geo.total_params() + self.trainable_params(m),
            _ => self.geo.total_params(),
        }
    }

    /// Live activation elements for one decoder block's recompute
    /// workspace (flash attention — no S² score materialization).
    fn block_act_elems(&self, tokens: f64, m: Method) -> f64 {
        let g = &self.geo;
        let d = g.d_model as f64;
        let f = g.d_ff_expert as f64;
        let fs = g.d_ff_shared as f64;
        let k = g.top_k as f64;
        let e = g.n_experts as f64;
        // norm out + q,k,v + attn out + proj out
        let attn = 5.0 * d + g.d_kv() as f64;
        // router logits + combine + top-k expert intermediates + shared
        let moe = 2.0 * e + k * 2.0 * f + 2.0 * fs + d;
        let adapters = match m {
            Method::Revffn => 3.0 * d, // P↑ outputs ×2 + P↓ input
            Method::Lora | Method::Dora => 4.0 * self.rank as f64,
            _ => 0.0,
        };
        tokens * (attn + moe + adapters)
    }

    /// Activation bytes live at the backward-pass peak.
    fn activation_bytes(&self, m: Method, batch: u64, seq: u64) -> f64 {
        let g = &self.geo;
        let tokens = (batch * seq) as f64;
        let boundary = tokens * g.d_model as f64; // one inter-layer hidden
        let block = self.block_act_elems(tokens, m);
        let l = g.n_layers as f64;
        let elems = match m {
            // PEFT: every block's set cached, unless the run enables
            // gradient checkpointing (assumption flag)
            Method::Lora | Method::Dora | Method::Ia3 => {
                if self.assume.peft_checkpointing {
                    l * boundary + block
                } else {
                    l * (block + boundary)
                }
            }
            // full FT with per-layer checkpointing: boundaries + one block
            Method::SftCheckpoint | Method::Lomo | Method::Galore => l * boundary + block,
            // reversible: two d/2 streams (=1 boundary) + one block —
            // independent of depth (§3.1)
            Method::Revffn => 2.0 * boundary + block,
        };
        elems * self.assume.act_bytes
    }

    /// [`activation_bytes`](Self::breakdown) made public for the HLO
    /// liveness cross-check (`analysis/liveness.rs`): the per-program
    /// peak predictions price backward-carrying programs from exactly
    /// the live set the breakdown uses.
    pub fn backward_activation_bytes(&self, m: Method, batch: u64, seq: u64) -> f64 {
        self.activation_bytes(m, batch, seq)
    }

    /// Activation bytes live during an inference-only forward: one
    /// inter-layer boundary plus one block's workspace (layers reuse the
    /// workspace; nothing is cached for a backward pass).
    pub fn forward_activation_bytes(&self, m: Method, batch: u64, seq: u64) -> f64 {
        let tokens = (batch * seq) as f64;
        let boundary = tokens * self.geo.d_model as f64;
        (boundary + self.block_act_elems(tokens, m)) * self.assume.act_bytes
    }

    /// Logits + log-softmax workspace bytes (public wrapper over the
    /// breakdown's logits term, for the same cross-check).
    pub fn logits_term_bytes(&self, batch: u64, seq: u64) -> f64 {
        self.logits_bytes(batch, seq)
    }

    fn logits_bytes(&self, batch: u64, seq: u64) -> f64 {
        let v = self.geo.vocab_size as f64;
        let toks = if self.assume.chunked_logits {
            // vocab-chunked cross-entropy (Liger-style): 1/64 of positions
            (batch * seq) as f64 / 64.0
        } else {
            (batch * seq) as f64
        };
        // logits + log-softmax workspace, fp32
        2.0 * toks * v * 4.0
    }

    /// Full breakdown at a given microbatch.
    pub fn breakdown(&self, m: Method, batch: u64, seq: u64) -> Breakdown {
        let a = &self.assume;
        let trainable = self.trainable_params(m) as f64;
        let weights = self.total_weights(m) as f64 * a.w_bytes;
        // LoMo's fused update writes weights in place — no fp32 master copy
        // (that is half its point); other methods keep one under mixed
        // precision when the recipe says so.
        let master = if a.master_weights && m != Method::Lomo {
            trainable * 4.0
        } else {
            0.0
        };
        let grads = match m {
            // LoMo fuses grad computation with the update: only one
            // layer's gradients are ever materialized.
            Method::Lomo => self.geo.max_layer_params() as f64 * a.g_bytes,
            _ => trainable * a.g_bytes,
        };
        let moments = match m {
            Method::Lomo => 0.0,
            Method::Galore => {
                // rank-r moments for 2-D tensors; embed dominates
                let g = &self.geo;
                let r = self.rank as f64;
                let two_d: f64 = (g.embed_params() / g.d_model) as f64 * r // embed: V×d -> r×V
                    + (g.n_layers as f64)
                        * (r * (2.0 * g.d_model as f64 + 2.0 * g.d_kv() as f64) // attn
                            + g.n_experts as f64 * 3.0 * r * g.d_ff_expert.max(g.d_model) as f64
                            + 3.0 * r * g.d_ff_shared.max(g.d_model) as f64);
                2.0 * two_d * a.m_bytes
            }
            _ => 2.0 * trainable * a.m_bytes,
        };
        let activations = self.activation_bytes(m, batch, seq);
        let logits = self.logits_bytes(batch, seq);
        let total = (weights + master + grads + moments + activations + logits) * a.overhead;
        Breakdown { weights, master, grads, moments, activations, logits, total }
    }

    /// Peak VRAM in GB.
    pub fn peak_gb(&self, m: Method, batch: u64, seq: u64) -> f64 {
        Breakdown::gb(self.breakdown(m, batch, seq).total)
    }

    /// Bytes of a *host-side* full-state snapshot: all weights plus the
    /// two Adam moments of the trainable set. This is what a suspended
    /// job pins in host RAM as literal mirrors (and what a checkpoint
    /// materializes) — always f32, regardless of the device-dtype
    /// assumptions, because the runtime's host literals are f32.
    pub fn host_state_bytes(&self, m: Method) -> f64 {
        let trainable = self.trainable_params(m) as f64;
        (self.total_weights(m) as f64 + 2.0 * trainable) * 4.0
    }

    /// [`MemoryModel::host_state_bytes`] in GB.
    pub fn host_state_gb(&self, m: Method) -> f64 {
        Breakdown::gb(self.host_state_bytes(m))
    }

    /// Largest batch (doubling + linear refine) fitting `budget_gb`.
    pub fn max_batch(&self, m: Method, seq: u64, budget_gb: f64) -> u64 {
        if self.peak_gb(m, 1, seq) > budget_gb {
            return 0;
        }
        let mut b = 1u64;
        while self.peak_gb(m, b * 2, seq) <= budget_gb && b < 1 << 20 {
            b *= 2;
        }
        let mut best = b;
        for cand in b..b * 2 {
            if self.peak_gb(m, cand, seq) <= budget_gb {
                best = cand;
            } else {
                break;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> MemoryModel {
        MemoryModel::new(Geometry::qwen15_moe_a27b(), Assumptions::bf16_mixed())
    }

    #[test]
    fn qwen_total_params_near_14_3b() {
        let g = Geometry::qwen15_moe_a27b();
        let total = g.total_params() as f64;
        assert!(
            (total - 14.3e9).abs() / 14.3e9 < 0.05,
            "got {total:.3e}, want ~14.3e9"
        );
    }

    #[test]
    fn revffn_adds_small_adapter_overhead() {
        let g = Geometry::qwen15_moe_a27b();
        let extra = g.total_params_revffn() as f64 - g.total_params() as f64;
        assert!(extra > 0.0);
        let frac = extra / g.total_params() as f64;
        assert!(frac < 0.02, "adapters must be O(d^2): {extra:.2e}");
    }

    #[test]
    fn peft_uses_less_than_full_ft() {
        let m = model();
        let (b, s) = (8, 2048);
        assert!(m.peak_gb(Method::Lora, b, s) < m.peak_gb(Method::SftCheckpoint, b, s));
        assert!(m.peak_gb(Method::Ia3, b, s) < m.peak_gb(Method::SftCheckpoint, b, s));
    }

    #[test]
    fn revffn_beats_sft_checkpointing_at_training_batch() {
        // The reversible saving scales with batch: at fine-tuning batches
        // (B>=16) activation savings dominate the adapter-state overhead.
        let m = model();
        let (b, s) = (32, 2048);
        assert!(m.peak_gb(Method::Revffn, b, s) < m.peak_gb(Method::SftCheckpoint, b, s));
    }

    #[test]
    fn revffn_crossover_batch_is_small() {
        // below a handful of samples the adapters cost more than the
        // activations save — the crossover must sit at single-digit batch
        let m = MemoryModel::new(Geometry::qwen15_moe_a27b(), Assumptions::paper_calibrated());
        let rev16 = m.peak_gb(Method::Revffn, 16, 2048);
        let sft16 = m.peak_gb(Method::SftCheckpoint, 16, 2048);
        assert!(rev16 < sft16, "by B=16 RevFFN must win: {rev16} vs {sft16}");
    }

    #[test]
    fn revffn_activations_depth_independent() {
        let mut g = Geometry::qwen15_moe_a27b();
        let a = Assumptions::bf16_mixed();
        g.n_layers = 24;
        let m24 = MemoryModel::new(g.clone(), a).breakdown(Method::Revffn, 8, 2048).activations;
        g.n_layers = 48;
        let m48 = MemoryModel::new(g, a).breakdown(Method::Revffn, 8, 2048).activations;
        assert!((m48 - m24).abs() / m24 < 1e-9, "reversible act must not scale with L");
    }

    #[test]
    fn sft_activations_scale_with_depth() {
        let mut g = Geometry::qwen15_moe_a27b();
        let a = Assumptions::bf16_mixed();
        g.n_layers = 24;
        let m24 = MemoryModel::new(g.clone(), a)
            .breakdown(Method::SftCheckpoint, 8, 2048)
            .activations;
        g.n_layers = 48;
        let m48 = MemoryModel::new(g, a).breakdown(Method::SftCheckpoint, 8, 2048).activations;
        assert!(m48 > 1.5 * m24);
    }

    #[test]
    fn lomo_has_no_moments() {
        let m = model();
        let b = m.breakdown(Method::Lomo, 8, 2048);
        assert_eq!(b.moments, 0.0);
        assert!(b.grads < m.breakdown(Method::SftCheckpoint, 8, 2048).grads / 4.0);
    }

    #[test]
    fn galore_moments_much_smaller_than_adamw() {
        let m = model();
        let adamw = m.breakdown(Method::SftCheckpoint, 8, 2048).moments;
        let galore = m.breakdown(Method::Galore, 8, 2048).moments;
        assert!(galore < adamw / 10.0, "galore {galore:.2e} vs adamw {adamw:.2e}");
    }

    #[test]
    fn max_batch_monotone_in_budget() {
        let m = model();
        let b40 = m.max_batch(Method::Revffn, 2048, 40.0);
        let b80 = m.max_batch(Method::Revffn, 2048, 80.0);
        assert!(b80 >= b40);
    }

    #[test]
    fn max_batch_zero_when_weights_dont_fit() {
        let m = model();
        assert_eq!(m.max_batch(Method::SftCheckpoint, 2048, 1.0), 0);
    }

    #[test]
    fn host_snapshot_smaller_than_device_peak_but_nonzero() {
        // the admission host ledger reserves this: it must be a real
        // cost (weights + both moments) yet below the device peak (no
        // activations, no logits) at fine-tuning shapes
        let m = model();
        for method in Method::ALL {
            let host = m.host_state_gb(method);
            let peak = m.peak_gb(method, 32, 2048);
            assert!(host > 0.0, "{method:?} host snapshot must cost something");
            assert!(host < peak, "{method:?}: host {host:.1} GB vs peak {peak:.1} GB");
        }
        // full-parameter methods pin far bigger host mirrors than PEFT:
        // LoRA's moments cover adapters only, SFT's cover everything
        let lora = m.host_state_gb(Method::Lora);
        let sft = m.host_state_gb(Method::SftCheckpoint);
        assert!(sft > 1.5 * lora, "sft host {sft:.1} GB vs lora {lora:.1} GB");
    }

    #[test]
    fn assumptions_presets_parse_by_name() {
        assert!(Assumptions::parse("bf16_mixed").unwrap().master_weights);
        assert!(!Assumptions::parse("paper").unwrap().master_weights);
        assert_eq!(Assumptions::parse("f32").unwrap().w_bytes, 4.0);
        assert!(Assumptions::parse("fp8").is_err());
    }
}
