//! Table-1 style report generation from the analytic memory model.

use crate::memory::model::{Assumptions, Breakdown, Geometry, MemoryModel, Method};

/// Paper Table 1 reference values (GB / samples-per-s) for comparison.
pub fn paper_table1(method: Method) -> (f64, f64) {
    match method {
        Method::Lora => (18.2, 75.4),
        Method::Dora => (19.5, 71.8),
        Method::Ia3 => (17.9, 74.1),
        Method::SftCheckpoint => (65.4, 19.7),
        Method::Lomo => (42.2, 17.3),
        Method::Galore => (45.1, 35.2),
        Method::Revffn => (39.5, 24.6),
    }
}

#[derive(Debug, Clone)]
pub struct MemoryRow {
    pub method: String,
    pub peak_gb: f64,
    pub max_batch: u64,
    pub breakdown: Breakdown,
    pub paper_gb: f64,
}

/// Build the Table-1 memory column at a given geometry/assumptions.
///
/// Mirrors the paper's protocol: each method's microbatch is the largest
/// fitting the 80 GB budget at `seq`; peak VRAM is reported at that batch
/// (so every row sits under, but near, the budget in the components that
/// matter for it).
pub fn table1_memory(
    geo: Geometry,
    assume: Assumptions,
    seq: u64,
    budget_gb: f64,
    fixed_batch: Option<u64>,
) -> Vec<MemoryRow> {
    let model = MemoryModel::new(geo, assume);
    Method::ALL
        .iter()
        .map(|&m| {
            let batch = fixed_batch.unwrap_or_else(|| model.max_batch(m, seq, budget_gb));
            let bd = model.breakdown(m, batch.max(1), seq);
            MemoryRow {
                method: m.label().to_string(),
                peak_gb: Breakdown::gb(bd.total),
                max_batch: batch,
                breakdown: bd,
                paper_gb: paper_table1(m).0,
            }
        })
        .collect()
}

/// Pretty-print the rows like the paper's table.
pub fn format_table(rows: &[MemoryRow], title: &str) -> String {
    let mut s = String::new();
    s.push_str(&format!("{title}\n"));
    s.push_str(&format!(
        "{:<22} {:>10} {:>10} {:>9} | {:>9} {:>9} {:>9} {:>9} {:>9}\n",
        "Method", "Peak(GB)", "Paper(GB)", "maxB", "weights", "master", "grads", "moments", "acts"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<22} {:>10.1} {:>10.1} {:>9} | {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>9.1}\n",
            r.method,
            r.peak_gb,
            r.paper_gb,
            r.max_batch,
            Breakdown::gb(r.breakdown.weights),
            Breakdown::gb(r.breakdown.master),
            Breakdown::gb(r.breakdown.grads),
            Breakdown::gb(r.breakdown.moments),
            Breakdown::gb(r.breakdown.activations),
        ));
    }
    s
}

/// Qualitative checks the paper's table implies (used by tests/benches).
pub fn ordering_checks(rows: &[MemoryRow]) -> Vec<(String, bool)> {
    let get = |label: &str| rows.iter().find(|r| r.method == label).map(|r| r.peak_gb);
    let mut out = Vec::new();
    if let (Some(lora), Some(sft)) = (get("LoRA"), get("SFT + Checkpointing")) {
        out.push(("PEFT (LoRA) below SFT+ckpt".to_string(), lora < sft));
    }
    if let (Some(rev), Some(sft)) = (get("RevFFN"), get("SFT + Checkpointing")) {
        out.push(("RevFFN below SFT+ckpt".to_string(), rev < sft));
    }
    if let Some(r) = activation_reduction(rows) {
        // The paper's "49% reduction" is the activation term (its peak
        // totals are not consistent with any fixed optimizer recipe —
        // see EXPERIMENTS.md E1); the reversible design halves it.
        out.push((
            format!("RevFFN activation reduction vs SFT = {:.0}% (paper text: 49%)", r * 100.0),
            r > 0.30,
        ));
    }
    if let (Some(rev), Some(lora)) = (get("RevFFN"), get("LoRA")) {
        out.push(("RevFFN above PEFT (full-parameter cost)".to_string(), rev > lora));
    }
    out
}

/// RevFFN's fractional *peak-VRAM* reduction vs SFT+ckpt.
pub fn rev_reduction(rows: &[MemoryRow]) -> Option<f64> {
    let get = |label: &str| rows.iter().find(|r| r.method == label).map(|r| r.peak_gb);
    let rev = get("RevFFN")?;
    let sft = get("SFT + Checkpointing")?;
    Some((sft - rev) / sft)
}

/// RevFFN's fractional *activation-memory* reduction vs SFT+ckpt — the
/// quantity the paper's "49% reduction" text actually tracks (the peak
/// totals in its Table 1 are not mutually consistent; soundness band 0).
pub fn activation_reduction(rows: &[MemoryRow]) -> Option<f64> {
    let get = |label: &str| {
        rows.iter().find(|r| r.method == label).map(|r| r.breakdown.activations)
    };
    let rev = get("RevFFN")?;
    let sft = get("SFT + Checkpointing")?;
    Some((sft - rev) / sft)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_rows_cover_all_methods() {
        let rows = table1_memory(
            Geometry::qwen15_moe_a27b(),
            Assumptions::bf16_mixed(),
            2048,
            80.0,
            Some(8),
        );
        assert_eq!(rows.len(), 7);
        assert!(rows.iter().all(|r| r.peak_gb > 0.0));
    }

    #[test]
    fn qualitative_orderings_hold_at_fixed_batch() {
        let rows = table1_memory(
            Geometry::qwen15_moe_a27b(),
            Assumptions::paper_calibrated(),
            2048,
            80.0,
            Some(64),
        );
        for (check, ok) in ordering_checks(&rows) {
            assert!(ok, "failed: {check}");
        }
    }

    #[test]
    fn formatting_contains_all_rows() {
        let rows = table1_memory(
            Geometry::qwen15_moe_a27b(),
            Assumptions::bf16_mixed(),
            2048,
            80.0,
            Some(4),
        );
        let text = format_table(&rows, "Table 1");
        assert!(text.contains("RevFFN"));
        assert!(text.contains("GaLore"));
        assert_eq!(text.lines().count(), 9);
    }
}
