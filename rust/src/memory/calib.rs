//! Calibration of the analytic VRAM model against XLA's live-buffer
//! analysis of the actually-lowered tiny graphs.
//!
//! `make artifacts` (with `--analyze`) embeds each variant's
//! `memory_analysis` — XLA's measured temp/argument/output buffer sizes
//! for the compiled train_step. The *temp* bytes correspond to our
//! activations(+workspace) term at f32; arguments/outputs correspond to
//! weights+moments. Comparing per method validates the model's relative
//! structure (RevFFN ≪ naive, checkpointing < PEFT caching, …).

use std::path::Path;

use crate::engine::Method as FtMethod;
use crate::error::Result;
use crate::memory::model::{Assumptions, Geometry, MemoryModel};
use crate::runtime::artifact::Artifact;

/// One calibration row: analytic vs measured.
#[derive(Debug, Clone)]
pub struct CalibRow {
    pub variant: String,
    pub measured_temp_bytes: u64,
    pub analytic_act_bytes: f64,
    /// measured / analytic (1.0 = perfect).
    pub ratio: f64,
}

/// Compare every analyzed variant under `cfg_dir` against the analytic
/// model at the same (f32) assumptions and batch shape. Variant →
/// method resolution goes through the `engine::Method` registry;
/// ablation-only variants (`revffn_naive`, `reconstruct*`) are skipped.
pub fn calibrate(cfg_dir: impl AsRef<Path>) -> Result<Vec<CalibRow>> {
    let index = crate::runtime::artifact::ArtifactIndex::load(&cfg_dir)?;
    let mut rows = Vec::new();
    for variant in &index.variants {
        let Some(method) = FtMethod::from_variant(variant).map(|m| m.memory_method()) else {
            continue;
        };
        let art = Artifact::load(cfg_dir.as_ref().join(variant))?;
        // prefer the undonated analysis: donation aliases args into temps
        // and would blur the pure-activation comparison
        let Some(ma) = art
            .manifest
            .memory_analysis_nodonate
            .as_ref()
            .or(art.manifest.memory_analysis.as_ref())
        else { continue };
        let geo = Geometry::from_manifest(&art.manifest.model);
        let model = MemoryModel::new(geo, Assumptions::f32_exact());
        let io = &art.manifest.io;
        let bd = model.breakdown(method, io.batch_size as u64, io.seq_len as u64);
        let analytic = bd.activations + bd.logits + bd.grads;
        rows.push(CalibRow {
            variant: variant.clone(),
            measured_temp_bytes: ma.temp_size_bytes,
            analytic_act_bytes: analytic,
            ratio: ma.temp_size_bytes as f64 / analytic.max(1.0),
        });
    }
    Ok(rows)
}

/// The reversibility memory claim, measured on the real lowered graphs:
/// XLA temp bytes of the reversible train step vs the identical math
/// without the custom VJP (`revffn_naive`). Returns (reversible, naive).
pub fn reversible_vs_naive(cfg_dir: impl AsRef<Path>) -> Result<Option<(u64, u64)>> {
    let dir = cfg_dir.as_ref();
    let load = |v: &str| -> Result<Option<u64>> {
        let p = dir.join(v);
        if !p.join("manifest.json").exists() {
            return Ok(None);
        }
        let m = Artifact::load(p)?.manifest;
        Ok(m
            .memory_analysis_nodonate
            .or(m.memory_analysis)
            .map(|m| m.temp_size_bytes))
    };
    match (load(FtMethod::Revffn.eval_variant())?, load("revffn_naive")?) {
        (Some(r), Some(n)) => Ok(Some((r, n))),
        _ => Ok(None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_dir() -> Option<std::path::PathBuf> {
        let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        p.join("index.json").exists().then_some(p)
    }

    #[test]
    fn calibration_rows_exist() {
        let Some(dir) = cfg_dir() else { return };
        let rows = calibrate(&dir).unwrap();
        assert!(rows.len() >= 5, "expected most variants analyzed, got {}", rows.len());
        for r in &rows {
            assert!(r.measured_temp_bytes > 0, "{}", r.variant);
        }
    }

    #[test]
    fn reversible_temp_strictly_below_naive() {
        let Some(dir) = cfg_dir() else { return };
        let Some((rev, naive)) = reversible_vs_naive(&dir).unwrap() else { return };
        assert!(
            rev < naive,
            "reversible backward must shrink XLA temp memory: {rev} vs {naive}"
        );
    }
}
