//! VRAM accounting: the analytic peak-memory model (Table 1's memory
//! column at real Qwen geometry), its calibration against XLA live-buffer
//! analysis of the lowered tiny graphs, and table-shaped reporting.

pub mod calib;
pub mod model;
pub mod report;

pub use model::{Assumptions, Breakdown, Geometry, MemoryModel, Method};
pub use report::{
    format_table, ordering_checks, paper_table1, rev_reduction, table1_memory, MemoryRow,
};
