//! Crate-wide error type.
//!
//! Wraps the failure domains the coordinator crosses: PJRT/XLA runtime
//! errors, manifest/config parsing, I/O, and internal invariant
//! violations. `eyre` is used at the binary edge; the library keeps a
//! concrete enum so callers can match on failure classes.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    /// XLA / PJRT runtime failure (compile, execute, literal conversion).
    Xla(xla::Error),
    /// I/O failure (artifact files, blobs, checkpoints).
    Io(std::io::Error),
    /// Manifest / config deserialization failure.
    Parse(String),
    /// Shape or layout mismatch between manifest and runtime buffers.
    Layout(String),
    /// Invalid configuration (bad method name, impossible schedule…).
    Config(String),
    /// Training diverged or hit an invariant violation.
    Training(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Layout(m) => write!(f, "layout error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Training(m) => write!(f, "training error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}
