//! # RevFFN — memory-efficient full-parameter fine-tuning of MoE LLMs
//!
//! Rust coordinator (L3) for the three-layer RevFFN stack:
//!
//! * **L1** Pallas kernels and **L2** JAX model live under `python/compile`
//!   and are AOT-lowered to HLO text by `make artifacts`. Python never runs
//!   at training time.
//! * **L3** (this crate) owns the training loop: configuration, data
//!   pipeline, two-stage schedule (§3.3 of the paper), optimizer-step
//!   execution through the PJRT C API, VRAM accounting, evaluation, and
//!   checkpointing.
//!
//! ## Driving API (the `engine` module)
//!
//! Methods are typed ([`engine::Method`]), model loading goes through
//! one facade ([`engine::Session`]), and training is step-granular
//! ([`engine::Run`] yields [`engine::StepEvent`]s). Quick start (after
//! `make artifacts`):
//!
//! ```no_run
//! use revffn::config::RunConfig;
//! use revffn::coordinator::Trainer;
//! use revffn::engine::{Method, StepEvent};
//! use revffn::runtime::Device;
//!
//! let mut cfg = RunConfig::default_tiny("artifacts/tiny");
//! cfg.method = Method::Revffn;
//! let device = Device::cpu().unwrap();
//! let mut trainer = Trainer::new(&device, cfg).unwrap();
//!
//! // drive the two-stage schedule one event at a time
//! let mut run = trainer.start().unwrap();
//! while let Some(event) = run.step().unwrap() {
//!     match event {
//!         StepEvent::Step(rec) => println!("step {} loss {:.4}", rec.step, rec.loss),
//!         StepEvent::EvalPoint { step, eval_loss } => {
//!             println!("eval @ {step}: {eval_loss:.4}")
//!         }
//!         _ => {}
//!     }
//! }
//! let report = run.finish().unwrap();
//! println!("final loss {:.4}", report.final_loss);
//! ```
//!
//! (`trainer.run()` remains as the blocking wrapper over the same loop.)
//!
//! Many runs can share one device: `revffn serve` ([`serve`]) drives N
//! owned runs round-robin with peak-VRAM admission control and streams
//! their events over a JSON-lines TCP control plane — see
//! `docs/SERVE.md`.
//!
//! Inference and evaluation load through the session facade:
//!
//! ```no_run
//! use revffn::engine::{Method, Session};
//!
//! let session = Session::builder("artifacts/tiny")
//!     .method(Method::Revffn)
//!     .build()
//!     .unwrap();
//! let scores = session.bench_scores(32, 7).unwrap();
//! println!("mmlu-like {:.1}%", scores.mmlu_like);
//! ```

pub mod analysis;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod eval;
pub mod memory;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod util;

pub use engine::{Method, Run, Session, SessionBuilder, StepEvent};
pub use error::{Error, Result};
