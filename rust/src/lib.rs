//! # RevFFN — memory-efficient full-parameter fine-tuning of MoE LLMs
//!
//! Rust coordinator (L3) for the three-layer RevFFN stack:
//!
//! * **L1** Pallas kernels and **L2** JAX model live under `python/compile`
//!   and are AOT-lowered to HLO text by `make artifacts`. Python never runs
//!   at training time.
//! * **L3** (this crate) owns the training loop: configuration, data
//!   pipeline, two-stage schedule (§3.3 of the paper), optimizer-step
//!   execution through the PJRT C API, VRAM accounting, evaluation, and
//!   checkpointing.
//!
//! Quick start (after `make artifacts`):
//!
//! ```no_run
//! use revffn::runtime::{Device, Artifact};
//! use revffn::coordinator::Trainer;
//! use revffn::config::RunConfig;
//!
//! let cfg = RunConfig::default_tiny("artifacts/tiny");
//! let device = Device::cpu().unwrap();
//! let mut trainer = Trainer::new(&device, cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("final loss {:.4}", report.final_loss);
//! ```

pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod memory;
pub mod runtime;
pub mod util;

pub use error::{Error, Result};
