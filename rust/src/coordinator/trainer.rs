//! The training coordinator: corpus → tokenizer → optional LM pre-pass →
//! two-stage fine-tuning with LR scheduling, gradient-accumulation,
//! periodic validation, metrics and checkpointing.
//!
//! This is the paper's launcher. It owns no math: every optimizer step
//! is one PJRT execution of the AOT train_step artifact for the active
//! (method, stage) variant.

use std::path::PathBuf;

use crate::checkpoint;
use crate::config::RunConfig;
use crate::coordinator::lr::lr_at;
use crate::coordinator::metrics::{Metrics, StepRecord};
use crate::coordinator::schedule::{plan, Phase};
use crate::data::dataset::{encode_corpus, encode_lm_text};
use crate::data::synthetic::{Corpus, CorpusConfig};
use crate::data::tokenizer::Tokenizer;
use crate::data::Batcher;
use crate::error::{Error, Result};
use crate::runtime::artifact::Artifact;
use crate::runtime::pjrt::{Device, ProgramCache};
use crate::runtime::stepper::Stepper;

/// Outcome summary of a full run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub method: String,
    pub steps_run: u64,
    pub final_loss: f32,
    pub first_loss: f32,
    pub eval_loss: Option<f32>,
    pub median_samples_per_s: f64,
    pub wall_time_s: f64,
}

pub struct Trainer<'d> {
    device: &'d Device,
    cache: ProgramCache,
    pub cfg: RunConfig,
    pub tokenizer: Tokenizer,
    pub corpus: Corpus,
    pub metrics: Metrics,
    /// The live model after `run` (for the eval suite).
    pub stepper: Option<Stepper>,
}

impl<'d> Trainer<'d> {
    /// Prepare data (generate corpus, train tokenizer, no XLA work yet).
    pub fn new(device: &'d Device, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        let corpus = Corpus::generate(CorpusConfig {
            seed: cfg.data.seed,
            n_train: cfg.data.n_train,
            n_eval: cfg.data.n_eval,
            n_places: cfg.data.n_places,
            ..Default::default()
        });
        // vocab size comes from the artifact geometry
        let probe_stage = if cfg.method == "revffn" && cfg.schedule.stage2_steps == 0 {
            1
        } else {
            2
        };
        let probe = Artifact::load(cfg.variant_dir(probe_stage))?;
        let vocab = probe.manifest.model.vocab_size;
        let tokenizer = Tokenizer::train(&corpus.pretrain_text(), vocab)?;
        Ok(Trainer {
            device,
            cache: ProgramCache::new(),
            cfg,
            tokenizer,
            corpus,
            metrics: Metrics::new(),
            stepper: None,
        })
    }

    fn load_stepper(&self, stage: u8) -> Result<Stepper> {
        let artifact = Artifact::load(self.cfg.variant_dir(stage))?;
        Stepper::new(self.device, &self.cache, artifact)
    }

    /// LM pre-pass on the standard model — the "pre-trained checkpoint"
    /// substitute. Returns the pre-passed parameter store.
    fn pretrain(&mut self) -> Result<Option<Stepper>> {
        if self.cfg.data.pretrain_steps == 0 {
            return Ok(None);
        }
        let sft_dir = self.cfg.artifacts.join("sft");
        if !sft_dir.join("manifest.json").exists() {
            return Ok(None); // artifact set without sft (pallas-only dirs)
        }
        let artifact = Artifact::load(&sft_dir)?;
        let mut stepper = Stepper::new(self.device, &self.cache, artifact)?;
        let (b, s) = stepper.batch_shape();
        let samples = encode_lm_text(&self.tokenizer, &self.corpus.pretrain_text(), s);
        let mut batcher = Batcher::new(samples, b, s, self.cfg.seed ^ 0xface);
        for step in 0..self.cfg.data.pretrain_steps {
            let batch = batcher.next_batch();
            let stats = stepper.train_step(&batch, self.cfg.data.pretrain_lr)?;
            if step % 20 == 0 {
                eprintln!("[pretrain] step {step} loss {:.4}", stats.loss);
            }
        }
        Ok(Some(stepper))
    }

    /// Execute the full schedule. Returns the report; the trained model
    /// stays available in `self.stepper`.
    pub fn run(&mut self) -> Result<TrainReport> {
        let phases = plan(&self.cfg);
        if phases.is_empty() {
            return Err(Error::Config("empty schedule".into()));
        }

        let pre = self.pretrain()?;

        let mut pre = pre;
        let mut current: Option<Stepper> = None;
        let mut eval_loss = None;
        for phase in &phases {
            let mut stepper = self.load_stepper(phase.stage)?;
            // parameter handoff: stage N adopts stage N-1 (or the pre-pass)
            if let Some(prev) = current.as_mut() {
                let params = prev.materialize_params()?;
                stepper.adopt_params(params)?;
            } else if let Some(pre) = pre.as_mut() {
                let params = pre.materialize_params()?;
                let copied = stepper.adopt_params(params)?;
                eprintln!("[handoff] adopted {copied} pre-passed tensors");
            }
            eval_loss = Some(self.run_phase(&mut stepper, phase)?);
            current = Some(stepper);
        }

        let mut stepper = current.expect("at least one phase ran");
        stepper.materialize_params()?;
        let (first, last) = self.metrics.loss_delta().unwrap_or((0.0, 0.0));
        let report = TrainReport {
            method: self.cfg.method.clone(),
            steps_run: self.metrics.steps.len() as u64,
            final_loss: last,
            first_loss: first,
            eval_loss,
            median_samples_per_s: self.metrics.median_throughput().unwrap_or(0.0),
            wall_time_s: self.metrics.wall_time_s(),
        };

        std::fs::create_dir_all(&self.cfg.out_dir)?;
        self.metrics
            .write_jsonl(self.cfg.out_dir.join("metrics.jsonl"))?;
        if self.cfg.save_checkpoint {
            checkpoint::save(
                &self.cfg.out_dir.join("final.rvt"),
                &stepper.params,
                stepper.step,
            )?;
        }
        self.stepper = Some(stepper);
        Ok(report)
    }

    fn run_phase(&mut self, stepper: &mut Stepper, phase: &Phase) -> Result<f32> {
        let (b, s) = stepper.batch_shape();
        let train_samples = encode_corpus(&self.tokenizer, &self.corpus.train, s);
        let eval_samples = encode_corpus(&self.tokenizer, &self.corpus.eval, s);
        if train_samples.is_empty() {
            return Err(Error::Config(format!("no training samples fit seq_len {s}")));
        }
        let mut batcher = Batcher::new(train_samples, b, s, self.cfg.seed);
        let eval_batcher = Batcher::new(eval_samples, b, s, self.cfg.seed);

        eprintln!(
            "[{}] {} steps, peak lr {:.2e}, batch {}x{}",
            phase.label, phase.steps, phase.peak_lr, b, s
        );
        let accumulate = self.cfg.grad_accum > 1 && stepper.supports_accumulation();
        for step in 0..phase.steps {
            let lr = lr_at(&self.cfg.schedule, phase.peak_lr, step, phase.steps);
            let mut loss_acc = 0.0;
            let mut gn_acc = 0.0;
            let mut aux_acc = 0.0;
            let t0 = std::time::Instant::now();
            if accumulate {
                // true microbatch accumulation: grad-only passes summed
                // host-side, then ONE optimizer update on the mean grad
                let mut grads: Option<Vec<Vec<f32>>> = None;
                for _ in 0..self.cfg.grad_accum {
                    let batch = batcher.next_batch();
                    let (g, loss, aux) = stepper.grad_step(&batch)?;
                    loss_acc += loss;
                    aux_acc += aux;
                    match grads.as_mut() {
                        None => grads = Some(g),
                        Some(acc) => {
                            for (a, gi) in acc.iter_mut().zip(&g) {
                                for (x, y) in a.iter_mut().zip(gi) {
                                    *x += *y;
                                }
                            }
                        }
                    }
                }
                let mut grads = grads.expect("grad_accum >= 1");
                let scale = 1.0 / self.cfg.grad_accum as f32;
                for g in grads.iter_mut() {
                    for x in g.iter_mut() {
                        *x *= scale;
                    }
                }
                gn_acc = stepper.apply_accumulated(&grads, lr)? * self.cfg.grad_accum as f32;
            } else {
                for _ in 0..self.cfg.grad_accum {
                    let batch = batcher.next_batch();
                    let stats = stepper.train_step(&batch, lr)?;
                    loss_acc += stats.loss;
                    gn_acc += stats.grad_norm;
                    aux_acc += stats.router_aux;
                }
            }
            let time_acc = t0.elapsed().as_secs_f64();
            let ga = self.cfg.grad_accum as f32;
            let samples = (b * self.cfg.grad_accum) as f64;
            self.metrics.record_step(StepRecord {
                step: stepper.step,
                stage: phase.stage,
                loss: loss_acc / ga,
                lr,
                grad_norm: gn_acc / ga,
                router_aux: aux_acc / ga,
                step_time_s: time_acc,
                samples_per_s: samples / time_acc.max(1e-9),
            });
            if step % 25 == 0 {
                eprintln!(
                    "[{}] step {}/{} loss {:.4} lr {:.2e}",
                    phase.label,
                    step,
                    phase.steps,
                    loss_acc / ga,
                    lr
                );
            }
            if self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0 {
                let el = self.validate(stepper, &eval_batcher)?;
                self.metrics.record_eval(stepper.step, el);
                eprintln!("[{}] step {} eval_loss {:.4}", phase.label, step, el);
            }
        }
        let el = self.validate(stepper, &eval_batcher)?;
        self.metrics.record_eval(stepper.step, el);
        Ok(el)
    }

    fn validate(&self, stepper: &Stepper, eval_batcher: &Batcher) -> Result<f32> {
        let batches = eval_batcher.sequential_batches();
        if batches.is_empty() {
            return Ok(f32::NAN);
        }
        let mut total = 0.0;
        let n = batches.len().min(8); // cap validation cost
        for batch in batches.iter().take(n) {
            let (loss, _aux) = stepper.eval_step(batch)?;
            total += loss;
        }
        Ok(total / n as f32)
    }

    /// Path of the metrics file for this run.
    pub fn metrics_path(&self) -> PathBuf {
        self.cfg.out_dir.join("metrics.jsonl")
    }
}
