//! The training coordinator: corpus → tokenizer → schedule (optional LM
//! pre-pass phase + fine-tuning stages) with LR scheduling,
//! gradient-accumulation, periodic validation, metrics and checkpointing.
//!
//! This is the paper's launcher. It owns no math: every optimizer step
//! is one PJRT execution of the AOT train_step artifact for the active
//! (method, stage) variant. Since the engine API redesign the stepping
//! itself lives in [`crate::engine::Run`]; [`Trainer::run`] is a thin
//! compatibility loop over [`Trainer::start`] that adds stderr progress
//! logging. External callers that want to interleave, pause, or observe
//! runs should drive [`crate::engine::Run::step`] directly; the serve
//! scheduler ([`crate::serve`]) multiplexes many owned runs
//! ([`Trainer::into_run`]) over one shared device this way.

use std::path::PathBuf;

use crate::config::RunConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::schedule::plan;
use crate::data::synthetic::Corpus;
use crate::data::tokenizer::Tokenizer;
use crate::data::Batcher;
use crate::engine::run::{Run, StepEvent};
use crate::engine::session::corpus_and_tokenizer;
use crate::engine::Method;
use crate::error::Result;
use crate::eval::{BenchScores, EvalSuite};
use crate::runtime::artifact::Artifact;
use crate::runtime::pjrt::{Device, ProgramCache};
use crate::runtime::stepper::Stepper;

/// Outcome summary of a full run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub method: Method,
    /// Every recorded optimizer step, including the LM pre-pass phase.
    pub steps_run: u64,
    pub final_loss: f32,
    /// First fine-tuning loss (the pre-pass measures a different
    /// objective and is excluded — see `Metrics::loss_delta`).
    pub first_loss: f32,
    pub eval_loss: Option<f32>,
    pub median_samples_per_s: f64,
    pub wall_time_s: f64,
}

pub struct Trainer {
    /// Shared device handle (cheap clone — Arc'd PJRT client).
    pub(crate) device: Device,
    pub(crate) cache: ProgramCache,
    pub cfg: RunConfig,
    pub tokenizer: Tokenizer,
    pub corpus: Corpus,
    pub metrics: Metrics,
    /// The live model after `run` (for the eval suite).
    pub stepper: Option<Stepper>,
}

impl Trainer {
    /// Prepare data (generate corpus, train tokenizer, no XLA work yet).
    pub fn new(device: &Device, cfg: RunConfig) -> Result<Self> {
        Self::with_cache(device, ProgramCache::new(), cfg)
    }

    /// Like [`Trainer::new`], but sharing a compiled-program cache with
    /// other trainers on the same device — the serve scheduler compiles
    /// each artifact variant once across all concurrent jobs.
    pub fn with_cache(device: &Device, cache: ProgramCache, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        // vocab size comes from the artifact geometry: probe the variant
        // of the schedule's final phase
        let probe_stage = plan(&cfg).last().map(|p| p.stage).unwrap_or(2);
        let probe = Artifact::load(cfg.variant_dir(probe_stage))?;
        let vocab = probe.manifest.model.vocab_size;
        let (corpus, tokenizer) = corpus_and_tokenizer(cfg.data.corpus_config(), vocab)?;
        Ok(Trainer {
            device: device.clone(),
            cache,
            cfg,
            tokenizer,
            corpus,
            metrics: Metrics::new(),
            stepper: None,
        })
    }

    pub(crate) fn load_stepper(&self, stage: u8) -> Result<Stepper> {
        let artifact = Artifact::load(self.cfg.variant_dir(stage))?;
        Stepper::new(&self.device, &self.cache, artifact)
    }

    /// Variant directory of the LM pre-pass model (always `sft`), if
    /// the artifact set ships one (pallas-only dirs do not — the
    /// pre-pass phase is skipped then).
    pub(crate) fn prepass_dir(&self) -> Option<PathBuf> {
        let dir = self.cfg.artifacts.join(Method::Sft.eval_variant());
        dir.join("manifest.json").exists().then_some(dir)
    }

    pub(crate) fn load_prepass_stepper(&self) -> Result<Stepper> {
        let dir = self.prepass_dir().ok_or_else(|| {
            crate::error::Error::Config("artifact set has no sft variant for the pre-pass".into())
        })?;
        let artifact = Artifact::load(dir)?;
        Stepper::new(&self.device, &self.cache, artifact)
    }

    /// Begin a step-granular run over the planned schedule (including
    /// the LM pre-pass phase, which streams its events too). Drive it
    /// with [`Run::step`], then call [`Run::finish`] for the report.
    pub fn start(&mut self) -> Result<Run<&mut Trainer>> {
        Run::new(self)
    }

    /// Consume the trainer into an owned run — the form a scheduler
    /// holds N of to multiplex concurrent jobs over one device.
    pub fn into_run(self) -> Result<Run<Trainer>> {
        Run::new(self)
    }

    /// Execute the full schedule (compatibility wrapper: a thin loop
    /// over [`Trainer::start`] that logs progress to stderr). Returns
    /// the report; the trained model stays available in `self.stepper`.
    pub fn run(&mut self) -> Result<TrainReport> {
        let run = self.start()?;
        Self::drive(run)
    }

    /// Like [`Trainer::run`], but resuming from a full-state RVT2
    /// checkpoint (see [`crate::checkpoint`]): params, Adam moments,
    /// step counters and the data cursor are restored before the first
    /// step, so the continuation is bit-identical to the uninterrupted
    /// run. The report covers the resumed portion only.
    pub fn run_resumed(&mut self, ckpt: crate::checkpoint::Checkpoint) -> Result<TrainReport> {
        let mut run = self.start()?;
        run.restore(ckpt)?;
        Self::drive(run)
    }

    /// The stderr-logging drive loop shared by [`Trainer::run`] and
    /// [`Trainer::run_resumed`].
    fn drive<T: std::borrow::BorrowMut<Trainer>>(mut run: Run<T>) -> Result<TrainReport> {
        let mut label = "";
        let mut phase_steps = 0u64;
        let mut local_step = 0u64;
        while let Some(event) = run.step()? {
            match event {
                StepEvent::PhaseStarted {
                    label: l, steps, peak_lr, batch_size, seq_len, ..
                } => {
                    label = l;
                    phase_steps = steps;
                    local_step = 0;
                    eprintln!(
                        "[{label}] {steps} steps, peak lr {peak_lr:.2e}, batch {batch_size}x{seq_len}"
                    );
                }
                StepEvent::Step(rec) => {
                    if local_step % 25 == 0 {
                        eprintln!(
                            "[{label}] step {local_step}/{phase_steps} loss {:.4} lr {:.2e}",
                            rec.loss, rec.lr
                        );
                    }
                    local_step += 1;
                }
                StepEvent::EvalPoint { eval_loss, .. } => {
                    eprintln!(
                        "[{label}] step {} eval_loss {eval_loss:.4}",
                        local_step.saturating_sub(1)
                    );
                }
                StepEvent::PhaseFinished { .. } => {}
            }
        }
        run.finish()
    }

    /// Validation pass over up to `cfg.eval_batches` sequential eval
    /// batches (0 = all). Batches stream from the batcher's lazy
    /// iterator, so capped evaluation never assembles the skipped tail.
    pub(crate) fn validate(&self, stepper: &Stepper, eval_batcher: &Batcher) -> Result<f32> {
        let total_batches = eval_batcher.n_sequential_batches();
        if total_batches == 0 {
            return Ok(f32::NAN);
        }
        let cap =
            if self.cfg.eval_batches == 0 { total_batches } else { self.cfg.eval_batches };
        let n = total_batches.min(cap);
        if n < total_batches {
            eprintln!(
                "[eval] scoring {n}/{total_batches} eval batches ({} skipped; raise eval_batches to cover all)",
                total_batches - n
            );
        }
        let mut total = 0.0;
        for batch in eval_batcher.sequential_batches().take(n) {
            let (loss, _aux) = stepper.eval_step(&batch)?;
            total += loss;
        }
        Ok(total / n as f32)
    }

    /// Score the trained model on the synthetic Table-2 benchmark suite.
    /// Requires a completed run (the stepper it produced).
    pub fn bench_scores(&self, n_questions: usize, seed: u64) -> Result<BenchScores> {
        let stepper = self.stepper.as_ref().ok_or_else(|| {
            crate::error::Error::Config("bench_scores requires a completed run".into())
        })?;
        EvalSuite::new(self.corpus.world.clone(), n_questions, seed).run(
            stepper,
            &self.tokenizer,
            &self.corpus.eval,
        )
    }

    /// Path of the metrics file for this run.
    pub fn metrics_path(&self) -> PathBuf {
        self.cfg.out_dir.join("metrics.jsonl")
    }
}
