//! The training coordinator: corpus → tokenizer → optional LM pre-pass →
//! two-stage fine-tuning with LR scheduling, gradient-accumulation,
//! periodic validation, metrics and checkpointing.
//!
//! This is the paper's launcher. It owns no math: every optimizer step
//! is one PJRT execution of the AOT train_step artifact for the active
//! (method, stage) variant. Since the engine API redesign the stepping
//! itself lives in [`crate::engine::Run`]; [`Trainer::run`] is a thin
//! compatibility loop over [`Trainer::start`] that adds stderr progress
//! logging. External callers that want to interleave, pause, or observe
//! runs should drive [`crate::engine::Run::step`] directly.

use std::path::PathBuf;

use crate::config::RunConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::schedule::plan;
use crate::data::dataset::encode_lm_text;
use crate::data::synthetic::Corpus;
use crate::data::tokenizer::Tokenizer;
use crate::data::{Batcher, Pipeline};
use crate::engine::run::{Run, StepEvent};
use crate::engine::session::corpus_and_tokenizer;
use crate::engine::Method;
use crate::error::Result;
use crate::eval::{BenchScores, EvalSuite};
use crate::runtime::artifact::Artifact;
use crate::runtime::pjrt::{Device, ProgramCache};
use crate::runtime::stepper::Stepper;

/// Outcome summary of a full run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub method: Method,
    pub steps_run: u64,
    pub final_loss: f32,
    pub first_loss: f32,
    pub eval_loss: Option<f32>,
    pub median_samples_per_s: f64,
    pub wall_time_s: f64,
}

pub struct Trainer<'d> {
    pub(crate) device: &'d Device,
    pub(crate) cache: ProgramCache,
    pub cfg: RunConfig,
    pub tokenizer: Tokenizer,
    pub corpus: Corpus,
    pub metrics: Metrics,
    /// The live model after `run` (for the eval suite).
    pub stepper: Option<Stepper>,
}

impl<'d> Trainer<'d> {
    /// Prepare data (generate corpus, train tokenizer, no XLA work yet).
    pub fn new(device: &'d Device, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        // vocab size comes from the artifact geometry: probe the variant
        // of the schedule's final phase
        let probe_stage = plan(&cfg).last().map(|p| p.stage).unwrap_or(2);
        let probe = Artifact::load(cfg.variant_dir(probe_stage))?;
        let vocab = probe.manifest.model.vocab_size;
        let (corpus, tokenizer) = corpus_and_tokenizer(cfg.data.corpus_config(), vocab)?;
        Ok(Trainer {
            device,
            cache: ProgramCache::new(),
            cfg,
            tokenizer,
            corpus,
            metrics: Metrics::new(),
            stepper: None,
        })
    }

    pub(crate) fn load_stepper(&self, stage: u8) -> Result<Stepper> {
        let artifact = Artifact::load(self.cfg.variant_dir(stage))?;
        Stepper::new(self.device, &self.cache, artifact)
    }

    /// LM pre-pass on the standard model — the "pre-trained checkpoint"
    /// substitute. Returns the pre-passed parameter store.
    pub(crate) fn pretrain(&mut self) -> Result<Option<Stepper>> {
        if self.cfg.data.pretrain_steps == 0 {
            return Ok(None);
        }
        let sft_dir = self.cfg.artifacts.join(Method::Sft.eval_variant());
        if !sft_dir.join("manifest.json").exists() {
            return Ok(None); // artifact set without sft (pallas-only dirs)
        }
        let artifact = Artifact::load(&sft_dir)?;
        let mut stepper = Stepper::new(self.device, &self.cache, artifact)?;
        if self.cfg.device_resident {
            if let Err(e) = stepper.enable_device_state() {
                eprintln!("[device] pre-pass buffer path unavailable ({e}); using literals");
            }
        }
        let (b, s) = stepper.batch_shape();
        let samples = encode_lm_text(&self.tokenizer, &self.corpus.pretrain_text(), s);
        // the pre-pass streams through the same prefetch pipeline as
        // training phases, so its batch assembly overlaps execution too
        let mut pipeline = Pipeline::spawn(Batcher::new(samples, b, s, self.cfg.seed ^ 0xface));
        for step in 0..self.cfg.data.pretrain_steps {
            let batch = pipeline.next_batch()?;
            let stats = stepper.train_step(&batch, self.cfg.data.pretrain_lr)?;
            pipeline.recycle(batch);
            if step % 20 == 0 {
                eprintln!("[pretrain] step {step} loss {:.4}", stats.loss);
            }
        }
        // the pre-pass stepper only serves as a parameter source from
        // here on (open_phase adoption); release its pinned device
        // buffers now instead of holding a full extra state copy
        // device-side for the rest of the run
        stepper.disable_device_state()?;
        Ok(Some(stepper))
    }

    /// Begin a step-granular run over the planned schedule (runs the LM
    /// pre-pass eagerly). Drive it with [`Run::step`], then call
    /// [`Run::finish`] for the report.
    pub fn start(&mut self) -> Result<Run<'_, 'd>> {
        Run::new(self)
    }

    /// Execute the full schedule (compatibility wrapper: a thin loop
    /// over [`Trainer::start`] that logs progress to stderr). Returns
    /// the report; the trained model stays available in `self.stepper`.
    pub fn run(&mut self) -> Result<TrainReport> {
        let mut run = self.start()?;
        let mut label = "";
        let mut phase_steps = 0u64;
        let mut local_step = 0u64;
        while let Some(event) = run.step()? {
            match event {
                StepEvent::PhaseStarted {
                    label: l, steps, peak_lr, batch_size, seq_len, ..
                } => {
                    label = l;
                    phase_steps = steps;
                    local_step = 0;
                    eprintln!(
                        "[{label}] {steps} steps, peak lr {peak_lr:.2e}, batch {batch_size}x{seq_len}"
                    );
                }
                StepEvent::Step(rec) => {
                    if local_step % 25 == 0 {
                        eprintln!(
                            "[{label}] step {local_step}/{phase_steps} loss {:.4} lr {:.2e}",
                            rec.loss, rec.lr
                        );
                    }
                    local_step += 1;
                }
                StepEvent::EvalPoint { eval_loss, .. } => {
                    eprintln!(
                        "[{label}] step {} eval_loss {eval_loss:.4}",
                        local_step.saturating_sub(1)
                    );
                }
                StepEvent::PhaseFinished { .. } => {}
            }
        }
        run.finish()
    }

    /// Validation pass over up to `cfg.eval_batches` sequential eval
    /// batches (0 = all). Batches stream from the batcher's lazy
    /// iterator, so capped evaluation never assembles the skipped tail.
    pub(crate) fn validate(&self, stepper: &Stepper, eval_batcher: &Batcher) -> Result<f32> {
        let total_batches = eval_batcher.n_sequential_batches();
        if total_batches == 0 {
            return Ok(f32::NAN);
        }
        let cap =
            if self.cfg.eval_batches == 0 { total_batches } else { self.cfg.eval_batches };
        let n = total_batches.min(cap);
        if n < total_batches {
            eprintln!(
                "[eval] scoring {n}/{total_batches} eval batches ({} skipped; raise eval_batches to cover all)",
                total_batches - n
            );
        }
        let mut total = 0.0;
        for batch in eval_batcher.sequential_batches().take(n) {
            let (loss, _aux) = stepper.eval_step(&batch)?;
            total += loss;
        }
        Ok(total / n as f32)
    }

    /// Score the trained model on the synthetic Table-2 benchmark suite.
    /// Requires a completed run (the stepper it produced).
    pub fn bench_scores(&self, n_questions: usize, seed: u64) -> Result<BenchScores> {
        let stepper = self.stepper.as_ref().ok_or_else(|| {
            crate::error::Error::Config("bench_scores requires a completed run".into())
        })?;
        EvalSuite::new(self.corpus.world.clone(), n_questions, seed).run(
            stepper,
            &self.tokenizer,
            &self.corpus.eval,
        )
    }

    /// Path of the metrics file for this run.
    pub fn metrics_path(&self) -> PathBuf {
        self.cfg.out_dir.join("metrics.jsonl")
    }
}
