//! Training metrics: per-step records, throughput accounting, and a
//! JSON-lines sink for offline analysis (loss curves in EXPERIMENTS.md).

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use crate::error::Result;
use crate::util::json::ObjBuilder;

#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    pub stage: u8,
    pub loss: f32,
    pub lr: f32,
    pub grad_norm: f32,
    pub router_aux: f32,
    /// Wall-clock of the whole logged step (microbatches + update +
    /// batch waits).
    pub step_time_s: f64,
    /// PJRT execute time within the step — `step_time_s` minus this is
    /// coordinator overhead (batch assembly, literal staging), which the
    /// accumulate and fused paths must keep comparable.
    pub device_time_s: f64,
    pub samples_per_s: f64,
}

#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: u64,
    pub eval_loss: f32,
}

/// Collects step/eval records and computes run-level summaries.
pub struct Metrics {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { steps: Vec::new(), evals: Vec::new(), started: Instant::now() }
    }

    pub fn record_step(&mut self, rec: StepRecord) {
        self.steps.push(rec);
    }

    pub fn record_eval(&mut self, step: u64, eval_loss: f32) {
        self.evals.push(EvalRecord { step, eval_loss });
    }

    pub fn wall_time_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Mean of the last `n` entries (the shared smoothing kernel).
    fn tail_mean(losses: &[f32], n: usize) -> Option<f32> {
        if losses.is_empty() {
            return None;
        }
        let tail = &losses[losses.len().saturating_sub(n)..];
        Some(tail.iter().sum::<f32>() / tail.len() as f32)
    }

    /// Mean loss over the last `n` steps (smoothed final loss, all
    /// stages).
    pub fn smoothed_loss(&self, n: usize) -> Option<f32> {
        let losses: Vec<f32> = self.steps.iter().map(|r| r.loss).collect();
        Self::tail_mean(&losses, n)
    }

    /// Median samples/s over the fine-tuning steps (Table-1
    /// throughput). LM pre-pass records (stage 0) are excluded — they
    /// run a different artifact — unless the run was pre-pass only.
    pub fn median_throughput(&self) -> Option<f64> {
        let mut v: Vec<f64> = self
            .steps
            .iter()
            .filter(|r| r.stage != 0)
            .map(|r| r.samples_per_s)
            .collect();
        if v.is_empty() {
            v = self.steps.iter().map(|r| r.samples_per_s).collect();
        }
        if v.is_empty() {
            return None;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(v[v.len() / 2])
    }

    /// First/last loss — the "did it learn" check. Both ends are
    /// computed over the *fine-tuning* steps only (the LM pre-pass
    /// streams through the metrics as stage 0 but measures a different
    /// objective, so it must contaminate neither the first loss nor
    /// the smoothed tail of a short run); a pre-pass-only run falls
    /// back to all records.
    pub fn loss_delta(&self) -> Option<(f32, f32)> {
        let mut losses: Vec<f32> =
            self.steps.iter().filter(|r| r.stage != 0).map(|r| r.loss).collect();
        if losses.is_empty() {
            losses = self.steps.iter().map(|r| r.loss).collect();
        }
        let first = *losses.first()?;
        let last = Self::tail_mean(&losses, 10)?;
        Some((first, last))
    }

    /// Write JSON-lines: one object per step + per eval.
    pub fn write_jsonl(&self, path: impl AsRef<Path>) -> Result<()> {
        self.write_jsonl_lines(path.as_ref(), &[])
    }

    /// Like [`Metrics::write_jsonl`], but first preserves records
    /// already in the file that this collection does not supersede. A
    /// resumed run holds only post-resume records in memory and must
    /// not erase the history its predecessor wrote; steps replayed
    /// since the snapshot DO supersede their stale file versions. Step
    /// records are keyed by `(stage, step)`, eval records by `step`
    /// (an eval line carries no `stage`).
    pub fn write_jsonl_merged(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let step_keys: std::collections::HashSet<(u64, u64)> =
            self.steps.iter().map(|s| (s.stage as u64, s.step)).collect();
        let eval_keys: std::collections::HashSet<u64> =
            self.evals.iter().map(|e| e.step).collect();
        let mut kept: Vec<String> = Vec::new();
        if let Ok(text) = std::fs::read_to_string(path) {
            for line in text.lines() {
                let Ok(j) = crate::util::json::parse(line) else {
                    continue; // drop an unparsable (e.g. torn) line
                };
                let Ok(step) = j.u64_of("step") else { continue };
                let superseded = match j.get("stage").and_then(crate::util::json::Json::as_u64) {
                    Some(stage) => step_keys.contains(&(stage, step)),
                    None => eval_keys.contains(&step),
                };
                if !superseded {
                    kept.push(line.to_string());
                }
            }
        }
        self.write_jsonl_lines(path, &kept)
    }

    fn write_jsonl_lines(&self, path: &Path, prefix: &[String]) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        for line in prefix {
            writeln!(f, "{line}")?;
        }
        for s in &self.steps {
            let j = ObjBuilder::new()
                .num("step", s.step as f64)
                .num("stage", s.stage as f64)
                .num("loss", s.loss as f64)
                .num("lr", s.lr as f64)
                .num("grad_norm", s.grad_norm as f64)
                .num("router_aux", s.router_aux as f64)
                .num("step_time_s", s.step_time_s)
                .num("device_time_s", s.device_time_s)
                .num("samples_per_s", s.samples_per_s)
                .build();
            writeln!(f, "{j}")?;
        }
        for e in &self.evals {
            let j = ObjBuilder::new()
                .num("step", e.step as f64)
                .num("eval_loss", e.eval_loss as f64)
                .build();
            writeln!(f, "{j}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, loss: f32, sps: f64) -> StepRecord {
        StepRecord {
            step,
            stage: 2,
            loss,
            lr: 1e-4,
            grad_norm: 1.0,
            router_aux: 0.0,
            step_time_s: 0.1,
            device_time_s: 0.08,
            samples_per_s: sps,
        }
    }

    #[test]
    fn smoothed_loss_tail() {
        let mut m = Metrics::new();
        for i in 0..20 {
            m.record_step(rec(i, 10.0 - i as f32 * 0.1, 8.0));
        }
        let s = m.smoothed_loss(5).unwrap();
        assert!(s < 8.6 && s > 8.0);
    }

    #[test]
    fn median_throughput_robust_to_outliers() {
        let mut m = Metrics::new();
        m.record_step(rec(0, 1.0, 100.0)); // first-step compile outlier
        for i in 1..10 {
            m.record_step(rec(i, 1.0, 10.0));
        }
        assert_eq!(m.median_throughput().unwrap(), 10.0);
    }

    #[test]
    fn prepass_records_excluded_from_summaries() {
        let mut m = Metrics::new();
        // stage-0 pre-pass: high LM loss, different throughput
        for i in 0..5 {
            let mut r = rec(i, 9.0, 50.0);
            r.stage = 0;
            m.record_step(r);
        }
        for i in 5..25 {
            m.record_step(rec(i, 4.0 - (i - 5) as f32 * 0.1, 10.0));
        }
        let (first, last) = m.loss_delta().unwrap();
        assert_eq!(first, 4.0, "first loss must be the first fine-tune step");
        assert!(last < first);
        assert_eq!(m.median_throughput().unwrap(), 10.0);
    }

    #[test]
    fn short_run_final_loss_excludes_prepass_tail() {
        // fewer than 10 fine-tune steps after a long pre-pass: the
        // smoothed final loss must not average in stage-0 records
        let mut m = Metrics::new();
        for i in 0..60 {
            let mut r = rec(i, 9.0, 50.0);
            r.stage = 0;
            m.record_step(r);
        }
        for i in 60..63 {
            m.record_step(rec(i, 2.0, 10.0));
        }
        let (first, last) = m.loss_delta().unwrap();
        assert_eq!(first, 2.0);
        assert_eq!(last, 2.0, "final loss must be pure fine-tune: got {last}");
    }

    #[test]
    fn prepass_only_run_still_summarizes() {
        let mut m = Metrics::new();
        for i in 0..4 {
            let mut r = rec(i, 8.0 - i as f32, 5.0);
            r.stage = 0;
            m.record_step(r);
        }
        assert_eq!(m.loss_delta().unwrap().0, 8.0);
        assert_eq!(m.median_throughput().unwrap(), 5.0);
    }

    #[test]
    fn merged_write_preserves_predecessor_history() {
        let dir = crate::util::ScratchDir::new("metrics-merge").unwrap();
        let p = dir.join("metrics.jsonl");
        // the predecessor run wrote steps 0..4 and an eval at 2
        let mut before = Metrics::new();
        for i in 0..4 {
            before.record_step(rec(i, 5.0, 1.0));
        }
        before.record_eval(2, 4.5);
        before.write_jsonl(&p).unwrap();
        // the resumed run replays from the snapshot at step 2: its
        // memory holds steps 2..6 (fresher) and an eval at 4
        let mut after = Metrics::new();
        for i in 2..6 {
            after.record_step(rec(i, 3.0, 2.0));
        }
        after.record_eval(4, 2.5);
        after.write_jsonl_merged(&p).unwrap();

        let text = std::fs::read_to_string(&p).unwrap();
        let parsed: Vec<crate::util::json::Json> =
            text.lines().map(|l| crate::util::json::parse(l).unwrap()).collect();
        let steps: Vec<(u64, f64)> = parsed
            .iter()
            .filter(|j| j.get("stage").is_some())
            .map(|j| (j.u64_of("step").unwrap(), j.f64_of("loss").unwrap()))
            .collect();
        // pre-snapshot history survives; replayed steps are deduped to
        // their fresh versions
        assert_eq!(
            steps,
            vec![(0, 5.0), (1, 5.0), (2, 3.0), (3, 3.0), (4, 3.0), (5, 3.0)]
        );
        let evals: Vec<(u64, f64)> = parsed
            .iter()
            .filter(|j| j.get("eval_loss").is_some())
            .map(|j| (j.u64_of("step").unwrap(), j.f64_of("eval_loss").unwrap()))
            .collect();
        assert_eq!(evals, vec![(2, 4.5), (4, 2.5)]);
    }

    #[test]
    fn merged_write_without_existing_file_equals_plain_write() {
        let dir = crate::util::ScratchDir::new("metrics-merge-fresh").unwrap();
        let p = dir.join("metrics.jsonl");
        let mut m = Metrics::new();
        m.record_step(rec(0, 5.0, 1.0));
        m.write_jsonl_merged(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap().lines().count(), 1);
    }

    #[test]
    fn jsonl_written() {
        let dir = crate::util::ScratchDir::new("metrics").unwrap();
        let mut m = Metrics::new();
        m.record_step(rec(0, 5.0, 1.0));
        m.record_eval(0, 4.5);
        let p = dir.join("metrics.jsonl");
        m.write_jsonl(&p).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text.lines().count(), 2);
    }
}
