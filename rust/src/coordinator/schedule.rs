//! Two-stage training schedule (§3.3).
//!
//! Stage 1 ("adapter warm-up"): only the projection adapters P↑/P↓ and
//! the stream norms train, at a small LR — realised by executing the
//! `revffn_stage1` artifact, whose train_step computes gradients for the
//! adapter subset only. Stage 2 ("joint fine-tuning"): everything except
//! the MoE routers trains (`revffn_stage2`). Non-RevFFN methods run a
//! single stage.
//!
//! The ablations of Table 3 are schedule edits: `w/o Stage 1` sets
//! stage1_steps = 0; `w/o Stage 2` sets stage2_steps = 0 and extends
//! stage 1.

use crate::config::RunConfig;

/// One executable phase of a run.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    /// 1 or 2 — selects the artifact variant for RevFFN.
    pub stage: u8,
    pub steps: u64,
    pub peak_lr: f32,
    pub label: &'static str,
}

/// Expand a run config into its ordered phases.
pub fn plan(cfg: &RunConfig) -> Vec<Phase> {
    let s = &cfg.schedule;
    if !cfg.method.is_two_stage() {
        return vec![Phase {
            stage: 2,
            steps: s.stage2_steps,
            peak_lr: s.lr,
            label: "finetune",
        }];
    }
    let mut phases = Vec::new();
    if s.stage1_steps > 0 {
        phases.push(Phase {
            stage: 1,
            steps: s.stage1_steps,
            peak_lr: s.stage1_lr,
            label: "stage1-adapter-warmup",
        });
    }
    if s.stage2_steps > 0 {
        phases.push(Phase {
            stage: 2,
            steps: s.stage2_steps,
            peak_lr: s.lr,
            label: "stage2-joint-finetune",
        });
    }
    phases
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn revffn_has_two_phases() {
        let cfg = RunConfig::default_tiny("a");
        let p = plan(&cfg);
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].stage, 1);
        assert_eq!(p[1].stage, 2);
        assert!(p[0].peak_lr < p[1].peak_lr, "stage-1 LR must be small (§3.3)");
    }

    #[test]
    fn ablation_without_stage1() {
        let mut cfg = RunConfig::default_tiny("a");
        cfg.schedule.stage1_steps = 0;
        let p = plan(&cfg);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].stage, 2);
    }

    #[test]
    fn ablation_without_stage2() {
        let mut cfg = RunConfig::default_tiny("a");
        cfg.schedule.stage2_steps = 0;
        let p = plan(&cfg);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].stage, 1);
    }

    #[test]
    fn baselines_are_single_phase() {
        let mut cfg = RunConfig::default_tiny("a");
        cfg.method = crate::engine::Method::Lora;
        let p = plan(&cfg);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].label, "finetune");
    }
}
