//! Training schedule: optional LM pre-pass, then the two-stage plan (§3.3).
//!
//! The LM pre-pass (`cfg.data.pretrain_steps`) stands in for "start from
//! a pre-trained checkpoint": it runs next-token prediction on the `sft`
//! artifact and its parameters are adopted by the first fine-tuning
//! stage. Since the serve redesign it is a planned phase like any other,
//! so `Run::step()` streams its events and a scheduler can preempt
//! mid-pre-pass.
//!
//! Stage 1 ("adapter warm-up"): only the projection adapters P↑/P↓ and
//! the stream norms train, at a small LR — realised by executing the
//! `revffn_stage1` artifact, whose train_step computes gradients for the
//! adapter subset only. Stage 2 ("joint fine-tuning"): everything except
//! the MoE routers trains (`revffn_stage2`). Non-RevFFN methods run a
//! single stage.
//!
//! The ablations of Table 3 are schedule edits: `w/o Stage 1` sets
//! stage1_steps = 0; `w/o Stage 2` sets stage2_steps = 0 and extends
//! stage 1.

use crate::config::RunConfig;

/// What a phase executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseKind {
    /// LM pre-pass on the standard (`sft`) model — the "pre-trained
    /// checkpoint" substitute. Records metrics as stage 0, runs no
    /// validation, and always uses `grad_accum = 1` at a flat LR.
    LmPrepass,
    /// A fine-tuning stage of the configured method.
    Train,
}

/// One executable phase of a run.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub kind: PhaseKind,
    /// 1 or 2 — selects the artifact variant for RevFFN. 0 for the LM
    /// pre-pass (which always executes the `sft` variant).
    pub stage: u8,
    pub steps: u64,
    pub peak_lr: f32,
    pub label: &'static str,
}

/// Expand a run config into its ordered phases.
pub fn plan(cfg: &RunConfig) -> Vec<Phase> {
    let mut phases = Vec::new();
    if cfg.data.pretrain_steps > 0 {
        phases.push(Phase {
            kind: PhaseKind::LmPrepass,
            stage: 0,
            steps: cfg.data.pretrain_steps,
            peak_lr: cfg.data.pretrain_lr,
            label: "lm-prepass",
        });
    }
    let s = &cfg.schedule;
    if !cfg.method.is_two_stage() {
        phases.push(Phase {
            kind: PhaseKind::Train,
            stage: 2,
            steps: s.stage2_steps,
            peak_lr: s.lr,
            label: "finetune",
        });
        return phases;
    }
    if s.stage1_steps > 0 {
        phases.push(Phase {
            kind: PhaseKind::Train,
            stage: 1,
            steps: s.stage1_steps,
            peak_lr: s.stage1_lr,
            label: "stage1-adapter-warmup",
        });
    }
    if s.stage2_steps > 0 {
        phases.push(Phase {
            kind: PhaseKind::Train,
            stage: 2,
            steps: s.stage2_steps,
            peak_lr: s.lr,
            label: "stage2-joint-finetune",
        });
    }
    phases
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    /// Default tiny config with the pre-pass disabled (the historical
    /// two-phase shape most tests assume).
    fn cfg_no_prepass() -> RunConfig {
        let mut cfg = RunConfig::default_tiny("a");
        cfg.data.pretrain_steps = 0;
        cfg
    }

    #[test]
    fn revffn_has_two_phases() {
        let p = plan(&cfg_no_prepass());
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].stage, 1);
        assert_eq!(p[1].stage, 2);
        assert!(p.iter().all(|ph| ph.kind == PhaseKind::Train));
        assert!(p[0].peak_lr < p[1].peak_lr, "stage-1 LR must be small (§3.3)");
    }

    #[test]
    fn prepass_is_a_planned_phase() {
        let mut cfg = cfg_no_prepass();
        cfg.data.pretrain_steps = 40;
        let p = plan(&cfg);
        assert_eq!(p.len(), 3);
        assert_eq!(p[0].kind, PhaseKind::LmPrepass);
        assert_eq!(p[0].stage, 0);
        assert_eq!(p[0].steps, 40);
        assert_eq!(p[0].peak_lr, cfg.data.pretrain_lr);
        assert_eq!(p[1].stage, 1);
        assert_eq!(p[2].stage, 2);
    }

    #[test]
    fn prepass_precedes_single_stage_methods_too() {
        let mut cfg = cfg_no_prepass();
        cfg.method = crate::engine::Method::Sft;
        cfg.data.pretrain_steps = 10;
        let p = plan(&cfg);
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].kind, PhaseKind::LmPrepass);
        assert_eq!(p[1].label, "finetune");
    }

    #[test]
    fn ablation_without_stage1() {
        let mut cfg = cfg_no_prepass();
        cfg.schedule.stage1_steps = 0;
        let p = plan(&cfg);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].stage, 2);
    }

    #[test]
    fn ablation_without_stage2() {
        let mut cfg = cfg_no_prepass();
        cfg.schedule.stage2_steps = 0;
        let p = plan(&cfg);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].stage, 1);
    }

    #[test]
    fn baselines_are_single_phase() {
        let mut cfg = cfg_no_prepass();
        cfg.method = crate::engine::Method::Lora;
        let p = plan(&cfg);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].label, "finetune");
    }
}
