//! Learning-rate schedules (computed host-side; the HLO step takes lr as
//! a scalar input, so schedules never require re-lowering).

use crate::config::{LrSchedule, ScheduleConfig};

/// LR at optimizer step `step` (0-based) of a phase `total` steps long.
pub fn lr_at(sched: &ScheduleConfig, peak: f32, step: u64, total: u64) -> f32 {
    let total = total.max(1);
    let warm = sched.warmup_steps.min(total.saturating_sub(1));
    if step < warm {
        return peak * (step + 1) as f32 / warm.max(1) as f32;
    }
    let min_lr = peak * sched.min_lr_factor;
    let progress = (step - warm) as f32 / (total - warm).max(1) as f32;
    let progress = progress.clamp(0.0, 1.0);
    match sched.lr_schedule {
        LrSchedule::Constant => peak,
        LrSchedule::WarmupCosine => {
            min_lr + 0.5 * (peak - min_lr) * (1.0 + (std::f32::consts::PI * progress).cos())
        }
        LrSchedule::WarmupLinear => peak - (peak - min_lr) * progress,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScheduleConfig;

    fn sched(kind: LrSchedule) -> ScheduleConfig {
        ScheduleConfig { lr_schedule: kind, warmup_steps: 10, min_lr_factor: 0.1,
                         ..Default::default() }
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = sched(LrSchedule::WarmupCosine);
        let l1 = lr_at(&s, 1.0, 0, 100);
        let l5 = lr_at(&s, 1.0, 4, 100);
        let l10 = lr_at(&s, 1.0, 9, 100);
        assert!(l1 < l5 && l5 < l10);
        assert!((l10 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = sched(LrSchedule::WarmupCosine);
        let end = lr_at(&s, 1.0, 99, 100);
        assert!((end - 0.1).abs() < 0.02, "end lr {end}");
    }

    #[test]
    fn linear_decays_to_min() {
        let s = sched(LrSchedule::WarmupLinear);
        let end = lr_at(&s, 2.0, 99, 100);
        assert!((end - 0.2).abs() < 0.05, "end lr {end}");
    }

    #[test]
    fn constant_stays_flat() {
        let s = sched(LrSchedule::Constant);
        assert_eq!(lr_at(&s, 0.5, 50, 100), 0.5);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = sched(LrSchedule::WarmupCosine);
        let mut prev = f32::MAX;
        for step in 10..100 {
            let l = lr_at(&s, 1.0, step, 100);
            assert!(l <= prev + 1e-6);
            prev = l;
        }
    }
}
