//! L3 coordination: the trainer (launch → pre-pass → two-stage schedule →
//! metrics/checkpoints), LR schedules, and metrics sinks.
//!
//! Since the engine API redesign, step execution lives in
//! [`crate::engine::Run`]: `Trainer::start()` returns a `Run` whose
//! `step()` yields `StepEvent`s one unit of work at a time, and
//! `Trainer::run()` is the blocking compatibility loop over it. Method
//! selection is typed ([`crate::engine::Method`]) and model loading for
//! eval/generate goes through [`crate::engine::Session`].

pub mod lr;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use metrics::{Metrics, StepRecord};
pub use schedule::{plan, Phase};
pub use trainer::{TrainReport, Trainer};
