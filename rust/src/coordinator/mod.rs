//! L3 coordination: the trainer (launch → pre-pass → two-stage schedule →
//! metrics/checkpoints), LR schedules, and metrics sinks.

pub mod lr;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use metrics::{Metrics, StepRecord};
pub use schedule::{plan, Phase};
pub use trainer::{TrainReport, Trainer};
