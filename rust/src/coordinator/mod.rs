//! L3 coordination: the trainer (schedule planning → LM pre-pass phase →
//! fine-tuning stages → metrics/checkpoints), LR schedules, and metrics
//! sinks.
//!
//! Since the engine API redesign, step execution lives in
//! [`crate::engine::Run`]: `Trainer::start()` returns a `Run` whose
//! `step()` yields `StepEvent`s one unit of work at a time (the LM
//! pre-pass is a planned [`Phase`] and streams its events too), and
//! `Trainer::run()` is the blocking compatibility loop over it. Method
//! selection is typed ([`crate::engine::Method`]) and model loading for
//! eval/generate goes through [`crate::engine::Session`].

pub mod lr;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use metrics::{Metrics, StepRecord};
pub use schedule::{plan, Phase, PhaseKind};
pub use trainer::{TrainReport, Trainer};
