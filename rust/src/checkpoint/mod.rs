//! `.rvt` checkpoint format — self-describing binary parameter snapshots.
//!
//! Layout (little-endian):
//! ```text
//! magic  "RVT1"            4 bytes
//! step   u64               8 bytes
//! count  u32               4 bytes
//! repeat count times:
//!   name_len u32, name utf-8 bytes
//!   ndim u32, dims u32 * ndim
//!   data f32 * prod(dims)
//! ```
//! Tensors are name-tagged (not positional) so checkpoints survive
//! manifest reorderings and can be loaded into a different variant of
//! the same model (e.g. stage-1 → stage-2 handoff across processes).

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::literal::{cast_f32_le, extend_f32_le};
use crate::runtime::stepper::Stepper;
use crate::runtime::store::ParamStore;

const MAGIC: &[u8; 4] = b"RVT1";

/// Write every tensor of `params` to `path`. Streams straight out of the
/// store's borrowed snapshot — no tensor is cloned — and converts each
/// tensor to bytes in one reused buffer (one `write_all` per tensor
/// instead of one per element).
pub fn save(path: impl AsRef<Path>, params: &ParamStore, step: u64) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&step.to_le_bytes())?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    let mut buf: Vec<u8> = Vec::new();
    for (name, shape, data) in params.snapshot() {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u32).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&(shape.len() as u32).to_le_bytes())?;
        for d in shape {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        buf.clear();
        extend_f32_le(data, &mut buf);
        f.write_all(&buf)?;
    }
    Ok(())
}

/// Snapshot a live stepper to `path`, materializing its host mirror
/// first. On the device-resident path this is where the lazy download
/// chain fires — `DeviceState::to_literals()` → `ParamStore` — so a
/// checkpoint is the one deliberate full-state host transfer of a
/// buffer-resident run.
pub fn save_stepper(path: impl AsRef<Path>, stepper: &mut Stepper) -> Result<()> {
    let step = stepper.step;
    let params = stepper.materialize_params()?;
    save(path, params, step)
}

/// A loaded checkpoint: (step, name → (shape, data)).
pub struct Checkpoint {
    pub step: u64,
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Parse("not an RVT1 checkpoint".into()));
    }
    let mut b8 = [0u8; 8];
    f.read_exact(&mut b8)?;
    let step = u64::from_le_bytes(b8);
    let mut b4 = [0u8; 4];
    f.read_exact(&mut b4)?;
    let count = u32::from_le_bytes(b4) as usize;
    let mut tensors = Vec::with_capacity(count);
    let mut buf: Vec<u8> = Vec::new(); // reused byte buffer across tensors
    for _ in 0..count {
        f.read_exact(&mut b4)?;
        let nlen = u32::from_le_bytes(b4) as usize;
        let mut nb = vec![0u8; nlen];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).map_err(|e| Error::Parse(e.to_string()))?;
        f.read_exact(&mut b4)?;
        let ndim = u32::from_le_bytes(b4) as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            f.read_exact(&mut b4)?;
            shape.push(u32::from_le_bytes(b4) as usize);
        }
        let n: usize = shape.iter().product::<usize>().max(1);
        let mut data = vec![0f32; n];
        buf.resize(n * 4, 0);
        f.read_exact(&mut buf)?;
        cast_f32_le(&buf, &mut data)?;
        tensors.push((name, shape, data));
    }
    Ok(Checkpoint { step, tensors })
}

/// Restore matching tensors into `params`; returns how many matched.
pub fn restore_into(ckpt: &Checkpoint, params: &mut ParamStore) -> Result<usize> {
    let mut n = 0;
    for (name, _shape, data) in &ckpt.tensors {
        if params.tensor(name).is_some() {
            params.set_tensor(name, data.clone())?;
            n += 1;
        }
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::TensorSpec;

    fn store() -> ParamStore {
        let specs = vec![
            TensorSpec {
                name: "embed".into(),
                shape: vec![4, 2],
                dtype: "f32".into(),
                blob: "x".into(),
                offset: 0,
                nbytes: 32,
            },
            TensorSpec {
                name: "norm_f".into(),
                shape: vec![2],
                dtype: "f32".into(),
                blob: "x".into(),
                offset: 32,
                nbytes: 8,
            },
        ];
        let host = vec![vec![1.0; 8], vec![0.5; 2]];
        ParamStore::from_host(specs, host).unwrap()
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::util::ScratchDir::new("ckpt").unwrap();
        let p = dir.join("ck.rvt");
        let s = store();
        save(&p, &s, 42).unwrap();
        let ck = load(&p).unwrap();
        assert_eq!(ck.step, 42);
        assert_eq!(ck.tensors.len(), 2);
        assert_eq!(ck.tensors[0].0, "embed");
        assert_eq!(ck.tensors[0].2, vec![1.0; 8]);
    }

    #[test]
    fn restore_matches_by_name() {
        let dir = crate::util::ScratchDir::new("ckpt").unwrap();
        let p = dir.join("ck.rvt");
        let mut s = store();
        s.set_tensor("norm_f", vec![9.0, 9.0]).unwrap();
        save(&p, &s, 1).unwrap();
        let mut fresh = store();
        let ck = load(&p).unwrap();
        let n = restore_into(&ck, &mut fresh).unwrap();
        assert_eq!(n, 2);
        assert_eq!(fresh.tensor("norm_f").unwrap(), &[9.0, 9.0]);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = crate::util::ScratchDir::new("ckpt2").unwrap();
        let p = dir.join("junk.rvt");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(load(&p).is_err());
    }
}
