//! `.rvt` checkpoint format — self-describing binary training snapshots.
//!
//! Two generations, one reader:
//!
//! **RVT1** (legacy, still readable): parameters only.
//! ```text
//! magic  "RVT1"            4 bytes
//! step   u64               8 bytes
//! count  u32               4 bytes
//! repeat count times:
//!   name_len u32, name utf-8 bytes
//!   ndim u32, dims u32 * ndim
//!   data f32 * prod(dims)
//! ```
//!
//! **RVT2** (current): the RVT1 body followed by the full training
//! state, so a resumed run continues *bit-identically* — Adam moments,
//! the optimizer step counter, and the data-pipeline cursor all come
//! back, not just the weights.
//! ```text
//! magic  "RVT2"
//! <RVT1 body: step, count, named tensors>
//! opt_flag u8 (1 = Adam moments follow)
//!   n_opt u32
//!   m tensors: (ndim u32, dims u32 * ndim, data f32 * prod) * n_opt
//!   v tensors: same layout, same count
//! cursor_flag u8 (1 = run cursor follows)
//!   phase_idx u64, step_in_phase u64, batches_taken u64,
//!   batch_seed u64, seq u64, steps_total u64
//! ```
//! Moments are positional (manifest `opt_shapes` order); parameters are
//! name-tagged so checkpoints survive manifest reorderings and can be
//! loaded into a different variant of the same model.
//!
//! The reader is hardened against corrupt or truncated files: every
//! allocation is bounded by the bytes actually remaining in the file,
//! and any structural violation surfaces as [`Error::Parse`] — a bad
//! header can never trigger a multi-GB allocation.
//!
//! Periodic mid-run snapshots (`cfg.checkpoint_every`) are written
//! atomically (write `.tmp`, then rename) under
//! `out_dir/ckpt-p<phase>-s<step>.rvt`; [`latest_checkpoint`] finds the
//! newest and [`prune_checkpoints`] enforces `cfg.keep_last`.

use std::io::{Read, Seek, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::runtime::literal::{cast_f32_le, extend_f32_le};
use crate::runtime::stepper::Stepper;
use crate::runtime::store::ParamStore;
use crate::util::faults::{self, FaultKind, FaultSite};

const MAGIC_V1: &[u8; 4] = b"RVT1";
const MAGIC_V2: &[u8; 4] = b"RVT2";

/// Adam moment state of a checkpoint (manifest `opt_shapes` order,
/// positional — moments have no names).
#[derive(Debug, Clone, PartialEq)]
pub struct OptMoments {
    pub m: Vec<(Vec<usize>, Vec<f32>)>,
    pub v: Vec<(Vec<usize>, Vec<f32>)>,
}

/// Where a run stood when the snapshot was taken — everything
/// [`crate::engine::Run::restore`] needs to fast-forward to the exact
/// step and replay the data pipeline from the right batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunCursor {
    /// Index into the planned phases.
    pub phase_idx: u64,
    /// Optimizer steps completed inside that phase.
    pub step_in_phase: u64,
    /// Batches the run consumed from the phase's `Batcher` (the resumed
    /// batcher skips this many to land on the next unseen batch).
    pub batches_taken: u64,
    /// Seed the phase's batcher was created with (validated on resume —
    /// a mismatch means the config changed and replay would diverge).
    pub batch_seed: u64,
    /// Events the run had yielded (serve event-stream continuity).
    pub seq: u64,
    /// Optimizer steps completed across all phases (checkpoint cadence).
    pub steps_total: u64,
}

/// A loaded checkpoint: params always; moments + cursor when the file
/// is RVT2 and the writer included them.
pub struct Checkpoint {
    pub step: u64,
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
    pub opt: Option<OptMoments>,
    pub cursor: Option<RunCursor>,
}

// ---------------------------------------------------------------- write

fn write_tensor_body(
    f: &mut impl Write,
    shape: &[usize],
    data: &[f32],
    buf: &mut Vec<u8>,
) -> Result<()> {
    f.write_all(&(shape.len() as u32).to_le_bytes())?;
    for d in shape {
        f.write_all(&(*d as u32).to_le_bytes())?;
    }
    buf.clear();
    extend_f32_le(data, buf);
    f.write_all(buf)?;
    Ok(())
}

fn write_params(f: &mut impl Write, params: &ParamStore, step: u64) -> Result<()> {
    f.write_all(&step.to_le_bytes())?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    let mut buf: Vec<u8> = Vec::new();
    for (name, shape, data) in params.snapshot() {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u32).to_le_bytes())?;
        f.write_all(nb)?;
        write_tensor_body(f, shape, data, &mut buf)?;
    }
    Ok(())
}

/// Write a params-only RVT1 checkpoint (legacy format; kept so the
/// compatibility path stays exercised and tools that only care about
/// weights can write the smaller file).
pub fn save(path: impl AsRef<Path>, params: &ParamStore, step: u64) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC_V1)?;
    write_params(&mut f, params, step)
}

/// Write a full-state RVT2 checkpoint atomically: the bytes land in
/// `<path>.tmp` first and only a complete, flushed and fsynced file is
/// renamed into place — a process crash mid-write can never leave a
/// torn `.rvt` behind, and the data is durable before the rename so a
/// power loss shortly after cannot journal the rename without the
/// bytes. (Resume additionally falls back to the next-newest snapshot
/// if the newest fails to parse — see [`latest_valid_checkpoint`].)
pub fn save_state(
    path: impl AsRef<Path>,
    params: &ParamStore,
    step: u64,
    opt: Option<&OptMoments>,
    cursor: Option<&RunCursor>,
) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("rvt.tmp");
    // Injected checkpoint faults (docs/ROBUSTNESS.md): `error` fails the
    // write up front, `torn` truncates the payload and skips the fsync
    // but still renames — fabricating exactly the crash the validating
    // reader and `latest_valid_checkpoint` exist to catch.
    let mut torn = false;
    match faults::hit(FaultSite::CkptWrite) {
        None => {}
        Some(FaultKind::Torn) => torn = true,
        Some(FaultKind::Delay(ms)) => {
            crate::util::retry::pause(std::time::Duration::from_millis(ms))
        }
        Some(FaultKind::Error) => {
            return Err(Error::Training("injected fault: ckpt_write".into()))
        }
    }
    {
        let file = std::fs::File::create(&tmp)?;
        let mut f = std::io::BufWriter::new(file);
        f.write_all(MAGIC_V2)?;
        write_params(&mut f, params, step)?;
        match opt {
            Some(o) => {
                f.write_all(&[1u8])?;
                f.write_all(&(o.m.len() as u32).to_le_bytes())?;
                let mut buf: Vec<u8> = Vec::new();
                for (shape, data) in o.m.iter().chain(o.v.iter()) {
                    write_tensor_body(&mut f, shape, data, &mut buf)?;
                }
            }
            None => f.write_all(&[0u8])?,
        }
        match cursor {
            Some(c) => {
                f.write_all(&[1u8])?;
                for word in [
                    c.phase_idx,
                    c.step_in_phase,
                    c.batches_taken,
                    c.batch_seed,
                    c.seq,
                    c.steps_total,
                ] {
                    f.write_all(&word.to_le_bytes())?;
                }
            }
            None => f.write_all(&[0u8])?,
        }
        f.flush()?;
        if !torn {
            faults::failpoint(FaultSite::CkptFsync)?;
            f.get_ref().sync_all()?;
        }
    }
    if torn {
        let len = std::fs::metadata(&tmp)?.len();
        let keep = ((len as f64) * faults::torn_fraction()) as u64;
        let f = std::fs::OpenOptions::new().write(true).open(&tmp)?;
        f.set_len(keep.min(len.saturating_sub(1)))?;
    }
    faults::failpoint(FaultSite::CkptRename)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Snapshot a live stepper to `path` as RVT2 (params + moments + step;
/// pass a cursor to make the file resumable by [`crate::engine::Run`]).
/// On the device-resident path this is where the lazy download chain
/// fires — `DeviceState::to_literals()` → host vectors — so a
/// checkpoint is the one deliberate full-state host transfer of a
/// buffer-resident run.
pub fn save_stepper_state(
    path: impl AsRef<Path>,
    stepper: &mut Stepper,
    cursor: Option<&RunCursor>,
) -> Result<()> {
    let step = stepper.step;
    let shapes = stepper.opt_shapes().to_vec();
    let (m, v) = stepper.opt_snapshot()?;
    let opt = OptMoments {
        m: shapes.iter().cloned().zip(m).collect(),
        v: shapes.into_iter().zip(v).collect(),
    };
    let params = stepper.materialize_params()?;
    save_state(path, params, step, Some(&opt), cursor)
}

/// [`save_stepper_state`] without a run cursor (end-of-run `final.rvt`:
/// full state for inspection/eval, but the schedule is complete so
/// there is nothing to resume).
pub fn save_stepper(path: impl AsRef<Path>, stepper: &mut Stepper) -> Result<()> {
    save_stepper_state(path, stepper, None)
}

// ----------------------------------------------------------------- read

/// Budgeted reader: tracks how many bytes can still legally be read so
/// no header field can request an allocation beyond the file's actual
/// size. Every shortfall is an [`Error::Parse`], never an abort or an
/// oversized `vec!`.
struct Reader<R: Read> {
    r: R,
    remaining: u64,
}

impl<R: Read> Reader<R> {
    fn claim(&mut self, n: u64, what: &str) -> Result<()> {
        if n > self.remaining {
            return Err(Error::Parse(format!(
                "truncated checkpoint: {what} wants {n} bytes, {} remain",
                self.remaining
            )));
        }
        Ok(())
    }

    fn fill(&mut self, buf: &mut [u8], what: &str) -> Result<()> {
        self.claim(buf.len() as u64, what)?;
        self.r
            .read_exact(buf)
            .map_err(|e| Error::Parse(format!("truncated checkpoint reading {what}: {e}")))?;
        self.remaining -= buf.len() as u64;
        Ok(())
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<Vec<u8>> {
        // claim BEFORE allocating: a corrupt length field must error,
        // not reserve gigabytes
        self.claim(n as u64, what)?;
        let mut buf = vec![0u8; n];
        self.fill(&mut buf, what)?;
        Ok(buf)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        let mut b = [0u8; 1];
        self.fill(&mut b, what)?;
        Ok(b[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let mut b = [0u8; 4];
        self.fill(&mut b, what)?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let mut b = [0u8; 8];
        self.fill(&mut b, what)?;
        Ok(u64::from_le_bytes(b))
    }

    /// Shape + payload byte count of a tensor body, with every
    /// dimension count and the element product bounded by the
    /// remaining file size.
    fn tensor_shape(&mut self, what: &str) -> Result<(Vec<usize>, u64)> {
        let ndim = self.u32(what)? as usize;
        self.claim(4 * ndim as u64, what)?;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u32(what)? as usize);
        }
        let mut n: usize = 1;
        for &d in &shape {
            n = n.checked_mul(d).ok_or_else(|| {
                Error::Parse(format!("corrupt checkpoint: {what} shape {shape:?} overflows"))
            })?;
        }
        let n = n.max(1);
        let nbytes = (n as u64).checked_mul(4).ok_or_else(|| {
            Error::Parse(format!("corrupt checkpoint: {what} byte size overflows"))
        })?;
        self.claim(nbytes, what)?;
        Ok((shape, nbytes))
    }

    /// `(shape, data)` — the payload-materializing read.
    fn tensor_body(&mut self, what: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let (shape, nbytes) = self.tensor_shape(what)?;
        let raw = self.bytes(nbytes as usize, what)?;
        let mut data = vec![0f32; (nbytes / 4) as usize];
        cast_f32_le(&raw, &mut data)?;
        Ok((shape, data))
    }

    fn cursor_body(&mut self) -> Result<RunCursor> {
        Ok(RunCursor {
            phase_idx: self.u64("cursor.phase_idx")?,
            step_in_phase: self.u64("cursor.step_in_phase")?,
            batches_taken: self.u64("cursor.batches_taken")?,
            batch_seed: self.u64("cursor.batch_seed")?,
            seq: self.u64("cursor.seq")?,
            steps_total: self.u64("cursor.steps_total")?,
        })
    }
}

impl<R: Read + Seek> Reader<R> {
    fn skip(&mut self, n: u64, what: &str) -> Result<()> {
        self.claim(n, what)?;
        self.r
            .seek(std::io::SeekFrom::Current(n as i64))
            .map_err(|e| Error::Parse(format!("truncated checkpoint skipping {what}: {e}")))?;
        self.remaining -= n;
        Ok(())
    }

    fn skip_tensor_body(&mut self, what: &str) -> Result<()> {
        let (_shape, nbytes) = self.tensor_shape(what)?;
        self.skip(nbytes, what)
    }
}

fn open_reader(path: &Path) -> Result<(Reader<std::io::BufReader<std::fs::File>>, bool)> {
    let file = std::fs::File::open(path)?;
    let len = file.metadata()?.len();
    let mut r = Reader { r: std::io::BufReader::new(file), remaining: len };
    let mut magic = [0u8; 4];
    r.fill(&mut magic, "magic")?;
    let v2 = match &magic {
        m if m == MAGIC_V1 => false,
        m if m == MAGIC_V2 => true,
        _ => return Err(Error::Parse("not an RVT1/RVT2 checkpoint".into())),
    };
    Ok((r, v2))
}

fn load_impl(path: &Path, want_opt: bool) -> Result<Checkpoint> {
    let (mut r, v2) = open_reader(path)?;
    let step = r.u64("step")?;
    let count = r.u32("tensor count")? as usize;
    // each tensor costs at least name_len(4) + ndim(4) + data(4) bytes
    r.claim(12 * count as u64, "tensor table")?;
    let mut tensors = Vec::with_capacity(count);
    for i in 0..count {
        let what = format!("tensor {i}");
        let nlen = r.u32(&what)? as usize;
        let nb = r.bytes(nlen, &what)?;
        let name = String::from_utf8(nb)
            .map_err(|e| Error::Parse(format!("corrupt checkpoint: tensor {i} name: {e}")))?;
        let (shape, data) = r.tensor_body(&name)?;
        tensors.push((name, shape, data));
    }
    if !v2 {
        return Ok(Checkpoint { step, tensors, opt: None, cursor: None });
    }
    let opt = if r.u8("opt flag")? != 0 {
        let n_opt = r.u32("opt count")? as usize;
        r.claim(2 * 8 * n_opt as u64, "opt table")?;
        if want_opt {
            let mut read_set = |tag: &str| -> Result<Vec<(Vec<usize>, Vec<f32>)>> {
                (0..n_opt).map(|i| r.tensor_body(&format!("{tag} moment {i}"))).collect()
            };
            let m = read_set("m")?;
            let v = read_set("v")?;
            Some(OptMoments { m, v })
        } else {
            // params-only consumers seek past the moment payloads —
            // for a full-parameter method they are ~2x the weights
            for i in 0..2 * n_opt {
                r.skip_tensor_body(&format!("moment {i}"))?;
            }
            None
        }
    } else {
        None
    };
    let cursor = if r.u8("cursor flag")? != 0 { Some(r.cursor_body()?) } else { None };
    Ok(Checkpoint { step, tensors, opt, cursor })
}

/// Load a checkpoint in full (params + moments + cursor).
pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
    load_impl(path.as_ref(), true)
}

/// Load the parameters (and cursor) only, seeking past the Adam moment
/// payloads instead of materializing them — the `Session`/eval path
/// restores weights and discards moments, so reading them would cost
/// ~3x the I/O and a transient 2x-model-size allocation for nothing.
/// `opt` is always `None` in the result.
pub fn load_params(path: impl AsRef<Path>) -> Result<Checkpoint> {
    load_impl(path.as_ref(), false)
}

/// Parse only the trailing [`RunCursor`] of a checkpoint, seeking over
/// every tensor payload instead of materializing it — the serve submit
/// path reads this to continue event numbering without paying for a
/// full snapshot load. `Ok(None)` for RVT1 files or RVT2 files written
/// without a cursor.
pub fn load_cursor(path: impl AsRef<Path>) -> Result<Option<RunCursor>> {
    let (mut r, v2) = open_reader(path.as_ref())?;
    if !v2 {
        return Ok(None);
    }
    let _step = r.u64("step")?;
    let count = r.u32("tensor count")? as usize;
    r.claim(12 * count as u64, "tensor table")?;
    for i in 0..count {
        let what = format!("tensor {i}");
        let nlen = r.u32(&what)? as u64;
        r.skip(nlen, &what)?;
        r.skip_tensor_body(&what)?;
    }
    if r.u8("opt flag")? != 0 {
        let n_opt = r.u32("opt count")? as usize;
        r.claim(2 * 8 * n_opt as u64, "opt table")?;
        for i in 0..2 * n_opt {
            r.skip_tensor_body(&format!("moment {i}"))?;
        }
    }
    if r.u8("cursor flag")? == 0 {
        return Ok(None);
    }
    Ok(Some(r.cursor_body()?))
}

/// Structural summary of a checkpoint: tensor names + shapes and moment
/// shapes, with every payload seeked over instead of materialized.
/// `revffn check` cross-checks this against a manifest (the same
/// comparison [`restore_into`] / `restore_opt` make at load time)
/// without RAM proportional to the weights.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointSummary {
    pub step: u64,
    pub tensors: Vec<(String, Vec<usize>)>,
    /// `(m shapes, v shapes)` when the file carries Adam moments.
    pub opt_shapes: Option<(Vec<Vec<usize>>, Vec<Vec<usize>>)>,
    pub cursor: Option<RunCursor>,
}

/// Walk a checkpoint's structure (names, shapes, flags) with every
/// payload skipped. Same hardened bounded reader as [`load`]: corrupt
/// or truncated files surface as [`Error::Parse`], never as an
/// oversized allocation.
pub fn summarize(path: impl AsRef<Path>) -> Result<CheckpointSummary> {
    let (mut r, v2) = open_reader(path.as_ref())?;
    let step = r.u64("step")?;
    let count = r.u32("tensor count")? as usize;
    r.claim(12 * count as u64, "tensor table")?;
    let mut tensors = Vec::with_capacity(count);
    for i in 0..count {
        let what = format!("tensor {i}");
        let nlen = r.u32(&what)? as usize;
        let nb = r.bytes(nlen, &what)?;
        let name = String::from_utf8(nb)
            .map_err(|e| Error::Parse(format!("corrupt checkpoint: tensor {i} name: {e}")))?;
        let (shape, nbytes) = r.tensor_shape(&name)?;
        r.skip(nbytes, &name)?;
        tensors.push((name, shape));
    }
    if !v2 {
        return Ok(CheckpointSummary { step, tensors, opt_shapes: None, cursor: None });
    }
    let opt_shapes = if r.u8("opt flag")? != 0 {
        let n_opt = r.u32("opt count")? as usize;
        r.claim(2 * 8 * n_opt as u64, "opt table")?;
        let mut sets = [Vec::with_capacity(n_opt), Vec::with_capacity(n_opt)];
        for (which, set) in sets.iter_mut().enumerate() {
            let tag = if which == 0 { "m" } else { "v" };
            for i in 0..n_opt {
                let what = format!("{tag} moment {i}");
                let (shape, nbytes) = r.tensor_shape(&what)?;
                r.skip(nbytes, &what)?;
                set.push(shape);
            }
        }
        let [m, v] = sets;
        Some((m, v))
    } else {
        None
    };
    let cursor = if r.u8("cursor flag")? != 0 { Some(r.cursor_body()?) } else { None };
    Ok(CheckpointSummary { step, tensors, opt_shapes, cursor })
}

// -------------------------------------------------------------- restore

/// Restore matching tensors into `params`; returns how many matched.
/// A same-name tensor whose stored shape differs from the store's is an
/// [`Error::Layout`] — restoring by flat element count alone would
/// silently corrupt the run.
pub fn restore_into(ckpt: &Checkpoint, params: &mut ParamStore) -> Result<usize> {
    let mut n = 0;
    for (name, shape, data) in &ckpt.tensors {
        let Some(spec) = params.spec(name) else {
            continue;
        };
        if &spec.shape != shape {
            return Err(Error::Layout(format!(
                "checkpoint tensor {name}: stored shape {shape:?} != model shape {:?}",
                spec.shape
            )));
        }
        params.set_tensor(name, data.clone())?;
        n += 1;
    }
    Ok(n)
}

// ---------------------------------------------- periodic-snapshot files

const PERIODIC_PREFIX: &str = "ckpt-";

/// Path of a periodic snapshot. Zero-padded so lexicographic filename
/// order equals training order (`latest_checkpoint` and retention both
/// rely on it).
pub fn periodic_path(dir: impl AsRef<Path>, phase_idx: u64, step_in_phase: u64) -> PathBuf {
    dir.as_ref().join(format!("{PERIODIC_PREFIX}p{phase_idx:02}-s{step_in_phase:08}.rvt"))
}

/// Sorted (oldest → newest) periodic snapshot files in `dir`.
fn periodic_files(dir: &Path) -> Vec<PathBuf> {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return Vec::new();
    };
    let mut files: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with(PERIODIC_PREFIX) && n.ends_with(".rvt"))
                .unwrap_or(false)
        })
        .collect();
    files.sort();
    files
}

/// Newest periodic snapshot in `dir` (`--resume` auto-discovery), or
/// `None` when the directory holds none.
pub fn latest_checkpoint(dir: impl AsRef<Path>) -> Option<PathBuf> {
    periodic_files(dir.as_ref()).pop()
}

/// Newest periodic snapshot in `dir` that parses structurally (a cheap
/// seek-based walk of the whole file — no tensor payload is
/// materialized), falling back to older snapshots when the newest is
/// torn. Atomic writes make torn files rare, but a power loss right
/// after a rename can still leave one — and losing the run to its own
/// freshest checkpoint is exactly what resume must survive.
pub fn latest_valid_checkpoint(dir: impl AsRef<Path>) -> Option<PathBuf> {
    let mut files = periodic_files(dir.as_ref());
    while let Some(path) = files.pop() {
        match load_cursor(&path) {
            Ok(_) => return Some(path),
            Err(e) => eprintln!(
                "[checkpoint] skipping unreadable snapshot {}: {e}",
                path.display()
            ),
        }
    }
    None
}

/// Delete the oldest periodic snapshots beyond `keep_last` (0 keeps
/// everything). Deletion failures are reported but non-fatal — losing a
/// stale snapshot must never kill the run that outgrew it.
pub fn prune_checkpoints(dir: impl AsRef<Path>, keep_last: usize) {
    if keep_last == 0 {
        return;
    }
    let files = periodic_files(dir.as_ref());
    if files.len() <= keep_last {
        return;
    }
    for old in &files[..files.len() - keep_last] {
        if let Err(e) = std::fs::remove_file(old) {
            eprintln!("[checkpoint] could not prune {}: {e}", old.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::TensorSpec;

    fn store() -> ParamStore {
        let specs = vec![
            TensorSpec {
                name: "embed".into(),
                shape: vec![4, 2],
                dtype: "f32".into(),
                blob: "x".into(),
                offset: 0,
                nbytes: 32,
            },
            TensorSpec {
                name: "norm_f".into(),
                shape: vec![2],
                dtype: "f32".into(),
                blob: "x".into(),
                offset: 32,
                nbytes: 8,
            },
        ];
        let host = vec![vec![1.0; 8], vec![0.5; 2]];
        ParamStore::from_host(specs, host).unwrap()
    }

    fn moments() -> OptMoments {
        OptMoments {
            m: vec![(vec![4, 2], vec![0.25; 8]), (vec![2], vec![0.5; 2])],
            v: vec![(vec![4, 2], vec![0.125; 8]), (vec![2], vec![1.5; 2])],
        }
    }

    fn cursor() -> RunCursor {
        RunCursor {
            phase_idx: 1,
            step_in_phase: 7,
            batches_taken: 14,
            batch_seed: 0xfeed,
            seq: 21,
            steps_total: 9,
        }
    }

    #[test]
    fn rvt1_save_load_roundtrip() {
        let dir = crate::util::ScratchDir::new("ckpt").unwrap();
        let p = dir.join("ck.rvt");
        let s = store();
        save(&p, &s, 42).unwrap();
        let ck = load(&p).unwrap();
        assert_eq!(ck.step, 42);
        assert_eq!(ck.tensors.len(), 2);
        assert_eq!(ck.tensors[0].0, "embed");
        assert_eq!(ck.tensors[0].2, vec![1.0; 8]);
        assert!(ck.opt.is_none(), "RVT1 carries no moments");
        assert!(ck.cursor.is_none(), "RVT1 carries no cursor");
    }

    #[test]
    fn summarize_matches_full_load_without_payloads() {
        let dir = crate::util::ScratchDir::new("cksum").unwrap();
        let p = dir.join("ck.rvt");
        let s = store();
        save_state(&p, &s, 9, Some(&moments()), Some(&cursor())).unwrap();
        let sm = summarize(&p).unwrap();
        assert_eq!(sm.step, 9);
        assert_eq!(
            sm.tensors,
            vec![("embed".to_string(), vec![4, 2]), ("norm_f".to_string(), vec![2])]
        );
        let (m, v) = sm.opt_shapes.expect("moments present");
        assert_eq!(m, vec![vec![4, 2], vec![2]]);
        assert_eq!(v, m);
        assert_eq!(sm.cursor, Some(cursor()));
        // RVT1: params only
        save(&p, &s, 3).unwrap();
        let sm = summarize(&p).unwrap();
        assert!(sm.opt_shapes.is_none());
        assert!(sm.cursor.is_none());
    }

    #[test]
    fn summarize_rejects_truncated_file() {
        let dir = crate::util::ScratchDir::new("cktrunc").unwrap();
        let p = dir.join("ck.rvt");
        save_state(&p, &store(), 9, Some(&moments()), Some(&cursor())).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() / 2]).unwrap();
        assert!(matches!(summarize(&p), Err(Error::Parse(_)) | Err(Error::Io(_))));
    }

    #[test]
    fn rvt2_full_state_roundtrip() {
        let dir = crate::util::ScratchDir::new("ckpt2").unwrap();
        let p = dir.join("full.rvt");
        save_state(&p, &store(), 9, Some(&moments()), Some(&cursor())).unwrap();
        let ck = load(&p).unwrap();
        assert_eq!(ck.step, 9);
        assert_eq!(ck.tensors.len(), 2);
        assert_eq!(ck.opt.as_ref().unwrap(), &moments());
        assert_eq!(ck.cursor.unwrap(), cursor());
        // atomic write leaves no tmp file behind
        assert!(!dir.join("full.rvt.tmp").exists());
    }

    #[test]
    fn rvt2_without_optional_sections() {
        let dir = crate::util::ScratchDir::new("ckpt3").unwrap();
        let p = dir.join("lean.rvt");
        save_state(&p, &store(), 3, None, None).unwrap();
        let ck = load(&p).unwrap();
        assert!(ck.opt.is_none());
        assert!(ck.cursor.is_none());
    }

    #[test]
    fn restore_matches_by_name() {
        let dir = crate::util::ScratchDir::new("ckpt4").unwrap();
        let p = dir.join("ck.rvt");
        let mut s = store();
        s.set_tensor("norm_f", vec![9.0, 9.0]).unwrap();
        save(&p, &s, 1).unwrap();
        let mut fresh = store();
        let ck = load(&p).unwrap();
        let n = restore_into(&ck, &mut fresh).unwrap();
        assert_eq!(n, 2);
        assert_eq!(fresh.tensor("norm_f").unwrap(), &[9.0, 9.0]);
    }

    #[test]
    fn same_count_different_shape_rejected() {
        // an 8-element [2, 4] must NOT restore into an 8-element [4, 2]
        let dir = crate::util::ScratchDir::new("ckpt5").unwrap();
        let p = dir.join("ck.rvt");
        let transposed = ParamStore::from_host(
            vec![TensorSpec {
                name: "embed".into(),
                shape: vec![2, 4],
                dtype: "f32".into(),
                blob: "x".into(),
                offset: 0,
                nbytes: 32,
            }],
            vec![vec![7.0; 8]],
        )
        .unwrap();
        save(&p, &transposed, 1).unwrap();
        let ck = load(&p).unwrap();
        let mut target = store();
        let err = restore_into(&ck, &mut target).unwrap_err();
        assert!(matches!(err, Error::Layout(_)), "got {err}");
        // target untouched by the failed restore
        assert_eq!(target.tensor("embed").unwrap(), &[1.0; 8]);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = crate::util::ScratchDir::new("ckpt6").unwrap();
        let p = dir.join("junk.rvt");
        std::fs::write(&p, b"NOPEnope").unwrap();
        assert!(matches!(load(&p).unwrap_err(), Error::Parse(_)));
    }

    #[test]
    fn truncation_anywhere_is_a_parse_error() {
        let dir = crate::util::ScratchDir::new("ckpt7").unwrap();
        let p = dir.join("full.rvt");
        save_state(&p, &store(), 9, Some(&moments()), Some(&cursor())).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // chop at every prefix length: each must fail cleanly as Parse
        let probe = dir.join("cut.rvt");
        for cut in 0..bytes.len() {
            std::fs::write(&probe, &bytes[..cut]).unwrap();
            match load(&probe) {
                Err(Error::Parse(_)) => {}
                Err(other) => panic!("cut at {cut}: expected Parse, got {other}"),
                Ok(_) => panic!("cut at {cut}: truncated file must not load"),
            }
        }
    }

    #[test]
    fn oversized_header_fields_error_without_allocating() {
        let dir = crate::util::ScratchDir::new("ckpt8").unwrap();
        let p = dir.join("ck.rvt");
        save(&p, &store(), 1).unwrap();
        let bytes = std::fs::read(&p).unwrap();

        // name_len is the u32 right after magic+step+count (offset 16):
        // claim a 4 GB name in a <1 KB file
        let mut evil = bytes.clone();
        evil[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&p, &evil).unwrap();
        assert!(matches!(load(&p).unwrap_err(), Error::Parse(_)));

        // tensor count claims 4 billion tensors
        let mut evil = bytes.clone();
        evil[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&p, &evil).unwrap();
        assert!(matches!(load(&p).unwrap_err(), Error::Parse(_)));

        // ndim for "embed" (offset 16 + 4 + 5) claims a billion dims
        let mut evil = bytes.clone();
        evil[25..29].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&p, &evil).unwrap();
        assert!(matches!(load(&p).unwrap_err(), Error::Parse(_)));

        // dims whose product overflows usize
        let mut evil = bytes;
        evil[25..29].copy_from_slice(&2u32.to_le_bytes()); // ndim = 2
        evil[29..33].copy_from_slice(&u32::MAX.to_le_bytes());
        evil[33..37].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&p, &evil).unwrap();
        assert!(matches!(load(&p).unwrap_err(), Error::Parse(_)));
    }

    #[test]
    fn periodic_paths_sort_chronologically() {
        let a = periodic_path("out", 0, 2);
        let b = periodic_path("out", 0, 10);
        let c = periodic_path("out", 1, 1);
        assert!(a.to_str().unwrap() < b.to_str().unwrap(), "step 2 before step 10");
        assert!(b.to_str().unwrap() < c.to_str().unwrap(), "phase 0 before phase 1");
    }

    #[test]
    fn latest_and_prune_respect_order_and_keep_last() {
        let dir = crate::util::ScratchDir::new("ckpt9").unwrap();
        let s = store();
        for (phase, step) in [(0u64, 2u64), (0, 4), (1, 2), (1, 4)] {
            save_state(periodic_path(&dir.path, phase, step), &s, step, None, None).unwrap();
        }
        assert_eq!(latest_checkpoint(&dir.path).unwrap(), periodic_path(&dir.path, 1, 4));

        prune_checkpoints(&dir.path, 2);
        let left: Vec<_> = periodic_files(&dir.path);
        assert_eq!(left, vec![periodic_path(&dir.path, 1, 2), periodic_path(&dir.path, 1, 4)]);

        // keep_last = 0 keeps everything
        prune_checkpoints(&dir.path, 0);
        assert_eq!(periodic_files(&dir.path).len(), 2);
    }

    #[test]
    fn latest_checkpoint_ignores_final_and_missing_dirs() {
        let dir = crate::util::ScratchDir::new("ckpt10").unwrap();
        save(dir.join("final.rvt"), &store(), 5).unwrap();
        assert!(latest_checkpoint(&dir.path).is_none(), "final.rvt is not a periodic snapshot");
        assert!(latest_checkpoint(dir.join("nonexistent")).is_none());
    }
}
