//! Engine API integration tests: the step-granular `Run` driver must
//! emit `PhaseStarted`/`Step`/`EvalPoint`/`PhaseFinished` events that
//! mirror the metrics `Trainer::run()` records for the same config, and
//! the `Session` facade must load the eval/generate path.
//!
//! Like the other integration tests, everything skips silently when
//! `artifacts/tiny` is absent (run `make artifacts` first).

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use revffn::config::RunConfig;
use revffn::coordinator::Trainer;
use revffn::engine::{Method, Session, StepEvent};
use revffn::eval::GenerateConfig;
use revffn::runtime::Device;
use revffn::util::ScratchDir;

fn artifacts_root() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    p.join("index.json").exists().then_some(p)
}

/// A tiny 2-phase RevFFN config with mid-phase eval points.
fn tiny_cfg(root: &Path, out: &Path) -> RunConfig {
    let mut cfg = RunConfig::default_tiny(root);
    cfg.method = Method::Revffn;
    cfg.schedule.stage1_steps = 2;
    cfg.schedule.stage2_steps = 3;
    cfg.schedule.warmup_steps = 1;
    cfg.data.pretrain_steps = 0;
    cfg.data.n_train = 48;
    cfg.data.n_eval = 16;
    cfg.eval_every = 2;
    cfg.eval_batches = 2;
    cfg.out_dir = out.into();
    cfg
}

#[test]
fn stepwise_run_matches_trainer_run() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("engine").unwrap();
    let device = Device::cpu().unwrap();

    // A: drive the 2-phase run step-by-step, observing every event
    let mut trainer_a = Trainer::new(&device, tiny_cfg(&root, &scratch.join("a"))).unwrap();
    let observed: Rc<RefCell<usize>> = Rc::new(RefCell::new(0));
    let observed_in = observed.clone();
    let mut events = Vec::new();
    let mut run = trainer_a.start().unwrap();
    run.set_observer(move |_ev| *observed_in.borrow_mut() += 1);
    while let Some(ev) = run.step().unwrap() {
        events.push(ev);
    }
    let report_a = run.finish().unwrap();
    assert_eq!(*observed.borrow(), events.len(), "observer sees every event");

    // event shape: PhaseStarted(1) .. PhaseFinished(1) PhaseStarted(2) ..
    let stages_started: Vec<u8> = events
        .iter()
        .filter_map(|e| match e {
            StepEvent::PhaseStarted { stage, .. } => Some(*stage),
            _ => None,
        })
        .collect();
    assert_eq!(stages_started, vec![1, 2]);
    let stages_finished: Vec<u8> = events
        .iter()
        .filter_map(|e| match e {
            StepEvent::PhaseFinished { stage, .. } => Some(*stage),
            _ => None,
        })
        .collect();
    assert_eq!(stages_finished, vec![1, 2]);

    // Step events mirror the metrics records one-to-one
    let step_events: Vec<(u64, f32)> = events
        .iter()
        .filter_map(|e| match e {
            StepEvent::Step(rec) => Some((rec.step, rec.loss)),
            _ => None,
        })
        .collect();
    assert_eq!(step_events.len(), 5, "2 stage-1 + 3 stage-2 steps");
    let metric_steps: Vec<(u64, f32)> =
        trainer_a.metrics.steps.iter().map(|r| (r.step, r.loss)).collect();
    assert_eq!(step_events, metric_steps);

    // EvalPoint events mirror the eval records (cadence + phase ends)
    let eval_events: Vec<(u64, f32)> = events
        .iter()
        .filter_map(|e| match e {
            StepEvent::EvalPoint { step, eval_loss } => Some((*step, *eval_loss)),
            _ => None,
        })
        .collect();
    let metric_evals: Vec<(u64, f32)> =
        trainer_a.metrics.evals.iter().map(|e| (e.step, e.eval_loss)).collect();
    assert_eq!(eval_events, metric_evals);
    assert!(!eval_events.is_empty());

    // B: the blocking compatibility wrapper over the same config must
    // record bit-identical metrics (training is deterministic)
    let mut trainer_b = Trainer::new(&device, tiny_cfg(&root, &scratch.join("b"))).unwrap();
    let report_b = trainer_b.run().unwrap();
    let metric_steps_b: Vec<(u64, f32)> =
        trainer_b.metrics.steps.iter().map(|r| (r.step, r.loss)).collect();
    assert_eq!(step_events, metric_steps_b, "Run::step == Trainer::run step metrics");
    let metric_evals_b: Vec<(u64, f32)> =
        trainer_b.metrics.evals.iter().map(|e| (e.step, e.eval_loss)).collect();
    assert_eq!(eval_events, metric_evals_b, "Run::step == Trainer::run eval metrics");
    assert_eq!(report_a.steps_run, report_b.steps_run);
    assert_eq!(report_a.final_loss, report_b.final_loss);
    assert_eq!(report_a.eval_loss, report_b.eval_loss);

    // both wrote their metrics sink
    assert!(scratch.join("a").join("metrics.jsonl").exists());
    assert!(scratch.join("b").join("metrics.jsonl").exists());
}

#[test]
fn grad_norm_consistent_across_accumulation_paths() {
    // satellite regression: the accumulate path must record the
    // mean-gradient norm, not `grad_accum` times it — with grad_accum=1
    // both paths see the same single batch, so the recorded norms must
    // be of the same scale (they differ only by clipping/update order).
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("engine-gn").unwrap();
    let device = Device::cpu().unwrap();
    let mut cfg = tiny_cfg(&root, &scratch.join("gn"));
    cfg.schedule.stage1_steps = 0;
    cfg.schedule.stage2_steps = 2;
    cfg.eval_every = 0;
    let mut trainer = Trainer::new(&device, cfg).unwrap();
    trainer.run().unwrap();
    for rec in &trainer.metrics.steps {
        assert!(rec.grad_norm.is_finite() && rec.grad_norm >= 0.0);
    }
}

#[test]
fn session_loads_eval_and_generate_path() {
    let Some(root) = artifacts_root() else { return };
    let session = Session::builder(&root).method(Method::Revffn).build().unwrap();
    assert!(session.stepper.vocab_size() > 0);
    // scoring a couple of questions exercises the whole facade
    let scores = session.bench_scores(2, 7).unwrap();
    assert!(scores.mmlu_like >= 0.0 && scores.mmlu_like <= 100.0);
    let text = session
        .generate(
            "Compute 2 plus 3.",
            &GenerateConfig { max_new_tokens: 2, ..Default::default() },
        )
        .unwrap();
    assert!(!text.is_empty());
}

#[test]
fn session_build_program_loads_reconstruct() {
    let Some(root) = artifacts_root() else { return };
    if !root.join("reconstruct").join("manifest.json").exists() {
        return;
    }
    let raw = Session::builder(&root)
        .variant("reconstruct")
        .build_program("reconstruct")
        .unwrap();
    assert!(!raw.params.is_empty());
    let io = &raw.artifact.manifest.io;
    let tokens: Vec<i32> =
        (0..io.batch_size * io.seq_len).map(|i| (i % 60) as i32 + 4).collect();
    let mut inputs = raw.params.to_literals().unwrap();
    inputs.push(
        revffn::runtime::literal::i32_literal(&tokens, &[io.batch_size, io.seq_len]).unwrap(),
    );
    let out = raw.program.run(&inputs).unwrap();
    let err = revffn::runtime::literal::scalar_to_f32(&out[0]).unwrap();
    assert!(err.is_finite());
}
