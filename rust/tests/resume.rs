//! Kill-and-resume bit-parity over the real AOT artifacts — the
//! headline crash-safety guarantee: a run killed at an arbitrary step
//! and resumed from its latest periodic snapshot reproduces the
//! uninterrupted run exactly — same losses, same grad norms, same
//! final parameters, bit for bit — because the snapshot restores the
//! Adam moments, the optimizer step counter and the data-pipeline
//! cursor, not just the weights.
//!
//! Like the other integration tests, everything skips silently when
//! `artifacts/tiny` is absent (run `make artifacts` first).

use std::path::{Path, PathBuf};

use revffn::checkpoint;
use revffn::config::RunConfig;
use revffn::coordinator::Trainer;
use revffn::engine::{Method, StepEvent};
use revffn::runtime::Device;
use revffn::util::ScratchDir;

fn artifacts_root() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    p.join("index.json").exists().then_some(p)
}

/// A 2+4-step RevFFN run snapshotting every step (pre-pass off).
fn cfg(root: &Path, out: &Path, grad_accum: usize) -> RunConfig {
    let mut cfg = RunConfig::default_tiny(root);
    cfg.method = Method::Revffn;
    cfg.schedule.stage1_steps = 2;
    cfg.schedule.stage2_steps = 4;
    cfg.schedule.warmup_steps = 1;
    cfg.data.pretrain_steps = 0;
    cfg.data.n_train = 48;
    cfg.data.n_eval = 16;
    cfg.grad_accum = grad_accum;
    cfg.eval_every = 0;
    cfg.eval_batches = 1;
    cfg.checkpoint_every = 1;
    cfg.keep_last = 0; // keep every snapshot so any kill point resumes
    cfg.out_dir = out.into();
    cfg
}

/// (stage, step) → (loss bits, grad-norm bits) of a finished trainer.
fn signature(t: &Trainer) -> Vec<((u8, u64), (u32, u32))> {
    t.metrics
        .steps
        .iter()
        .map(|r| ((r.stage, r.step), (r.loss.to_bits(), r.grad_norm.to_bits())))
        .collect()
}

/// Final parameters as (name, bits) — the strictest equality there is.
fn param_bits(t: &Trainer) -> Vec<(String, Vec<u32>)> {
    t.stepper
        .as_ref()
        .expect("finished run leaves a stepper")
        .params
        .snapshot()
        .map(|(n, _s, d)| (n.to_string(), d.iter().map(|x| x.to_bits()).collect()))
        .collect()
}

/// Train uninterrupted; then for each kill point, train a second copy,
/// kill it after `kill_after` optimizer steps, resume from the newest
/// snapshot on disk, and demand the combined trajectory and final
/// params match the baseline bit-for-bit.
fn kill_resume_case(tag: &str, grad_accum: usize, kill_points: &[usize]) {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new(tag).unwrap();

    let baseline = {
        let device = Device::cpu().unwrap();
        let mut t = Trainer::new(&device, cfg(&root, &scratch.join("solo"), grad_accum)).unwrap();
        t.run().unwrap();
        (signature(&t), param_bits(&t))
    };

    for &kill_after in kill_points {
        let out = scratch.join(format!("kill-{kill_after}"));

        // phase 1 of the "crash": drive step-granularly, then drop the
        // run mid-schedule without finish() — state survives only as
        // the periodic snapshots
        {
            let device = Device::cpu().unwrap();
            let mut t = Trainer::new(&device, cfg(&root, &out, grad_accum)).unwrap();
            let mut run = t.start().unwrap();
            let mut steps = 0usize;
            while steps < kill_after {
                match run.step().unwrap() {
                    Some(StepEvent::Step(_)) => steps += 1,
                    Some(_) => {}
                    None => panic!("schedule ended before the kill point {kill_after}"),
                }
            }
        }
        let ckpt_path = checkpoint::latest_checkpoint(&out)
            .unwrap_or_else(|| panic!("no snapshot before kill point {kill_after}"));

        // phase 2: a fresh process (fresh trainer) resumes and finishes
        let device = Device::cpu().unwrap();
        let mut t = Trainer::new(&device, cfg(&root, &out, grad_accum)).unwrap();
        let ckpt = checkpoint::load(&ckpt_path).unwrap();
        t.run_resumed(ckpt).unwrap();

        // the resumed tail must be a suffix of the baseline trajectory…
        let tail = signature(&t);
        let full = &baseline.0;
        assert!(tail.len() <= full.len(), "kill {kill_after}: resumed run overran the schedule");
        assert_eq!(
            &full[full.len() - tail.len()..],
            &tail[..],
            "kill {kill_after}: resumed losses/grad-norms diverged from the uninterrupted run"
        );
        // …and the final parameters identical to the last bit
        assert_eq!(
            baseline.1,
            param_bits(&t),
            "kill {kill_after}: final params diverged from the uninterrupted run"
        );
    }
}

#[test]
fn kill_and_resume_is_bit_identical_across_stages() {
    // kill points land mid-stage-1, at the stage boundary, and
    // mid-stage-2 — every structurally distinct resume position
    kill_resume_case("resume-fused", 1, &[1, 2, 4]);
}

#[test]
fn kill_and_resume_with_grad_accum_replays_the_microbatch_cursor() {
    // grad_accum > 1: each optimizer step drains several batches, so
    // the cursor replay must skip batches_taken = steps × ga exactly
    kill_resume_case("resume-accum", 2, &[3]);
}

#[test]
fn params_only_checkpoints_cannot_resume_a_run() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("resume-reject").unwrap();
    let device = Device::cpu().unwrap();
    let c = cfg(&root, &scratch.join("r"), 1);
    let mut t = Trainer::new(&device, c).unwrap();
    let mut run = t.start().unwrap();
    // drive a couple of events so a snapshot exists
    for _ in 0..4 {
        run.step().unwrap();
    }
    drop(run);
    let path = checkpoint::latest_checkpoint(&scratch.join("r")).unwrap();

    // strip the checkpoint down (simulates an RVT1 file or a final
    // snapshot) — a fresh run must refuse to resume from it
    let full = checkpoint::load(&path).unwrap();
    let mut t2 = Trainer::new(&device, cfg(&root, &scratch.join("r"), 1)).unwrap();
    let mut run2 = t2.start().unwrap();
    let no_moments = checkpoint::Checkpoint {
        step: full.step,
        tensors: full.tensors.clone(),
        opt: None,
        cursor: full.cursor,
    };
    assert!(
        run2.restore(no_moments).is_err(),
        "moment-less checkpoints must be rejected (silent Adam reset)"
    );
    let no_cursor = checkpoint::Checkpoint {
        step: full.step,
        tensors: full.tensors,
        opt: full.opt,
        cursor: None,
    };
    assert!(run2.restore(no_cursor).is_err(), "cursor-less checkpoints must be rejected");
}

#[test]
fn resume_rejects_mismatched_configs() {
    let Some(root) = artifacts_root() else { return };
    let scratch = ScratchDir::new("resume-mismatch").unwrap();
    let device = Device::cpu().unwrap();
    let mut t = Trainer::new(&device, cfg(&root, &scratch.join("m"), 1)).unwrap();
    let mut run = t.start().unwrap();
    for _ in 0..4 {
        run.step().unwrap();
    }
    drop(run);
    let ckpt_path = checkpoint::latest_checkpoint(&scratch.join("m")).unwrap();

    // a different data seed would replay different batches — the
    // recorded batch seed must catch it at restore/open time
    let mut other = cfg(&root, &scratch.join("m"), 1);
    other.seed = 999;
    let mut t2 = Trainer::new(&device, other).unwrap();
    let ckpt = checkpoint::load(&ckpt_path).unwrap();
    assert!(
        t2.run_resumed(ckpt).is_err(),
        "resume must refuse a checkpoint recorded under a different batch seed"
    );
}
