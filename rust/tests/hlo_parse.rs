//! HLO-text parser goldens and robustness sweep (`analysis::hlo`).
//!
//! Golden half: one committed program text per trainable variant
//! (`tests/fixtures/hlo/`) covering the dialect surface the lowering
//! pipeline emits — aux computations, tuple-shaped values,
//! `get-tuple-element`, `while` with computation-reference attributes,
//! `custom-call`, donation headers. Each must parse to the exact
//! structure the liveness pass consumes.
//!
//! Fuzz half: every fixture is truncated at stride offsets and
//! byte-mutated with a deterministic LCG; `parse_module` must always
//! return `Ok` or a structured `Error::Parse` — never panic — and
//! `parse_signature` must stay panic-free too. This is the tolerance
//! contract `check --hlo-mem` relies on when pointed at real XLA dumps.

use std::path::PathBuf;

use revffn::analysis::hlo::{parse_module, parse_signature, Shape};
use revffn::analysis::liveness::entry_peak;
use revffn::error::Error;

const VARIANTS: &[&str] = &[
    "sft",
    "lora",
    "dora",
    "ia3",
    "lomo",
    "galore",
    "revffn_stage1",
    "revffn_stage2",
];

fn fixture_text(variant: &str) -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/hlo")
        .join(format!("{variant}.hlo.txt"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

#[test]
fn every_variant_fixture_parses_with_entry_and_root() {
    for v in VARIANTS {
        let m = parse_module(&fixture_text(v)).unwrap_or_else(|e| panic!("{v}: {e}"));
        let entry = m.entry().unwrap_or_else(|| panic!("{v}: no ENTRY"));
        assert!(entry.root().is_some(), "{v}: no ROOT");
        assert!(
            entry.instrs.iter().any(|i| i.opcode == "parameter"),
            "{v}: no parameters"
        );
        // every fixture donates at least its first state buffer
        assert!(
            m.alias.contains(&(0, 0)),
            "{v}: missing the {{0}}: (0) donation, alias = {:?}",
            m.alias
        );
        // the signature reader and the module parser must agree on arity
        let sig = parse_signature(&fixture_text(v)).unwrap_or_else(|| panic!("{v}: no signature"));
        let n_params = entry.instrs.iter().filter(|i| i.param_number.is_some()).count();
        assert_eq!(sig.params.len(), n_params, "{v}: param arity disagreement");
        // liveness must be computable on every golden program
        let peak = entry_peak(&m).unwrap_or_else(|e| panic!("{v}: {e}"));
        assert!(peak.peak_bytes > 0, "{v}: zero peak");
    }
}

#[test]
fn sft_golden_structure() {
    let m = parse_module(&fixture_text("sft")).unwrap();
    assert_eq!(m.name, "train_step.0");
    assert_eq!(m.computations.len(), 2, "aux %add_f32 + ENTRY");
    assert_eq!(m.alias, vec![(0, 0), (1, 1), (2, 2), (3, 3)]);
    let entry = m.entry().unwrap();
    assert_eq!(entry.name, "main.1");
    assert_eq!(entry.instrs.iter().filter(|i| i.param_number.is_some()).count(), 9);
    let root = entry.root().unwrap();
    assert_eq!(root.opcode, "tuple");
    assert_eq!(root.operands.len(), 7);
    assert_eq!(root.operands[0], "newp.17");
    // a reduce's to_apply reference is an attribute, not an operand
    let loss = entry.instrs.iter().find(|i| i.name == "loss.15").unwrap();
    assert_eq!(loss.operands, vec!["lse.14".to_string(), "scalar.10".to_string()]);
    assert!(loss.attrs.contains("to_apply=%add_f32"), "attrs: {}", loss.attrs);
}

#[test]
fn dora_golden_tuple_values_and_custom_call() {
    let m = parse_module(&fixture_text("dora")).unwrap();
    let entry = m.entry().unwrap();
    let cc = entry.instrs.iter().find(|i| i.opcode == "custom-call").unwrap();
    match &cc.shape {
        Shape::Tuple(elems) => {
            assert_eq!(elems.len(), 2);
            assert_eq!(cc.shape.flat_bytes(), 8 * 2 * 4 + 4);
        }
        other => panic!("custom-call shape should be a tuple, got {}", other.render()),
    }
    assert!(cc.attrs.contains("custom_call_target=\"column_norm\""));
    let gte: Vec<_> =
        entry.instrs.iter().filter(|i| i.opcode == "get-tuple-element").collect();
    assert_eq!(gte.len(), 2);
    assert_eq!(gte[0].operands, vec!["normed.4".to_string()]);
    assert!(gte[0].attrs.contains("index=0"));
}

#[test]
fn galore_golden_while_loop_bodies() {
    let m = parse_module(&fixture_text("galore")).unwrap();
    assert_eq!(m.computations.len(), 3, "cond + body + ENTRY");
    assert!(m.computations.iter().any(|c| c.name == "cond.inc" && !c.is_entry));
    assert!(m.computations.iter().any(|c| c.name == "body.inc" && !c.is_entry));
    let entry = m.entry().unwrap();
    let w = entry.instrs.iter().find(|i| i.opcode == "while").unwrap();
    // the loop-carried tuple is the only operand; the computation
    // references live in the attributes
    assert_eq!(w.operands, vec!["init.4".to_string()]);
    assert!(w.attrs.contains("condition=%cond.inc"));
    assert!(w.attrs.contains("body=%body.inc"));
    // the while's tuple shape is (s32[], f32[4,2]) = 4 + 32 bytes
    assert_eq!(w.shape.flat_bytes(), 36);
}

#[test]
fn revffn_stages_share_the_two_stream_signature() {
    for v in ["revffn_stage1", "revffn_stage2"] {
        let m = parse_module(&fixture_text(v)).unwrap();
        let entry = m.entry().unwrap();
        let streams: Vec<_> = entry
            .instrs
            .iter()
            .filter(|i| i.param_number == Some(0) || i.param_number == Some(1))
            .collect();
        assert_eq!(streams.len(), 2, "{v}");
        for s in &streams {
            assert_eq!(s.shape.flat_bytes(), 2 * 4 * 4 * 4, "{v}: {}", s.name);
        }
        // both residual streams are donated — the reversible calling
        // convention that makes the live set depth-independent
        assert_eq!(m.alias, vec![(0, 0), (1, 1)], "{v}");
    }
}

#[test]
fn truncations_never_panic_and_degrade_to_parse_errors() {
    for v in VARIANTS {
        let text = fixture_text(v);
        let bytes = text.as_bytes();
        for cut in (0..bytes.len()).step_by(7) {
            let head = String::from_utf8_lossy(&bytes[..cut]);
            match parse_module(&head) {
                Ok(_) => {}
                Err(Error::Parse(msg)) => {
                    assert!(msg.starts_with("hlo:"), "{v}@{cut}: unstructured error {msg}")
                }
                Err(e) => panic!("{v}@{cut}: non-Parse error {e}"),
            }
            let _ = parse_signature(&head); // must not panic either
        }
    }
}

#[test]
fn byte_mutations_never_panic() {
    // deterministic LCG so the sweep is reproducible without any
    // clock/rng dependency
    let mut state: u64 = 0x9e37_79b9_7f4a_7c15;
    let mut next = move || {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        state
    };
    for v in VARIANTS {
        let text = fixture_text(v);
        for _ in 0..200 {
            let mut bytes = text.as_bytes().to_vec();
            let pos = (next() as usize) % bytes.len();
            bytes[pos] = (next() & 0xff) as u8;
            let mutated = String::from_utf8_lossy(&bytes).into_owned();
            match parse_module(&mutated) {
                Ok(_) => {}
                Err(Error::Parse(_)) => {}
                Err(e) => panic!("{v}: mutation produced non-Parse error {e}"),
            }
            let _ = parse_signature(&mutated);
        }
    }
}
