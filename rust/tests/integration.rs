//! Integration tests over the real AOT artifacts: every variant must
//! load, compile and execute; training must learn; the two-stage handoff
//! must preserve weights; the reversibility and memory claims must hold
//! on the lowered graphs.
//!
//! All tests skip silently when `artifacts/tiny` is absent (run
//! `make artifacts` first); CI always builds artifacts before testing.

use std::path::PathBuf;

use revffn::data::synthetic::{Corpus, CorpusConfig};
use revffn::data::{encode_corpus, Batcher, Tokenizer};
use revffn::engine::Method;
use revffn::runtime::{Artifact, ArtifactIndex, Device, ProgramCache, Stepper};

fn artifacts_root() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    p.join("index.json").exists().then_some(p)
}

// PjRtClient is Rc-backed (not Send), so each test owns its client.
fn ctx() -> (Device, ProgramCache) {
    (Device::cpu().expect("PJRT CPU client"), ProgramCache::new())
}

fn make_stepper_in(device: &Device, cache: &ProgramCache, variant: &str) -> Option<Stepper> {
    let root = artifacts_root()?;
    let artifact = Artifact::load(root.join(variant)).ok()?;
    Some(Stepper::new(device, cache, artifact).expect("stepper"))
}

fn data_for(stepper: &Stepper, n: usize) -> Batcher {
    let corpus = Corpus::generate(CorpusConfig { n_train: n, ..Default::default() });
    let tok = Tokenizer::train(&corpus.train_text(), stepper.vocab_size()).unwrap();
    let (b, s) = stepper.batch_shape();
    Batcher::new(encode_corpus(&tok, &corpus.train, s), b, s, 0)
}

#[test]
fn every_variant_compiles_and_loads_params() {
    let Some(root) = artifacts_root() else { return };
    let (device, cache) = ctx();
    let index = ArtifactIndex::load(&root).unwrap();
    for variant in &index.variants {
        let artifact = Artifact::load(root.join(variant)).unwrap();
        for kind in artifact.manifest.artifacts.keys() {
            let path = artifact.hlo_path(kind).unwrap();
            cache
                .get_or_load(&device, &path)
                .unwrap_or_else(|e| panic!("compile {variant}/{kind}: {e}"));
        }
        let params = revffn::runtime::ParamStore::from_blobs(&artifact)
            .unwrap_or_else(|e| panic!("blobs {variant}: {e}"));
        assert_eq!(params.len(), artifact.manifest.tensors.len());
        assert!(params.global_norm() > 0.0, "{variant}: zero params");
    }
}

#[test]
fn revffn_train_step_learns() {
    let (device, cache) = ctx();
    let Some(mut stepper) = make_stepper_in(&device, &cache, Method::Revffn.variant(2)) else {
        return;
    };
    let mut batcher = data_for(&stepper, 64);
    let mut losses = Vec::new();
    for _ in 0..6 {
        let stats = stepper.train_step(&batcher.next_batch(), 3e-4).unwrap();
        losses.push(stats.loss);
        assert!(stats.loss.is_finite());
        assert!(stats.grad_norm.is_finite());
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn all_method_train_steps_execute() {
    let Some(root) = artifacts_root() else { return };
    let (device, cache) = ctx();
    for variant in Method::ALL.map(|m| m.variant(1)) {
        if !root.join(variant).join("manifest.json").exists() {
            continue;
        }
        let mut stepper = make_stepper_in(&device, &cache, variant).unwrap();
        let mut batcher = data_for(&stepper, 16);
        let stats = stepper
            .train_step(&batcher.next_batch(), 1e-4)
            .unwrap_or_else(|e| panic!("{variant}: {e}"));
        assert!(stats.loss.is_finite(), "{variant}: loss {}", stats.loss);
    }
}

#[test]
fn eval_step_is_pure() {
    let (device, cache) = ctx();
    let Some(stepper) = make_stepper_in(&device, &cache, Method::Revffn.variant(2)) else { return };
    let mut batcher = data_for(&stepper, 16);
    let batch = batcher.next_batch();
    let (l1, _) = stepper.eval_step(&batch).unwrap();
    let (l2, _) = stepper.eval_step(&batch).unwrap();
    assert_eq!(l1, l2, "eval must be deterministic and mutate nothing");
}

#[test]
fn forward_shape_and_finiteness() {
    let (device, cache) = ctx();
    let Some(stepper) = make_stepper_in(&device, &cache, Method::Revffn.variant(2)) else { return };
    let (b, s) = stepper.batch_shape();
    let v = stepper.vocab_size();
    let tokens: Vec<i32> = (0..b * s).map(|i| (i % 60) as i32 + 4).collect();
    let logits = stepper.forward(&tokens).unwrap();
    assert_eq!(logits.len(), b * s * v);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn stage_handoff_preserves_weights() {
    let (device, cache) = ctx();
    let Some(mut s1) = make_stepper_in(&device, &cache, Method::Revffn.variant(1)) else { return };
    let Some(mut s2) = make_stepper_in(&device, &cache, Method::Revffn.variant(2)) else { return };
    // train stage 1 a little so params differ from the blob init
    let mut batcher = data_for(&s1, 16);
    for _ in 0..2 {
        s1.train_step(&batcher.next_batch(), 1e-3).unwrap();
    }
    let s1_params = s1.materialize_params().unwrap();
    let copied = s2.adopt_params(s1_params).unwrap();
    assert_eq!(copied, s1.params.len(), "same manifest => all tensors copied");
    let name = &s1.params.specs()[0].name.clone();
    assert_eq!(s1.params.tensor(name).unwrap(), s2.params.tensor(name).unwrap());
}

#[test]
fn pretrain_transfer_standard_to_revffn() {
    // The pre-pass trains the standard model; the RevFFN scaffold adopts
    // the shared tensors by name (embed, layers.attn.*, layers.moe.*).
    let (device, cache) = ctx();
    let Some(mut sft) = make_stepper_in(&device, &cache, Method::Sft.eval_variant()) else {
        return;
    };
    let Some(mut rev) = make_stepper_in(&device, &cache, Method::Revffn.variant(1)) else {
        return;
    };
    let mut batcher = data_for(&sft, 16);
    sft.train_step(&batcher.next_batch(), 1e-3).unwrap();
    let sft_params = sft.materialize_params().unwrap();
    let copied = rev.adopt_params(sft_params).unwrap();
    assert!(copied > 0, "shared tensors must transfer");
    assert!(copied < rev.params.len(), "adapters must NOT come from sft");
    assert_eq!(
        sft.params.tensor("embed").unwrap(),
        rev.params.tensor("embed").unwrap()
    );
}

#[test]
fn deterministic_training_given_same_inputs() {
    let (device, cache) = ctx();
    let Some(mut a) = make_stepper_in(&device, &cache, Method::Revffn.variant(2)) else { return };
    let Some(mut b) = make_stepper_in(&device, &cache, Method::Revffn.variant(2)) else { return };
    let mut ba = data_for(&a, 16);
    let mut bb = data_for(&b, 16);
    for _ in 0..2 {
        let sa = a.train_step(&ba.next_batch(), 1e-3).unwrap();
        let sb = b.train_step(&bb.next_batch(), 1e-3).unwrap();
        assert_eq!(sa.loss, sb.loss, "training must be bit-deterministic");
    }
}

#[test]
fn reversible_memory_claim_on_lowered_graphs() {
    let Some(root) = artifacts_root() else { return };
    let Some((rev, naive)) =
        revffn::memory::calib::reversible_vs_naive(&root).unwrap() else { return };
    assert!(
        (naive as f64) / (rev as f64) > 2.0,
        "reversible backward must cut XLA temp memory at least 2x: {rev} vs {naive}"
    );
}

#[test]
fn reconstruct_error_bounded_and_iteration_sweep_improves() {
    let Some(root) = artifacts_root() else { return };
    let (device, cache) = ctx();
    let params_src = make_stepper_in(&device, &cache, Method::Revffn.variant(2)).unwrap();
    // freshly constructed: host mirror is clean
    let mut errs = Vec::new();
    for variant in ["reconstruct", "reconstruct_iters4", "reconstruct_symmetric"] {
        let dir = root.join(variant);
        if !dir.join("manifest.json").exists() {
            return;
        }
        let artifact = Artifact::load(&dir).unwrap();
        let prog = cache
            .get_or_load(&device, artifact.hlo_path("reconstruct").unwrap())
            .unwrap();
        let io = &artifact.manifest.io;
        let mut inputs = params_src.params.to_literals().unwrap();
        let tokens: Vec<i32> =
            (0..io.batch_size * io.seq_len).map(|i| (i % 60) as i32 + 4).collect();
        inputs.push(
            revffn::runtime::literal::i32_literal(&tokens, &[io.batch_size, io.seq_len])
                .unwrap(),
        );
        let out = prog.run(&inputs).unwrap();
        errs.push(revffn::runtime::literal::scalar_to_f32(&out[0]).unwrap());
    }
    // 1 iteration: bounded; 4 iterations: much smaller; symmetric: fp noise
    assert!(errs[0] < 5e-2, "1-iter error {}", errs[0]);
    assert!(errs[1] < errs[0], "more iterations must shrink error: {errs:?}");
    assert!(errs[2] < 1e-4, "symmetric variant must be exact-ish: {}", errs[2]);
}

#[test]
fn pallas_variant_matches_ref_variant_outputs() {
    // The tiny_pallas artifacts route hot loops through the L1 kernels;
    // logits must agree with the ref-path artifacts on identical weights.
    let Some(root) = artifacts_root() else { return };
    let pallas_root = root.parent().unwrap().join("tiny_pallas");
    if !pallas_root.join(Method::Revffn.variant(2)).join("manifest.json").exists() {
        return;
    }
    let (device, cache) = ctx();
    let ref_art = Artifact::load(root.join(Method::Revffn.variant(2))).unwrap();
    let pl_art = Artifact::load(pallas_root.join(Method::Revffn.variant(2))).unwrap();
    assert!(pl_art.manifest.use_pallas);
    let ref_stepper = Stepper::new(&device, &cache, ref_art).unwrap();
    let mut pl_stepper = Stepper::new(&device, &cache, pl_art).unwrap();
    // same weights (adopt by name), pallas batch shape may differ
    pl_stepper.adopt_params(&ref_stepper.params).unwrap();
    let (b, s) = pl_stepper.batch_shape();
    let v = pl_stepper.vocab_size();
    let tokens: Vec<i32> = (0..b * s).map(|i| (i % 60) as i32 + 4).collect();
    let pl_logits = pl_stepper.forward(&tokens).unwrap();

    // score the same tokens through the ref artifact (bigger batch: pad)
    let (rb, rs) = ref_stepper.batch_shape();
    assert_eq!(v, ref_stepper.vocab_size());
    if rs < s {
        return; // shapes incompatible; covered by python-side tests
    }
    let mut ref_tokens = vec![4i32; rb * rs];
    for i in 0..b {
        for t in 0..s {
            ref_tokens[i * rs + t] = tokens[i * s + t];
        }
    }
    let ref_logits = ref_stepper.forward(&ref_tokens).unwrap();
    let mut max_diff = 0f32;
    for i in 0..b {
        for t in 0..s {
            for c in 0..v {
                let a = pl_logits[(i * s + t) * v + c];
                let r = ref_logits[(i * rs + t) * v + c];
                max_diff = max_diff.max((a - r).abs());
            }
        }
    }
    assert!(max_diff < 2e-2, "pallas vs ref logits diverge: {max_diff}");
}
