//! Property-based tests over the coordinator substrates (proptest-style,
//! driven by the in-crate harness): tokenizer round-trips, JSON codec
//! round-trips, checkpoint format, batcher invariants, LR schedule
//! bounds, memory-model monotonicity, instruction masking.

use revffn::config::{LrSchedule, ScheduleConfig};
use revffn::coordinator::lr::lr_at;
use revffn::data::dataset::{encode_example, encode_lm_chunk};
use revffn::data::synthetic::{Example, Family};
use revffn::data::tokenizer::Tokenizer;
use revffn::data::Batcher;
use revffn::memory::{Assumptions, Geometry, MemoryModel, Method};
use revffn::util::json;
use revffn::util::prop::{gen, prop_check};
use revffn::util::rng::Rng;

#[test]
fn prop_tokenizer_roundtrip_any_ascii() {
    let corpus = "the quick brown fox jumps over the lazy dog 0123456789 ".repeat(30);
    let tok = Tokenizer::train(&corpus, 300).unwrap();
    prop_check("tokenizer-roundtrip", 100, 11,
        |rng| gen::string(rng, 60),
        |s| tok.decode(&tok.encode(s)) == *s);
}

#[test]
fn prop_tokenizer_ids_in_vocab() {
    let corpus = "aa bb cc dd ee ff ".repeat(40);
    let vocab = 290;
    let tok = Tokenizer::train(&corpus, vocab).unwrap();
    prop_check("tokenizer-vocab-bound", 100, 13,
        |rng| gen::string(rng, 80),
        |s| tok.encode(s).iter().all(|&i| (i as usize) < vocab));
}

#[test]
fn prop_json_string_roundtrip() {
    prop_check("json-string-roundtrip", 200, 17,
        |rng| gen::string(rng, 40),
        |s| {
            let j = json::Json::Str(s.clone());
            json::parse(&j.to_string()).map(|b| b == j).unwrap_or(false)
        });
}

#[test]
fn prop_json_number_array_roundtrip() {
    prop_check("json-num-roundtrip", 100, 19,
        |rng| {
            let n = rng.gen_range(0..30);
            gen::i32_vec(rng, n, -100000, 100000)
        },
        |v| {
            let j = json::Json::Arr(v.iter().map(|&x| json::Json::Num(x as f64)).collect());
            match json::parse(&j.to_string()) {
                Ok(json::Json::Arr(back)) => back
                    .iter()
                    .zip(v)
                    .all(|(b, &x)| b.as_f64() == Some(x as f64)),
                _ => false,
            }
        });
}

#[test]
fn prop_lr_always_in_bounds() {
    let scheds = [LrSchedule::Constant, LrSchedule::WarmupCosine, LrSchedule::WarmupLinear];
    prop_check("lr-bounds", 300, 23,
        |rng| {
            let kind = scheds[rng.gen_range(0..3)];
            let total = rng.gen_range(1..500) as u64;
            let step = rng.gen_range(0..total as usize) as u64;
            let peak = rng.gen_f32() + 1e-3;
            (kind, total, step, peak)
        },
        |&(kind, total, step, peak)| {
            let s = ScheduleConfig {
                lr_schedule: kind,
                warmup_steps: 10,
                min_lr_factor: 0.1,
                ..Default::default()
            };
            let lr = lr_at(&s, peak, step, total);
            lr > 0.0 && lr <= peak * (1.0 + 1e-6)
        });
}

#[test]
fn prop_batcher_preserves_sample_multiset_per_epoch() {
    prop_check("batcher-epoch-coverage", 30, 29,
        |rng| (rng.gen_range(4..40), rng.gen_range(1..5), rng.next_u64()),
        |&(n, b, seed)| {
            let n = n - n % b; // full batches only for exact coverage
            if n == 0 {
                return true;
            }
            let samples: Vec<_> = (0..n)
                .map(|i| revffn::data::Sample {
                    tokens: vec![i as i32; 4],
                    targets: vec![i as i32; 4],
                    loss_mask: vec![1.0; 4],
                })
                .collect();
            let mut batcher = Batcher::new(samples, b, 4, seed);
            let mut seen = vec![0usize; n];
            for _ in 0..n / b {
                let batch = batcher.next_batch();
                for row in 0..b {
                    seen[batch.tokens[row * 4] as usize] += 1;
                }
            }
            seen.iter().all(|&c| c == 1)
        });
}

#[test]
fn prop_mask_never_covers_prompt() {
    let corpus = "Compute 1 plus 2. The answer is 3. ".repeat(30);
    let tok = Tokenizer::train(&corpus, 300).unwrap();
    prop_check("mask-prompt-disjoint", 60, 31,
        |rng| {
            let a = rng.gen_range(1..50);
            let b = rng.gen_range(1..50);
            Example {
                instruction: format!("Compute {a} plus {b}."),
                response: format!("The answer is {}.", a + b),
                family: Family::Arithmetic,
            }
        },
        |ex| {
            let Ok(s) = encode_example(&tok, ex, 96) else { return true };
            let prompt_len =
                tok.encode(&revffn::data::dataset::render_prompt(&ex.instruction)).len() + 1;
            s.loss_mask[..prompt_len.saturating_sub(1)].iter().all(|&m| m == 0.0)
        });
}

#[test]
fn prop_lm_chunk_targets_shifted() {
    prop_check("lm-shift", 80, 37,
        |rng| {
            let n = rng.gen_range(2..40);
            gen::i32_vec(rng, n, 4, 260)
        },
        |ids| {
            let s = encode_lm_chunk(ids, 24);
            (0..23).all(|t| s.loss_mask[t] == 0.0 || s.targets[t] == s.tokens[t + 1])
        });
}

#[test]
fn prop_memory_monotone_in_batch_and_seq() {
    let model = MemoryModel::new(Geometry::qwen15_moe_a27b(), Assumptions::bf16_mixed());
    prop_check("memory-monotone", 60, 41,
        |rng| {
            let m = Method::ALL[rng.gen_range(0..Method::ALL.len())];
            let b = rng.gen_range(1..64) as u64;
            let s = [512u64, 1024, 2048][rng.gen_range(0..3)];
            (m, b, s)
        },
        |&(m, b, s)| {
            model.peak_gb(m, b + 1, s) >= model.peak_gb(m, b, s)
                && model.peak_gb(m, b, s * 2) >= model.peak_gb(m, b, s)
        });
}

#[test]
fn prop_checkpoint_roundtrip_random_tensors() {
    use revffn::runtime::artifact::TensorSpec;
    use revffn::runtime::ParamStore;
    prop_check("checkpoint-roundtrip", 25, 43,
        |rng| {
            let n_tensors = rng.gen_range(1..6);
            (0..n_tensors)
                .map(|i| {
                    let rows = rng.gen_range(1..5);
                    let cols = rng.gen_range(1..7);
                    (format!("t{i}"), vec![rows, cols], gen::f32_vec(rng, rows * cols, 2.0))
                })
                .collect::<Vec<_>>()
        },
        |tensors| {
            let specs: Vec<TensorSpec> = tensors
                .iter()
                .map(|(name, shape, data)| TensorSpec {
                    name: name.clone(),
                    shape: shape.clone(),
                    dtype: "f32".into(),
                    blob: "none".into(),
                    offset: 0,
                    nbytes: data.len() * 4,
                })
                .collect();
            let host: Vec<Vec<f32>> = tensors.iter().map(|(_, _, d)| d.clone()).collect();
            let store = ParamStore::from_host(specs.clone(), host).unwrap();
            let dir = revffn::util::ScratchDir::new("prop-ckpt").unwrap();
            let path = dir.join("x.rvt");
            revffn::checkpoint::save(&path, &store, 5).unwrap();
            let ck = revffn::checkpoint::load(&path).unwrap();
            ck.step == 5
                && ck.tensors.len() == tensors.len()
                && ck.tensors.iter().zip(tensors).all(|(a, b)| a.0 == b.0 && a.2 == b.2)
        });
}

#[test]
fn prop_lang_b_preserves_structure() {
    use revffn::data::synthetic::to_lang_b;
    prop_check("lang-b-structure", 100, 47,
        |rng| gen::string(rng, 50),
        |s| {
            let b = to_lang_b(s);
            b.chars().count() == s.chars().count()
                && s.chars().zip(b.chars()).all(|(x, y)| {
                    x.is_ascii_alphabetic() == y.is_ascii_alphabetic()
                        && (!x.is_ascii_alphabetic() || x != y || !x.is_ascii_alphabetic())
                        && (x.is_ascii_uppercase() == y.is_ascii_uppercase())
                })
        });
}

#[test]
fn prop_rng_shuffle_uniformish() {
    // sanity: over many shuffles of [0,1,2], each permutation appears
    let mut counts = std::collections::HashMap::new();
    let mut rng = Rng::seed_from_u64(51);
    for _ in 0..600 {
        let mut v = vec![0, 1, 2];
        rng.shuffle(&mut v);
        *counts.entry(v).or_insert(0) += 1;
    }
    assert_eq!(counts.len(), 6, "all 6 permutations must occur");
    assert!(counts.values().all(|&c| c > 40), "roughly uniform: {counts:?}");
}
