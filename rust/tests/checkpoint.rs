//! Crash-safety tests for the `.rvt` checkpoint format that need no
//! XLA device or artifacts — they run everywhere (tier-1).
//!
//! Complementing the unit tests in `checkpoint/mod.rs` (targeted
//! corrupt-header cases), these sweep randomized corruption over real
//! RVT2 bytes: whatever the mutation, `load` must return a clean error
//! — never panic, never allocate past the file size — and a valid file
//! must keep round-tripping the full training state.

use revffn::checkpoint::{
    latest_checkpoint, latest_valid_checkpoint, load, load_cursor, load_params, periodic_path,
    prune_checkpoints, restore_into, save, save_state, OptMoments, RunCursor,
};
use revffn::error::Error;
use revffn::runtime::artifact::TensorSpec;
use revffn::runtime::store::ParamStore;
use revffn::util::{Rng, ScratchDir};

fn spec(name: &str, shape: Vec<usize>) -> TensorSpec {
    let n: usize = shape.iter().product::<usize>().max(1);
    TensorSpec {
        name: name.into(),
        shape,
        dtype: "f32".into(),
        blob: "x".into(),
        offset: 0,
        nbytes: n * 4,
    }
}

fn store() -> ParamStore {
    let specs = vec![
        spec("embed", vec![6, 3]),
        spec("layer.0.w", vec![3, 3]),
        spec("norm_f", vec![3]),
    ];
    let host = vec![
        (0..18).map(|i| i as f32 * 0.5).collect(),
        (0..9).map(|i| -(i as f32)).collect(),
        vec![1.0, 2.0, 3.0],
    ];
    ParamStore::from_host(specs, host).unwrap()
}

fn moments() -> OptMoments {
    OptMoments {
        m: vec![(vec![3, 3], vec![0.25; 9]), (vec![3], vec![0.5; 3])],
        v: vec![(vec![3, 3], vec![0.0625; 9]), (vec![3], vec![1.5; 3])],
    }
}

fn cursor() -> RunCursor {
    RunCursor {
        phase_idx: 1,
        step_in_phase: 11,
        batches_taken: 22,
        batch_seed: 0xdead_beef,
        seq: 35,
        steps_total: 13,
    }
}

#[test]
fn full_state_survives_the_roundtrip() {
    let dir = ScratchDir::new("rvt2-roundtrip").unwrap();
    let p = dir.join("state.rvt");
    save_state(&p, &store(), 13, Some(&moments()), Some(&cursor())).unwrap();

    let ck = load(&p).unwrap();
    assert_eq!(ck.step, 13);
    assert_eq!(ck.cursor.unwrap(), cursor());
    assert_eq!(ck.opt.unwrap(), moments());
    let mut fresh = store();
    fresh.set_tensor("norm_f", vec![0.0; 3]).unwrap();
    assert_eq!(restore_into(&ck, &mut fresh).unwrap(), 3);
    assert_eq!(fresh.tensor("norm_f").unwrap(), &[1.0, 2.0, 3.0]);

    // the cursor-only fast path reads the same cursor without
    // materializing tensors
    assert_eq!(load_cursor(&p).unwrap(), Some(cursor()));

    // the params-only fast path seeks past the moments but delivers
    // identical tensors + cursor
    let lean = load_params(&p).unwrap();
    assert_eq!(lean.step, 13);
    assert_eq!(lean.tensors, load(&p).unwrap().tensors);
    assert!(lean.opt.is_none(), "load_params must not materialize moments");
    assert_eq!(lean.cursor.unwrap(), cursor());
}

#[test]
fn rvt1_files_still_load_params_only() {
    let dir = ScratchDir::new("rvt1-compat").unwrap();
    let p = dir.join("old.rvt");
    save(&p, &store(), 7).unwrap();
    let ck = load(&p).unwrap();
    assert_eq!(ck.step, 7);
    assert_eq!(ck.tensors.len(), 3);
    assert!(ck.opt.is_none());
    assert!(ck.cursor.is_none());
    assert_eq!(load_cursor(&p).unwrap(), None, "RVT1 has no cursor to fast-read");
}

/// Randomized corruption sweep: flip/overwrite bytes all over valid
/// RVT2 bytes. Every mutant must either load (the mutation hit tensor
/// payload, which carries no structure) or fail with a typed error —
/// never panic, never OOM on a fabricated length field.
#[test]
fn randomly_corrupted_files_fail_cleanly() {
    let dir = ScratchDir::new("rvt2-fuzz").unwrap();
    let p = dir.join("state.rvt");
    save_state(&p, &store(), 13, Some(&moments()), Some(&cursor())).unwrap();
    let pristine = std::fs::read(&p).unwrap();

    let mut rng = Rng::seed_from_u64(0x5eed);
    let probe = dir.join("mutant.rvt");
    for round in 0..500 {
        let mut bytes = pristine.clone();
        match round % 3 {
            // single-byte flip
            0 => {
                let i = rng.gen_range(0..bytes.len());
                bytes[i] ^= (rng.next_u32() % 255 + 1) as u8;
            }
            // 4-byte overwrite (fabricates length/dim fields)
            1 => {
                let i = rng.gen_range(0..bytes.len().saturating_sub(4));
                let v = rng.next_u32().to_le_bytes();
                bytes[i..i + 4].copy_from_slice(&v);
            }
            // truncate at a random point
            _ => {
                bytes.truncate(rng.gen_range(0..bytes.len()));
            }
        }
        std::fs::write(&probe, &bytes).unwrap();
        match load(&probe) {
            Ok(_) => {} // payload-only damage: structurally fine
            Err(Error::Parse(_)) | Err(Error::Layout(_)) => {}
            Err(other) => panic!("round {round}: unexpected error class {other}"),
        }
        // the seek-based readers must be equally robust
        match load_cursor(&probe) {
            Ok(_) => {}
            Err(Error::Parse(_)) | Err(Error::Layout(_)) => {}
            Err(other) => panic!("round {round}: load_cursor error class {other}"),
        }
        match load_params(&probe) {
            Ok(_) => {}
            Err(Error::Parse(_)) | Err(Error::Layout(_)) => {}
            Err(other) => panic!("round {round}: load_params error class {other}"),
        }
    }
}

/// A length field pointing gigabytes past the end of the file must be
/// rejected up front — bounded by the file size — instead of reserving
/// a huge buffer and failing on read.
#[test]
fn fabricated_lengths_never_outallocate_the_file() {
    let dir = ScratchDir::new("rvt2-bound").unwrap();
    let p = dir.join("state.rvt");
    save_state(&p, &store(), 1, Some(&moments()), None).unwrap();
    let pristine = std::fs::read(&p).unwrap();
    let probe = dir.join("evil.rvt");
    // overwrite every aligned u32 position with u32::MAX — any length
    // or dim field it lands on now claims ~4 GB
    for at in (4..pristine.len().saturating_sub(4)).step_by(4) {
        let mut bytes = pristine.clone();
        bytes[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&probe, &bytes).unwrap();
        match load(&probe) {
            Ok(_) | Err(Error::Parse(_)) | Err(Error::Layout(_)) => {}
            Err(other) => panic!("offset {at}: unexpected error class {other}"),
        }
    }
}

#[test]
fn retention_keeps_newest_and_writes_are_atomic() {
    let dir = ScratchDir::new("rvt2-retain").unwrap();
    let s = store();
    for step in 1..=6u64 {
        save_state(periodic_path(&dir.path, 0, step), &s, step, None, None).unwrap();
        prune_checkpoints(&dir.path, 2);
    }
    // only the two newest remain, no tmp residue, latest wins
    let names: Vec<String> = {
        let mut v: Vec<String> = std::fs::read_dir(&dir.path)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        v.sort();
        v
    };
    assert_eq!(names, vec!["ckpt-p00-s00000005.rvt", "ckpt-p00-s00000006.rvt"]);
    assert_eq!(latest_checkpoint(&dir.path).unwrap(), periodic_path(&dir.path, 0, 6));
    // every surviving file is complete and loadable (atomicity: a
    // half-written file would have been left as .tmp, never .rvt)
    for n in names {
        load(dir.join(&n)).unwrap();
    }
}

#[test]
fn torn_newest_snapshot_falls_back_to_older_one() {
    // a power loss right after rename can leave the newest file
    // truncated — discovery must fall back to the intact predecessor
    // instead of losing the run to its own freshest checkpoint
    let dir = ScratchDir::new("rvt2-torn").unwrap();
    let s = store();
    save_state(periodic_path(&dir.path, 0, 2), &s, 2, None, Some(&cursor())).unwrap();
    save_state(periodic_path(&dir.path, 0, 4), &s, 4, None, Some(&cursor())).unwrap();
    // tear the newest: keep only the first 40 bytes
    let newest = periodic_path(&dir.path, 0, 4);
    let bytes = std::fs::read(&newest).unwrap();
    std::fs::write(&newest, &bytes[..40]).unwrap();

    assert_eq!(latest_checkpoint(&dir.path).unwrap(), newest, "raw discovery is unchanged");
    assert_eq!(
        latest_valid_checkpoint(&dir.path).unwrap(),
        periodic_path(&dir.path, 0, 2),
        "valid discovery must skip the torn file"
    );

    // both torn: nothing to resume
    let older = periodic_path(&dir.path, 0, 2);
    let bytes = std::fs::read(&older).unwrap();
    std::fs::write(&older, &bytes[..7]).unwrap();
    assert!(latest_valid_checkpoint(&dir.path).is_none());
}

#[test]
fn cursor_extremes_roundtrip() {
    let dir = ScratchDir::new("rvt2-extremes").unwrap();
    let p = dir.join("edge.rvt");
    let edge = RunCursor {
        phase_idx: 0,
        step_in_phase: u64::MAX,
        batches_taken: u64::MAX,
        batch_seed: u64::MAX,
        seq: 0,
        steps_total: u64::MAX,
    };
    save_state(&p, &store(), u64::MAX, None, Some(&edge)).unwrap();
    let ck = load(&p).unwrap();
    assert_eq!(ck.step, u64::MAX);
    assert_eq!(ck.cursor.unwrap(), edge);
}
