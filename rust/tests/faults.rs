//! Fault-injection matrix (docs/ROBUSTNESS.md): every injection site
//! driven through its real failure surface, asserting the system either
//! recovers bit-identically or lands in the right degraded state.
//!
//! Device-free tests (checkpoint sites, plan plumbing) run everywhere —
//! tier-1. Device tests (execute faults → supervised retry / quarantine
//! / watchdog) skip silently when `artifacts/tiny` is absent, like the
//! other integration suites.
//!
//! Fault plans are process-global: every test that installs one holds
//! `faults::test_lock()` for its whole body and clears on entry, so the
//! suite is safe under the default parallel test runner.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use revffn::checkpoint::{
    latest_valid_checkpoint, load, periodic_path, save_state, OptMoments, RunCursor,
};
use revffn::config::{PriceGeometry, RunConfig, ServeConfig};
use revffn::coordinator::Trainer;
use revffn::engine::Method;
use revffn::runtime::artifact::TensorSpec;
use revffn::runtime::store::ParamStore;
use revffn::runtime::Device;
use revffn::serve::{JobState, Scheduler};
use revffn::util::faults::{self, FaultPlan, FaultSite};
use revffn::util::json;
use revffn::util::ScratchDir;

// ---------------------------------------------------------------- fixtures

fn spec(name: &str, shape: Vec<usize>) -> TensorSpec {
    let n: usize = shape.iter().product::<usize>().max(1);
    TensorSpec { name: name.into(), shape, dtype: "f32".into(), blob: "x".into(), offset: 0, nbytes: n * 4 }
}

fn store() -> ParamStore {
    let specs = vec![spec("embed", vec![4, 3]), spec("norm_f", vec![3])];
    let host = vec![(0..12).map(|i| i as f32 * 0.5).collect(), vec![1.0, 2.0, 3.0]];
    ParamStore::from_host(specs, host).unwrap()
}

fn moments() -> OptMoments {
    OptMoments {
        m: vec![(vec![4, 3], vec![0.25; 12]), (vec![3], vec![0.5; 3])],
        v: vec![(vec![4, 3], vec![0.0625; 12]), (vec![3], vec![1.5; 3])],
    }
}

fn cursor(step: u64) -> RunCursor {
    RunCursor {
        phase_idx: 0,
        step_in_phase: step,
        batches_taken: step,
        batch_seed: 7,
        seq: step,
        steps_total: step,
    }
}

fn artifacts_root() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    p.join("index.json").exists().then_some(p)
}

/// A short single-stage SFT run (steps are unique per stage).
fn job_cfg(root: &Path, out: &Path) -> RunConfig {
    let mut cfg = RunConfig::default_tiny(root);
    cfg.method = Method::Sft;
    cfg.schedule.stage1_steps = 0;
    cfg.schedule.stage2_steps = 4;
    cfg.schedule.warmup_steps = 1;
    cfg.data.pretrain_steps = 0;
    cfg.data.n_train = 48;
    cfg.data.n_eval = 16;
    cfg.eval_every = 0;
    cfg.eval_batches = 1;
    cfg.checkpoint_every = 2;
    cfg.out_dir = out.into();
    cfg
}

/// Serve options with fast supervised retries (1ms base backoff).
fn sup_opts(root: &Path, scratch: &Path, max_attempts: u32) -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        artifacts: root.to_path_buf(),
        budget_gb: 1e9,
        quantum: 2,
        assumptions: "f32".into(),
        price_geometry: PriceGeometry::Manifest,
        run_root: scratch.join("serve"),
        checkpoint_every: 0,
        recover: false,
        retry_max_attempts: max_attempts,
        retry_base_ms: 1,
        retry_max_ms: 4,
        ..ServeConfig::default()
    }
}

/// Per-job (stage, step) → loss-bits map, LAST event wins — replayed
/// steps after a supervised retry overwrite their first emission, so
/// the map is the deterministic projection of a recovered stream.
fn step_map(events: &[String]) -> HashMap<(u64, u64), u32> {
    events
        .iter()
        .map(|l| json::parse(l).unwrap())
        .filter(|j| j.str_of("type").unwrap() == "step")
        .map(|j| {
            (
                (j.u64_of("stage").unwrap(), j.u64_of("step").unwrap()),
                (j.f64_of("loss").unwrap() as f32).to_bits(),
            )
        })
        .collect()
}

// ------------------------------------------------- device-free: checkpoint

#[test]
fn ckpt_write_error_fault_fails_save_and_leaves_no_snapshot() {
    let _g = faults::test_lock();
    faults::clear();
    let dir = ScratchDir::new("fault-ckpt-write").unwrap();
    let p = dir.join("state.rvt");

    faults::install(FaultPlan::parse("ckpt_write@1:error").unwrap());
    let err = save_state(&p, &store(), 1, Some(&moments()), Some(&cursor(1)));
    assert!(err.is_err(), "injected write fault must fail the save");
    assert!(!p.exists(), "no snapshot may appear after a failed write");

    // the window has passed: the next save succeeds and round-trips
    let saved = save_state(&p, &store(), 2, Some(&moments()), Some(&cursor(2)));
    assert!(saved.is_ok(), "{saved:?}");
    assert_eq!(load(&p).unwrap().step, 2);
    faults::clear();
}

#[test]
fn ckpt_fsync_and_rename_faults_fail_save_atomically() {
    let _g = faults::test_lock();
    faults::clear();
    let dir = ScratchDir::new("fault-ckpt-fsync").unwrap();

    for plan in ["ckpt_fsync@1:error", "ckpt_rename@1:error"] {
        let p = dir.join(format!("{}.rvt", plan.split('@').next().unwrap()));
        faults::install(FaultPlan::parse(plan).unwrap());
        assert!(
            save_state(&p, &store(), 1, Some(&moments()), Some(&cursor(1))).is_err(),
            "{plan} must fail the save"
        );
        assert!(!p.exists(), "{plan}: the final path must never materialize");
        faults::clear();
    }
}

#[test]
fn torn_ckpt_write_is_skipped_by_latest_valid_checkpoint() {
    let _g = faults::test_lock();
    faults::clear();
    let dir = ScratchDir::new("fault-ckpt-torn").unwrap();
    let out = dir.join("out");

    // a good snapshot at step 2, then a torn one at step 4
    let good = periodic_path(&out, 0, 2);
    save_state(&good, &store(), 2, Some(&moments()), Some(&cursor(2))).unwrap();
    faults::install(FaultPlan::parse("seed=3; ckpt_write@1:torn").unwrap());
    let torn = periodic_path(&out, 0, 4);
    save_state(&torn, &store(), 4, Some(&moments()), Some(&cursor(4))).unwrap();
    faults::clear();

    // the torn file exists (it renamed into place) but cannot load —
    // exactly the crash shape latest_valid_checkpoint exists to skip
    assert!(torn.exists());
    assert!(load(&torn).is_err(), "torn snapshot must not parse");
    assert_eq!(
        latest_valid_checkpoint(&out),
        Some(good),
        "resume must fall back to the newest snapshot that parses"
    );
}

#[test]
fn delay_fault_stalls_but_preserves_the_snapshot() {
    let _g = faults::test_lock();
    faults::clear();
    let dir = ScratchDir::new("fault-ckpt-delay").unwrap();
    let p = dir.join("state.rvt");
    faults::install(FaultPlan::parse("ckpt_write@1:delay=10").unwrap());
    let t0 = std::time::Instant::now();
    save_state(&p, &store(), 3, Some(&moments()), Some(&cursor(3))).unwrap();
    assert!(t0.elapsed() >= std::time::Duration::from_millis(10));
    assert_eq!(load(&p).unwrap().step, 3, "a delay fault must not corrupt the write");
    faults::clear();
}

// ------------------------------------------------------ device: supervision

#[test]
fn execute_fault_retries_with_backoff_and_finishes_bit_identical() {
    let Some(root) = artifacts_root() else { return };
    let _g = faults::test_lock();
    faults::clear();
    let scratch = ScratchDir::new("fault-retry").unwrap();

    // fault-free solo baseline
    let solo: HashMap<(u64, u64), u32> = {
        let device = Device::cpu().unwrap();
        let mut t = Trainer::new(&device, job_cfg(&root, &scratch.join("solo"))).unwrap();
        t.run().unwrap();
        t.metrics.steps.iter().map(|r| ((r.stage as u64, r.step), r.loss.to_bits())).collect()
    };

    // the 3rd program execute fails once, mid-run (past the step-2
    // periodic snapshot, before the schedule ends)
    faults::install(FaultPlan::parse("pjrt_execute@3:error").unwrap());
    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, sup_opts(&root, &scratch, 3)).unwrap();
    let a = sched.submit(job_cfg(&root, &scratch.join("faulted")), Some("a".into())).unwrap();
    assert!(a.admitted);
    sched.run_until_idle().unwrap();
    faults::clear();

    assert_eq!(sched.job_state(&a.id), Some(JobState::Finished));
    assert_eq!(faults::fired(FaultSite::PjrtExecute), 0, "plan cleared");
    let board = sched.board();
    let board = board.lock().unwrap();
    let snap = &board.jobs[0].snap;
    assert_eq!(snap.attempts, 1, "exactly one supervised retry");
    assert!(snap.error.is_none(), "a recovered job reports no error");
    assert_eq!(
        step_map(&board.jobs[0].events.to_vec()),
        solo,
        "recovered run must be bit-identical to the fault-free solo run"
    );
}

#[test]
fn persistent_execute_fault_quarantines_with_failure_chain() {
    let Some(root) = artifacts_root() else { return };
    let _g = faults::test_lock();
    faults::clear();
    let scratch = ScratchDir::new("fault-quarantine").unwrap();

    // every execute fails, forever — retries burn down via the failing
    // device-health probe, then the job quarantines
    faults::install(FaultPlan::parse("pjrt_execute@1x0:error").unwrap());
    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, sup_opts(&root, &scratch, 2)).unwrap();
    let a = sched.submit(job_cfg(&root, &scratch.join("dead")), Some("a".into())).unwrap();
    sched.run_until_idle().unwrap();
    faults::clear();

    assert_eq!(sched.job_state(&a.id), Some(JobState::Quarantined));
    {
        let board = sched.board();
        let board = board.lock().unwrap();
        let snap = &board.jobs[0].snap;
        assert_eq!(snap.attempts, 3, "max_attempts=2 allows 3 total failures");
        let chain = snap.error.clone().expect("quarantine must carry the failure chain");
        assert!(chain.contains("attempt 1:"), "chain lists each failure: {chain}");
        assert!(chain.contains("attempt 3:"), "chain lists each failure: {chain}");
        assert!(chain.contains("injected fault"), "{chain}");
        assert!(chain.contains("device health probe"), "probe failures join the chain: {chain}");
    }

    // the device is healthy again: other jobs proceed
    let b = sched.submit(job_cfg(&root, &scratch.join("alive")), Some("b".into())).unwrap();
    sched.run_until_idle().unwrap();
    assert_eq!(sched.job_state(&b.id), Some(JobState::Finished));

    // the resume verb accepts the quarantined state (every execute
    // failed, so no snapshot was ever written — the state gate must
    // pass and the snapshot check must be what rejects it)
    let err = sched.resume_job(&a.id).expect_err("no snapshot exists to resume from");
    let msg = err.to_string();
    assert!(msg.contains("no periodic snapshot"), "state gate must accept quarantined: {msg}");
}

#[test]
fn watchdog_fails_a_stalled_quantum_and_the_retry_finishes() {
    let Some(root) = artifacts_root() else { return };
    let _g = faults::test_lock();
    faults::clear();
    let scratch = ScratchDir::new("fault-watchdog").unwrap();

    // the 3rd execute stalls well past the quantum deadline, once
    faults::install(FaultPlan::parse("pjrt_execute@3:delay=1500").unwrap());
    let mut opts = sup_opts(&root, &scratch, 3);
    opts.quantum_deadline_ms = 250;
    let device = Device::cpu().unwrap();
    let mut sched = Scheduler::new(device, opts).unwrap();
    let a = sched.submit(job_cfg(&root, &scratch.join("stall")), Some("a".into())).unwrap();
    sched.run_until_idle().unwrap();
    faults::clear();

    assert_eq!(sched.job_state(&a.id), Some(JobState::Finished));
    let board = sched.board();
    let board = board.lock().unwrap();
    assert!(
        board.jobs[0].snap.attempts >= 1,
        "the stalled quantum must have tripped the watchdog"
    );
    assert_eq!(board.committed_gb, 0.0, "budget fully released after recovery");
}
