//! Hot-path integration tests: the literal-resident accumulate loop must
//! produce the same mean gradient as the legacy host-summing path, and
//! the prefetch pipeline must deliver exactly the synchronous batcher's
//! sequence.
//!
//! The accumulation parity tests skip silently when `artifacts/tiny` is
//! absent (run `make artifacts` first); the pipeline tests are pure.

use std::path::PathBuf;

use revffn::data::synthetic::{Corpus, CorpusConfig};
use revffn::data::{encode_corpus, Batcher, Pipeline, Tokenizer};
use revffn::runtime::literal::to_f32_vec;
use revffn::runtime::{Artifact, Batch, Device, GradAccumulator, ProgramCache, Stepper};

fn artifacts_root() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    p.join("index.json").exists().then_some(p)
}

/// Stepper + two deterministic batches for the revffn_stage2 variant.
fn stage2_fixture(device: &Device, cache: &ProgramCache) -> Option<(Stepper, Vec<Batch>)> {
    let root = artifacts_root()?;
    let artifact = Artifact::load(root.join("revffn_stage2")).ok()?;
    let stepper = Stepper::new(device, cache, artifact).ok()?;
    if !stepper.supports_accumulation() {
        return None;
    }
    let (b, s) = stepper.batch_shape();
    let corpus = Corpus::generate(CorpusConfig { n_train: 64, ..Default::default() });
    let tokenizer = Tokenizer::train(&corpus.train_text(), stepper.vocab_size()).ok()?;
    let samples = encode_corpus(&tokenizer, &corpus.train, s);
    let mut batcher = Batcher::new(samples, b, s, 3);
    let batches = (0..2).map(|_| batcher.next_batch()).collect();
    Some((stepper, batches))
}

#[test]
fn accumulate_literal_path_matches_host_summing() {
    let device = Device::cpu().unwrap();
    let cache = ProgramCache::new();
    let Some((stepper, batches)) = stage2_fixture(&device, &cache) else { return };

    // literal-resident path: gradients never materialized on host until
    // this test downloads the final mean for comparison
    let mut acc = GradAccumulator::for_stepper(&stepper);
    for batch in &batches {
        acc.add(stepper.grad_step_literals(batch).unwrap().grads).unwrap();
    }
    assert_eq!(acc.count(), 2);
    let mean_lits = acc.finish().unwrap();
    let mean_dev: Vec<Vec<f32>> =
        mean_lits.iter().map(|l| to_f32_vec(l).unwrap()).collect();

    // legacy host-summing path over the SAME batches
    let mut host_sum: Option<Vec<Vec<f32>>> = None;
    for batch in &batches {
        let (g, _loss, _aux) = stepper.grad_step(batch).unwrap();
        match host_sum.as_mut() {
            None => host_sum = Some(g),
            Some(acc) => {
                for (a, gi) in acc.iter_mut().zip(&g) {
                    for (x, y) in a.iter_mut().zip(gi) {
                        *x += *y;
                    }
                }
            }
        }
    }
    let mut host_mean = host_sum.unwrap();
    for g in host_mean.iter_mut() {
        for x in g.iter_mut() {
            *x *= 0.5;
        }
    }

    assert_eq!(mean_dev.len(), host_mean.len());
    for (td, (d, h)) in mean_dev.iter().zip(&host_mean).enumerate() {
        assert_eq!(d.len(), h.len(), "tensor {td} length");
        for (i, (x, y)) in d.iter().zip(h).enumerate() {
            assert!(
                (x - y).abs() <= 1e-5 + 1e-4 * y.abs(),
                "tensor {td} elem {i}: device {x} vs host {y}"
            );
        }
    }
}

#[test]
fn forced_host_fallback_matches_device_accumulator() {
    let device = Device::cpu().unwrap();
    let cache = ProgramCache::new();
    let Some((stepper, batches)) = stage2_fixture(&device, &cache) else { return };

    let mut dev_acc = GradAccumulator::for_stepper(&stepper);
    // fallback accumulator: no compiled accum/scale pair
    let mut host_acc = GradAccumulator::new(None, None, stepper.trainable_shapes());
    assert!(!host_acc.is_device_resident());

    // two optimizer steps through the SAME recycled accumulators — the
    // second exercises buffer reuse after finish()
    for _ in 0..2 {
        for batch in &batches {
            dev_acc.add(stepper.grad_step_literals(batch).unwrap().grads).unwrap();
            host_acc.add(stepper.grad_step_literals(batch).unwrap().grads).unwrap();
        }
        let dev = dev_acc.finish().unwrap();
        let host = host_acc.finish().unwrap();
        assert_eq!(dev_acc.count(), 0);
        for (d_lit, h_lit) in dev.iter().zip(&host) {
            let d = to_f32_vec(d_lit).unwrap();
            let h = to_f32_vec(h_lit).unwrap();
            for (x, y) in d.iter().zip(&h) {
                assert!((x - y).abs() <= 1e-5 + 1e-4 * y.abs());
            }
        }
    }
}

#[test]
fn accumulate_grad_norm_comparable_to_fused_steps() {
    let device = Device::cpu().unwrap();
    let cache = ProgramCache::new();
    let Some((mut stepper_a, batches)) = stage2_fixture(&device, &cache) else { return };

    // grad_accum=2, literal-resident: one update on the mean gradient
    let mut acc = GradAccumulator::for_stepper(&stepper_a);
    for batch in &batches {
        acc.add(stepper_a.grad_step_literals(batch).unwrap().grads).unwrap();
    }
    let mean = acc.finish().unwrap();
    let (gn_accum, _t) = stepper_a.apply_accumulated(&mean, 1e-4).unwrap();

    // two fused steps over the same batches (params drift by one tiny
    // update between them, and per-microbatch norms average >= the
    // mean-gradient norm, so the comparison is a band, not an equality)
    let (mut stepper_b, _) = stage2_fixture(&device, &cache).unwrap();
    let mut gn_sum = 0.0f32;
    for batch in &batches {
        gn_sum += stepper_b.train_step(batch, 1e-4).unwrap().grad_norm;
    }
    let gn_fused = gn_sum / 2.0;

    assert!(gn_accum.is_finite() && gn_accum >= 0.0);
    assert!(
        gn_accum <= gn_fused * 1.5 + 1e-3,
        "mean-gradient norm {gn_accum} should not exceed the averaged per-batch norms {gn_fused}"
    );
    assert!(
        gn_accum >= gn_fused * 0.2 - 1e-3,
        "mean-gradient norm {gn_accum} collapsed vs per-batch norms {gn_fused}"
    );
}

#[test]
fn pipeline_delivers_synchronous_sequence_on_real_corpus() {
    // pure (no artifacts): the prefetch pipeline over an encoded corpus
    // must be bit-identical to the synchronous batcher with the same seed
    let corpus = Corpus::generate(CorpusConfig { n_train: 48, ..Default::default() });
    let tokenizer = Tokenizer::train(&corpus.train_text(), 256).unwrap();
    let samples = encode_corpus(&tokenizer, &corpus.train, 32);
    assert!(!samples.is_empty());

    let mut sync = Batcher::new(samples.clone(), 4, 32, 11);
    let mut pipe = Pipeline::spawn(Batcher::new(samples, 4, 32, 11));
    for _ in 0..3 * 12 {
        // several epochs worth, so reshuffles are covered too
        let got = pipe.next_batch().unwrap();
        let want = sync.next_batch();
        assert_eq!(got.tokens, want.tokens);
        assert_eq!(got.targets, want.targets);
        assert_eq!(got.loss_mask, want.loss_mask);
        pipe.recycle(got);
    }
}
